//! Shared L2 cache model (§IV-A: SM-level partitioning would still suffer
//! interference on the shared L2/TLB; context switches evict useful lines).
//!
//! The model is ownership-based: the L2 remembers which context's working
//! set it currently holds and how much of the cache each context's recent
//! kernels cover. A kernel from a context that does not own the cache pays
//! a cold-start penalty on its first batches proportional to how much of
//! its footprint was evicted — the "cache-related preemption delay" the
//! paper attributes to context switching (§VII-B).

use crate::util::CtxId;

#[derive(Debug)]
pub struct L2State {
    capacity: u64,
    /// Context whose working set currently dominates the cache.
    owner: Option<CtxId>,
    /// Bytes of the owner's working set resident.
    resident: u64,
}

impl L2State {
    pub fn new(capacity: u64) -> Self {
        Self { capacity, owner: None, resident: 0 }
    }

    pub fn owner(&self) -> Option<CtxId> {
        self.owner
    }

    /// A kernel from `ctx` with `footprint` bytes begins executing.
    /// Returns the *cold fraction* in [0, 1]: how much of its footprint
    /// must be (re)fetched because another context owned the cache.
    pub fn touch(&mut self, ctx: CtxId, footprint: u64) -> f64 {
        let fp = footprint.min(self.capacity.max(1));
        let cold = match self.owner {
            Some(o) if o == ctx => {
                // Warm owner: only the part beyond what is resident misses.
                if fp <= self.resident {
                    0.0
                } else {
                    (fp - self.resident) as f64 / fp.max(1) as f64
                }
            }
            Some(_) => 1.0, // other context evicted us
            None => 1.0,    // first touch ever
        };
        self.owner = Some(ctx);
        self.resident = self.resident.max(fp).min(self.capacity);
        if cold >= 1.0 {
            self.resident = fp;
        }
        cold
    }

    /// Model a pure eviction event (e.g. copy engine streaming through L2).
    pub fn pollute(&mut self, bytes: u64) {
        self.resident = self.resident.saturating_sub(bytes);
        if self.resident == 0 {
            self.owner = None;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_touch_is_fully_cold() {
        let mut l2 = L2State::new(512 * 1024);
        assert_eq!(l2.touch(CtxId(0), 100 * 1024), 1.0);
    }

    #[test]
    fn repeated_touch_same_ctx_is_warm() {
        let mut l2 = L2State::new(512 * 1024);
        l2.touch(CtxId(0), 100 * 1024);
        assert_eq!(l2.touch(CtxId(0), 100 * 1024), 0.0);
        // A larger footprint is partially cold.
        let cold = l2.touch(CtxId(0), 200 * 1024);
        assert!(cold > 0.4 && cold < 0.6, "cold={cold}");
    }

    #[test]
    fn other_context_evicts() {
        let mut l2 = L2State::new(512 * 1024);
        l2.touch(CtxId(0), 100 * 1024);
        assert_eq!(l2.touch(CtxId(1), 100 * 1024), 1.0);
        assert_eq!(l2.owner(), Some(CtxId(1)));
        // And the original context is now cold again.
        assert_eq!(l2.touch(CtxId(0), 100 * 1024), 1.0);
    }

    #[test]
    fn footprint_clamped_to_capacity() {
        let mut l2 = L2State::new(1024);
        let cold = l2.touch(CtxId(0), 10 * 1024 * 1024);
        assert_eq!(cold, 1.0);
        assert_eq!(l2.touch(CtxId(0), 1024), 0.0); // resident == capacity
    }

    #[test]
    fn pollution_degrades_residency() {
        let mut l2 = L2State::new(512 * 1024);
        l2.touch(CtxId(0), 400 * 1024);
        l2.pollute(300 * 1024);
        let cold = l2.touch(CtxId(0), 400 * 1024);
        assert!(cold > 0.7, "cold={cold}");
        l2.pollute(u64::MAX);
        assert_eq!(l2.owner(), None);
    }
}
