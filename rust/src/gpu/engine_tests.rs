//! Engine-level tests: single-app progress, strategy behaviours,
//! invariants the rest of the evaluation relies on.

use super::engine::Sim;
use crate::apps::program::{Program, RepeatMode};
use crate::config::{SimConfig, StrategyKind};
use crate::cudart::{Grid, KernelDesc};
use crate::util::AppId;

fn kernel() -> KernelDesc {
    KernelDesc::compute("test_k", Grid::new(16, 256), 20_000)
        .with_l2_footprint(256 * 1024)
}

fn burst_program(n: usize) -> Program {
    Program::kernel_burst("bench", kernel(), n)
}

fn cfg(strategy: StrategyKind) -> SimConfig {
    SimConfig::default().with_strategy(strategy).with_seed(42)
}

fn run(strategy: StrategyKind, programs: Vec<Program>) -> Sim {
    let mut sim = Sim::new(cfg(strategy), programs);
    sim.run();
    sim
}

#[test]
fn single_app_single_kernel_completes() {
    let p = Program::new("one", RepeatMode::Once)
        .launch(kernel())
        .sync()
        .mark_completion();
    let sim = run(StrategyKind::None, vec![p]);
    assert!(!sim.horizon_reached(), "must finish before horizon");
    assert_eq!(sim.completions(AppId(0)).len(), 1);
    let kt = sim.trace.kernel_exec_times(AppId(0));
    assert_eq!(kt.len(), 1);
    assert!(kt[0] > 0);
}

#[test]
fn burst_runs_all_kernels_in_order() {
    let sim = run(StrategyKind::None, vec![burst_program(10)]);
    let recs: Vec<_> = sim.trace.kernel_ops(AppId(0)).collect();
    assert_eq!(recs.len(), 10);
    // FIFO: starts must be non-decreasing and each op starts after the
    // previous completed (single stream).
    for w in recs.windows(2) {
        assert!(w[1].started_at >= w[0].completed_at, "stream FIFO violated");
    }
}

#[test]
fn copies_and_kernels_complete() {
    let p = Program::new("mix", RepeatMode::Once)
        .memcpy_h2d(1 << 20)
        .launch(kernel())
        .memcpy_d2h(1 << 16)
        .sync()
        .mark_completion();
    let sim = run(StrategyKind::None, vec![p]);
    assert_eq!(sim.completions(AppId(0)).len(), 1);
    let copies = sim.trace.ops.iter().filter(|r| r.is_copy).count();
    assert_eq!(copies, 2);
}

#[test]
fn all_strategies_complete_the_same_workload() {
    for s in StrategyKind::ALL {
        let sim = run(s, vec![burst_program(20)]);
        assert_eq!(
            sim.trace.kernel_ops(AppId(0)).count(),
            20,
            "strategy {s} lost kernels"
        );
        assert_eq!(sim.completions(AppId(0)).len(), 1, "strategy {s}");
    }
}

#[test]
fn parallel_apps_complete_under_all_strategies() {
    for s in StrategyKind::ALL {
        let sim = run(s, vec![burst_program(15), burst_program(15)]);
        for a in 0..2 {
            assert_eq!(
                sim.trace.kernel_ops(AppId(a)).count(),
                15,
                "strategy {s} app {a}"
            );
            assert_eq!(sim.completions(AppId(a)).len(), 1, "strategy {s} app {a}");
        }
    }
}

#[test]
fn synced_and_worker_isolate_parallel_kernels() {
    for s in [StrategyKind::Synced, StrategyKind::Worker] {
        let sim = run(s, vec![burst_program(25), burst_program(25)]);
        assert_eq!(
            sim.trace.cross_app_kernel_overlaps(),
            0,
            "{s} must isolate GPU operations (§VII-B)"
        );
    }
}

#[test]
fn none_overlaps_parallel_kernels() {
    let sim = run(StrategyKind::None, vec![burst_program(40), burst_program(40)]);
    assert!(
        sim.trace.cross_app_kernel_overlaps() > 0,
        "unmitigated parallel execution must interleave kernels"
    );
}

#[test]
fn parallel_is_slower_than_isolation() {
    let iso = run(StrategyKind::None, vec![burst_program(50)]);
    let par = run(StrategyKind::None, vec![burst_program(50), burst_program(50)]);
    let iso_end = *iso.completions(AppId(0)).last().unwrap();
    let par_end = *par.completions(AppId(0)).last().unwrap();
    assert!(
        par_end > iso_end * 3 / 2,
        "sharing the GPU must cost >1.5x (got {iso_end} vs {par_end})"
    );
}

#[test]
fn deterministic_same_seed_same_trace() {
    let a = run(StrategyKind::None, vec![burst_program(30), burst_program(30)]);
    let b = run(StrategyKind::None, vec![burst_program(30), burst_program(30)]);
    assert_eq!(a.trace.ops.len(), b.trace.ops.len());
    for (x, y) in a.trace.ops.iter().zip(&b.trace.ops) {
        assert_eq!(x.started_at, y.started_at);
        assert_eq!(x.completed_at, y.completed_at);
    }
}

#[test]
fn different_seeds_differ() {
    let mut c1 = cfg(StrategyKind::None);
    c1.seed = 1;
    let mut s1 = Sim::new(c1, vec![burst_program(30)]);
    s1.run();
    let mut c2 = cfg(StrategyKind::None);
    c2.seed = 2;
    let mut s2 = Sim::new(c2, vec![burst_program(30)]);
    s2.run();
    let t1: u64 = s1.trace.kernel_exec_times(AppId(0)).iter().sum();
    let t2: u64 = s2.trace.kernel_exec_times(AppId(0)).iter().sum();
    assert_ne!(t1, t2, "jitter must depend on the seed");
}

#[test]
fn context_switches_recorded_in_parallel_none() {
    let sim = run(StrategyKind::None, vec![burst_program(40), burst_program(40)]);
    assert!(
        sim.trace.switches.len() >= 2,
        "time-slicing two contexts must record switches, got {}",
        sim.trace.switches.len()
    );
}

#[test]
fn looping_program_stops_at_horizon() {
    let p = Program::new("loop", RepeatMode::LoopUntilHorizon)
        .compute(1_000)
        .launch(kernel())
        .sync()
        .mark_completion();
    let mut c = cfg(StrategyKind::None);
    c.horizon_ns = 50_000_000; // 50 ms
    let mut sim = Sim::new(c, vec![p]);
    sim.run();
    assert!(sim.horizon_reached());
    assert!(sim.completions(AppId(0)).len() > 10);
}

#[test]
fn worker_strategy_ordered_op_waits_for_drain() {
    // HostFunc between launches must not overtake deferred kernels.
    let p = Program::new("ordered", RepeatMode::Once)
        .launch(kernel())
        .host_func(5_000)
        .launch(kernel())
        .sync()
        .mark_completion();
    let sim = run(StrategyKind::Worker, vec![p]);
    assert_eq!(sim.completions(AppId(0)).len(), 1);
    // The host-func must complete after kernel 1 completes.
    let k1_done = sim
        .trace
        .ops
        .iter()
        .filter(|r| r.is_kernel)
        .map(|r| r.completed_at)
        .min()
        .unwrap();
    let hf = sim
        .trace
        .ops
        .iter()
        .find(|r| !r.is_kernel && !r.is_copy)
        .expect("host func record");
    assert!(hf.started_at >= k1_done, "Alg. 7 ordering violated");
}

#[test]
fn completion_times_strictly_increase() {
    let p = Program::new("loop", RepeatMode::LoopUntilHorizon)
        .compute(10_000)
        .launch(kernel())
        .sync()
        .mark_completion();
    let mut c = cfg(StrategyKind::None);
    c.horizon_ns = 100_000_000;
    let mut sim = Sim::new(c, vec![p]);
    sim.run();
    let comps = sim.completions(AppId(0));
    for w in comps.windows(2) {
        assert!(w[1] > w[0]);
    }
}

#[test]
fn ptb_partitions_sms() {
    let sim = run(StrategyKind::Ptb, vec![burst_program(10), burst_program(10)]);
    // With block-level tracing on, every batch of app0 must sit on SMs 0-3
    // and app1 on SMs 4-7.
    assert!(!sim.trace.blocks.is_empty());
    for b in &sim.trace.blocks {
        if b.app == AppId(0) {
            assert!(b.sm.0 < 4, "app0 escaped its PTB partition: sm{}", b.sm.0);
        } else {
            assert!(b.sm.0 >= 4, "app1 escaped its PTB partition: sm{}", b.sm.0);
        }
    }
}

#[test]
#[should_panic(expected = "at most 64 contexts")]
fn more_than_64_contexts_rejected() {
    // Regression: the runnable-set bitmask has one bit per context; a 65th
    // context used to alias silently onto bit 0 (ctx 64 & 63 == 0) and
    // corrupt scheduling. Sim::new must refuse up front instead.
    let programs: Vec<Program> = (0..65)
        .map(|_| Program::new("tiny", RepeatMode::Once).compute(10).mark_completion())
        .collect();
    let _ = Sim::new(cfg(StrategyKind::None), programs);
}

#[test]
fn exactly_64_contexts_accepted() {
    let programs: Vec<Program> = (0..64)
        .map(|_| Program::new("tiny", RepeatMode::Once).compute(10).mark_completion())
        .collect();
    let mut sim = Sim::new(cfg(StrategyKind::None), programs);
    sim.run();
    for a in 0..64 {
        assert_eq!(sim.completions(AppId(a)).len(), 1, "app{a}");
    }
}

/// Compact, fully-ordered fingerprint of a run's op interleaving. Two
/// traces with the same fingerprint had byte-identical op timelines.
fn trace_fingerprint(sim: &Sim) -> Vec<(usize, bool, bool, u64, u64, u64)> {
    sim.trace
        .ops
        .iter()
        .map(|r| (r.app.0, r.is_kernel, r.is_copy, r.enqueued_at, r.started_at, r.completed_at))
        .collect()
}

#[test]
fn policy_dispatch_is_trace_stable_per_strategy() {
    // The policy layer must be a pure refactor of the old per-strategy
    // `match`: for a fixed seed, every strategy's op interleaving is
    // deterministic and reproducible run-over-run (the same invariant the
    // pre-refactor trace obeyed — combined with the legacy-oracle tests in
    // control::policy this pins behaviour preservation).
    for s in StrategyKind::ALL {
        let a = run(s, vec![burst_program(12), burst_program(12)]);
        let b = run(s, vec![burst_program(12), burst_program(12)]);
        let fa = trace_fingerprint(&a);
        assert_eq!(fa, trace_fingerprint(&b), "strategy {s} trace not stable");
        assert!(!fa.is_empty(), "strategy {s} produced no ops");
    }
}

#[test]
fn lock_cycles_balance_under_synced() {
    let sim = run(StrategyKind::Synced, vec![burst_program(12), burst_program(12)]);
    // Every grant must have a matching release (24 ops + copies = none).
    assert_eq!(sim.locks[0].grants.len(), sim.locks[0].releases.len());
    assert_eq!(sim.locks[0].grants.len(), 24);
}

// ---------------------------------------------------------------------
// fleet (num_gpus > 1)
// ---------------------------------------------------------------------

fn fleet_cfg(strategy: StrategyKind, num_gpus: usize) -> SimConfig {
    cfg(strategy).with_num_gpus(num_gpus)
}

#[test]
fn fleet_apps_placed_round_robin() {
    let progs = (0..4).map(|_| burst_program(2)).collect();
    let sim = Sim::new(fleet_cfg(StrategyKind::None, 2), progs);
    assert_eq!(sim.num_gpus(), 2);
    assert_eq!(sim.shard_of(AppId(0)), 0);
    assert_eq!(sim.shard_of(AppId(1)), 1);
    assert_eq!(sim.shard_of(AppId(2)), 0);
    assert_eq!(sim.shard_of(AppId(3)), 1);
    assert_eq!(sim.shard_apps(0), vec![AppId(0), AppId(2)]);
    assert_eq!(sim.shard_apps(1), vec![AppId(1), AppId(3)]);
}

#[test]
fn fleet_all_apps_complete_under_all_strategies() {
    for s in StrategyKind::ALL {
        let progs = (0..4).map(|_| burst_program(8)).collect();
        let mut sim = Sim::new(fleet_cfg(s, 2), progs);
        sim.run();
        for a in 0..4 {
            assert_eq!(sim.completions(AppId(a)).len(), 1, "strategy {s} app {a}");
            assert_eq!(sim.trace.kernel_ops(AppId(a)).count(), 8, "strategy {s} app {a}");
        }
    }
}

#[test]
fn fleet_gated_strategies_isolate_per_shard_but_overlap_across() {
    // The paper's guarantee holds per GPU: a gated strategy must show
    // zero cross-app overlap WITHIN each shard, while the two shards run
    // genuinely in parallel (cross-shard kernel overlap exists — that is
    // the fleet's whole throughput win).
    for s in [StrategyKind::Synced, StrategyKind::Worker] {
        let progs = (0..4).map(|_| burst_program(20)).collect();
        let mut sim = Sim::new(fleet_cfg(s, 2), progs);
        sim.run();
        for (shard, ov) in sim.within_shard_overlaps().iter().enumerate() {
            assert_eq!(*ov, 0, "{s}: shard {shard} violated per-GPU isolation");
        }
        assert!(
            sim.trace.cross_app_kernel_overlaps() > 0,
            "{s}: shards never overlapped — the fleet is not parallel"
        );
    }
}

#[test]
fn fleet_scales_throughput_for_isolating_strategies() {
    // 2 apps on 1 GPU serialise behind one lock; on 2 GPUs each app owns
    // a full device, so the last completion lands much earlier.
    let mk = |g: usize| {
        let progs = (0..2).map(|_| burst_program(30)).collect();
        let mut sim = Sim::new(fleet_cfg(StrategyKind::Synced, g), progs);
        sim.run();
        (0..2)
            .map(|a| *sim.completions(AppId(a)).last().unwrap())
            .max()
            .unwrap()
    };
    let one = mk(1);
    let two = mk(2);
    assert!(
        two * 3 < one * 2,
        "2 shards must cut the makespan by >1.5x (got {one} -> {two})"
    );
}

#[test]
fn fleet_runs_are_deterministic() {
    let mk = || {
        let progs = (0..5).map(|_| burst_program(10)).collect();
        let mut sim = Sim::new(fleet_cfg(StrategyKind::Worker, 3), progs);
        sim.run();
        trace_fingerprint(&sim)
    };
    assert_eq!(mk(), mk(), "fleet trace not reproducible");
}

#[test]
fn fleet_ptb_partitions_within_each_shard() {
    // 4 apps on 2 GPUs = 2 PTB peers per shard: each peer owns HALF of
    // its own GPU's 8 SMs (not a quarter — partitions never span GPUs).
    let progs = (0..4).map(|_| burst_program(6)).collect();
    let mut sim = Sim::new(fleet_cfg(StrategyKind::Ptb, 2), progs);
    sim.run();
    assert!(!sim.trace.blocks.is_empty());
    for b in &sim.trace.blocks {
        // Apps 0/1 are rank 0 on their shard (SMs 0-3); apps 2/3 rank 1.
        if b.app.0 < 2 {
            assert!(b.sm.0 < 4, "app{} escaped its partition: sm{}", b.app.0, b.sm.0);
        } else {
            assert!(b.sm.0 >= 4, "app{} escaped its partition: sm{}", b.app.0, b.sm.0);
        }
    }
}

#[test]
fn fleet_per_shard_locks_are_independent() {
    // Synced on 2 shards: each shard's lock sees only its own app's
    // grants, and both stay balanced.
    let progs = (0..2).map(|_| burst_program(9)).collect();
    let mut sim = Sim::new(fleet_cfg(StrategyKind::Synced, 2), progs);
    sim.run();
    assert_eq!(sim.locks.len(), 2);
    for (s, lock) in sim.locks.iter().enumerate() {
        assert_eq!(lock.grants.len(), lock.releases.len(), "shard {s} unbalanced");
        assert_eq!(lock.grants.len(), 9, "shard {s}: one grant per op");
        assert_eq!(lock.max_waiters, 0, "shard {s}: single app never waits");
    }
}

#[test]
fn fleet_empty_shards_are_benign() {
    // num_gpus > #apps leaves trailing shards with no work: the
    // partitioner must skip them (an empty sub-sim would run straight
    // to its Horizon event and spuriously flag the merged run), and the
    // populated shards must behave exactly as in a tighter fleet.
    let progs: Vec<Program> = (0..2).map(|_| burst_program(4)).collect();
    let mut sim = Sim::new(fleet_cfg(StrategyKind::None, 4), progs);
    sim.run();
    assert!(!sim.horizon_reached(), "empty shard leaked a horizon flag");
    for a in 0..2 {
        assert_eq!(sim.completions(AppId(a)).len(), 1, "app {a}");
        assert_eq!(sim.shard_of(AppId(a)), a);
    }
    assert!(sim.shard_apps(2).is_empty());
    assert!(sim.shard_apps(3).is_empty());
}

#[test]
fn fleet_thread_count_is_invisible() {
    // The partition/merge contract (DESIGN.md §11): COOK_SIM_THREADS is
    // a throughput knob, never a semantics knob. Pin it through the
    // explicit API so parallel test binaries can't race on the env var.
    let mk = |threads| {
        let progs = (0..5).map(|_| burst_program(7)).collect();
        let mut sim = Sim::new(fleet_cfg(StrategyKind::Callback, 3), progs);
        sim.run_with_sim_threads(threads);
        trace_fingerprint(&sim)
    };
    let seq = mk(1);
    assert!(!seq.is_empty());
    assert_eq!(seq, mk(2), "2 threads changed the fleet trace");
    assert_eq!(seq, mk(8), "8 threads changed the fleet trace");
}

// ---------------------------------------------------------------------
// open-loop arrivals (SimConfig::arrivals)
// ---------------------------------------------------------------------

use crate::control::traffic::ArrivalProcess;

/// A served-request shape: one kernel + barrier + completion mark per
/// iteration, looping until the horizon.
fn serving_program() -> Program {
    Program::new("served", RepeatMode::LoopUntilHorizon)
        .compute(5_000)
        .launch(kernel())
        .sync()
        .mark_completion()
}

fn open_cfg(rate_hz: f64, cap: usize, horizon_ns: u64) -> SimConfig {
    cfg(StrategyKind::Worker)
        .with_horizon_ns(horizon_ns)
        .with_arrivals(ArrivalProcess::Poisson { rate_hz })
        .with_arrival_queue_cap(cap)
}

#[test]
fn open_loop_light_load_completes_arrivals_with_low_latency() {
    // 200/s against a sub-ms service time: every arrival is admitted,
    // served, and measured from its arrival instant.
    let mut sim = Sim::new(open_cfg(200.0, 64, 500_000_000), vec![serving_program()]);
    sim.run();
    let (offered, shed) = sim.arrival_counts(AppId(0));
    assert!(offered > 50, "500 ms at 200/s must offer ~100 (got {offered})");
    assert_eq!(shed, 0, "light load must not shed");
    let lat = sim.arrival_latencies(AppId(0));
    assert_eq!(lat.len(), sim.completions(AppId(0)).len());
    assert_eq!(lat.len(), offered - sim.apps[0].arrival_backlog.len()
        - sim.apps[0].arrival_inflight.len(), "admitted arrivals must complete or be in flight");
    // Under-load: typical arrival-to-completion stays near the service
    // time, far below the 5 ms inter-arrival gap (the rare injected
    // Pareto tail can push an individual sample higher).
    let mut sorted = lat.to_vec();
    sorted.sort_unstable();
    let p50 = sorted[sorted.len() / 2];
    assert!(p50 < 2_000_000, "light-load median latency blew up: {p50} ns");
    assert!(*sorted.last().unwrap() < 50_000_000, "latency tail unreasonable");
}

#[test]
fn open_loop_overload_sheds_at_the_backlog_bound() {
    // Offer far beyond the service rate into a backlog of 4: the bound
    // must hold (sheds) and latency must reflect queueing delay, which a
    // closed-loop run structurally cannot show.
    let mut sim = Sim::new(open_cfg(50_000.0, 4, 200_000_000), vec![serving_program()]);
    sim.run();
    let (offered, shed) = sim.arrival_counts(AppId(0));
    assert!(offered > 1_000, "flood must offer thousands (got {offered})");
    assert!(shed > 0, "cap-4 backlog under flood must shed");
    assert!(sim.apps[0].arrival_backlog.len() <= 4, "backlog bound violated");
    let lat = sim.arrival_latencies(AppId(0));
    assert!(!lat.is_empty());
    // Queue delay dominates: the worst latency far exceeds the best.
    let (min, max) = (*lat.iter().min().unwrap(), *lat.iter().max().unwrap());
    assert!(max > 2 * min, "no queueing delay visible: min={min} max={max}");
}

#[test]
fn open_loop_runs_are_seed_deterministic() {
    let mk = |seed: u64| {
        let c = open_cfg(2_000.0, 16, 100_000_000).with_seed(seed);
        let mut sim = Sim::new(c, vec![serving_program(), serving_program()]);
        sim.run();
        (
            sim.arrival_latencies(AppId(0)).to_vec(),
            sim.arrival_latencies(AppId(1)).to_vec(),
            sim.arrival_counts(AppId(0)),
            sim.arrival_counts(AppId(1)),
        )
    };
    assert_eq!(mk(9), mk(9), "identical seeds must reproduce the run exactly");
    assert_ne!(mk(9).0, mk(10).0, "different seeds must differ");
}

#[test]
fn closed_loop_runs_never_touch_arrival_state() {
    let sim = run(StrategyKind::Synced, vec![burst_program(6)]);
    assert_eq!(sim.arrival_counts(AppId(0)), (0, 0));
    assert!(sim.arrival_latencies(AppId(0)).is_empty());
    assert_eq!(sim.completions(AppId(0)).len(), 1);
}

#[test]
fn open_loop_leaves_once_programs_ungated() {
    // RepeatMode::Once programs model setup work, not served requests:
    // they must run to completion even with no arrivals scheduled at all.
    let p = Program::new("setup", RepeatMode::Once)
        .launch(kernel())
        .sync()
        .mark_completion();
    let mut sim = Sim::new(
        cfg(StrategyKind::None).with_arrivals(ArrivalProcess::Poisson { rate_hz: 0.001 }),
        vec![p],
    );
    sim.run();
    assert_eq!(sim.completions(AppId(0)).len(), 1);
}

// ---------------------------------------------------------------------
// seeded fault injection (SimConfig::faults, DESIGN.md §12)
// ---------------------------------------------------------------------

use crate::control::fault::FaultSpec;

fn faults(spec: &str) -> FaultSpec {
    spec.parse().expect("test fault spec must parse")
}

#[test]
fn one_shot_hang_stretches_the_faulted_run() {
    let clean = run(StrategyKind::None, vec![burst_program(10)]);
    assert_eq!(clean.faults_total(), 0, "no spec, no injections");
    let mut sim = Sim::new(
        cfg(StrategyKind::None).with_faults(faults("hang:at=0:ms=5")),
        vec![burst_program(10)],
    );
    sim.run();
    assert_eq!(sim.fault_count(AppId(0)), 1);
    assert_eq!(sim.faults_total(), 1);
    let clean_end = *clean.completions(AppId(0)).last().unwrap();
    let hung_end = *sim.completions(AppId(0)).last().unwrap();
    assert!(
        hung_end >= clean_end + 4_000_000,
        "a 5 ms kernel hang must delay completion (clean {clean_end}, hung {hung_end})"
    );
}

#[test]
fn payload_selector_confines_the_hang_to_its_victim() {
    let mut sim = Sim::new(
        cfg(StrategyKind::Synced).with_faults(faults("hang:payload=1@at=0:ms=3")),
        vec![burst_program(8), burst_program(8)],
    );
    sim.run();
    assert_eq!(sim.fault_count(AppId(0)), 0, "non-victim stays clean");
    assert_eq!(sim.fault_count(AppId(1)), 1);
    // Both apps still complete their full workload under injection.
    for a in 0..2 {
        assert_eq!(sim.trace.kernel_ops(AppId(a)).count(), 8, "app {a}");
        assert_eq!(sim.completions(AppId(a)).len(), 1, "app {a}");
    }
}

#[test]
fn periodic_hangs_are_seed_deterministic() {
    let mk = |seed: u64| {
        let c = cfg(StrategyKind::Worker)
            .with_seed(seed)
            .with_horizon_ns(500_000_000)
            .with_faults(faults("hang:period=10:ms=1"));
        let mut sim = Sim::new(c, vec![serving_program()]);
        sim.run();
        (sim.faults_total(), trace_fingerprint(&sim))
    };
    let (n, fp) = mk(7);
    assert!(n > 0, "a 10 ms period over 500 ms must fire");
    assert_eq!((n, fp.clone()), mk(7), "identical seeds must replay exactly");
    assert_ne!(fp, mk(8).1, "different seeds must draw different schedules");
}

#[test]
fn fleet_fault_schedule_is_thread_count_invariant() {
    // Faults ride the same deal/merge contract as arrivals (§11):
    // COOK_SIM_THREADS must never change where or how often they land.
    let mk = |threads| {
        let progs = (0..5).map(|_| burst_program(7)).collect();
        let c = fleet_cfg(StrategyKind::Callback, 3)
            .with_horizon_ns(500_000_000)
            .with_faults(faults("hang:period=5:ms=1,hang:shard=1@at=1:ms=2"));
        let mut sim = Sim::new(c, progs);
        sim.run_with_sim_threads(threads);
        let counts: Vec<usize> = (0..5).map(|a| sim.fault_count(AppId(a))).collect();
        (counts, trace_fingerprint(&sim))
    };
    let seq = mk(1);
    assert!(seq.0.iter().sum::<usize>() > 0, "fleet spec must inject");
    assert_eq!(seq, mk(2), "2 threads changed the faulted fleet trace");
    assert_eq!(seq, mk(8), "8 threads changed the faulted fleet trace");
}
