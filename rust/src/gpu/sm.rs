//! Per-SM residency accounting (§II-B limits).
//!
//! The block scheduler fits batches of thread blocks onto SMs subject to
//! the Volta residency limits: at most 32 blocks and 64 warps resident per
//! SM. Only the *active* context's batches occupy SMs — on a context
//! switch all register state is saved and residency resets (which is
//! precisely why switches are costly, §VII-B).

use crate::config::PlatformConfig;

/// Dynamic residency state of one SM.
#[derive(Debug, Clone, Default)]
pub struct SmState {
    pub used_blocks: usize,
    pub used_warps: usize,
}

impl SmState {
    /// How many more blocks of `warps_per_block` warps fit right now.
    pub fn fits(&self, plat: &PlatformConfig, warps_per_block: usize) -> usize {
        let by_blocks = plat.max_blocks_per_sm.saturating_sub(self.used_blocks);
        if warps_per_block == 0 {
            return by_blocks;
        }
        let by_warps =
            plat.max_warps_per_sm.saturating_sub(self.used_warps) / warps_per_block;
        by_blocks.min(by_warps)
    }

    pub fn occupy(&mut self, blocks: usize, warps_per_block: usize) {
        self.used_blocks += blocks;
        self.used_warps += blocks * warps_per_block;
    }

    pub fn vacate(&mut self, blocks: usize, warps_per_block: usize) {
        assert!(self.used_blocks >= blocks, "SM block underflow");
        assert!(self.used_warps >= blocks * warps_per_block, "SM warp underflow");
        self.used_blocks -= blocks;
        self.used_warps -= blocks * warps_per_block;
    }

    pub fn is_empty(&self) -> bool {
        self.used_blocks == 0 && self.used_warps == 0
    }

    /// Warp occupancy in [0, 1] (utilization metric).
    pub fn warp_occupancy(&self, plat: &PlatformConfig) -> f64 {
        self.used_warps as f64 / plat.max_warps_per_sm as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn plat() -> PlatformConfig {
        PlatformConfig::default()
    }

    #[test]
    fn fits_respects_both_limits() {
        let p = plat();
        let sm = SmState::default();
        // 32-warp blocks (1024 threads): warp limit binds -> 2.
        assert_eq!(sm.fits(&p, 32), 2);
        // 1-warp blocks: block limit binds -> 32.
        assert_eq!(sm.fits(&p, 1), 32);
    }

    #[test]
    fn occupy_vacate_roundtrip() {
        let p = plat();
        let mut sm = SmState::default();
        let n = sm.fits(&p, 8); // 8 blocks of 8 warps
        assert_eq!(n, 8);
        sm.occupy(n, 8);
        assert_eq!(sm.fits(&p, 8), 0);
        assert!((sm.warp_occupancy(&p) - 1.0).abs() < 1e-9);
        sm.vacate(n, 8);
        assert!(sm.is_empty());
    }

    #[test]
    fn partial_occupancy_leaves_room() {
        let p = plat();
        let mut sm = SmState::default();
        sm.occupy(4, 8); // 32 warps used
        assert_eq!(sm.fits(&p, 8), 4);
        assert_eq!(sm.fits(&p, 32), 1);
        assert!((sm.warp_occupancy(&p) - 0.5).abs() < 1e-9);
    }

    #[test]
    #[should_panic(expected = "underflow")]
    fn vacate_underflow_panics() {
        let mut sm = SmState::default();
        sm.vacate(1, 1);
    }

    #[test]
    fn zero_warp_blocks_limited_by_block_count() {
        let p = plat();
        let sm = SmState::default();
        assert_eq!(sm.fits(&p, 0), p.max_blocks_per_sm);
    }
}
