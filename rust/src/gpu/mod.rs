//! The Volta GPU discrete-event simulator (the paper's physical testbed,
//! rebuilt as a deterministic model — see DESIGN.md substitution table).
//!
//! One [`Sim`] models a *fleet* of `SimConfig::num_gpus` independent
//! devices — each shard with its own SM bank, L2, copy engine, context
//! scheduler and `GPU_LOCK` — under a single virtual clock. The default
//! (`num_gpus = 1`) is exactly the paper's single embedded Volta; see
//! DESIGN.md §8 for the sharded-fleet semantics.

pub mod cache;
pub mod engine;
pub mod event;
pub mod sm;

pub use engine::{Sim, SCALE_WINDOWS};

#[cfg(test)]
mod engine_tests;
