//! The Volta GPU discrete-event simulator (the paper's physical testbed,
//! rebuilt as a deterministic model — see DESIGN.md substitution table).

pub mod cache;
pub mod engine;
pub mod event;
pub mod sm;

pub use engine::Sim;

#[cfg(test)]
mod engine_tests;
