//! Discrete-event core: the event kinds and the time-ordered queue.
//!
//! Events that can be invalidated by state changes (batch completions,
//! quantum expiries) carry a generation counter; handlers drop events whose
//! generation no longer matches — the standard DES cancellation idiom,
//! cheaper than removing entries from the heap.

use crate::util::{AppId, BlockUid, Nanos, OpUid};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Everything that can be scheduled in virtual time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Event {
    /// A host thread finishes its current compute segment / wakes up.
    HostReady(AppId),
    /// A worker thread wakes up (deferred-worker strategy).
    WorkerReady(AppId),
    /// A host-func callback begins executing on a callback-pool thread.
    CallbackStart(OpUid),
    /// A host-func callback body returns.
    CallbackDone(OpUid),
    /// A batch of thread blocks completes on an SM. Carries the batch's
    /// slab slot (direct index, no hashing) plus its unique uid so a
    /// reused slot invalidates stale events (freeze/cancel idiom).
    BatchDone { slot: u32, uid: BlockUid },
    /// A copy-engine transfer completes (the shard is derived from the
    /// op's context; `gen` is the owning shard's copy generation).
    CopyDone { op: OpUid, gen: u64 },
    /// The context-scheduling quantum of one GPU shard expires.
    QuantumExpire { shard: u32, gen: u64 },
    /// A context switch (state save/restore) on one shard completes.
    SwitchDone { shard: u32, gen: u64 },
    /// A software-stack stall delaying an op's dispatch ends.
    StallDone(OpUid),
    /// A sleeping GPU-lock waiter on one shard finishes waking up
    /// (sem_post latency); grants happen here, letting fresh acquires
    /// barge in the meantime.
    LockWake { shard: u32 },
    /// An open-loop request arrives for an application (traffic
    /// injection, `SimConfig::arrivals`): admitted into the app's
    /// bounded backlog or shed, mirroring the live admission queue.
    ArrivalDue(AppId),
    /// End of the measurement horizon.
    Horizon,
}

/// Min-heap of (time, seq, event). The monotonically increasing sequence
/// number makes ordering of simultaneous events deterministic (insertion
/// order), which keeps whole runs bit-reproducible.
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Reverse<(Nanos, u64, Event)>>,
    seq: u64,
}

impl EventQueue {
    pub fn new() -> Self {
        Self::default()
    }

    /// Pre-sized queue (capacity derived from the run's op count so the
    /// steady-state heap never reallocates).
    pub fn with_capacity(cap: usize) -> Self {
        Self { heap: BinaryHeap::with_capacity(cap), seq: 0 }
    }

    pub fn push(&mut self, at: Nanos, ev: Event) {
        self.seq += 1;
        self.heap.push(Reverse((at, self.seq, ev)));
    }

    pub fn pop(&mut self) -> Option<(Nanos, Event)> {
        self.heap.pop().map(|Reverse((t, _, e))| (t, e))
    }

    pub fn peek_time(&self) -> Option<Nanos> {
        self.heap.peek().map(|Reverse((t, _, _))| *t)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, Event::Horizon);
        q.push(10, Event::HostReady(AppId(0)));
        q.push(20, Event::WorkerReady(AppId(1)));
        assert_eq!(q.pop(), Some((10, Event::HostReady(AppId(0)))));
        assert_eq!(q.pop(), Some((20, Event::WorkerReady(AppId(1)))));
        assert_eq!(q.pop(), Some((30, Event::Horizon)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn simultaneous_events_keep_insertion_order() {
        let mut q = EventQueue::new();
        q.push(5, Event::HostReady(AppId(0)));
        q.push(5, Event::HostReady(AppId(1)));
        q.push(5, Event::HostReady(AppId(2)));
        assert_eq!(q.pop().unwrap().1, Event::HostReady(AppId(0)));
        assert_eq!(q.pop().unwrap().1, Event::HostReady(AppId(1)));
        assert_eq!(q.pop().unwrap().1, Event::HostReady(AppId(2)));
    }

    #[test]
    fn peek_time() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_time(), None);
        q.push(42, Event::Horizon);
        assert_eq!(q.peek_time(), Some(42));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn with_capacity_behaves_like_new() {
        let mut q = EventQueue::with_capacity(128);
        assert!(q.is_empty());
        q.push(1, Event::Horizon);
        assert_eq!(q.pop(), Some((1, Event::Horizon)));
    }
}
