//! Discrete-event core: the event kinds and the time-ordered queue.
//!
//! Events that can be invalidated by state changes (batch completions,
//! quantum expiries) carry a generation counter; handlers drop events whose
//! generation no longer matches — the standard DES cancellation idiom,
//! cheaper than removing entries from the queue.
//!
//! # The calendar queue
//!
//! [`EventQueue`] is a two-level calendar/bucket queue, replacing the
//! original `BinaryHeap<Reverse<(Nanos, u64, Event)>>` whose O(log n)
//! push/pop dominated the per-event loop at high event counts:
//!
//! * **Ring level** — [`EventQueue::NUM_BUCKETS`] FIFO lanes, each
//!   covering a [`EventQueue::BUCKET_NS`]-wide window of virtual time.
//!   The ring spans `NUM_BUCKETS * BUCKET_NS` (~4 ms) starting at `base`;
//!   push and pop on the ring are O(1) amortised (an occupancy bitmap
//!   jumps empty stretches in O(ring/64) words).
//! * **Overflow level** — events beyond the ring's window park in a small
//!   binary heap and migrate into the ring exactly once, when the window
//!   slides over them. The O(log n) tax is only paid by the rare far
//!   -future event (horizon markers, pathological stalls), never by the
//!   steady-state launch/complete traffic.
//!
//! **Determinism contract:** the queue pops in exactly ascending
//! `(time, insertion-seq)` order — identical to the heap it replaces, so
//! whole runs stay bit-reproducible (pinned by the golden-trace suite and
//! by the randomized heap-equivalence tests below). Within a bucket,
//! multiple distinct timestamps may coexist; pop scans the head bucket
//! for the `(time, seq)` minimum, which is unique because `seq` is. The
//! ring + bitmap layout never influences pop order, only its cost.
//!
//! **Per-shard queues:** a partitioned fleet run (DESIGN.md §11) gives
//! every shard's sub-simulation its own private `EventQueue` — the
//! calendar is engine-local state, never shared across threads, so the
//! (time, seq) contract above holds independently per shard and the
//! shard-major merge order is deterministic by construction.

use crate::util::{AppId, BlockUid, Nanos, OpUid};
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Everything that can be scheduled in virtual time.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Event {
    /// A host thread finishes its current compute segment / wakes up.
    HostReady(AppId),
    /// A worker thread wakes up (deferred-worker strategy).
    WorkerReady(AppId),
    /// A host-func callback begins executing on a callback-pool thread.
    CallbackStart(OpUid),
    /// A host-func callback body returns.
    CallbackDone(OpUid),
    /// A batch of thread blocks completes on an SM. Carries the batch's
    /// slab slot (direct index, no hashing) plus its unique uid so a
    /// reused slot invalidates stale events (freeze/cancel idiom).
    BatchDone { slot: u32, uid: BlockUid },
    /// A copy-engine transfer completes (the shard is derived from the
    /// op's context; `gen` is the owning shard's copy generation).
    CopyDone { op: OpUid, gen: u64 },
    /// The context-scheduling quantum of one GPU shard expires.
    QuantumExpire { shard: u32, gen: u64 },
    /// A context switch (state save/restore) on one shard completes.
    SwitchDone { shard: u32, gen: u64 },
    /// A software-stack stall delaying an op's dispatch ends.
    StallDone(OpUid),
    /// A sleeping GPU-lock waiter on one shard finishes waking up
    /// (sem_post latency); grants happen here, letting fresh acquires
    /// barge in the meantime.
    LockWake { shard: u32 },
    /// An open-loop request arrives for an application (traffic
    /// injection, `SimConfig::arrivals`): admitted into the app's
    /// bounded backlog or shed, mirroring the live admission queue.
    ArrivalDue(AppId),
    /// A seeded kernel-hang injection fires for an application
    /// (`SimConfig::faults`): the app's next dispatched batch is
    /// stretched by the scheduled extra nanoseconds, mirroring the live
    /// `FaultyExecutor` hang (DESIGN.md §12).
    FaultDue(AppId),
    /// A scheduled fleet scale transition reaches one shard
    /// (`SimConfig::autoscale`): the mirrored elastic controller's
    /// pre-partition timeline says the active-shard count changes here.
    /// Pure observability — the handler records the transition in the
    /// scale log and changes no other sim state, which is what keeps
    /// `autoscale: None` runs bit-identical to pre-elastic traces.
    ScaleDue { shard: u32 },
    /// End of the measurement horizon.
    Horizon,
}

/// One scheduled entry: (time, insertion seq, event).
type Entry = (Nanos, u64, Event);

/// log2 of the lane width: 4096 ns per lane. Steady-state engine events
/// (launch overheads, block batches, lock wakes) land within a few
/// microseconds-to-milliseconds of `now`, i.e. inside the ring.
const BUCKET_SHIFT: u32 = 12;
/// Number of ring lanes (power of two for mask indexing).
const NUM_BUCKETS: usize = 1024;
/// Occupancy bitmap words (one bit per lane).
const OCC_WORDS: usize = NUM_BUCKETS / 64;

/// Calendar/bucket queue of (time, seq, event) — see the module docs for
/// the two-level layout and the determinism contract. The monotonically
/// increasing sequence number makes ordering of simultaneous events
/// deterministic (insertion order), which keeps whole runs
/// bit-reproducible.
#[derive(Debug)]
pub struct EventQueue {
    /// The ring: `NUM_BUCKETS` FIFO lanes of `BUCKET_NS`-wide windows,
    /// lane `(t / BUCKET_NS) % NUM_BUCKETS`. Lanes are unsorted; pop
    /// scans the head lane for the (time, seq) minimum.
    buckets: Vec<Vec<Entry>>,
    /// One bit per lane: set iff the lane is non-empty (O(words) skip of
    /// empty stretches when the clock jumps).
    occ: [u64; OCC_WORDS],
    /// Events at or beyond `base + WINDOW_NS`; migrate into the ring when
    /// the window slides over them (each pays the heap tax once).
    overflow: BinaryHeap<Reverse<Entry>>,
    /// Aligned start of the head lane's window. Monotone non-decreasing.
    base: Nanos,
    /// Events currently in the ring (vs. `len` = ring + overflow).
    ring_len: usize,
    len: usize,
    seq: u64,
    /// Reusable buffer for `pop_batch` (same-instant seq sort).
    scratch: Vec<(u64, Event)>,
}

impl EventQueue {
    /// Width of one lane's time window, ns.
    pub const BUCKET_NS: Nanos = 1 << BUCKET_SHIFT;
    /// Number of lanes.
    pub const NUM_BUCKETS: usize = NUM_BUCKETS;
    /// Virtual-time span covered by the ring (~4.2 ms).
    pub const WINDOW_NS: Nanos = (NUM_BUCKETS as Nanos) << BUCKET_SHIFT;

    pub fn new() -> Self {
        Self::with_capacity(0)
    }

    /// Pre-sized queue. The ring is fixed-size by design; the hint sizes
    /// the overflow heap and the batch scratch so the steady state never
    /// reallocates.
    pub fn with_capacity(cap: usize) -> Self {
        Self {
            buckets: (0..NUM_BUCKETS).map(|_| Vec::new()).collect(),
            occ: [0; OCC_WORDS],
            overflow: BinaryHeap::with_capacity(cap.min(1024)),
            base: 0,
            ring_len: 0,
            len: 0,
            seq: 0,
            scratch: Vec::with_capacity(16),
        }
    }

    /// Lane holding the window `[base, base + BUCKET_NS)`.
    #[inline]
    fn head(&self) -> usize {
        ((self.base >> BUCKET_SHIFT) as usize) & (NUM_BUCKETS - 1)
    }

    #[inline]
    fn set_occ(&mut self, lane: usize) {
        self.occ[lane >> 6] |= 1u64 << (lane & 63);
    }

    #[inline]
    fn clear_occ(&mut self, lane: usize) {
        self.occ[lane >> 6] &= !(1u64 << (lane & 63));
    }

    /// First occupied lane at/after `from` in circular order, or None.
    fn next_occupied(&self, from: usize) -> Option<usize> {
        let (w0, b0) = (from >> 6, from & 63);
        let first = self.occ[w0] & (u64::MAX << b0);
        if first != 0 {
            return Some((w0 << 6) + first.trailing_zeros() as usize);
        }
        for k in 1..=OCC_WORDS {
            let w = (w0 + k) % OCC_WORDS;
            let mut word = self.occ[w];
            if w == w0 {
                // Wrapped all the way around: only bits below `from`.
                word &= (1u64 << b0) - 1;
            }
            if word != 0 {
                return Some((w << 6) + word.trailing_zeros() as usize);
            }
        }
        None
    }

    /// Drop an entry into its ring lane. Late entries (`t < base`, legal
    /// for arbitrary workloads; the engine never produces them) share the
    /// head lane — the head-lane min-scan orders them correctly.
    fn place(&mut self, entry: Entry) {
        let lane = if entry.0 <= self.base {
            self.head()
        } else {
            ((entry.0 >> BUCKET_SHIFT) as usize) & (NUM_BUCKETS - 1)
        };
        if self.buckets[lane].is_empty() {
            self.set_occ(lane);
        }
        self.buckets[lane].push(entry);
        self.ring_len += 1;
    }

    /// Migrate every overflow entry the current window now covers.
    /// (`t - base < WINDOW` as a subtraction so `base + WINDOW` can never
    /// overflow near `Nanos::MAX`.)
    fn drain_overflow(&mut self) {
        while let Some(&Reverse((t, _, _))) = self.overflow.peek() {
            if t.saturating_sub(self.base) >= Self::WINDOW_NS {
                break;
            }
            let Reverse(entry) = self.overflow.pop().expect("peeked");
            self.place(entry);
        }
    }

    /// Slide the window until the head lane is non-empty. Requires
    /// `len > 0`. Invariant used throughout: ring entries all lie below
    /// `base + WINDOW_NS`, overflow entries all at/above it — so the ring
    /// always holds the global minimum when non-empty, and the first
    /// occupied lane from `head` (circular order == window time order)
    /// holds it.
    fn ensure_front(&mut self) {
        debug_assert!(self.len > 0);
        if self.ring_len == 0 {
            // Ring drained: jump the window straight to the earliest
            // overflow event (no lane-by-lane crawl across idle time).
            let &Reverse((t, _, _)) = self.overflow.peek().expect("len > 0");
            self.base = (t >> BUCKET_SHIFT) << BUCKET_SHIFT;
            self.drain_overflow();
            debug_assert!(self.ring_len > 0);
            return;
        }
        let h = self.head();
        if !self.buckets[h].is_empty() {
            return;
        }
        let next = self.next_occupied(h).expect("ring_len > 0");
        let steps = (next + NUM_BUCKETS - h) % NUM_BUCKETS;
        debug_assert!(steps > 0, "head lane empty but its bit set");
        self.base += (steps as Nanos) << BUCKET_SHIFT;
        // Entries pulled in here are ≥ the old window end, hence later
        // than every ring entry; they land behind the new head.
        self.drain_overflow();
    }

    pub fn push(&mut self, at: Nanos, ev: Event) {
        self.seq += 1;
        self.len += 1;
        if at.saturating_sub(self.base) >= Self::WINDOW_NS {
            self.overflow.push(Reverse((at, self.seq, ev)));
        } else {
            self.place((at, self.seq, ev));
        }
    }

    pub fn pop(&mut self) -> Option<(Nanos, Event)> {
        if self.len == 0 {
            return None;
        }
        self.ensure_front();
        let h = self.head();
        let b = &mut self.buckets[h];
        let mut mi = 0;
        let mut best = (b[0].0, b[0].1);
        for (i, &(t, s, _)) in b.iter().enumerate().skip(1) {
            if (t, s) < best {
                best = (t, s);
                mi = i;
            }
        }
        let (t, _, e) = b.swap_remove(mi);
        let emptied = b.is_empty();
        self.len -= 1;
        self.ring_len -= 1;
        if emptied {
            self.clear_occ(h);
        }
        Some((t, e))
    }

    /// Drain **every** event scheduled at the next instant into `out`
    /// (in insertion order — exactly the order `pop` would yield them)
    /// and return that instant; `None` iff the queue is empty.
    ///
    /// Same-timestamp events always share one lane, so one scan collects
    /// the whole instant. The engine runs its dirty-set pump once per
    /// returned batch instead of once per event.
    pub fn pop_batch(&mut self, out: &mut Vec<Event>) -> Option<Nanos> {
        out.clear();
        if self.len == 0 {
            return None;
        }
        self.ensure_front();
        let h = self.head();
        let b = &mut self.buckets[h];
        let t = b.iter().map(|&(t, _, _)| t).min().expect("head lane non-empty");
        self.scratch.clear();
        let mut i = 0;
        while i < b.len() {
            if b[i].0 == t {
                let (_, s, e) = b.swap_remove(i);
                self.scratch.push((s, e));
            } else {
                i += 1;
            }
        }
        let emptied = b.is_empty();
        let n = self.scratch.len();
        self.len -= n;
        self.ring_len -= n;
        if emptied {
            self.clear_occ(h);
        }
        self.scratch.sort_unstable_by_key(|&(s, _)| s);
        out.extend(self.scratch.iter().map(|&(_, e)| e));
        Some(t)
    }

    /// Time of the next event. Slides the window (hence `&mut`); pop
    /// order is unaffected.
    pub fn peek_time(&mut self) -> Option<Nanos> {
        if self.len == 0 {
            return None;
        }
        self.ensure_front();
        self.buckets[self.head()].iter().map(|&(t, _, _)| t).min()
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl Default for EventQueue {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::DetRng;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(30, Event::Horizon);
        q.push(10, Event::HostReady(AppId(0)));
        q.push(20, Event::WorkerReady(AppId(1)));
        assert_eq!(q.pop(), Some((10, Event::HostReady(AppId(0)))));
        assert_eq!(q.pop(), Some((20, Event::WorkerReady(AppId(1)))));
        assert_eq!(q.pop(), Some((30, Event::Horizon)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn simultaneous_events_keep_insertion_order() {
        let mut q = EventQueue::new();
        q.push(5, Event::HostReady(AppId(0)));
        q.push(5, Event::HostReady(AppId(1)));
        q.push(5, Event::HostReady(AppId(2)));
        assert_eq!(q.pop().unwrap().1, Event::HostReady(AppId(0)));
        assert_eq!(q.pop().unwrap().1, Event::HostReady(AppId(1)));
        assert_eq!(q.pop().unwrap().1, Event::HostReady(AppId(2)));
    }

    #[test]
    fn peek_time() {
        let mut q = EventQueue::new();
        assert_eq!(q.peek_time(), None);
        q.push(42, Event::Horizon);
        assert_eq!(q.peek_time(), Some(42));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn with_capacity_behaves_like_new() {
        let mut q = EventQueue::with_capacity(128);
        assert!(q.is_empty());
        q.push(1, Event::Horizon);
        assert_eq!(q.pop(), Some((1, Event::Horizon)));
    }

    #[test]
    fn far_future_events_cross_the_overflow_level() {
        let mut q = EventQueue::new();
        // Far beyond the ring window: must park in overflow...
        let far = 10 * EventQueue::WINDOW_NS + 17;
        q.push(far, Event::Horizon);
        q.push(3, Event::HostReady(AppId(0)));
        assert_eq!(q.pop(), Some((3, Event::HostReady(AppId(0)))));
        // ...and migrate back when the window jumps over the idle gap.
        assert_eq!(q.pop(), Some((far, Event::Horizon)));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn pop_batch_drains_one_instant_in_seq_order() {
        let mut q = EventQueue::new();
        q.push(7, Event::HostReady(AppId(0)));
        q.push(9, Event::Horizon);
        q.push(7, Event::WorkerReady(AppId(1)));
        q.push(7, Event::HostReady(AppId(2)));
        let mut out = Vec::new();
        assert_eq!(q.pop_batch(&mut out), Some(7));
        assert_eq!(
            out,
            vec![
                Event::HostReady(AppId(0)),
                Event::WorkerReady(AppId(1)),
                Event::HostReady(AppId(2)),
            ]
        );
        assert_eq!(q.pop_batch(&mut out), Some(9));
        assert_eq!(out, vec![Event::Horizon]);
        assert_eq!(q.pop_batch(&mut out), None);
        assert!(out.is_empty());
    }

    // ------------------------------------------------------------------
    // determinism equivalence suite: the calendar queue must yield the
    // IDENTICAL pop sequence as the reference heap it replaced, under
    // randomized (seeded) push/pop workloads — simultaneous-timestamp
    // FIFO order and far-future overflow events included.
    // ------------------------------------------------------------------

    /// The original `BinaryHeap<Reverse<(Nanos, u64, Event)>>` queue,
    /// kept verbatim as the ordering oracle.
    #[derive(Default)]
    struct RefHeapQueue {
        heap: BinaryHeap<Reverse<Entry>>,
        seq: u64,
    }

    impl RefHeapQueue {
        fn push(&mut self, at: Nanos, ev: Event) {
            self.seq += 1;
            self.heap.push(Reverse((at, self.seq, ev)));
        }

        fn pop(&mut self) -> Option<(Nanos, Event)> {
            self.heap.pop().map(|Reverse((t, _, e))| (t, e))
        }
    }

    /// A seeded event zoo: the uid payloads double as identity markers so
    /// any ordering divergence is visible in the comparison.
    fn random_event(rng: &mut DetRng, k: u64) -> Event {
        match rng.next_u64() % 6 {
            0 => Event::HostReady(AppId((k % 64) as usize)),
            1 => Event::WorkerReady(AppId((k % 64) as usize)),
            2 => Event::CallbackStart(OpUid(k)),
            3 => Event::BatchDone { slot: (k % 97) as u32, uid: BlockUid(k) },
            4 => Event::LockWake { shard: (k % 4) as u32 },
            _ => Event::StallDone(OpUid(k)),
        }
    }

    /// Random push time relative to the virtual clock: mostly near-term
    /// (inside the ring), sometimes same-instant (FIFO ties), sometimes
    /// far future (overflow level), occasionally in the "past" (legal
    /// for the queue even though the engine never does it).
    fn random_time(rng: &mut DetRng, now: Nanos) -> Nanos {
        match rng.next_u64() % 10 {
            0 => now, // same instant: exercises FIFO tie-break
            1..=5 => now + rng.next_u64() % (EventQueue::BUCKET_NS * 3), // near
            6 | 7 => now + rng.next_u64() % EventQueue::WINDOW_NS, // mid-ring
            // far future: exercises the overflow level
            8 => now + EventQueue::WINDOW_NS + rng.next_u64() % (50 * EventQueue::WINDOW_NS),
            _ => now.saturating_sub(rng.next_u64() % 1000), // late
        }
    }

    /// Drive both queues through an identical randomized push/pop script
    /// and demand identical pop sequences, including the final drain.
    fn run_equivalence(seed: u64, steps: usize) {
        let mut rng = DetRng::new(seed);
        let mut cal = EventQueue::new();
        let mut heap = RefHeapQueue::default();
        let mut now: Nanos = 0;
        for k in 0..steps as u64 {
            // Biased toward pushes so the queues stay populated.
            if rng.next_u64() % 3 != 0 {
                let t = random_time(&mut rng, now);
                let ev = random_event(&mut rng, k);
                cal.push(t, ev);
                heap.push(t, ev);
            } else {
                let a = cal.pop();
                let b = heap.pop();
                assert_eq!(a, b, "divergence at step {k} (seed {seed})");
                if let Some((t, _)) = a {
                    now = now.max(t);
                }
            }
        }
        loop {
            let a = cal.pop();
            let b = heap.pop();
            assert_eq!(a, b, "divergence in final drain (seed {seed})");
            if a.is_none() {
                break;
            }
        }
        assert!(cal.is_empty());
    }

    #[test]
    fn matches_reference_heap_on_random_workloads() {
        for seed in 0..8 {
            run_equivalence(seed, 4_000);
        }
    }

    #[test]
    fn matches_reference_heap_on_overflow_heavy_workload() {
        // Skew every push far ahead so the overflow level and the
        // window-jump path carry the whole run.
        let mut rng = DetRng::new(99);
        let mut cal = EventQueue::new();
        let mut heap = RefHeapQueue::default();
        for k in 0..2_000u64 {
            let t = (rng.next_u64() % 200) * EventQueue::WINDOW_NS
                + rng.next_u64() % EventQueue::BUCKET_NS;
            let ev = random_event(&mut rng, k);
            cal.push(t, ev);
            heap.push(t, ev);
        }
        loop {
            let a = cal.pop();
            let b = heap.pop();
            assert_eq!(a, b);
            if a.is_none() {
                break;
            }
        }
    }

    #[test]
    fn pop_batch_equals_consecutive_pops() {
        // Two identically-fed queues: draining one via pop_batch must
        // reproduce the other's pop stream exactly, batch boundaries
        // falling precisely on timestamp changes.
        let mut rng = DetRng::new(1234);
        let mut a = EventQueue::new();
        let mut b = EventQueue::new();
        let mut now = 0;
        for k in 0..3_000u64 {
            let t = random_time(&mut rng, now);
            now = now.max(t.saturating_sub(EventQueue::BUCKET_NS));
            let ev = random_event(&mut rng, k);
            a.push(t, ev);
            b.push(t, ev);
        }
        let mut batch = Vec::new();
        while let Some(t) = a.pop_batch(&mut batch) {
            assert!(!batch.is_empty());
            for &ev in &batch {
                assert_eq!(b.pop(), Some((t, ev)));
            }
            // The next event (if any) is at a strictly later instant.
            if let Some(nt) = b.peek_time() {
                assert!(nt > t, "batch at {t} missed a same-instant event");
            }
        }
        assert_eq!(b.pop(), None);
    }

    #[test]
    fn interleaved_same_instant_pushes_stay_fifo() {
        // Pushing at the instant currently being drained must order the
        // new event after everything already popped but before later
        // times — exactly what the heap did.
        let mut q = EventQueue::new();
        q.push(100, Event::HostReady(AppId(0)));
        q.push(200, Event::Horizon);
        assert_eq!(q.pop(), Some((100, Event::HostReady(AppId(0)))));
        q.push(100, Event::WorkerReady(AppId(1))); // same instant, mid-drain
        q.push(150, Event::HostReady(AppId(2)));
        assert_eq!(q.pop(), Some((100, Event::WorkerReady(AppId(1)))));
        assert_eq!(q.pop(), Some((150, Event::HostReady(AppId(2)))));
        assert_eq!(q.pop(), Some((200, Event::Horizon)));
    }

    #[test]
    fn len_tracks_ring_and_overflow() {
        let mut q = EventQueue::new();
        q.push(1, Event::Horizon);
        q.push(EventQueue::WINDOW_NS * 3, Event::Horizon);
        assert_eq!(q.len(), 2);
        q.pop();
        assert_eq!(q.len(), 1);
        q.pop();
        assert!(q.is_empty());
    }
}
