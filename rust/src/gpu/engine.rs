//! The discrete-event simulator engine: virtual clock, event dispatch, and
//! the pump fix-point tying together hosts, workers, the lock, the driver
//! queues, the context scheduler, the block scheduler, and the copy engine.
//!
//! One `Sim` = one run of one configuration (`bench-isol-strategy`).
//! Everything is deterministic given (config, seed): the event queue breaks
//! ties by insertion order and every random draw comes from seeded
//! subsystem streams.

use crate::apps::host::{HostPhase, HostState};
use crate::apps::program::{CompiledStep, Program, RepeatMode};
use crate::config::SimConfig;
use crate::control::arbiter::{class_of, make_arbiter, Arbiter, Waiter};
use crate::control::concurrency::ConcurrencyMode;
use crate::control::lock::{GpuLock, LockClient};
use crate::control::policy::{AccessPolicy, Admission, Arbitration, OrderedOpRule};
use crate::control::worker::{WorkerPhase, WorkerState};
use crate::cudart::{
    CopyDesc, GpuContext, KernelInstance, LockAction, Op, OpKind, OpState,
};
use crate::gpu::cache::L2State;
use crate::gpu::event::{Event, EventQueue};
use crate::gpu::sm::SmState;
use crate::trace::record::{
    BlockRecord, OpRecord, StallRecord, SwitchRecord, TraceCollector,
};
use crate::util::{AppId, BlockUid, CtxId, DetRng, Nanos, OpUid, SmId, StreamId};
use std::collections::VecDeque;

// ---------------------------------------------------------------------
// dirty-set pump bits (which subsystems an event handler touched)
// ---------------------------------------------------------------------

/// Host threads have a step to (re)try (some app entered `Ready`).
const D_HOSTS: u8 = 1 << 0;
/// A worker queue gained work or a worker went idle.
const D_WORKERS: u8 = 1 << 1;
/// A stream head may now dispatch (insert/retire/stall-clear/slot-free).
const D_DRIVER: u8 = 1 << 2;
/// Device state changed (SM residency, run pool, copy engine, switches).
const D_GPU: u8 = 1 << 3;

/// Per-op bitflags stored in a dense `Vec<u8>` alongside the op slab
/// (replaces the old `HashSet<OpUid>` stall bookkeeping).
const F_STALLED: u8 = 1 << 0;
const F_STALL_CHECKED: u8 = 1 << 1;

/// A kernel admitted to the device, tracking block progress.
#[derive(Debug)]
struct KernelRun {
    op: OpUid,
    ctx: CtxId,
    app: AppId,
    total: u32,
    dispatched: u32,
    done: u32,
    warps_per_block: usize,
    block_cost_ns: Nanos,
    /// Cold-start penalty (ns) to charge on batches of the next dispatch
    /// round (set on admission and on post-switch resume).
    pending_cold_ns: Nanos,
}

/// A batch of blocks executing on one SM.
#[derive(Debug, Clone, Copy)]
struct Batch {
    uid: BlockUid,
    op: OpUid,
    ctx: CtxId,
    app: AppId,
    sm: SmId,
    blocks: usize,
    warps_per_block: usize,
    started_at: Nanos,
    end_at: Nanos,
    resumed: bool,
}

/// A batch frozen mid-execution by a context switch.
#[derive(Debug, Clone, Copy)]
struct FrozenBatch {
    op: OpUid,
    ctx: CtxId,
    app: AppId,
    blocks: usize,
    warps_per_block: usize,
    remaining_ns: Nanos,
}

/// Slot-indexed slab of live batches. Insertion reuses freed slots
/// (LIFO), iteration runs in ascending slot order — both deterministic,
/// unlike the `HashMap<u64, Batch>` this replaces (whose randomized
/// iteration order leaked into freeze ordering). `BatchDone` events
/// carry (slot, uid); a reused slot's stale event fails the uid check.
#[derive(Debug, Default)]
struct BatchSlab {
    slots: Vec<Option<Batch>>,
    free: Vec<u32>,
}

impl BatchSlab {
    fn insert(&mut self, b: Batch) -> u32 {
        match self.free.pop() {
            Some(i) => {
                self.slots[i as usize] = Some(b);
                i
            }
            None => {
                self.slots.push(Some(b));
                (self.slots.len() - 1) as u32
            }
        }
    }

    fn get(&self, slot: u32) -> Option<&Batch> {
        self.slots.get(slot as usize).and_then(|s| s.as_ref())
    }

    fn remove(&mut self, slot: u32) -> Option<Batch> {
        let b = self.slots.get_mut(slot as usize)?.take();
        if b.is_some() {
            self.free.push(slot);
        }
        b
    }

    fn iter(&self) -> impl Iterator<Item = &Batch> {
        self.slots.iter().filter_map(|s| s.as_ref())
    }

    fn num_slots(&self) -> u32 {
        self.slots.len() as u32
    }
}

/// Dynamic state of ONE simulated GPU (one fleet shard). Everything here
/// is dense (`Vec`-indexed slabs, per-ctx vectors, per-op bitflags) — the
/// per-event loop does no hashing and no steady-state allocation. A
/// single-GPU run (`num_gpus == 1`, the paper's testbed) has exactly one
/// of these; the fleet simulator holds one per shard, so each GPU has an
/// independent context scheduler, copy engine, and switch/quantum state.
#[derive(Debug, Default)]
struct GpuExec {
    run_pool: Vec<KernelRun>,
    frozen: Vec<FrozenBatch>,
    active_ctx: Option<CtxId>,
    /// Previous owner of the SMs (switch cost applies when it changes).
    last_ctx: Option<CtxId>,
    switching: bool,
    /// Context to activate when the in-flight switch completes.
    pending_next: Option<CtxId>,
    quantum_gen: u64,
    quantum_armed: bool,
    switch_gen: u64,
    rr_next: usize,
    copy_current: Option<OpUid>,
    copy_gen: u64,
    copy_q: VecDeque<OpUid>,
}

/// Set of runnable contexts as a bitmask (the Xavier never hosts more
/// than a handful of GPU contexts; 64 is far beyond any real setup).
///
/// The bitmask representation bounds the simulator at
/// [`RunnableSet::MAX_CTXS`] contexts: a context id ≥ 64 would alias onto
/// another context's bit (and `nth` would then recover the wrong `CtxId`).
/// `Sim::new` enforces the bound up front so the hot path can index bits
/// directly.
#[derive(Debug, Clone, Copy)]
struct RunnableSet {
    mask: u64,
}

impl RunnableSet {
    /// Hard capacity of the bitmask (one bit per context).
    const MAX_CTXS: usize = 64;

    fn is_empty(self) -> bool {
        self.mask == 0
    }
    fn len(self) -> usize {
        self.mask.count_ones() as usize
    }
    fn contains(self, c: CtxId) -> bool {
        debug_assert!(c.0 < Self::MAX_CTXS, "ctx id {} out of bitmask range", c.0);
        self.mask & (1u64 << c.0) != 0
    }
    /// n-th set context in ascending id order.
    fn nth(self, n: usize) -> CtxId {
        let mut m = self.mask;
        for _ in 0..n {
            m &= m - 1; // clear lowest set bit
        }
        CtxId(m.trailing_zeros() as usize)
    }
    /// Position of `c` among the set contexts.
    fn position(self, c: CtxId) -> Option<usize> {
        if !self.contains(c) {
            return None;
        }
        let below = self.mask & ((1u64 << c.0) - 1);
        Some(below.count_ones() as usize)
    }
}

/// The simulator: a fleet of `cfg.num_gpus` independent GPU shards (one,
/// by default — the paper's single embedded Volta) driven by one virtual
/// clock.
///
/// One `Sim` = one run of one configuration (`bench-isol-strategy`,
/// optionally sharded). Everything is deterministic given (config, seed);
/// see the [`crate::gpu`] module docs and DESIGN.md §4.
///
/// # Example
///
/// Run a one-kernel program to completion and inspect its trace:
///
/// ```
/// use cook::apps::program::{Program, RepeatMode};
/// use cook::config::SimConfig;
/// use cook::cudart::{Grid, KernelDesc};
/// use cook::gpu::Sim;
/// use cook::util::AppId;
///
/// let kernel = KernelDesc::compute("k", Grid::new(8, 128), 10_000);
/// let prog = Program::new("demo", RepeatMode::Once)
///     .launch(kernel)
///     .sync()
///     .mark_completion();
/// let mut sim = Sim::new(SimConfig::default(), vec![prog]);
/// sim.run();
/// assert_eq!(sim.completions(AppId(0)).len(), 1);
/// assert_eq!(sim.num_gpus(), 1);
/// ```
pub struct Sim {
    pub cfg: SimConfig,
    /// Per-strategy behaviour plans (the only strategy dispatch point).
    policy: AccessPolicy,
    pub now: Nanos,
    events: EventQueue,
    pub ops: Vec<Op>,
    /// Per-op bitflags (`F_*`), parallel to `ops`.
    op_flags: Vec<u8>,
    /// Dirty-set pump bits (`D_*`): which subsystems need a pump pass.
    dirty: u8,
    pub ctxs: Vec<GpuContext>,
    pub apps: Vec<HostState>,
    pub workers: Vec<Option<WorkerState>>,
    /// One `GPU_LOCK` semaphore per shard: the paper's serialisation
    /// guarantee holds per GPU, never across GPUs.
    pub locks: Vec<GpuLock>,
    /// Per-shard grant arbiter driving each lock's wake path (DESIGN.md
    /// §13). FIFO (the default) picks queue position 0, reproducing
    /// the pre-arbiter `grant_next` bit-for-bit — the golden traces
    /// pin that.
    arbiters: Vec<Box<dyn Arbiter>>,
    /// QoS class of each application: `class_of(i, classes.len())` over
    /// GLOBAL app indices — the sharded runner deals these from the
    /// parent, never regenerates them from a sub-sim's local view, the
    /// same rule the live serving path applies to clients/requests.
    class_of_app: Vec<usize>,
    /// Per-shard SM banks (`sms[shard][sm]`).
    sms: Vec<Vec<SmState>>,
    /// Per-shard scheduler/copy-engine state.
    gpus: Vec<GpuExec>,
    /// Live batches of ALL shards in one slab: `BatchDone` events carry
    /// (slot, uid) and a batch's shard is derived from its ctx, so the
    /// event shape is identical at any fleet size.
    batches: BatchSlab,
    /// Per-shard L2 caches, split into slices: `l2[shard][slice]`.
    /// One full-capacity slice everywhere except `mig:<s>`, which
    /// hard-partitions the array per tenant class (slice = class % s),
    /// so co-runners in different classes can never evict each other.
    l2: Vec<Vec<L2State>>,
    /// Per-context timestamp of last device activity (stall exposure),
    /// indexed by ctx id; `None` = never active.
    last_activity: Vec<Option<Nanos>>,
    /// Shard owning each context (`ctx i -> shard i % num_gpus`).
    shard_of_ctx: Vec<usize>,
    pub trace: TraceCollector,
    rng_exec: DetRng,
    rng_stall: DetRng,
    next_block_uid: u64,
    horizon_reached: bool,
    /// Per-app SM masks (PTB partitioning among same-shard peers;
    /// all-true otherwise).
    sm_mask: Vec<Vec<bool>>,
    /// Open-loop traffic injection (`SimConfig::arrivals`): true when an
    /// arrival process paces looping applications. Closed-loop runs pay
    /// exactly one branch per host step for this.
    open_loop: bool,
    /// Per-app arrival offsets, generated in `new` and drained into
    /// `ArrivalDue` events at the start of `run`.
    arrival_schedule: Vec<Vec<Nanos>>,
    /// Open-loop arrivals offered per app (admitted + shed).
    arrivals_offered: Vec<usize>,
    /// Open-loop arrivals shed per app (backlog at `arrival_queue_cap`).
    arrivals_shed: Vec<usize>,
    /// Per-app kernel-hang injection schedule (`SimConfig::faults`):
    /// sorted `(t_ns, extra_ns)` pairs, turned into `FaultDue` events at
    /// the start of `run` and popped in order as they fire. The sharded
    /// runner deals these per app exactly like arrival schedules, so the
    /// merged trace is a pure function of (config, seed).
    fault_schedule: Vec<std::collections::VecDeque<(Nanos, Nanos)>>,
    /// Injected hang nanoseconds waiting to stretch the app's next
    /// dispatched batch (a hang needs a victim kernel; an idle app's
    /// hang waits for its next dispatch).
    pending_fault_ns: Vec<Nanos>,
    /// Fault injections fired per app.
    faults_injected: Vec<usize>,
    /// Mirrored autoscale timeline (`SimConfig::autoscale`): the
    /// active-shard count per policy window, `(window start, active)`.
    /// Computed pre-partition from the GLOBAL arrival stream (like the
    /// arrival and fault schedules), so the fleet's scale story is a
    /// pure function of (config, seed) at any `COOK_SIM_THREADS`.
    /// Empty unless autoscale is set on an open-loop run.
    scale_timeline: Vec<(Nanos, usize)>,
    /// Per-shard scheduled scale transitions, turned into `ScaleDue`
    /// events at the start of `run` and popped in order as they fire
    /// (the sharded runner deals these from the parent, like faults).
    scale_transitions: Vec<std::collections::VecDeque<(Nanos, usize)>>,
    /// Per-shard fired transitions `(t, new active count)` — the
    /// observability log `ScaleDue` appends to. Nothing else in the
    /// engine reads it, which is what keeps `autoscale: None` traces
    /// bit-identical to the fixed-fleet engine.
    scale_log: Vec<Vec<(Nanos, usize)>>,
    /// Source programs retained for the shard partitioner (`num_gpus > 1`
    /// only): `run` re-compiles each shard's subset into an independent
    /// sub-simulation. `None` for single-GPU runs and after a fleet run.
    fleet_programs: Option<Vec<Program>>,
}

/// Tag base for per-shard child seeds ("SHAR" | shard index): shard `s`
/// of a fleet run draws every stream from
/// `DetRng::new(cfg.seed).child_seed(SHARD_SEED_TAG | s)`, so shard
/// streams are independent of each other and of how many draws any
/// other shard makes (each shard's seed mixes only the root seed and
/// its own index).
const SHARD_SEED_TAG: u64 = 0x5348_4152_0000_0000;

/// Policy windows the mirrored autoscaler evaluates over the horizon
/// (the `cook experiment autoscale` figure plots one row per window).
pub const SCALE_WINDOWS: usize = 16;

/// Build the autoscale timeline: bucket the global arrival stream into
/// [`SCALE_WINDOWS`] equal windows and map the per-window counts onto
/// an active-shard count via the deterministic controller mirror
/// ([`crate::control::elastic::plan_windows`]). Bounds clamp to the
/// fleet's shard count so the timeline can never name a shard the sim
/// does not have.
fn plan_scale_timeline(
    stream: &[Nanos],
    horizon_ns: Nanos,
    auto: crate::control::elastic::AutoscaleSpec,
    num_gpus: usize,
) -> Vec<(Nanos, usize)> {
    let w = (horizon_ns / SCALE_WINDOWS as Nanos).max(1);
    let mut counts = vec![0usize; SCALE_WINDOWS];
    for &t in stream {
        counts[((t / w) as usize).min(SCALE_WINDOWS - 1)] += 1;
    }
    let plan = crate::control::elastic::plan_windows(
        &counts,
        auto.min.min(num_gpus),
        auto.max.min(num_gpus),
    );
    plan.into_iter().enumerate().map(|(i, a)| (i as Nanos * w, a)).collect()
}

/// Active-shard count at time `t` per a non-empty timeline (the entry
/// in force: last window starting at or before `t`).
fn active_at(timeline: &[(Nanos, usize)], t: Nanos) -> usize {
    let i = timeline.partition_point(|&(ts, _)| ts <= t);
    timeline[i.saturating_sub(1)].1
}

/// Collapse a timeline into per-shard transition deques: a change from
/// `a` to `b` active shards at `t` touches exactly the shards in
/// `min(a,b)..max(a,b)` (the ones that go live or start draining), each
/// of which gets one `(t, b)` entry — the schedule behind its
/// `ScaleDue` events.
fn transitions_of(
    timeline: &[(Nanos, usize)],
    num_gpus: usize,
) -> Vec<std::collections::VecDeque<(Nanos, usize)>> {
    let mut out = vec![std::collections::VecDeque::new(); num_gpus];
    let Some(&(_, first)) = timeline.first() else {
        return out;
    };
    let mut prev = first;
    for &(t, a) in &timeline[1..] {
        if a != prev {
            for s in a.min(prev)..a.max(prev) {
                out[s].push_back((t, a));
            }
            prev = a;
        }
    }
    out
}

impl Sim {
    /// Build a simulator running `programs`, one application per program,
    /// each in its own GPU context with its own default stream (§II-A).
    pub fn new(cfg: SimConfig, programs: Vec<Program>) -> Self {
        let n = programs.len();
        assert!(
            n <= RunnableSet::MAX_CTXS,
            "Sim supports at most {} contexts (got {n}): the runnable-set \
             bitmask carries one bit per context",
            RunnableSet::MAX_CTXS
        );
        assert!(cfg.num_gpus >= 1, "num_gpus must be >= 1");
        let num_gpus = cfg.num_gpus;
        // Round-robin placement of applications over the fleet's shards.
        let shard_of_ctx: Vec<usize> = (0..n).map(|i| i % num_gpus).collect();
        let policy = AccessPolicy::new(cfg.strategy);
        let root = DetRng::new(cfg.seed);
        let mut ctxs = Vec::with_capacity(n);
        let mut apps = Vec::with_capacity(n);
        let mut workers = Vec::with_capacity(n);
        let mut trace = TraceCollector::new(true);
        // Op-count hint for pre-sizing the event queue, the op slab and
        // the trace: one-shot programs run their routines once (x4 covers
        // the callback strategy's 3-ops-per-routine expansion plus host
        // events); looping programs get a generous starting block and the
        // vectors amortise from there.
        let mut op_hint = 0usize;
        for (i, prog) in programs.iter().enumerate() {
            let ctx_id = CtxId(i);
            let mut ctx = GpuContext::new(ctx_id, cfg.platform.callback_threads);
            let stream = ctx.default_stream();
            if policy.uses_worker() {
                let wstream = ctx.create_stream();
                workers.push(Some(WorkerState::new(wstream)));
            } else {
                workers.push(None);
            }
            // Program build: kernel names are interned here, once; the
            // hot path only ever sees dense `SymId`s.
            let compiled = prog.compile(&mut |name| trace.intern(name));
            op_hint += compiled.gpu_routines().max(1)
                * match compiled.repeat {
                    RepeatMode::Once => 4,
                    RepeatMode::LoopUntilHorizon => 64,
                };
            apps.push(HostState::new(compiled, ctx_id, stream));
            ctxs.push(ctx);
        }
        let op_hint = op_hint.min(1 << 20);
        trace.reserve_ops(op_hint);
        // Open-loop traffic: one seeded global arrival stream covering
        // the horizon, dealt round-robin over the applications that can
        // consume it — looping programs only (the same assignment the
        // live fleet dispatcher uses; `Once` programs model setup work
        // and never take requests, so dealing them arrivals would admit
        // backlog nobody ever drains and silently dilute the offered
        // load). Deterministic in (config, seed); empty when closed-loop.
        let open_loop = cfg.arrivals.is_open_loop();
        let mut arrival_schedule = vec![Vec::new(); n];
        let serving_apps: Vec<usize> = (0..n)
            .filter(|&i| apps[i].program.repeat == RepeatMode::LoopUntilHorizon)
            .collect();
        let mut scale_timeline: Vec<(Nanos, usize)> = Vec::new();
        if open_loop && !serving_apps.is_empty() {
            let stream = cfg.arrivals.schedule_until(cfg.horizon_ns, cfg.seed);
            if let Some(auto) = cfg.autoscale {
                scale_timeline = plan_scale_timeline(&stream, cfg.horizon_ns, auto, num_gpus);
            }
            for (k, t) in stream.into_iter().enumerate() {
                // Deal each arrival over the serving apps whose shard is
                // live at its arrival time (the mirrored controller's
                // window timeline). Without autoscale the timeline is
                // empty and the dealing is the historical
                // `k % serving_apps` — byte-for-byte.
                let live: Vec<usize> = if scale_timeline.is_empty() {
                    Vec::new()
                } else {
                    let active = active_at(&scale_timeline, t);
                    serving_apps
                        .iter()
                        .copied()
                        .filter(|&a| shard_of_ctx[a] < active)
                        .collect()
                };
                let pool = if live.is_empty() { &serving_apps } else { &live };
                arrival_schedule[pool[k % pool.len()]].push(t);
            }
        }
        let scale_transitions = transitions_of(&scale_timeline, num_gpus);
        // Seeded kernel-hang injections (`SimConfig::faults`, DESIGN.md
        // §12): a per-app schedule of (fire time, extra ns), a pure
        // function of (spec, app, shard, horizon, seed) — the simulator
        // mirror of the live `FaultyExecutor`'s hangs.
        let mut fault_schedule: Vec<std::collections::VecDeque<(Nanos, Nanos)>> =
            vec![std::collections::VecDeque::new(); n];
        if cfg.faults.has_sim_clauses() {
            for i in 0..n {
                fault_schedule[i] = cfg
                    .faults
                    .sim_schedule(i, shard_of_ctx[i], cfg.horizon_ns, cfg.seed)
                    .into();
            }
        }
        let num_sms = cfg.platform.num_sms;
        // Spatial policies (PTB) pin each application to its SM share —
        // partitioned among the apps that share its *shard*: every GPU of
        // the fleet has the full SM bank, so partitions never span GPUs.
        let sm_mask = (0..n)
            .map(|i| {
                let peers = shard_of_ctx.iter().filter(|&&s| s == shard_of_ctx[i]).count();
                let rank = shard_of_ctx[..i].iter().filter(|&&s| s == shard_of_ctx[i]).count();
                (0..num_sms)
                    .map(|sm| policy.sm_allowed(rank, peers, sm, num_sms))
                    .collect()
            })
            .collect();
        // `mig:<s>` hard-partitions each shard's L2 into `s` equal
        // slices; every other mode keeps one full-capacity slice, so
        // the cook path touches the exact same cache object as before.
        let l2_slices = cfg.concurrency.l2_slices();
        // How many contexts each shard's `GPU_LOCK` may grant at once:
        // 1 for cook/streams (the paper's exclusive semaphore), the
        // quota/slice count for mps/mig spatial co-running.
        let lock_capacity = cfg.concurrency.sim_lock_capacity();
        let mut sim = Self {
            policy,
            l2: (0..num_gpus)
                .map(|_| {
                    (0..l2_slices)
                        .map(|_| L2State::new(cfg.platform.l2_bytes / l2_slices))
                        .collect()
                })
                .collect(),
            sms: vec![vec![SmState::default(); num_sms]; num_gpus],
            rng_exec: root.child(0x45584543), // "EXEC"
            rng_stall: root.child(0x5354414c), // "STAL"
            arbiters: (0..num_gpus).map(|_| make_arbiter(cfg.arbiter, &cfg.classes)).collect(),
            class_of_app: (0..n).map(|i| class_of(i, cfg.classes.len())).collect(),
            cfg,
            now: 0,
            events: EventQueue::with_capacity(op_hint),
            ops: Vec::with_capacity(op_hint),
            op_flags: Vec::with_capacity(op_hint),
            dirty: 0,
            ctxs,
            apps,
            workers,
            locks: (0..num_gpus)
                .map(|_| GpuLock::with_count(lock_capacity))
                .collect(),
            gpus: (0..num_gpus).map(|_| GpuExec::default()).collect(),
            batches: BatchSlab::default(),
            last_activity: vec![None; n],
            shard_of_ctx,
            trace,
            next_block_uid: 0,
            horizon_reached: false,
            sm_mask,
            open_loop,
            arrival_schedule,
            arrivals_offered: vec![0; n],
            arrivals_shed: vec![0; n],
            fault_schedule,
            pending_fault_ns: vec![0; n],
            faults_injected: vec![0; n],
            scale_timeline,
            scale_transitions,
            scale_log: vec![Vec::new(); num_gpus],
            fleet_programs: (num_gpus > 1).then_some(programs),
        };
        // Mode-driven SM banking (mps/mig) overrides the policy masks;
        // cook/streams leave them untouched.
        sim.recompute_concurrency_masks();
        sim
    }

    /// Re-derive the SM masks the concurrency mode imposes (DESIGN.md
    /// §14). `mps:<q>` pins each application to the SM bank of its
    /// shard-local rank (`rank % q`) — spatial sharing with a quota,
    /// the simulator's model of MPS active-thread percentages. `mig:<s>`
    /// pins each application to the bank of its tenant-class slice
    /// (`class % s`) — a hard partition that follows the GLOBAL class
    /// identity, which is why the sharded runner must call this again
    /// after dealing `class_of_app` from the parent (thread-count
    /// invariance depends on it). SM `i` belongs to bank `i * k /
    /// num_sms`, the same proportional split PTB uses, so every bank is
    /// non-empty whenever `k <= num_sms`. `cook`/`streams` keep the
    /// policy-derived masks untouched.
    fn recompute_concurrency_masks(&mut self) {
        let num_sms = self.cfg.platform.num_sms;
        let k = match self.cfg.concurrency {
            ConcurrencyMode::Mps { quota } => quota,
            ConcurrencyMode::Mig { slices } => slices,
            ConcurrencyMode::Cook | ConcurrencyMode::Streams => return,
        }
        .clamp(1, num_sms);
        for i in 0..self.sm_mask.len() {
            let bank = match self.cfg.concurrency {
                ConcurrencyMode::Mps { .. } => {
                    // Shard-local rank: position of app i among the apps
                    // placed on its shard. Identical in the parent and in
                    // a sub-sim (round-robin dealing preserves order).
                    let rank = self.shard_of_ctx[..i]
                        .iter()
                        .filter(|&&s| s == self.shard_of_ctx[i])
                        .count();
                    rank % k
                }
                ConcurrencyMode::Mig { .. } => self.class_of_app[i] % k,
                _ => unreachable!(),
            };
            for sm in 0..num_sms {
                self.sm_mask[i][sm] = sm * k / num_sms == bank;
            }
        }
    }

    /// Number of GPU shards in this run's fleet.
    pub fn num_gpus(&self) -> usize {
        self.gpus.len()
    }

    /// The shard (GPU) application `app` is placed on.
    pub fn shard_of(&self, app: AppId) -> usize {
        self.shard_of_ctx[self.apps[app.0].ctx.0]
    }

    /// Applications placed on `shard`, in app-id order.
    pub fn shard_apps(&self, shard: usize) -> Vec<AppId> {
        (0..self.apps.len())
            .filter(|&a| self.shard_of(AppId(a)) == shard)
            .map(AppId)
            .collect()
    }

    /// Cross-app kernel overlaps *within* each shard, indexed by shard.
    /// The paper's isolation guarantee is per-GPU: a gated strategy must
    /// drive every entry to 0, while kernels on different shards may (and
    /// should) overlap freely.
    pub fn within_shard_overlaps(&self) -> Vec<usize> {
        (0..self.num_gpus())
            .map(|s| self.trace.cross_app_kernel_overlaps_among(&self.shard_apps(s)))
            .collect()
    }

    /// The mirrored autoscale timeline `(window start, active shards)`.
    /// Empty unless `SimConfig::autoscale` is set on an open-loop run.
    pub fn scale_timeline(&self) -> &[(Nanos, usize)] {
        &self.scale_timeline
    }

    /// Scale transitions that fired on `shard`, in time order, as
    /// `(t, new active count)` — filled by `ScaleDue` events.
    pub fn scale_log(&self, shard: usize) -> &[(Nanos, usize)] {
        &self.scale_log[shard]
    }

    #[inline]
    fn shard_of_app(&self, app: AppId) -> usize {
        self.shard_of(app)
    }

    #[inline]
    fn shard_of_op(&self, op: OpUid) -> usize {
        self.shard_of_ctx[self.ops[op.0 as usize].ctx.0]
    }

    /// Run to completion: all apps done, or the horizon, whichever first.
    ///
    /// A single-GPU run (`num_gpus == 1`, the paper's testbed) executes
    /// the one sequential event loop it always has. A fleet run
    /// (`num_gpus > 1`) is *partitioned*: each shard becomes an
    /// independent single-GPU sub-simulation (DESIGN.md §11) and the
    /// sub-sims execute on a worker pool capped by `COOK_SIM_THREADS`
    /// (or `--sim-threads`; default: available cores), then merge back in
    /// canonical shard order. The merged result is a pure function of
    /// (config, seed) — bit-identical at EVERY pool size, including 1.
    pub fn run(&mut self) {
        self.run_with_sim_threads(crate::harness::parallel::sim_threads());
    }

    /// [`Sim::run`] with an explicit sub-simulation pool size instead of
    /// the `COOK_SIM_THREADS` environment cap (tests pin thread counts
    /// without racing on the process environment; the result does not
    /// depend on `threads`). Ignored for single-GPU runs.
    pub fn run_with_sim_threads(&mut self, threads: usize) {
        if self.num_gpus() > 1 {
            self.run_sharded(threads.max(1));
        } else {
            self.run_single();
        }
    }

    /// Partitioned fleet run: split into per-shard sub-sims, execute on
    /// `threads` workers, merge in shard order. See DESIGN.md §11 for the
    /// partition contract; the shard-independence invariant it leans on
    /// (per-shard locks, SM banks, L2, copy engines; stall exposure and
    /// PTB partitions scoped to same-shard peers) is §8's.
    fn run_sharded(&mut self, threads: usize) {
        let Some(programs) = self.fleet_programs.take() else {
            return; // fleet Sim already ran (run() is idempotent when done)
        };
        let n = self.num_gpus();
        let root = DetRng::new(self.cfg.seed);
        let mut subs: Vec<(usize, Sim)> = Vec::with_capacity(n);
        for shard in 0..n {
            // Global apps of this shard, ascending (local j <-> global
            // shard + j*n — the round-robin placement inverted).
            let globals: Vec<usize> = (shard..self.apps.len()).step_by(n).collect();
            if globals.is_empty() {
                // num_gpus > apps: an idle GPU simulates nothing (its
                // lone Horizon event must not flag the merged run).
                continue;
            }
            let mut cfg = self.cfg.clone();
            cfg.num_gpus = 1;
            cfg.seed = root.child_seed(SHARD_SEED_TAG | shard as u64);
            let progs: Vec<Program> =
                globals.iter().map(|&g| programs[g].clone()).collect();
            let mut sub = Sim::new(cfg, progs);
            // The sub-sim regenerated an arrival schedule from its own
            // (local) app set and seed; overwrite it with this shard's
            // slice of the GLOBAL stream, so the fleet-wide dealing
            // (`k % serving_apps`, one seeded stream — DESIGN.md §9) is
            // preserved exactly under partitioning.
            for (j, &g) in globals.iter().enumerate() {
                // Class identity follows the GLOBAL app index (the
                // sub-sim recomputed it from local indices, which would
                // scramble class membership across shards).
                sub.class_of_app[j] = self.class_of_app[g];
                sub.arrival_schedule[j] = std::mem::take(&mut self.arrival_schedule[g]);
                // Fault schedules deal the same way: the parent computed
                // them per GLOBAL app index (and the fleet's root seed),
                // so the sub-sim must not regenerate them from its local
                // view — thread-count invariance depends on it.
                sub.fault_schedule[j] = std::mem::take(&mut self.fault_schedule[g]);
            }
            // The mirrored scale timeline is a per-SHARD schedule: hand
            // this shard its slice of the parent's pre-partition plan
            // (the sub-sim computed a degenerate single-shard one).
            sub.scale_transitions[0] = std::mem::take(&mut self.scale_transitions[shard]);
            // `mig` SM banks follow the GLOBAL class identity dealt just
            // above; re-derive the masks the sub-sim computed from its
            // local (scrambled) view. No-op for cook/streams.
            sub.recompute_concurrency_masks();
            subs.push((shard, sub));
        }
        // Sub-sims are embarrassingly parallel: no shared mutable state,
        // each a pure function of its (config, seed, arrival slice).
        // `parallel_map_with` returns them in input order, so the merge
        // below is canonical (shard, time, seq) at ANY pool size.
        let done = crate::harness::parallel::parallel_map_with(threads, subs, |(s, mut sub)| {
            sub.run_single();
            (s, sub)
        });
        for (shard, sub) in done {
            self.merge_shard(shard, sub);
        }
    }

    /// Fold one finished sub-simulation back into the fleet view. Records
    /// are appended shard-major (each sub's trace is already in (time,
    /// seq) order), op uids are renumbered into one dense global space,
    /// local app/ctx ids map back through the round-robin placement, and
    /// kernel-name symbols re-intern into the fleet table.
    fn merge_shard(&mut self, shard: usize, mut sub: Sim) {
        let n = self.num_gpus();
        let base = self.ops.len() as u64;
        let to_app = |a: AppId| AppId(shard + a.0 * n);
        let to_ctx = |c: CtxId| CtxId(shard + c.0 * n);
        let sym_remap = self.trace.merge_syms(&sub.trace);
        for r in sub.trace.ops.drain(..) {
            self.trace.ops.push(OpRecord {
                op: OpUid(r.op.0 + base),
                app: to_app(r.app),
                sym: r.sym.map(|s| sym_remap[s.0 as usize]),
                ..r
            });
        }
        for b in sub.trace.blocks.drain(..) {
            // SM ids are per-shard bank indices on both sides: no remap.
            self.trace.blocks.push(BlockRecord {
                op: OpUid(b.op.0 + base),
                app: to_app(b.app),
                ..b
            });
        }
        for sw in sub.trace.switches.drain(..) {
            self.trace.switches.push(SwitchRecord {
                from: sw.from.map(to_ctx),
                to: to_ctx(sw.to),
                ..sw
            });
        }
        for st in sub.trace.stalls.drain(..) {
            self.trace.stalls.push(StallRecord { op: OpUid(st.op.0 + base), ..st });
        }
        for mut o in sub.ops.drain(..) {
            o.uid = OpUid(o.uid.0 + base);
            o.app = to_app(o.app);
            o.ctx = to_ctx(o.ctx);
            o.stream.ctx = to_ctx(o.stream.ctx);
            self.ops.push(o);
            self.op_flags.push(0);
        }
        // Per-app host state comes back whole (completions, arrival
        // backlog/in-flight/latencies, block accounting); only its ctx
        // identity needs the local -> global rename.
        for (j, mut a) in sub.apps.drain(..).enumerate() {
            let g = shard + j * n;
            a.ctx = CtxId(g);
            a.stream.ctx = CtxId(g);
            self.arrivals_offered[g] = sub.arrivals_offered[j];
            self.arrivals_shed[g] = sub.arrivals_shed[j];
            self.faults_injected[g] = sub.faults_injected[j];
            self.apps[g] = a;
        }
        for (j, w) in sub.workers.drain(..).enumerate() {
            let g = shard + j * n;
            self.workers[g] = w.map(|mut w| {
                w.stream.ctx = CtxId(g);
                w
            });
        }
        self.locks[shard] = std::mem::take(&mut sub.locks).into_iter().next().unwrap();
        self.scale_log[shard] = std::mem::take(&mut sub.scale_log[0]);
        self.now = self.now.max(sub.now);
        self.horizon_reached |= sub.horizon_reached;
    }

    /// The sequential event loop: one virtual clock over one event queue
    /// (single-GPU runs take this path whole; every fleet shard runs it
    /// inside its own sub-simulation).
    fn run_single(&mut self) {
        self.events.push(self.cfg.horizon_ns, Event::Horizon);
        // Open-loop traffic: the full arrival stream is scheduled up
        // front (it is independent of service progress by definition).
        let schedule = std::mem::take(&mut self.arrival_schedule);
        for (i, times) in schedule.into_iter().enumerate() {
            for t in times {
                self.events.push(t, Event::ArrivalDue(AppId(i)));
            }
        }
        // Fault injections are scheduled up front too; the per-app deque
        // stays in place — each FaultDue pops its front entry (both are
        // sorted by fire time, so they stay in lock-step).
        for i in 0..self.fault_schedule.len() {
            for &(t, _) in self.fault_schedule[i].iter() {
                self.events.push(t, Event::FaultDue(AppId(i)));
            }
        }
        // Mirrored scale transitions are scheduled up front too; each
        // ScaleDue pops its shard's front entry (sorted by fire time).
        for s in 0..self.scale_transitions.len() {
            for &(t, _) in self.scale_transitions[s].iter() {
                self.events.push(t, Event::ScaleDue { shard: s as u32 });
            }
        }
        for i in 0..self.apps.len() {
            self.events.push(0, Event::HostReady(AppId(i)));
        }
        // Bootstrap: hosts start in `Ready` (not `Busy`), so the initial
        // HostReady events alone would mark nothing. Mirror the legacy
        // engine's unconditional first pump by marking everything dirty.
        self.mark(D_HOSTS | D_WORKERS | D_DRIVER | D_GPU);
        // Batch drain: pull EVERY event of the next virtual instant at
        // once (in the same (time, seq) order single pops would yield)
        // and run the dirty-set pump once per instant, not once per
        // event. Events pushed *at the current instant* by a handler or
        // by the pump form a follow-up batch at the same timestamp.
        //
        // This is a deliberate semantic change from the per-event pump,
        // not a pure optimisation: a generation/uid-guarded event that
        // shares an instant with the event that would have invalidated
        // it (e.g. a QuantumExpire landing at the same nanosecond as the
        // active context's final BatchDone) used to be cancelled by the
        // intervening pump and is now handled first. Every such handler
        // copes with arbitrary state (the guards exist precisely for
        // stale events), so the result is a different-but-valid schedule
        // — still a pure function of (config, seed), pinned by the
        // golden-trace suite from its first generation on the batched
        // engine.
        let mut batch: Vec<Event> = Vec::with_capacity(16);
        'run: while let Some(t) = self.events.pop_batch(&mut batch) {
            debug_assert!(t >= self.now, "time went backwards");
            self.now = t;
            for &ev in &batch {
                if ev == Event::Horizon {
                    // Horizon is pushed first (lowest seq), so nothing at
                    // the horizon instant is ever handled before it.
                    self.horizon_reached = true;
                    break 'run;
                }
                self.handle(ev);
            }
            self.pump();
            if self.apps.iter().all(|a| a.done()) {
                break;
            }
        }
    }

    pub fn horizon_reached(&self) -> bool {
        self.horizon_reached
    }

    // ------------------------------------------------------------------
    // event handling
    // ------------------------------------------------------------------

    fn handle(&mut self, ev: Event) {
        match ev {
            Event::HostReady(app) => {
                let a = &mut self.apps[app.0];
                if a.phase == HostPhase::Busy {
                    a.phase = HostPhase::Ready;
                }
                // Mark unconditionally: the host may already be `Ready`
                // (initial events) — the legacy engine pumped regardless.
                self.mark(D_HOSTS);
            }
            Event::WorkerReady(app) => self.worker_on_ready(app),
            Event::CallbackStart(op) => self.callback_start(op),
            Event::CallbackDone(op) => self.callback_done(op),
            Event::BatchDone { slot, uid } => self.batch_done(slot, uid),
            Event::CopyDone { op, gen } => self.copy_done(op, gen),
            Event::QuantumExpire { shard, gen } => self.quantum_expire(shard as usize, gen),
            Event::SwitchDone { shard, gen } => self.switch_done(shard as usize, gen),
            Event::StallDone(op) => {
                self.clear_flag(op, F_STALLED);
                self.mark(D_DRIVER);
            }
            Event::LockWake { shard } => self.lock_wake(shard as usize),
            Event::ArrivalDue(app) => self.arrival_due(app),
            Event::FaultDue(app) => self.fault_due(app),
            Event::ScaleDue { shard } => self.scale_due(shard),
            Event::Horizon => unreachable!("handled in run()"),
        }
    }

    /// A scheduled kernel-hang injection fires for `app`: its next
    /// dispatched batch is stretched by the scheduled extra nanoseconds
    /// (the simulator mirror of the live `FaultyExecutor` hang). A hang
    /// needs a victim kernel, so an idle app's hang waits, accumulated,
    /// until its next dispatch.
    fn fault_due(&mut self, app: AppId) {
        if let Some((_, extra)) = self.fault_schedule[app.0].pop_front() {
            self.pending_fault_ns[app.0] += extra;
            self.faults_injected[app.0] += 1;
            self.mark(D_GPU);
        }
    }

    /// A mirrored scale transition reaches this shard: record it in the
    /// scale log. Pure observability — arrivals were already dealt
    /// against the timeline in `new`, and nothing is marked dirty, so
    /// `autoscale: None` traces stay bit-identical to the fixed-fleet
    /// engine and the log is invariant under `COOK_SIM_THREADS`.
    fn scale_due(&mut self, shard: u32) {
        if let Some(entry) = self.scale_transitions[shard as usize].pop_front() {
            self.scale_log[shard as usize].push(entry);
        }
    }

    /// An open-loop arrival lands for `app`: admit it into the bounded
    /// backlog (waking a parked host) or shed it — the simulator mirror
    /// of the live admission queue's `reject` boundary. Latency is
    /// measured from this instant (see `MarkCompletion`).
    fn arrival_due(&mut self, app: AppId) {
        self.arrivals_offered[app.0] += 1;
        let cap = self.cfg.arrival_queue_cap;
        let now = self.now;
        let a = &mut self.apps[app.0];
        // A non-looping app can never consume an arrival (scheduling
        // excludes them; this guard keeps conservation if that changes).
        if a.done()
            || a.program.repeat != RepeatMode::LoopUntilHorizon
            || a.arrival_backlog.len() >= cap
        {
            self.arrivals_shed[app.0] += 1;
            return;
        }
        a.arrival_backlog.push_back(now);
        if a.phase == HostPhase::WaitingArrival {
            a.unblock(now);
        }
        self.mark(D_HOSTS);
    }

    // ------------------------------------------------------------------
    // dirty-set bookkeeping
    // ------------------------------------------------------------------

    #[inline]
    fn mark(&mut self, bits: u8) {
        self.dirty |= bits;
    }

    #[inline]
    fn flag(&self, op: OpUid, f: u8) -> bool {
        self.op_flags[op.0 as usize] & f != 0
    }

    #[inline]
    fn set_flag(&mut self, op: OpUid, f: u8) {
        self.op_flags[op.0 as usize] |= f;
    }

    #[inline]
    fn clear_flag(&mut self, op: OpUid, f: u8) {
        self.op_flags[op.0 as usize] &= !f;
    }

    /// Dirty-set fix-point pump (contract documented in DESIGN.md §7).
    ///
    /// Event handlers and mutation helpers mark the subsystems they
    /// touched (`D_*` bits); one sweep visits only marked subsystems, in
    /// the fixed order hosts -> workers -> driver -> GPU. Each bit is
    /// consumed *at its turn*, so a subsystem marked by an earlier pump
    /// in the same sweep still runs this sweep — exactly the mutation
    /// order of the legacy rescan-everything fix-point, minus the
    /// unproductive scans. A pump that changed anything re-marks itself
    /// (it may be productive again, e.g. a freed stream slot).
    fn pump(&mut self) {
        for _ in 0..10_000 {
            if self.dirty == 0 {
                return;
            }
            if self.dirty & D_HOSTS != 0 {
                self.dirty &= !D_HOSTS;
                if self.host_pump() {
                    self.mark(D_HOSTS);
                }
            }
            if self.dirty & D_WORKERS != 0 {
                self.dirty &= !D_WORKERS;
                if self.worker_pump() {
                    self.mark(D_WORKERS);
                }
            }
            if self.dirty & D_DRIVER != 0 {
                self.dirty &= !D_DRIVER;
                if self.driver_pump() {
                    self.mark(D_DRIVER);
                }
            }
            if self.dirty & D_GPU != 0 {
                self.dirty &= !D_GPU;
                if self.gpu_pump() {
                    self.mark(D_GPU);
                }
            }
        }
        panic!("pump failed to reach a fix-point (simulator bug)");
    }

    // ------------------------------------------------------------------
    // lock
    // ------------------------------------------------------------------

    /// The QoS class of a lock client (callbacks map through their op's
    /// owning application).
    fn class_of_client(&self, client: LockClient) -> usize {
        match client {
            LockClient::Host(app) | LockClient::Worker(app) => self.class_of_app[app.0],
            LockClient::Callback(op) => self.class_of_app[self.ops[op.0 as usize].app.0],
        }
    }

    /// Which queued waiter the next grant on `shard` goes to, as a
    /// position into the lock's arrival-order queue — the simulator
    /// mirror of the live gate's `issue_baton` pick. FIFO-order
    /// policies (and a lone waiter) short-circuit to position 0, so the
    /// default config's hot path allocates nothing and is bit-identical
    /// to the pre-arbiter engine.
    fn pick_waiter(&self, shard: usize) -> usize {
        let lock = &self.locks[shard];
        if self.arbiters[shard].kind().is_fifo_order() || lock.num_waiters() <= 1 {
            return 0;
        }
        let k = self.cfg.classes.len();
        let snap: Vec<Waiter> = lock
            .queued_waiters()
            .map(|w| {
                let class = self.class_of_client(w.client);
                let deadline_ns = if k > 0 {
                    self.cfg.classes[class]
                        .deadline_ms
                        .map(|d| w.enqueued + d.saturating_mul(1_000_000))
                } else {
                    None
                };
                Waiter { ticket: w.ticket, class, deadline_ns }
            })
            .collect();
        self.arbiters[shard].pick(&snap).min(lock.num_waiters() - 1)
    }

    /// `sem_wait` on one shard's lock. A successful (barging) grant
    /// still counts toward the client's class share — mirroring the
    /// live gate's idle fast path, which also feeds `on_grant`.
    fn lock_acquire(&mut self, shard: usize, client: LockClient) -> bool {
        if self.locks[shard].acquire(client, self.now) {
            let class = self.class_of_client(client);
            self.arbiters[shard].on_grant(class);
            true
        } else {
            false
        }
    }

    /// A sleeping waiter's wakeup on one shard's lock completes: grant if
    /// the count survived the barging window (`GpuLock::acquire` docs).
    /// One wake event is scheduled per release; the handoff latency is
    /// the wake delay. The arbiter chooses WHICH waiter takes the grant;
    /// FIFO always picks the head.
    fn lock_wake(&mut self, shard: usize) {
        let pos = self.pick_waiter(shard);
        let Some(client) = self.locks[shard].grant_nth(pos, self.now) else { return };
        let class = self.class_of_client(client);
        self.arbiters[shard].on_grant(class);
        match client {
            LockClient::Host(app) => {
                let a = &mut self.apps[app.0];
                a.holds_lock = true;
                a.unblock(self.now);
                // Back to `Ready`: the blocked routine re-executes.
                self.mark(D_HOSTS);
            }
            LockClient::Worker(app) => {
                if let Some(w) = &mut self.workers[app.0] {
                    if let WorkerPhase::WaitingLock(op) = w.phase {
                        w.phase = WorkerPhase::LockGranted(op);
                        self.events.push(self.now, Event::WorkerReady(app));
                    }
                }
            }
            LockClient::Callback(op) => {
                self.events
                    .push(self.now + self.cfg.timing.cb_exec_ns, Event::CallbackDone(op));
            }
        }
    }

    /// `sem_post` on one shard's lock + schedule the waiters' wakeup
    /// after the handoff delay. Driver callback threads wake fast
    /// (busy-polling); application host/worker threads pay the full
    /// cross-process futex latency.
    fn lock_release(&mut self, shard: usize) {
        self.locks[shard].release(self.now);
        // Peek-only pick to classify the wake delay (who is *likely* to
        // take the grant); the actual winner is re-picked at wake time,
        // when the queue may have changed. Under FIFO both picks are the
        // head, as before the arbiter existed.
        let pos = self.pick_waiter(shard);
        if let Some(head) = self.locks[shard].waiter_at(pos) {
            let delay = match head {
                LockClient::Callback(_) => self.cfg.timing.cb_wake_ns,
                _ => self.cfg.timing.lock_handoff_ns,
            };
            self.events
                .push(self.now + delay, Event::LockWake { shard: shard as u32 });
        }
    }

    // ------------------------------------------------------------------
    // host threads
    // ------------------------------------------------------------------

    fn host_pump(&mut self) -> bool {
        let mut changed = false;
        for i in 0..self.apps.len() {
            while self.apps[i].phase == HostPhase::Ready {
                if self.exec_host_step(AppId(i)) {
                    changed = true;
                } else {
                    break;
                }
            }
        }
        changed
    }

    /// Execute the current step of `app`'s program. Returns true if any
    /// state changed (the step ran or transitioned to a blocking phase).
    fn exec_host_step(&mut self, app: AppId) -> bool {
        // Open-loop gating (DESIGN.md §9): at an iteration boundary a
        // looping program consumes one admitted arrival, or parks in
        // `WaitingArrival` until `ArrivalDue` lands one. `Once` programs
        // are untouched (they model setup work, not served requests).
        if self.open_loop {
            let now = self.now;
            let a = &mut self.apps[app.0];
            if a.pc == 0
                && !a.iteration_admitted
                && a.program.repeat == RepeatMode::LoopUntilHorizon
                && !a.done()
            {
                match a.arrival_backlog.pop_front() {
                    Some(t) => {
                        a.iteration_admitted = true;
                        a.arrival_inflight.push_back(t);
                    }
                    None => {
                        a.block(HostPhase::WaitingArrival, now);
                        return true;
                    }
                }
            }
        }
        let Some(step) = self.apps[app.0].current_step() else {
            return false;
        };
        match step {
            CompiledStep::Compute(d) => {
                // CPU time stolen by driver callbacks is charged here:
                // callbacks preempt *application computation*, not the
                // thin routine-call overheads (a host thread blocked at a
                // barrier yields its core to the callback for free).
                let steal = std::mem::take(&mut self.apps[app.0].pending_steal_ns);
                self.host_busy(app, d + steal);
                self.apps[app.0].advance();
            }
            CompiledStep::MarkCompletion => {
                let now = self.now;
                let a = &mut self.apps[app.0];
                a.completions.push(now);
                // Open-loop latency: this iteration's arrival (FIFO) to
                // completion — the same arrival-to-completion measure the
                // live serving path reports.
                if let Some(arrived) = a.arrival_inflight.pop_front() {
                    a.arrival_latency_ns.push(now.saturating_sub(arrived));
                }
                a.advance();
            }
            CompiledStep::Launch(k) => return self.routine_launch(app, k),
            CompiledStep::Memcpy(c) => return self.routine_memcpy(app, c),
            CompiledStep::HostFunc(d) => return self.routine_host_func(app, d),
            CompiledStep::Sync => return self.routine_sync(app),
        }
        true
    }

    fn host_busy(&mut self, app: AppId, d: Nanos) {
        self.apps[app.0].phase = HostPhase::Busy;
        self.events.push(self.now + d, Event::HostReady(app));
    }

    /// `cudaLaunchKernel` through the active hook (Alg. 1/3/4/5).
    fn routine_launch(&mut self, app: AppId, k: KernelInstance) -> bool {
        let cost = self.cfg.timing.launch_overhead_ns;
        self.routine_gpu_op(app, OpKind::Kernel(k), cost)
    }

    /// `cudaMemcpy` through the active hook (Alg. 2 and strategy hooks).
    fn routine_memcpy(&mut self, app: AppId, c: CopyDesc) -> bool {
        let cost = self.cfg.timing.launch_overhead_ns + self.cfg.timing.memcpy_call_extra_ns;
        self.routine_gpu_op(app, OpKind::Copy(c), cost)
    }

    /// Shared kernel/copy hook body. The per-strategy *decision* lives in
    /// `control::policy`; this match interprets the returned plan with the
    /// simulator's mechanisms (ops, events, the lock, the worker queue).
    fn routine_gpu_op(&mut self, app: AppId, kind: OpKind, base_cost: Nanos) -> bool {
        let stream = self.apps[app.0].stream;
        match self.policy.admission() {
            Admission::Direct => {
                let op = self.new_op(app, kind, stream);
                self.insert_in_stream(op);
                self.host_busy(app, base_cost);
                self.apps[app.0].advance();
            }
            Admission::CallbackBracket => {
                // Alg. 3: acquire-callback, the op, release-callback.
                let acq = self.new_op(
                    app,
                    OpKind::HostFunc {
                        exec_ns: self.cfg.timing.cb_exec_ns,
                        lock_action: LockAction::Acquire,
                    },
                    stream,
                );
                let op = self.new_op(app, kind, stream);
                let rel = self.new_op(
                    app,
                    OpKind::HostFunc {
                        exec_ns: self.cfg.timing.cb_exec_ns,
                        lock_action: LockAction::Release,
                    },
                    stream,
                );
                self.insert_in_stream(acq);
                self.insert_in_stream(op);
                self.insert_in_stream(rel);
                self.host_busy(app, 3 * base_cost);
                self.apps[app.0].advance();
            }
            Admission::AcquireSyncRelease => {
                // Alg. 4: acquire; insert; sync; release (this app's
                // shard lock — isolation is per-GPU).
                let shard = self.shard_of_app(app);
                if !self.apps[app.0].holds_lock {
                    if self.lock_acquire(shard, LockClient::Host(app)) {
                        self.apps[app.0].holds_lock = true;
                    } else {
                        let now = self.now;
                        self.apps[app.0].block(HostPhase::WaitingLock, now);
                        return true;
                    }
                }
                let op = self.new_op(app, kind, stream);
                self.insert_in_stream(op);
                let now = self.now;
                self.apps[app.0].block(HostPhase::WaitingOp(op), now);
                // pc advances when the op completes (routine is synchronous).
            }
            Admission::DeferToWorker => {
                // Alg. 5: deep-copy args, defer to the worker queue. The
                // copy size (8 bytes per pointer-ish param, layout walked
                // through the registry) was resolved at program build and
                // rides the kernel instance.
                let wstream = self.workers[app.0].as_ref().unwrap().stream;
                let op = self.new_op(app, kind, wstream);
                let args_bytes = match &self.ops[op.0 as usize].kind {
                    OpKind::Kernel(k) => k.args_bytes,
                    _ => 32,
                };
                self.workers[app.0].as_mut().unwrap().enqueue(op, args_bytes);
                self.mark(D_WORKERS);
                self.host_busy(app, base_cost + self.cfg.timing.worker_enqueue_ns);
                self.apps[app.0].advance();
            }
        }
        true
    }

    /// An application host-func (the "other ordered operation" of Alg. 7).
    fn routine_host_func(&mut self, app: AppId, d: Nanos) -> bool {
        let stream = self.apps[app.0].stream;
        match self.policy.ordered_op() {
            OrderedOpRule::DrainWorkerFirst => {
                // Alg. 7: sync on worker, then insert in the app stream.
                if self.workers[app.0].as_ref().unwrap().drained() {
                    let op = self.new_op(
                        app,
                        OpKind::HostFunc { exec_ns: d, lock_action: LockAction::None },
                        stream,
                    );
                    self.insert_in_stream(op);
                    self.host_busy(app, self.cfg.timing.launch_overhead_ns);
                    self.apps[app.0].advance();
                } else {
                    let now = self.now;
                    self.apps[app.0].pending_ordered_ns = Some(d);
                    self.apps[app.0].block(HostPhase::WaitingWorker, now);
                }
            }
            OrderedOpRule::Passthrough => {
                // Trampoline: pass through unchanged (only kernel/copy are
                // hooked by the callback/synced strategies).
                let op = self.new_op(
                    app,
                    OpKind::HostFunc { exec_ns: d, lock_action: LockAction::None },
                    stream,
                );
                self.insert_in_stream(op);
                self.host_busy(app, self.cfg.timing.launch_overhead_ns);
                self.apps[app.0].advance();
            }
        }
        true
    }

    /// `cudaDeviceSynchronize` (the burst barrier).
    fn routine_sync(&mut self, app: AppId) -> bool {
        let ctx = self.apps[app.0].ctx;
        let worker_ok = match &self.workers[app.0] {
            Some(w) => w.drained(),
            None => true,
        };
        if worker_ok && self.ctx_quiescent(ctx) {
            self.apps[app.0].burst += 1;
            self.host_busy(app, self.cfg.timing.sync_wakeup_ns);
            self.apps[app.0].advance();
        } else {
            let now = self.now;
            let phase = if worker_ok { HostPhase::WaitingDevice } else { HostPhase::WaitingWorker };
            self.apps[app.0].block(phase, now);
        }
        true
    }

    // ------------------------------------------------------------------
    // worker threads (Alg. 6)
    // ------------------------------------------------------------------

    fn worker_pump(&mut self) -> bool {
        let mut changed = false;
        for i in 0..self.workers.len() {
            let Some(w) = &self.workers[i] else { continue };
            if w.phase == WorkerPhase::Idle {
                if let Some(&op) = w.queue.front() {
                    // Dequeue cost, plus CPU contention with a busy host
                    // thread (the worker shares the app's CPU resources).
                    let mut cost = self.cfg.timing.worker_dequeue_ns;
                    if self.apps[i].phase == HostPhase::Busy {
                        cost += self.cfg.timing.worker_contention_ns;
                    }
                    let w = self.workers[i].as_mut().unwrap();
                    w.queue.pop_front();
                    w.phase = WorkerPhase::Dequeuing(op);
                    self.events.push(self.now + cost, Event::WorkerReady(AppId(i)));
                    changed = true;
                }
            }
        }
        changed
    }

    fn worker_on_ready(&mut self, app: AppId) {
        let Some(w) = &self.workers[app.0] else { return };
        match w.phase {
            WorkerPhase::Dequeuing(op) => {
                let shard = self.shard_of_app(app);
                if self.lock_acquire(shard, LockClient::Worker(app)) {
                    self.worker_lock_granted_inner(app, op);
                } else {
                    self.workers[app.0].as_mut().unwrap().phase =
                        WorkerPhase::WaitingLock(op);
                }
            }
            WorkerPhase::LockGranted(op) => {
                self.worker_lock_granted_inner(app, op);
            }
            _ => {}
        }
    }

    fn worker_lock_granted_inner(&mut self, app: AppId, op: OpUid) {
        let now = self.now;
        let w = self.workers[app.0].as_mut().unwrap();
        w.on_lock_granted(now);
        w.phase = WorkerPhase::WaitingOp(op);
        self.insert_in_stream(op);
    }

    /// Called when a worker's in-flight op completes: release the lock,
    /// go idle, wake any host blocked on worker drain.
    fn worker_op_complete(&mut self, app: AppId) {
        let now = self.now;
        let w = self.workers[app.0].as_mut().unwrap();
        w.on_lock_released(now);
        w.processed += 1;
        w.phase = WorkerPhase::Idle;
        // Idle again: the worker pump may dequeue the next deferred op.
        self.mark(D_WORKERS);
        self.lock_release(self.shard_of_app(app));
        self.wake_worker_waiters(app);
    }

    fn wake_worker_waiters(&mut self, app: AppId) {
        if !self.workers[app.0].as_ref().unwrap().drained() {
            return;
        }
        if self.apps[app.0].phase == HostPhase::WaitingWorker {
            // Barrier or ordered-op wait (Alg. 7).
            if let Some(d) = self.apps[app.0].pending_ordered_ns.take() {
                self.apps[app.0].unblock(self.now);
                let stream = self.apps[app.0].stream;
                let op = self.new_op(
                    app,
                    OpKind::HostFunc { exec_ns: d, lock_action: LockAction::None },
                    stream,
                );
                self.insert_in_stream(op);
                self.host_busy(app, self.cfg.timing.launch_overhead_ns);
                self.apps[app.0].advance();
            } else {
                // Barrier: also requires ctx quiescence (ordered ops may
                // still be in the app stream).
                let ctx = self.apps[app.0].ctx;
                if self.ctx_quiescent(ctx) {
                    self.apps[app.0].unblock(self.now);
                    self.apps[app.0].burst += 1;
                    self.host_busy(app, self.cfg.timing.sync_wakeup_ns);
                    self.apps[app.0].advance();
                }
            }
        }
    }

    // ------------------------------------------------------------------
    // op plumbing
    // ------------------------------------------------------------------

    fn new_op(&mut self, app: AppId, kind: OpKind, stream: StreamId) -> OpUid {
        let uid = OpUid(self.ops.len() as u64);
        self.ops.push(Op {
            uid,
            app,
            ctx: self.apps[app.0].ctx,
            stream,
            kind,
            state: OpState::Queued,
            enqueued_at: self.now,
            started_at: None,
            completed_at: None,
            burst: self.apps[app.0].burst,
        });
        self.op_flags.push(0);
        uid
    }

    fn insert_in_stream(&mut self, op: OpUid) {
        let stream = self.ops[op.0 as usize].stream;
        self.ctxs[stream.ctx.0].stream_mut(stream).push(op);
        // A new stream tail may be (or become) the dispatchable head.
        self.mark(D_DRIVER);
    }

    /// Retire an in-flight op from its stream, unblocking the head.
    fn retire_in_stream(&mut self, op: OpUid) {
        let sid = self.ops[op.0 as usize].stream;
        self.ctxs[sid.ctx.0].stream_mut(sid).retire(op);
        self.mark(D_DRIVER);
    }

    // ------------------------------------------------------------------
    // driver front-end: stream heads -> device
    // ------------------------------------------------------------------

    fn driver_pump(&mut self) -> bool {
        let mut changed = false;
        for c in 0..self.ctxs.len() {
            for s in 0..self.ctxs[c].num_streams() {
                let sid = StreamId { ctx: CtxId(c), idx: s };
                let Some(op) = self.ctxs[c].stream(sid).head() else { continue };
                if self.flag(op, F_STALLED) {
                    continue;
                }
                // Dispatch policy: strict FIFO, except that up to
                // `hw_prefetch_depth` kernels/copies may be pushed past
                // in-flight callbacks (§VII-B isolation leak), and
                // callbacks may stack up to the pool size.
                let (mut pending_cbs, mut in_flight_len) = (0usize, 0usize);
                for o in self.ctxs[c].stream(sid).in_flight_all() {
                    in_flight_len += 1;
                    if matches!(self.ops[o.0 as usize].kind, OpKind::HostFunc { .. }) {
                        pending_cbs += 1;
                    }
                }
                let non_cb_in_flight = in_flight_len - pending_cbs;
                match &self.ops[op.0 as usize].kind {
                    OpKind::Kernel(_) | OpKind::Copy(_) => {
                        if non_cb_in_flight > 0 {
                            continue; // a kernel/copy is already in flight
                        }
                        if pending_cbs > self.cfg.platform.hw_prefetch_depth {
                            continue; // too deep past pending callbacks
                        }
                        if self.maybe_stall(op) {
                            changed = true;
                            continue;
                        }
                        self.ctxs[c].stream_mut(sid).begin_past(op);
                        self.ops[op.0 as usize].state = OpState::Running;
                        self.last_activity[c] = Some(self.now);
                        self.clear_flag(op, F_STALL_CHECKED); // done with dice
                        if self.ops[op.0 as usize].is_kernel() {
                            self.admit_kernel(op);
                        } else {
                            let shard = self.shard_of_ctx[c];
                            self.gpus[shard].copy_q.push_back(op);
                            self.mark(D_GPU);
                        }
                        changed = true;
                    }
                    OpKind::HostFunc { .. } => {
                        // The stream position is held until the callback
                        // body returns (CallbackDone retires it); the
                        // driver only needs a free pool thread to start.
                        if non_cb_in_flight > 0 {
                            continue; // completion order: wait for the op
                        }
                        if self.ctxs[c].claim_callback_slot(op).is_some() {
                            self.ctxs[c].stream_mut(sid).begin_past(op);
                            self.ops[op.0 as usize].state = OpState::Running;
                            self.events.push(
                                self.now + self.cfg.timing.cb_dispatch_ns,
                                Event::CallbackStart(op),
                            );
                            changed = true;
                        }
                    }
                    OpKind::Marker => {
                        if in_flight_len > 0 {
                            continue;
                        }
                        self.ctxs[c].stream_mut(sid).begin(op);
                        self.ctxs[c].stream_mut(sid).retire(op);
                        self.mark(D_DRIVER);
                        self.ops[op.0 as usize].started_at = Some(self.now);
                        self.complete_op(op);
                        changed = true;
                    }
                }
            }
        }
        changed
    }

    /// Shared-software-queue stall injection (DESIGN.md §5): dispatching
    /// while another context was recently active at the driver level may
    /// collide in the shared queues. The queues are per-GPU, so only
    /// contexts on the *same shard* expose each other. Returns true if
    /// the op got stalled.
    fn maybe_stall(&mut self, op: OpUid) -> bool {
        if self.flag(op, F_STALL_CHECKED) {
            return false; // already diced
        }
        self.set_flag(op, F_STALL_CHECKED);
        let ctx = self.ops[op.0 as usize].ctx;
        let shard = self.shard_of_ctx[ctx.0];
        let window = self.cfg.timing.stall_window_ns;
        let exposed = self.last_activity.iter().copied().enumerate().any(|(c, t)| {
            c != ctx.0
                && self.shard_of_ctx[c] == shard
                && matches!(t, Some(t) if self.now.saturating_sub(t) <= window)
        });
        if !exposed || !self.rng_stall.chance(self.cfg.timing.stall_prob) {
            return false;
        }
        let base = self.op_base_cost(op).max(1_000);
        let mult = self.rng_stall.pareto(self.cfg.timing.stall_alpha, self.cfg.timing.stall_cap);
        let dur = (base as f64 * mult) as Nanos;
        self.set_flag(op, F_STALLED);
        self.trace.stalls.push(StallRecord { op, at: self.now, duration_ns: dur });
        self.events.push(self.now + dur, Event::StallDone(op));
        true
    }

    /// Nominal standalone device cost of an op (stall sizing).
    fn op_base_cost(&self, op: OpUid) -> Nanos {
        match &self.ops[op.0 as usize].kind {
            OpKind::Kernel(k) => {
                let waves = (k.grid.blocks as u64).div_ceil(
                    (self.cfg.platform.num_sms
                        * self.cfg.platform.blocks_resident_per_sm(k.grid.threads_per_block))
                        as u64
                        | 1,
                );
                waves * k.block_cost_ns
            }
            OpKind::Copy(c) => self.cfg.timing.copy_duration_ns(c.bytes),
            OpKind::HostFunc { exec_ns, .. } => *exec_ns,
            OpKind::Marker => 0,
        }
    }

    // ------------------------------------------------------------------
    // callbacks (driver pool)
    // ------------------------------------------------------------------

    fn callback_start(&mut self, op: OpUid) {
        self.ops[op.0 as usize].started_at = Some(self.now);
        let (exec_ns, action) = match &self.ops[op.0 as usize].kind {
            OpKind::HostFunc { exec_ns, lock_action } => (*exec_ns, *lock_action),
            _ => unreachable!("callback_start on non-hostfunc"),
        };
        let shard = self.shard_of_op(op);
        match action {
            LockAction::Acquire => {
                if self.lock_acquire(shard, LockClient::Callback(op)) {
                    self.events
                        .push(self.now + self.cfg.timing.cb_exec_ns, Event::CallbackDone(op));
                }
                // else: blocked in the lock FIFO; lock_pump schedules done.
            }
            LockAction::Release => {
                self.lock_release(shard);
                self.events
                    .push(self.now + self.cfg.timing.cb_exec_ns, Event::CallbackDone(op));
            }
            LockAction::None => {
                self.events.push(self.now + exec_ns, Event::CallbackDone(op));
            }
        }
    }

    fn callback_done(&mut self, op: OpUid) {
        let ctx = self.ops[op.0 as usize].ctx;
        // Find and free the slot this op held.
        let slot = self.ctxs[ctx.0]
            .callback_slots
            .iter()
            .position(|s| *s == crate::cudart::context::CallbackSlot::Busy(op))
            .expect("callback op must hold a slot");
        self.ctxs[ctx.0].release_callback_slot(slot);
        // Slot freed + stream position retired: the driver may dispatch.
        self.retire_in_stream(op);
        // The callback ran on the application's CPU: charge the steal to
        // the app's next host compute segment (cache pollution + wakeups).
        let app = self.ops[op.0 as usize].app;
        self.apps[app.0].pending_steal_ns += self.cfg.timing.cb_steal_ns;
        self.complete_op(op);
    }

    // ------------------------------------------------------------------
    // GPU: context arbitration + block scheduling + copy engine
    // ------------------------------------------------------------------

    fn admit_kernel(&mut self, op: OpUid) {
        let shard = self.shard_of_op(op);
        let o = &self.ops[op.0 as usize];
        let k = o.kernel().expect("admit_kernel on non-kernel");
        self.gpus[shard].run_pool.push(KernelRun {
            op,
            ctx: o.ctx,
            app: o.app,
            total: k.grid.blocks.max(1),
            dispatched: 0,
            done: 0,
            warps_per_block: k.grid.warps_per_block(self.cfg.platform.warp_size) as usize,
            block_cost_ns: k.block_cost_ns,
            pending_cold_ns: 0,
        });
        // New device work: the block scheduler has dispatching to do.
        self.mark(D_GPU);
    }

    /// Contexts of `shard` that currently have device work (kernels or
    /// frozen blocks). Bitmask-based: no allocation on the hot path.
    fn runnable_ctxs(&self, shard: usize) -> RunnableSet {
        let mut mask: u64 = 0;
        for kr in &self.gpus[shard].run_pool {
            mask |= 1u64 << kr.ctx.0;
        }
        for fb in &self.gpus[shard].frozen {
            mask |= 1u64 << fb.ctx.0;
        }
        RunnableSet { mask }
    }

    /// Pump every shard: the GPUs are independent devices sharing only
    /// the virtual clock, so each runs its own copy engine and context
    /// arbitration. The `D_GPU` dirty bit stays fleet-global, so one
    /// marked shard re-pumps them all — an accepted deviation from the
    /// §7 minimal-mark contract: a shard pump with nothing to do is a
    /// handful of empty-vec scans, fleets are small (≤ a few GPUs), and
    /// splitting `D_GPU` per shard would complicate every mark site for
    /// a win the 1-GPU paper configurations (the hot benches) never see.
    fn gpu_pump(&mut self) -> bool {
        let mut changed = false;
        for shard in 0..self.gpus.len() {
            changed |= self.gpu_pump_shard(shard);
        }
        changed
    }

    fn gpu_pump_shard(&mut self, shard: usize) -> bool {
        let mut changed = self.copy_pump(shard);
        if self.gpus[shard].switching {
            return changed;
        }
        // Spatial co-running comes from the policy (PTB) *or* the
        // concurrency mode (mps/mig banks): either way, every runnable
        // context dispatches onto its own SM bank with no temporal
        // arbitration.
        let spatial = self.policy.arbitration() == Arbitration::Spatial
            || self.cfg.concurrency.spatial();
        let streams = self.cfg.concurrency == ConcurrencyMode::Streams;
        let runnable = self.runnable_ctxs(shard);
        if runnable.is_empty() {
            return changed;
        }
        if spatial {
            // Spatial partitioning: all contexts co-active on their SM
            // partitions; no temporal arbitration.
            for i in 0..runnable.len() {
                changed |= self.dispatch_blocks(shard, runnable.nth(i));
            }
            return changed;
        }
        // Temporal arbitration: one active context at a time (per GPU).
        let active_has_work = self.gpus[shard]
            .active_ctx
            .map(|c| runnable.contains(c))
            .unwrap_or(false);
        if !active_has_work {
            if streams {
                // Kernel-boundary preemption: the outgoing context keeps
                // the device until its in-flight batches drain (no
                // mid-batch freeze), then the highest-priority runnable
                // context takes over. `batch_done` marks `D_GPU`, so the
                // pump re-runs exactly at the boundary.
                if let Some(active) = self.gpus[shard].active_ctx {
                    if self.batches.iter().any(|b| b.ctx == active) {
                        return changed;
                    }
                }
                let next = self.priority_pick(&runnable);
                changed |= self.begin_switch(shard, next);
                return changed;
            }
            // Pick the next runnable context round-robin and switch.
            let next = runnable.nth(self.gpus[shard].rr_next % runnable.len());
            self.gpus[shard].rr_next = self.gpus[shard].rr_next.wrapping_add(1);
            changed |= self.begin_switch(shard, next);
            return changed;
        }
        let active = self.gpus[shard].active_ctx.unwrap();
        if streams {
            // Class-priority scheduling at kernel boundaries only: a
            // higher-priority context displaces the active one exactly
            // when the active context has nothing in flight. No quantum
            // is ever armed — streams never freeze a batch mid-kernel.
            let best = self.priority_pick(&runnable);
            if best != active
                && self.stream_priority(best) < self.stream_priority(active)
                && !self.batches.iter().any(|b| b.ctx == active)
            {
                changed |= self.begin_switch(shard, best);
                return changed;
            }
            changed |= self.dispatch_blocks(shard, active);
            return changed;
        }
        // Arm the preemption quantum while others are waiting.
        if runnable.len() > 1 && !self.gpus[shard].quantum_armed {
            self.gpus[shard].quantum_armed = true;
            self.gpus[shard].quantum_gen += 1;
            self.events.push(
                self.now + self.cfg.timing.ctx_quantum_ns,
                Event::QuantumExpire {
                    shard: shard as u32,
                    gen: self.gpus[shard].quantum_gen,
                },
            );
        }
        changed |= self.dispatch_blocks(shard, active);
        changed
    }

    /// Begin a context switch on `shard` to `next`. Instant when the SMs
    /// were idle and never owned (cold boot); otherwise costs
    /// ctx_switch_ns.
    fn begin_switch(&mut self, shard: usize, next: CtxId) -> bool {
        if self.gpus[shard].active_ctx == Some(next) {
            return false;
        }
        let from = self.gpus[shard].active_ctx.or(self.gpus[shard].last_ctx);
        // A switch away from resident state (frozen blocks to save) costs
        // the full register save/restore; a drained context hands the SMs
        // over with a cheap runlist update. The slab holds every shard's
        // batches, but only this shard's active ctx can match here.
        let must_save = self
            .batches
            .iter()
            .any(|b| Some(b.ctx) == self.gpus[shard].active_ctx)
            || self.gpus[shard].frozen.iter().any(|f| Some(f.ctx) == from);
        let cost = if from.is_some() && from != Some(next) {
            if must_save {
                self.cfg.timing.ctx_switch_ns
            } else {
                self.cfg.timing.idle_switch_ns
            }
        } else {
            0
        };
        self.freeze_active(shard);
        self.trace.switches.push(SwitchRecord { at: self.now, from, to: next, cost_ns: cost });
        if cost == 0 {
            self.activate(shard, next);
        } else {
            self.gpus[shard].switching = true;
            self.gpus[shard].switch_gen += 1;
            self.gpus[shard].active_ctx = None;
            self.gpus[shard].pending_next = Some(next);
            self.events.push(
                self.now + cost,
                Event::SwitchDone { shard: shard as u32, gen: self.gpus[shard].switch_gen },
            );
        }
        self.mark(D_GPU);
        true
    }

    fn switch_done(&mut self, shard: usize, gen: u64) {
        if gen != self.gpus[shard].switch_gen || !self.gpus[shard].switching {
            return;
        }
        self.gpus[shard].switching = false;
        if let Some(next) = self.gpus[shard].pending_next.take() {
            self.activate(shard, next);
        }
        // Switch complete: the new context's blocks may now dispatch.
        self.mark(D_GPU);
    }

    fn activate(&mut self, shard: usize, ctx: CtxId) {
        self.gpus[shard].active_ctx = Some(ctx);
        self.gpus[shard].last_ctx = Some(ctx);
        // CRPD is charged per batch at dispatch time through the L2
        // model's cold fraction (dispatch_blocks); nothing to do here.
    }

    /// Freeze all running batches of `shard`'s active context (state
    /// save). Slab order = slot order: deterministic, allocation-free.
    fn freeze_active(&mut self, shard: usize) {
        let Some(active) = self.gpus[shard].active_ctx else { return };
        for slot in 0..self.batches.num_slots() {
            match self.batches.get(slot) {
                Some(b) if b.ctx == active => {}
                _ => continue,
            }
            let b = self.batches.remove(slot).unwrap();
            self.sms[shard][b.sm.0].vacate(b.blocks, b.warps_per_block);
            self.gpus[shard].frozen.push(FrozenBatch {
                op: b.op,
                ctx: b.ctx,
                app: b.app,
                blocks: b.blocks,
                warps_per_block: b.warps_per_block,
                remaining_ns: b.end_at.saturating_sub(self.now),
            });
            // Its BatchDone event is now stale (uid check fails).
        }
        self.gpus[shard].quantum_armed = false;
        self.gpus[shard].active_ctx = None;
    }

    /// Streams-mode priority of a context: its tenant class (lower =
    /// more urgent), the same `class_of` identity every other layer
    /// uses, so "high-priority stream" and "gold tenant" are one notion.
    fn stream_priority(&self, ctx: CtxId) -> usize {
        self.class_of_app[ctx.0]
    }

    /// The highest-priority runnable context (lowest tenant class, FIFO
    /// tie-break on context id — `RunnableSet` iterates in ctx order).
    fn priority_pick(&self, runnable: &RunnableSet) -> CtxId {
        (0..runnable.len())
            .map(|i| runnable.nth(i))
            .min_by_key(|c| (self.stream_priority(*c), c.0))
            .expect("priority_pick on an empty runnable set")
    }

    fn quantum_expire(&mut self, shard: usize, gen: u64) {
        if gen != self.gpus[shard].quantum_gen || !self.gpus[shard].quantum_armed {
            return;
        }
        self.gpus[shard].quantum_armed = false;
        let runnable = self.runnable_ctxs(shard);
        if runnable.len() <= 1 {
            return; // nobody else waiting anymore
        }
        let Some(active) = self.gpus[shard].active_ctx else { return };
        // Round-robin to the next context after the active one.
        let pos = runnable.position(active).unwrap_or(0);
        let next = runnable.nth((pos + 1) % runnable.len());
        self.begin_switch(shard, next);
    }

    /// Place pending (and previously frozen) blocks of `ctx` onto the SMs
    /// of its shard.
    fn dispatch_blocks(&mut self, shard: usize, ctx: CtxId) -> bool {
        let mut changed = false;
        // 1. Resume frozen batches first (they keep their progress).
        let frozen: Vec<FrozenBatch> = {
            let mut out = Vec::new();
            let mut i = 0;
            while i < self.gpus[shard].frozen.len() {
                if self.gpus[shard].frozen[i].ctx == ctx {
                    out.push(self.gpus[shard].frozen.remove(i));
                } else {
                    i += 1;
                }
            }
            out
        };
        for fb in frozen {
            let sm = self.pick_sm(shard, fb.app, fb.warps_per_block);
            let crpd = self.cfg.timing.crpd_ns;
            match sm {
                Some(sm) => {
                    self.sms[shard][sm.0].occupy(fb.blocks, fb.warps_per_block);
                    let dur = fb.remaining_ns + crpd;
                    self.spawn_batch(fb.op, ctx, fb.app, sm, fb.blocks, fb.warps_per_block, dur, true);
                    changed = true;
                }
                None => {
                    self.gpus[shard].frozen.push(fb); // no room: stays frozen
                }
            }
        }
        // 2. Dispatch fresh blocks, kernels in admission order.
        for i in 0..self.gpus[shard].run_pool.len() {
            let (op, app, wpb, cost, cold) = {
                let kr = &self.gpus[shard].run_pool[i];
                if kr.ctx != ctx || kr.dispatched >= kr.total {
                    continue;
                }
                (kr.op, kr.app, kr.warps_per_block, kr.block_cost_ns, kr.pending_cold_ns)
            };
            loop {
                let remaining = {
                    let kr = &self.gpus[shard].run_pool[i];
                    (kr.total - kr.dispatched) as usize
                };
                if remaining == 0 {
                    break;
                }
                let Some(sm) = self.pick_sm(shard, app, wpb) else { break };
                let fit = self.sms[shard][sm.0].fits(&self.cfg.platform, wpb).min(remaining);
                if fit == 0 {
                    break;
                }
                self.sms[shard][sm.0].occupy(fit, wpb);
                // First touch of this kernel's working set on the L2.
                let footprint = match &self.ops[op.0 as usize].kind {
                    OpKind::Kernel(k) => k.l2_footprint_bytes,
                    _ => 0,
                };
                let cold_frac = if footprint > 0 {
                    let slice = self.l2_slice_of_ctx(ctx);
                    self.l2[shard][slice].touch(ctx, footprint)
                } else {
                    0.0
                };
                let jit = self.rng_exec.jitter(self.cfg.timing.jitter_amp);
                let tail = if self.rng_exec.chance(self.cfg.timing.inherent_tail_prob) {
                    self.rng_exec.pareto(1.0, self.cfg.timing.inherent_tail_cap)
                } else {
                    1.0
                };
                let dur = (cost as f64 * jit * tail) as Nanos
                    + cold
                    + (self.cfg.timing.crpd_ns as f64 * cold_frac) as Nanos
                    // Pending hang injection (`SimConfig::faults`): the
                    // whole accumulated stretch lands on this batch.
                    + std::mem::take(&mut self.pending_fault_ns[app.0]);
                self.gpus[shard].run_pool[i].dispatched += fit as u32;
                if self.ops[op.0 as usize].started_at.is_none() {
                    self.ops[op.0 as usize].started_at = Some(self.now);
                }
                self.spawn_batch(op, ctx, app, sm, fit, wpb, dur, false);
                changed = true;
            }
            self.gpus[shard].run_pool[i].pending_cold_ns = 0;
        }
        if changed {
            self.last_activity[ctx.0] = Some(self.now);
        }
        changed
    }

    /// Least-loaded SM of `shard` allowed for `app` with room for one
    /// more block.
    fn pick_sm(&self, shard: usize, app: AppId, warps_per_block: usize) -> Option<SmId> {
        let mut best: Option<(usize, usize)> = None; // (used_warps, idx)
        for (i, sm) in self.sms[shard].iter().enumerate() {
            if !self.sm_mask[app.0][i] {
                continue;
            }
            if sm.fits(&self.cfg.platform, warps_per_block) == 0 {
                continue;
            }
            match best {
                Some((w, _)) if sm.used_warps >= w => {}
                _ => best = Some((sm.used_warps, i)),
            }
        }
        best.map(|(_, i)| SmId(i))
    }

    #[allow(clippy::too_many_arguments)]
    fn spawn_batch(
        &mut self,
        op: OpUid,
        ctx: CtxId,
        app: AppId,
        sm: SmId,
        blocks: usize,
        warps_per_block: usize,
        dur: Nanos,
        resumed: bool,
    ) {
        self.next_block_uid += 1;
        let uid = BlockUid(self.next_block_uid);
        let end = self.now + dur.max(1);
        let slot = self.batches.insert(Batch {
            uid,
            op,
            ctx,
            app,
            sm,
            blocks,
            warps_per_block,
            started_at: self.now,
            end_at: end,
            resumed,
        });
        self.events.push(end, Event::BatchDone { slot, uid });
    }

    fn batch_done(&mut self, slot: u32, uid: BlockUid) {
        match self.batches.get(slot) {
            Some(b) if b.uid == uid => {}
            _ => return, // stale: batch was frozen/cancelled, slot reused
        }
        let b = self.batches.remove(slot).unwrap();
        let shard = self.shard_of_ctx[b.ctx.0];
        self.sms[shard][b.sm.0].vacate(b.blocks, b.warps_per_block);
        // Freed SM residency (and possibly a finished kernel): the block
        // scheduler has room to fill.
        self.mark(D_GPU);
        if self.trace.block_level {
            self.trace.blocks.push(BlockRecord {
                op: b.op,
                app: b.app,
                sm: b.sm,
                blocks: b.blocks as u32,
                start: b.started_at,
                end: self.now,
                resumed: b.resumed,
            });
        }
        let idx = self.gpus[shard]
            .run_pool
            .iter()
            .position(|kr| kr.op == b.op)
            .expect("batch for unknown kernel");
        self.gpus[shard].run_pool[idx].done += b.blocks as u32;
        self.last_activity[b.ctx.0] = Some(self.now);
        if self.gpus[shard].run_pool[idx].done >= self.gpus[shard].run_pool[idx].total {
            let kr = self.gpus[shard].run_pool.remove(idx);
            // FIFO retirement in the op's stream.
            self.retire_in_stream(kr.op);
            self.complete_op(kr.op);
        }
    }

    fn copy_pump(&mut self, shard: usize) -> bool {
        if self.gpus[shard].copy_current.is_some() {
            return false;
        }
        let Some(op) = self.gpus[shard].copy_q.pop_front() else { return false };
        let bytes = match &self.ops[op.0 as usize].kind {
            OpKind::Copy(c) => c.bytes,
            _ => unreachable!("copy_pump on non-copy"),
        };
        let jit = self.rng_exec.jitter(self.cfg.timing.jitter_amp);
        let dur = (self.cfg.timing.copy_duration_ns(bytes) as f64 * jit) as Nanos;
        self.ops[op.0 as usize].started_at = Some(self.now);
        // Copies stream through the L2, polluting it (§VII-A effects) —
        // only the copying context's own slice under `mig` partitioning.
        let slice = self.l2_slice_of_ctx(self.ops[op.0 as usize].ctx);
        self.l2[shard][slice].pollute(bytes.min(self.cfg.platform.l2_bytes / 2));
        self.gpus[shard].copy_current = Some(op);
        self.gpus[shard].copy_gen += 1;
        self.events.push(
            self.now + dur.max(1),
            Event::CopyDone { op, gen: self.gpus[shard].copy_gen },
        );
        true
    }

    fn copy_done(&mut self, op: OpUid, gen: u64) {
        let shard = self.shard_of_op(op);
        if self.gpus[shard].copy_current != Some(op) || gen != self.gpus[shard].copy_gen {
            return;
        }
        self.gpus[shard].copy_current = None;
        // Copy engine free: the next queued transfer may start.
        self.mark(D_GPU);
        self.retire_in_stream(op);
        let ctx = self.ops[op.0 as usize].ctx;
        self.last_activity[ctx.0] = Some(self.now);
        self.complete_op(op);
    }

    // ------------------------------------------------------------------
    // op completion + wakeups
    // ------------------------------------------------------------------

    fn complete_op(&mut self, op: OpUid) {
        // Stamp the op and derive its trace record in one borrow — no
        // `Op` clone, no string clone (kernel names are interned syms).
        let rec = {
            let o = &mut self.ops[op.0 as usize];
            o.state = OpState::Complete;
            if o.started_at.is_none() {
                o.started_at = Some(self.now);
            }
            o.completed_at = Some(self.now);
            OpRecord {
                op,
                app: o.app,
                sym: o.kernel().map(|k| k.sym),
                is_kernel: o.is_kernel(),
                is_copy: o.is_copy(),
                enqueued_at: o.enqueued_at,
                started_at: o.started_at.unwrap(),
                completed_at: self.now,
                burst: o.burst,
            }
        };
        self.trace.ops.push(rec);

        // Wake a synced-strategy host waiting on this op.
        for i in 0..self.apps.len() {
            if self.apps[i].phase == HostPhase::WaitingOp(op) {
                debug_assert!(self.apps[i].holds_lock);
                self.apps[i].holds_lock = false;
                self.lock_release(self.shard_of_app(AppId(i)));
                self.apps[i].unblock(self.now);
                self.apps[i].advance();
                self.host_busy(AppId(i), self.cfg.timing.sync_wakeup_ns);
            }
        }
        // Wake a worker waiting on this op.
        for i in 0..self.workers.len() {
            if let Some(w) = &self.workers[i] {
                if w.phase == WorkerPhase::WaitingOp(op) {
                    self.worker_op_complete(AppId(i));
                }
            }
        }
        // Wake hosts blocked on a device barrier (either directly, or via
        // the worker-drain phase when the drain already happened and only
        // stream quiescence was missing).
        for i in 0..self.apps.len() {
            let barrier_wait = match self.apps[i].phase {
                HostPhase::WaitingDevice => true,
                HostPhase::WaitingWorker => self.apps[i].pending_ordered_ns.is_none(),
                _ => false,
            };
            if barrier_wait {
                let ctx = self.apps[i].ctx;
                let worker_ok = match &self.workers[i] {
                    Some(w) => w.drained(),
                    None => true,
                };
                if worker_ok && self.ctx_quiescent(ctx) {
                    self.apps[i].unblock(self.now);
                    self.apps[i].burst += 1;
                    self.apps[i].advance();
                    self.host_busy(AppId(i), self.cfg.timing.sync_wakeup_ns);
                }
            }
        }
    }

    /// Nothing of `ctx` anywhere in its shard's stack: streams, run pool,
    /// copies, callbacks, stalls.
    pub fn ctx_quiescent(&self, ctx: CtxId) -> bool {
        if !self.ctxs[ctx.0].quiescent() {
            return false;
        }
        let shard = &self.gpus[self.shard_of_ctx[ctx.0]];
        if shard.run_pool.iter().any(|kr| kr.ctx == ctx) {
            return false;
        }
        if shard.frozen.iter().any(|fb| fb.ctx == ctx) {
            return false;
        }
        if let Some(op) = shard.copy_current {
            if self.ops[op.0 as usize].ctx == ctx {
                return false;
            }
        }
        if shard.copy_q.iter().any(|op| self.ops[op.0 as usize].ctx == ctx) {
            return false;
        }
        true
    }

    /// L2 slice serving `ctx` on its shard: slice 0 everywhere except
    /// `mig:<s>`, where the context's tenant class picks its partition.
    #[inline]
    fn l2_slice_of_ctx(&self, ctx: CtxId) -> usize {
        let k = self.l2[0].len();
        if k == 1 { 0 } else { self.class_of_app[ctx.0] % k }
    }

    /// How many L2 slices each shard's cache is split into (1 unless
    /// `mig` partitioning is active). Exposed for isolation tests.
    pub fn l2_slice_count(&self) -> usize {
        self.l2[0].len()
    }

    /// The L2 slice application `app` is pinned to (tenant-class slice
    /// under `mig`, slice 0 otherwise). Exposed for isolation tests.
    pub fn l2_slice_of_app(&self, app: AppId) -> usize {
        self.l2_slice_of_ctx(self.apps[app.0].ctx)
    }

    /// The SMs application `app` may dispatch onto (its shard-local
    /// bank). Exposed for isolation tests: `mig` banks of different
    /// tenant classes must be disjoint.
    pub fn sm_bank_of_app(&self, app: AppId) -> Vec<usize> {
        (0..self.cfg.platform.num_sms)
            .filter(|&sm| self.sm_mask[app.0][sm])
            .collect()
    }

    /// Inferences-per-second input: completion timestamps per app.
    pub fn completions(&self, app: AppId) -> &[Nanos] {
        &self.apps[app.0].completions
    }

    /// Arrival-to-completion latencies (ns) of `app`'s iterations under
    /// open-loop arrivals (empty for closed-loop runs). In completion
    /// order, not sorted.
    pub fn arrival_latencies(&self, app: AppId) -> &[Nanos] {
        &self.apps[app.0].arrival_latency_ns
    }

    /// (offered, shed) open-loop arrival counts for `app`; both zero for
    /// closed-loop runs.
    pub fn arrival_counts(&self, app: AppId) -> (usize, usize) {
        (self.arrivals_offered[app.0], self.arrivals_shed[app.0])
    }

    /// Kernel-hang injections fired for `app` (`SimConfig::faults`);
    /// zero when no sim-addressed fault clause is configured.
    pub fn fault_count(&self, app: AppId) -> usize {
        self.faults_injected[app.0]
    }

    /// Fault injections fired across every application.
    pub fn faults_total(&self) -> usize {
        self.faults_injected.iter().sum()
    }
}
