//! Minimal JSON parser (no external deps — the build is fully offline).
//!
//! Parses the constrained JSON this project itself produces (the AOT
//! `artifacts/manifest.json` and results files): objects, arrays, strings
//! with standard escapes, f64 numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub at: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.at, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing content"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn idx(&self, i: usize) -> Option<&Json> {
        match self {
            Json::Arr(v) => v.get(i),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

/// Compact serializer (round-trips through `Json::parse`). Object keys
/// emit in `BTreeMap` order — deterministic output for results files.
impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.is_finite() {
                    write!(f, "{n}")
                } else {
                    f.write_str("null") // JSON has no inf/nan
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                f.write_str("[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{x}")?;
                }
                f.write_str("]")
            }
            Json::Obj(m) => {
                f.write_str("{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\t' => f.write_str("\\t")?,
            '\r' => f.write_str("\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { at: self.i, msg: msg.to_string() }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && (self.b[self.i] as char).is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c == b'-' || c == b'+' || c == b'.' || c == b'e' || c == b'E' || c.is_ascii_digit()
            {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // UTF-8 passthrough: copy the raw byte run.
                    let start = self.i;
                    let len = utf8_len(c);
                    self.i += len;
                    if self.i > self.b.len() {
                        return Err(self.err("truncated utf8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let val = self.value()?;
            out.insert(key, val);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    if first < 0x80 {
        1
    } else if first >> 5 == 0b110 {
        2
    } else if first >> 4 == 0b1110 {
        3
    } else {
        4
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structure() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(j.get("a").unwrap().idx(0).unwrap().as_f64(), Some(1.0));
        assert_eq!(
            j.get("a").unwrap().idx(2).unwrap().get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(j.get("d").unwrap(), &Json::Bool(false));
    }

    #[test]
    fn escapes() {
        let j = Json::parse(r#""line\nquote\" endA""#).unwrap();
        assert_eq!(j.as_str(), Some("line\nquote\" endA"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"open").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn display_roundtrips_through_parse() {
        let src = r#"{"a": [1, 2.5, {"b": "c\nd"}], "e": false, "f": null}"#;
        let j = Json::parse(src).unwrap();
        let back = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn display_maps_nonfinite_to_null() {
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{
            "vecadd": {
                "hlo": "vecadd.hlo.txt",
                "args": [{"shape": [8], "dtype": "float32"}],
                "out_shape": [8],
                "golden_seed": 42,
                "golden_output_head": [1.0, -0.5]
            }
        }"#;
        let j = Json::parse(text).unwrap();
        let v = j.get("vecadd").unwrap();
        assert_eq!(v.get("hlo").unwrap().as_str(), Some("vecadd.hlo.txt"));
        assert_eq!(
            v.get("args").unwrap().idx(0).unwrap().get("shape").unwrap().idx(0).unwrap().as_usize(),
            Some(8)
        );
    }
}
