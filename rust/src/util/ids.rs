//! Strongly-typed identifiers used across the simulator.
//!
//! Everything is a thin newtype over an index so subsystems cannot confuse
//! an application id with a context id even though, in the common
//! one-context-per-process setup (§II-A of the paper), they happen to be
//! numerically equal.

use std::fmt;

/// An application (one host process, one CARMEL core, one GPU context).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct AppId(pub usize);

/// A GPU context. Separate OS processes default to separate contexts.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct CtxId(pub usize);

/// A CUDA stream within a context (FIFO queue of GPU operations).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct StreamId {
    pub ctx: CtxId,
    pub idx: usize,
}

/// A streaming multiprocessor (the Xavier Volta has 8).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SmId(pub usize);

/// Unique id of one GPU operation instance (kernel launch, copy, callback).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct OpUid(pub u64);

/// Unique id of one thread block instance of one kernel op.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct BlockUid(pub u64);

/// Interned kernel-name symbol. Resolved once when a `Program` is
/// compiled for a run (`Program::compile`); the hot path then carries
/// this dense id instead of cloning name strings per operation. Resolve
/// back to the name through `TraceCollector::sym_name`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct SymId(pub u32);

impl fmt::Display for AppId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "app{}", self.0)
    }
}
impl fmt::Display for CtxId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "ctx{}", self.0)
    }
}
impl fmt::Display for StreamId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}.s{}", self.ctx, self.idx)
    }
}
impl fmt::Display for OpUid {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "op{}", self.0)
    }
}
impl fmt::Display for SymId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "sym{}", self.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_forms() {
        assert_eq!(AppId(3).to_string(), "app3");
        let s = StreamId { ctx: CtxId(1), idx: 2 };
        assert_eq!(s.to_string(), "ctx1.s2");
        assert_eq!(OpUid(9).to_string(), "op9");
    }

    #[test]
    fn ids_are_ordered_and_hashable() {
        use std::collections::HashSet;
        let mut set = HashSet::new();
        set.insert(OpUid(1));
        set.insert(OpUid(1));
        set.insert(OpUid(2));
        assert_eq!(set.len(), 2);
        assert!(OpUid(1) < OpUid(2));
    }
}
