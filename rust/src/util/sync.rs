//! Synchronisation helpers shared by the live serving stack.

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Lock a mutex, recovering from poisoning.
///
/// For state that is valid after any panic of a holder — plain counters,
/// histograms, queues — poisoning carries no information worth
/// propagating, while an `unwrap()` (or a silently skipped `if let Ok`)
/// turns one panicked client into a permanently wedged lock for everyone
/// behind it (the ISSUE 4 gate regression). Callers whose invariants
/// *can* be broken mid-update must not use this.
pub(crate) fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(7u32));
        {
            let m = Arc::clone(&m);
            let _ = std::thread::spawn(move || {
                let _guard = m.lock().unwrap();
                panic!("poison");
            })
            .join();
        }
        assert!(m.is_poisoned());
        *lock_recover(&m) += 1;
        assert_eq!(*lock_recover(&m), 8);
    }
}
