//! Deterministic random numbers for the simulator (no external deps).
//!
//! Every run is seeded from the experiment config, so a configuration name
//! (e.g. `onnx_dna-parallel-synced`) plus a seed fully determines the
//! trace. The generator is xoshiro256** seeded through SplitMix64 — fast,
//! well-distributed, and trivially reproducible across platforms. Each
//! subsystem derives its own child stream so adding draws in one subsystem
//! never perturbs another.

/// SplitMix64 step (seeding and child derivation).
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic RNG handle (xoshiro256**).
#[derive(Debug, Clone)]
pub struct DetRng {
    s: [u64; 4],
    /// Root seed retained so children derive from identity, not position.
    seed: u64,
}

impl DetRng {
    /// Root generator for a run.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Self { s, seed }
    }

    /// Derive an independent child stream (e.g. per subsystem or per app).
    /// Children depend only on (root seed, tag), never on how many draws
    /// the parent has made.
    pub fn child(&self, tag: u64) -> Self {
        Self::new(self.child_seed(tag))
    }

    /// The seed `child(tag)` reseeds with — for carrying a derived stream
    /// identity across an API boundary that takes a `u64` seed (e.g. the
    /// per-shard `SimConfig`s of a partitioned fleet run) while keeping
    /// the (root seed, tag)-only dependence of `child`.
    pub fn child_seed(&self, tag: u64) -> u64 {
        self.seed ^ tag.wrapping_mul(0xA24B_AED4_963E_E407).rotate_left(17)
    }

    /// Next raw 64-bit value (xoshiro256**).
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        // 53 high bits -> uniform double in [0, 1).
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        if lo >= hi {
            return lo;
        }
        let span = hi - lo + 1;
        lo + self.next_u64() % span
    }

    /// Uniform usize in [0, n) — handy for index picking. n must be > 0.
    pub fn index(&mut self, n: usize) -> usize {
        assert!(n > 0, "index() on empty range");
        (self.next_u64() % n as u64) as usize
    }

    /// Multiplicative jitter factor in [1-amp, 1+amp].
    pub fn jitter(&mut self, amp: f64) -> f64 {
        1.0 + amp * (2.0 * self.f64() - 1.0)
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Heavy-tailed sample in [1, cap]: Pareto-like, used by the
    /// software-stack stall injector (gpu/stall.rs) to reproduce the
    /// paper's rare 1200x onnx_dna outliers.
    pub fn pareto(&mut self, alpha: f64, cap: f64) -> f64 {
        let u = self.f64().max(1e-12);
        (1.0 / u.powf(1.0 / alpha)).min(cap)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn determinism_same_seed_same_stream() {
        let mut a = DetRng::new(7);
        let mut b = DetRng::new(7);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn children_are_independent_of_parent_draw_count() {
        let root = DetRng::new(1);
        let mut c1 = root.child(42);
        let mut root2 = DetRng::new(1);
        let _ = root2.next_u64(); // extra parent draw must not matter
        let mut c2 = root2.child(42);
        assert_eq!(c1.next_u64(), c2.next_u64());
    }

    #[test]
    fn child_seed_matches_child_stream() {
        let root = DetRng::new(11);
        let via_seed = DetRng::new(root.child_seed(7));
        let mut direct = root.child(7);
        let mut indirect = via_seed;
        for _ in 0..16 {
            assert_eq!(direct.next_u64(), indirect.next_u64());
        }
    }

    #[test]
    fn children_with_different_tags_differ() {
        let root = DetRng::new(1);
        assert_ne!(root.child(1).clone().next_u64(), root.child(2).clone().next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = DetRng::new(2);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_mean_is_near_half() {
        let mut r = DetRng::new(9);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn jitter_bounds() {
        let mut r = DetRng::new(3);
        for _ in 0..1000 {
            let j = r.jitter(0.05);
            assert!((0.95..=1.05).contains(&j));
        }
    }

    #[test]
    fn pareto_bounds_and_tail() {
        let mut r = DetRng::new(4);
        let mut seen_big = false;
        for _ in 0..20_000 {
            let v = r.pareto(1.0, 1200.0);
            assert!((1.0..=1200.0).contains(&v));
            if v > 100.0 {
                seen_big = true;
            }
        }
        assert!(seen_big, "heavy tail should occasionally exceed 100x");
    }

    #[test]
    fn range_degenerate_and_inclusive() {
        let mut r = DetRng::new(5);
        assert_eq!(r.range(4, 4), 4);
        assert_eq!(r.range(9, 2), 9);
        let mut saw_lo = false;
        let mut saw_hi = false;
        for _ in 0..1000 {
            match r.range(1, 3) {
                1 => saw_lo = true,
                3 => saw_hi = true,
                2 => {}
                other => panic!("out of range: {other}"),
            }
        }
        assert!(saw_lo && saw_hi);
    }
}
