//! Virtual time. The simulator clock is in nanoseconds; chronograms are
//! reported in GPU cycles like the paper's Figure 11 (the Xavier GPU tops
//! out at 1.377 GHz under MAXN).

/// Simulator timestamps and durations, in nanoseconds of virtual time.
pub type Nanos = u64;

/// Nominal Volta GPU frequency on the Jetson AGX Xavier under MAXN (Hz).
pub const GPU_HZ: u64 = 1_377_000_000;

/// Convert a nanosecond duration to GPU cycles (for chronogram axes).
pub fn ns_to_cycles(ns: Nanos) -> u64 {
    // (ns * GHz) without overflow for any plausible sim horizon:
    // ns < 2^44 for a 4-hour run, GPU_HZ < 2^31, so use u128.
    ((ns as u128 * GPU_HZ as u128) / 1_000_000_000u128) as u64
}

/// Convert GPU cycles to nanoseconds of virtual time.
pub fn cycles_to_ns(cycles: u64) -> Nanos {
    ((cycles as u128 * 1_000_000_000u128) / GPU_HZ as u128) as u64
}

/// Microseconds helper for readable timing configs.
pub const fn us(n: u64) -> Nanos {
    n * 1_000
}

/// Milliseconds helper for readable timing configs.
pub const fn ms(n: u64) -> Nanos {
    n * 1_000_000
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cycle_conversion_roundtrip() {
        for ns in [0u64, 1_000, 1_000_000, 60_000_000_000] {
            let cyc = ns_to_cycles(ns);
            let back = cycles_to_ns(cyc);
            // Round-trip is exact to within one cycle's worth of ns.
            assert!(back.abs_diff(ns) <= 1, "{ns} -> {cyc} -> {back}");
        }
    }

    #[test]
    fn one_second_is_gpu_hz_cycles() {
        assert_eq!(ns_to_cycles(1_000_000_000), GPU_HZ);
    }

    #[test]
    fn helpers() {
        assert_eq!(us(5), 5_000);
        assert_eq!(ms(2), 2_000_000);
    }
}
