//! Shared primitives: identifiers, virtual time, deterministic RNG.

pub mod ids;
pub mod rng;
pub mod json;
pub(crate) mod sync;
pub mod time;

pub use ids::{AppId, BlockUid, CtxId, OpUid, SmId, StreamId, SymId};
pub use rng::DetRng;
pub(crate) use sync::lock_recover;
pub use time::{cycles_to_ns, ns_to_cycles, Nanos, GPU_HZ};
