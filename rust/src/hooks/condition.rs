//! Hook conditions: which symbols each template applies to (§V-A).
//!
//! A COOK configuration is a list of rules evaluated in order; the first
//! match decides the symbol's treatment. Symbols matching no rule get the
//! default error trampoline — "an application cannot call methods which
//! may generate unmanaged GPU operations" (§VII-D).

use crate::cudart::{Symbol, SymbolCategory};

/// How a matched symbol is treated.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum HookClass {
    /// Apply the strategy's kernel-launch template.
    Launch,
    /// Apply the strategy's memory-copy template.
    Memcpy,
    /// Apply the worker strategy's ordered-op template (Alg. 7).
    OrderedOp,
    /// Intercept the undocumented registration channel (kernel registry).
    Register,
    /// Forward unchanged to the hooked library (benign query API).
    Passthrough,
    /// Default: raise `cookErrorUnhookedSymbol` when called.
    Error,
}

/// A single condition: pattern + category filter -> class.
#[derive(Debug, Clone)]
pub struct HookCondition {
    /// Glob-ish pattern over the symbol name: `*` matches any run of
    /// characters (the only metacharacter, as in the paper's config).
    pub pattern: String,
    /// Optional category restriction.
    pub category: Option<SymbolCategory>,
    pub class: HookClass,
}

impl HookCondition {
    pub fn new(pattern: &str, class: HookClass) -> Self {
        Self { pattern: pattern.to_string(), category: None, class }
    }

    pub fn with_category(mut self, cat: SymbolCategory) -> Self {
        self.category = Some(cat);
        self
    }

    pub fn matches(&self, sym: &Symbol) -> bool {
        if let Some(cat) = self.category {
            if sym.category != cat {
                return false;
            }
        }
        glob_match(&self.pattern, &sym.name)
    }
}

/// Minimal `*`-glob matcher (no character classes, like the COOK config).
pub fn glob_match(pattern: &str, name: &str) -> bool {
    fn inner(p: &[u8], n: &[u8]) -> bool {
        match (p.first(), n.first()) {
            (None, None) => true,
            (Some(b'*'), _) => {
                // `*` absorbs zero or more characters.
                inner(&p[1..], n) || (!n.is_empty() && inner(p, &n[1..]))
            }
            (Some(c), Some(d)) if c == d => inner(&p[1..], &n[1..]),
            _ => false,
        }
    }
    inner(pattern.as_bytes(), name.as_bytes())
}

/// An ordered rule set (one per strategy configuration).
#[derive(Debug, Clone, Default)]
pub struct ConditionSet {
    pub rules: Vec<HookCondition>,
}

impl ConditionSet {
    pub fn new(rules: Vec<HookCondition>) -> Self {
        Self { rules }
    }

    /// First-match classification; `Error` when nothing matches.
    pub fn classify(&self, sym: &Symbol) -> HookClass {
        for r in &self.rules {
            if r.matches(sym) {
                return r.class;
            }
        }
        HookClass::Error
    }

    /// Serialise to the on-disk config format (counted in Table II).
    pub fn to_config_text(&self, library: &str, strategy: &str) -> String {
        let mut out = String::new();
        out.push_str("# COOK hook configuration\n");
        out.push_str(&format!("# library: {library}\n"));
        out.push_str(&format!("# strategy: {strategy}\n"));
        out.push_str("# rules are evaluated first-match\n\n");
        for r in &self.rules {
            let cat = r
                .category
                .map(|c| format!(" category={c:?}"))
                .unwrap_or_default();
            out.push_str(&format!(
                "hook pattern={}{} template={:?}\n",
                r.pattern, cat, r.class
            ));
        }
        out.push_str("\ndefault template=Error\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cudart::SymbolTable;

    #[test]
    fn glob_basics() {
        assert!(glob_match("cudaMemcpy", "cudaMemcpy"));
        assert!(!glob_match("cudaMemcpy", "cudaMemcpyAsync"));
        assert!(glob_match("cudaMemcpy*", "cudaMemcpyAsync"));
        assert!(glob_match("*Async", "cudaMemcpyAsync"));
        assert!(glob_match("cuda*cpy*", "cudaMemcpy2DAsync"));
        assert!(glob_match("*", "anything"));
        assert!(!glob_match("", "x"));
        assert!(glob_match("", ""));
    }

    #[test]
    fn first_match_wins() {
        let set = ConditionSet::new(vec![
            HookCondition::new("cudaMemcpyAsync", HookClass::Passthrough),
            HookCondition::new("cudaMemcpy*", HookClass::Memcpy),
        ]);
        let t = SymbolTable::cuda_runtime_11_4();
        assert_eq!(
            set.classify(t.get("cudaMemcpyAsync").unwrap()),
            HookClass::Passthrough
        );
        assert_eq!(set.classify(t.get("cudaMemcpy2D").unwrap()), HookClass::Memcpy);
    }

    #[test]
    fn unmatched_defaults_to_error() {
        let set = ConditionSet::default();
        let t = SymbolTable::cuda_runtime_11_4();
        assert_eq!(set.classify(t.get("cudaMalloc").unwrap()), HookClass::Error);
    }

    #[test]
    fn category_filter_applies() {
        let t = SymbolTable::cuda_runtime_11_4();
        let rule = HookCondition::new("cuda*", HookClass::Launch)
            .with_category(crate::cudart::SymbolCategory::Launch);
        assert!(rule.matches(t.get("cudaLaunchKernel").unwrap()));
        assert!(!rule.matches(t.get("cudaMemcpy").unwrap()));
    }

    #[test]
    fn config_text_contains_rules() {
        let set = ConditionSet::new(vec![HookCondition::new("cudaLaunch*", HookClass::Launch)]);
        let text = set.to_config_text("libcudart.so", "synced");
        assert!(text.contains("pattern=cudaLaunch*"));
        assert!(text.contains("default template=Error"));
    }
}
