//! Lines-of-code counter (the paper measures Table II with `cloc`).
//!
//! Counts code, comment, and blank lines for C-family sources and the
//! COOK config format. Rules follow cloc: a line containing both code and
//! a comment counts as code; block comments may span lines.

/// A LoC breakdown.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LocCount {
    pub code: usize,
    pub comment: usize,
    pub blank: usize,
}

impl LocCount {
    pub fn total(&self) -> usize {
        self.code + self.comment + self.blank
    }

    pub fn add(&mut self, other: LocCount) {
        self.code += other.code;
        self.comment += other.comment;
        self.blank += other.blank;
    }
}

/// Count a C-family source text (`//` and `/* */` comments).
pub fn count_c(text: &str) -> LocCount {
    let mut out = LocCount::default();
    let mut in_block = false;
    for line in text.lines() {
        let trimmed = line.trim();
        if trimmed.is_empty() {
            out.blank += 1;
            continue;
        }
        if in_block {
            // Does the block end here, with code after it?
            if let Some(end) = trimmed.find("*/") {
                in_block = false;
                let rest = trimmed[end + 2..].trim();
                if rest.is_empty() {
                    out.comment += 1;
                } else {
                    // Code after the comment: count as code (cloc rule).
                    out.code += 1;
                    in_block = rest.contains("/*") && !rest[rest.find("/*").unwrap()..].contains("*/");
                }
            } else {
                out.comment += 1;
            }
            continue;
        }
        if let Some(stripped) = trimmed.strip_prefix("//") {
            let _ = stripped;
            out.comment += 1;
            continue;
        }
        if trimmed.starts_with("/*") {
            // Whole-line block comment?
            if let Some(end) = trimmed.find("*/") {
                let rest = trimmed[end + 2..].trim();
                if rest.is_empty() {
                    out.comment += 1;
                } else {
                    out.code += 1;
                }
            } else {
                in_block = true;
                out.comment += 1;
            }
            continue;
        }
        out.code += 1;
        // A code line can open a block comment that continues.
        if let Some(start) = trimmed.find("/*") {
            if !trimmed[start..].contains("*/") {
                in_block = true;
            }
        }
    }
    out
}

/// Count a COOK config text (`#` comments).
pub fn count_config(text: &str) -> LocCount {
    let mut out = LocCount::default();
    for line in text.lines() {
        let trimmed = line.trim();
        if trimmed.is_empty() {
            out.blank += 1;
        } else if trimmed.starts_with('#') {
            out.comment += 1;
        } else {
            out.code += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_simple_c() {
        let src = "int main(void) {\n    return 0; // done\n}\n\n// trailing\n";
        let c = count_c(src);
        assert_eq!(c.code, 3);
        assert_eq!(c.comment, 1);
        assert_eq!(c.blank, 1);
    }

    #[test]
    fn block_comments_span_lines() {
        let src = "/*\n * header\n */\nint x;\n/* inline */ int y;\n";
        let c = count_c(src);
        assert_eq!(c.comment, 3);
        assert_eq!(c.code, 2);
    }

    #[test]
    fn code_opening_block_comment() {
        let src = "int x; /* starts\ncontinues\n*/\nint y;\n";
        let c = count_c(src);
        assert_eq!(c.code, 2); // int x line, int y line
        assert_eq!(c.comment, 2); // continues + closing line
    }

    #[test]
    fn config_counting() {
        let src = "# comment\n\nhook pattern=x template=Launch\n";
        let c = count_config(src);
        assert_eq!(c, LocCount { code: 1, comment: 1, blank: 1 });
        assert_eq!(c.total(), 3);
    }

    #[test]
    fn empty_text() {
        assert_eq!(count_c(""), LocCount::default());
        assert_eq!(count_config(""), LocCount::default());
    }

    #[test]
    fn add_accumulates() {
        let mut a = LocCount { code: 1, comment: 2, blank: 3 };
        a.add(LocCount { code: 10, comment: 20, blank: 30 });
        assert_eq!(a, LocCount { code: 11, comment: 22, blank: 33 });
    }
}
