//! The COOK toolchain: configurable generation of C hooks (§V-A).
//!
//! Pipeline (Figure 4): extract symbols from the hooked library
//! ([`crate::cudart::SymbolTable`]) -> find declarations -> match hook
//! [`condition`]s -> expand [`template`]s -> gather the generated library
//! ([`generate::HookLibrary`]). [`loc`] measures the artefacts (Table II).

pub mod condition;
pub mod generate;
pub mod loc;
pub mod template;
mod templates_c;

pub use condition::{ConditionSet, HookClass, HookCondition};
pub use generate::{generate_standard, standard_conditions, GeneratedFile, HookLibrary};
pub use loc::{count_c, count_config, LocCount};

/// Table II row: LoC required and generated for one strategy.
#[derive(Debug, Clone, Copy)]
pub struct LocReport {
    pub configuration: usize,
    pub templates: usize,
    pub generated: usize,
}

/// Measure the Table II row for a strategy.
pub fn loc_report(strategy: crate::config::StrategyKind) -> LocReport {
    let lib = generate_standard(strategy);
    let configuration = count_config(lib.config_text()).code;
    let templates: usize = lib
        .template_texts()
        .iter()
        .map(|t| count_c(t).code)
        .sum();
    let generated = count_c(&lib.generated_code()).code;
    LocReport { configuration, templates, generated }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StrategyKind;

    #[test]
    fn table2_shape_holds() {
        let cb = loc_report(StrategyKind::Callback);
        let sy = loc_report(StrategyKind::Synced);
        let wk = loc_report(StrategyKind::Worker);
        // Paper Table II: callback 153/151/6804, synced 153/149/6813,
        // worker 171/1056/8383. The shape we must preserve:
        // 1. configs are small and callback == synced size-wise;
        assert!(cb.configuration < 60 && sy.configuration < 60);
        assert_eq!(cb.configuration, sy.configuration);
        // 2. worker config is slightly larger;
        assert!(wk.configuration > cb.configuration);
        // 3. callback/synced templates are small and close; worker's are
        //    several times larger (the deferred-worker runtime);
        assert!(cb.templates.abs_diff(sy.templates) < 30);
        assert!(wk.templates > 3 * cb.templates);
        // 4. generated code is thousands of lines, worker largest.
        assert!(cb.generated > 1_000);
        assert!(sy.generated > 1_000);
        assert!(wk.generated > cb.generated);
        assert!(wk.generated > sy.generated);
        // 5. generation leverage: output dwarfs the maintained inputs.
        assert!(cb.generated > 10 * (cb.configuration + cb.templates));
    }
}
