//! Template expansion: instantiate a hook template with one symbol's
//! declaration ("Generate a hook" step of Figure 4).

use super::condition::HookClass;
use super::templates_c as c;
use crate::config::StrategyKind;
use crate::cudart::Symbol;

/// Expand `{PLACEHOLDER}`s of a template for one symbol.
pub fn expand(template: &str, sym: &Symbol) -> String {
    let params = if sym.params.is_empty() {
        "void".to_string()
    } else {
        sym.params
            .iter()
            .map(|(t, n)| format!("{t} {n}"))
            .collect::<Vec<_>>()
            .join(", ")
    };
    let args = sym.arg_names().join(", ");
    template
        .replace("{RET}", &sym.ret)
        .replace("{NAME}", &sym.name)
        .replace("{PARAMS}", &params)
        .replace("{ARGS}", &args)
        .replace("{NPARAMS}", &sym.params.len().to_string())
}

/// The template text for a (strategy, class) pair.
///
/// `None` means the class is not hooked under this strategy and falls back
/// to a plain trampoline (e.g. ordered-op hooks exist only under worker).
pub fn template_for(strategy: StrategyKind, class: HookClass) -> Option<&'static str> {
    use HookClass::*;
    match (strategy, class) {
        (_, Passthrough) => Some(c::TRAMPOLINE),
        (_, Error) => Some(c::ERROR_TRAMPOLINE),
        (StrategyKind::None | StrategyKind::Ptb, Launch | Memcpy | OrderedOp | Register) => {
            Some(c::TRAMPOLINE)
        }
        (StrategyKind::Callback, Launch | Memcpy) => Some(c::CALLBACK_HOOK),
        (StrategyKind::Callback, OrderedOp | Register) => Some(c::TRAMPOLINE),
        (StrategyKind::Synced, Launch | Memcpy) => Some(c::SYNCED_HOOK),
        (StrategyKind::Synced, OrderedOp | Register) => Some(c::TRAMPOLINE),
        (StrategyKind::Worker, Launch) => Some(c::WORKER_LAUNCH_HOOK),
        (StrategyKind::Worker, Memcpy) => Some(c::WORKER_COPY_HOOK),
        (StrategyKind::Worker, OrderedOp) => Some(c::WORKER_ORDERED_HOOK),
        (StrategyKind::Worker, Register) => Some(c::REGISTER_HOOK),
    }
}

/// Strategy-level support code bundled into the generated library
/// ("Templates" column of Table II, beyond the per-symbol ones).
pub fn strategy_preamble(strategy: StrategyKind) -> Vec<(&'static str, &'static str)> {
    match strategy {
        StrategyKind::None | StrategyKind::Ptb => vec![],
        StrategyKind::Callback => vec![("cook_callback.c", c::CALLBACK_PREAMBLE)],
        StrategyKind::Synced => vec![("cook_synced.c", c::SYNCED_PREAMBLE)],
        StrategyKind::Worker => vec![("cook_worker.c", c::WORKER_RUNTIME)],
    }
}

/// All template texts for a strategy (the "Templates" LoC of Table II):
/// per-class templates + preamble + the common trampolines.
pub fn all_templates(strategy: StrategyKind) -> Vec<&'static str> {
    let mut v = vec![c::TRAMPOLINE, c::ERROR_TRAMPOLINE, c::UNKNOWN_TRAMPOLINE];
    for class in [
        HookClass::Launch,
        HookClass::Memcpy,
        HookClass::OrderedOp,
        HookClass::Register,
    ] {
        if let Some(t) = template_for(strategy, class) {
            if !v.contains(&t) {
                v.push(t);
            }
        }
    }
    for (_, text) in strategy_preamble(strategy) {
        v.push(text);
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cudart::SymbolTable;

    fn table() -> SymbolTable {
        SymbolTable::cuda_runtime_11_4()
    }

    #[test]
    fn expand_fills_all_placeholders() {
        let t = table();
        let sym = t.get("cudaMemcpy").unwrap();
        let out = expand(c_template(), sym);
        assert!(out.contains("cudaError_t cudaMemcpy(void* dst, const void* src, size_t count, enum cudaMemcpyKind kind)"));
        assert!(out.contains("real(dst, src, count, kind)"));
        for ph in ["{RET}", "{NAME}", "{PARAMS}", "{ARGS}", "{NPARAMS}"] {
            assert!(!out.contains(ph), "unexpanded {ph} in:\n{out}");
        }
    }

    fn c_template() -> &'static str {
        super::c::TRAMPOLINE
    }

    #[test]
    fn expand_void_params() {
        let t = table();
        let sym = t.get("cudaDeviceSynchronize").unwrap();
        let out = expand(c_template(), sym);
        assert!(out.contains("cudaDeviceSynchronize(void)"));
        assert!(out.contains("real()"));
    }

    #[test]
    fn synced_hooks_launch_and_copy() {
        let t = template_for(StrategyKind::Synced, HookClass::Launch).unwrap();
        assert!(t.contains("cook_acquire"));
        assert!(t.contains("cook_sync_device"));
        let t2 = template_for(StrategyKind::Synced, HookClass::Memcpy).unwrap();
        assert_eq!(t, t2, "paper: same code template for kernel and copy");
    }

    #[test]
    fn worker_has_distinct_ordered_template() {
        let t = template_for(StrategyKind::Worker, HookClass::OrderedOp).unwrap();
        assert!(t.contains("cook_worker_drain"));
    }

    #[test]
    fn none_strategy_only_trampolines() {
        let t = template_for(StrategyKind::None, HookClass::Launch).unwrap();
        assert!(t.contains("real({ARGS})"));
        assert!(!t.contains("cook_acquire"));
    }

    #[test]
    fn worker_templates_are_largest() {
        let loc = |s: StrategyKind| -> usize {
            all_templates(s).iter().map(|t| t.lines().count()).sum()
        };
        let (cb, sy, wk) = (
            loc(StrategyKind::Callback),
            loc(StrategyKind::Synced),
            loc(StrategyKind::Worker),
        );
        assert!(wk > 3 * cb, "Table II shape: worker templates dominate ({wk} vs {cb})");
        assert!(wk > 3 * sy);
    }
}
