//! Hook-library generation: the COOK toolchain of Figure 4.
//!
//! extract symbols -> find declarations -> match conditions -> expand
//! templates -> gather into a compilable C source tree. The output is the
//! artefact Table II measures; the in-memory classification table is what
//! the simulator's routine dispatch uses.

use super::condition::{ConditionSet, HookClass, HookCondition};
use super::template::{all_templates, expand, strategy_preamble, template_for};
use super::templates_c as c;
use crate::config::StrategyKind;
use crate::cudart::{Symbol, SymbolCategory, SymbolTable};
use std::collections::BTreeMap;

/// One generated source file.
#[derive(Debug, Clone)]
pub struct GeneratedFile {
    pub name: String,
    pub contents: String,
}

/// The generated hook library for one (library, strategy) pair.
#[derive(Debug)]
pub struct HookLibrary {
    pub strategy: StrategyKind,
    pub library: String,
    /// Per-symbol classification (the simulator's dispatch table).
    pub bindings: BTreeMap<String, HookClass>,
    /// The emitted source tree (config + headers + C files).
    pub files: Vec<GeneratedFile>,
    /// Symbols with no declaration (the paper's *unknown* symbols).
    pub unknown_symbols: Vec<String>,
}

/// The paper's standard configuration for a strategy (§VII-D): hook the
/// kernel-execution and copy routines; the worker strategy additionally
/// hooks synchronisation-related methods (ordered ops, Alg. 7) and the
/// undocumented registration channel; benign query/management API passes
/// through; everything else errors.
pub fn standard_conditions(strategy: StrategyKind) -> ConditionSet {
    use HookClass::*;
    use SymbolCategory as Cat;
    let mut rules = vec![
        HookCondition::new("cudaLaunchKernel*", Launch),
        HookCondition::new("cudaLaunchCooperativeKernel*", Launch),
        HookCondition::new("cudaGraphLaunch*", Launch),
        HookCondition::new("cudaMemcpy*", Memcpy),
        HookCondition::new("cudaMemset*", Memcpy),
    ];
    if strategy == StrategyKind::Worker {
        // Ordered ops: everything that creates or depends on sync points.
        rules.push(HookCondition::new("*", OrderedOp).with_category(Cat::Sync));
        rules.push(HookCondition::new("*", OrderedOp).with_category(Cat::Event));
        rules.push(HookCondition::new("*", OrderedOp).with_category(Cat::HostFunc));
        rules.push(HookCondition::new("__cudaRegister*", Register));
        rules.push(HookCondition::new("*", Register).with_category(Cat::Internal));
    }
    // Benign management/query API: explicitly ignored (trampoline).
    for cat in [
        Cat::Device,
        Cat::Memory,
        Cat::Stream,
        Cat::Event,
        Cat::Sync,
        Cat::HostFunc,
        Cat::Occupancy,
        Cat::Misc,
        Cat::Internal,
    ] {
        rules.push(HookCondition::new("*", Passthrough).with_category(cat));
    }
    ConditionSet::new(rules)
}

impl HookLibrary {
    /// Run the full generation workflow of Figure 4.
    pub fn generate(
        table: &SymbolTable,
        strategy: StrategyKind,
        conditions: &ConditionSet,
    ) -> Self {
        let mut bindings = BTreeMap::new();
        let mut hooks_c = String::new();
        let mut tramps_c = String::new();
        let mut unknown_symbols = Vec::new();

        hooks_c.push_str("/* cook_hooks.c — generated: strategy hooks. */\n");
        hooks_c.push_str("#include \"cook_common.h\"\n\n");
        tramps_c.push_str("/* cook_trampolines.c — generated: forwarding + error stubs. */\n");
        tramps_c.push_str("#include \"cook_common.h\"\n\n");

        for sym in &table.symbols {
            // "Find symbol declaration": unknown symbols can only get the
            // abort stub — their signatures are not recoverable (§VII-D).
            if !sym.has_declaration {
                unknown_symbols.push(sym.name.clone());
                tramps_c.push_str(&expand(c::UNKNOWN_TRAMPOLINE, sym));
                tramps_c.push('\n');
                bindings.insert(sym.name.clone(), HookClass::Error);
                continue;
            }
            let class = conditions.classify(sym);
            bindings.insert(sym.name.clone(), class);
            let template = template_for(strategy, class)
                .unwrap_or(c::ERROR_TRAMPOLINE);
            let code = expand(template, sym);
            match class {
                HookClass::Launch
                | HookClass::Memcpy
                | HookClass::OrderedOp
                | HookClass::Register
                    if is_real_hook(strategy, class) =>
                {
                    hooks_c.push_str(&code);
                    hooks_c.push('\n');
                }
                _ => {
                    tramps_c.push_str(&code);
                    tramps_c.push('\n');
                }
            }
        }

        let mut files = vec![
            GeneratedFile {
                name: "config.cook".into(),
                contents: conditions.to_config_text(&table.library, strategy.name()),
            },
            GeneratedFile { name: "cook_common.h".into(), contents: c::COMMON_HEADER.into() },
            GeneratedFile { name: "cook_common.c".into(), contents: c::COMMON_IMPL.into() },
        ];
        for (name, text) in strategy_preamble(strategy) {
            files.push(GeneratedFile { name: name.into(), contents: text.into() });
        }
        files.push(GeneratedFile { name: "cook_hooks.c".into(), contents: hooks_c });
        files.push(GeneratedFile { name: "cook_trampolines.c".into(), contents: tramps_c });

        Self {
            strategy,
            library: table.library.clone(),
            bindings,
            files,
            unknown_symbols,
        }
    }

    /// Symbols that got a strategy hook (not a trampoline/stub) — the
    /// "<70 methods intercepted" count of §VII-D.
    pub fn hooked_symbols(&self) -> Vec<&str> {
        self.bindings
            .iter()
            .filter(|(_, c)| is_real_hook(self.strategy, **c))
            .map(|(n, _)| n.as_str())
            .collect()
    }

    /// All generated code, concatenated (for the "Generated code" LoC).
    pub fn generated_code(&self) -> String {
        let mut out = String::new();
        for f in &self.files {
            if f.name != "config.cook" {
                out.push_str(&f.contents);
                out.push('\n');
            }
        }
        out
    }

    /// The configuration text (for the "Configuration" LoC).
    pub fn config_text(&self) -> &str {
        &self.files[0].contents
    }

    /// All template texts for this strategy (the "Templates" LoC).
    pub fn template_texts(&self) -> Vec<&'static str> {
        all_templates(self.strategy)
    }

    /// Write the source tree under `dir` (used by the hookgen CLI).
    pub fn write_to(&self, dir: &std::path::Path) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        for f in &self.files {
            std::fs::write(dir.join(&f.name), &f.contents)?;
        }
        Ok(())
    }
}

/// Does (strategy, class) expand to an actual behavioural hook (vs a
/// forwarding trampoline)?
fn is_real_hook(strategy: StrategyKind, class: HookClass) -> bool {
    match strategy {
        StrategyKind::None | StrategyKind::Ptb => false,
        StrategyKind::Callback | StrategyKind::Synced => {
            matches!(class, HookClass::Launch | HookClass::Memcpy)
        }
        StrategyKind::Worker => matches!(
            class,
            HookClass::Launch | HookClass::Memcpy | HookClass::OrderedOp | HookClass::Register
        ),
    }
}

/// Convenience: generate with the standard conditions.
pub fn generate_standard(strategy: StrategyKind) -> HookLibrary {
    let table = SymbolTable::cuda_runtime_11_4();
    let conditions = standard_conditions(strategy);
    HookLibrary::generate(&table, strategy, &conditions)
}

#[allow(dead_code)]
fn _assert_symbol_unused(_: &Symbol) {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn covers_every_exported_symbol() {
        for s in StrategyKind::PAPER_SET {
            let lib = generate_standard(s);
            assert_eq!(
                lib.bindings.len(),
                385,
                "in-place replacement must export all symbols (Aspect 1)"
            );
        }
    }

    #[test]
    fn hooked_count_below_seventy() {
        for s in [StrategyKind::Callback, StrategyKind::Synced, StrategyKind::Worker] {
            let lib = generate_standard(s);
            let n = lib.hooked_symbols().len();
            assert!(
                n > 10 && n < 70,
                "§VII-D: strategies intercept <70 methods (got {n} for {s})"
            );
        }
    }

    #[test]
    fn worker_hooks_more_than_synced() {
        let w = generate_standard(StrategyKind::Worker).hooked_symbols().len();
        let s = generate_standard(StrategyKind::Synced).hooked_symbols().len();
        assert!(w > s, "worker adds ordered-op + registration hooks ({w} vs {s})");
    }

    #[test]
    fn unknown_symbols_get_abort_stubs() {
        let lib = generate_standard(StrategyKind::Synced);
        assert!(!lib.unknown_symbols.is_empty());
        assert!(lib.unknown_symbols.iter().any(|n| n.ends_with("_ptsz")));
        let code = lib.generated_code();
        assert!(code.contains("call to unknown symbol cudaLaunchKernel_ptsz"));
    }

    #[test]
    fn launch_and_memcpy_are_hooked() {
        for s in [StrategyKind::Callback, StrategyKind::Synced, StrategyKind::Worker] {
            let lib = generate_standard(s);
            let hooked = lib.hooked_symbols();
            assert!(hooked.contains(&"cudaLaunchKernel"), "{s}");
            assert!(hooked.contains(&"cudaMemcpy"), "{s}");
            assert!(hooked.contains(&"cudaMemcpyAsync"), "{s}");
        }
    }

    #[test]
    fn worker_hooks_sync_and_registration() {
        let lib = generate_standard(StrategyKind::Worker);
        assert_eq!(lib.bindings["cudaDeviceSynchronize"], HookClass::OrderedOp);
        assert_eq!(lib.bindings["cudaEventRecord"], HookClass::OrderedOp);
        assert_eq!(lib.bindings["__cudaRegisterFunction"], HookClass::Register);
        // ... while synced passes them through.
        let lib = generate_standard(StrategyKind::Synced);
        assert_eq!(lib.bindings["cudaDeviceSynchronize"], HookClass::Passthrough);
    }

    #[test]
    fn generated_code_compilable_shape() {
        let lib = generate_standard(StrategyKind::Synced);
        let code = lib.generated_code();
        // Balanced braces is a cheap structural sanity check.
        let open = code.matches('{').count();
        let close = code.matches('}').count();
        assert_eq!(open, close, "unbalanced braces in generated C");
        assert!(code.contains("cudaError_t cudaLaunchKernel("));
    }

    #[test]
    fn graph_api_errors_out_by_default() {
        let lib = generate_standard(StrategyKind::Synced);
        assert_eq!(lib.bindings["cudaGraphCreate"], HookClass::Error);
    }

    #[test]
    fn write_to_disk_roundtrip() {
        let lib = generate_standard(StrategyKind::Worker);
        let dir = std::env::temp_dir().join(format!("cook_hookgen_{}", std::process::id()));
        lib.write_to(&dir).unwrap();
        let hooks = std::fs::read_to_string(dir.join("cook_hooks.c")).unwrap();
        assert!(hooks.contains("worker hook"));
        assert!(dir.join("cook_worker.c").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
