//! Experiment specifications (§VI-D): `bench-isol-strategy` configuration
//! naming, e.g. `cuda_mmult-parallel-synced`.

use crate::apps::{dna, mmult, Program};
use crate::config::{SimConfig, StrategyKind};
use std::fmt;
use std::str::FromStr;

/// Which benchmark application.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Bench {
    CudaMmult,
    OnnxDna,
}

impl Bench {
    pub fn name(&self) -> &'static str {
        match self {
            Bench::CudaMmult => "cuda_mmult",
            Bench::OnnxDna => "onnx_dna",
        }
    }

    pub fn program(&self) -> Program {
        match self {
            Bench::CudaMmult => mmult::program(),
            Bench::OnnxDna => dna::program(),
        }
    }

    /// Measurement protocol (§VI-C): mmult is a single run; dna samples a
    /// 60 s window after 30 s warm-up. Scaled-down defaults keep the whole
    /// evaluation tractable; the full protocol is available via
    /// `RunProtocol::paper_scale`.
    pub fn protocol(&self) -> RunProtocol {
        match self {
            Bench::CudaMmult => RunProtocol { warmup_ns: 0, window_ns: 2_000_000_000 },
            Bench::OnnxDna => RunProtocol {
                warmup_ns: 1_000_000_000,  // paper: 30 s
                window_ns: 4_000_000_000,  // paper: 60 s
            },
        }
    }
}

/// Isolation vs parallel (2 mirrored instances, §VI-D).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Isol {
    Isolation,
    Parallel,
}

impl Isol {
    pub fn name(&self) -> &'static str {
        match self {
            Isol::Isolation => "isolation",
            Isol::Parallel => "parallel",
        }
    }

    pub fn instances(&self) -> usize {
        match self {
            Isol::Isolation => 1,
            Isol::Parallel => 2,
        }
    }
}

/// Warm-up + measurement window.
#[derive(Debug, Clone, Copy)]
pub struct RunProtocol {
    pub warmup_ns: u64,
    pub window_ns: u64,
}

impl RunProtocol {
    /// The paper's full protocol (30 s warm-up, 60 s window).
    pub fn paper_scale() -> Self {
        Self { warmup_ns: 30_000_000_000, window_ns: 60_000_000_000 }
    }
}

/// A full experiment configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ExperimentSpec {
    pub bench: Bench,
    pub isol: Isol,
    pub strategy: StrategyKind,
}

impl ExperimentSpec {
    pub fn new(bench: Bench, isol: Isol, strategy: StrategyKind) -> Self {
        Self { bench, isol, strategy }
    }

    /// The 16 configurations of Figures 9/10 + Table I (2 benches x 2
    /// isolation modes x 4 strategies).
    pub fn paper_grid() -> Vec<ExperimentSpec> {
        let mut v = Vec::new();
        for bench in [Bench::CudaMmult, Bench::OnnxDna] {
            for isol in [Isol::Isolation, Isol::Parallel] {
                for strategy in StrategyKind::PAPER_SET {
                    v.push(Self::new(bench, isol, strategy));
                }
            }
        }
        v
    }

    pub fn programs(&self) -> Vec<Program> {
        (0..self.isol.instances()).map(|_| self.bench.program()).collect()
    }

    pub fn sim_config(&self, seed: u64) -> SimConfig {
        let protocol = self.bench.protocol();
        SimConfig::default()
            .with_strategy(self.strategy)
            .with_seed(seed)
            .with_horizon_ns(protocol.warmup_ns + protocol.window_ns)
    }
}

impl fmt::Display for ExperimentSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}-{}-{}", self.bench.name(), self.isol.name(), self.strategy)
    }
}

impl FromStr for ExperimentSpec {
    type Err = String;

    /// Parse `bench-isol-strategy` (strategy may itself not contain '-').
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let (rest, strategy) = s
            .rsplit_once('-')
            .ok_or_else(|| format!("bad spec '{s}': expected bench-isol-strategy"))?;
        let (bench, isol) = rest
            .rsplit_once('-')
            .ok_or_else(|| format!("bad spec '{s}': expected bench-isol-strategy"))?;
        let bench = match bench {
            "cuda_mmult" => Bench::CudaMmult,
            "onnx_dna" => Bench::OnnxDna,
            other => return Err(format!("unknown bench '{other}'")),
        };
        let isol = match isol {
            "isolation" => Isol::Isolation,
            "parallel" => Isol::Parallel,
            other => return Err(format!("unknown isolation mode '{other}'")),
        };
        let strategy: StrategyKind = strategy.parse()?;
        Ok(Self { bench, isol, strategy })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_parse_roundtrip() {
        for spec in ExperimentSpec::paper_grid() {
            let name = spec.to_string();
            let back: ExperimentSpec = name.parse().unwrap();
            assert_eq!(back, spec, "{name}");
        }
    }

    #[test]
    fn paper_grid_is_sixteen() {
        assert_eq!(ExperimentSpec::paper_grid().len(), 16);
    }

    #[test]
    fn example_from_paper() {
        let s: ExperimentSpec = "cuda_mmult-parallel-synced".parse().unwrap();
        assert_eq!(s.bench, Bench::CudaMmult);
        assert_eq!(s.isol, Isol::Parallel);
        assert_eq!(s.strategy, StrategyKind::Synced);
        assert_eq!(s.programs().len(), 2);
    }

    #[test]
    fn bad_specs_rejected() {
        assert!("nope".parse::<ExperimentSpec>().is_err());
        assert!("cuda_mmult-sideways-none".parse::<ExperimentSpec>().is_err());
        assert!("mystery-parallel-none".parse::<ExperimentSpec>().is_err());
        assert!("cuda_mmult-parallel-mps".parse::<ExperimentSpec>().is_err());
    }

    #[test]
    fn horizon_covers_protocol() {
        let s: ExperimentSpec = "onnx_dna-isolation-none".parse().unwrap();
        let cfg = s.sim_config(0);
        let p = s.bench.protocol();
        assert_eq!(cfg.horizon_ns, p.warmup_ns + p.window_ns);
    }
}
