//! Experiment harness: configuration specs (§VI-D), the runner, and the
//! figure/table emitters that regenerate the paper's evaluation.

pub mod figures;
pub mod parallel;
pub mod runner;
pub mod serving;
pub mod spec;

pub use parallel::{max_threads, parallel_map, parallel_map_with, sim_threads};
pub use runner::{result_from_sim, run_spec, run_spec_pooled, RunResult};
pub use serving::{fleet_sweep, load_sweep, serve_sweep};
pub use spec::{Bench, ExperimentSpec, Isol, RunProtocol};
