//! Serving sweep: the live-path analogue of the simulator's ablation
//! benches. Runs one [`ServeSpec`] under every access-control strategy
//! and tabulates throughput, latency quantiles, and gate occupancy —
//! the serving counterpart of Table I's IPS comparison.

use crate::config::StrategyKind;
use crate::control::serving::{serve, ServeBackend, ServeReport, ServeSpec};
use anyhow::Result;
use std::fmt::Write as _;

/// Run `base` under every strategy against `backend`; returns the
/// rendered table and the per-strategy reports (in `StrategyKind::ALL`
/// order).
///
/// Deliberately sequential, unlike the simulator sweeps fanned out by
/// `harness::parallel::parallel_map`: each serving run spawns real
/// client/worker threads and *measures wall-clock* IPS and latency, so
/// running strategies concurrently would contend for cores and corrupt
/// the numbers the sweep exists to report. Virtual-time `Sim` runs have
/// no such coupling; live wall-clock runs do.
pub fn serve_sweep(
    base: &ServeSpec,
    backend: &dyn ServeBackend,
) -> Result<(String, Vec<ServeReport>)> {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "== serve sweep: {} clients x {} requests (batch {}), payloads [{}] ==",
        base.clients,
        base.requests,
        base.batch,
        base.payloads.join(", ")
    );
    let _ = writeln!(
        out,
        "{:<10} {:>8} {:>9} {:>9} {:>9} {:>12} {:>12}",
        "strategy", "IPS", "p50 ms", "p95 ms", "max ms", "gate-w p95", "gate-h p95"
    );
    let mut reports = Vec::new();
    for strategy in StrategyKind::ALL {
        let mut spec = base.clone();
        spec.strategy = strategy;
        let r = serve(&spec, backend)?;
        let (gw, gh) = match &r.gate {
            Some(g) => (
                format!("{:.2}", g.wait.quantile_ns(0.95) as f64 / 1e6),
                format!("{:.2}", g.hold.quantile_ns(0.95) as f64 / 1e6),
            ),
            None => ("-".to_string(), "-".to_string()),
        };
        let _ = writeln!(
            out,
            "{:<10} {:>8.1} {:>9.2} {:>9.2} {:>9.2} {:>12} {:>12}",
            strategy.name(),
            r.ips(),
            r.latency_p(0.50),
            r.latency_p(0.95),
            r.latencies_ms.last().copied().unwrap_or(0.0),
            gw,
            gh,
        );
        reports.push(r);
    }
    Ok((out, reports))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::serving::SyntheticBackend;

    #[test]
    fn sweep_covers_all_strategies() {
        let base = ServeSpec::new(StrategyKind::None, "dna")
            .with_clients(2)
            .with_requests(3);
        let (text, reports) = serve_sweep(&base, &SyntheticBackend::new(30)).unwrap();
        assert_eq!(reports.len(), StrategyKind::ALL.len());
        for (s, r) in StrategyKind::ALL.iter().zip(&reports) {
            assert_eq!(r.strategy, *s);
            assert_eq!(r.total(), 6);
            assert!(text.contains(s.name()), "missing {s} in:\n{text}");
        }
        assert!(text.contains("IPS"));
    }
}
