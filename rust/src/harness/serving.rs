//! Serving sweeps: the live-path analogue of the simulator's ablation
//! benches. [`serve_sweep`] runs one [`ServeSpec`] under every
//! access-control strategy (the serving counterpart of Table I's IPS
//! comparison); [`fleet_sweep`] sweeps the *shard count* instead,
//! tabulating how aggregate throughput and tail latency scale as the
//! same client population spreads over a growing fleet.

use crate::config::StrategyKind;
use crate::control::fleet::{serve_fleet, FleetReport, FleetSpec, Placement};
use crate::control::serving::{serve, ServeBackend, ServeReport, ServeSpec};
use crate::control::traffic::ArrivalProcess;
use anyhow::{anyhow, Result};
use std::fmt::Write as _;

/// Run `base` under every strategy against `backend`; returns the
/// rendered table and the per-strategy reports (in `StrategyKind::ALL`
/// order).
///
/// Deliberately sequential, unlike the simulator sweeps fanned out by
/// `harness::parallel::parallel_map`: each serving run spawns real
/// client/worker threads and *measures wall-clock* IPS and latency, so
/// running strategies concurrently would contend for cores and corrupt
/// the numbers the sweep exists to report. Virtual-time `Sim` runs have
/// no such coupling; live wall-clock runs do.
pub fn serve_sweep(
    base: &ServeSpec,
    backend: &dyn ServeBackend,
) -> Result<(String, Vec<ServeReport>)> {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "== serve sweep: {} clients x {} requests (batch {}), payloads [{}] ==",
        base.clients,
        base.requests,
        base.batch,
        base.payloads.join(", ")
    );
    let _ = writeln!(
        out,
        "{:<10} {:>8} {:>9} {:>9} {:>9} {:>12} {:>12}",
        "strategy", "IPS", "p50 ms", "p95 ms", "max ms", "gate-w p95", "gate-h p95"
    );
    let mut reports = Vec::new();
    for strategy in StrategyKind::ALL {
        let mut spec = base.clone();
        spec.strategy = strategy;
        let r = serve(&spec, backend)?;
        let (gw, gh) = match &r.gate {
            Some(g) => (
                format!("{:.2}", g.wait.quantile_ns(0.95) as f64 / 1e6),
                format!("{:.2}", g.hold.quantile_ns(0.95) as f64 / 1e6),
            ),
            None => ("-".to_string(), "-".to_string()),
        };
        let _ = writeln!(
            out,
            "{:<10} {:>8.1} {:>9.2} {:>9.2} {:>9.2} {:>12} {:>12}",
            strategy.name(),
            r.ips(),
            r.latency_p(0.50),
            r.latency_p(0.95),
            r.latency.max(),
            gw,
            gh,
        );
        reports.push(r);
    }
    Ok((out, reports))
}

/// Run `base` across fleets of every size in `shard_counts` (same
/// placement, same client population) and tabulate aggregate IPS,
/// latency quantiles, and speedup over the 1-shard (or smallest) fleet.
///
/// Sweep points run **sequentially** — each point is itself a concurrent
/// fleet measuring wall-clock throughput, so overlapping points would
/// contend for cores and corrupt the scaling curve. *Within* a point the
/// shards fan out via `parallel_map` (that concurrency is the quantity
/// being measured). DESIGN.md §8 spells out this split.
pub fn fleet_sweep(
    base: &ServeSpec,
    placement: Placement,
    shard_counts: &[usize],
    backend: &dyn ServeBackend,
) -> Result<(String, Vec<FleetReport>)> {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "== fleet sweep ({placement}): {} clients x {} requests (batch {}), strategy {} ==",
        base.clients, base.requests, base.batch, base.strategy
    );
    let _ = writeln!(
        out,
        "{:<7} {:>9} {:>9} {:>9} {:>9} {:>8} {:>8}",
        "shards", "IPS", "p50 ms", "p95 ms", "max ms", "active", "speedup"
    );
    let mut reports = Vec::new();
    let mut base_ips = None;
    for &shards in shard_counts {
        let spec = FleetSpec::new(base.clone(), shards, placement);
        let r = serve_fleet(&spec, backend)?;
        let ips = r.ips();
        let baseline = *base_ips.get_or_insert(ips);
        let _ = writeln!(
            out,
            "{:<7} {:>9.1} {:>9.2} {:>9.2} {:>9.2} {:>8} {:>7.2}x",
            shards,
            ips,
            r.latency_p(0.50),
            r.latency_p(0.95),
            r.latency.max(),
            r.active_shards(),
            ips / baseline.max(1e-9),
        );
        reports.push(r);
    }
    Ok((out, reports))
}

/// Run `base` under open-loop Poisson arrivals at every rate in
/// `rates_hz` and tabulate the latency-vs-offered-load saturation curve:
/// goodput, SLO attainment, shed/timeout counts, and latency quantiles
/// measured from arrival. Queue capacity, shed policy, SLO and seed come
/// from `base.traffic`.
///
/// Sweep points run **sequentially** for the same reason [`serve_sweep`]
/// does: each point measures wall-clock latency with real threads, and a
/// concurrently running sibling would corrupt exactly the knee this
/// sweep exists to locate.
pub fn load_sweep(
    base: &ServeSpec,
    rates_hz: &[f64],
    backend: &dyn ServeBackend,
) -> Result<(String, Vec<ServeReport>)> {
    if rates_hz.is_empty() {
        return Err(anyhow!("load sweep needs at least one rate"));
    }
    let mut out = String::new();
    let _ = writeln!(
        out,
        "== load sweep: {} workers, {} requests total per point, strategy {}, \
         queue cap {}, shed {}, SLO {:.1} ms ==",
        base.clients,
        base.clients * base.requests,
        base.strategy,
        base.traffic.queue_cap,
        base.traffic.shed,
        base.traffic.slo_ms,
    );
    let _ = writeln!(
        out,
        "{:<10} {:>10} {:>8} {:>8} {:>7} {:>8} {:>9} {:>9} {:>9}",
        "offered/s", "goodput/s", "SLO %", "shed", "t/out", "p50 ms", "p95 ms", "p99 ms", "max ms"
    );
    let mut reports = Vec::new();
    for &rate in rates_hz {
        let mut spec = base.clone();
        spec.traffic.arrivals = ArrivalProcess::Poisson { rate_hz: rate };
        let r = serve(&spec, backend)?;
        let t = r.traffic.as_ref().expect("open-loop run must report traffic");
        let _ = writeln!(
            out,
            "{:<10.1} {:>10.1} {:>7.1}% {:>8} {:>7} {:>8.2} {:>9.2} {:>9.2} {:>9.2}",
            rate,
            t.goodput(r.wall_s),
            t.slo_attainment_pct(),
            t.shed,
            t.timed_out,
            r.latency_p(0.50),
            r.latency_p(0.95),
            r.latency_p(0.99),
            r.latency.max(),
        );
        reports.push(r);
    }
    Ok((out, reports))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::serving::SyntheticBackend;

    #[test]
    fn sweep_covers_all_strategies() {
        let base = ServeSpec::new(StrategyKind::None, "dna")
            .with_clients(2)
            .with_requests(3);
        let (text, reports) = serve_sweep(&base, &SyntheticBackend::new(30)).unwrap();
        assert_eq!(reports.len(), StrategyKind::ALL.len());
        for (s, r) in StrategyKind::ALL.iter().zip(&reports) {
            assert_eq!(r.strategy, *s);
            assert_eq!(r.total(), 6);
            assert!(text.contains(s.name()), "missing {s} in:\n{text}");
        }
        assert!(text.contains("IPS"));
    }

    #[test]
    fn load_sweep_tabulates_every_rate() {
        use crate::control::traffic::{ShedPolicy, TrafficSpec};
        let base = ServeSpec::new(StrategyKind::Worker, "dna")
            .with_clients(2)
            .with_requests(5)
            .with_traffic(TrafficSpec {
                arrivals: ArrivalProcess::Poisson { rate_hz: 1.0 }, // overridden per point
                queue_cap: 16,
                shed: ShedPolicy::Reject,
                slo_ms: 100.0,
                seed: 4,
            });
        let (text, reports) =
            load_sweep(&base, &[500.0, 2_000.0], &SyntheticBackend::new(30)).unwrap();
        assert_eq!(reports.len(), 2);
        for r in &reports {
            let t = r.traffic.as_ref().unwrap();
            assert_eq!(t.offered, 10);
            assert!(t.accounted());
        }
        assert!(text.contains("load sweep"), "{text}");
        assert!(text.contains("goodput"), "{text}");
        assert!(text.contains("SLO"), "{text}");
        assert!(load_sweep(&base, &[], &SyntheticBackend::new(30)).is_err());
    }

    #[test]
    fn fleet_sweep_covers_every_shard_count() {
        let base = ServeSpec::new(StrategyKind::Worker, "dna")
            .with_clients(4)
            .with_requests(2);
        let (text, reports) =
            fleet_sweep(&base, Placement::RoundRobin, &[1, 2, 4], &SyntheticBackend::new(30))
                .unwrap();
        assert_eq!(reports.len(), 3);
        for (r, want) in reports.iter().zip([1usize, 2, 4]) {
            assert_eq!(r.shards.len(), want);
            assert_eq!(r.total(), 8);
        }
        assert!(text.contains("fleet sweep"), "{text}");
        assert!(text.contains("speedup"), "{text}");
    }
}
