//! Deterministic fan-out of independent simulator runs across cores.
//!
//! One `Sim` is single-threaded by construction; an *experiment* is many
//! independent (config, seed) runs — figures sweep 6–16 configurations,
//! pooled runs sweep seeds, ablations sweep parameters. [`parallel_map`]
//! fans those runs over a `std::thread::scope` worker pool while keeping
//! the result order identical to the input order, so every consumer
//! (figure emitters, pooled mergers, bench tables) produces bit-identical
//! output whether it runs on 1 core or 64.
//!
//! Determinism guarantee: `f` receives each input exactly once; result
//! slot `i` holds `f(inputs[i])`. Thread scheduling decides only *when*
//! a run executes, never *what* it computes (each `Sim` draws from its
//! own seeded RNG streams) nor *where* its result lands.
//!
//! `COOK_THREADS=n` caps the pool (1 = fully serial), e.g. for timing
//! individual runs or debugging.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Worker-pool size: `COOK_THREADS` override, else available cores.
pub fn max_threads() -> usize {
    env_threads("COOK_THREADS")
}

/// Worker-pool size for *shard-parallel fleet simulation* (the per-shard
/// sub-sims of one `num_gpus > 1` `Sim::run`): `COOK_SIM_THREADS`
/// override, else available cores. A separate knob from `COOK_THREADS`
/// because the two pools nest — an experiment grid fanned out by
/// [`parallel_map`] may itself contain fleet runs, and capping one axis
/// must not cap the other.
pub fn sim_threads() -> usize {
    env_threads("COOK_SIM_THREADS")
}

fn env_threads(var: &str) -> usize {
    if let Ok(v) = std::env::var(var) {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Apply `f` to every input on a scoped worker pool; results come back
/// in input order. Panics in `f` propagate to the caller (the scope
/// joins all workers before returning).
pub fn parallel_map<T, R, F>(inputs: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    parallel_map_with(max_threads(), inputs, f)
}

/// [`parallel_map`] with an explicit pool size instead of the
/// `COOK_THREADS` environment cap. `threads <= 1` runs inline on the
/// caller's thread. The explicit form exists so callers with their own
/// cap (the fleet simulator's `COOK_SIM_THREADS`, tests pinning a thread
/// count without racing on the process environment) share one pool
/// implementation — and one determinism guarantee: result slot `i` holds
/// `f(inputs[i])` at ANY pool size.
pub fn parallel_map_with<T, R, F>(threads: usize, inputs: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = inputs.len();
    let threads = threads.min(n);
    if threads <= 1 {
        return inputs.into_iter().map(f).collect();
    }
    // Per-slot mutexes rather than one global queue lock: a worker takes
    // job i, computes, writes slot i. fetch_add hands out indices in
    // ascending order; ordering of *completion* is irrelevant.
    let jobs: Vec<Mutex<Option<T>>> =
        inputs.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let slots: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let next = AtomicUsize::new(0);
    let f = &f;
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = jobs[i]
                    .lock()
                    .unwrap()
                    .take()
                    .expect("parallel_map job dispatched twice");
                let out = f(item);
                *slots[i].lock().unwrap() = Some(out);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap()
                .expect("parallel_map worker exited without a result")
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StrategyKind;
    use crate::harness::run_spec;
    use crate::harness::spec::{Bench, ExperimentSpec, Isol};

    #[test]
    fn preserves_input_order() {
        let out = parallel_map((0..64).collect(), |i: usize| i * 2);
        assert_eq!(out, (0..64).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_input_is_fine() {
        let out: Vec<usize> = parallel_map(Vec::<usize>::new(), |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn single_item_runs_inline() {
        assert_eq!(parallel_map(vec![41usize], |i| i + 1), vec![42]);
    }

    #[test]
    fn explicit_thread_counts_agree() {
        // The determinism guarantee, parameterised: every pool size
        // yields the same result vector (and 1 runs inline).
        let inputs: Vec<usize> = (0..37).collect();
        let expect: Vec<usize> = inputs.iter().map(|i| i * 3 + 1).collect();
        for threads in [1, 2, 8, 64] {
            let out = parallel_map_with(threads, inputs.clone(), |i| i * 3 + 1);
            assert_eq!(out, expect, "{threads} threads");
        }
    }

    #[test]
    fn parallel_sim_runs_match_sequential() {
        // The determinism guarantee the experiment harness rests on:
        // fanning runs across threads changes nothing about any result.
        let spec = ExperimentSpec::new(Bench::CudaMmult, Isol::Parallel, StrategyKind::None);
        let seeds: Vec<u64> = (0..4).collect();
        let seq: Vec<_> = seeds.iter().map(|&s| run_spec(spec, s)).collect();
        let par = parallel_map(seeds, |s| run_spec(spec, s));
        for (a, b) in seq.iter().zip(&par) {
            assert_eq!(a.seed, b.seed);
            assert_eq!(a.net, b.net, "seed {}", a.seed);
            assert_eq!(a.kernels, b.kernels);
            assert_eq!(a.overlaps, b.overlaps);
            assert_eq!(a.switches, b.switches);
            assert_eq!(a.stalls, b.stalls);
        }
    }

    #[test]
    #[should_panic(expected = "boom")]
    fn worker_panics_propagate() {
        let _ = parallel_map((0..8).collect::<Vec<usize>>(), |i| {
            if i == 5 {
                panic!("boom");
            }
            i
        });
    }
}
