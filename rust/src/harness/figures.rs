//! Figure/table emitters: regenerate every table and figure of the
//! paper's evaluation section (§VII) from simulator runs.
//!
//! Each emitter returns the rendered text (also used by `cargo bench`
//! harnesses) and can persist CSV series for external plotting.

use super::parallel::parallel_map;
use super::runner::{run_spec, RunResult};
use super::spec::{Bench, ExperimentSpec, Isol};
use crate::config::{SimConfig, StrategyKind};
use crate::control::arbiter::parse_classes;
use crate::control::concurrency::ConcurrencyMode;
use crate::control::traffic::ArrivalProcess;
use crate::gpu::Sim;
use crate::hooks::{loc_report, LocReport};
use crate::metrics::ips_with_warmup;
use crate::metrics::stats::quantile_sorted;
use crate::util::AppId;
use std::fmt::Write as _;
use std::path::Path;

/// Figures 9/10: NET distribution per configuration, one row per
/// instance, rendered as boxplot summaries. The 8 configurations are
/// independent sims, so they fan out across cores; rendering follows
/// input order, keeping the emitted text identical at any core count.
pub fn net_figure(bench: Bench, seed: u64) -> (String, Vec<RunResult>) {
    let mut out = String::new();
    let _ = writeln!(
        out,
        "== Normalised Kernel Runtime (NET) distribution: {} ==",
        bench.name()
    );
    let mut specs = Vec::new();
    for isol in [Isol::Isolation, Isol::Parallel] {
        for strategy in StrategyKind::PAPER_SET {
            specs.push(ExperimentSpec::new(bench, isol, strategy));
        }
    }
    let results = parallel_map(specs, |spec| run_spec(spec, seed));
    for r in &results {
        let _ = writeln!(out, "{}", r.spec);
        for inst in 0..r.net.len() {
            match r.net_box(inst) {
                Some(b) => {
                    let _ = writeln!(out, "  inst{}: {}", inst, b.render());
                }
                None => {
                    let _ = writeln!(out, "  inst{}: no kernels measured", inst);
                }
            }
        }
        let _ = writeln!(
            out,
            "  pooled: max={:.1}x  frac>10x={:.4}%  overlaps={}  stalls={}",
            r.max_net(),
            100.0 * r.frac_net_above(10.0),
            r.overlaps,
            r.stalls
        );
    }
    (out, results)
}

/// Figure 11: chronograms of cuda_mmult under the various configurations
/// (isolation/parallel x none, plus the three strategies and PTB).
pub fn chronogram_figure(seed: u64) -> (String, Vec<RunResult>) {
    let mut out = String::new();
    let mut results = Vec::new();
    let configs = [
        ExperimentSpec::new(Bench::CudaMmult, Isol::Isolation, StrategyKind::None),
        ExperimentSpec::new(Bench::CudaMmult, Isol::Parallel, StrategyKind::None),
        ExperimentSpec::new(Bench::CudaMmult, Isol::Parallel, StrategyKind::Callback),
        ExperimentSpec::new(Bench::CudaMmult, Isol::Parallel, StrategyKind::Synced),
        ExperimentSpec::new(Bench::CudaMmult, Isol::Parallel, StrategyKind::Worker),
        ExperimentSpec::new(Bench::CudaMmult, Isol::Parallel, StrategyKind::Ptb),
    ];
    let _ = writeln!(out, "== Chronograms: cuda_mmult (Fig. 11) ==");
    results.extend(parallel_map(configs.to_vec(), |spec| run_spec(spec, seed)));
    for r in &results {
        let _ = writeln!(
            out,
            "{}: total={:.1} Mcycles, cross-instance overlap={}",
            r.spec,
            r.chronogram.total_mcycles(),
            if r.chronogram.has_cross_lane_overlap() { "YES" } else { "no" }
        );
        out.push_str(&r.chronogram.render_ascii(24));
    }
    (out, results)
}

/// Table I: IPS achieved by the onnx_dna benchmark per configuration.
pub fn ips_table(seed: u64) -> (String, Vec<(ExperimentSpec, f64)>) {
    let mut out = String::new();
    let mut cells = Vec::new();
    let _ = writeln!(out, "== Inferences per Second (Table I): onnx_dna ==");
    let _ = writeln!(
        out,
        "{:<12} {:>8} {:>10} {:>8} {:>8}",
        "Config", "none", "callback", "synced", "worker"
    );
    let mut specs = Vec::new();
    for isol in [Isol::Isolation, Isol::Parallel] {
        for strategy in StrategyKind::PAPER_SET {
            specs.push(ExperimentSpec::new(Bench::OnnxDna, isol, strategy));
        }
    }
    let results = parallel_map(specs, |spec| run_spec(spec, seed));
    for (row_idx, isol) in [Isol::Isolation, Isol::Parallel].into_iter().enumerate() {
        let mut row = format!("{:<12}", isol.name());
        for (col, strategy) in StrategyKind::PAPER_SET.into_iter().enumerate() {
            let r = &results[row_idx * StrategyKind::PAPER_SET.len() + col];
            // Paper reports the application IPS; in parallel both
            // instances are mirrored, report the mean.
            let v = r.ips.iter().sum::<f64>() / r.ips.len() as f64;
            let width = if strategy == StrategyKind::Callback { 10 } else { 8 };
            let _ = write!(row, " {:>width$.0}", v, width = width);
            cells.push((r.spec, v));
        }
        let _ = writeln!(out, "{row}");
    }
    (out, cells)
}

/// Table II: LoC required and generated for the different strategies.
pub fn loc_table() -> (String, Vec<(StrategyKind, LocReport)>) {
    let mut out = String::new();
    let mut rows = Vec::new();
    let _ = writeln!(out, "== Lines of Code (Table II) ==");
    let _ = writeln!(
        out,
        "{:<10} {:>14} {:>10} {:>15}",
        "Strategy", "Configuration", "Templates", "Generated code"
    );
    for strategy in [StrategyKind::Callback, StrategyKind::Synced, StrategyKind::Worker] {
        let r = loc_report(strategy);
        let _ = writeln!(
            out,
            "{:<10} {:>14} {:>10} {:>15}",
            strategy.name(),
            r.configuration,
            r.templates,
            r.generated
        );
        rows.push((strategy, r));
    }
    (out, rows)
}

/// One row of the shard-scaling figure.
#[derive(Debug, Clone)]
pub struct ShardScalingRow {
    pub num_gpus: usize,
    /// Sum of the per-app IPS over the measurement window.
    pub aggregate_ips: f64,
    /// Aggregate IPS per shard, indexed by shard.
    pub per_shard_ips: Vec<f64>,
    /// Cross-app kernel overlaps *within* any shard (isolation check —
    /// must stay 0 for an isolating strategy at every fleet size).
    pub within_shard_overlaps: usize,
    /// Aggregate-IPS speedup over the 1-shard fleet.
    pub speedup: f64,
}

/// Shard-scaling section (beyond the paper): the same 4-application
/// onnx_dna workload under the isolating `worker` strategy, simulated on
/// fleets of 1, 2, and 4 GPUs. Shows the tentpole claim end-to-end: the
/// per-GPU serialisation guarantee holds at every size (zero
/// within-shard overlaps) while aggregate IPS scales with the shard
/// count. Fleet sizes are independent sims, so they fan out across
/// cores like the other figures.
pub fn shard_scaling_figure(seed: u64) -> (String, Vec<ShardScalingRow>) {
    const APPS: usize = 4;
    const FLEETS: [usize; 3] = [1, 2, 4];
    let protocol = Bench::OnnxDna.protocol();
    let runs = parallel_map(FLEETS.to_vec(), move |g| {
        let cfg = SimConfig::default()
            .with_strategy(StrategyKind::Worker)
            .with_seed(seed)
            .with_horizon_ns(protocol.warmup_ns + protocol.window_ns)
            .with_num_gpus(g);
        let programs = (0..APPS).map(|_| Bench::OnnxDna.program()).collect();
        let mut sim = Sim::new(cfg, programs);
        sim.run();
        let app_ips: Vec<f64> = (0..APPS)
            .map(|a| {
                ips_with_warmup(
                    sim.completions(AppId(a)),
                    protocol.warmup_ns,
                    protocol.window_ns,
                )
            })
            .collect();
        let per_shard_ips: Vec<f64> = (0..g)
            .map(|s| {
                (0..APPS)
                    .filter(|&a| sim.shard_of(AppId(a)) == s)
                    .map(|a| app_ips[a])
                    .sum()
            })
            .collect();
        ShardScalingRow {
            num_gpus: g,
            aggregate_ips: app_ips.iter().sum(),
            per_shard_ips,
            within_shard_overlaps: sim.within_shard_overlaps().iter().sum(),
            speedup: 1.0, // filled against the 1-shard row below
        }
    });
    let baseline = runs[0].aggregate_ips.max(1e-9);
    let rows: Vec<ShardScalingRow> = runs
        .into_iter()
        .map(|mut r| {
            r.speedup = r.aggregate_ips / baseline;
            r
        })
        .collect();
    let mut out = String::new();
    let _ = writeln!(
        out,
        "== Shard scaling: onnx_dna x {APPS} apps, worker strategy (fleet) =="
    );
    let _ = writeln!(
        out,
        "{:<7} {:>11} {:>9} {:>16} {:>20}",
        "shards", "agg IPS", "speedup", "in-shard ovl", "per-shard IPS"
    );
    for r in &rows {
        let per_shard = r
            .per_shard_ips
            .iter()
            .map(|v| format!("{v:.0}"))
            .collect::<Vec<_>>()
            .join("/");
        let _ = writeln!(
            out,
            "{:<7} {:>11.1} {:>8.2}x {:>16} {:>20}",
            r.num_gpus, r.aggregate_ips, r.speedup, r.within_shard_overlaps, per_shard
        );
    }
    (out, rows)
}

/// One offered-load point of the saturation figure.
#[derive(Debug, Clone)]
pub struct LoadPoint {
    pub rate_hz: f64,
    /// Arrivals generated across all apps.
    pub offered: usize,
    /// Arrivals shed at the bounded per-app backlog.
    pub shed: usize,
    /// Iterations completed (arrival-to-completion latency recorded).
    pub completed: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Completions per second of virtual time.
    pub goodput_ips: f64,
}

/// Latency vs offered load (beyond the paper): the same 2-application
/// onnx_dna workload under the isolating `worker` strategy, driven by
/// open-loop Poisson arrivals swept across rates. Latency is measured
/// from *arrival* to completion, so the curve shows the hockey stick a
/// closed-loop protocol structurally hides: flat near the service time
/// below the knee, then queueing delay (bounded by the admission cap,
/// with the overflow shed) past saturation. Rates are independent sims,
/// so they fan out across cores like the other figures; the live
/// counterpart is `cook serve --arrivals poisson:R --load-sweep ...`
/// (`harness::load_sweep`), which reports the same curve in wall-clock.
pub fn saturation_figure(seed: u64) -> (String, Vec<LoadPoint>) {
    const APPS: usize = 2;
    // onnx_dna serves ~113 IPS per app in isolation (Table I), less when
    // two apps share the GPU: the sweep brackets that capacity from
    // clearly-under to far-past the knee.
    const RATES: [f64; 6] = [50.0, 100.0, 200.0, 400.0, 800.0, 1600.0];
    const HORIZON_NS: u64 = 2_000_000_000;
    const QUEUE_CAP: usize = 64;
    let points = parallel_map(RATES.to_vec(), move |rate| {
        let cfg = SimConfig::default()
            .with_strategy(StrategyKind::Worker)
            .with_seed(seed)
            .with_horizon_ns(HORIZON_NS)
            .with_arrivals(ArrivalProcess::Poisson { rate_hz: rate })
            .with_arrival_queue_cap(QUEUE_CAP);
        let programs = (0..APPS).map(|_| Bench::OnnxDna.program()).collect();
        let mut sim = Sim::new(cfg, programs);
        sim.run();
        let mut lat_ms: Vec<f64> = (0..APPS)
            .flat_map(|a| sim.arrival_latencies(AppId(a)).iter().map(|&ns| ns as f64 / 1e6))
            .collect();
        lat_ms.sort_by(f64::total_cmp);
        let (offered, shed) = (0..APPS)
            .map(|a| sim.arrival_counts(AppId(a)))
            .fold((0, 0), |acc, c| (acc.0 + c.0, acc.1 + c.1));
        let q = |p: f64| if lat_ms.is_empty() { 0.0 } else { quantile_sorted(&lat_ms, p) };
        LoadPoint {
            rate_hz: rate,
            offered,
            shed,
            completed: lat_ms.len(),
            p50_ms: q(0.50),
            p95_ms: q(0.95),
            p99_ms: q(0.99),
            goodput_ips: lat_ms.len() as f64 / (HORIZON_NS as f64 / 1e9),
        }
    });
    let mut out = String::new();
    let _ = writeln!(
        out,
        "== Latency vs offered load: onnx_dna x {APPS} apps, worker strategy, \
         open-loop Poisson (queue cap {QUEUE_CAP}) =="
    );
    let _ = writeln!(
        out,
        "{:<10} {:>9} {:>9} {:>7} {:>11} {:>9} {:>9} {:>9}",
        "offered/s", "offered", "shed", "done", "goodput/s", "p50 ms", "p95 ms", "p99 ms"
    );
    for p in &points {
        let _ = writeln!(
            out,
            "{:<10} {:>9} {:>9} {:>7} {:>11.1} {:>9.2} {:>9.2} {:>9.2}",
            p.rate_hz, p.offered, p.shed, p.completed, p.goodput_ips, p.p50_ms, p.p95_ms,
            p.p99_ms
        );
    }
    (out, points)
}

/// One concurrency-mode point of the isolation figure.
#[derive(Debug, Clone)]
pub struct IsolationRow {
    pub mode: ConcurrencyMode,
    /// Sum of the per-app IPS over the measurement window.
    pub aggregate_ips: f64,
    /// Pooled inter-completion gaps (both apps), ms.
    pub p50_ms: f64,
    pub p99_ms: f64,
    /// Variability: p99/p50 of the pooled gaps. COOK buys a tight
    /// spread by serialising; the sharing modes trade spread for
    /// aggregate throughput — each mode is one point on that frontier.
    pub spread: f64,
    /// Iteration gaps measured (after warmup).
    pub completed: usize,
}

/// All four concurrency modes, in the order the figure tabulates them.
pub const ISOLATION_MODES: [ConcurrencyMode; 4] = [
    ConcurrencyMode::Cook,
    ConcurrencyMode::Mps { quota: 2 },
    ConcurrencyMode::Mig { slices: 2 },
    ConcurrencyMode::Streams,
];

/// Isolation-vs-throughput figure (beyond the paper, DESIGN.md §14): the
/// same 2-application contended onnx_dna workload under each concurrency
/// mode, plotting iteration-time variability (p99/p50 of the pooled
/// inter-completion gaps) against aggregate IPS. `cook` runs the paper's
/// serialised `synced` strategy — predictable but paying lock handoffs
/// and context switches; `mps`/`mig` co-run spatially on split SM banks
/// (`mig` also splits the L2 per tenant class); `streams` time-slices by
/// class priority with kernel-boundary preemption. Two tenant classes
/// (`a`, `b`) map one per app, so `mig`/`streams` exercise their
/// class-routing paths. Modes are independent sims fanned out across
/// cores, deterministic in (mode, seed).
pub fn isolation_figure(seed: u64) -> (String, Vec<IsolationRow>) {
    isolation_figure_for(seed, &ISOLATION_MODES)
}

/// Single-mode (or subset) variant backing `--concurrency` on
/// `cook experiment isolation`.
pub fn isolation_figure_for(
    seed: u64,
    modes: &[ConcurrencyMode],
) -> (String, Vec<IsolationRow>) {
    const APPS: usize = 2;
    let protocol = Bench::OnnxDna.protocol();
    let rows = parallel_map(modes.to_vec(), move |mode| {
        // cook is the paper's serialised access: the synced strategy's
        // gate. The sharing modes are device-level mechanisms and run
        // ungated — the mode itself decides what co-runs.
        let strategy =
            if mode.is_cook() { StrategyKind::Synced } else { StrategyKind::None };
        let cfg = SimConfig::default()
            .with_strategy(strategy)
            .with_seed(seed)
            .with_horizon_ns(protocol.warmup_ns + protocol.window_ns)
            .with_classes(parse_classes("a,b").expect("static class spec"))
            .with_concurrency(mode);
        let programs = (0..APPS).map(|_| Bench::OnnxDna.program()).collect();
        let mut sim = Sim::new(cfg, programs);
        sim.run();
        let aggregate_ips: f64 = (0..APPS)
            .map(|a| {
                ips_with_warmup(
                    sim.completions(AppId(a)),
                    protocol.warmup_ns,
                    protocol.window_ns,
                )
            })
            .sum();
        // Variability input: inter-completion gaps per app (the gap IS
        // the iteration time under a closed loop), pooled across apps.
        let mut gaps_ms: Vec<f64> = Vec::new();
        for a in 0..APPS {
            let cs: Vec<u64> = sim
                .completions(AppId(a))
                .iter()
                .copied()
                .filter(|&t| t >= protocol.warmup_ns)
                .collect();
            gaps_ms.extend(cs.windows(2).map(|w| (w[1] - w[0]) as f64 / 1e6));
        }
        gaps_ms.sort_by(f64::total_cmp);
        let q = |p: f64| if gaps_ms.is_empty() { 0.0 } else { quantile_sorted(&gaps_ms, p) };
        let (p50_ms, p99_ms) = (q(0.50), q(0.99));
        IsolationRow {
            mode,
            aggregate_ips,
            p50_ms,
            p99_ms,
            spread: if p50_ms > 0.0 { p99_ms / p50_ms } else { 0.0 },
            completed: gaps_ms.len(),
        }
    });
    let mut out = String::new();
    let _ = writeln!(
        out,
        "== Isolation vs throughput: onnx_dna x {APPS} apps per concurrency \
         mode (DESIGN.md §14) =="
    );
    let _ = writeln!(
        out,
        "{:<9} {:>11} {:>9} {:>9} {:>9} {:>7}",
        "mode", "agg IPS", "p50 ms", "p99 ms", "p99/p50", "iters"
    );
    for r in &rows {
        let _ = writeln!(
            out,
            "{:<9} {:>11.1} {:>9.2} {:>9.2} {:>8.2}x {:>7}",
            r.mode.to_string(),
            r.aggregate_ips,
            r.p50_ms,
            r.p99_ms,
            r.spread,
            r.completed
        );
    }
    (out, rows)
}

/// One controller window of the autoscale figure.
#[derive(Debug, Clone)]
pub struct AutoscalePoint {
    pub window: usize,
    /// Window start, virtual milliseconds.
    pub start_ms: f64,
    /// Arrivals offered during the window (all apps).
    pub offered: usize,
    /// Active shards the mirrored controller planned for the window.
    pub active_shards: usize,
    /// Iterations completed during the window (all apps).
    pub completed: usize,
}

/// Autoscale section (beyond the paper): a 4-shard fleet of onnx_dna
/// apps under bursty open-loop arrivals with the mirrored elastic
/// controller (`autoscale 1..4`, DESIGN.md §15). One row per controller
/// window shows the active-shard count chasing the burst envelope:
/// scale-up inside the on-phase, drain-then-retire after the hysteresis
/// delay in the off-phase. The live counterpart is
/// `cook serve --autoscale 1..4 --arrivals bursty:...`.
pub fn autoscale_figure(seed: u64) -> (String, Vec<AutoscalePoint>) {
    use crate::gpu::SCALE_WINDOWS;
    const APPS: usize = 4;
    const FLEET: usize = 4;
    const HORIZON_NS: u64 = 2_000_000_000;
    let arrivals = ArrivalProcess::Bursty { rate_hz: 800.0, on_ms: 250, off_ms: 250 };
    let cfg = SimConfig::default()
        .with_strategy(StrategyKind::Worker)
        .with_seed(seed)
        .with_horizon_ns(HORIZON_NS)
        .with_num_gpus(FLEET)
        .with_arrivals(arrivals)
        .with_arrival_queue_cap(64)
        .with_autoscale("1..4".parse().expect("static autoscale spec"));
    let programs = (0..APPS).map(|_| Bench::OnnxDna.program()).collect();
    let mut sim = Sim::new(cfg, programs);
    sim.run();
    // Re-derive the per-window offered counts from the same seeded
    // stream the engine dealt (pure function of (arrivals, seed)), and
    // bucket completions over the identical window grid.
    let w = (HORIZON_NS / SCALE_WINDOWS as u64).max(1);
    let bucket = |t: u64| ((t / w) as usize).min(SCALE_WINDOWS - 1);
    let mut offered = vec![0usize; SCALE_WINDOWS];
    for t in arrivals.schedule_until(HORIZON_NS, seed) {
        offered[bucket(t)] += 1;
    }
    let mut completed = vec![0usize; SCALE_WINDOWS];
    for a in 0..APPS {
        for &t in sim.completions(AppId(a)) {
            completed[bucket(t)] += 1;
        }
    }
    let timeline = sim.scale_timeline();
    let points: Vec<AutoscalePoint> = (0..SCALE_WINDOWS)
        .map(|i| AutoscalePoint {
            window: i,
            start_ms: (i as u64 * w) as f64 / 1e6,
            offered: offered[i],
            active_shards: timeline.get(i).map_or(1, |&(_, a)| a),
            completed: completed[i],
        })
        .collect();
    let mut out = String::new();
    let _ = writeln!(
        out,
        "== Elastic autoscale: onnx_dna x {APPS} apps, worker strategy, \
         bursty arrivals, autoscale 1..{FLEET} =="
    );
    let _ = writeln!(
        out,
        "{:<7} {:>9} {:>9} {:>9} {:>7}  {}",
        "window", "start ms", "offered", "done", "shards", "active"
    );
    for p in &points {
        let _ = writeln!(
            out,
            "{:<7} {:>9.0} {:>9} {:>9} {:>7}  {}",
            p.window,
            p.start_ms,
            p.offered,
            p.completed,
            p.active_shards,
            "#".repeat(p.active_shards)
        );
    }
    (out, points)
}

/// Persist a figure's CSV series under `dir`.
pub fn write_net_csv(dir: &Path, bench: Bench, results: &[RunResult]) -> std::io::Result<()> {
    std::fs::create_dir_all(dir)?;
    for r in results {
        let mut csv = String::from("instance,net\n");
        for (inst, vals) in r.net.iter().enumerate() {
            for v in vals {
                let _ = writeln!(csv, "{inst},{v}");
            }
        }
        std::fs::write(dir.join(format!("net-{}.csv", r.spec)), csv)?;
    }
    std::fs::write(dir.join(format!("net-{}-README", bench.name())), "NET samples per config\n")?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loc_table_renders_three_rows() {
        let (text, rows) = loc_table();
        assert_eq!(rows.len(), 3);
        assert!(text.contains("callback"));
        assert!(text.contains("worker"));
        // Worker generated code must be the largest (Table II shape).
        let worker = rows.iter().find(|(s, _)| *s == StrategyKind::Worker).unwrap().1;
        let synced = rows.iter().find(|(s, _)| *s == StrategyKind::Synced).unwrap().1;
        assert!(worker.generated > synced.generated);
    }

    #[test]
    fn ips_table_shape() {
        // Smoke: seed-0 run of all 8 dna configs (the full protocol runs
        // in the bench harness; this checks wiring only).
        let (text, cells) = ips_table(0);
        assert_eq!(cells.len(), 8);
        assert!(text.contains("isolation"));
        assert!(text.contains("parallel"));
        let iso_none = cells[0].1;
        let par_none = cells[4].1;
        assert!(iso_none > par_none, "parallel must be slower");
    }

    #[test]
    fn saturation_figure_shows_the_knee() {
        let (text, points) = saturation_figure(0);
        assert_eq!(points.len(), 6);
        // Offered load must grow with the swept rate...
        for w in points.windows(2) {
            assert!(w[1].offered > w[0].offered, "offered load must increase");
        }
        // ...and the curve must saturate past the knee: at the top rate
        // the system either sheds or completes a clearly sub-offered
        // fraction, with a latency tail above the under-load point.
        let (lo, hi) = (&points[0], &points[points.len() - 1]);
        assert!(
            hi.shed > 0 || hi.completed < hi.offered * 9 / 10,
            "top rate never saturated: {hi:?}"
        );
        assert!(
            hi.p99_ms > lo.p99_ms,
            "tail latency must grow past the knee: {:.3} -> {:.3}",
            lo.p99_ms,
            hi.p99_ms
        );
        assert!(lo.completed > 0 && hi.completed > 0);
        assert!(text.contains("offered load"), "{text}");
    }

    #[test]
    fn isolation_figure_has_one_distinct_point_per_mode() {
        let (text, rows) = isolation_figure(0);
        assert_eq!(rows.len(), 4);
        for r in &rows {
            assert!(r.aggregate_ips > 0.0, "{}: no throughput", r.mode);
            assert!(r.completed > 0, "{}: no iterations measured", r.mode);
            assert!(text.contains(&r.mode.to_string()), "{text}");
        }
        // Each mode must land on its own point of the variability-vs-IPS
        // frontier (the sharing mechanisms are genuinely different, so
        // identical numbers mean a mode is not wired through).
        for i in 0..rows.len() {
            for j in (i + 1)..rows.len() {
                assert!(
                    rows[i].aggregate_ips != rows[j].aggregate_ips
                        || rows[i].p99_ms != rows[j].p99_ms,
                    "{} and {} landed on the same point",
                    rows[i].mode,
                    rows[j].mode
                );
            }
        }
        // Spatial co-running removes the serialisation overheads (lock
        // handoffs, context switches), so mps must not lose to cook.
        let ips_of = |m: ConcurrencyMode| {
            rows.iter().find(|r| r.mode == m).unwrap().aggregate_ips
        };
        assert!(
            ips_of(ConcurrencyMode::Mps { quota: 2 }) >= ips_of(ConcurrencyMode::Cook),
            "mps must match or beat cook on aggregate IPS"
        );
    }

    #[test]
    fn autoscale_figure_chases_the_burst_envelope() {
        let (text, points) = autoscale_figure(0);
        assert_eq!(points.len(), crate::gpu::SCALE_WINDOWS);
        for p in &points {
            assert!(
                (1..=4).contains(&p.active_shards),
                "window {}: active shards {} outside 1..4",
                p.window,
                p.active_shards
            );
        }
        // The controller must actually move: full fleet inside the
        // bursts, scaled down (after hysteresis) in the quiet phases.
        assert!(points.iter().any(|p| p.active_shards == 4), "never scaled up: {text}");
        assert!(points.iter().any(|p| p.active_shards < 4), "never scaled down: {text}");
        // Scale-up is immediate: the busiest window runs the full fleet.
        let busiest = points.iter().max_by_key(|p| p.offered).unwrap();
        assert_eq!(busiest.active_shards, 4, "busiest window under-provisioned");
        assert!(points.iter().map(|p| p.offered).sum::<usize>() > 0);
        assert!(points.iter().map(|p| p.completed).sum::<usize>() > 0);
        assert!(text.contains("autoscale 1..4"), "{text}");
    }

    #[test]
    fn shard_scaling_monotone_and_isolated() {
        let (text, rows) = shard_scaling_figure(0);
        assert_eq!(rows.len(), 3);
        assert_eq!(rows[0].num_gpus, 1);
        assert!((rows[0].speedup - 1.0).abs() < 1e-9);
        for r in &rows {
            assert_eq!(
                r.within_shard_overlaps, 0,
                "{} shards: worker must isolate per GPU",
                r.num_gpus
            );
            assert_eq!(r.per_shard_ips.len(), r.num_gpus);
        }
        // 4 apps over 2 GPUs halves the contention; over 4 each app owns
        // a device — aggregate IPS must strictly improve at each step.
        assert!(rows[1].aggregate_ips > rows[0].aggregate_ips);
        assert!(rows[2].aggregate_ips > rows[1].aggregate_ips);
        assert!(text.contains("Shard scaling"), "{text}");
    }
}
