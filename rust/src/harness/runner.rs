//! Experiment runner: spec -> simulated run -> extracted metrics.

use super::spec::ExperimentSpec;
use crate::gpu::Sim;
use crate::metrics::stats::BoxStats;
use crate::metrics::{ips_with_warmup, net_per_kernel};
use crate::trace::chronogram::Chronogram;
use crate::util::AppId;

/// Everything a figure/table needs from one run.
#[derive(Debug)]
pub struct RunResult {
    pub spec: ExperimentSpec,
    pub seed: u64,
    /// Per-instance NET samples (eq. 1).
    pub net: Vec<Vec<f64>>,
    /// Per-instance IPS over the measurement window (eq. 2).
    pub ips: Vec<f64>,
    /// Per-instance kernel counts (sanity/coverage).
    pub kernels: Vec<usize>,
    /// Chronogram of the run (Fig. 11 input).
    pub chronogram: Chronogram,
    /// Cross-app kernel overlap count (isolation check, §VII-B).
    pub overlaps: usize,
    /// Context switches observed.
    pub switches: usize,
    /// Software-stack stalls injected.
    pub stalls: usize,
    /// Shard (GPU) each instance ran on — all zeros for the paper's
    /// single-GPU configurations; fleet runs key NET/IPS rows by this.
    pub shards: Vec<usize>,
    /// Cross-app kernel overlaps *within* each shard (indexed by shard).
    /// The per-GPU isolation check: gated strategies must keep every
    /// entry at 0 even when the fleet overlaps across shards.
    pub shard_overlaps: Vec<usize>,
}

impl RunResult {
    /// Boxplot summary per instance (Figs. 9/10 rendering input).
    pub fn net_box(&self, instance: usize) -> Option<BoxStats> {
        let v = &self.net[instance];
        if v.is_empty() {
            None
        } else {
            Some(BoxStats::from(v))
        }
    }

    /// Worst NET across all instances.
    pub fn max_net(&self) -> f64 {
        self.net
            .iter()
            .flatten()
            .copied()
            .fold(1.0, f64::max)
    }

    /// Fraction of kernels above a NET threshold, pooled over instances.
    pub fn frac_net_above(&self, threshold: f64) -> f64 {
        let all: Vec<f64> = self.net.iter().flatten().copied().collect();
        BoxStats::frac_above(&all, threshold)
    }
}

/// Run one experiment configuration.
///
/// Fleet specs (`num_gpus > 1`) execute shard-parallel inside
/// [`Sim::run`] under the `COOK_SIM_THREADS` cap — a second, *nested*
/// level of parallelism below the [`super::parallel::parallel_map`]
/// fan-out over specs/seeds; the result is identical at any setting of
/// either knob (DESIGN.md §11).
pub fn run_spec(spec: ExperimentSpec, seed: u64) -> RunResult {
    let mut sim = Sim::new(spec.sim_config(seed), spec.programs());
    sim.run();
    result_from_sim(spec, seed, &sim)
}

/// Extract a [`RunResult`] from a finished sim (shared by [`run_spec`]
/// and the CLI's `--config` override path, so the metric assembly lives
/// in exactly one place).
pub fn result_from_sim(spec: ExperimentSpec, seed: u64, sim: &Sim) -> RunResult {
    let n = sim.apps.len();
    let protocol = spec.bench.protocol();
    let mut net = Vec::new();
    let mut ips = Vec::new();
    let mut kernels = Vec::new();
    for a in 0..n {
        net.push(net_per_kernel(&sim.trace, AppId(a)));
        ips.push(ips_with_warmup(
            sim.completions(AppId(a)),
            protocol.warmup_ns,
            protocol.window_ns,
        ));
        kernels.push(sim.trace.kernel_ops(AppId(a)).count());
    }
    let overlaps = sim.trace.cross_app_kernel_overlaps();
    // A single-GPU run's only shard sees exactly the global overlap set;
    // skip the second pairwise scan on the hot (fig9/10/table1) path.
    let shard_overlaps = if sim.num_gpus() == 1 {
        vec![overlaps]
    } else {
        sim.within_shard_overlaps()
    };
    RunResult {
        spec,
        seed,
        net,
        ips,
        kernels,
        chronogram: Chronogram::from_trace(&sim.trace, n),
        overlaps,
        switches: sim.trace.switches.len(),
        stalls: sim.trace.stalls.len(),
        shards: (0..n).map(|a| sim.shard_of(AppId(a))).collect(),
        shard_overlaps,
    }
}

/// Run a spec across several seeds and pool the NET samples (the paper
/// collects one long run; pooling seeds tightens the tails we report).
///
/// Per-seed runs are independent, so they fan out across cores via
/// [`super::parallel::parallel_map`]; the merge folds in seed order, so
/// the pooled result is identical to the old sequential loop.
pub fn run_spec_pooled(spec: ExperimentSpec, seeds: &[u64]) -> RunResult {
    assert!(!seeds.is_empty());
    let results = super::parallel::parallel_map(seeds.to_vec(), |s| run_spec(spec, s));
    let mut it = results.into_iter();
    let mut base = it.next().unwrap();
    for r in it {
        for (acc, more) in base.net.iter_mut().zip(r.net) {
            acc.extend(more);
        }
        for (acc, more) in base.ips.iter_mut().zip(r.ips) {
            *acc = (*acc + more) / 2.0;
        }
        base.overlaps += r.overlaps;
        base.switches += r.switches;
        base.stalls += r.stalls;
        for (acc, more) in base.shard_overlaps.iter_mut().zip(r.shard_overlaps) {
            *acc += more;
        }
    }
    base
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StrategyKind;
    use crate::harness::spec::{Bench, Isol};

    #[test]
    fn mmult_isolation_none_runs() {
        let spec = ExperimentSpec::new(Bench::CudaMmult, Isol::Isolation, StrategyKind::None);
        let r = run_spec(spec, 7);
        assert_eq!(r.kernels[0], crate::apps::mmult::LAUNCHES);
        assert!(r.net_box(0).is_some());
        assert_eq!(r.overlaps, 0);
    }

    #[test]
    fn mmult_parallel_synced_isolates() {
        let spec = ExperimentSpec::new(Bench::CudaMmult, Isol::Parallel, StrategyKind::Synced);
        let r = run_spec(spec, 7);
        assert_eq!(r.overlaps, 0, "synced must isolate");
        assert!(!r.chronogram.has_cross_lane_overlap());
    }

    #[test]
    fn mmult_parallel_none_overlaps_and_switches() {
        let spec = ExperimentSpec::new(Bench::CudaMmult, Isol::Parallel, StrategyKind::None);
        let r = run_spec(spec, 7);
        assert!(r.overlaps > 0);
        assert!(r.switches > 0);
        assert!(r.chronogram.has_cross_lane_overlap());
    }

    #[test]
    fn pooled_run_accumulates_net() {
        let spec = ExperimentSpec::new(Bench::CudaMmult, Isol::Isolation, StrategyKind::None);
        let single = run_spec(spec, 1);
        let pooled = run_spec_pooled(spec, &[1, 2]);
        assert_eq!(pooled.net[0].len(), 2 * single.net[0].len());
    }
}
