//! Normalised Kernel Runtime — NET, eq. (1) of the paper:
//!
//! NET_{k,c}^i = ET_{k,c}^i / min_j(ET_{k,c}^j)
//!
//! computed per kernel *name* within one configuration, so a slow kernel
//! type does not inflate the NET of a fast one.

use crate::trace::record::TraceCollector;
use crate::util::{AppId, Nanos};
use std::collections::HashMap;

/// Compute NET values for every kernel instance of `app`, normalising
/// each instance by the minimum observed time of the *same kernel name*.
pub fn net_per_kernel(trace: &TraceCollector, app: AppId) -> Vec<f64> {
    let mut by_name: HashMap<&str, Vec<Nanos>> = HashMap::new();
    for r in trace.kernel_ops(app) {
        let name = r.kernel_name.as_deref().unwrap_or("?");
        by_name.entry(name).or_default().push(r.exec_ns());
    }
    let mut out = Vec::new();
    for (_, times) in by_name {
        let min = *times.iter().min().unwrap_or(&1) as f64;
        let min = min.max(1.0);
        for t in times {
            out.push(t as f64 / min);
        }
    }
    out
}

/// NET pooled across all apps (one boxplot per instance in Figs. 9/10 —
/// this helper returns per-app vectors keyed by app index).
pub fn net_all_apps(trace: &TraceCollector, num_apps: usize) -> Vec<Vec<f64>> {
    (0..num_apps)
        .map(|a| net_per_kernel(trace, AppId(a)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::record::OpRecord;
    use crate::util::OpUid;

    fn rec(app: usize, name: &str, start: Nanos, end: Nanos) -> OpRecord {
        OpRecord {
            op: OpUid(start),
            app: AppId(app),
            kernel_name: Some(name.to_string()),
            is_kernel: true,
            is_copy: false,
            enqueued_at: start,
            started_at: start,
            completed_at: end,
            burst: 0,
        }
    }

    #[test]
    fn net_normalises_by_min() {
        let mut t = TraceCollector::new(false);
        t.ops.push(rec(0, "k", 0, 100));
        t.ops.push(rec(0, "k", 200, 300)); // 100 -> NET 1.0
        t.ops.push(rec(0, "k", 400, 650)); // 250 -> NET 2.5
        let mut v = net_per_kernel(&t, AppId(0));
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(v, vec![1.0, 1.0, 2.5]);
    }

    #[test]
    fn net_is_per_kernel_name() {
        let mut t = TraceCollector::new(false);
        t.ops.push(rec(0, "fast", 0, 10));
        t.ops.push(rec(0, "slow", 0, 1000));
        let v = net_per_kernel(&t, AppId(0));
        // Both are the min of their own name -> both exactly 1.0.
        assert_eq!(v, vec![1.0, 1.0]);
    }

    #[test]
    fn net_ignores_other_apps_and_copies() {
        let mut t = TraceCollector::new(false);
        t.ops.push(rec(0, "k", 0, 100));
        t.ops.push(rec(1, "k", 0, 999));
        let mut c = rec(0, "c", 0, 5);
        c.is_kernel = false;
        c.is_copy = true;
        t.ops.push(c);
        assert_eq!(net_per_kernel(&t, AppId(0)).len(), 1);
    }

    #[test]
    fn net_all_apps_shapes() {
        let mut t = TraceCollector::new(false);
        t.ops.push(rec(0, "k", 0, 100));
        t.ops.push(rec(1, "k", 0, 100));
        t.ops.push(rec(1, "k", 200, 400));
        let v = net_all_apps(&t, 2);
        assert_eq!(v[0].len(), 1);
        assert_eq!(v[1].len(), 2);
    }

    #[test]
    fn empty_trace_empty_net() {
        let t = TraceCollector::new(false);
        assert!(net_per_kernel(&t, AppId(0)).is_empty());
    }
}
