//! Normalised Kernel Runtime — NET, eq. (1) of the paper:
//!
//! NET_{k,c}^i = ET_{k,c}^i / min_j(ET_{k,c}^j)
//!
//! computed per kernel *name* within one configuration, so a slow kernel
//! type does not inflate the NET of a fast one.

use crate::trace::record::TraceCollector;
use crate::util::{AppId, Nanos};

/// Compute NET values for every kernel instance of `app`, normalising
/// each instance by the minimum observed time of the *same kernel name*.
///
/// Kernel names are interned symbols, so grouping is a dense
/// `Vec`-indexed bucket fill — no hashing, single pass over the trace,
/// deterministic output order (symbol-less records, then ascending
/// symbol id).
pub fn net_per_kernel(trace: &TraceCollector, app: AppId) -> Vec<f64> {
    // Bucket 0 collects records without a symbol (hand-built traces in
    // tests); interned symbol s maps to bucket s+1. Real traces have
    // every sym < num_syms; the resize is a test-only escape hatch.
    let mut by_sym: Vec<Vec<Nanos>> = vec![Vec::new(); trace.num_syms() + 1];
    let mut total = 0usize;
    for r in trace.kernel_ops(app) {
        let idx = r.sym.map(|s| s.0 as usize + 1).unwrap_or(0);
        if idx >= by_sym.len() {
            by_sym.resize(idx + 1, Vec::new());
        }
        by_sym[idx].push(r.exec_ns());
        total += 1;
    }
    let mut out = Vec::with_capacity(total);
    for times in by_sym {
        if times.is_empty() {
            continue;
        }
        let min = (*times.iter().min().unwrap() as f64).max(1.0);
        for t in times {
            out.push(t as f64 / min);
        }
    }
    out
}

/// NET pooled across all apps (one boxplot per instance in Figs. 9/10 —
/// this helper returns per-app vectors keyed by app index).
pub fn net_all_apps(trace: &TraceCollector, num_apps: usize) -> Vec<Vec<f64>> {
    (0..num_apps)
        .map(|a| net_per_kernel(trace, AppId(a)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::record::OpRecord;
    use crate::util::OpUid;

    fn push(t: &mut TraceCollector, app: usize, name: &str, start: Nanos, end: Nanos) {
        let sym = t.intern(name);
        t.ops.push(OpRecord {
            op: OpUid(start),
            app: AppId(app),
            sym: Some(sym),
            is_kernel: true,
            is_copy: false,
            enqueued_at: start,
            started_at: start,
            completed_at: end,
            burst: 0,
        });
    }

    #[test]
    fn net_normalises_by_min() {
        let mut t = TraceCollector::new(false);
        push(&mut t, 0, "k", 0, 100);
        push(&mut t, 0, "k", 200, 300); // 100 -> NET 1.0
        push(&mut t, 0, "k", 400, 650); // 250 -> NET 2.5
        let mut v = net_per_kernel(&t, AppId(0));
        v.sort_by(f64::total_cmp);
        assert_eq!(v, vec![1.0, 1.0, 2.5]);
    }

    #[test]
    fn net_is_per_kernel_name() {
        let mut t = TraceCollector::new(false);
        push(&mut t, 0, "fast", 0, 10);
        push(&mut t, 0, "slow", 0, 1000);
        let v = net_per_kernel(&t, AppId(0));
        // Both are the min of their own name -> both exactly 1.0.
        assert_eq!(v, vec![1.0, 1.0]);
    }

    #[test]
    fn net_ignores_other_apps_and_copies() {
        let mut t = TraceCollector::new(false);
        push(&mut t, 0, "k", 0, 100);
        push(&mut t, 1, "k", 0, 999);
        push(&mut t, 0, "c", 0, 5);
        let last = t.ops.last_mut().unwrap();
        last.is_kernel = false;
        last.is_copy = true;
        assert_eq!(net_per_kernel(&t, AppId(0)).len(), 1);
    }

    #[test]
    fn net_groups_symbolless_records_together() {
        // Hand-built traces may carry no symbol; they form one group.
        let mut t = TraceCollector::new(false);
        push(&mut t, 0, "k", 0, 100);
        t.ops.push(OpRecord {
            op: OpUid(7),
            app: AppId(0),
            sym: None,
            is_kernel: true,
            is_copy: false,
            enqueued_at: 0,
            started_at: 0,
            completed_at: 40,
            burst: 0,
        });
        let v = net_per_kernel(&t, AppId(0));
        assert_eq!(v, vec![1.0, 1.0]);
    }

    #[test]
    fn net_all_apps_shapes() {
        let mut t = TraceCollector::new(false);
        push(&mut t, 0, "k", 0, 100);
        push(&mut t, 1, "k", 0, 100);
        push(&mut t, 1, "k", 200, 400);
        let v = net_all_apps(&t, 2);
        assert_eq!(v[0].len(), 1);
        assert_eq!(v[1].len(), 2);
    }

    #[test]
    fn empty_trace_empty_net() {
        let t = TraceCollector::new(false);
        assert!(net_per_kernel(&t, AppId(0)).is_empty());
    }
}
