//! Inferences per Second — IPS, eq. (2) of the paper:
//!
//! IPS_{a,c}^t = N_{a,c}^t / duration(t)
//!
//! measured by counting completed executions in a sampling window.

use crate::util::Nanos;

/// IPS over the window [start_ns, end_ns).
pub fn ips(completions: &[Nanos], start_ns: Nanos, end_ns: Nanos) -> f64 {
    assert!(end_ns > start_ns, "empty IPS window");
    let n = completions
        .iter()
        .filter(|&&t| t >= start_ns && t < end_ns)
        .count();
    n as f64 / ((end_ns - start_ns) as f64 / 1e9)
}

/// IPS with the paper's measurement protocol (§VI-C): a warm-up period is
/// discarded, then a fixed sampling window is measured.
pub fn ips_with_warmup(completions: &[Nanos], warmup_ns: Nanos, window_ns: Nanos) -> f64 {
    ips(completions, warmup_ns, warmup_ns + window_ns)
}

/// Per-second IPS samples across the window (the "regular intervals" of
/// eq. 2 — useful for time-series plots and stability checks).
pub fn ips_series(completions: &[Nanos], start_ns: Nanos, end_ns: Nanos) -> Vec<f64> {
    let mut out = Vec::new();
    let mut t = start_ns;
    while t + 1_000_000_000 <= end_ns {
        out.push(ips(completions, t, t + 1_000_000_000));
        t += 1_000_000_000;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_in_window_only() {
        // 10 completions at 0.1s..1.0s, then 2 more later.
        let mut c: Vec<Nanos> = (1..=10).map(|i| i * 100_000_000).collect();
        c.push(5_000_000_000);
        c.push(6_000_000_000);
        assert_eq!(ips(&c, 0, 1_000_000_000), 9.0); // t < end excludes 1.0 s
        assert_eq!(ips(&c, 0, 2_000_000_000), 5.0);
    }

    #[test]
    fn warmup_discards_initial_burst() {
        // Fast burst in the first second, steady 2/s afterwards.
        let mut c: Vec<Nanos> = (0..100).map(|i| i * 10_000_000).collect();
        for i in 0..10 {
            c.push(1_000_000_000 + i * 500_000_000);
        }
        let v = ips_with_warmup(&c, 1_000_000_000, 5_000_000_000);
        assert_eq!(v, 2.0);
    }

    #[test]
    fn series_has_one_sample_per_second() {
        let c: Vec<Nanos> = (0..30).map(|i| i * 100_000_000).collect(); // 10/s for 3 s
        let s = ips_series(&c, 0, 3_000_000_000);
        assert_eq!(s.len(), 3);
        assert_eq!(s[0], 10.0);
    }

    #[test]
    #[should_panic(expected = "empty IPS window")]
    fn empty_window_panics() {
        ips(&[], 5, 5);
    }

    #[test]
    fn no_completions_zero_ips() {
        assert_eq!(ips(&[], 0, 1_000_000_000), 0.0);
    }
}
