//! Inferences per Second — IPS, eq. (2) of the paper:
//!
//! IPS_{a,c}^t = N_{a,c}^t / duration(t)
//!
//! measured by counting completed executions in a sampling window.

use crate::util::Nanos;

/// IPS over the window [start_ns, end_ns).
///
/// A degenerate window (`end_ns <= start_ns`) yields 0.0 rather than
/// panicking: short serving runs reach it whenever the warm-up period
/// meets or exceeds the run length (ISSUE 4 regression).
pub fn ips(completions: &[Nanos], start_ns: Nanos, end_ns: Nanos) -> f64 {
    if end_ns <= start_ns {
        return 0.0;
    }
    let n = completions
        .iter()
        .filter(|&&t| t >= start_ns && t < end_ns)
        .count();
    n as f64 / ((end_ns - start_ns) as f64 / 1e9)
}

/// IPS with the paper's measurement protocol (§VI-C): a warm-up period is
/// discarded, then a fixed sampling window is measured.
pub fn ips_with_warmup(completions: &[Nanos], warmup_ns: Nanos, window_ns: Nanos) -> f64 {
    ips(completions, warmup_ns, warmup_ns + window_ns)
}

/// Per-second IPS samples across the window (the "regular intervals" of
/// eq. 2 — useful for time-series plots and stability checks).
///
/// A trailing partial window (when the span is not a whole number of
/// seconds) is included as a final sample normalised by its true width,
/// so the tail is accounted for instead of silently truncated
/// (ISSUE 4 regression).
pub fn ips_series(completions: &[Nanos], start_ns: Nanos, end_ns: Nanos) -> Vec<f64> {
    let mut out = Vec::new();
    let mut t = start_ns;
    while t + 1_000_000_000 <= end_ns {
        out.push(ips(completions, t, t + 1_000_000_000));
        t += 1_000_000_000;
    }
    if t < end_ns {
        out.push(ips(completions, t, end_ns));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counts_in_window_only() {
        // 10 completions at 0.1s..1.0s, then 2 more later.
        let mut c: Vec<Nanos> = (1..=10).map(|i| i * 100_000_000).collect();
        c.push(5_000_000_000);
        c.push(6_000_000_000);
        assert_eq!(ips(&c, 0, 1_000_000_000), 9.0); // t < end excludes 1.0 s
        assert_eq!(ips(&c, 0, 2_000_000_000), 5.0);
    }

    #[test]
    fn warmup_discards_initial_burst() {
        // Fast burst in the first second, steady 2/s afterwards.
        let mut c: Vec<Nanos> = (0..100).map(|i| i * 10_000_000).collect();
        for i in 0..10 {
            c.push(1_000_000_000 + i * 500_000_000);
        }
        let v = ips_with_warmup(&c, 1_000_000_000, 5_000_000_000);
        assert_eq!(v, 2.0);
    }

    #[test]
    fn series_has_one_sample_per_second() {
        let c: Vec<Nanos> = (0..30).map(|i| i * 100_000_000).collect(); // 10/s for 3 s
        let s = ips_series(&c, 0, 3_000_000_000);
        assert_eq!(s.len(), 3);
        assert_eq!(s[0], 10.0);
    }

    #[test]
    fn degenerate_window_is_zero() {
        // Regression (ISSUE 4): this used to panic, reachable from short
        // serving runs whose warm-up meets or exceeds the run length.
        assert_eq!(ips(&[], 5, 5), 0.0);
        assert_eq!(ips(&[1, 2, 3], 9, 3), 0.0);
        assert_eq!(ips_with_warmup(&[1, 2, 3], 10, 0), 0.0);
    }

    #[test]
    fn series_includes_trailing_partial_window() {
        // 10/s for 3.5 s: three full one-second samples plus a final
        // half-second sample normalised by its true width.
        let c: Vec<Nanos> = (0..35).map(|i| i * 100_000_000).collect();
        let s = ips_series(&c, 0, 3_500_000_000);
        assert_eq!(s.len(), 4, "partial window must be accounted for");
        assert_eq!(s[3], 10.0, "partial window normalised by its width");
        // Exact multiples are unchanged.
        assert_eq!(ips_series(&c, 0, 3_000_000_000).len(), 3);
    }

    #[test]
    fn no_completions_zero_ips() {
        assert_eq!(ips(&[], 0, 1_000_000_000), 0.0);
    }
}
