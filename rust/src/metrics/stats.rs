//! Distribution statistics for the evaluation figures: quantiles and the
//! boxplot summaries of Figures 9/10 (median box, p0.5-p99.5 whiskers).

/// Linear-interpolated quantile of an unsorted slice (q in [0, 1]).
pub fn quantile(values: &[f64], q: f64) -> f64 {
    assert!(!values.is_empty(), "quantile of empty slice");
    let mut v: Vec<f64> = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile_sorted(&v, q)
}

/// Quantile of an already-sorted slice.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// The boxplot summary used by Figures 9/10: the box captures the 50% of
/// samples around the median, whiskers capture 99% of the data (p0.5 to
/// p99.5), and the extremes are reported separately.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BoxStats {
    pub min: f64,
    pub whisker_lo: f64, // p0.5
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub whisker_hi: f64, // p99.5
    pub max: f64,
    pub mean: f64,
    pub n: usize,
}

impl BoxStats {
    pub fn from(values: &[f64]) -> Self {
        assert!(!values.is_empty(), "BoxStats of empty slice");
        let mut v: Vec<f64> = values.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        Self {
            min: v[0],
            whisker_lo: quantile_sorted(&v, 0.005),
            q1: quantile_sorted(&v, 0.25),
            median: quantile_sorted(&v, 0.5),
            q3: quantile_sorted(&v, 0.75),
            whisker_hi: quantile_sorted(&v, 0.995),
            max: *v.last().unwrap(),
            mean,
            n: v.len(),
        }
    }

    /// Fraction of samples strictly above `threshold` (outlier-tail
    /// statements like "less than 0.5% of kernels exceed a 10x slowdown").
    pub fn frac_above(values: &[f64], threshold: f64) -> f64 {
        let n = values.iter().filter(|v| **v > threshold).count();
        n as f64 / values.len().max(1) as f64
    }

    /// One-line rendering for tables/logs.
    pub fn render(&self) -> String {
        format!(
            "n={} min={:.3} p0.5={:.3} q1={:.3} med={:.3} q3={:.3} p99.5={:.3} max={:.3}",
            self.n, self.min, self.whisker_lo, self.q1, self.median, self.q3,
            self.whisker_hi, self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantile_basics() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&v, 0.0), 1.0);
        assert_eq!(quantile(&v, 0.5), 3.0);
        assert_eq!(quantile(&v, 1.0), 5.0);
        assert_eq!(quantile(&v, 0.25), 2.0);
        // interpolation
        let v2 = [0.0, 10.0];
        assert_eq!(quantile(&v2, 0.5), 5.0);
    }

    #[test]
    fn quantile_unsorted_input() {
        let v = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(quantile(&v, 0.5), 3.0);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn quantile_empty_panics() {
        quantile(&[], 0.5);
    }

    #[test]
    fn box_stats_ordering_invariant() {
        let v: Vec<f64> = (0..1000).map(|i| (i as f64 * 37.0) % 100.0).collect();
        let b = BoxStats::from(&v);
        assert!(b.min <= b.whisker_lo);
        assert!(b.whisker_lo <= b.q1);
        assert!(b.q1 <= b.median);
        assert!(b.median <= b.q3);
        assert!(b.q3 <= b.whisker_hi);
        assert!(b.whisker_hi <= b.max);
        assert_eq!(b.n, 1000);
    }

    #[test]
    fn whiskers_capture_99_percent() {
        // 1000 ones with 2 extreme outliers: whiskers must exclude them.
        let mut v = vec![1.0; 1000];
        v.push(500.0);
        v.push(0.001);
        let b = BoxStats::from(&v);
        assert_eq!(b.median, 1.0);
        assert!(b.whisker_hi < 500.0);
        assert!(b.max == 500.0);
    }

    #[test]
    fn frac_above() {
        let v = [1.0, 1.0, 1.0, 11.0];
        assert_eq!(BoxStats::frac_above(&v, 10.0), 0.25);
        assert_eq!(BoxStats::frac_above(&v, 100.0), 0.0);
    }

    #[test]
    fn render_contains_median() {
        let b = BoxStats::from(&[1.0, 2.0, 3.0]);
        assert!(b.render().contains("med=2.000"));
    }
}
