//! Distribution statistics for the evaluation figures: quantiles and the
//! boxplot summaries of Figures 9/10 (median box, p0.5-p99.5 whiskers) —
//! plus the streaming quantile machinery the serving layers report with:
//! [`Histogram`] (integer nanoseconds, log2 buckets), [`QuantileSketch`]
//! (f64 samples, fine-grained log buckets) and [`LatencyStats`] (a sketch
//! with an optional exact-vector cross-check path).
//!
//! Every sort in this module orders by [`f64::total_cmp`]: a single NaN
//! sample must degrade one reading, never panic a whole report.

/// Linear-interpolated quantile of an unsorted slice (q in [0, 1]).
pub fn quantile(values: &[f64], q: f64) -> f64 {
    assert!(!values.is_empty(), "quantile of empty slice");
    let mut v: Vec<f64> = values.to_vec();
    v.sort_by(f64::total_cmp);
    quantile_sorted(&v, q)
}

/// Nearest-rank quantile (rank `ceil(q*n)`) of a sorted slice; 0.0 when
/// empty. The serving/fleet layers report this flavour (exact sample, no
/// interpolation); the debug assertion keeps a future merge path from
/// silently feeding unsorted data (ISSUE 4).
pub fn nearest_rank(sorted: &[f64], q: f64) -> f64 {
    debug_assert!(
        sorted
            .windows(2)
            .all(|w| w[0].total_cmp(&w[1]) != std::cmp::Ordering::Greater),
        "nearest_rank requires sorted input"
    );
    let n = sorted.len();
    if n == 0 {
        return 0.0;
    }
    let rank = ((q.clamp(0.0, 1.0) * n as f64).ceil() as usize).clamp(1, n);
    sorted[rank - 1]
}

/// Quantile of an already-sorted slice.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// The boxplot summary used by Figures 9/10: the box captures the 50% of
/// samples around the median, whiskers capture 99% of the data (p0.5 to
/// p99.5), and the extremes are reported separately.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BoxStats {
    pub min: f64,
    pub whisker_lo: f64, // p0.5
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub whisker_hi: f64, // p99.5
    pub max: f64,
    pub mean: f64,
    pub n: usize,
}

impl BoxStats {
    pub fn from(values: &[f64]) -> Self {
        assert!(!values.is_empty(), "BoxStats of empty slice");
        let mut v: Vec<f64> = values.to_vec();
        v.sort_by(f64::total_cmp);
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        Self {
            min: v[0],
            whisker_lo: quantile_sorted(&v, 0.005),
            q1: quantile_sorted(&v, 0.25),
            median: quantile_sorted(&v, 0.5),
            q3: quantile_sorted(&v, 0.75),
            whisker_hi: quantile_sorted(&v, 0.995),
            max: *v.last().unwrap(),
            mean,
            n: v.len(),
        }
    }

    /// Fraction of samples strictly above `threshold` (outlier-tail
    /// statements like "less than 0.5% of kernels exceed a 10x slowdown").
    pub fn frac_above(values: &[f64], threshold: f64) -> f64 {
        let n = values.iter().filter(|v| **v > threshold).count();
        n as f64 / values.len().max(1) as f64
    }

    /// One-line rendering for tables/logs.
    pub fn render(&self) -> String {
        format!(
            "n={} min={:.3} p0.5={:.3} q1={:.3} med={:.3} q3={:.3} p99.5={:.3} max={:.3}",
            self.n, self.min, self.whisker_lo, self.q1, self.median, self.q3,
            self.whisker_hi, self.max
        )
    }
}

/// A log2-bucketed latency histogram over nanosecond samples.
///
/// Built for the serving gate's wait/hold accounting: recording is O(1)
/// and allocation-free, so it can sit on the admission hot path, while
/// quantile reads are approximate (bucket upper bound — at most 2x the
/// true value, which is ample for latency reporting across decades).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// buckets[i] counts samples with floor(log2(ns)) == i (bucket 0 also
    /// holds ns == 0); the last bucket is open-ended.
    buckets: [u64; Self::NUM_BUCKETS],
    count: u64,
    sum_ns: u128,
    min_ns: u64,
    max_ns: u64,
}

impl Histogram {
    const NUM_BUCKETS: usize = 64;

    pub fn new() -> Self {
        Self {
            buckets: [0; Self::NUM_BUCKETS],
            count: 0,
            sum_ns: 0,
            min_ns: u64::MAX,
            max_ns: 0,
        }
    }

    fn bucket_of(ns: u64) -> usize {
        if ns == 0 {
            0
        } else {
            (63 - ns.leading_zeros() as usize).min(Self::NUM_BUCKETS - 1)
        }
    }

    pub fn record(&mut self, ns: u64) {
        self.buckets[Self::bucket_of(ns)] += 1;
        self.count += 1;
        self.sum_ns += ns as u128;
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn min_ns(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min_ns
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    /// Approximate nearest-rank quantile: the upper bound of the bucket
    /// holding the rank-`ceil(q*n)` sample (exact min/max at q==0/1).
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        if q <= 0.0 {
            return self.min_ns();
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                // Upper bound of bucket i, clamped to the observed max.
                let ub = if i + 1 >= Self::NUM_BUCKETS {
                    u64::MAX
                } else {
                    (1u64 << (i + 1)) - 1
                };
                return ub.min(self.max_ns);
            }
        }
        self.max_ns
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    /// One-line rendering in milliseconds (serving reports).
    pub fn render_ms(&self) -> String {
        let ms = |ns: u64| ns as f64 / 1e6;
        format!(
            "n={} mean={:.3} p50≈{:.3} p95≈{:.3} p99≈{:.3} max={:.3} (ms)",
            self.count,
            self.mean_ns() / 1e6,
            ms(self.quantile_ns(0.50)),
            ms(self.quantile_ns(0.95)),
            ms(self.quantile_ns(0.99)),
            ms(self.max_ns),
        )
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

/// A streaming quantile sketch over f64 samples: a fixed array of
/// log-spaced buckets (ratio [`QuantileSketch::GAMMA`] between bucket
/// bounds) with nearest-rank extraction, mergeable like
/// [`Histogram::merge`].
///
/// Recording is O(1) and allocation-free; a quantile read walks the
/// fixed bucket array. The extracted value is the upper bound of the
/// bucket holding the nearest-rank sample, clamped to the observed
/// min/max — so its **relative error is at most `GAMMA - 1` (2%)** for
/// any sample in the trackable range `[1e-9, ~1e12]` (values outside
/// clamp to the range ends; min/max/mean/count are always exact). The
/// fleet property test cross-checks this bound against the exact
/// nearest-rank quantiles ([`LatencyStats`]'s `--exact-quantiles` path).
#[derive(Debug, Clone)]
pub struct QuantileSketch {
    /// counts[0] holds v <= MIN_VALUE (and non-finite junk); counts[i]
    /// (i >= 1) holds v in (MIN_VALUE*GAMMA^(i-1), MIN_VALUE*GAMMA^i],
    /// with the last bucket open-ended.
    counts: Vec<u32>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl QuantileSketch {
    /// Ratio between consecutive bucket upper bounds: the relative error
    /// bound of a quantile read is `GAMMA - 1`.
    pub const GAMMA: f64 = 1.02;
    /// Smallest trackable positive value. Latencies are recorded in
    /// milliseconds, so this is one femtosecond — far below clock
    /// resolution.
    const MIN_VALUE: f64 = 1e-9;
    /// Buckets needed to span MIN_VALUE..~1e12 at GAMMA spacing (the
    /// last bucket is an open-ended catch-all): ln(1e21)/ln(1.02) ~ 2442.
    const NUM_BUCKETS: usize = 2448;

    pub fn new() -> Self {
        Self {
            counts: vec![0; Self::NUM_BUCKETS],
            count: 0,
            sum: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    fn bucket_of(v: f64) -> usize {
        if v.is_nan() || v <= Self::MIN_VALUE {
            // A poisoned (NaN) sample degrades one reading in the bottom
            // bucket; it never panics a report.
            return 0;
        }
        let i = ((v / Self::MIN_VALUE).ln() / Self::GAMMA.ln()).ceil();
        if i.is_finite() && i >= 1.0 {
            (i as usize).min(Self::NUM_BUCKETS - 1)
        } else {
            Self::NUM_BUCKETS - 1 // +inf and fp fallout: top catch-all
        }
    }

    /// Upper bound of bucket `i` (the extracted representative before
    /// min/max clamping).
    fn bucket_upper(i: usize) -> f64 {
        if i == 0 {
            Self::MIN_VALUE
        } else {
            Self::MIN_VALUE * Self::GAMMA.powi(i as i32)
        }
    }

    pub fn record(&mut self, v: f64) {
        self.counts[Self::bucket_of(v)] += 1;
        self.count += 1;
        if v.is_finite() {
            self.sum += v;
            if v < self.min {
                self.min = v;
            }
            if v > self.max {
                self.max = v;
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Exact observed minimum (0.0 when empty or all-NaN).
    pub fn min(&self) -> f64 {
        if self.min.is_finite() {
            self.min
        } else {
            0.0
        }
    }

    /// Exact observed maximum (0.0 when empty or all-NaN).
    pub fn max(&self) -> f64 {
        if self.max.is_finite() {
            self.max
        } else {
            0.0
        }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Approximate nearest-rank quantile: the upper bound of the bucket
    /// holding the rank-`ceil(q*n)` sample, clamped to the exact
    /// observed [min, max]. Relative error <= `GAMMA - 1`.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let q = q.clamp(0.0, 1.0);
        if q <= 0.0 {
            return self.min();
        }
        if q >= 1.0 {
            return self.max();
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c as u64;
            if seen >= rank {
                return Self::bucket_upper(i).clamp(self.min(), self.max());
            }
        }
        self.max()
    }

    pub fn merge(&mut self, other: &QuantileSketch) {
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

impl Default for QuantileSketch {
    fn default() -> Self {
        Self::new()
    }
}

/// The serving layers' latency accumulator: a [`QuantileSketch`] fed
/// per-request (no accumulate-then-sort tax on the report path), plus an
/// optional **exact** sample vector retained only when the run asked for
/// it (`--exact-quantiles` / `ServeSpec::exact_quantiles`) — the
/// cross-check path the fleet property test uses to pin the sketch's
/// error bound.
#[derive(Debug, Clone)]
pub struct LatencyStats {
    pub sketch: QuantileSketch,
    /// Exact samples; sorted once [`LatencyStats::seal`]ed. `None` on
    /// the default (sketch-only) path.
    exact: Option<Vec<f64>>,
}

impl LatencyStats {
    pub fn new(keep_exact: bool) -> Self {
        Self {
            sketch: QuantileSketch::new(),
            exact: keep_exact.then(Vec::new),
        }
    }

    /// Build from a finished sample set (sealed and ready to query).
    pub fn from_values(values: &[f64], keep_exact: bool) -> Self {
        let mut s = Self::new(keep_exact);
        for &v in values {
            s.record(v);
        }
        s.seal();
        s
    }

    pub fn record(&mut self, v: f64) {
        self.sketch.record(v);
        if let Some(e) = &mut self.exact {
            e.push(v);
        }
    }

    /// Fold another stats object in. The exact vector survives only when
    /// both sides carry one (all shards of a run share the flag); call
    /// [`LatencyStats::seal`] after the last merge.
    pub fn merge(&mut self, other: &LatencyStats) {
        self.sketch.merge(&other.sketch);
        match (&mut self.exact, &other.exact) {
            (Some(a), Some(b)) => a.extend_from_slice(b),
            (e, _) => *e = None,
        }
    }

    /// Sort the exact vector (NaN-safe total order). Idempotent; every
    /// construction path calls this before the stats are queried.
    pub fn seal(&mut self) {
        if let Some(e) = &mut self.exact {
            e.sort_by(f64::total_cmp);
        }
    }

    pub fn count(&self) -> usize {
        self.sketch.count() as usize
    }

    pub fn is_empty(&self) -> bool {
        self.sketch.is_empty()
    }

    pub fn min(&self) -> f64 {
        self.sketch.min()
    }

    pub fn max(&self) -> f64 {
        self.sketch.max()
    }

    pub fn mean(&self) -> f64 {
        self.sketch.mean()
    }

    /// Nearest-rank quantile: **exact** when the run kept the exact
    /// vector, sketch extraction (<= 2% relative error) otherwise.
    pub fn quantile(&self, q: f64) -> f64 {
        match &self.exact {
            Some(sorted) => nearest_rank(sorted, q),
            None => self.sketch.quantile(q),
        }
    }

    /// The sorted exact samples, when this run kept them.
    pub fn exact_values(&self) -> Option<&[f64]> {
        self.exact.as_deref()
    }

    pub fn is_exact(&self) -> bool {
        self.exact.is_some()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantile_basics() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&v, 0.0), 1.0);
        assert_eq!(quantile(&v, 0.5), 3.0);
        assert_eq!(quantile(&v, 1.0), 5.0);
        assert_eq!(quantile(&v, 0.25), 2.0);
        // interpolation
        let v2 = [0.0, 10.0];
        assert_eq!(quantile(&v2, 0.5), 5.0);
    }

    #[test]
    fn quantile_unsorted_input() {
        let v = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(quantile(&v, 0.5), 3.0);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn quantile_empty_panics() {
        quantile(&[], 0.5);
    }

    #[test]
    fn box_stats_ordering_invariant() {
        let v: Vec<f64> = (0..1000).map(|i| (i as f64 * 37.0) % 100.0).collect();
        let b = BoxStats::from(&v);
        assert!(b.min <= b.whisker_lo);
        assert!(b.whisker_lo <= b.q1);
        assert!(b.q1 <= b.median);
        assert!(b.median <= b.q3);
        assert!(b.q3 <= b.whisker_hi);
        assert!(b.whisker_hi <= b.max);
        assert_eq!(b.n, 1000);
    }

    #[test]
    fn whiskers_capture_99_percent() {
        // 1000 ones with 2 extreme outliers: whiskers must exclude them.
        let mut v = vec![1.0; 1000];
        v.push(500.0);
        v.push(0.001);
        let b = BoxStats::from(&v);
        assert_eq!(b.median, 1.0);
        assert!(b.whisker_hi < 500.0);
        assert!(b.max == 500.0);
    }

    #[test]
    fn frac_above() {
        let v = [1.0, 1.0, 1.0, 11.0];
        assert_eq!(BoxStats::frac_above(&v, 10.0), 0.25);
        assert_eq!(BoxStats::frac_above(&v, 100.0), 0.0);
    }

    #[test]
    fn render_contains_median() {
        let b = BoxStats::from(&[1.0, 2.0, 3.0]);
        assert!(b.render().contains("med=2.000"));
    }

    #[test]
    fn histogram_empty_is_zeroed() {
        let h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.count(), 0);
        assert_eq!(h.min_ns(), 0);
        assert_eq!(h.max_ns(), 0);
        assert_eq!(h.mean_ns(), 0.0);
        assert_eq!(h.quantile_ns(0.5), 0);
    }

    #[test]
    fn histogram_basic_accounting() {
        let mut h = Histogram::new();
        for ns in [100u64, 200, 400, 800, 1_600] {
            h.record(ns);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.min_ns(), 100);
        assert_eq!(h.max_ns(), 1_600);
        assert_eq!(h.mean_ns(), 620.0);
    }

    #[test]
    fn histogram_quantile_bucket_bounds() {
        let mut h = Histogram::new();
        // 99 samples at ~1us, one outlier at ~1ms.
        for _ in 0..99 {
            h.record(1_000);
        }
        h.record(1_000_000);
        // p50 must land in the 1us bucket (upper bound < 2048ns)...
        assert!(h.quantile_ns(0.5) < 2_048, "p50 = {}", h.quantile_ns(0.5));
        // ...and p100 must see the outlier, clamped to the observed max.
        assert_eq!(h.quantile_ns(1.0), 1_000_000);
        // Approximation bound: never more than 2x the true value.
        assert!(h.quantile_ns(0.5) >= 1_000);
    }

    #[test]
    fn histogram_zero_sample_and_merge() {
        let mut a = Histogram::new();
        a.record(0);
        a.record(10);
        let mut b = Histogram::new();
        b.record(1 << 40);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.min_ns(), 0);
        assert_eq!(a.max_ns(), 1 << 40);
    }

    #[test]
    fn histogram_render_mentions_count() {
        let mut h = Histogram::new();
        h.record(5_000_000);
        assert!(h.render_ms().contains("n=1"));
    }

    // ------------------------------------------------ quantile sketch --

    #[test]
    fn sketch_empty_is_zeroed() {
        let s = QuantileSketch::new();
        assert!(s.is_empty());
        assert_eq!(s.count(), 0);
        assert_eq!(s.min(), 0.0);
        assert_eq!(s.max(), 0.0);
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.quantile(0.5), 0.0);
    }

    #[test]
    fn sketch_exact_scalars() {
        let mut s = QuantileSketch::new();
        for v in [0.5, 1.5, 2.0, 8.0] {
            s.record(v);
        }
        assert_eq!(s.count(), 4);
        assert_eq!(s.min(), 0.5);
        assert_eq!(s.max(), 8.0);
        assert!((s.mean() - 3.0).abs() < 1e-12);
        // q=0 / q=1 are the exact extremes.
        assert_eq!(s.quantile(0.0), 0.5);
        assert_eq!(s.quantile(1.0), 8.0);
    }

    #[test]
    fn sketch_quantiles_within_documented_error_bound() {
        // Samples across six decades; every sketch quantile must agree
        // with the exact nearest-rank quantile within GAMMA - 1 relative
        // error (clamping can only tighten it).
        let values: Vec<f64> = (1..=4000)
            .map(|i| 0.001 * 1.004f64.powi(i % 3500))
            .collect();
        let mut s = QuantileSketch::new();
        for &v in &values {
            s.record(v);
        }
        let mut sorted = values.clone();
        sorted.sort_by(f64::total_cmp);
        for q in [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 0.999] {
            let exact = nearest_rank(&sorted, q);
            let approx = s.quantile(q);
            let rel = (approx - exact).abs() / exact;
            assert!(
                rel <= QuantileSketch::GAMMA - 1.0 + 1e-9,
                "q={q}: sketch {approx} vs exact {exact} (rel {rel})"
            );
        }
    }

    #[test]
    fn sketch_quantiles_are_monotone_in_q() {
        let mut s = QuantileSketch::new();
        let mut x = 17u64;
        for _ in 0..500 {
            x = x.wrapping_mul(6364136223846793005).wrapping_add(1);
            s.record((x % 100_000) as f64 / 7.0);
        }
        let qs = [0.0, 0.1, 0.3, 0.5, 0.7, 0.9, 0.99, 1.0];
        for w in qs.windows(2) {
            assert!(
                s.quantile(w[0]) <= s.quantile(w[1]),
                "quantiles not monotone at {:?}",
                w
            );
        }
    }

    #[test]
    fn sketch_merge_equals_pooled_recording() {
        let (mut a, mut b, mut pooled) =
            (QuantileSketch::new(), QuantileSketch::new(), QuantileSketch::new());
        for i in 0..300 {
            let v = (i * i % 997) as f64 * 0.25;
            if i % 2 == 0 {
                a.record(v);
            } else {
                b.record(v);
            }
            pooled.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), pooled.count());
        assert_eq!(a.min(), pooled.min());
        assert_eq!(a.max(), pooled.max());
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(a.quantile(q), pooled.quantile(q), "q={q}");
        }
    }

    #[test]
    fn sketch_survives_nan_and_extremes() {
        let mut s = QuantileSketch::new();
        s.record(f64::NAN);
        s.record(0.0);
        s.record(-3.0);
        s.record(f64::INFINITY);
        s.record(1e30); // beyond the top bucket: clamped to max
        s.record(5.0);
        assert_eq!(s.count(), 6);
        assert_eq!(s.min(), -3.0);
        assert_eq!(s.max(), 1e30);
        // Quantiles stay finite and ordered — no panic, no NaN output.
        let p50 = s.quantile(0.5);
        assert!(p50.is_finite());
        assert!(s.quantile(0.9) >= p50);
    }

    // ------------------------------------------------- latency stats --

    #[test]
    fn latency_stats_exact_path_is_nearest_rank() {
        let s = LatencyStats::from_values(&[4.0, 1.0, 3.0, 2.0], true);
        assert!(s.is_exact());
        assert_eq!(s.quantile(0.50), 2.0);
        assert_eq!(s.quantile(0.25), 1.0);
        assert_eq!(s.quantile(1.00), 4.0);
        assert_eq!(s.exact_values().unwrap(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn latency_stats_sketch_path_tracks_exact_within_bound() {
        let values: Vec<f64> = (1..1000).map(|i| (i as f64).sqrt() * 3.7).collect();
        let sketchy = LatencyStats::from_values(&values, false);
        let exact = LatencyStats::from_values(&values, true);
        assert!(!sketchy.is_exact());
        assert_eq!(sketchy.count(), exact.count());
        assert_eq!(sketchy.max(), exact.max());
        for q in [0.1, 0.5, 0.95, 0.99] {
            let (a, e) = (sketchy.quantile(q), exact.quantile(q));
            assert!(
                (a - e).abs() / e <= QuantileSketch::GAMMA - 1.0 + 1e-9,
                "q={q}: {a} vs {e}"
            );
        }
    }

    #[test]
    fn latency_stats_merge_drops_exact_unless_both_sides_have_it() {
        let mut a = LatencyStats::from_values(&[1.0, 2.0], true);
        let b = LatencyStats::from_values(&[3.0], true);
        a.merge(&b);
        a.seal();
        assert_eq!(a.count(), 3);
        assert_eq!(a.exact_values().unwrap(), &[1.0, 2.0, 3.0]);
        assert_eq!(a.quantile(1.0), 3.0);

        let sketch_only = LatencyStats::from_values(&[9.0], false);
        a.merge(&sketch_only);
        assert!(!a.is_exact(), "exact vector cannot survive a sketch-only merge");
        assert_eq!(a.count(), 4);
        assert_eq!(a.max(), 9.0);
    }

    #[test]
    fn latency_stats_nan_does_not_panic_report() {
        // Regression for the satellite fix: one NaN latency used to
        // panic the whole report inside sort_by(partial_cmp().unwrap()).
        let s = LatencyStats::from_values(&[1.0, f64::NAN, 2.0], true);
        assert_eq!(s.count(), 3);
        let p50 = s.quantile(0.5);
        assert!(p50.is_finite(), "median must come from the finite samples");
        let sketchy = LatencyStats::from_values(&[1.0, f64::NAN, 2.0], false);
        assert!(sketchy.quantile(0.5).is_finite());
    }

    #[test]
    fn nearest_rank_basics() {
        assert_eq!(nearest_rank(&[], 0.5), 0.0);
        let v = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(nearest_rank(&v, 0.5), 2.0);
        assert_eq!(nearest_rank(&v, 0.0), 1.0);
        assert_eq!(nearest_rank(&v, 1.0), 4.0);
    }
}
