//! Distribution statistics for the evaluation figures: quantiles and the
//! boxplot summaries of Figures 9/10 (median box, p0.5-p99.5 whiskers).

/// Linear-interpolated quantile of an unsorted slice (q in [0, 1]).
pub fn quantile(values: &[f64], q: f64) -> f64 {
    assert!(!values.is_empty(), "quantile of empty slice");
    let mut v: Vec<f64> = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    quantile_sorted(&v, q)
}

/// Quantile of an already-sorted slice.
pub fn quantile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let q = q.clamp(0.0, 1.0);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// The boxplot summary used by Figures 9/10: the box captures the 50% of
/// samples around the median, whiskers capture 99% of the data (p0.5 to
/// p99.5), and the extremes are reported separately.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BoxStats {
    pub min: f64,
    pub whisker_lo: f64, // p0.5
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub whisker_hi: f64, // p99.5
    pub max: f64,
    pub mean: f64,
    pub n: usize,
}

impl BoxStats {
    pub fn from(values: &[f64]) -> Self {
        assert!(!values.is_empty(), "BoxStats of empty slice");
        let mut v: Vec<f64> = values.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mean = v.iter().sum::<f64>() / v.len() as f64;
        Self {
            min: v[0],
            whisker_lo: quantile_sorted(&v, 0.005),
            q1: quantile_sorted(&v, 0.25),
            median: quantile_sorted(&v, 0.5),
            q3: quantile_sorted(&v, 0.75),
            whisker_hi: quantile_sorted(&v, 0.995),
            max: *v.last().unwrap(),
            mean,
            n: v.len(),
        }
    }

    /// Fraction of samples strictly above `threshold` (outlier-tail
    /// statements like "less than 0.5% of kernels exceed a 10x slowdown").
    pub fn frac_above(values: &[f64], threshold: f64) -> f64 {
        let n = values.iter().filter(|v| **v > threshold).count();
        n as f64 / values.len().max(1) as f64
    }

    /// One-line rendering for tables/logs.
    pub fn render(&self) -> String {
        format!(
            "n={} min={:.3} p0.5={:.3} q1={:.3} med={:.3} q3={:.3} p99.5={:.3} max={:.3}",
            self.n, self.min, self.whisker_lo, self.q1, self.median, self.q3,
            self.whisker_hi, self.max
        )
    }
}

/// A log2-bucketed latency histogram over nanosecond samples.
///
/// Built for the serving gate's wait/hold accounting: recording is O(1)
/// and allocation-free, so it can sit on the admission hot path, while
/// quantile reads are approximate (bucket upper bound — at most 2x the
/// true value, which is ample for latency reporting across decades).
#[derive(Debug, Clone)]
pub struct Histogram {
    /// buckets[i] counts samples with floor(log2(ns)) == i (bucket 0 also
    /// holds ns == 0); the last bucket is open-ended.
    buckets: [u64; Self::NUM_BUCKETS],
    count: u64,
    sum_ns: u128,
    min_ns: u64,
    max_ns: u64,
}

impl Histogram {
    const NUM_BUCKETS: usize = 64;

    pub fn new() -> Self {
        Self {
            buckets: [0; Self::NUM_BUCKETS],
            count: 0,
            sum_ns: 0,
            min_ns: u64::MAX,
            max_ns: 0,
        }
    }

    fn bucket_of(ns: u64) -> usize {
        if ns == 0 {
            0
        } else {
            (63 - ns.leading_zeros() as usize).min(Self::NUM_BUCKETS - 1)
        }
    }

    pub fn record(&mut self, ns: u64) {
        self.buckets[Self::bucket_of(ns)] += 1;
        self.count += 1;
        self.sum_ns += ns as u128;
        self.min_ns = self.min_ns.min(ns);
        self.max_ns = self.max_ns.max(ns);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn min_ns(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min_ns
        }
    }

    pub fn max_ns(&self) -> u64 {
        self.max_ns
    }

    pub fn mean_ns(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_ns as f64 / self.count as f64
        }
    }

    /// Approximate nearest-rank quantile: the upper bound of the bucket
    /// holding the rank-`ceil(q*n)` sample (exact min/max at q==0/1).
    pub fn quantile_ns(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let q = q.clamp(0.0, 1.0);
        if q <= 0.0 {
            return self.min_ns();
        }
        let rank = ((q * self.count as f64).ceil() as u64).clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= rank {
                // Upper bound of bucket i, clamped to the observed max.
                let ub = if i + 1 >= Self::NUM_BUCKETS {
                    u64::MAX
                } else {
                    (1u64 << (i + 1)) - 1
                };
                return ub.min(self.max_ns);
            }
        }
        self.max_ns
    }

    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_ns += other.sum_ns;
        self.min_ns = self.min_ns.min(other.min_ns);
        self.max_ns = self.max_ns.max(other.max_ns);
    }

    /// One-line rendering in milliseconds (serving reports).
    pub fn render_ms(&self) -> String {
        let ms = |ns: u64| ns as f64 / 1e6;
        format!(
            "n={} mean={:.3} p50≈{:.3} p95≈{:.3} p99≈{:.3} max={:.3} (ms)",
            self.count,
            self.mean_ns() / 1e6,
            ms(self.quantile_ns(0.50)),
            ms(self.quantile_ns(0.95)),
            ms(self.quantile_ns(0.99)),
            ms(self.max_ns),
        )
    }
}

impl Default for Histogram {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantile_basics() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(quantile(&v, 0.0), 1.0);
        assert_eq!(quantile(&v, 0.5), 3.0);
        assert_eq!(quantile(&v, 1.0), 5.0);
        assert_eq!(quantile(&v, 0.25), 2.0);
        // interpolation
        let v2 = [0.0, 10.0];
        assert_eq!(quantile(&v2, 0.5), 5.0);
    }

    #[test]
    fn quantile_unsorted_input() {
        let v = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(quantile(&v, 0.5), 3.0);
    }

    #[test]
    #[should_panic(expected = "empty")]
    fn quantile_empty_panics() {
        quantile(&[], 0.5);
    }

    #[test]
    fn box_stats_ordering_invariant() {
        let v: Vec<f64> = (0..1000).map(|i| (i as f64 * 37.0) % 100.0).collect();
        let b = BoxStats::from(&v);
        assert!(b.min <= b.whisker_lo);
        assert!(b.whisker_lo <= b.q1);
        assert!(b.q1 <= b.median);
        assert!(b.median <= b.q3);
        assert!(b.q3 <= b.whisker_hi);
        assert!(b.whisker_hi <= b.max);
        assert_eq!(b.n, 1000);
    }

    #[test]
    fn whiskers_capture_99_percent() {
        // 1000 ones with 2 extreme outliers: whiskers must exclude them.
        let mut v = vec![1.0; 1000];
        v.push(500.0);
        v.push(0.001);
        let b = BoxStats::from(&v);
        assert_eq!(b.median, 1.0);
        assert!(b.whisker_hi < 500.0);
        assert!(b.max == 500.0);
    }

    #[test]
    fn frac_above() {
        let v = [1.0, 1.0, 1.0, 11.0];
        assert_eq!(BoxStats::frac_above(&v, 10.0), 0.25);
        assert_eq!(BoxStats::frac_above(&v, 100.0), 0.0);
    }

    #[test]
    fn render_contains_median() {
        let b = BoxStats::from(&[1.0, 2.0, 3.0]);
        assert!(b.render().contains("med=2.000"));
    }

    #[test]
    fn histogram_empty_is_zeroed() {
        let h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.count(), 0);
        assert_eq!(h.min_ns(), 0);
        assert_eq!(h.max_ns(), 0);
        assert_eq!(h.mean_ns(), 0.0);
        assert_eq!(h.quantile_ns(0.5), 0);
    }

    #[test]
    fn histogram_basic_accounting() {
        let mut h = Histogram::new();
        for ns in [100u64, 200, 400, 800, 1_600] {
            h.record(ns);
        }
        assert_eq!(h.count(), 5);
        assert_eq!(h.min_ns(), 100);
        assert_eq!(h.max_ns(), 1_600);
        assert_eq!(h.mean_ns(), 620.0);
    }

    #[test]
    fn histogram_quantile_bucket_bounds() {
        let mut h = Histogram::new();
        // 99 samples at ~1us, one outlier at ~1ms.
        for _ in 0..99 {
            h.record(1_000);
        }
        h.record(1_000_000);
        // p50 must land in the 1us bucket (upper bound < 2048ns)...
        assert!(h.quantile_ns(0.5) < 2_048, "p50 = {}", h.quantile_ns(0.5));
        // ...and p100 must see the outlier, clamped to the observed max.
        assert_eq!(h.quantile_ns(1.0), 1_000_000);
        // Approximation bound: never more than 2x the true value.
        assert!(h.quantile_ns(0.5) >= 1_000);
    }

    #[test]
    fn histogram_zero_sample_and_merge() {
        let mut a = Histogram::new();
        a.record(0);
        a.record(10);
        let mut b = Histogram::new();
        b.record(1 << 40);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.min_ns(), 0);
        assert_eq!(a.max_ns(), 1 << 40);
    }

    #[test]
    fn histogram_render_mentions_count() {
        let mut h = Histogram::new();
        h.record(5_000_000);
        assert!(h.render_ms().contains("n=1"));
    }
}
