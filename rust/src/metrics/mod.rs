//! Evaluation metrics (§VI-E): NET (eq. 1), IPS (eq. 2), and the
//! distribution statistics behind the paper's boxplots.

pub mod ips;
pub mod net;
pub mod stats;

pub use ips::{ips, ips_series, ips_with_warmup};
pub use net::{net_all_apps, net_per_kernel};
pub use stats::{nearest_rank, quantile, BoxStats, Histogram, LatencyStats, QuantileSketch};
