//! Native fallback engine: pure-Rust reference execution of the AOT
//! payloads whose math is fully specified by the manifest shapes.
//!
//! The default build carries no PJRT/XLA dependency (the `xla` crate and
//! its `xla_extension` shared library are heavyweight and unavailable in
//! offline environments), yet the serving subsystem still needs real
//! numerics to push through the access-control machinery. This engine
//! executes:
//!
//! * `mmult`  — naive row-major f32 matmul (the cuda_mmult payload);
//! * `vecadd` — `(x + y) * 2` (the runtime smoke payload).
//!
//! `dna` (the CNN) bakes jax-PRNG weights into its HLO artifact and has
//! no manifest-derivable reference, so it reports unsupported here and
//! requires the `pjrt` feature. [`NativeEngine::supports`] lets callers
//! (CLI `validate`, serving) distinguish "unsupported in this build"
//! from failure.

use super::artifact::Manifest;
use anyhow::{anyhow, Context, Result};

/// Manifest-driven pure-Rust executor for reference payloads.
pub struct NativeEngine {
    pub manifest: Manifest,
}

impl NativeEngine {
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        Ok(Self { manifest: Manifest::load(dir)? })
    }

    /// Load from the default artifact directory (`$COOK_ARTIFACTS` or
    /// `./artifacts`).
    pub fn load_default() -> Result<Self> {
        Self::load(Manifest::default_dir())
    }

    pub fn platform(&self) -> String {
        "native-cpu (reference interpreter)".to_string()
    }

    /// Can this build execute `payload`? (`dna` needs the `pjrt` feature.)
    pub fn supports(&self, payload: usize) -> bool {
        self.manifest
            .artifacts
            .get(payload)
            .map(|s| matches!(s.name.as_str(), "mmult" | "vecadd"))
            .unwrap_or(false)
    }

    /// Execute artifact `payload` with flat f32 inputs (row-major order);
    /// returns the flat f32 output.
    pub fn execute(&self, payload: usize, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        let spec = self
            .manifest
            .artifacts
            .get(payload)
            .ok_or_else(|| anyhow!("unknown payload index {payload}"))?;
        if inputs.len() != spec.arg_sizes.len() {
            return Err(anyhow!(
                "{}: expected {} args, got {}",
                spec.name,
                spec.arg_sizes.len(),
                inputs.len()
            ));
        }
        for (i, input) in inputs.iter().enumerate() {
            if input.len() != spec.arg_sizes[i] {
                return Err(anyhow!(
                    "{} arg {i}: expected {} elements, got {}",
                    spec.name,
                    spec.arg_sizes[i],
                    input.len()
                ));
            }
        }
        match spec.name.as_str() {
            "mmult" => {
                let a_shape = &spec.arg_shapes[0];
                let b_shape = &spec.arg_shapes[1];
                if a_shape.len() != 2 || b_shape.len() != 2 || a_shape[1] != b_shape[0] {
                    return Err(anyhow!(
                        "mmult: incompatible shapes {a_shape:?} x {b_shape:?}"
                    ));
                }
                Ok(matmul(&inputs[0], &inputs[1], a_shape[0], a_shape[1], b_shape[1]))
            }
            "vecadd" => Ok(inputs[0]
                .iter()
                .zip(&inputs[1])
                .map(|(x, y)| (x + y) * 2.0)
                .collect()),
            other => Err(anyhow!(
                "payload '{other}' is not supported by the native engine \
                 (build with the `pjrt` feature for full AOT execution)"
            )),
        }
    }

    /// Execute with the manifest's deterministic golden inputs.
    pub fn execute_golden(&self, payload: usize) -> Result<Vec<f32>> {
        let spec = &self.manifest.artifacts[payload];
        self.execute(payload, &spec.golden_inputs())
    }

    /// Validate numerics against the jax-computed golden vectors (only
    /// meaningful for payloads this engine supports).
    pub fn validate_golden(&self, payload: usize) -> Result<()> {
        let spec = &self.manifest.artifacts[payload];
        let out = self.execute_golden(payload)?;
        super::check_golden(spec, &out)
    }

    /// Validate every payload this build can execute (unsupported
    /// payloads are skipped — the `pjrt` build validates them all).
    pub fn validate_all(&self) -> Result<()> {
        for p in 0..self.manifest.artifacts.len() {
            if self.supports(p) {
                self.validate_golden(p)
                    .with_context(|| format!("artifact {}", self.manifest.artifacts[p].name))?;
            }
        }
        Ok(())
    }
}

/// Naive row-major f32 matmul: (m x k) * (k x n) -> (m x n). Accumulates
/// in f32 like the XLA CPU dot, keeping goldens within tolerance.
fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let aik = a[i * k + kk];
            let brow = &b[kk * n..(kk + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += aik * bv;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::super::artifact::ArtifactSpec;
    use super::*;

    fn manifest_with(names: &[&str]) -> Manifest {
        let artifacts = names
            .iter()
            .map(|n| {
                let (arg_shapes, out_shape): (Vec<Vec<usize>>, Vec<usize>) = match *n {
                    "mmult" => (vec![vec![4, 4], vec![4, 4]], vec![4, 4]),
                    "vecadd" => (vec![vec![8], vec![8]], vec![8]),
                    _ => (vec![vec![2]], vec![8]),
                };
                ArtifactSpec {
                    name: n.to_string(),
                    hlo_path: "/nonexistent".into(),
                    arg_sizes: arg_shapes
                        .iter()
                        .map(|s| s.iter().product::<usize>().max(1))
                        .collect(),
                    arg_shapes,
                    out_shape,
                    golden_seed: 42,
                    golden_output_head: vec![],
                    golden_output_sum: f64::NAN,
                }
            })
            .collect();
        Manifest { dir: "/nonexistent".into(), artifacts }
    }

    fn engine() -> NativeEngine {
        NativeEngine { manifest: manifest_with(&["mmult", "dna", "vecadd"]) }
    }

    #[test]
    fn vecadd_exact() {
        let e = engine();
        let out = e.execute(2, &[vec![1.5; 8], vec![-0.5; 8]]).unwrap();
        assert_eq!(out, vec![2.0; 8]);
    }

    #[test]
    fn mmult_identity() {
        let e = engine();
        // A * I == A for a 4x4 identity.
        let a: Vec<f32> = (0..16).map(|i| i as f32 * 0.5 - 3.0).collect();
        let mut ident = vec![0.0f32; 16];
        for i in 0..4 {
            ident[i * 4 + i] = 1.0;
        }
        let out = e.execute(0, &[a.clone(), ident]).unwrap();
        assert_eq!(out, a);
    }

    #[test]
    fn mmult_known_product() {
        // [[1,2],[3,4]] * [[5,6],[7,8]] = [[19,22],[43,50]]
        let out = matmul(&[1.0, 2.0, 3.0, 4.0], &[5.0, 6.0, 7.0, 8.0], 2, 2, 2);
        assert_eq!(out, vec![19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn dna_unsupported_with_clear_error() {
        let e = engine();
        assert!(!e.supports(1));
        assert!(e.supports(0));
        assert!(e.supports(2));
        let err = e.execute(1, &[vec![0.0; 2]]).unwrap_err().to_string();
        assert!(err.contains("pjrt"), "{err}");
    }

    #[test]
    fn arity_and_size_rejected() {
        let e = engine();
        assert!(e.execute(2, &[vec![0.0; 8]]).is_err(), "arity");
        assert!(e.execute(2, &[vec![0.0; 4], vec![0.0; 8]]).is_err(), "size");
        assert!(e.execute(99, &[]).is_err(), "unknown payload");
    }

    #[test]
    fn validate_all_skips_unsupported() {
        // No golden heads in the test manifest, so validation reduces to
        // executing the supported payloads — dna must be skipped, not
        // failed.
        engine().validate_all().unwrap();
    }
}
