//! AOT artifact manifest: what `python/compile/aot.py` produced.
//!
//! The manifest carries, per artifact, the HLO file name, the argument
//! shapes, and golden vectors (deterministic inputs + jax-computed
//! outputs) so the rust runtime can validate numerics with no python
//! anywhere near the request path.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::{Path, PathBuf};

/// Stable indices of the standard artifacts (matches aot.py's ARTIFACTS
/// insertion order; resolved by name at load time, so a reordering in
/// python cannot silently misroute payloads).
pub const PAYLOAD_MMULT: usize = 0;
pub const PAYLOAD_DNA: usize = 1;
pub const PAYLOAD_VECADD: usize = 2;

/// Names in payload-index order.
pub const PAYLOAD_NAMES: [&str; 3] = ["mmult", "dna", "vecadd"];

/// One artifact's metadata.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub hlo_path: PathBuf,
    /// Flattened element counts of each argument.
    pub arg_sizes: Vec<usize>,
    /// Argument shapes (row-major dims).
    pub arg_shapes: Vec<Vec<usize>>,
    pub out_shape: Vec<usize>,
    pub golden_seed: u64,
    pub golden_output_head: Vec<f32>,
    pub golden_output_sum: f64,
}

impl ArtifactSpec {
    /// Regenerate the deterministic golden inputs:
    /// value[i] = ((i + seed + argidx) % 17) * 0.0625 - 0.5
    /// (mirrors `aot.py::_golden_inputs` exactly).
    pub fn golden_inputs(&self) -> Vec<Vec<f32>> {
        self.arg_sizes
            .iter()
            .enumerate()
            .map(|(argidx, &n)| {
                (0..n as u64)
                    .map(|i| ((i + self.golden_seed + argidx as u64) % 17) as f32 * 0.0625 - 0.5)
                    .collect()
            })
            .collect()
    }

    pub fn out_elems(&self) -> usize {
        self.out_shape.iter().product::<usize>().max(1)
    }
}

/// The parsed manifest.
#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: Vec<ArtifactSpec>, // ordered by PAYLOAD_NAMES
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts` first)"))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("{path:?}: {e}"))?;
        let mut artifacts = Vec::new();
        for name in PAYLOAD_NAMES {
            let entry = json
                .get(name)
                .ok_or_else(|| anyhow!("manifest missing artifact '{name}'"))?;
            artifacts.push(Self::parse_entry(&dir, name, entry)?);
        }
        Ok(Self { dir, artifacts })
    }

    fn parse_entry(dir: &Path, name: &str, entry: &Json) -> Result<ArtifactSpec> {
        let hlo = entry
            .get("hlo")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow!("artifact {name}: missing hlo"))?;
        let args = entry
            .get("args")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("artifact {name}: missing args"))?;
        let mut arg_sizes = Vec::new();
        let mut arg_shapes = Vec::new();
        for a in args {
            let shape: Vec<usize> = a
                .get("shape")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow!("artifact {name}: bad arg shape"))?
                .iter()
                .filter_map(Json::as_usize)
                .collect();
            arg_sizes.push(shape.iter().product::<usize>().max(1));
            arg_shapes.push(shape);
        }
        let out_shape: Vec<usize> = entry
            .get("out_shape")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("artifact {name}: missing out_shape"))?
            .iter()
            .filter_map(Json::as_usize)
            .collect();
        let golden_output_head: Vec<f32> = entry
            .get("golden_output_head")
            .and_then(Json::as_arr)
            .unwrap_or(&[])
            .iter()
            .filter_map(|v| v.as_f64().map(|f| f as f32))
            .collect();
        Ok(ArtifactSpec {
            name: name.to_string(),
            hlo_path: dir.join(hlo),
            arg_sizes,
            arg_shapes,
            out_shape,
            golden_seed: entry
                .get("golden_seed")
                .and_then(Json::as_f64)
                .unwrap_or(42.0) as u64,
            golden_output_head,
            golden_output_sum: entry
                .get("golden_output_sum")
                .and_then(Json::as_f64)
                .unwrap_or(f64::NAN),
        })
    }

    /// Default artifact directory: `$COOK_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("COOK_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn golden_inputs_formula() {
        let spec = ArtifactSpec {
            name: "t".into(),
            hlo_path: "/tmp/x".into(),
            arg_sizes: vec![4, 2],
            arg_shapes: vec![vec![4], vec![2]],
            out_shape: vec![4],
            golden_seed: 42,
            golden_output_head: vec![],
            golden_output_sum: 0.0,
        };
        let inputs = spec.golden_inputs();
        // arg 0: ((i + 42) % 17) * 0.0625 - 0.5 for i in 0..4
        assert_eq!(inputs[0][0], ((42u64 % 17) as f32) * 0.0625 - 0.5);
        assert_eq!(inputs[0][1], ((43u64 % 17) as f32) * 0.0625 - 0.5);
        // arg 1 shifts by argidx = 1.
        assert_eq!(inputs[1][0], ((43u64 % 17) as f32) * 0.0625 - 0.5);
        assert_eq!(inputs.len(), 2);
        assert_eq!(inputs[0].len(), 4);
    }

    #[test]
    fn load_real_manifest_when_built() {
        // Integration-style: only runs when `make artifacts` has run.
        let dir = Manifest::default_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.artifacts.len(), 3);
        assert_eq!(m.artifacts[PAYLOAD_MMULT].name, "mmult");
        assert_eq!(m.artifacts[PAYLOAD_DNA].name, "dna");
        assert_eq!(m.artifacts[PAYLOAD_VECADD].name, "vecadd");
        assert!(m.artifacts[PAYLOAD_DNA].hlo_path.exists());
        assert_eq!(m.artifacts[PAYLOAD_VECADD].arg_sizes, vec![8, 8]);
    }

    #[test]
    fn out_elems_product() {
        let spec = ArtifactSpec {
            name: "t".into(),
            hlo_path: "/tmp/x".into(),
            arg_sizes: vec![],
            arg_shapes: vec![],
            out_shape: vec![2, 3, 4],
            golden_seed: 0,
            golden_output_head: vec![],
            golden_output_sum: 0.0,
        };
        assert_eq!(spec.out_elems(), 24);
    }
}
