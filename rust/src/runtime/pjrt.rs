//! PJRT-backed engine (feature `pjrt`): load the AOT artifacts (HLO text
//! produced by the L2/L1 python compile path) and execute them through
//! the `xla` crate (PJRT C API).
//!
//! Python never runs on this path: `make artifacts` compiled the models
//! once; this module loads `artifacts/*.hlo.txt`, compiles them on the
//! CPU client, and executes them with concrete inputs.
//!
//! Enabling the `pjrt` cargo feature requires the `xla` crate (0.1.6)
//! and its `xla_extension` shared library in the build environment; the
//! default build uses [`super::native::NativeEngine`] instead.

use super::artifact::{Manifest, PAYLOAD_NAMES};
use anyhow::{anyhow, Context, Result};

/// A loaded PJRT engine: one compiled executable per artifact.
pub struct PjrtEngine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    executables: Vec<xla::PjRtLoadedExecutable>,
}

impl PjrtEngine {
    /// Load and compile every artifact in the manifest directory.
    pub fn load(dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        let mut executables = Vec::new();
        for spec in &manifest.artifacts {
            // HLO *text* interchange: the text parser reassigns instruction
            // ids, avoiding the 64-bit-id protos jax >= 0.5 would emit.
            let proto = xla::HloModuleProto::from_text_file(
                spec.hlo_path
                    .to_str()
                    .ok_or_else(|| anyhow!("non-utf8 path {:?}", spec.hlo_path))?,
            )
            .map_err(|e| anyhow!("parsing {:?}: {e:?}", spec.hlo_path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", spec.name))?;
            executables.push(exe);
        }
        Ok(Self { manifest, client, executables })
    }

    /// Load from the default artifact directory (`$COOK_ARTIFACTS` or
    /// `./artifacts`).
    pub fn load_default() -> Result<Self> {
        Self::load(Manifest::default_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// PJRT executes every artifact in the manifest.
    pub fn supports(&self, payload: usize) -> bool {
        payload < self.manifest.artifacts.len()
    }

    /// Execute artifact `payload` with flat f32 inputs (row-major order);
    /// returns the flat f32 output.
    pub fn execute(&self, payload: usize, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        let spec = self
            .manifest
            .artifacts
            .get(payload)
            .ok_or_else(|| anyhow!("unknown payload index {payload}"))?;
        if inputs.len() != spec.arg_sizes.len() {
            return Err(anyhow!(
                "{}: expected {} args, got {}",
                spec.name,
                spec.arg_sizes.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (input, shape)) in inputs.iter().zip(&spec.arg_shapes).enumerate() {
            if input.len() != spec.arg_sizes[i] {
                return Err(anyhow!(
                    "{} arg {i}: expected {} elements, got {}",
                    spec.name,
                    spec.arg_sizes[i],
                    input.len()
                ));
            }
            let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
            let lit = xla::Literal::vec1(input)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape arg {i}: {e:?}"))?;
            literals.push(lit);
        }
        let result = self.executables[payload]
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {}: {e:?}", spec.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {}: {e:?}", spec.name))?;
        // aot.py lowers with return_tuple=True -> unwrap the 1-tuple.
        let out = result
            .to_tuple1()
            .map_err(|e| anyhow!("untuple {}: {e:?}", spec.name))?;
        out.to_vec::<f32>()
            .map_err(|e| anyhow!("to_vec {}: {e:?}", spec.name))
    }

    /// Execute with the manifest's deterministic golden inputs.
    pub fn execute_golden(&self, payload: usize) -> Result<Vec<f32>> {
        let spec = &self.manifest.artifacts[payload];
        self.execute(payload, &spec.golden_inputs())
    }

    /// Validate numerics against the jax-computed golden vectors: the
    /// cross-language correctness gate for the whole AOT path.
    pub fn validate_golden(&self, payload: usize) -> Result<()> {
        let spec = &self.manifest.artifacts[payload];
        let out = self.execute_golden(payload)?;
        super::check_golden(spec, &out)
    }

    pub fn validate_all(&self) -> Result<()> {
        for p in 0..self.manifest.artifacts.len() {
            self.validate_golden(p)
                .with_context(|| format!("artifact {}", PAYLOAD_NAMES[p]))?;
        }
        Ok(())
    }
}
