//! Runtime: load the AOT artifacts (HLO text produced by the L2/L1
//! python compile path) and execute them from rust.
//!
//! Two interchangeable engines sit behind the [`Engine`] alias:
//!
//! * **PJRT** (`pjrt` cargo feature): compiles the HLO artifacts through
//!   the `xla` crate's PJRT CPU client — full fidelity, every payload.
//! * **Native** (default): a pure-Rust reference interpreter for the
//!   payloads whose math the manifest fully specifies (`mmult`,
//!   `vecadd`); no external native libraries required. `dna` reports
//!   unsupported (its weights are baked into the HLO artifact).
//!
//! Both expose the same surface (`load`, `execute`, `validate_golden`,
//! `supports`, ...), so the simulator's kernel payloads, the CLI, and
//! the live serving subsystem are engine-agnostic.

pub mod artifact;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use artifact::{
    ArtifactSpec, Manifest, PAYLOAD_DNA, PAYLOAD_MMULT, PAYLOAD_NAMES, PAYLOAD_VECADD,
};
pub use native::NativeEngine;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtEngine;

/// The engine this build executes payloads with.
#[cfg(feature = "pjrt")]
pub type Engine = pjrt::PjrtEngine;
/// The engine this build executes payloads with.
#[cfg(not(feature = "pjrt"))]
pub type Engine = native::NativeEngine;

use anyhow::{anyhow, Result};

/// Shared golden validation: compare an execution's output against the
/// manifest's jax-computed golden vectors (head elements + checksum).
pub(crate) fn check_golden(spec: &ArtifactSpec, out: &[f32]) -> Result<()> {
    if out.len() != spec.out_elems() {
        return Err(anyhow!(
            "{}: output has {} elements, manifest says {}",
            spec.name,
            out.len(),
            spec.out_elems()
        ));
    }
    for (i, (got, want)) in out.iter().zip(&spec.golden_output_head).enumerate() {
        let tol = 1e-3 * want.abs().max(1.0);
        if (got - want).abs() > tol {
            return Err(anyhow!(
                "{}: output[{i}] = {got}, jax golden = {want}",
                spec.name
            ));
        }
    }
    if spec.golden_output_sum.is_finite() {
        let sum: f64 = out.iter().map(|v| *v as f64).sum();
        let tol = 1e-3 * spec.golden_output_sum.abs().max(1.0);
        if (sum - spec.golden_output_sum).abs() > tol {
            return Err(anyhow!(
                "{}: output sum {sum} vs jax golden {}",
                spec.name,
                spec.golden_output_sum
            ));
        }
    }
    Ok(())
}
