//! Chronogram rendering (Figure 11): the trace of kernel executions per
//! benchmark instance, from the beginning of their first executed block to
//! the completion of their last, on a GPU-cycle axis.

use super::record::TraceCollector;
use crate::util::{ns_to_cycles, AppId, Nanos};
use std::fmt::Write as _;

/// One rendered lane (benchmark instance = column in the paper's figure).
#[derive(Debug)]
pub struct Lane {
    pub app: AppId,
    /// (start, end) of each kernel execution, ns.
    pub spans: Vec<(Nanos, Nanos)>,
}

/// Extracted chronogram data.
#[derive(Debug)]
pub struct Chronogram {
    pub lanes: Vec<Lane>,
    pub end_ns: Nanos,
}

impl Chronogram {
    pub fn from_trace(trace: &TraceCollector, num_apps: usize) -> Self {
        let mut lanes = Vec::new();
        let mut end_ns = 0;
        for a in 0..num_apps {
            let mut spans: Vec<(Nanos, Nanos)> = trace
                .kernel_ops(AppId(a))
                .map(|r| (r.started_at, r.completed_at))
                .collect();
            spans.sort_unstable();
            if let Some(&(_, e)) = spans.last() {
                end_ns = end_ns.max(e);
            }
            lanes.push(Lane { app: AppId(a), spans });
        }
        Self { lanes, end_ns }
    }

    /// Total duration in Mcycles (the paper's Fig. 11 axis unit).
    pub fn total_mcycles(&self) -> f64 {
        ns_to_cycles(self.end_ns) as f64 / 1e6
    }

    /// Do any spans of different lanes overlap (isolation check)?
    pub fn has_cross_lane_overlap(&self) -> bool {
        for (i, la) in self.lanes.iter().enumerate() {
            for lb in &self.lanes[i + 1..] {
                for &(s1, e1) in &la.spans {
                    for &(s2, e2) in &lb.spans {
                        if s1 < e2 && s2 < e1 {
                            return true;
                        }
                    }
                }
            }
        }
        false
    }

    /// CSV export: `app,start_cycles,end_cycles` per kernel execution.
    pub fn to_csv(&self) -> String {
        let mut out = String::from("app,start_cycles,end_cycles\n");
        for lane in &self.lanes {
            for &(s, e) in &lane.spans {
                let _ = writeln!(out, "{},{},{}", lane.app.0, ns_to_cycles(s), ns_to_cycles(e));
            }
        }
        out
    }

    /// ASCII rendering: time flows downward (like the paper's figure),
    /// one column per instance, `#` where a kernel executes.
    pub fn render_ascii(&self, rows: usize) -> String {
        let rows = rows.max(1);
        let end = self.end_ns.max(1);
        let mut out = String::new();
        let _ = writeln!(
            out,
            "time v  ({} rows of {:.2} Mcycles each, total {:.1} Mcycles)",
            rows,
            self.total_mcycles() / rows as f64,
            self.total_mcycles()
        );
        let _ = writeln!(
            out,
            "        {}",
            self.lanes
                .iter()
                .map(|l| format!("inst{:<3}", l.app.0))
                .collect::<Vec<_>>()
                .join(" ")
        );
        for r in 0..rows {
            let t0 = end * r as u64 / rows as u64;
            let t1 = end * (r as u64 + 1) / rows as u64;
            let mut line = format!("{:>7} ", r);
            for lane in &self.lanes {
                let busy = lane.spans.iter().any(|&(s, e)| s < t1 && t0 < e);
                line.push_str(if busy { "  ##   " } else { "  ..   " });
            }
            let _ = writeln!(out, "{}", line.trim_end());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::record::OpRecord;
    use crate::util::OpUid;

    fn trace_with(spans: &[(usize, Nanos, Nanos)]) -> TraceCollector {
        let mut t = TraceCollector::new(false);
        let sym = t.intern("k");
        for &(app, s, e) in spans {
            t.ops.push(OpRecord {
                op: OpUid(s),
                app: AppId(app),
                sym: Some(sym),
                is_kernel: true,
                is_copy: false,
                enqueued_at: s,
                started_at: s,
                completed_at: e,
                burst: 0,
            });
        }
        t
    }

    #[test]
    fn extracts_lanes_and_total() {
        let t = trace_with(&[(0, 0, 100), (0, 200, 300), (1, 50, 150)]);
        let c = Chronogram::from_trace(&t, 2);
        assert_eq!(c.lanes[0].spans.len(), 2);
        assert_eq!(c.lanes[1].spans.len(), 1);
        assert_eq!(c.end_ns, 300);
    }

    #[test]
    fn overlap_detection() {
        let no = Chronogram::from_trace(&trace_with(&[(0, 0, 100), (1, 100, 200)]), 2);
        assert!(!no.has_cross_lane_overlap());
        let yes = Chronogram::from_trace(&trace_with(&[(0, 0, 100), (1, 50, 150)]), 2);
        assert!(yes.has_cross_lane_overlap());
    }

    #[test]
    fn csv_has_header_and_rows() {
        let c = Chronogram::from_trace(&trace_with(&[(0, 0, 1000)]), 1);
        let csv = c.to_csv();
        assert!(csv.starts_with("app,start_cycles,end_cycles\n"));
        assert_eq!(csv.lines().count(), 2);
    }

    #[test]
    fn ascii_marks_busy_rows() {
        let c = Chronogram::from_trace(&trace_with(&[(0, 0, 500), (1, 500, 1000)]), 2);
        let art = c.render_ascii(10);
        assert!(art.contains("##"));
        assert!(art.contains("inst0"));
        assert!(art.contains("inst1"));
    }
}
