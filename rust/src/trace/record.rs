//! Trace records collected during a run.
//!
//! Two granularities, matching §VI-B:
//! * application-level (nsys-analogue): one record per GPU operation with
//!   its full lifecycle timestamps;
//! * kernel-level (custom instrumentation): one record per *batch* of
//!   thread blocks placed on an SM, end-to-end.

use crate::util::{AppId, CtxId, Nanos, OpUid, SmId, SymId};

/// Application-level record: the lifecycle of one GPU operation.
/// `Copy`: no owned strings — kernel names are interned once at program
/// build and carried as a [`SymId`] (resolve with
/// [`TraceCollector::sym_name`]).
#[derive(Debug, Clone, Copy)]
pub struct OpRecord {
    pub op: OpUid,
    pub app: AppId,
    /// Interned kernel name (kernel ops only).
    pub sym: Option<SymId>,
    pub is_kernel: bool,
    pub is_copy: bool,
    pub enqueued_at: Nanos,
    pub started_at: Nanos,
    pub completed_at: Nanos,
    pub burst: usize,
}

impl OpRecord {
    /// Device-side execution time (ET in eq. 1).
    pub fn exec_ns(&self) -> Nanos {
        self.completed_at.saturating_sub(self.started_at)
    }

    /// Queueing delay from routine call to execution start.
    pub fn queue_ns(&self) -> Nanos {
        self.started_at.saturating_sub(self.enqueued_at)
    }
}

/// Kernel-level record: one batch of blocks on one SM.
#[derive(Debug, Clone, Copy)]
pub struct BlockRecord {
    pub op: OpUid,
    pub app: AppId,
    pub sm: SmId,
    pub blocks: u32,
    pub start: Nanos,
    pub end: Nanos,
    /// True when the batch was resumed after a context-switch freeze.
    pub resumed: bool,
}

/// Context-switch record.
#[derive(Debug, Clone, Copy)]
pub struct SwitchRecord {
    pub at: Nanos,
    pub from: Option<CtxId>,
    pub to: CtxId,
    pub cost_ns: Nanos,
}

/// Software-stack stall record (shared-queue collision).
#[derive(Debug, Clone, Copy)]
pub struct StallRecord {
    pub op: OpUid,
    pub at: Nanos,
    pub duration_ns: Nanos,
}

/// Everything collected during one simulated run.
#[derive(Debug, Default)]
pub struct TraceCollector {
    pub ops: Vec<OpRecord>,
    pub blocks: Vec<BlockRecord>,
    pub switches: Vec<SwitchRecord>,
    pub stalls: Vec<StallRecord>,
    /// Collect block-level records? (kernel-level instrumentation on/off —
    /// nsys-level op records are always on.)
    pub block_level: bool,
    /// Interned kernel-name table (`SymId` -> name). Filled once when the
    /// run's programs are compiled; the distinct-name count is small, so
    /// interning is a linear scan with no hashing.
    names: Vec<String>,
}

impl TraceCollector {
    pub fn new(block_level: bool) -> Self {
        Self { block_level, ..Default::default() }
    }

    /// Intern `name`, returning its dense symbol id. Called at program
    /// build time only — never on the per-event hot path.
    pub fn intern(&mut self, name: &str) -> SymId {
        if let Some(i) = self.names.iter().position(|n| n == name) {
            return SymId(i as u32);
        }
        self.names.push(name.to_string());
        SymId((self.names.len() - 1) as u32)
    }

    /// Number of distinct interned kernel names.
    pub fn num_syms(&self) -> usize {
        self.names.len()
    }

    /// Merge `other`'s interned name table into this collector, returning
    /// the remap table indexed by `other`'s `SymId`s: entry `i` is the id
    /// the name `other` knows as `SymId(i)` carries here. Names already
    /// present keep their id (intern dedupes), so merging a shard trace
    /// whose programs were compiled against a different collector costs
    /// one table walk, never a rename of existing records.
    pub fn merge_syms(&mut self, other: &TraceCollector) -> Vec<SymId> {
        other.names.iter().map(|n| self.intern(n)).collect()
    }

    /// Resolve a record's symbol back to the kernel name ("?" when the
    /// op carries no symbol or the id is unknown to this collector).
    pub fn sym_name(&self, sym: Option<SymId>) -> &str {
        sym.and_then(|s| self.names.get(s.0 as usize))
            .map(String::as_str)
            .unwrap_or("?")
    }

    /// Pre-size the op-record vector (called once by `Sim::new` from the
    /// programs' op counts so steady-state pushes never reallocate).
    pub fn reserve_ops(&mut self, n: usize) {
        self.ops.reserve(n);
    }

    /// Kernel op records for one app, in completion order.
    pub fn kernel_ops(&self, app: AppId) -> impl Iterator<Item = &OpRecord> {
        self.ops.iter().filter(move |r| r.app == app && r.is_kernel)
    }

    /// All execution times of kernels of `app` (NET numerator inputs).
    pub fn kernel_exec_times(&self, app: AppId) -> Vec<Nanos> {
        self.kernel_ops(app).map(|r| r.exec_ns()).collect()
    }

    /// Overlap check used by the isolation property tests (§VII-B): do any
    /// two *kernel* executions from different apps overlap in time?
    pub fn cross_app_kernel_overlaps(&self) -> usize {
        self.count_overlaps(|_| true)
    }

    /// Cross-app kernel overlaps restricted to a subset of apps — the
    /// per-shard isolation check of a fleet run: a gated strategy must
    /// show zero overlaps *among the apps sharing one GPU*, while apps on
    /// different shards are free to overlap.
    pub fn cross_app_kernel_overlaps_among(&self, apps: &[AppId]) -> usize {
        self.count_overlaps(|a| apps.contains(&a))
    }

    fn count_overlaps(&self, in_group: impl Fn(AppId) -> bool) -> usize {
        let mut kernels: Vec<&OpRecord> = self
            .ops
            .iter()
            .filter(|r| r.is_kernel && in_group(r.app))
            .collect();
        kernels.sort_by_key(|r| r.started_at);
        let mut overlaps = 0;
        for i in 0..kernels.len() {
            for j in (i + 1)..kernels.len() {
                let (a, b) = (kernels[i], kernels[j]);
                if b.started_at >= a.completed_at {
                    break; // sorted: no later kernel can overlap a
                }
                if a.app != b.app {
                    overlaps += 1;
                }
            }
        }
        overlaps
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(app: usize, start: Nanos, end: Nanos) -> OpRecord {
        OpRecord {
            op: OpUid(start),
            app: AppId(app),
            sym: Some(SymId(0)),
            is_kernel: true,
            is_copy: false,
            enqueued_at: start.saturating_sub(10),
            started_at: start,
            completed_at: end,
            burst: 0,
        }
    }

    #[test]
    fn exec_and_queue_times() {
        let r = rec(0, 100, 180);
        assert_eq!(r.exec_ns(), 80);
        assert_eq!(r.queue_ns(), 10);
    }

    #[test]
    fn overlap_detection_cross_app() {
        let mut t = TraceCollector::new(false);
        t.ops.push(rec(0, 0, 100));
        t.ops.push(rec(1, 50, 150)); // overlaps app0
        t.ops.push(rec(0, 200, 300));
        t.ops.push(rec(1, 300, 400)); // touches but does not overlap
        assert_eq!(t.cross_app_kernel_overlaps(), 1);
    }

    #[test]
    fn overlap_same_app_not_counted() {
        let mut t = TraceCollector::new(false);
        t.ops.push(rec(0, 0, 100));
        t.ops.push(rec(0, 50, 150));
        assert_eq!(t.cross_app_kernel_overlaps(), 0);
    }

    #[test]
    fn overlap_among_subset_ignores_other_apps() {
        // Apps 0/1 overlap, apps 2/3 overlap; the per-shard view sees
        // only its own pair.
        let mut t = TraceCollector::new(false);
        t.ops.push(rec(0, 0, 100));
        t.ops.push(rec(1, 50, 150));
        t.ops.push(rec(2, 60, 160));
        t.ops.push(rec(3, 70, 170));
        assert_eq!(t.cross_app_kernel_overlaps_among(&[AppId(0), AppId(1)]), 1);
        assert_eq!(t.cross_app_kernel_overlaps_among(&[AppId(2), AppId(3)]), 1);
        assert_eq!(t.cross_app_kernel_overlaps_among(&[AppId(0)]), 0);
        assert_eq!(t.cross_app_kernel_overlaps_among(&[]), 0);
        // The unrestricted count sees every cross pair.
        assert!(t.cross_app_kernel_overlaps() > 2);
    }

    #[test]
    fn kernel_exec_times_filters_by_app() {
        let mut t = TraceCollector::new(false);
        t.ops.push(rec(0, 0, 10));
        t.ops.push(rec(1, 0, 20));
        t.ops.push(rec(0, 30, 70));
        assert_eq!(t.kernel_exec_times(AppId(0)), vec![10, 40]);
    }

    #[test]
    fn merge_syms_remaps_and_dedupes() {
        let mut a = TraceCollector::new(false);
        let conv = a.intern("conv0");
        let _dense = a.intern("dense");
        let mut b = TraceCollector::new(false);
        let b_relu = b.intern("relu"); // new to a
        let b_conv = b.intern("conv0"); // already in a, different id
        let remap = a.merge_syms(&b);
        assert_eq!(remap.len(), 2);
        assert_eq!(remap[b_conv.0 as usize], conv, "shared name keeps a's id");
        assert_eq!(a.sym_name(Some(remap[b_relu.0 as usize])), "relu");
        assert_eq!(a.num_syms(), 3);
        // Idempotent: merging again adds nothing.
        let again = a.merge_syms(&b);
        assert_eq!(again, remap);
        assert_eq!(a.num_syms(), 3);
    }

    #[test]
    fn intern_dedupes_and_resolves() {
        let mut t = TraceCollector::new(false);
        let a = t.intern("conv0");
        let b = t.intern("dense");
        let a2 = t.intern("conv0");
        assert_eq!(a, a2);
        assert_ne!(a, b);
        assert_eq!(t.num_syms(), 2);
        assert_eq!(t.sym_name(Some(a)), "conv0");
        assert_eq!(t.sym_name(Some(b)), "dense");
        assert_eq!(t.sym_name(None), "?");
        assert_eq!(t.sym_name(Some(SymId(99))), "?");
    }
}
