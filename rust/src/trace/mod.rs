//! Instrumentation: trace records (nsys-analogue + kernel-level, §VI-B)
//! and the chronogram renderer (Fig. 11).

pub mod chronogram;
pub mod record;

pub use chronogram::Chronogram;
pub use record::{BlockRecord, OpRecord, StallRecord, SwitchRecord, TraceCollector};
