//! Simulated CUDA Runtime substrate.
//!
//! The paper hooks `libcudart.so`; we cannot link the proprietary library,
//! so this module *is* our `libcudart`: the same API surface (symbol table
//! with C signatures, consumed by the COOK generator in `hooks/`), FIFO
//! streams, per-process GPU contexts, events, host-func callbacks and the
//! undocumented kernel-registration channel the worker strategy intercepts.

pub mod context;
pub mod error;
pub mod op;
pub mod registry;
pub mod stream;
pub mod symbols;

pub use context::GpuContext;
pub use error::CudaError;
pub use op::{CopyDesc, CopyDir, Grid, KernelDesc, KernelInstance, LockAction, Op, OpKind, OpState};
pub use registry::{KernelRegistry, RegisteredKernel};
pub use stream::Stream;
pub use symbols::{Symbol, SymbolCategory, SymbolTable};
