//! CUDA streams: per-context FIFO queues of GPU operations (§II-B).
//!
//! A stream guarantees First-In First-Out *completion* of its operations;
//! it guarantees nothing about ordering or isolation relative to other
//! streams — that gap is exactly what the COOK strategies close.
//!
//! Host-func (callback) operations have weaker dispatch semantics than the
//! documentation suggests: the driver may push a bounded amount of work to
//! the hardware queue past a still-pending callback (`hw_prefetch_depth`).
//! This reproduces the paper's measurement that the callback strategy
//! fails to isolate GPU operations (§VII-B): a kernel prefetched past a
//! blocked acquire-callback reaches the GPU without holding the lock.

use crate::util::OpUid;
use std::collections::VecDeque;

/// One FIFO stream. Op payloads live in the sim's op table; the stream
/// tracks ordering and the in-flight window.
#[derive(Debug, Default, Clone)]
pub struct Stream {
    queue: VecDeque<OpUid>,
    /// Ops handed to the device, not yet retired. Multiple entries occur
    /// only when callbacks are pending and work was prefetched past them.
    in_flight: Vec<OpUid>,
}

impl Stream {
    pub fn new() -> Self {
        Self::default()
    }

    /// `insert op ... in stream` (Algorithms 1-2).
    pub fn push(&mut self, op: OpUid) {
        self.queue.push_back(op);
    }

    /// The op at the stream head (next in FIFO order), if any.
    pub fn head(&self) -> Option<OpUid> {
        self.queue.front().copied()
    }

    /// Strict-FIFO dispatch: the head, only when nothing is in flight.
    pub fn dispatchable(&self) -> Option<OpUid> {
        if self.in_flight.is_empty() {
            self.head()
        } else {
            None
        }
    }

    /// Hand the head to the device under strict FIFO (panics otherwise).
    pub fn begin(&mut self, op: OpUid) {
        assert_eq!(self.dispatchable(), Some(op), "stream FIFO violation");
        self.queue.pop_front();
        self.in_flight.push(op);
    }

    /// Hand the head to the device *past* pending in-flight callbacks
    /// (the prefetch path). The engine enforces the depth policy; the
    /// stream only checks that `op` is the true head.
    pub fn begin_past(&mut self, op: OpUid) {
        assert_eq!(self.head(), Some(op), "begin_past on non-head op");
        self.queue.pop_front();
        self.in_flight.push(op);
    }

    /// Retire an in-flight op (any position — callbacks may complete out
    /// of order relative to prefetched kernels).
    pub fn retire(&mut self, op: OpUid) {
        let pos = self
            .in_flight
            .iter()
            .position(|o| *o == op)
            .expect("retiring op that is not in flight");
        self.in_flight.remove(pos);
    }

    /// Ops queued behind the head (not counting in-flight).
    pub fn depth(&self) -> usize {
        self.queue.len()
    }

    /// True when nothing is queued nor in flight — the condition a
    /// stream-synchronise waits for.
    pub fn idle(&self) -> bool {
        self.queue.is_empty() && self.in_flight.is_empty()
    }

    /// All in-flight ops (dispatch-policy input).
    pub fn in_flight_all(&self) -> &[OpUid] {
        &self.in_flight
    }

    /// Iterate queued ops in FIFO order (trace/debug).
    pub fn iter(&self) -> impl Iterator<Item = OpUid> + '_ {
        self.queue.iter().copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_is_preserved() {
        let mut s = Stream::new();
        for i in 0..5 {
            s.push(OpUid(i));
        }
        for i in 0..5 {
            assert_eq!(s.dispatchable(), Some(OpUid(i)));
            s.begin(OpUid(i));
            // Next op must not be strictly dispatchable while i flies.
            assert_eq!(s.dispatchable(), None);
            s.retire(OpUid(i));
        }
        assert!(s.idle());
    }

    #[test]
    #[should_panic(expected = "stream FIFO violation")]
    fn out_of_order_begin_panics() {
        let mut s = Stream::new();
        s.push(OpUid(1));
        s.push(OpUid(2));
        s.begin(OpUid(2));
    }

    #[test]
    #[should_panic(expected = "not in flight")]
    fn retire_wrong_op_panics() {
        let mut s = Stream::new();
        s.push(OpUid(1));
        s.begin(OpUid(1));
        s.retire(OpUid(7));
    }

    #[test]
    fn prefetch_past_pending_callback() {
        let mut s = Stream::new();
        s.push(OpUid(1)); // callback
        s.push(OpUid(2)); // kernel
        s.begin(OpUid(1));
        assert_eq!(s.dispatchable(), None);
        assert_eq!(s.head(), Some(OpUid(2)));
        s.begin_past(OpUid(2));
        assert_eq!(s.in_flight_all(), &[OpUid(1), OpUid(2)]);
        // Out-of-order retirement: the kernel finishes first.
        s.retire(OpUid(2));
        assert_eq!(s.in_flight_all(), &[OpUid(1)]);
        s.retire(OpUid(1));
        assert!(s.idle());
    }

    #[test]
    #[should_panic(expected = "non-head")]
    fn begin_past_requires_head() {
        let mut s = Stream::new();
        s.push(OpUid(1));
        s.push(OpUid(2));
        s.begin_past(OpUid(2));
    }

    #[test]
    fn idle_and_depth() {
        let mut s = Stream::new();
        assert!(s.idle());
        s.push(OpUid(1));
        s.push(OpUid(2));
        assert_eq!(s.depth(), 2);
        assert!(!s.idle());
        s.begin(OpUid(1));
        assert_eq!(s.depth(), 1);
        assert!(!s.idle()); // in-flight keeps it busy
    }
}
