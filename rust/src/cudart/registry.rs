//! Kernel registration: the `__cudaRegisterFunction` analogue (§V-B3).
//!
//! The worker strategy must deep-copy kernel argument lists because the
//! caller's stack frame may be gone by the time the worker replays the
//! launch. The paper builds a per-application list of known kernels —
//! parameter count, sizes, and argument-list layout — by intercepting the
//! undocumented registration primitives; this registry is that list.

use std::collections::HashMap;

/// Layout of one registered kernel's argument list.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct RegisteredKernel {
    pub name: String,
    /// Size in bytes of each parameter, in declaration order.
    pub param_sizes: Vec<usize>,
    /// Alignment of each parameter (argument-list layout reconstruction).
    pub param_aligns: Vec<usize>,
}

impl RegisteredKernel {
    pub fn new(name: impl Into<String>, param_sizes: Vec<usize>) -> Self {
        let param_aligns = param_sizes
            .iter()
            .map(|s| s.next_power_of_two().clamp(1, 16))
            .collect();
        Self { name: name.into(), param_sizes, param_aligns }
    }

    pub fn num_params(&self) -> usize {
        self.param_sizes.len()
    }

    /// Bytes the worker must copy to capture one launch's arguments,
    /// honouring each parameter's alignment within the marshalled buffer.
    pub fn args_copy_bytes(&self) -> usize {
        let mut off = 0usize;
        for (sz, al) in self.param_sizes.iter().zip(&self.param_aligns) {
            off = off.next_multiple_of(*al.max(&1));
            off += sz;
        }
        off
    }
}

/// Per-application table of registered kernels.
#[derive(Debug, Default)]
pub struct KernelRegistry {
    by_name: HashMap<String, RegisteredKernel>,
}

impl KernelRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// `__cudaRegisterFunction`: record a kernel's argument layout.
    /// Re-registration (dlopen of the same module) overwrites in place.
    pub fn register(&mut self, kernel: RegisteredKernel) {
        self.by_name.insert(kernel.name.clone(), kernel);
    }

    pub fn lookup(&self, name: &str) -> Option<&RegisteredKernel> {
        self.by_name.get(name)
    }

    pub fn len(&self) -> usize {
        self.by_name.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_name.is_empty()
    }

    /// Cost (bytes) of deep-copying a launch of `name`; `None` when the
    /// kernel is unknown — the condition the paper flags as breaking the
    /// worker strategy (Aspect 3 caveat in §V-B3).
    pub fn copy_cost(&self, name: &str) -> Option<usize> {
        self.lookup(name).map(|k| k.args_copy_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_and_lookup() {
        let mut r = KernelRegistry::new();
        r.register(RegisteredKernel::new("matmul", vec![8, 8, 8, 4]));
        assert_eq!(r.len(), 1);
        let k = r.lookup("matmul").unwrap();
        assert_eq!(k.num_params(), 4);
        assert!(r.lookup("missing").is_none());
    }

    #[test]
    fn args_copy_accounts_for_alignment() {
        // 1-byte param then 8-byte param: pad to offset 8, total 16.
        let k = RegisteredKernel::new("k", vec![1, 8]);
        assert_eq!(k.args_copy_bytes(), 16);
        // Pointers only: tight packing.
        let k2 = RegisteredKernel::new("k2", vec![8, 8, 8]);
        assert_eq!(k2.args_copy_bytes(), 24);
        // Empty arg list is legal (kernels taking no parameters).
        let k3 = RegisteredKernel::new("k3", vec![]);
        assert_eq!(k3.args_copy_bytes(), 0);
    }

    #[test]
    fn reregistration_overwrites() {
        let mut r = KernelRegistry::new();
        r.register(RegisteredKernel::new("k", vec![4]));
        r.register(RegisteredKernel::new("k", vec![4, 4]));
        assert_eq!(r.len(), 1);
        assert_eq!(r.lookup("k").unwrap().num_params(), 2);
    }

    #[test]
    fn copy_cost_unknown_kernel_is_none() {
        let r = KernelRegistry::new();
        assert_eq!(r.copy_cost("ghost"), None);
    }
}
