//! GPU contexts: one per OS process by default (§IV-A), owning streams
//! and a small pool of driver callback threads.

use super::stream::Stream;
use crate::util::{CtxId, OpUid, StreamId};

/// Per-context callback-thread slot state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CallbackSlot {
    Idle,
    /// Executing (or blocked inside) the host function of this op.
    Busy(OpUid),
}

/// A GPU context: streams + callback pool + pending host-func work.
#[derive(Debug)]
pub struct GpuContext {
    pub id: CtxId,
    streams: Vec<Stream>,
    /// Driver callback threads; `cudaLaunchHostFunc` bodies run here.
    pub callback_slots: Vec<CallbackSlot>,
    /// Host funcs whose stream position retired but no slot was free yet.
    pub callback_backlog: Vec<OpUid>,
}

impl GpuContext {
    pub fn new(id: CtxId, callback_threads: usize) -> Self {
        Self {
            id,
            streams: vec![Stream::new()], // default stream 0
            callback_slots: vec![CallbackSlot::Idle; callback_threads.max(1)],
            callback_backlog: Vec::new(),
        }
    }

    /// Create an additional stream (e.g. the worker strategy's private
    /// `worker_queue` stream) and return its id.
    pub fn create_stream(&mut self) -> StreamId {
        self.streams.push(Stream::new());
        StreamId { ctx: self.id, idx: self.streams.len() - 1 }
    }

    pub fn default_stream(&self) -> StreamId {
        StreamId { ctx: self.id, idx: 0 }
    }

    pub fn stream(&self, id: StreamId) -> &Stream {
        assert_eq!(id.ctx, self.id);
        &self.streams[id.idx]
    }

    pub fn stream_mut(&mut self, id: StreamId) -> &mut Stream {
        assert_eq!(id.ctx, self.id);
        &mut self.streams[id.idx]
    }

    pub fn streams(&self) -> impl Iterator<Item = (StreamId, &Stream)> {
        self.streams
            .iter()
            .enumerate()
            .map(move |(idx, s)| (StreamId { ctx: self.id, idx }, s))
    }

    pub fn num_streams(&self) -> usize {
        self.streams.len()
    }

    /// All streams idle and no callback work pending: the condition a
    /// device-synchronise waits for (together with no in-flight copies).
    pub fn quiescent(&self) -> bool {
        self.streams.iter().all(|s| s.idle())
            && self.callback_backlog.is_empty()
            && self.callback_slots.iter().all(|s| *s == CallbackSlot::Idle)
    }

    /// Claim a free callback slot for `op`; returns the slot index.
    pub fn claim_callback_slot(&mut self, op: OpUid) -> Option<usize> {
        for (i, slot) in self.callback_slots.iter_mut().enumerate() {
            if *slot == CallbackSlot::Idle {
                *slot = CallbackSlot::Busy(op);
                return Some(i);
            }
        }
        None
    }

    pub fn release_callback_slot(&mut self, slot: usize) {
        assert!(
            matches!(self.callback_slots[slot], CallbackSlot::Busy(_)),
            "releasing idle callback slot"
        );
        self.callback_slots[slot] = CallbackSlot::Idle;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> GpuContext {
        GpuContext::new(CtxId(0), 2)
    }

    #[test]
    fn default_stream_exists() {
        let c = ctx();
        assert_eq!(c.default_stream().idx, 0);
        assert_eq!(c.num_streams(), 1);
        assert!(c.quiescent());
    }

    #[test]
    fn create_stream_returns_fresh_ids() {
        let mut c = ctx();
        let s1 = c.create_stream();
        let s2 = c.create_stream();
        assert_eq!(s1.idx, 1);
        assert_eq!(s2.idx, 2);
        assert_eq!(c.num_streams(), 3);
    }

    #[test]
    fn quiescent_tracks_streams_and_callbacks() {
        let mut c = ctx();
        c.stream_mut(c.default_stream()).push(OpUid(1));
        assert!(!c.quiescent());
        let s = c.default_stream();
        c.stream_mut(s).begin(OpUid(1));
        c.stream_mut(s).retire(OpUid(1));
        assert!(c.quiescent());
        let slot = c.claim_callback_slot(OpUid(2)).unwrap();
        assert!(!c.quiescent());
        c.release_callback_slot(slot);
        assert!(c.quiescent());
    }

    #[test]
    fn callback_pool_exhausts() {
        let mut c = ctx();
        assert!(c.claim_callback_slot(OpUid(1)).is_some());
        assert!(c.claim_callback_slot(OpUid(2)).is_some());
        assert!(c.claim_callback_slot(OpUid(3)).is_none());
    }

    #[test]
    #[should_panic(expected = "releasing idle")]
    fn double_release_panics() {
        let mut c = ctx();
        let slot = c.claim_callback_slot(OpUid(1)).unwrap();
        c.release_callback_slot(slot);
        c.release_callback_slot(slot);
    }
}
