//! GPU operations: kernels, copies, host-func callbacks (§II-A).
//!
//! Two kernel representations exist on purpose:
//! * [`KernelDesc`] is the *authoring* form (owned name string, builder
//!   methods) used by programs and workload generators;
//! * [`KernelInstance`] is the *execution* form the simulator's op slab
//!   carries: the name is interned to a dense [`SymId`] when the program
//!   is compiled for a run, so the per-event hot path never touches a
//!   heap-allocated string and `Op` stays `Copy`.

use crate::util::{AppId, CtxId, Nanos, OpUid, StreamId, SymId};

/// Kernel launch grid: number of thread blocks and their (uniform) shape.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Grid {
    pub blocks: u32,
    pub threads_per_block: u32,
}

impl Grid {
    pub fn new(blocks: u32, threads_per_block: u32) -> Self {
        Self { blocks, threads_per_block }
    }

    /// Total threads invoked by the call (the kernel "size", §II-B).
    pub fn total_threads(&self) -> u64 {
        self.blocks as u64 * self.threads_per_block as u64
    }

    pub fn warps_per_block(&self, warp_size: u32) -> u32 {
        self.threads_per_block.div_ceil(warp_size.max(1))
    }
}

/// A kernel operation: a function executed on the GPU following a grid.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelDesc {
    /// Registered kernel name (resolved via `registry::KernelRegistry`).
    pub name: String,
    pub grid: Grid,
    /// Warm-cache execution time of one block with the SM to itself.
    pub block_cost_ns: Nanos,
    /// Working-set footprint in the shared L2, bytes (cache model input).
    pub l2_footprint_bytes: u64,
    /// Index of the AOT artifact computing this kernel's payload, if the
    /// run executes real numerics through the PJRT runtime.
    pub payload: Option<usize>,
}

impl KernelDesc {
    pub fn compute(name: impl Into<String>, grid: Grid, block_cost_ns: Nanos) -> Self {
        Self {
            name: name.into(),
            grid,
            block_cost_ns,
            l2_footprint_bytes: 0,
            payload: None,
        }
    }

    pub fn with_l2_footprint(mut self, bytes: u64) -> Self {
        self.l2_footprint_bytes = bytes;
        self
    }

    pub fn with_payload(mut self, artifact: usize) -> Self {
        self.payload = Some(artifact);
        self
    }

    /// Compile-time lowering: resolve this descriptor into the `Copy`
    /// execution form the simulator carries, with the name replaced by
    /// its interned symbol id.
    pub fn instance(&self, sym: SymId) -> KernelInstance {
        KernelInstance {
            sym,
            grid: self.grid,
            block_cost_ns: self.block_cost_ns,
            l2_footprint_bytes: self.l2_footprint_bytes,
            payload: self.payload,
            // Worker-strategy deep-copy model: 8 bytes per pointer-ish
            // param, param count derived from the registered name.
            args_bytes: 8 * (2 + self.name.len() as u64 % 6),
        }
    }
}

/// Execution form of a kernel launch: everything the simulator needs,
/// all `Copy`, no heap payload. Built once per program step at compile
/// time (`Program::compile`), not per launch on the hot path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelInstance {
    /// Interned kernel name (resolve via `TraceCollector::sym_name`).
    pub sym: SymId,
    pub grid: Grid,
    /// Warm-cache execution time of one block with the SM to itself.
    pub block_cost_ns: Nanos,
    /// Working-set footprint in the shared L2, bytes (cache model input).
    pub l2_footprint_bytes: u64,
    /// Index of the AOT artifact computing this kernel's payload, if any.
    pub payload: Option<usize>,
    /// Bytes the deferred worker deep-copies for this launch's args.
    pub args_bytes: u64,
}

/// Direction of a copy operation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CopyDir {
    HostToDevice,
    DeviceToHost,
    DeviceToDevice,
}

/// A copy operation moving data between host and GPU memory space.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CopyDesc {
    pub bytes: u64,
    pub dir: CopyDir,
}

/// Everything a stream can carry.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OpKind {
    Kernel(KernelInstance),
    Copy(CopyDesc),
    /// `cudaLaunchHostFunc`: run a host function in stream order. The
    /// `lock_action` distinguishes the COOK acquire/release callbacks from
    /// application host funcs (which just burn CPU time).
    HostFunc { exec_ns: Nanos, lock_action: LockAction },
    /// `cudaEventRecord`-style marker (completes instantly on the device,
    /// used by the worker strategy's ordered-op template, Alg. 7).
    Marker,
}

/// What a host-func callback does to the global GPU lock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LockAction {
    None,
    Acquire,
    Release,
}

/// Lifecycle of an operation inside the simulated stack.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpState {
    /// Inserted in a stream, not yet at the head.
    Queued,
    /// At the stream head, waiting for the device front-end.
    AtHead,
    /// Executing (blocks on SMs / bytes on the copy engine / callback).
    Running,
    Complete,
}

/// One operation instance flowing through the stack. `Copy`: the op
/// slab hands out cheap by-value reads on the hot path.
#[derive(Debug, Clone, Copy)]
pub struct Op {
    pub uid: OpUid,
    pub app: AppId,
    pub ctx: CtxId,
    pub stream: StreamId,
    pub kind: OpKind,
    pub state: OpState,
    /// Virtual time the host routine inserted the op.
    pub enqueued_at: Nanos,
    /// Virtual time execution began on the device (kernel: first block).
    pub started_at: Option<Nanos>,
    /// Virtual time execution completed (kernel: last block).
    pub completed_at: Option<Nanos>,
    /// Burst index within the application (Aspect 6 bookkeeping).
    pub burst: usize,
}

impl Op {
    pub fn is_kernel(&self) -> bool {
        matches!(self.kind, OpKind::Kernel(_))
    }

    pub fn is_copy(&self) -> bool {
        matches!(self.kind, OpKind::Copy(_))
    }

    /// End-to-end device execution time, once complete (ET in eq. 1).
    pub fn exec_time_ns(&self) -> Option<Nanos> {
        match (self.started_at, self.completed_at) {
            (Some(s), Some(c)) => Some(c.saturating_sub(s)),
            _ => None,
        }
    }

    pub fn kernel(&self) -> Option<&KernelInstance> {
        match &self.kind {
            OpKind::Kernel(k) => Some(k),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ids::*;

    fn mk_op(kind: OpKind) -> Op {
        Op {
            uid: OpUid(1),
            app: AppId(0),
            ctx: CtxId(0),
            stream: StreamId { ctx: CtxId(0), idx: 0 },
            kind,
            state: OpState::Queued,
            enqueued_at: 0,
            started_at: None,
            completed_at: None,
            burst: 0,
        }
    }

    #[test]
    fn grid_arithmetic() {
        let g = Grid::new(64, 1024);
        assert_eq!(g.total_threads(), 65_536);
        assert_eq!(g.warps_per_block(32), 32);
        // Non-multiple rounds up to whole warps.
        assert_eq!(Grid::new(1, 33).warps_per_block(32), 2);
    }

    #[test]
    fn exec_time_requires_both_stamps() {
        let mut op = mk_op(OpKind::Marker);
        assert_eq!(op.exec_time_ns(), None);
        op.started_at = Some(100);
        assert_eq!(op.exec_time_ns(), None);
        op.completed_at = Some(350);
        assert_eq!(op.exec_time_ns(), Some(250));
    }

    #[test]
    fn kind_predicates() {
        let k = mk_op(OpKind::Kernel(
            KernelDesc::compute("k", Grid::new(1, 32), 1000).instance(SymId(7)),
        ));
        assert!(k.is_kernel() && !k.is_copy());
        assert_eq!(k.kernel().unwrap().sym, SymId(7));
        let c = mk_op(OpKind::Copy(CopyDesc { bytes: 4, dir: CopyDir::HostToDevice }));
        assert!(c.is_copy() && c.kernel().is_none());
    }

    #[test]
    fn kernel_desc_builders() {
        let k = KernelDesc::compute("mm", Grid::new(4, 256), 10_000)
            .with_l2_footprint(1 << 20)
            .with_payload(2);
        assert_eq!(k.l2_footprint_bytes, 1 << 20);
        assert_eq!(k.payload, Some(2));
    }

    #[test]
    fn instance_preserves_fields_and_args_model() {
        let d = KernelDesc::compute("mm", Grid::new(4, 256), 10_000)
            .with_l2_footprint(1 << 20)
            .with_payload(2);
        let i = d.instance(SymId(3));
        assert_eq!(i.sym, SymId(3));
        assert_eq!(i.grid, d.grid);
        assert_eq!(i.block_cost_ns, d.block_cost_ns);
        assert_eq!(i.l2_footprint_bytes, d.l2_footprint_bytes);
        assert_eq!(i.payload, d.payload);
        // The worker deep-copy model: 8 * (2 + len("mm") % 6) = 32.
        assert_eq!(i.args_bytes, 32);
    }
}
