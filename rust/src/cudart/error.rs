//! CUDA-runtime-style error codes surfaced by the simulated API.

use std::fmt;

/// Subset of `cudaError_t` the simulated runtime can return, plus the
/// COOK-specific `UnhookedSymbol` raised by error trampolines (§VII-D: the
//  tool is configured to fail on calls to unmanaged CUDA methods).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CudaError {
    Success,
    InvalidValue,
    InvalidConfiguration,
    InvalidResourceHandle,
    NotReady,
    LaunchFailure,
    /// A call reached a default error trampoline: the symbol has no hook
    /// and no explicit exclusion rule in the COOK configuration.
    UnhookedSymbol,
}

impl CudaError {
    pub fn is_success(&self) -> bool {
        matches!(self, CudaError::Success)
    }

    /// The numeric code an application would observe.
    pub fn code(&self) -> i32 {
        match self {
            CudaError::Success => 0,
            CudaError::InvalidValue => 1,
            CudaError::InvalidConfiguration => 9,
            CudaError::InvalidResourceHandle => 400,
            CudaError::NotReady => 600,
            CudaError::LaunchFailure => 719,
            CudaError::UnhookedSymbol => 9001,
        }
    }
}

impl fmt::Display for CudaError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let name = match self {
            CudaError::Success => "cudaSuccess",
            CudaError::InvalidValue => "cudaErrorInvalidValue",
            CudaError::InvalidConfiguration => "cudaErrorInvalidConfiguration",
            CudaError::InvalidResourceHandle => "cudaErrorInvalidResourceHandle",
            CudaError::NotReady => "cudaErrorNotReady",
            CudaError::LaunchFailure => "cudaErrorLaunchFailure",
            CudaError::UnhookedSymbol => "cookErrorUnhookedSymbol",
        };
        f.write_str(name)
    }
}

impl std::error::Error for CudaError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_are_distinct() {
        use std::collections::HashSet;
        let all = [
            CudaError::Success,
            CudaError::InvalidValue,
            CudaError::InvalidConfiguration,
            CudaError::InvalidResourceHandle,
            CudaError::NotReady,
            CudaError::LaunchFailure,
            CudaError::UnhookedSymbol,
        ];
        let codes: HashSet<i32> = all.iter().map(|e| e.code()).collect();
        assert_eq!(codes.len(), all.len());
    }

    #[test]
    fn display_names() {
        assert_eq!(CudaError::Success.to_string(), "cudaSuccess");
        assert_eq!(CudaError::NotReady.to_string(), "cudaErrorNotReady");
        assert!(CudaError::Success.is_success());
        assert!(!CudaError::NotReady.is_success());
    }
}
