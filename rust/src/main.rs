//! `cook` — CLI for the COOK access-control reproduction.
//!
//! Subcommands:
//! * `run <spec>` — simulate one `bench-isol-strategy` configuration.
//! * `experiment <fig9|fig10|fig11|table1|table2|all>` — regenerate a
//!   paper figure/table.
//! * `chronogram <spec>` — render the Fig. 11-style chronogram.
//! * `hookgen --strategy <s> [--out <dir>]` — run the COOK toolchain and
//!   emit the generated hook library source tree.
//! * `symbols` — list the hooked library's exported surface.
//! * `validate` — load the AOT artifacts and check numerics against the
//!   jax golden vectors (PJRT engine with the `pjrt` feature, the native
//!   reference interpreter otherwise).
//! * `serve` — live serving: concurrent clients run payload inferences
//!   (any manifest payload, all five strategies, optional batching)
//!   through the access-control policy layer; `--shards N` routes the
//!   clients across a fleet of per-GPU gates (`control::fleet`), and
//!   `--shard-sweep` tabulates throughput scaling across fleet sizes;
//!   `--autoscale MIN..MAX` hands the fleet to the elastic controller
//!   (`control::elastic`): SLO-driven scale-up, drain-then-retire
//!   scale-down, and work stealing (DESIGN.md §15).

use anyhow::{anyhow, bail, Context, Result};
use cook::config::StrategyKind;
use cook::control::arbiter::{parse_classes, ArbiterKind, TenantClass};
use cook::control::concurrency::ConcurrencyMode;
use cook::control::fault::{FaultPlan, FaultSpec, FaultyBackend, RetryPolicy};
use cook::control::fleet::{serve_fleet, FleetSpec, Placement};
use cook::control::serving::{serve, ManifestBackend, ServeBackend, ServeSpec, SyntheticBackend};
use cook::control::traffic::{ArrivalProcess, ShedPolicy, TrafficSpec};
use cook::cudart::SymbolTable;
use cook::harness::{
    figures, fleet_sweep, load_sweep, run_spec, serve_sweep, Bench, ExperimentSpec,
};
use cook::hooks::generate_standard;
use cook::runtime::{Engine, Manifest};
use std::path::PathBuf;
use std::time::Instant;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &args[1..];
    // Global knob: `--sim-threads N` caps the shard-parallel fleet
    // engine (DESIGN.md §11) for every command, same as setting
    // COOK_SIM_THREADS in the environment. 1 forces sequential.
    if let Some(n) = flag(rest, "--sim-threads") {
        n.parse::<usize>()
            .map_err(|_| anyhow!("--sim-threads wants a positive integer, got '{n}'"))?;
        std::env::set_var("COOK_SIM_THREADS", n);
    }
    match cmd.as_str() {
        "run" => cmd_run(rest),
        "experiment" => cmd_experiment(rest),
        "chronogram" => cmd_chronogram(rest),
        "hookgen" => cmd_hookgen(rest),
        "symbols" => cmd_symbols(rest),
        "validate" => cmd_validate(),
        "serve" => cmd_serve(rest),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown command '{other}' (try `cook help`)"),
    }
}

fn print_usage() {
    println!(
        "cook — COOK access control on an embedded Volta GPU (reproduction)\n\
         \n\
         usage: cook <command> [options]\n\
         \n\
         commands:\n\
         \x20 run <bench-isol-strategy> [--seed N]      simulate one configuration\n\
         \x20 experiment <fig9|fig10|fig11|table1|table2|fleet|load|isolation|autoscale|all> [--seed N] [--out DIR]\n\
         \x20 chronogram <bench-isol-strategy> [--seed N] [--rows N]\n\
         \x20 hookgen --strategy <s> [--out DIR]        generate the hook library\n\
         \x20 symbols [--unknown]                       list libcudart exported symbols\n\
         \x20 validate                                  check AOT artifacts vs jax goldens\n\
         \x20 serve [--strategy s] [--payload p[,p]] [--clients N] [--requests N]\n\
         \x20       [--batch N] [--sweep] [--synthetic]\n\
         \x20       [--shards N] [--placement rr|least-loaded|affinity] [--shard-sweep N[,N]]\n\
         \x20       [--autoscale MIN..MAX]\n\
         \x20       [--arrivals closed|poisson:R|bursty:R@ON/OFF|ramp:A-B]\n\
         \x20       [--queue-cap N] [--shed block|reject|timeout:MS] [--slo-ms X]\n\
         \x20       [--load-sweep R[,R...]] [--exact-quantiles]\n\
         \x20       [--faults SPEC] [--retries N] [--lease-ms MS]\n\
         \x20       [--arbiter fifo|wrr|credit|edf] [--classes SPEC]\n\
         \x20       [--concurrency cook|mps[:quota]|mig[:slices]|streams]\n\
         \x20       serve payload inferences through the access-control layer\n\
         \x20       (--sweep tabulates all strategies; --synthetic needs no artifacts;\n\
         \x20        --shards N routes clients across a fleet of per-GPU gates;\n\
         \x20        --shard-sweep tabulates scaling across fleet sizes;\n\
         \x20        --arrivals opens the loop: generated load, bounded admission\n\
         \x20        queues, SLO accounting from arrival; --load-sweep emits the\n\
         \x20        latency-vs-offered-load saturation curve; --exact-quantiles\n\
         \x20        keeps exact latency vectors instead of the streaming sketch;\n\
         \x20        --faults injects seeded chaos, e.g.\n\
         \x20        'error:p=0.01,hang:shard=2@req=500:ms=50,crash:payload=1@req=100';\n\
         \x20        --retries N retries failed requests with backoff; --lease-ms\n\
         \x20        arms the gate-lease watchdog that revokes hung holders;\n\
         \x20        --arbiter picks the gate's grant order and --classes declares\n\
         \x20        QoS tenant classes, e.g.\n\
         \x20        'gold:weight=3:slo=20,free:credits=8:deadline=40' —\n\
         \x20        clients/requests map to classes round-robin and the report\n\
         \x20        adds per-class latency/goodput/SLO attainment;\n\
         \x20        --concurrency picks what may hold the device at once:\n\
         \x20        cook = exclusive FIFO gate (default, the paper), mps:<q> =\n\
         \x20        q concurrent holders, mig:<s> = s per-class partitions,\n\
         \x20        streams = unbounded admission, class-priority device;\n\
         \x20        --autoscale MIN..MAX runs the elastic fleet controller:\n\
         \x20        needs open-loop --arrivals, hot-adds shards under pressure\n\
         \x20        up to MAX slots, retires quiet ones drain-first down to MIN,\n\
         \x20        and reports every scale event)\n\
         \n\
         global options:\n\
         \x20 --sim-threads N   thread cap for the shard-parallel fleet engine\n\
         \x20                   (equivalent to COOK_SIM_THREADS; 1 = sequential;\n\
         \x20                    results are bit-identical at every setting)\n\
         \n\
         benches: cuda_mmult, onnx_dna;  isolation|parallel;\n\
         strategies: none, callback, synced, worker, ptb;\n\
         payloads: dna, mmult, vecadd (from the AOT manifest)"
    );
}

/// Tiny flag scanner: `--key value` pairs after positional args.
fn flag<'a>(rest: &'a [String], key: &str) -> Option<&'a str> {
    rest.iter()
        .position(|a| a == key)
        .and_then(|i| rest.get(i + 1))
        .map(|s| s.as_str())
}

fn seed_of(rest: &[String]) -> u64 {
    flag(rest, "--seed").and_then(|s| s.parse().ok()).unwrap_or(0)
}

fn cmd_run(rest: &[String]) -> Result<()> {
    let spec: ExperimentSpec = rest
        .first()
        .ok_or_else(|| anyhow!("usage: cook run <bench-isol-strategy>"))?
        .parse()
        .map_err(|e: String| anyhow!(e))?;
    let seed = seed_of(rest);
    let t0 = Instant::now();
    let r = if let Some(path) = flag(rest, "--config") {
        // Model overrides from a flat key = value file (config::file).
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let mut cfg = spec.sim_config(seed);
        let n = cook::config::apply_overrides(&mut cfg, &text).map_err(|e| anyhow!("{e}"))?;
        println!("applied {n} overrides from {path}");
        let mut sim = cook::gpu::Sim::new(cfg, spec.programs());
        sim.run();
        cook::harness::runner::result_from_sim(spec, seed, &sim)
    } else {
        run_spec(spec, seed)
    };
    println!("config {spec} (seed {seed}), simulated in {:?}", t0.elapsed());
    for inst in 0..r.net.len() {
        match r.net_box(inst) {
            Some(b) => println!("  NET inst{inst}: {}", b.render()),
            None => println!("  NET inst{inst}: no kernels"),
        }
        println!("  IPS inst{inst}: {:.1}", r.ips[inst]);
    }
    println!(
        "  kernels={:?} overlaps={} switches={} stalls={} total={:.1} Mcycles",
        r.kernels,
        r.overlaps,
        r.switches,
        r.stalls,
        r.chronogram.total_mcycles()
    );
    Ok(())
}

fn cmd_experiment(rest: &[String]) -> Result<()> {
    let which = rest.first().map(|s| s.as_str()).unwrap_or("all");
    let seed = seed_of(rest);
    let out_dir = flag(rest, "--out").map(PathBuf::from);
    // `--concurrency` narrows the isolation figure to one mode (the
    // full figure sweeps all four).
    let concurrency: Option<ConcurrencyMode> = flag(rest, "--concurrency")
        .map(|s| s.parse().map_err(|e: String| anyhow!(e)))
        .transpose()?;
    let mut emitted = String::new();
    let run_one = |name: &str, emitted: &mut String| -> Result<()> {
        let t0 = Instant::now();
        let text = match name {
            "fig9" => figures::net_figure(Bench::CudaMmult, seed).0,
            "fig10" => figures::net_figure(Bench::OnnxDna, seed).0,
            "fig11" => figures::chronogram_figure(seed).0,
            "table1" => figures::ips_table(seed).0,
            "table2" => figures::loc_table().0,
            "fleet" => figures::shard_scaling_figure(seed).0,
            "load" => figures::saturation_figure(seed).0,
            "autoscale" => figures::autoscale_figure(seed).0,
            "isolation" => match concurrency {
                Some(mode) => figures::isolation_figure_for(seed, &[mode]).0,
                None => figures::isolation_figure(seed).0,
            },
            other => bail!("unknown experiment '{other}'"),
        };
        println!("{text}");
        println!("[{name} regenerated in {:?}]\n", t0.elapsed());
        emitted.push_str(&text);
        emitted.push('\n');
        Ok(())
    };
    if which == "all" {
        for name in [
            "fig9", "fig10", "fig11", "table1", "table2", "fleet", "load", "isolation",
            "autoscale",
        ] {
            run_one(name, &mut emitted)?;
        }
    } else {
        run_one(which, &mut emitted)?;
    }
    if let Some(dir) = out_dir {
        std::fs::create_dir_all(&dir)?;
        let path = dir.join(format!("experiment-{which}.txt"));
        std::fs::write(&path, emitted).with_context(|| format!("writing {path:?}"))?;
        println!("wrote {path:?}");
    }
    Ok(())
}

fn cmd_chronogram(rest: &[String]) -> Result<()> {
    let spec: ExperimentSpec = rest
        .first()
        .ok_or_else(|| anyhow!("usage: cook chronogram <bench-isol-strategy>"))?
        .parse()
        .map_err(|e: String| anyhow!(e))?;
    let seed = seed_of(rest);
    let rows: usize = flag(rest, "--rows").and_then(|s| s.parse().ok()).unwrap_or(32);
    let r = run_spec(spec, seed);
    println!(
        "{spec}: total={:.1} Mcycles, cross-instance overlap={}",
        r.chronogram.total_mcycles(),
        if r.chronogram.has_cross_lane_overlap() { "YES" } else { "no" }
    );
    print!("{}", r.chronogram.render_ascii(rows));
    Ok(())
}

fn cmd_hookgen(rest: &[String]) -> Result<()> {
    let strategy: StrategyKind = flag(rest, "--strategy")
        .ok_or_else(|| anyhow!("usage: cook hookgen --strategy <none|callback|synced|worker>"))?
        .parse()
        .map_err(|e: String| anyhow!(e))?;
    let lib = generate_standard(strategy);
    println!(
        "strategy {strategy}: {} symbols bound, {} hooked, {} unknown",
        lib.bindings.len(),
        lib.hooked_symbols().len(),
        lib.unknown_symbols.len()
    );
    let report = cook::hooks::loc_report(strategy);
    println!(
        "LoC: configuration={} templates={} generated={}",
        report.configuration, report.templates, report.generated
    );
    if let Some(dir) = flag(rest, "--out") {
        let dir = PathBuf::from(dir);
        lib.write_to(&dir)?;
        println!("wrote {} files to {dir:?}", lib.files.len());
    }
    Ok(())
}

fn cmd_symbols(rest: &[String]) -> Result<()> {
    let table = SymbolTable::cuda_runtime_11_4();
    let only_unknown = rest.iter().any(|a| a == "--unknown");
    println!("{} exports {} symbols", table.library, table.len());
    for sym in &table.symbols {
        if only_unknown && sym.has_declaration {
            continue;
        }
        match sym.declaration() {
            Some(d) => println!("  {d}"),
            None => println!("  {} (unknown: declaration not found)", sym.name),
        }
    }
    Ok(())
}

fn cmd_validate() -> Result<()> {
    let engine = Engine::load_default()?;
    println!("engine platform: {}", engine.platform());
    let mut skipped = 0;
    for (i, spec) in engine.manifest.artifacts.iter().enumerate() {
        if !engine.supports(i) {
            println!("  {}: SKIP (requires the `pjrt` build feature)", spec.name);
            skipped += 1;
            continue;
        }
        let t0 = Instant::now();
        engine.validate_golden(i)?;
        println!(
            "  {}: OK ({} args, out {:?}) in {:?}",
            spec.name,
            spec.arg_sizes.len(),
            spec.out_shape,
            t0.elapsed()
        );
    }
    if skipped == 0 {
        println!("all artifacts match the jax golden vectors");
    } else {
        println!("all supported artifacts match the jax golden vectors ({skipped} skipped)");
    }
    Ok(())
}

fn cmd_serve(rest: &[String]) -> Result<()> {
    let clients: usize = flag(rest, "--clients").and_then(|s| s.parse().ok()).unwrap_or(2);
    let requests: usize = flag(rest, "--requests").and_then(|s| s.parse().ok()).unwrap_or(50);
    let batch: usize = flag(rest, "--batch").and_then(|s| s.parse().ok()).unwrap_or(1);
    let payloads: Vec<String> = flag(rest, "--payload")
        .unwrap_or("dna")
        .split(',')
        .map(str::to_string)
        .collect();
    let synthetic = rest.iter().any(|a| a == "--synthetic");
    let sweep = rest.iter().any(|a| a == "--sweep");
    // Exact nearest-rank quantiles (O(n log n) report sort) instead of
    // the default streaming sketch (<= 2% relative error, O(1) records).
    let exact_quantiles = rest.iter().any(|a| a == "--exact-quantiles");
    let shards: usize = flag(rest, "--shards").and_then(|s| s.parse().ok()).unwrap_or(1);
    if shards == 0 {
        bail!("--shards must be >= 1");
    }
    let placement: Placement = flag(rest, "--placement")
        .unwrap_or("rr")
        .parse()
        .map_err(|e: String| anyhow!(e))?;
    // Elastic fleet (ISSUE 10): controller bounds. The shard slot pool
    // is the upper bound; `--shards` may pin it explicitly, otherwise it
    // follows MAX.
    let autoscale: Option<cook::control::elastic::AutoscaleSpec> = flag(rest, "--autoscale")
        .map(|s| s.parse().map_err(|e: String| anyhow!(e)))
        .transpose()?;
    let shard_sweep: Option<Vec<usize>> = match flag(rest, "--shard-sweep") {
        Some(list) => Some(
            list.split(',')
                .map(|s| {
                    s.trim()
                        .parse::<usize>()
                        .map_err(|_| anyhow!("bad shard count '{s}' in --shard-sweep"))
                })
                .collect::<Result<_>>()?,
        ),
        None => None,
    };
    // Traffic knobs (ISSUE 4): arrival process, bounded admission, SLO.
    let arrivals: ArrivalProcess = flag(rest, "--arrivals")
        .unwrap_or("closed")
        .parse()
        .map_err(|e: String| anyhow!(e))?;
    let queue_cap: usize = flag(rest, "--queue-cap").and_then(|s| s.parse().ok()).unwrap_or(64);
    let shed_policy: ShedPolicy = flag(rest, "--shed")
        .unwrap_or("block")
        .parse()
        .map_err(|e: String| anyhow!(e))?;
    let slo_ms: f64 = flag(rest, "--slo-ms").and_then(|s| s.parse().ok()).unwrap_or(50.0);
    let traffic = TrafficSpec {
        arrivals,
        queue_cap,
        shed: shed_policy,
        slo_ms,
        seed: seed_of(rest),
    };
    let load_sweep_rates: Option<Vec<f64>> = match flag(rest, "--load-sweep") {
        Some(list) => Some(
            list.split(',')
                .map(|s| {
                    s.trim()
                        .parse::<f64>()
                        .map_err(|_| anyhow!("bad rate '{s}' in --load-sweep"))
                })
                .collect::<Result<_>>()?,
        ),
        None => None,
    };

    // QoS knobs (ISSUE 8): arbitration policy + tenant classes.
    let arbiter: ArbiterKind = flag(rest, "--arbiter")
        .unwrap_or("fifo")
        .parse()
        .map_err(|e: String| anyhow!(e))?;
    let classes: Vec<TenantClass> = parse_classes(flag(rest, "--classes").unwrap_or(""))
        .map_err(|e: String| anyhow!(e))?;

    // Concurrency mode (ISSUE 9): what may hold the device at once.
    let concurrency: ConcurrencyMode = flag(rest, "--concurrency")
        .unwrap_or("cook")
        .parse()
        .map_err(|e: String| anyhow!(e))?;

    // Robustness knobs (ISSUE 7): fault injection, retries, gate leases.
    let fault_spec: FaultSpec = flag(rest, "--faults")
        .unwrap_or("")
        .parse()
        .map_err(|e: String| anyhow!(e))?;
    let retries: u32 = flag(rest, "--retries")
        .map(|s| s.parse().map_err(|_| anyhow!("--retries wants an integer, got '{s}'")))
        .transpose()?
        .unwrap_or(0);
    let lease_ms: Option<u64> = flag(rest, "--lease-ms")
        .map(|s| s.parse().map_err(|_| anyhow!("--lease-ms wants milliseconds, got '{s}'")))
        .transpose()?;

    let mut backend: Box<dyn ServeBackend> = if synthetic {
        println!("serving synthetic payloads (no artifacts required)");
        Box::new(SyntheticBackend::new(200))
    } else {
        // Validate numerics of the served payloads once before serving.
        let engine = Engine::load_default()?;
        println!("serving on {}", engine.platform());
        for (i, spec) in engine.manifest.artifacts.iter().enumerate() {
            if payloads.iter().any(|p| *p == spec.name) {
                if engine.supports(i) {
                    engine.validate_golden(i)?;
                } else {
                    bail!(
                        "payload '{}' is not executable by this build \
                         (rebuild with --features pjrt)",
                        spec.name
                    );
                }
            }
        }
        drop(engine);
        Box::new(ManifestBackend::new(Manifest::default_dir()))
    };
    if !fault_spec.is_empty() {
        println!("fault injection armed: {fault_spec} (seed {})", seed_of(rest));
        let plan = std::sync::Arc::new(FaultPlan::new(fault_spec, seed_of(rest)));
        backend = Box::new(FaultyBackend::new(backend, plan));
    }

    let mut base = ServeSpec::new(StrategyKind::None, "dna")
        .with_payloads(payloads)
        .with_clients(clients)
        .with_requests(requests)
        .with_batch(batch)
        .with_traffic(traffic)
        .with_exact_quantiles(exact_quantiles)
        .with_arbiter(arbiter)
        .with_classes(classes.clone())
        .with_concurrency(concurrency);
    if !concurrency.is_cook() {
        println!("concurrency {concurrency}: mode-defined admission (DESIGN.md §14)");
    }
    if !classes.is_empty() {
        println!(
            "arbiter {arbiter}: {} tenant classes ({})",
            classes.len(),
            cook::control::arbiter::render_classes(&classes)
        );
    } else if arbiter != ArbiterKind::Fifo {
        println!("arbiter {arbiter}: no classes declared; every client is class 0");
    }
    if retries > 0 {
        base = base.with_retry(RetryPolicy { seed: seed_of(rest), ..RetryPolicy::with_budget(retries) });
    }
    if let Some(ms) = lease_ms {
        base = base.with_lease_ms(ms);
    }
    if sweep {
        if flag(rest, "--strategy").is_some() {
            bail!("--sweep runs every strategy; drop --strategy or drop --sweep");
        }
        if shards > 1 || shard_sweep.is_some() {
            bail!("--sweep sweeps strategies on one shard; use --shard-sweep for the fleet axis");
        }
        if load_sweep_rates.is_some() {
            bail!("--sweep and --load-sweep are separate axes; pick one");
        }
        if autoscale.is_some() {
            bail!("--sweep runs fixed single-shard fleets; drop --autoscale");
        }
        let (text, _) = serve_sweep(&base, backend.as_ref())?;
        print!("{text}");
        return Ok(());
    }
    let strategy: StrategyKind = flag(rest, "--strategy")
        .unwrap_or("worker")
        .parse()
        .map_err(|e: String| anyhow!(e))?;
    let mut spec = base;
    spec.strategy = strategy;
    if let Some(auto) = autoscale {
        if load_sweep_rates.is_some() || shard_sweep.is_some() {
            bail!("--autoscale is its own fleet axis; drop --load-sweep/--shard-sweep");
        }
        // Slot pool defaults to the controller's upper bound; an explicit
        // --shards must match it (FleetSpec::validate says why).
        let slots = if flag(rest, "--shards").is_some() { shards } else { auto.max };
        println!(
            "strategy {strategy}: elastic fleet {auto} over {slots} shard slots \
             (SLO-driven scale-up, drain-then-retire scale-down, work stealing)"
        );
        let fleet = FleetSpec::new(spec, slots, placement).with_autoscale(auto);
        let report = serve_fleet(&fleet, backend.as_ref())?;
        println!("{}", report.render());
        return Ok(());
    }
    if let Some(rates) = load_sweep_rates {
        if shards > 1 || shard_sweep.is_some() {
            bail!("--load-sweep measures one shard; drop --shards/--shard-sweep");
        }
        if flag(rest, "--arrivals").is_some() {
            // The sweep would silently overwrite the process per point.
            bail!("--load-sweep sweeps Poisson rates; drop --arrivals");
        }
        let (text, _) = load_sweep(&spec, &rates, backend.as_ref())?;
        print!("{text}");
        return Ok(());
    }
    if let Some(counts) = shard_sweep {
        let (text, _) = fleet_sweep(&spec, placement, &counts, backend.as_ref())?;
        print!("{text}");
    } else if shards > 1 {
        // FleetReport::render already leads with the fleet shape line.
        let fleet = FleetSpec::new(spec, shards, placement);
        let report = serve_fleet(&fleet, backend.as_ref())?;
        println!("{}", report.render());
    } else {
        if spec.traffic.arrivals.is_open_loop() {
            println!(
                "strategy {strategy}: open-loop arrivals {} over {clients} workers \
                 ({} requests total, queue cap {queue_cap}, shed {shed_policy}, \
                 SLO {slo_ms} ms)",
                spec.traffic.arrivals,
                clients * requests,
            );
        } else {
            println!(
                "strategy {strategy}: {clients} clients x {requests} requests (batch {batch})"
            );
        }
        let report = serve(&spec, backend.as_ref())?;
        println!("{}", report.render());
    }
    Ok(())
}
