//! Host-code programs: the burst/barrier structure of §II-A.
//!
//! An application is host code that interleaves CPU work with GPU routine
//! calls and synchronisation barriers. Programs are plain data so the same
//! program can run under every strategy and the generated traces are
//! directly comparable.

use crate::cudart::{CopyDesc, CopyDir, KernelDesc, KernelInstance};
use crate::util::{Nanos, SymId};

/// One step of host code.
#[derive(Debug, Clone)]
pub enum HostStep {
    /// CPU-side work (pre/post-processing between GPU routines).
    Compute(Nanos),
    /// `cudaLaunchKernel`: asynchronous kernel launch (Alg. 1).
    Launch(KernelDesc),
    /// `cudaMemcpyAsync`: asynchronous copy (Alg. 2).
    Memcpy(CopyDesc),
    /// `cudaLaunchHostFunc`: an application host-func in stream order —
    /// the "other stream-ordered operation" of Alg. 7.
    HostFunc(Nanos),
    /// `cudaDeviceSynchronize`: barrier awaiting all prior GPU operations.
    Sync,
    /// Marks the completion of one application iteration (inference) —
    /// drives the IPS metric (eq. 2) and separates bursts for Aspect 6.
    MarkCompletion,
}

impl HostStep {
    /// Does this step insert a GPU operation (vs pure host behaviour)?
    pub fn is_gpu_routine(&self) -> bool {
        matches!(self, HostStep::Launch(_) | HostStep::Memcpy(_) | HostStep::HostFunc(_))
    }
}

/// Whether the program runs once or loops until the horizon.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RepeatMode {
    Once,
    LoopUntilHorizon,
}

/// A complete host program.
#[derive(Debug, Clone)]
pub struct Program {
    pub name: String,
    pub steps: Vec<HostStep>,
    pub repeat: RepeatMode,
}

impl Program {
    pub fn new(name: impl Into<String>, repeat: RepeatMode) -> Self {
        Self { name: name.into(), steps: Vec::new(), repeat }
    }

    pub fn compute(mut self, ns: Nanos) -> Self {
        self.steps.push(HostStep::Compute(ns));
        self
    }

    pub fn launch(mut self, k: KernelDesc) -> Self {
        self.steps.push(HostStep::Launch(k));
        self
    }

    pub fn memcpy_h2d(mut self, bytes: u64) -> Self {
        self.steps
            .push(HostStep::Memcpy(CopyDesc { bytes, dir: CopyDir::HostToDevice }));
        self
    }

    pub fn memcpy_d2h(mut self, bytes: u64) -> Self {
        self.steps
            .push(HostStep::Memcpy(CopyDesc { bytes, dir: CopyDir::DeviceToHost }));
        self
    }

    pub fn host_func(mut self, ns: Nanos) -> Self {
        self.steps.push(HostStep::HostFunc(ns));
        self
    }

    pub fn sync(mut self) -> Self {
        self.steps.push(HostStep::Sync);
        self
    }

    pub fn mark_completion(mut self) -> Self {
        self.steps.push(HostStep::MarkCompletion);
        self
    }

    /// Number of GPU routines per iteration of the program.
    pub fn gpu_routines(&self) -> usize {
        self.steps.iter().filter(|s| s.is_gpu_routine()).count()
    }

    /// Number of bursts (sequences of routines closed by a barrier).
    pub fn bursts(&self) -> usize {
        let mut bursts = 0;
        let mut open = false;
        for s in &self.steps {
            match s {
                HostStep::Launch(_) | HostStep::Memcpy(_) | HostStep::HostFunc(_) => {
                    open = true;
                }
                HostStep::Sync => {
                    if open {
                        bursts += 1;
                        open = false;
                    }
                }
                _ => {}
            }
        }
        if open {
            bursts += 1;
        }
        bursts
    }

    /// Convenience: a one-burst microbenchmark launching `k` `n` times.
    pub fn kernel_burst(name: &str, k: KernelDesc, n: usize) -> Self {
        let mut p = Program::new(name, RepeatMode::Once).compute(5_000);
        for _ in 0..n {
            p = p.launch(k.clone());
        }
        p.sync().mark_completion()
    }

    /// Lower the program to its execution form: every kernel name is
    /// resolved through `intern` exactly once, here, so the simulator's
    /// per-event loop never clones strings or hashes names. The interner
    /// is supplied by the run (the `TraceCollector` owns the table).
    pub fn compile(&self, intern: &mut dyn FnMut(&str) -> SymId) -> CompiledProgram {
        let steps = self
            .steps
            .iter()
            .map(|s| match s {
                HostStep::Compute(d) => CompiledStep::Compute(*d),
                HostStep::Launch(k) => CompiledStep::Launch(k.instance(intern(&k.name))),
                HostStep::Memcpy(c) => CompiledStep::Memcpy(*c),
                HostStep::HostFunc(d) => CompiledStep::HostFunc(*d),
                HostStep::Sync => CompiledStep::Sync,
                HostStep::MarkCompletion => CompiledStep::MarkCompletion,
            })
            .collect();
        CompiledProgram { name: self.name.clone(), steps, repeat: self.repeat }
    }
}

/// One step of a compiled (execution-form) program. Fully `Copy`: the
/// host state machine reads steps by value with no per-step allocation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum CompiledStep {
    Compute(Nanos),
    Launch(KernelInstance),
    Memcpy(CopyDesc),
    HostFunc(Nanos),
    Sync,
    MarkCompletion,
}

/// A program lowered by [`Program::compile`] for one simulator run.
#[derive(Debug, Clone)]
pub struct CompiledProgram {
    pub name: String,
    pub steps: Vec<CompiledStep>,
    pub repeat: RepeatMode,
}

impl CompiledProgram {
    /// Number of GPU routines per iteration (event-queue sizing input).
    pub fn gpu_routines(&self) -> usize {
        self.steps
            .iter()
            .filter(|s| {
                matches!(
                    s,
                    CompiledStep::Launch(_) | CompiledStep::Memcpy(_) | CompiledStep::HostFunc(_)
                )
            })
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cudart::Grid;

    fn kd() -> KernelDesc {
        KernelDesc::compute("k", Grid::new(8, 256), 10_000)
    }

    #[test]
    fn builder_produces_expected_steps() {
        let p = Program::new("t", RepeatMode::Once)
            .compute(100)
            .memcpy_h2d(1024)
            .launch(kd())
            .sync()
            .memcpy_d2h(512)
            .sync()
            .mark_completion();
        assert_eq!(p.steps.len(), 7);
        assert_eq!(p.gpu_routines(), 3);
        assert_eq!(p.bursts(), 2);
    }

    #[test]
    fn kernel_burst_shape() {
        let p = Program::kernel_burst("mmult", kd(), 300);
        assert_eq!(p.gpu_routines(), 300);
        assert_eq!(p.bursts(), 1);
        assert_eq!(p.repeat, RepeatMode::Once);
    }

    #[test]
    fn trailing_open_burst_counts() {
        let p = Program::new("t", RepeatMode::Once).launch(kd());
        assert_eq!(p.bursts(), 1);
    }

    #[test]
    fn host_only_program_has_no_bursts() {
        let p = Program::new("t", RepeatMode::Once).compute(5).mark_completion();
        assert_eq!(p.bursts(), 0);
        assert_eq!(p.gpu_routines(), 0);
    }

    #[test]
    fn compile_interns_each_distinct_name_once() {
        let p = Program::new("t", RepeatMode::Once)
            .launch(kd())
            .launch(kd())
            .launch(KernelDesc::compute("other", Grid::new(1, 32), 5))
            .sync()
            .mark_completion();
        let mut names: Vec<String> = Vec::new();
        let c = p.compile(&mut |n| {
            if let Some(i) = names.iter().position(|x| x == n) {
                SymId(i as u32)
            } else {
                names.push(n.to_string());
                SymId((names.len() - 1) as u32)
            }
        });
        assert_eq!(names, vec!["k".to_string(), "other".to_string()]);
        assert_eq!(c.steps.len(), p.steps.len());
        assert_eq!(c.gpu_routines(), p.gpu_routines());
        match (&c.steps[0], &c.steps[2]) {
            (CompiledStep::Launch(a), CompiledStep::Launch(b)) => {
                assert_eq!(a.sym, SymId(0));
                assert_eq!(b.sym, SymId(1));
            }
            other => panic!("unexpected compiled steps: {other:?}"),
        }
    }
}
