//! The `cuda_mmult` benchmark (§VI-C): the NVIDIA matrix-multiply sample.
//!
//! One burst repeatedly calling the same matmul kernel (300x) over the
//! same input data, then a single synchronisation barrier. The kernel's
//! compute is the L1 Pallas tiled matmul, AOT-compiled into
//! `artifacts/mmult.hlo.txt`; the timing model below is calibrated so an
//! isolated run lands around the paper's ~8 Mcycles (Fig. 11).

use super::program::Program;
use crate::cudart::{Grid, KernelDesc};
use crate::runtime::PAYLOAD_MMULT;

/// Kernel launches per run (the sample's repeat count).
pub const LAUNCHES: usize = 300;

/// Matrix dimension (matches `python/compile/model.py::MMULT_DIM`).
pub const DIM: usize = 256;

/// The matmul kernel: 32x32-thread blocks over a 256x256 output -> 64
/// blocks of 1024 threads. 1024 threads = 32 warps = 2 resident blocks
/// per SM; 16 blocks in flight across 8 SMs -> 4 waves.
pub fn kernel() -> KernelDesc {
    let blocks = ((DIM / 32) * (DIM / 32)) as u32; // 64
    KernelDesc::compute("matrixMulCUDA", Grid::new(blocks, 1024), 4_800)
        // A+B+C tiles: 3 * 256KiB = 768KiB vs 512KiB L2 -> saturating.
        .with_l2_footprint(400 * 1024)
        .with_payload(PAYLOAD_MMULT)
}

/// The full benchmark program: setup copies, one 300-launch burst, one
/// result copy, single barrier (matches the sample's structure).
pub fn program() -> Program {
    let mut p = Program::new("cuda_mmult", super::program::RepeatMode::Once)
        .compute(200_000) // allocation + input preparation
        .memcpy_h2d((DIM * DIM * 4) as u64)
        .memcpy_h2d((DIM * DIM * 4) as u64);
    for _ in 0..LAUNCHES {
        p = p.launch(kernel());
    }
    p.sync()
        .memcpy_d2h((DIM * DIM * 4) as u64)
        .sync()
        .mark_completion()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SimConfig, StrategyKind};
    use crate::gpu::Sim;
    use crate::util::{ns_to_cycles, AppId};

    #[test]
    fn program_shape() {
        let p = program();
        assert_eq!(p.gpu_routines(), LAUNCHES + 3);
        assert_eq!(p.bursts(), 2);
    }

    #[test]
    fn isolation_lands_near_eight_mcycles() {
        let mut sim = Sim::new(SimConfig::default().with_seed(1), vec![program()]);
        sim.run();
        let end = *sim.completions(AppId(0)).last().expect("must complete");
        let mcycles = ns_to_cycles(end) as f64 / 1e6;
        // Paper Fig. 11: ~8 Mcycles in isolation. Accept a generous band;
        // EXPERIMENTS.md records the exact measured value.
        assert!(
            (4.0..16.0).contains(&mcycles),
            "isolation run at {mcycles:.1} Mcycles, expected ~8"
        );
    }

    #[test]
    fn parallel_none_slowdown_is_multiple_x() {
        let mut iso = Sim::new(SimConfig::default().with_seed(1), vec![program()]);
        iso.run();
        let mut par = Sim::new(
            SimConfig::default().with_seed(1),
            vec![program(), program()],
        );
        par.run();
        let iso_end = *iso.completions(AppId(0)).last().unwrap() as f64;
        let par_end = (0..2)
            .map(|a| *par.completions(AppId(a)).last().unwrap())
            .max()
            .unwrap() as f64;
        let slowdown = par_end / iso_end;
        // Paper: ~3.5x (28 over 8 Mcycles). Require clearly more than 2x.
        assert!(
            slowdown > 2.2 && slowdown < 8.0,
            "parallel slowdown {slowdown:.2} out of band"
        );
    }

    #[test]
    fn strategies_isolate_mmult(
    ) {
        for s in [StrategyKind::Synced, StrategyKind::Worker] {
            let mut sim = Sim::new(
                SimConfig::default().with_strategy(s).with_seed(2),
                vec![program(), program()],
            );
            sim.run();
            assert_eq!(sim.trace.cross_app_kernel_overlaps(), 0, "{s}");
        }
    }
}
