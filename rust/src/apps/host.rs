//! Per-application host-thread state machine.
//!
//! Each application runs on its own CARMEL core (§II-A), so host threads
//! never contend for CPU in the model; they contend only on the GPU lock
//! and the GPU itself. The engine (gpu/engine.rs) drives these states.

use super::program::{CompiledProgram, CompiledStep};
use crate::util::{CtxId, Nanos, OpUid, StreamId};
use std::collections::VecDeque;

/// What the host thread is doing right now.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HostPhase {
    /// Executing host code; a `HostReady` event will fire at the end.
    Busy,
    /// Ready to execute the next program step (engine pump picks it up).
    Ready,
    /// Waiting on the global GPU lock (synced strategy).
    WaitingLock,
    /// Waiting for a specific op to complete (synced strategy sync).
    WaitingOp(OpUid),
    /// Waiting for the whole context to go quiescent (device barrier).
    WaitingDevice,
    /// Waiting for the worker to drain (worker strategy barrier/Alg. 7).
    WaitingWorker,
    /// Waiting for an open-loop arrival to admit the next iteration
    /// (`SimConfig::arrivals`; closed-loop runs never enter this phase).
    WaitingArrival,
    /// Program finished (RepeatMode::Once exhausted).
    Done,
}

/// Host-thread state for one application.
#[derive(Debug)]
pub struct HostState {
    /// Execution-form program (kernel names interned at compile time).
    pub program: CompiledProgram,
    pub ctx: CtxId,
    pub stream: StreamId,
    /// Program counter into `program.steps`.
    pub pc: usize,
    pub phase: HostPhase,
    /// Completed iterations (MarkCompletion count) with timestamps — the
    /// IPS metric samples this (eq. 2).
    pub completions: Vec<Nanos>,
    /// Current burst index (incremented at each Sync) for Aspect 6 checks.
    pub burst: usize,
    /// Set while inside a hooked routine that must release the lock on
    /// completion of `WaitingOp` (synced strategy).
    pub holds_lock: bool,
    /// Pending ordered-op to insert after worker drain (Alg. 7).
    pub pending_ordered_ns: Option<Nanos>,
    /// CPU time stolen from this host thread by driver callbacks, charged
    /// to the next compute segment (callback strategy cost model).
    pub pending_steal_ns: Nanos,
    /// Total virtual time spent blocked (hook overhead metric).
    pub blocked_ns: Nanos,
    /// Timestamp when the current blocking phase began.
    pub blocked_since: Option<Nanos>,
    /// Admitted open-loop arrivals not yet consumed by an iteration
    /// (bounded by `SimConfig::arrival_queue_cap`; the engine sheds past
    /// it). Each entry is the arrival timestamp.
    pub arrival_backlog: VecDeque<Nanos>,
    /// Arrival timestamps of iterations currently in flight (consumed at
    /// iteration start, popped at `MarkCompletion` for latency).
    pub arrival_inflight: VecDeque<Nanos>,
    /// Arrival-to-completion latencies (ns) of completed iterations
    /// under open-loop arrivals.
    pub arrival_latency_ns: Vec<Nanos>,
    /// The current iteration already consumed its arrival (reset when
    /// the program counter wraps); keeps blocking hook re-entries at
    /// pc 0 from double-charging the backlog.
    pub iteration_admitted: bool,
}

impl HostState {
    pub fn new(program: CompiledProgram, ctx: CtxId, stream: StreamId) -> Self {
        Self {
            program,
            ctx,
            stream,
            pc: 0,
            phase: HostPhase::Ready,
            completions: Vec::new(),
            burst: 0,
            holds_lock: false,
            pending_ordered_ns: None,
            pending_steal_ns: 0,
            blocked_ns: 0,
            blocked_since: None,
            arrival_backlog: VecDeque::new(),
            arrival_inflight: VecDeque::new(),
            arrival_latency_ns: Vec::new(),
            iteration_admitted: false,
        }
    }

    /// Move to a blocking phase, stamping block-time accounting.
    pub fn block(&mut self, phase: HostPhase, now: Nanos) {
        debug_assert!(matches!(
            phase,
            HostPhase::WaitingLock
                | HostPhase::WaitingOp(_)
                | HostPhase::WaitingDevice
                | HostPhase::WaitingWorker
                | HostPhase::WaitingArrival
        ));
        self.phase = phase;
        self.blocked_since = Some(now);
    }

    /// Leave a blocking phase back to Ready.
    pub fn unblock(&mut self, now: Nanos) {
        if let Some(since) = self.blocked_since.take() {
            self.blocked_ns += now.saturating_sub(since);
        }
        self.phase = HostPhase::Ready;
    }

    /// Advance past the current step; wraps or finishes per repeat mode.
    pub fn advance(&mut self) {
        self.pc += 1;
        if self.pc >= self.program.steps.len() {
            match self.program.repeat {
                super::program::RepeatMode::Once => self.phase = HostPhase::Done,
                super::program::RepeatMode::LoopUntilHorizon => {
                    self.pc = 0;
                    // The next iteration must consume its own arrival
                    // under open-loop traffic.
                    self.iteration_admitted = false;
                }
            }
        }
    }

    /// Current step by value (`CompiledStep` is `Copy`; no per-step
    /// clone of kernel descriptors on the hot path).
    pub fn current_step(&self) -> Option<CompiledStep> {
        if self.phase == HostPhase::Done {
            None
        } else {
            self.program.steps.get(self.pc).copied()
        }
    }

    pub fn done(&self) -> bool {
        self.phase == HostPhase::Done
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::program::{Program, RepeatMode};
    use crate::util::ids::*;

    fn host(repeat: RepeatMode) -> HostState {
        let p = Program::new("t", repeat).compute(10).mark_completion();
        let compiled = p.compile(&mut |_| SymId(0));
        HostState::new(compiled, CtxId(0), StreamId { ctx: CtxId(0), idx: 0 })
    }

    #[test]
    fn advance_once_terminates() {
        let mut h = host(RepeatMode::Once);
        assert!(matches!(h.current_step(), Some(CompiledStep::Compute(10))));
        h.advance();
        assert!(matches!(h.current_step(), Some(CompiledStep::MarkCompletion)));
        h.advance();
        assert!(h.done());
        assert!(h.current_step().is_none());
    }

    #[test]
    fn advance_loop_wraps() {
        let mut h = host(RepeatMode::LoopUntilHorizon);
        h.advance();
        h.advance();
        assert!(!h.done());
        assert_eq!(h.pc, 0);
    }

    #[test]
    fn block_accounting_accumulates() {
        let mut h = host(RepeatMode::Once);
        h.block(HostPhase::WaitingLock, 100);
        h.unblock(250);
        h.block(HostPhase::WaitingDevice, 300);
        h.unblock(400);
        assert_eq!(h.blocked_ns, 250);
        assert_eq!(h.phase, HostPhase::Ready);
    }
}
