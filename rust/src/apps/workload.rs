//! Random workload generator: arbitrary-but-valid host programs for
//! property-based testing and parameter sweeps (ablation benches).
//!
//! The generator explores the application design space of §II-A: number,
//! type and order of GPU operations; number and size of bursts; position
//! of synchronisation barriers; host compute between routines.

use super::program::{Program, RepeatMode};
use crate::cudart::{Grid, KernelDesc};
use crate::util::DetRng;

/// Bounds for the generator.
#[derive(Debug, Clone)]
pub struct WorkloadParams {
    pub min_bursts: usize,
    pub max_bursts: usize,
    pub min_ops_per_burst: usize,
    pub max_ops_per_burst: usize,
    pub max_block_cost_ns: u64,
    pub max_blocks: u32,
    pub copy_prob: f64,
    pub host_func_prob: f64,
    pub max_host_gap_ns: u64,
}

impl Default for WorkloadParams {
    fn default() -> Self {
        Self {
            min_bursts: 1,
            max_bursts: 4,
            min_ops_per_burst: 1,
            max_ops_per_burst: 12,
            max_block_cost_ns: 60_000,
            max_blocks: 128,
            copy_prob: 0.2,
            host_func_prob: 0.08,
            max_host_gap_ns: 80_000,
        }
    }
}

/// Generate a random (but structurally valid) one-shot program.
pub fn random_program(rng: &mut DetRng, params: &WorkloadParams) -> Program {
    let bursts = rng.range(params.min_bursts as u64, params.max_bursts as u64) as usize;
    let mut p = Program::new(
        format!("workload_{}", rng.range(0, u32::MAX as u64)),
        RepeatMode::Once,
    )
    .compute(rng.range(1_000, 200_000));
    let mut kernel_idx = 0usize;
    for _ in 0..bursts {
        let ops =
            rng.range(params.min_ops_per_burst as u64, params.max_ops_per_burst as u64) as usize;
        for _ in 0..ops {
            if rng.chance(params.host_func_prob) {
                p = p.host_func(rng.range(1_000, 30_000));
            } else if rng.chance(params.copy_prob) {
                let bytes = rng.range(1_024, 4 << 20);
                p = if rng.chance(0.5) { p.memcpy_h2d(bytes) } else { p.memcpy_d2h(bytes) };
            } else {
                // Thread counts stay within platform limits (<=1024) and
                // warp-multiple shapes dominate, as real kernels do.
                let threads = 32 * rng.range(1, 32) as u32;
                let blocks = rng.range(1, params.max_blocks as u64) as u32;
                let cost = rng.range(500, params.max_block_cost_ns);
                let k = KernelDesc::compute(
                    format!("wk{kernel_idx}"),
                    Grid::new(blocks, threads),
                    cost,
                )
                .with_l2_footprint(rng.range(0, 512 * 1024));
                kernel_idx += 1;
                p = p.launch(k);
            }
            if rng.chance(0.6) {
                p = p.compute(rng.range(500, params.max_host_gap_ns));
            }
        }
        p = p.sync();
    }
    p.mark_completion()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SimConfig, StrategyKind};
    use crate::gpu::Sim;
    use crate::util::AppId;

    #[test]
    fn generated_programs_are_valid() {
        let mut rng = DetRng::new(11);
        for _ in 0..20 {
            let p = random_program(&mut rng, &WorkloadParams::default());
            assert!(p.bursts() >= 1);
            assert!(p.steps.len() >= 3);
            // Threads per block within platform limits.
            for s in &p.steps {
                if let super::super::program::HostStep::Launch(k) = s {
                    assert!(k.grid.threads_per_block <= 1024);
                    assert!(k.grid.blocks >= 1);
                }
            }
        }
    }

    #[test]
    fn generator_is_deterministic() {
        let mut a = DetRng::new(5);
        let mut b = DetRng::new(5);
        let params = WorkloadParams::default();
        let pa = random_program(&mut a, &params);
        let pb = random_program(&mut b, &params);
        assert_eq!(pa.steps.len(), pb.steps.len());
    }

    #[test]
    fn random_workloads_complete_under_all_strategies() {
        let mut rng = DetRng::new(23);
        let params = WorkloadParams::default();
        for trial in 0..5 {
            let p1 = random_program(&mut rng, &params);
            let p2 = random_program(&mut rng, &params);
            for s in StrategyKind::ALL {
                let mut sim = Sim::new(
                    SimConfig::default().with_strategy(s).with_seed(trial),
                    vec![p1.clone(), p2.clone()],
                );
                sim.run();
                assert_eq!(
                    sim.completions(AppId(0)).len(),
                    1,
                    "trial {trial} strategy {s}: app0 did not complete"
                );
                assert_eq!(
                    sim.completions(AppId(1)).len(),
                    1,
                    "trial {trial} strategy {s}: app1 did not complete"
                );
            }
        }
    }
}
