//! Applications: host-code programs, the host state machine, and the two
//! paper benchmarks (`cuda_mmult`, `onnx_dna`) plus a workload generator.

pub mod host;
pub mod dna;
pub mod mmult;
pub mod program;
pub mod workload;

pub use program::{CompiledProgram, CompiledStep, HostStep, Program, RepeatMode};
