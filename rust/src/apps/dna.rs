//! The `onnx_dna` benchmark (§VI-C): an industrial drone detect-and-avoid
//! DNN served by the ONNX runtime.
//!
//! Modelled as DNA-Net: each inference is a few long bursts of
//! heterogeneous kernels (convolutions, dense layers, elementwise ops) and
//! copies, with few synchronisation points and host pre/post-processing
//! around them — the structure the paper describes (long bursts, CPU and
//! GPU working in tandem). Real numerics for the model live in
//! `artifacts/dna.hlo.txt` (L2 JAX model over the L1 Pallas kernels);
//! the timing shape below is calibrated to land the isolated run near the
//! paper's 113 inferences/s.

use super::program::{Program, RepeatMode};
use crate::cudart::{Grid, KernelDesc};
use crate::runtime::PAYLOAD_DNA;

/// Convolution-layer kernel: many blocks, big L2 footprint.
pub fn conv_kernel(idx: usize) -> KernelDesc {
    KernelDesc::compute(
        format!("dna_conv{idx}"),
        Grid::new(96, 256),
        125_000, // 2 waves on 8 SMs at 8 blocks/SM -> ~250 us
    )
    .with_l2_footprint(320 * 1024)
    .with_payload(PAYLOAD_DNA)
}

/// Dense-layer kernel (the Pallas fused dense).
pub fn dense_kernel(idx: usize) -> KernelDesc {
    KernelDesc::compute(format!("dna_dense{idx}"), Grid::new(32, 256), 150_000)
        .with_l2_footprint(200 * 1024)
        .with_payload(PAYLOAD_DNA)
}

/// Elementwise / activation / pooling kernel.
pub fn elem_kernel(idx: usize) -> KernelDesc {
    KernelDesc::compute(format!("dna_elem{idx}"), Grid::new(48, 256), 60_000)
        .with_l2_footprint(96 * 1024)
        .with_payload(PAYLOAD_DNA)
}

/// Input frame size (camera image, bytes).
pub const INPUT_BYTES: u64 = 640 * 480 * 3;

/// One full inference: three bursts, ~50 GPU operations.
fn add_inference(mut p: Program) -> Program {
    // Host: frame acquisition + preprocessing, then upload.
    p = p.compute(600_000).memcpy_h2d(INPUT_BYTES);

    // Burst 1: backbone convolutions, interleaved with activations.
    for i in 0..4 {
        p = p.compute(150_000).launch(conv_kernel(i));
        p = p.compute(70_000).launch(elem_kernel(i));
    }
    p = p.sync();

    // Burst 2: deeper layers — the long burst with no sync points.
    for i in 0..8 {
        p = p.compute(150_000).launch(conv_kernel(4 + i));
        if i % 2 == 0 {
            p = p.compute(70_000).launch(elem_kernel(4 + i));
        }
    }
    for i in 0..6 {
        p = p.compute(100_000).launch(dense_kernel(i));
    }
    // An ONNX-runtime internal host callback rides the stream here (the
    // "other ordered operation" the worker strategy must order, Alg. 7).
    p = p.host_func(12_000);
    for i in 0..6 {
        p = p.compute(70_000).launch(elem_kernel(12 + i));
    }
    p = p.sync();

    // Burst 3: detection head + result download.
    for i in 0..4 {
        p = p.compute(100_000).launch(dense_kernel(6 + i));
    }
    p = p.launch(elem_kernel(20)).memcpy_d2h(64 * 1024).sync();

    // Host postprocessing (NMS, track update) closes the iteration.
    p.compute(900_000).mark_completion()
}

/// The looping benchmark application (measured over a sampling window).
pub fn program() -> Program {
    add_inference(Program::new("onnx_dna", RepeatMode::LoopUntilHorizon))
}

/// A single-inference variant (useful in tests and examples).
pub fn single_inference() -> Program {
    add_inference(Program::new("onnx_dna_single", RepeatMode::Once))
}

/// GPU operations per inference (kernels + copies; excludes host funcs).
pub fn ops_per_inference() -> usize {
    single_inference()
        .steps
        .iter()
        .filter(|s| {
            matches!(
                s,
                super::program::HostStep::Launch(_) | super::program::HostStep::Memcpy(_)
            )
        })
        .count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{SimConfig, StrategyKind};
    use crate::gpu::Sim;
    use crate::metrics::ips::ips;
    use crate::util::AppId;

    #[test]
    fn program_shape_long_bursts_few_syncs() {
        let p = single_inference();
        assert_eq!(p.bursts(), 3, "three bursts per inference");
        assert!(p.gpu_routines() > 35, "long bursts: {}", p.gpu_routines());
    }

    #[test]
    fn single_inference_completes() {
        let mut sim = Sim::new(SimConfig::default().with_seed(3), vec![single_inference()]);
        sim.run();
        assert_eq!(sim.completions(AppId(0)).len(), 1);
    }

    #[test]
    fn isolation_ips_in_paper_band() {
        let mut cfg = SimConfig::default().with_seed(4);
        cfg.horizon_ns = 3_000_000_000; // 3 s window
        let mut sim = Sim::new(cfg, vec![program()]);
        sim.run();
        let v = ips(sim.completions(AppId(0)), 0, 3_000_000_000);
        // Paper Table I: 113 IPS in isolation-none. Wide acceptance band
        // here; the exact measured value goes to EXPERIMENTS.md.
        assert!((60.0..220.0).contains(&v), "isolation IPS {v:.1}, expected ~113");
    }

    #[test]
    fn parallel_halves_throughput_or_worse() {
        let mut cfg = SimConfig::default().with_seed(5);
        cfg.horizon_ns = 2_000_000_000;
        let mut iso = Sim::new(cfg.clone(), vec![program()]);
        iso.run();
        let mut par = Sim::new(cfg, vec![program(), program()]);
        par.run();
        let iso_ips = ips(iso.completions(AppId(0)), 0, 2_000_000_000);
        let par_ips = ips(par.completions(AppId(0)), 0, 2_000_000_000);
        assert!(
            par_ips < 0.55 * iso_ips,
            "paper: >2x IPS drop in parallel (iso {iso_ips:.0}, par {par_ips:.0})"
        );
    }

    #[test]
    fn worker_isolates_dna() {
        let mut cfg = SimConfig::default()
            .with_strategy(StrategyKind::Worker)
            .with_seed(6);
        cfg.horizon_ns = 1_000_000_000;
        let mut sim = Sim::new(cfg, vec![program(), program()]);
        sim.run();
        assert_eq!(sim.trace.cross_app_kernel_overlaps(), 0);
    }
}
