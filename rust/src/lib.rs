//! # COOK — access control on an embedded Volta GPU (full reproduction)
//!
//! This crate reproduces the system of Lesage, Boniol & Pagetti, *"COOK
//! Access Control on an embedded Volta GPU"* (CS.AR 2024): a configurable
//! C-hook (COOK) generator plus temporal access-control strategies that
//! serialise GPU operations from concurrent applications behind a global
//! GPU lock — and scales that guarantee out to a sharded multi-GPU
//! serving fleet.
//!
//! The paper's testbed is a physical Jetson AGX Xavier; this reproduction
//! replaces the physical platform with a deterministic discrete-event
//! simulator of the Volta execution model ([`gpu`]) and a simulated CUDA
//! Runtime surface ([`cudart`]), while real numerics run through AOT
//! compiled JAX/Pallas artifacts on a PJRT CPU client ([`runtime`]).
//! See `DESIGN.md` for the substitution table and experiment index, and
//! `README.md` for the quickstart and the figure → command reproduction
//! matrix.
//!
//! ## Layer map (rust + JAX + Pallas, AOT via PJRT)
//!
//! * L3 (this crate): hooks, strategies, simulator, apps, harness, CLI.
//! * L2 (`python/compile/model.py`): JAX models, lowered once to HLO text.
//! * L1 (`python/compile/kernels/`): Pallas kernels with jnp oracles.
//!
//! ## Module tour
//!
//! | Module | Role |
//! |--------|------|
//! | [`apps`] | Benchmark programs (`cuda_mmult`, `onnx_dna`) compiled to step lists |
//! | [`cudart`] | Simulated CUDA Runtime surface: contexts, streams, ops, symbol table |
//! | [`control`] | Access control: [`control::policy::AccessPolicy`] (the ONE strategy dispatch point), the simulated [`control::lock::GpuLock`], the live [`control::gate::GpuGate`], the serving loop ([`control::serving`]), the sharded fleet ([`control::fleet`]) and the open-loop traffic layer ([`control::traffic`]: arrival processes, bounded admission, SLO accounting) |
//! | [`gpu`] | The discrete-event Volta simulator ([`gpu::Sim`]), now a fleet of `num_gpus` independent shards |
//! | [`harness`] | Experiment specs, the parallel runner, figure/table emitters, serving sweeps |
//! | [`hooks`] | The COOK generator: condition rules → generated C hook tree (Table II) |
//! | [`metrics`] | NET (eq. 1), IPS (eq. 2), quantiles, latency [`metrics::stats::Histogram`] |
//! | [`runtime`] | AOT artifact execution: PJRT (`--features pjrt`) or the native interpreter |
//! | [`trace`] | Trace records, per-shard overlap checks, Fig. 11 chronograms |
//!
//! ## Strategy dispatch
//!
//! Strategy dispatch lives in exactly one place — the
//! [`control::policy::AccessPolicy`] layer — interpreted by the simulator
//! ([`gpu::engine`]) with simulated events and by the live multi-payload
//! serving subsystem ([`control::serving`]) with real threads behind the
//! FIFO [`control::gate::GpuGate`].
//!
//! ## Scaling out: the fleet
//!
//! The paper serialises onto one GPU. [`control::fleet`] routes serving
//! clients across `N` shards — each with its **own** gate + policy
//! instance — via a [`control::fleet::ShardRouter`] (round-robin,
//! least-loaded, or payload-affinity placement), and
//! [`SimConfig::num_gpus`](config::SimConfig) gives the simulator one
//! lock, SM bank, L2 and copy engine per shard so the same topology can
//! be studied in deterministic virtual time (`cook experiment fleet`).
//! Per-GPU isolation is preserved by construction; aggregate throughput
//! scales with the shard count.
//!
//! ## Offered load: open-loop traffic
//!
//! Closed-loop clients structurally hide queueing delay (coordinated
//! omission). [`control::traffic`] drives serving with *generated* load
//! instead: seeded arrival processes
//! ([`control::traffic::ArrivalProcess`]), a bounded admission queue
//! with shed policies in front of each shard's gate, and SLO accounting
//! measured from arrival. [`SimConfig::arrivals`](config::SimConfig)
//! mirrors the axis in virtual time, so `cook experiment load` and
//! `cook serve --arrivals poisson:R --load-sweep ...` report the same
//! saturation-curve shape (DESIGN.md §9).

pub mod apps;
pub mod config;
pub mod control;
pub mod cudart;
pub mod gpu;
pub mod harness;
pub mod hooks;
pub mod metrics;
pub mod runtime;
pub mod trace;
pub mod util;
