//! # COOK — access control on an embedded Volta GPU (full reproduction)
//!
//! This crate reproduces the system of Lesage, Boniol & Pagetti, *"COOK
//! Access Control on an embedded Volta GPU"* (CS.AR 2024): a configurable
//! C-hook (COOK) generator plus temporal access-control strategies that
//! serialise GPU operations from concurrent applications behind a global
//! GPU lock.
//!
//! The paper's testbed is a physical Jetson AGX Xavier; this reproduction
//! replaces the physical platform with a deterministic discrete-event
//! simulator of the Volta execution model ([`gpu`]) and a simulated CUDA
//! Runtime surface ([`cudart`]), while real numerics run through AOT
//! compiled JAX/Pallas artifacts on a PJRT CPU client ([`runtime`]).
//! See DESIGN.md for the substitution table and experiment index.
//!
//! Layer map (rust + JAX + Pallas, AOT via PJRT):
//! * L3 (this crate): hooks, strategies, simulator, apps, harness, CLI.
//! * L2 (`python/compile/model.py`): JAX models, lowered once to HLO text.
//! * L1 (`python/compile/kernels/`): Pallas kernels with jnp oracles.
//!
//! Strategy dispatch lives in exactly one place — the
//! [`control::policy::AccessPolicy`] layer — interpreted by the simulator
//! ([`gpu::engine`]) with simulated events and by the live multi-payload
//! serving subsystem ([`control::serving`]) with real threads behind the
//! FIFO [`control::gate::GpuGate`].

pub mod apps;
pub mod config;
pub mod control;
pub mod cudart;
pub mod gpu;
pub mod harness;
pub mod hooks;
pub mod metrics;
pub mod runtime;
pub mod trace;
pub mod util;
