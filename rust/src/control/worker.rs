//! Deferred-worker state (worker strategy, Alg. 5-7).
//!
//! One worker thread per application, running on its own core, with a
//! FIFO `worker_queue` of deferred operations. The worker pops one op at a
//! time, acquires the GPU lock, inserts the op into its private worker
//! stream, synchronises, and releases (Alg. 6). Argument lists for kernel
//! launches were deep-copied at hook time using the kernel registry.

use crate::util::{Nanos, OpUid, StreamId};
use std::collections::VecDeque;

/// What the worker thread is doing.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerPhase {
    /// Nothing queued (or between ops).
    Idle,
    /// Dequeue overhead in progress; WorkerReady fires at its end.
    Dequeuing(OpUid),
    /// Waiting on the global GPU lock for this op.
    WaitingLock(OpUid),
    /// Lock granted; semaphore handoff latency in progress.
    LockGranted(OpUid),
    /// Op inserted in the worker stream; waiting for its completion.
    WaitingOp(OpUid),
}

/// Per-application worker-thread state.
#[derive(Debug)]
pub struct WorkerState {
    /// The worker's private stream (a new stream per worker, §V-B3).
    pub stream: StreamId,
    /// Deferred operations (uids into the sim's op table).
    pub queue: VecDeque<OpUid>,
    pub phase: WorkerPhase,
    /// Ops fully processed by this worker (drain condition bookkeeping).
    pub processed: u64,
    /// Total bytes of kernel-argument deep copies performed (cost metric).
    pub args_bytes_copied: u64,
    /// Time spent holding the GPU lock (occupancy metric).
    pub lock_held_ns: Nanos,
    /// Stamp of the last lock grant.
    pub lock_since: Option<Nanos>,
}

impl WorkerState {
    pub fn new(stream: StreamId) -> Self {
        Self {
            stream,
            queue: VecDeque::new(),
            phase: WorkerPhase::Idle,
            processed: 0,
            args_bytes_copied: 0,
            lock_held_ns: 0,
            lock_since: None,
        }
    }

    /// Hook side: defer an op to the worker (Alg. 5).
    pub fn enqueue(&mut self, op: OpUid, args_bytes: u64) {
        self.queue.push_back(op);
        self.args_bytes_copied += args_bytes;
    }

    /// Is the worker fully drained? This is the condition both the
    /// barrier hook and the ordered-op hook (Alg. 7) wait on: an empty
    /// queue is not enough — the in-flight op must have completed too.
    pub fn drained(&self) -> bool {
        self.queue.is_empty() && self.phase == WorkerPhase::Idle
    }

    pub fn on_lock_granted(&mut self, now: Nanos) {
        self.lock_since = Some(now);
    }

    pub fn on_lock_released(&mut self, now: Nanos) {
        if let Some(s) = self.lock_since.take() {
            self.lock_held_ns += now.saturating_sub(s);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::ids::*;

    fn ws() -> WorkerState {
        WorkerState::new(StreamId { ctx: CtxId(0), idx: 1 })
    }

    #[test]
    fn starts_idle_and_drained() {
        let w = ws();
        assert!(w.drained());
        assert_eq!(w.phase, WorkerPhase::Idle);
    }

    #[test]
    fn enqueue_breaks_drained() {
        let mut w = ws();
        w.enqueue(OpUid(1), 64);
        assert!(!w.drained());
        assert_eq!(w.args_bytes_copied, 64);
        assert_eq!(w.queue.len(), 1);
    }

    #[test]
    fn in_flight_op_blocks_drain_even_with_empty_queue() {
        let mut w = ws();
        w.enqueue(OpUid(1), 0);
        let op = w.queue.pop_front().unwrap();
        w.phase = WorkerPhase::WaitingOp(op);
        assert!(w.queue.is_empty());
        assert!(!w.drained(), "Alg. 7: must wait for in-flight op too");
        w.phase = WorkerPhase::Idle;
        assert!(w.drained());
    }

    #[test]
    fn lock_occupancy_accounting() {
        let mut w = ws();
        w.on_lock_granted(1_000);
        w.on_lock_released(4_500);
        w.on_lock_granted(10_000);
        w.on_lock_released(10_100);
        assert_eq!(w.lock_held_ns, 3_600);
    }
}
