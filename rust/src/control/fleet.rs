//! Sharded multi-GPU serving fleet.
//!
//! The paper's controller serialises GPU operations onto a *single*
//! embedded Volta. This module scales that guarantee horizontally: a
//! fleet of `N` shards, each owning its **own**
//! [`GpuGate`](crate::control::gate::GpuGate) +
//! [`AccessPolicy`](crate::control::policy::AccessPolicy) instance, so
//! per-GPU temporal isolation holds unchanged on every shard while
//! aggregate throughput scales with the shard count.
//!
//! Three layers:
//!
//! * [`Placement`] — the routing policy: round-robin, least-loaded (by
//!   shard queue depth), or payload-affinity (a payload's warm state —
//!   compiled executables, caches — stays on one shard).
//! * [`ShardRouter`] — the placement engine. Thread-safe and allocation
//!   -light; `route` picks a shard and bumps its depth, `complete`
//!   releases it. Routing is *advisory* (a racing `route` may observe a
//!   slightly stale depth), which is exactly how production load
//!   balancers behave; every correctness property (per-shard isolation,
//!   FIFO admission) is enforced by the shards' own gates, never by the
//!   router.
//! * [`serve_fleet`] — runs a [`ServeSpec`]'s clients across the fleet:
//!   clients are routed once at admission (a client keeps its shard for
//!   the whole run, like a sticky connection), shards then execute
//!   concurrently via [`parallel_map`](crate::harness::parallel_map)
//!   (they model independent devices), and each shard internally runs
//!   the ordinary [`serve`] loop with its own FIFO gate. Reports are
//!   merged into a [`FleetReport`]: per-shard breakdowns plus fleet
//!   -level latency quantiles and gate histograms (via
//!   [`Histogram::merge`](crate::metrics::stats::Histogram::merge)).
//!
//! The simulator models the same topology: `SimConfig::num_gpus` gives
//! [`Sim`](crate::gpu::Sim) one lock/SM-bank/L2/copy-engine per shard.
//! DESIGN.md §8 documents the router contract and the isolation
//! invariant.
//!
//! # Example
//!
//! ```
//! use cook::config::StrategyKind;
//! use cook::control::fleet::{serve_fleet, FleetSpec, Placement, ShardRouter};
//! use cook::control::serving::{ServeSpec, SyntheticBackend};
//!
//! // Routing alone: round-robin spreads clients evenly.
//! let router = ShardRouter::new(4, Placement::RoundRobin);
//! for _ in 0..8 {
//!     router.route(0);
//! }
//! assert!((0..4).all(|s| router.depth(s) == 2));
//!
//! // End-to-end: 4 clients over 2 shards, each shard with its own gate.
//! let base = ServeSpec::new(StrategyKind::Worker, "dna")
//!     .with_clients(4)
//!     .with_requests(2);
//! let spec = FleetSpec::new(base, 2, Placement::RoundRobin);
//! let report = serve_fleet(&spec, &SyntheticBackend::new(20)).unwrap();
//! assert_eq!(report.total(), 8);
//! assert_eq!(report.shards.len(), 2);
//! ```

use crate::config::StrategyKind;
use crate::control::arbiter::{class_of, ArbiterKind, CreditBank, CreditSnapshot};
use crate::control::fault::{
    panic_msg, Breaker, FaultReport, HealthSnapshot, ShardHealth,
};
use crate::control::concurrency::{ConcurrencyMode, ModeGate};
use crate::control::gate::GateStats;
use crate::control::policy::AccessPolicy;
use crate::control::serving::{
    admit, build_class_reports, build_latency_stats, fold_open_outs, make_gate, offered_rate_hz,
    open_worker, serve, ClassReport, OpenWorkerCtx, OpenWorkerOut, Pending, ServeBackend,
    ServeReport, ServeSpec,
};
use crate::control::traffic::{AdmissionQueue, ShedPolicy, TrafficReport};
use crate::metrics::stats::LatencyStats;
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::fmt;
use std::panic::AssertUnwindSafe;
use std::str::FromStr;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Barrier, PoisonError, RwLock};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------
// placement
// ---------------------------------------------------------------------

/// How the router places a client on a shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Placement {
    /// Strict rotation: client `k` lands on shard `k % N`. Fair by
    /// construction, blind to load and payload.
    RoundRobin,
    /// Pick the shard with the smallest current queue depth (ties break
    /// to the lowest shard id, keeping placement deterministic).
    LeastLoaded,
    /// Sticky payload affinity: the first client of a payload is placed
    /// least-loaded, every later client of the same payload follows it —
    /// so a payload's warm state (compiled executables, L2 residency)
    /// concentrates on one shard.
    Affinity,
}

impl Placement {
    pub const ALL: [Placement; 3] =
        [Self::RoundRobin, Self::LeastLoaded, Self::Affinity];

    pub fn name(&self) -> &'static str {
        match self {
            Self::RoundRobin => "rr",
            Self::LeastLoaded => "least-loaded",
            Self::Affinity => "affinity",
        }
    }
}

impl fmt::Display for Placement {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for Placement {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "rr" | "round-robin" => Ok(Self::RoundRobin),
            "least-loaded" | "ll" => Ok(Self::LeastLoaded),
            "affinity" | "payload-affinity" => Ok(Self::Affinity),
            other => Err(format!(
                "unknown placement '{other}' (expected rr|least-loaded|affinity)"
            )),
        }
    }
}

// ---------------------------------------------------------------------
// router
// ---------------------------------------------------------------------

/// Routes work onto fleet shards per the configured [`Placement`].
///
/// Depth accounting: [`ShardRouter::route`] increments the chosen
/// shard's depth and [`ShardRouter::complete`] decrements it, so
/// `LeastLoaded` reacts to whatever granularity the caller routes at —
/// per client (sticky sessions, what [`serve_fleet`] does) or per
/// request. The scan-then-increment is not one atomic step: two racing
/// routes may pick the same shard. That is deliberate (see module docs)
/// — the router balances, the per-shard gate *enforces*.
#[derive(Debug)]
pub struct ShardRouter {
    placement: Placement,
    rr_next: AtomicUsize,
    depths: Vec<AtomicUsize>,
    /// Payload slot -> shard, first-come sticky (affinity placement).
    /// `RwLock`, not `Mutex`: after warm-up every arrival is a pure
    /// lookup, and sticky routing must not serialise all arrivals on one
    /// exclusive lock — readers proceed concurrently; the write lock is
    /// taken only on a miss (first client of a payload).
    affinity: RwLock<HashMap<usize, usize>>,
}

impl ShardRouter {
    pub fn new(shards: usize, placement: Placement) -> Self {
        assert!(shards >= 1, "a fleet needs at least one shard");
        Self {
            placement,
            rr_next: AtomicUsize::new(0),
            depths: (0..shards).map(|_| AtomicUsize::new(0)).collect(),
            affinity: RwLock::new(HashMap::new()),
        }
    }

    pub fn num_shards(&self) -> usize {
        self.depths.len()
    }

    pub fn placement(&self) -> Placement {
        self.placement
    }

    /// Current queue depth of `shard` (routed minus completed).
    pub fn depth(&self, shard: usize) -> usize {
        self.depths[shard].load(Ordering::Relaxed)
    }

    /// Shallowest shard; ties break to the lowest id.
    fn least_loaded(&self) -> usize {
        let mut best = 0;
        let mut best_depth = usize::MAX;
        for (i, d) in self.depths.iter().enumerate() {
            let depth = d.load(Ordering::Relaxed);
            if depth < best_depth {
                best = i;
                best_depth = depth;
            }
        }
        best
    }

    /// Place one unit of work for `payload_slot` (an index identifying
    /// the payload, e.g. its slot in `ServeSpec::payloads`); returns the
    /// chosen shard with its depth already incremented. Pair with
    /// [`ShardRouter::complete`] when the work leaves the shard.
    pub fn route(&self, payload_slot: usize) -> usize {
        let shard = match self.placement {
            Placement::RoundRobin => {
                // Modular increment (ISSUE 4): a plain `fetch_add % N`
                // breaks strict rotation when the counter wraps at
                // `usize::MAX` and N doesn't divide it — the wrap jumps
                // the rotation back to 0, double-serving a shard. Keeping
                // the counter in [0, N) makes wrap-around a non-event.
                self.rr_next
                    .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| {
                        Some(n.wrapping_add(1) % self.num_shards())
                    })
                    .expect("fetch_update closure is infallible")
                    % self.num_shards()
            }
            Placement::LeastLoaded => self.least_loaded(),
            Placement::Affinity => {
                // Read-path fast-hit: the overwhelmingly common case is a
                // warm payload already pinned to its shard.
                let hit = self
                    .affinity
                    .read()
                    .unwrap_or_else(PoisonError::into_inner)
                    .get(&payload_slot)
                    .copied();
                match hit {
                    Some(s) => s,
                    None => {
                        let mut map =
                            self.affinity.write().unwrap_or_else(PoisonError::into_inner);
                        // Re-check under the write lock: a racing miss may
                        // have pinned the payload between our read and
                        // write — stickiness must win over a second
                        // least-loaded pick.
                        match map.get(&payload_slot) {
                            Some(&s) => s,
                            None => {
                                let s = self.least_loaded();
                                map.insert(payload_slot, s);
                                s
                            }
                        }
                    }
                }
            }
        };
        self.depths[shard].fetch_add(1, Ordering::Relaxed);
        shard
    }

    /// Work routed to `shard` finished: release its depth unit.
    ///
    /// The decrement is checked, never wrapping: a `complete` without a
    /// matching `route`/`transfer` (the signature of a steal racing a
    /// completion with broken bookkeeping) saturates at zero in release
    /// builds — an advisory counter must stay advisory, not poison the
    /// `LeastLoaded` scan with a ~`usize::MAX` depth — and trips a
    /// `debug_assert` in debug builds so the bug is loud where tests run.
    pub fn complete(&self, shard: usize) {
        let balanced = self.depths[shard].fetch_update(
            Ordering::Relaxed,
            Ordering::Relaxed,
            |d| d.checked_sub(1),
        );
        debug_assert!(
            balanced.is_ok(),
            "router depth underflow on shard {shard}: complete without a matching route"
        );
    }

    /// Move one routed unit from `from` to `to`: the open-loop dispatcher
    /// diverts a request when the routed shard's admission queue is full,
    /// a worker steals a burst from a deeper shard, or a retiring shard's
    /// backlog is requeued — and the depth accounting must follow it.
    /// Checked like [`ShardRouter::complete`]: the `from` decrement
    /// asserts in debug builds and saturates in release.
    pub fn transfer(&self, from: usize, to: usize) {
        if from == to {
            return;
        }
        self.complete(from);
        self.depths[to].fetch_add(1, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------
// fleet spec + report
// ---------------------------------------------------------------------

/// Configuration of one fleet serving run: a base [`ServeSpec`] (whose
/// clients are distributed over the fleet) plus the shard count and
/// placement policy.
#[derive(Debug, Clone)]
pub struct FleetSpec {
    pub base: ServeSpec,
    pub shards: usize,
    pub placement: Placement,
    /// Circuit-breaker thresholds applied per shard (open-loop fleets;
    /// DESIGN.md §12).
    pub breaker: Breaker,
    /// Elastic autoscaling bounds (DESIGN.md §15). `None` (the default)
    /// keeps the fixed-size fleet path byte-identical to the pre-elastic
    /// code; `Some` hands the run to `control::elastic`, with `shards`
    /// as the slot pool (= `autoscale.max`).
    pub autoscale: Option<crate::control::elastic::AutoscaleSpec>,
}

impl FleetSpec {
    pub fn new(base: ServeSpec, shards: usize, placement: Placement) -> Self {
        Self { base, shards, placement, breaker: Breaker::default(), autoscale: None }
    }

    /// Override the per-shard circuit-breaker thresholds.
    pub fn with_breaker(mut self, breaker: Breaker) -> Self {
        self.breaker = breaker;
        self
    }

    /// Enable elastic autoscaling between `auto.min` and `auto.max`
    /// live shards (open-loop arrivals only; `shards` must equal
    /// `auto.max` — the fleet pre-allocates one slot per possible shard).
    pub fn with_autoscale(mut self, auto: crate::control::elastic::AutoscaleSpec) -> Self {
        self.autoscale = Some(auto);
        self
    }

    fn validate(&self) -> Result<()> {
        if self.shards == 0 {
            return Err(anyhow!("a fleet needs at least one shard"));
        }
        if let Some(auto) = &self.autoscale {
            auto.validate().map_err(|e| anyhow!(e))?;
            if auto.max != self.shards {
                return Err(anyhow!(
                    "autoscale max ({}) must equal the fleet's shard slot pool ({})",
                    auto.max,
                    self.shards
                ));
            }
            if !self.base.traffic.arrivals.is_open_loop() {
                return Err(anyhow!(
                    "autoscale needs open-loop arrivals (--arrivals poisson|bursty|ramp): \
                     closed-loop fleets have no admission queues to scale against"
                ));
            }
        }
        Ok(())
    }
}

/// One shard's slice of a fleet run.
#[derive(Debug)]
pub struct ShardReport {
    pub shard: usize,
    /// Clients routed to this shard (0 = the shard idled all run).
    pub clients: usize,
    /// The shard's full serving report; `None` when no client was routed
    /// here (or, under a fault plan, when the whole shard crashed).
    pub report: Option<ServeReport>,
    /// Why the shard failed (panic or infrastructure error), when a
    /// fault plan let the fleet survive it instead of aborting.
    pub error: Option<String>,
    /// Final breaker state (health-managed open-loop fleets only).
    pub health: Option<HealthSnapshot>,
}

/// Result of a fleet serving run: per-shard breakdowns plus merged
/// fleet-level latency and gate statistics.
#[derive(Debug)]
pub struct FleetReport {
    pub strategy: StrategyKind,
    /// Concurrency mode every shard was admitted under (DESIGN.md §14).
    pub concurrency: ConcurrencyMode,
    pub placement: Placement,
    pub clients: usize,
    pub requests_per_client: usize,
    pub batch: usize,
    /// Fleet wall-clock (shards run concurrently; this is the makespan).
    pub wall_s: f64,
    /// Per-request latency distribution merged across every shard, ms
    /// (sketch merge; exact vectors survive on the `--exact-quantiles`
    /// path, where they are re-sorted once at fleet assembly).
    pub latency: LatencyStats,
    /// One entry per shard, in shard-id order.
    pub shards: Vec<ShardReport>,
    /// Per-tenant-class breakdowns merged across shards (empty unless
    /// classes are configured).
    pub classes: Vec<ClassReport>,
    /// Gate wait/hold statistics merged across shards (None for ungated
    /// strategies).
    pub gate: Option<GateStats>,
    /// Fleet-wide credit-bank counters (credit arbiter, open loop only —
    /// one bank is shared by every shard's admission, so per-tenant
    /// budgets hold fleet-wide, not per shard).
    pub credits: Option<CreditSnapshot>,
    /// Traffic/SLO accounting merged across shards (Some for open-loop
    /// runs); `shed` counts requests that found **every** shard's
    /// admission queue full.
    pub traffic: Option<TrafficReport>,
    /// Fault/recovery accounting merged across shards (Some whenever a
    /// fault plan was active or the watchdog/breakers fired).
    pub fault: Option<FaultReport>,
    /// Scale-event accounting (Some only for autoscaled runs;
    /// DESIGN.md §15).
    pub elastic: Option<crate::control::elastic::ElasticReport>,
}

impl FleetReport {
    pub fn total(&self) -> usize {
        self.clients * self.requests_per_client
    }

    /// Aggregate fleet throughput: completed requests over the fleet's
    /// wall-clock makespan (shed traffic never inflates throughput).
    pub fn ips(&self) -> f64 {
        self.latency.count() as f64 / self.wall_s.max(1e-9)
    }

    /// Nearest-rank quantile of the merged latencies; 0.0 when empty.
    /// Exact on the `--exact-quantiles` path, sketch extraction (<= 2%
    /// relative error) otherwise.
    pub fn latency_p(&self, q: f64) -> f64 {
        self.latency.quantile(q)
    }

    /// Shards that actually served clients.
    pub fn active_shards(&self) -> usize {
        self.shards.iter().filter(|s| s.clients > 0).count()
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "fleet: {} shards ({}), strategy {}: {} clients x {} requests \
             (batch {}): {:.1} IPS aggregate; latency ms p50={:.2} p95={:.2} \
             p99={:.2} max={:.2}",
            self.shards.len(),
            self.placement,
            self.strategy,
            self.clients,
            self.requests_per_client,
            self.batch,
            self.ips(),
            self.latency_p(0.50),
            self.latency_p(0.95),
            self.latency_p(0.99),
            self.latency.max(),
        );
        // Cook output stays byte-identical to the pre-refactor render.
        if !self.concurrency.is_cook() {
            out.push_str(&format!("\n  concurrency {}", self.concurrency));
        }
        for s in &self.shards {
            match &s.report {
                Some(r) => out.push_str(&format!(
                    "\n  shard {}: {} clients, {:.1} IPS; p50={:.2} p95={:.2} max={:.2} ms",
                    s.shard,
                    s.clients,
                    r.ips(),
                    r.latency_p(0.50),
                    r.latency_p(0.95),
                    r.latency.max(),
                )),
                None if s.error.is_some() => {
                    out.push_str(&format!("\n  shard {}: FAILED", s.shard))
                }
                None => out.push_str(&format!("\n  shard {}: idle (no clients routed)", s.shard)),
            }
            if let Some(h) = &s.health {
                if h.ejections > 0 {
                    out.push_str(&format!(
                        " [health {}: ejected {}x, reinstated {}x]",
                        h.state, h.ejections, h.reinstatements
                    ));
                }
            }
            if let Some(e) = &s.error {
                out.push_str(&format!(" — {e}"));
            }
        }
        for c in &self.classes {
            out.push_str(&format!(
                "\n  class {:<8} completed={}/{} goodput {:.1}/s; \
                 p50={:.2} p95={:.2} ms; SLO {:.0} ms attainment {:.1}%",
                c.name,
                c.completed,
                c.offered,
                c.goodput(self.wall_s),
                c.latency.quantile(0.50),
                c.latency.quantile(0.95),
                c.slo_ms,
                c.slo_attainment_pct(),
            ));
        }
        if let Some(g) = &self.gate {
            for line in g.render().lines() {
                out.push_str("\n  fleet ");
                out.push_str(line);
            }
        }
        if let Some(t) = &self.traffic {
            for line in t.render(self.wall_s).lines() {
                out.push_str("\n  fleet ");
                out.push_str(line);
            }
        }
        if let Some(f) = &self.fault {
            if !f.is_empty() {
                for line in f.render().lines() {
                    out.push_str("\n  fleet ");
                    out.push_str(line);
                }
            }
        }
        if let Some(e) = &self.elastic {
            for line in e.render().lines() {
                out.push_str("\n  ");
                out.push_str(line);
            }
        }
        out
    }
}

// ---------------------------------------------------------------------
// the fleet serve loop
// ---------------------------------------------------------------------

/// Serve `spec.base`'s clients across a fleet of `spec.shards` shards.
///
/// Each client is routed once (it keeps its shard — and hence its warm
/// executor and its position in that shard's FIFO — for the whole run),
/// then every non-idle shard runs the ordinary [`serve`] loop
/// concurrently with its **own** [`GpuGate`](crate::control::gate::GpuGate)
/// and policy instance. The
/// per-GPU isolation guarantee is therefore exactly the single-GPU one,
/// per shard; nothing is shared across shards but the backend.
pub fn serve_fleet(spec: &FleetSpec, backend: &dyn ServeBackend) -> Result<FleetReport> {
    spec.validate()?;
    let base = &spec.base;
    base.validate()?;
    if spec.autoscale.is_some() {
        // Elastic fleets own their whole serve loop (hot-add,
        // drain-then-retire, stealing); validate() already pinned the
        // open-loop requirement. Fixed fleets never enter this path, so
        // their output stays byte-identical.
        return crate::control::elastic::serve_fleet_elastic(spec, backend);
    }
    if base.traffic.arrivals.is_open_loop() {
        return serve_fleet_open_loop(spec, backend);
    }
    let router = ShardRouter::new(spec.shards, spec.placement);
    // Admission-time routing: client c serves payloads[c % len] (the
    // ServeSpec contract), and its payload slot is what affinity keys on.
    let mut assigned: Vec<Vec<usize>> = vec![Vec::new(); spec.shards];
    for c in 0..base.clients {
        let slot = c % base.payloads.len();
        let shard = router.route(slot);
        assigned[shard].push(slot);
    }
    // Per-shard sub-specs. A sub-spec maps its client `i` to
    // `payloads[i % len]`, so the payload list must reproduce each routed
    // client's payload positionally; compressing it to its minimal period
    // keeps that mapping while collapsing e.g. [dna, dna] -> [dna], so a
    // single-payload shard reports one per-payload row, not one per
    // client.
    let subs: Vec<Option<ServeSpec>> = assigned
        .iter()
        .enumerate()
        .map(|(shard, slots)| {
            if slots.is_empty() {
                return None;
            }
            let names: Vec<&str> =
                slots.iter().map(|&s| base.payloads[s].as_str()).collect();
            let period = (1..=names.len())
                .find(|&p| (0..names.len()).all(|i| names[i] == names[i % p]))
                .expect("p = len always reproduces the sequence");
            let mut sub = base.clone();
            sub.payloads = names[..period].iter().map(|s| s.to_string()).collect();
            sub.clients = slots.len();
            // The shard id selects shard-scoped fault clauses and keys
            // the plan's injection counters.
            sub.shard = shard;
            Some(sub)
        })
        .collect();

    let t0 = Instant::now();
    // Shards model independent GPUs: fan them out. Within a shard the
    // ordinary serve loop spawns that shard's client/stream threads. A
    // shard that panics (an injected boot crash, or any organic panic)
    // is contained here: the fleet survives with a failed ShardReport.
    let results: Vec<Option<Result<ServeReport>>> = crate::harness::parallel::parallel_map(
        subs,
        |sub| {
            sub.map(|s| {
                match std::panic::catch_unwind(AssertUnwindSafe(|| serve(&s, backend))) {
                    Ok(r) => r,
                    Err(p) => Err(anyhow!("shard panicked: {}", panic_msg(p))),
                }
            })
        },
    );
    let wall_s = t0.elapsed().as_secs_f64();

    // Under a fault plan, a failed shard is an expected outcome: record
    // it and keep the fleet's report. Without one, fail fast as before.
    let tolerate = backend.fault_plan().is_some();
    let mut shards = Vec::with_capacity(spec.shards);
    let mut latency = LatencyStats::new(base.exact_quantiles);
    let mut gate: Option<GateStats> = None;
    let mut classes: Vec<ClassReport> = Vec::new();
    let mut fault = FaultReport::default();
    let mut any_ok = false;
    let mut first_err: Option<anyhow::Error> = None;
    for (shard, result) in results.into_iter().enumerate() {
        let (report, error) = match result {
            None => (None, None),
            Some(Ok(r)) => {
                any_ok = true;
                latency.merge(&r.latency);
                if let Some(g) = &r.gate {
                    match &mut gate {
                        Some(merged) => merged.merge(g),
                        None => gate = Some(g.clone()),
                    }
                }
                // Every shard ran the same class list; merge by position.
                for (i, c) in r.classes.iter().enumerate() {
                    match classes.get_mut(i) {
                        Some(m) => m.merge(c),
                        None => classes.push(c.clone()),
                    }
                }
                if let Some(f) = &r.fault {
                    fault.merge(f);
                }
                (Some(r), None)
            }
            Some(Err(e)) => {
                let e = anyhow!("shard {shard}: {e}");
                if !tolerate {
                    return Err(e);
                }
                let msg = e.to_string();
                first_err.get_or_insert(e);
                (None, Some(msg))
            }
        };
        // A crashed shard shows up ejected, so the report reads like the
        // open-loop breaker view.
        let health = error.as_ref().map(|_| {
            let h = ShardHealth::new(spec.breaker);
            h.on_panic();
            h.snapshot()
        });
        shards.push(ShardReport {
            shard,
            clients: assigned[shard].len(),
            report,
            error,
            health,
        });
    }
    if !any_ok {
        if let Some(e) = first_err {
            return Err(e);
        }
    }
    if let Some(plan) = backend.fault_plan() {
        // Totals from the plan, not the per-shard sum: a shard that
        // crashed at boot counted its injection but returned no report.
        fault.injected = plan.counts_total();
    }
    fault.ejections += shards.iter().filter(|s| s.error.is_some()).count();
    let fault = (tolerate || !fault.is_empty()).then_some(fault);
    latency.seal();
    Ok(FleetReport {
        strategy: base.strategy,
        concurrency: base.concurrency,
        placement: spec.placement,
        clients: base.clients,
        requests_per_client: base.requests,
        batch: base.batch,
        wall_s,
        latency,
        shards,
        classes,
        gate,
        credits: None,
        traffic: None,
        fault,
        elastic: None,
    })
}

/// Open-loop fleet serving: one paced generator feeds per-shard bounded
/// admission queues, each drained by that shard's worker pool behind its
/// **own** [`GpuGate`]. The router places each arrival; a full queue
/// diverts it to the shallowest shard with room (depth accounting
/// follows via [`ShardRouter::transfer`]), and the generator applies the
/// shed policy only when **every** shard reports a full queue — the
/// "router sheds last" contract of DESIGN.md §9.
fn serve_fleet_open_loop(spec: &FleetSpec, backend: &dyn ServeBackend) -> Result<FleetReport> {
    let base = &spec.base;
    let policy = AccessPolicy::new(base.strategy);
    let tolerate = backend.fault_plan().is_some();
    let resolved: Vec<crate::control::serving::ResolvedPayload> = base
        .payloads
        .iter()
        .map(|p| backend.resolve(p))
        .collect::<Result<_>>()?;
    // Shards beyond the worker count would have an unserved queue; route
    // only over shards that own at least one worker.
    let active = spec.shards.min(base.clients);
    let router = ShardRouter::new(active, spec.placement);
    let queues: Vec<AdmissionQueue<Pending>> =
        (0..active).map(|_| AdmissionQueue::new(base.traffic.queue_cap)).collect();
    let gates: Vec<Option<ModeGate>> = (0..active).map(|_| make_gate(base, policy)).collect();
    // Per-shard circuit breakers. A shard whose boot-crash clause fires
    // starts the run ejected ("the process died"); after the breaker's
    // cooldown a probe request re-admits it — the self-healing loop of
    // DESIGN.md §12.
    let healths: Vec<ShardHealth> =
        (0..active).map(|_| ShardHealth::new(spec.breaker)).collect();
    let mut boot_err: Vec<Option<String>> = (0..active).map(|_| None).collect();
    if let Some(plan) = backend.fault_plan() {
        for s in 0..active {
            if let Err(p) = std::panic::catch_unwind(AssertUnwindSafe(|| plan.check_boot(s))) {
                healths[s].on_panic();
                boot_err[s] = Some(panic_msg(p));
            }
        }
    }
    // Worker c drains shard c % active; PTB's SM-share fallback divides
    // by the shard-local worker count (partitions never span shards).
    let shard_of_worker: Vec<usize> = (0..base.clients).map(|c| c % active).collect();
    let workers_of_shard: Vec<usize> =
        (0..active).map(|s| shard_of_worker.iter().filter(|&&x| x == s).count()).collect();
    let timeout = match base.traffic.shed {
        ShedPolicy::Timeout { ms } => Some(Duration::from_millis(ms)),
        _ => None,
    };
    let total = base.clients * base.requests;
    let offsets = base.traffic.arrivals.schedule_n(total, base.traffic.seed);
    let k = base.classes.len();
    // The credit arbiter's bank is ONE fleet-wide pool per class, shared
    // by every shard's admission and settle path — a tenant's budget
    // bounds its fleet-wide in-flight count, and a request re-routed to
    // another shard keeps the same credit outstanding.
    let credits = (base.arbiter == ArbiterKind::Credit).then(|| {
        CreditBank::new(
            &base.classes,
            u32::try_from(base.traffic.queue_cap).unwrap_or(u32::MAX),
        )
    });
    let shed = AtomicUsize::new(0);
    let routed: Vec<AtomicUsize> = (0..active).map(|_| AtomicUsize::new(0)).collect();
    let warm = Barrier::new(base.clients + 1);
    // Per-shard completion hooks: workers release router depth as
    // requests leave the system.
    let router_ref = &router;
    let done: Vec<Box<dyn Fn() + Sync + '_>> = (0..active)
        .map(|s| Box::new(move || router_ref.complete(s)) as Box<dyn Fn() + Sync + '_>)
        .collect();
    // Per-shard re-route hooks: a worker whose request failed offers it
    // to the shallowest *other* accepting shard. Depth and per-shard
    // offered counts follow the request; the receiving shard's done hook
    // will account it. False = nobody would take it (retry locally).
    let (queues_ref, healths_ref, routed_ref) = (&queues, &healths, &routed);
    let requeue: Vec<Box<dyn Fn(Pending) -> bool + Sync + '_>> = (0..active)
        .map(|from| {
            Box::new(move |p: Pending| {
                let mut order: Vec<usize> =
                    (0..queues_ref.len()).filter(|&x| x != from).collect();
                order.sort_by_key(|&x| (queues_ref[x].len(), x));
                let mut pending = Some(p);
                for to in order {
                    if !healths_ref[to].accepting() {
                        continue;
                    }
                    match queues_ref[to].try_push(pending.take().unwrap()) {
                        Ok(()) => {
                            let _ = routed_ref[from].fetch_update(
                                Ordering::Relaxed,
                                Ordering::Relaxed,
                                |d| d.checked_sub(1),
                            );
                            routed_ref[to].fetch_add(1, Ordering::Relaxed);
                            router_ref.transfer(from, to);
                            return true;
                        }
                        Err(back) => pending = Some(back),
                    }
                }
                false
            }) as Box<dyn Fn(Pending) -> bool + Sync + '_>
        })
        .collect();

    let (outs, wall_s) = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for (c, &shard) in shard_of_worker.iter().enumerate() {
            let (queue, gate, warm, resolved, done, health, req) = (
                &queues[shard],
                gates[shard].as_ref(),
                &warm,
                &resolved,
                &*done[shard],
                &healths[shard],
                &*requeue[shard],
            );
            let share = policy.sm_share(workers_of_shard[shard]);
            let credits = credits.as_ref();
            let handle = s.spawn(move || {
                let ctx = OpenWorkerCtx {
                    backend,
                    resolved,
                    queue,
                    gate,
                    batch: base.batch,
                    timeout,
                    share,
                    client: c,
                    shard,
                    retry: base.retry,
                    tolerate,
                    done: Some(done),
                    health: Some(health),
                    requeue: Some(req),
                    credits,
                    classes: k,
                };
                let out = open_worker(&ctx, warm);
                (shard, out)
            });
            handles.push((shard, handle));
        }
        warm.wait();
        let t0 = Instant::now();
        for (seq, &off) in offsets.iter().enumerate() {
            let arrival_at = t0 + Duration::from_nanos(off);
            let now = Instant::now();
            if arrival_at > now {
                std::thread::sleep(arrival_at - now);
            }
            let slot = seq % resolved.len();
            let class = class_of(seq, k);
            // Credit admission comes before routing: a class out of
            // credits sheds without touching router depth accounting.
            let granted = match (credits.as_ref(), base.traffic.shed) {
                (None, _) => true,
                (Some(b), ShedPolicy::Block) => {
                    b.take_blocking(class);
                    true
                }
                (Some(b), ShedPolicy::Reject) => b.try_take(class),
                (Some(b), ShedPolicy::Timeout { ms }) => {
                    b.take_timeout(class, Duration::from_millis(ms))
                }
            };
            if !granted {
                shed.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            let primary = router.route(slot);
            let mut pending = Some(Pending { slot, seq, arrival_at, attempt: 0, class });
            let mut placed: Option<usize> = None;
            // Health-aware placement: an ejected shard takes no new work
            // (its queue keeps draining); `accepting` also admits the
            // single probe that re-tests a cooled-down shard.
            if healths[primary].accepting() {
                match queues[primary].try_push(pending.take().unwrap()) {
                    Ok(()) => placed = Some(primary),
                    Err(back) => pending = Some(back),
                }
            }
            if placed.is_none() {
                // Divert: shallowest other accepting queue with room.
                let mut order: Vec<usize> = (0..active).filter(|&x| x != primary).collect();
                order.sort_by_key(|&x| (queues[x].len(), x));
                for cand in order {
                    if !healths[cand].accepting() {
                        continue;
                    }
                    match queues[cand].try_push(pending.take().unwrap()) {
                        Ok(()) => {
                            placed = Some(cand);
                            break;
                        }
                        Err(back) => pending = Some(back),
                    }
                }
            }
            match placed {
                Some(s) => {
                    routed[s].fetch_add(1, Ordering::Relaxed);
                    if s != primary {
                        router.transfer(primary, s);
                    }
                }
                None => {
                    // Every shard full: the shed policy decides, against
                    // the shard the router originally picked.
                    if admit(&queues[primary], pending.take().unwrap(), base.traffic.shed) {
                        routed[primary].fetch_add(1, Ordering::Relaxed);
                    } else {
                        if let Some(b) = credits.as_ref() {
                            b.put(class);
                        }
                        shed.fetch_add(1, Ordering::Relaxed);
                        router.complete(primary);
                    }
                }
            }
        }
        for q in &queues {
            q.close();
        }
        let outs: Vec<(usize, OpenWorkerOut)> = handles
            .into_iter()
            .map(|(shard, h)| {
                h.join().unwrap_or_else(|_| {
                    (
                        shard,
                        OpenWorkerOut {
                            error: Some(anyhow!("fleet open-loop worker panicked")),
                            ..OpenWorkerOut::default()
                        },
                    )
                })
            })
            .collect();
        (outs, t0.elapsed().as_secs_f64())
    });

    // Group worker outputs per shard and assemble shard + fleet reports.
    let mut per_shard: Vec<Vec<OpenWorkerOut>> = (0..active).map(|_| Vec::new()).collect();
    for (shard, out) in outs {
        per_shard[shard].push(out);
    }
    let mut shards = Vec::with_capacity(spec.shards);
    let mut fleet_latency = LatencyStats::new(base.exact_quantiles);
    let mut fleet_gate: Option<GateStats> = None;
    let mut fleet_traffic: Option<TrafficReport> = None;
    let mut fleet_fault = FaultReport::default();
    let mut fleet_class_samples: Vec<(usize, f64)> = Vec::new();
    // Span of the arrival schedule: per-shard offered rates are that
    // shard's admitted count over the same span, so the per-shard and
    // fleet-level renders stay mutually consistent.
    let span_s = offsets.last().map(|&l| l as f64 / 1e9).unwrap_or(0.0);
    for (shard, outs) in per_shard.into_iter().enumerate() {
        let o = fold_open_outs(outs, base.traffic.slo_ms);
        let mut shard_err = boot_err[shard].take();
        if let Some(e) = o.error {
            if !tolerate {
                return Err(anyhow!("shard {shard}: {e}"));
            }
            shard_err.get_or_insert(e.to_string());
        }
        let (queue_delay, timed_out, within_slo) = (o.queue_delay, o.timed_out, o.within_slo);
        let completed = o.samples.len();
        let (latency, per_payload) =
            build_latency_stats(o.samples, &base.payloads, base.exact_quantiles);
        fleet_latency.merge(&latency);
        // Shard-level class rows carry completions only (offered falls
        // back to completed): arrivals are routed — and re-routed —
        // fleet-wide, so per-class offered counts are a fleet-level fact.
        let shard_classes = build_class_reports(
            &base.classes,
            o.class_samples.clone(),
            &[],
            base.traffic.slo_ms,
            base.exact_quantiles,
        );
        fleet_class_samples.extend(o.class_samples);
        let gate_stats = gates[shard].as_ref().map(|g| g.stats());
        if let Some(g) = &gate_stats {
            match &mut fleet_gate {
                Some(merged) => merged.merge(g),
                None => fleet_gate = Some(g.clone()),
            }
        }
        // The shard's fault ledger: what the workers saw, what the plan
        // injected here, what the watchdog revoked, how the breaker
        // moved — and how long each closed outage lasted.
        let mut fault = o.fault;
        if let Some(plan) = backend.fault_plan() {
            fault.injected.merge(&plan.counts_for(shard));
        }
        if let Some(g) = &gate_stats {
            fault.revocations += g.revocations;
        }
        let health = healths[shard].snapshot();
        fault.ejections += health.ejections;
        fault.reinstatements += health.reinstatements;
        for ms in healths[shard].drain_recoveries_ms() {
            fault.recover_ms.record(ms);
        }
        fleet_fault.merge(&fault);
        // Per shard, "offered" is what the router admitted here (the
        // fleet-level report accounts for generator-side sheds), and the
        // offered rate is that count over the schedule span — not the
        // whole generator's rate.
        let shard_offered = routed[shard].load(Ordering::Relaxed);
        let shard_traffic = TrafficReport {
            arrivals: base.traffic.arrivals,
            queue_cap: base.traffic.queue_cap,
            shed_policy: base.traffic.shed,
            slo_ms: base.traffic.slo_ms,
            offered: shard_offered,
            completed,
            shed: 0,
            timed_out,
            failed: o.failed,
            retried: fault.retried,
            within_slo,
            queue_delay,
            offered_rate_hz: if span_s > 0.0 { shard_offered as f64 / span_s } else { 0.0 },
        };
        match &mut fleet_traffic {
            Some(merged) => merged.merge(&shard_traffic),
            None => fleet_traffic = Some(shard_traffic.clone()),
        }
        shards.push(ShardReport {
            shard,
            clients: workers_of_shard[shard],
            report: Some(ServeReport {
                strategy: base.strategy,
                concurrency: base.concurrency,
                clients: workers_of_shard[shard],
                requests_per_client: base.requests,
                batch: base.batch,
                wall_s,
                latency,
                per_payload,
                classes: shard_classes,
                gate: gate_stats,
                credits: None,
                traffic: Some(shard_traffic),
                fault: (tolerate || !fault.is_empty()).then_some(fault),
            }),
            error: shard_err,
            health: Some(health),
        });
    }
    for shard in active..spec.shards {
        shards.push(ShardReport { shard, clients: 0, report: None, error: None, health: None });
    }
    if let Some(t) = &mut fleet_traffic {
        t.offered = total;
        t.shed = shed.into_inner();
        // Fleet-level rate is the whole generator's (the per-shard
        // values it was merged from are shard-local).
        t.offered_rate_hz = offered_rate_hz(&offsets);
    }
    fleet_latency.seal();
    let mut fleet_offered_by_class = vec![0usize; k];
    if k > 0 {
        for seq in 0..total {
            fleet_offered_by_class[class_of(seq, k)] += 1;
        }
    }
    let fleet_classes = build_class_reports(
        &base.classes,
        fleet_class_samples,
        &fleet_offered_by_class,
        base.traffic.slo_ms,
        base.exact_quantiles,
    );
    let fleet_fault = (tolerate || !fleet_fault.is_empty()).then_some(fleet_fault);
    Ok(FleetReport {
        strategy: base.strategy,
        concurrency: base.concurrency,
        placement: spec.placement,
        clients: base.clients,
        requests_per_client: base.requests,
        batch: base.batch,
        wall_s,
        latency: fleet_latency,
        shards,
        classes: fleet_classes,
        gate: fleet_gate,
        credits: credits.map(|b| b.snapshot()),
        traffic: fleet_traffic,
        fault: fleet_fault,
        elastic: None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::fault::HealthState;
    use crate::control::policy::AccessPolicy;
    use crate::control::serving::SyntheticBackend;

    fn backend() -> SyntheticBackend {
        SyntheticBackend::new(40)
    }

    // ----------------------------------------------------- placement --

    #[test]
    fn placement_parse_roundtrip() {
        for p in Placement::ALL {
            assert_eq!(p.name().parse::<Placement>().unwrap(), p);
        }
        assert_eq!("round-robin".parse::<Placement>().unwrap(), Placement::RoundRobin);
        assert_eq!("ll".parse::<Placement>().unwrap(), Placement::LeastLoaded);
        assert_eq!("payload-affinity".parse::<Placement>().unwrap(), Placement::Affinity);
        assert!("random".parse::<Placement>().is_err());
    }

    #[test]
    fn round_robin_is_fair_and_ordered() {
        let r = ShardRouter::new(4, Placement::RoundRobin);
        let picks: Vec<usize> = (0..8).map(|_| r.route(0)).collect();
        assert_eq!(picks, vec![0, 1, 2, 3, 0, 1, 2, 3]);
        for s in 0..4 {
            assert_eq!(r.depth(s), 2, "shard {s} not evenly loaded");
        }
    }

    #[test]
    fn least_loaded_picks_the_shallower_queue() {
        let r = ShardRouter::new(3, Placement::LeastLoaded);
        assert_eq!(r.route(0), 0); // all empty: lowest id
        assert_eq!(r.route(0), 1); // depth [1,0,0]
        assert_eq!(r.route(0), 2); // depth [1,1,0]
        assert_eq!(r.route(0), 0); // tie again: lowest id
        // Drain shard 1: it becomes the unique shallowest.
        r.complete(1);
        assert_eq!(r.route(0), 1);
    }

    #[test]
    fn affinity_is_sticky_per_payload() {
        let r = ShardRouter::new(3, Placement::Affinity);
        let first = r.route(7);
        assert_eq!(first, 0, "first payload lands least-loaded");
        // A different payload goes elsewhere (shard 0 now deeper)...
        let other = r.route(8);
        assert_eq!(other, 1);
        // ...but payload 7 keeps returning to its warm shard even though
        // it is now the deepest.
        for _ in 0..5 {
            assert_eq!(r.route(7), first, "affinity must stick");
        }
        assert_eq!(r.depth(first), 6);
    }

    #[test]
    fn round_robin_survives_counter_wrap() {
        // Regression (ISSUE 4): with `fetch_add % N` the rotation breaks
        // when the counter wraps at usize::MAX and N doesn't divide it
        // (usize::MAX % 3 == 0, so ...MAX-1, MAX, wrap-to-0 yielded
        // 2, 0, 0 — shard 0 double-served). Pre-seed the counter at the
        // brink and demand strict rotation across the wrap.
        let r = ShardRouter::new(3, Placement::RoundRobin);
        r.rr_next.store(usize::MAX - 1, Ordering::Relaxed);
        let picks: Vec<usize> = (0..9).map(|_| r.route(0)).collect();
        for w in picks.windows(2) {
            assert_eq!(w[1], (w[0] + 1) % 3, "rotation broke across the wrap: {picks:?}");
        }
        assert!(picks.iter().all(|&s| s < 3));
    }

    #[test]
    #[cfg_attr(debug_assertions, should_panic(expected = "underflow"))]
    fn unmatched_complete_is_loud_in_debug_and_saturates_in_release() {
        // Satellite of ISSUE 10: an unmatched complete (a steal racing a
        // completion with broken bookkeeping) must never wrap the
        // advisory depth to ~usize::MAX. Debug builds assert; release
        // builds saturate at zero and keep balancing.
        let r = ShardRouter::new(2, Placement::LeastLoaded);
        r.complete(0); // nothing routed
        assert_eq!(r.depth(0), 0);
    }

    #[test]
    fn depth_conserved_under_concurrent_route_transfer_complete() {
        // Property (ISSUE 10): every route is balanced by exactly one
        // complete, possibly after a chain of transfers (divert at
        // admission, steal, retire-requeue). Hammered from 4 threads the
        // depths must return to zero — no unit lost, none double-freed.
        let r = &ShardRouter::new(4, Placement::RoundRobin);
        std::thread::scope(|s| {
            for t in 0..4usize {
                s.spawn(move || {
                    for i in 0..2_000usize {
                        let shard = r.route((t * 31 + i) % 7);
                        if i % 3 == 0 {
                            // Steal path: the unit moves shards, then
                            // completes where it landed.
                            let to = (shard + 1 + i % 3) % 4;
                            r.transfer(shard, to);
                            r.complete(to);
                        } else {
                            r.complete(shard);
                        }
                    }
                });
            }
        });
        for shard in 0..4 {
            assert_eq!(r.depth(shard), 0, "shard {shard} depth not conserved");
        }
    }

    #[test]
    #[should_panic(expected = "at least one shard")]
    fn zero_shard_router_rejected() {
        let _ = ShardRouter::new(0, Placement::RoundRobin);
    }

    // --------------------------------------------------------- fleet --

    #[test]
    fn fleet_serves_all_requests_across_shards() {
        let base = ServeSpec::new(StrategyKind::Worker, "dna")
            .with_clients(4)
            .with_requests(3);
        let spec = FleetSpec::new(base, 2, Placement::RoundRobin);
        let r = serve_fleet(&spec, &backend()).unwrap();
        assert_eq!(r.total(), 12);
        assert_eq!(r.latency.count(), 12);
        assert_eq!(r.shards.len(), 2);
        for s in &r.shards {
            assert_eq!(s.clients, 2, "round-robin must split 4 clients 2/2");
            let rep = s.report.as_ref().unwrap();
            assert_eq!(rep.total(), 6);
        }
        assert!(r.ips() > 0.0);
        assert!(r.latency_p(0.99) >= r.latency_p(0.5));
    }

    #[test]
    fn fleet_gate_is_per_shard_and_merged() {
        let base = ServeSpec::new(StrategyKind::Synced, "dna")
            .with_clients(4)
            .with_requests(2);
        let spec = FleetSpec::new(base, 2, Placement::RoundRobin);
        let r = serve_fleet(&spec, &backend()).unwrap();
        // Each shard gates independently: 2 warm-ups + 4 request grants.
        for s in &r.shards {
            let g = s.report.as_ref().unwrap().gate.as_ref().unwrap();
            assert_eq!(g.grants(), 6, "shard {}", s.shard);
        }
        // The fleet view merges both shards' histograms.
        assert_eq!(r.gate.as_ref().unwrap().grants(), 12);
    }

    #[test]
    fn fleet_ungated_strategy_reports_no_gate() {
        let base = ServeSpec::new(StrategyKind::None, "dna")
            .with_clients(2)
            .with_requests(2);
        let r = serve_fleet(&FleetSpec::new(base, 2, Placement::RoundRobin), &backend())
            .unwrap();
        assert!(r.gate.is_none());
        assert!(!AccessPolicy::new(StrategyKind::None).gated());
    }

    #[test]
    fn one_shard_fleet_degenerates_to_plain_serving() {
        let base = ServeSpec::new(StrategyKind::Worker, "dna")
            .with_clients(2)
            .with_requests(4);
        let r = serve_fleet(&FleetSpec::new(base, 1, Placement::LeastLoaded), &backend())
            .unwrap();
        assert_eq!(r.shards.len(), 1);
        assert_eq!(r.active_shards(), 1);
        let inner = r.shards[0].report.as_ref().unwrap();
        assert_eq!(inner.total(), r.total());
        // 2 warm-ups + 2 clients x 4 requests, all through ONE gate.
        assert_eq!(r.gate.unwrap().grants(), 10);
    }

    #[test]
    fn idle_shards_are_reported_idle() {
        // 1 client over 4 shards: three shards never see work.
        let base = ServeSpec::new(StrategyKind::Worker, "dna")
            .with_clients(1)
            .with_requests(2);
        let r = serve_fleet(&FleetSpec::new(base, 4, Placement::RoundRobin), &backend())
            .unwrap();
        assert_eq!(r.active_shards(), 1);
        assert_eq!(r.shards.iter().filter(|s| s.report.is_none()).count(), 3);
        assert_eq!(r.total(), 2);
        assert!(r.render().contains("idle"));
    }

    #[test]
    fn affinity_keeps_each_payload_on_one_shard() {
        // 4 clients, 2 payloads, affinity: clients of payload 'dna' all
        // land together, clients of 'mmult' all land together.
        let base = ServeSpec::new(StrategyKind::Worker, "dna")
            .with_payloads(vec!["dna".into(), "mmult".into()])
            .with_clients(4)
            .with_requests(2);
        let r = serve_fleet(&FleetSpec::new(base, 2, Placement::Affinity), &backend())
            .unwrap();
        for s in &r.shards {
            let rep = s.report.as_ref().unwrap();
            assert_eq!(
                rep.per_payload.len(),
                1,
                "shard {} serves a single payload under affinity",
                s.shard
            );
        }
        let names: Vec<&str> = r
            .shards
            .iter()
            .map(|s| s.report.as_ref().unwrap().per_payload[0].payload.as_str())
            .collect();
        assert!(names.contains(&"dna") && names.contains(&"mmult"));
    }

    #[test]
    fn invalid_fleet_rejected() {
        let base = ServeSpec::new(StrategyKind::None, "dna");
        let spec = FleetSpec::new(base, 0, Placement::RoundRobin);
        assert!(serve_fleet(&spec, &backend()).is_err());
    }

    #[test]
    fn fleet_quantiles_equal_resorted_concatenation() {
        // Merge-then-sort invariant (ISSUE 4), now the sketch-vs-exact
        // cross-check (ISSUE 5): on the exact-quantiles path the fleet's
        // latency_p must equal the nearest-rank quantile of the re-sorted
        // concatenation of every shard's latencies, and the merged
        // streaming sketch must agree with that exact value within its
        // documented relative error bound (GAMMA - 1).
        use crate::metrics::stats::{nearest_rank, QuantileSketch};
        let base = ServeSpec::new(StrategyKind::Worker, "dna")
            .with_payloads(vec!["dna".into(), "mmult".into()])
            .with_clients(6)
            .with_requests(4)
            .with_exact_quantiles(true);
        let r = serve_fleet(&FleetSpec::new(base, 3, Placement::RoundRobin), &backend())
            .unwrap();
        let mut concat: Vec<f64> = r
            .shards
            .iter()
            .filter_map(|s| s.report.as_ref())
            .flat_map(|rep| rep.latency.exact_values().expect("exact path").iter().copied())
            .collect();
        concat.sort_by(f64::total_cmp);
        assert_eq!(concat.len(), r.latency.count());
        assert!(r.latency.is_exact(), "fleet merge must keep the exact path");
        for q in [0.0, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 1.0] {
            let exact = nearest_rank(&concat, q);
            assert_eq!(
                r.latency_p(q),
                exact,
                "fleet quantile q={q} diverged from re-sorted concatenation"
            );
            // The merged sketch tracks the exact quantile within bound.
            let approx = r.latency.sketch.quantile(q);
            assert!(
                (approx - exact).abs() / exact.max(1e-12)
                    <= QuantileSketch::GAMMA - 1.0 + 1e-9,
                "q={q}: merged sketch {approx} vs exact {exact}"
            );
        }
    }

    #[test]
    fn fleet_default_path_is_sketch_only() {
        let base = ServeSpec::new(StrategyKind::Worker, "dna")
            .with_clients(2)
            .with_requests(3);
        let r = serve_fleet(&FleetSpec::new(base, 2, Placement::RoundRobin), &backend())
            .unwrap();
        assert!(!r.latency.is_exact());
        assert_eq!(r.latency.count(), 6);
        assert!(r.latency_p(0.99) >= r.latency_p(0.5));
    }

    // -------------------------------------------------- open-loop fleet --

    #[test]
    fn open_loop_fleet_conserves_requests_and_gates_per_shard() {
        use crate::control::traffic::{ArrivalProcess, TrafficSpec};
        let base = ServeSpec::new(StrategyKind::Synced, "dna")
            .with_clients(4)
            .with_requests(5)
            .with_traffic(TrafficSpec {
                arrivals: ArrivalProcess::Poisson { rate_hz: 2_000.0 },
                queue_cap: 32,
                shed: ShedPolicy::Block,
                slo_ms: 1_000.0,
                seed: 5,
            });
        let r = serve_fleet(&FleetSpec::new(base, 2, Placement::RoundRobin), &backend())
            .unwrap();
        let t = r.traffic.as_ref().expect("open-loop fleet must report traffic");
        assert_eq!(t.offered, 20);
        assert!(t.accounted(), "requests leaked across the fleet");
        assert_eq!(t.completed, 20, "blocking policy completes everything");
        assert_eq!(r.latency.count(), 20);
        assert_eq!(r.shards.len(), 2);
        // Per-shard: own gate, own queue accounting.
        let mut shard_offered = 0;
        for s in &r.shards {
            let rep = s.report.as_ref().unwrap();
            assert!(rep.gate.is_some(), "shard {} must gate", s.shard);
            let st = rep.traffic.as_ref().unwrap();
            assert_eq!(
                st.completed + st.timed_out + st.failed,
                st.offered,
                "shard {}",
                s.shard
            );
            shard_offered += st.offered;
        }
        assert_eq!(shard_offered, 20, "router must place every admitted arrival");
        let text = r.render();
        assert!(text.contains("goodput"), "{text}");
    }

    #[test]
    fn open_loop_fleet_sheds_only_when_all_queues_full() {
        use crate::control::traffic::{ArrivalProcess, TrafficSpec};
        // Flood 2 shards with tiny queues and slow service: the reject
        // policy must shed, and everything admitted must be accounted.
        let base = ServeSpec::new(StrategyKind::Worker, "dna")
            .with_clients(2)
            .with_requests(30)
            .with_traffic(TrafficSpec {
                arrivals: ArrivalProcess::Poisson { rate_hz: 30_000.0 },
                queue_cap: 2,
                shed: ShedPolicy::Reject,
                slo_ms: 50.0,
                seed: 2,
            });
        let r = serve_fleet(
            &FleetSpec::new(base, 2, Placement::LeastLoaded),
            &SyntheticBackend::new(2_000),
        )
        .unwrap();
        let t = r.traffic.as_ref().unwrap();
        assert_eq!(t.offered, 60);
        assert!(t.shed > 0, "flood against cap-2 queues must shed");
        assert!(t.accounted());
        assert!(t.completed < t.offered);
    }

    #[test]
    fn open_loop_fleet_with_more_shards_than_workers_idles_the_rest() {
        use crate::control::traffic::{ArrivalProcess, TrafficSpec};
        let base = ServeSpec::new(StrategyKind::Worker, "dna")
            .with_clients(2)
            .with_requests(3)
            .with_traffic(TrafficSpec {
                arrivals: ArrivalProcess::Poisson { rate_hz: 1_000.0 },
                queue_cap: 16,
                shed: ShedPolicy::Block,
                slo_ms: 1_000.0,
                seed: 0,
            });
        let r = serve_fleet(&FleetSpec::new(base, 4, Placement::RoundRobin), &backend())
            .unwrap();
        assert_eq!(r.shards.len(), 4);
        assert_eq!(r.active_shards(), 2, "workerless shards must stay idle");
        assert_eq!(r.traffic.as_ref().unwrap().completed, 6);
    }

    // --------------------------------------------------- fault paths --

    fn faulty(spec: &str) -> crate::control::fault::FaultyBackend<SyntheticBackend> {
        let plan = crate::control::fault::FaultPlan::new(spec.parse().unwrap(), 11);
        crate::control::fault::FaultyBackend::new(backend(), std::sync::Arc::new(plan))
    }

    #[test]
    fn fleet_survives_a_boot_crashing_shard() {
        // `crash:shard=1` kills shard 1's serve() at boot. The fleet must
        // contain the panic: shard 1 reports FAILED (and ejected), shard
        // 0 serves its half untouched.
        let base = ServeSpec::new(StrategyKind::Worker, "dna")
            .with_clients(4)
            .with_requests(3);
        let fb = faulty("crash:shard=1");
        let r = serve_fleet(&FleetSpec::new(base, 2, Placement::RoundRobin), &fb).unwrap();
        let failed = &r.shards[1];
        assert!(failed.report.is_none());
        let msg = failed.error.as_ref().expect("crashed shard must carry its error");
        assert!(msg.contains("boot crash"), "{msg}");
        assert_eq!(failed.health.unwrap().state, HealthState::Ejected);
        let ok = &r.shards[0];
        assert_eq!(ok.report.as_ref().unwrap().latency.count(), 6);
        assert_eq!(r.latency.count(), 6, "survivor's work still counts");
        let f = r.fault.as_ref().unwrap();
        assert_eq!(f.injected.crashes, 1);
        assert!(f.ejections >= 1);
        let text = r.render();
        assert!(text.contains("FAILED"), "{text}");
    }

    #[test]
    fn fleet_without_faults_still_fails_fast() {
        // No fault plan: a shard error aborts the fleet as before.
        struct BrokenBackend;
        impl ServeBackend for BrokenBackend {
            fn resolve(&self, payload: &str) -> Result<crate::control::serving::ResolvedPayload> {
                SyntheticBackend::new(10).resolve(payload)
            }
            fn executor(&self) -> Result<Box<dyn crate::control::serving::PayloadExecutor>> {
                Err(anyhow!("no executor today"))
            }
        }
        let base = ServeSpec::new(StrategyKind::None, "dna")
            .with_clients(2)
            .with_requests(1);
        let err = serve_fleet(&FleetSpec::new(base, 2, Placement::RoundRobin), &BrokenBackend)
            .unwrap_err();
        assert!(err.to_string().contains("shard"), "{err}");
    }

    #[test]
    fn render_mentions_fleet_shape() {
        let base = ServeSpec::new(StrategyKind::Synced, "dna")
            .with_clients(2)
            .with_requests(2);
        let r = serve_fleet(&FleetSpec::new(base, 2, Placement::LeastLoaded), &backend())
            .unwrap();
        let text = r.render();
        assert!(text.contains("2 shards"), "{text}");
        assert!(text.contains("least-loaded"), "{text}");
        assert!(text.contains("shard 0"), "{text}");
        assert!(text.contains("gate wait"), "{text}");
    }
}
