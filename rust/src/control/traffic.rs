//! Traffic generation and bounded admission: the offered-load axis.
//!
//! Every serving path in this repository used to be **closed-loop**: each
//! client fires its next request only after the previous one returns, so
//! the offered load can never exceed the service rate and queueing delay
//! is invisible — the classic *coordinated omission* trap, which
//! understates tail latency under real traffic. This module opens that
//! loop:
//!
//! * [`ArrivalProcess`] — seeded, deterministic arrival streams: the
//!   closed loop as before, open-loop Poisson, bursty on/off, and a
//!   linear rate ramp. Identical seeds produce identical streams.
//! * [`ShedPolicy`] + [`AdmissionQueue`] — a bounded queue in front of
//!   each shard's gate with a configurable full-queue policy: `block`
//!   (backpressure onto the generator), `reject` (shed immediately), or
//!   `timeout` (bounded admission wait, plus dequeue-side expiry).
//! * [`TrafficReport`] — SLO accounting where latency is measured from
//!   **arrival** (the scheduled instant, not admission and not dispatch),
//!   reporting goodput, SLO-attainment %, queue-delay histograms, and
//!   shed/timeout counts.
//!
//! The live serving loop ([`crate::control::serving`]), the fleet
//! ([`crate::control::fleet`]) and the simulator
//! ([`crate::config::SimConfig::arrivals`]) all consume the same
//! [`ArrivalProcess`], so the saturation curve has the same shape in
//! wall-clock and in virtual time. DESIGN.md §9 documents the contract.

use crate::metrics::stats::Histogram;
use crate::util::{lock_recover, DetRng, Nanos};
use std::collections::VecDeque;
use std::fmt;
use std::str::FromStr;
use std::sync::{Condvar, Mutex, PoisonError};
use std::time::Duration;

/// RNG stream tag for arrival generation (independent of the simulator's
/// `EXEC`/`STAL` streams).
const ARRIVAL_RNG_TAG: u64 = 0x5452_4646; // "TRFF"

// ---------------------------------------------------------------------
// arrival processes
// ---------------------------------------------------------------------

/// How requests arrive at the serving system.
///
/// All open-loop processes are generated from a seeded [`DetRng`] stream:
/// the schedule is a pure function of (process, seed), never of service
/// progress — that independence is what makes the load *offered* rather
/// than *admitted*.
///
/// # Example
///
/// ```
/// use cook::control::traffic::ArrivalProcess;
///
/// let p: ArrivalProcess = "poisson:200".parse().unwrap();
/// assert!(p.is_open_loop());
/// // Identical seeds produce identical arrival streams.
/// assert_eq!(p.schedule_n(100, 7), p.schedule_n(100, 7));
/// assert_ne!(p.schedule_n(100, 7), p.schedule_n(100, 8));
/// ```
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ArrivalProcess {
    /// Lock-step clients (the pre-traffic behaviour): a client issues its
    /// next request when the previous one returns. No pacing, no sheds.
    ClosedLoop,
    /// Open-loop Poisson arrivals at `rate_hz` (exponential gaps).
    Poisson { rate_hz: f64 },
    /// On/off bursts: Poisson at `rate_hz` during `on_ms` windows,
    /// silence during `off_ms` windows (square-wave modulated Poisson).
    Bursty { rate_hz: f64, on_ms: u64, off_ms: u64 },
    /// Linear rate ramp from `from_hz` to `to_hz` across the run (by
    /// arrival index in [`ArrivalProcess::schedule_n`], by time fraction
    /// in [`ArrivalProcess::schedule_until`]).
    Ramp { from_hz: f64, to_hz: f64 },
}

impl ArrivalProcess {
    /// Does this process pace arrivals independently of completions?
    pub fn is_open_loop(&self) -> bool {
        !matches!(self, Self::ClosedLoop)
    }

    /// Reject non-positive rates/windows up front so serving paths never
    /// divide by zero mid-run.
    pub fn validate(&self) -> Result<(), String> {
        let ok = |r: f64| r.is_finite() && r > 0.0;
        match *self {
            Self::ClosedLoop => Ok(()),
            Self::Poisson { rate_hz } if ok(rate_hz) => Ok(()),
            Self::Bursty { rate_hz, on_ms, off_ms } if ok(rate_hz) && on_ms > 0 && off_ms > 0 => {
                Ok(())
            }
            Self::Ramp { from_hz, to_hz } if ok(from_hz) && ok(to_hz) => Ok(()),
            _ => Err(format!("invalid arrival process '{self}' (rates/windows must be > 0)")),
        }
    }

    /// Instantaneous rate at run fraction `frac` in [0, 1].
    fn rate_at(&self, frac: f64) -> f64 {
        match *self {
            Self::ClosedLoop => 0.0,
            Self::Poisson { rate_hz } | Self::Bursty { rate_hz, .. } => rate_hz,
            Self::Ramp { from_hz, to_hz } => from_hz + (to_hz - from_hz) * frac.clamp(0.0, 1.0),
        }
    }

    /// One exponential inter-arrival gap (ns) at `rate_hz`.
    fn exp_gap_ns(rng: &mut DetRng, rate_hz: f64) -> f64 {
        // u in [0,1) => (1-u) in (0,1]: ln never sees 0.
        -(1.0 - rng.f64()).ln() / rate_hz * 1e9
    }

    /// Push `t_ns` out of a bursty off-window (to the start of the next
    /// on-window); identity for the other processes.
    fn skip_off_phase(&self, t_ns: f64) -> f64 {
        if let Self::Bursty { on_ms, off_ms, .. } = self {
            let on = *on_ms as f64 * 1e6;
            let cycle = on + *off_ms as f64 * 1e6;
            let pos = t_ns % cycle;
            if pos >= on {
                return t_ns - pos + cycle;
            }
        }
        t_ns
    }

    /// Exactly `n` arrival offsets (ns from run start), sorted. The
    /// closed loop has no schedule: it returns `n` zeros (callers gate on
    /// [`ArrivalProcess::is_open_loop`] before pacing).
    pub fn schedule_n(&self, n: usize, seed: u64) -> Vec<Nanos> {
        if !self.is_open_loop() {
            return vec![0; n];
        }
        let mut rng = DetRng::new(seed).child(ARRIVAL_RNG_TAG);
        let mut out = Vec::with_capacity(n);
        let mut t = 0.0f64;
        for k in 0..n {
            let frac = k as f64 / n.max(1) as f64;
            t += Self::exp_gap_ns(&mut rng, self.rate_at(frac));
            t = self.skip_off_phase(t);
            out.push(t as Nanos);
        }
        out
    }

    /// Arrival offsets (ns) strictly before `horizon_ns` (the simulator
    /// mirror: the stream covers the virtual-time horizon). Capped at
    /// 2^20 arrivals as a runaway-rate backstop.
    pub fn schedule_until(&self, horizon_ns: Nanos, seed: u64) -> Vec<Nanos> {
        if !self.is_open_loop() || horizon_ns == 0 {
            return Vec::new();
        }
        const CAP: usize = 1 << 20;
        let mut rng = DetRng::new(seed).child(ARRIVAL_RNG_TAG);
        let mut out = Vec::new();
        let h = horizon_ns as f64;
        let mut t = 0.0f64;
        while out.len() < CAP {
            t += Self::exp_gap_ns(&mut rng, self.rate_at((t / h).min(1.0)));
            t = self.skip_off_phase(t);
            if t >= h {
                break;
            }
            out.push(t as Nanos);
        }
        out
    }
}

impl fmt::Display for ArrivalProcess {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::ClosedLoop => f.write_str("closed"),
            Self::Poisson { rate_hz } => write!(f, "poisson:{rate_hz}"),
            Self::Bursty { rate_hz, on_ms, off_ms } => {
                write!(f, "bursty:{rate_hz}@{on_ms}/{off_ms}")
            }
            Self::Ramp { from_hz, to_hz } => write!(f, "ramp:{from_hz}-{to_hz}"),
        }
    }
}

impl FromStr for ArrivalProcess {
    type Err = String;

    /// `closed` | `poisson:RATE` | `bursty:RATE[@ON_MS/OFF_MS]` |
    /// `ramp:FROM-TO` (rates in requests/s).
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let bad = |what: &str| format!("bad arrival process '{s}': {what}");
        let parse_rate = |v: &str| -> Result<f64, String> {
            v.trim().parse::<f64>().map_err(|_| bad("rate must be a number"))
        };
        let out = if s == "closed" || s == "closed-loop" {
            Self::ClosedLoop
        } else if let Some(rate) = s.strip_prefix("poisson:") {
            Self::Poisson { rate_hz: parse_rate(rate)? }
        } else if let Some(rest) = s.strip_prefix("bursty:") {
            let (rate, windows) = rest.split_once('@').unwrap_or((rest, "100/100"));
            let (on, off) = windows
                .split_once('/')
                .ok_or_else(|| bad("expected bursty:RATE[@ON_MS/OFF_MS]"))?;
            Self::Bursty {
                rate_hz: parse_rate(rate)?,
                on_ms: on.trim().parse().map_err(|_| bad("bad on_ms"))?,
                off_ms: off.trim().parse().map_err(|_| bad("bad off_ms"))?,
            }
        } else if let Some(rest) = s.strip_prefix("ramp:") {
            let (from, to) =
                rest.split_once('-').ok_or_else(|| bad("expected ramp:FROM-TO"))?;
            Self::Ramp { from_hz: parse_rate(from)?, to_hz: parse_rate(to)? }
        } else {
            return Err(bad("expected closed|poisson:RATE|bursty:RATE@ON/OFF|ramp:FROM-TO"));
        };
        out.validate()?;
        Ok(out)
    }
}

// ---------------------------------------------------------------------
// shed policy
// ---------------------------------------------------------------------

/// What happens when an arrival finds the admission queue full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedPolicy {
    /// Backpressure: the generator blocks until a slot frees (later
    /// arrivals slip in *generation* time, but latency is still measured
    /// from the scheduled arrival instant, so the slip shows up as
    /// latency, not as omission).
    Block,
    /// Shed immediately: the request is dropped and counted.
    Reject,
    /// Bounded patience, both sides of the queue: the generator waits up
    /// to `ms` for a slot (shed on expiry), and a request that already
    /// waited longer than `ms` when dequeued is dropped as timed out.
    Timeout { ms: u64 },
}

impl fmt::Display for ShedPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Self::Block => f.write_str("block"),
            Self::Reject => f.write_str("reject"),
            Self::Timeout { ms } => write!(f, "timeout:{ms}"),
        }
    }
}

impl FromStr for ShedPolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "block" => Ok(Self::Block),
            "reject" => Ok(Self::Reject),
            other => {
                if let Some(ms) = other.strip_prefix("timeout:") {
                    let ms: u64 = ms
                        .parse()
                        .map_err(|_| format!("bad timeout '{other}' (expected timeout:MS)"))?;
                    if ms == 0 {
                        return Err("timeout must be >= 1 ms".to_string());
                    }
                    Ok(Self::Timeout { ms })
                } else {
                    Err(format!("unknown shed policy '{other}' (expected block|reject|timeout:MS)"))
                }
            }
        }
    }
}

// ---------------------------------------------------------------------
// traffic spec
// ---------------------------------------------------------------------

/// Traffic knobs of one serving run: arrival process, admission-queue
/// capacity, full-queue policy, SLO target, and the arrival-stream seed.
/// The default is the historical closed loop, so existing specs behave
/// identically.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrafficSpec {
    pub arrivals: ArrivalProcess,
    /// Bounded admission-queue capacity (per shard), requests.
    pub queue_cap: usize,
    pub shed: ShedPolicy,
    /// SLO target on arrival-to-completion latency, milliseconds.
    pub slo_ms: f64,
    /// Seed of the arrival stream (identical seeds, identical streams).
    pub seed: u64,
}

impl Default for TrafficSpec {
    fn default() -> Self {
        Self {
            arrivals: ArrivalProcess::ClosedLoop,
            queue_cap: 64,
            shed: ShedPolicy::Block,
            slo_ms: 50.0,
            seed: 0,
        }
    }
}

impl TrafficSpec {
    pub fn validate(&self) -> Result<(), String> {
        self.arrivals.validate()?;
        if self.arrivals.is_open_loop() {
            if self.queue_cap == 0 {
                return Err("queue_cap must be >= 1 for open-loop arrivals".to_string());
            }
            if !(self.slo_ms.is_finite() && self.slo_ms > 0.0) {
                return Err("slo_ms must be > 0".to_string());
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------
// bounded admission queue
// ---------------------------------------------------------------------

#[derive(Debug)]
struct QueueState<T> {
    q: VecDeque<T>,
    closed: bool,
}

/// A bounded MPMC admission queue: producers are traffic generators
/// applying a [`ShedPolicy`] at the full-queue boundary, consumers are
/// serving workers draining toward the gate. Closing wakes everyone;
/// [`AdmissionQueue::pop`] then drains the backlog before reporting
/// end-of-stream.
#[derive(Debug)]
pub struct AdmissionQueue<T> {
    state: Mutex<QueueState<T>>,
    not_full: Condvar,
    not_empty: Condvar,
    cap: usize,
}

impl<T> AdmissionQueue<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1, "admission queue needs capacity >= 1");
        Self {
            state: Mutex::new(QueueState { q: VecDeque::with_capacity(cap), closed: false }),
            not_full: Condvar::new(),
            not_empty: Condvar::new(),
            cap,
        }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Current occupancy (advisory: may be stale by the next instruction).
    pub fn len(&self) -> usize {
        lock_recover(&self.state).q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Non-blocking admit; `Err` hands the item back when the queue is
    /// full or closed.
    pub fn try_push(&self, item: T) -> Result<(), T> {
        let mut st = lock_recover(&self.state);
        if st.closed || st.q.len() >= self.cap {
            return Err(item);
        }
        st.q.push_back(item);
        drop(st);
        self.not_empty.notify_one();
        Ok(())
    }

    /// Blocking admit (the `block` shed policy); returns false if the
    /// queue closed while waiting.
    pub fn push_blocking(&self, item: T) -> bool {
        let mut st = lock_recover(&self.state);
        loop {
            if st.closed {
                return false;
            }
            if st.q.len() < self.cap {
                st.q.push_back(item);
                drop(st);
                self.not_empty.notify_one();
                return true;
            }
            st = self.not_full.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Admit with bounded patience (the `timeout` shed policy); `Err`
    /// hands the item back on expiry or close.
    pub fn push_timeout(&self, item: T, patience: Duration) -> Result<(), T> {
        let deadline = std::time::Instant::now() + patience;
        let mut st = lock_recover(&self.state);
        loop {
            if st.closed {
                return Err(item);
            }
            if st.q.len() < self.cap {
                st.q.push_back(item);
                drop(st);
                self.not_empty.notify_one();
                return Ok(());
            }
            let now = std::time::Instant::now();
            let Some(left) = deadline.checked_duration_since(now).filter(|d| !d.is_zero())
            else {
                return Err(item);
            };
            let (guard, _timed_out) = self
                .not_full
                .wait_timeout(st, left)
                .unwrap_or_else(PoisonError::into_inner);
            st = guard;
        }
    }

    /// Blocking dequeue; `None` only after close **and** drain.
    pub fn pop(&self) -> Option<T> {
        let mut st = lock_recover(&self.state);
        loop {
            if let Some(item) = st.q.pop_front() {
                drop(st);
                self.not_full.notify_one();
                return Some(item);
            }
            if st.closed {
                return None;
            }
            st = self.not_empty.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Blocking batched dequeue: wait for at least one item, then drain
    /// up to `max` under the SAME lock acquisition — the open-loop
    /// workers' burst collection in one mutex round-trip instead of a
    /// `pop` plus up to `max − 1` `try_pop`s (each a lock+notify cycle).
    /// Returns an empty vec only after close **and** drain, mirroring
    /// [`AdmissionQueue::pop`]'s end-of-stream contract: a close racing a
    /// batched drain still hands out every admitted item exactly once
    /// (the state mutex serialises the two), preserving `accounted()`
    /// conservation. Producers get one `not_full` wake per item removed.
    pub fn pop_batch(&self, max: usize) -> Vec<T> {
        assert!(max >= 1, "pop_batch needs max >= 1");
        let mut st = lock_recover(&self.state);
        loop {
            if !st.q.is_empty() {
                let take = st.q.len().min(max);
                let batch: Vec<T> = st.q.drain(..take).collect();
                drop(st);
                for _ in 0..take {
                    self.not_full.notify_one();
                }
                return batch;
            }
            if st.closed {
                return Vec::new();
            }
            st = self.not_empty.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Non-blocking dequeue (burst collection under one gate grant).
    pub fn try_pop(&self) -> Option<T> {
        let mut st = lock_recover(&self.state);
        let item = st.q.pop_front();
        drop(st);
        if item.is_some() {
            self.not_full.notify_one();
        }
        item
    }

    /// Non-blocking batched dequeue: take up to `max` items that are
    /// already waiting, never sleeping. This is the work-stealing
    /// primitive — a thief drains a burst from a *victim's* queue without
    /// ever parking on it (DESIGN.md §15). An empty vec means
    /// empty-right-now, closed or not; the caller decides what idleness
    /// means. Producers get one `not_full` wake per item removed, same
    /// as [`AdmissionQueue::pop_batch`].
    pub fn try_pop_batch(&self, max: usize) -> Vec<T> {
        assert!(max >= 1, "try_pop_batch needs max >= 1");
        let mut st = lock_recover(&self.state);
        let take = st.q.len().min(max);
        let batch: Vec<T> = st.q.drain(..take).collect();
        drop(st);
        for _ in 0..take {
            self.not_full.notify_one();
        }
        batch
    }

    /// Batched dequeue with bounded patience: like
    /// [`AdmissionQueue::pop_batch`], but gives up after `patience` with
    /// an empty vec while the queue is still open — the elastic worker's
    /// idle detector (an idle worker goes stealing instead of parking on
    /// its own queue forever). Distinguish "idle" from "end of stream"
    /// via [`AdmissionQueue::is_closed`] + [`AdmissionQueue::is_empty`]:
    /// the close-then-drain conservation contract of `pop_batch` is
    /// unchanged (the state mutex serialises a racing close).
    pub fn pop_batch_timeout(&self, max: usize, patience: Duration) -> Vec<T> {
        assert!(max >= 1, "pop_batch_timeout needs max >= 1");
        let deadline = std::time::Instant::now() + patience;
        let mut st = lock_recover(&self.state);
        loop {
            if !st.q.is_empty() {
                let take = st.q.len().min(max);
                let batch: Vec<T> = st.q.drain(..take).collect();
                drop(st);
                for _ in 0..take {
                    self.not_full.notify_one();
                }
                return batch;
            }
            if st.closed {
                return Vec::new();
            }
            let now = std::time::Instant::now();
            let Some(left) = deadline.checked_duration_since(now).filter(|d| !d.is_zero())
            else {
                return Vec::new();
            };
            let (guard, _timed_out) = self
                .not_empty
                .wait_timeout(st, left)
                .unwrap_or_else(PoisonError::into_inner);
            st = guard;
        }
    }

    /// Has [`AdmissionQueue::close`] been called? (The backlog may still
    /// be draining: end-of-stream is closed **and** empty.)
    pub fn is_closed(&self) -> bool {
        lock_recover(&self.state).closed
    }

    /// End of stream: wake every blocked producer and consumer.
    pub fn close(&self) {
        lock_recover(&self.state).closed = true;
        self.not_full.notify_all();
        self.not_empty.notify_all();
    }
}

// ---------------------------------------------------------------------
// traffic report
// ---------------------------------------------------------------------

/// Traffic/SLO accounting of one open-loop run (or one shard's slice of
/// a fleet run). Latency — and therefore `within_slo` — is measured from
/// the request's *scheduled arrival* to completion, never from admission:
/// queue delay under overload is precisely the signal closed-loop
/// clients hide.
#[derive(Debug, Clone)]
pub struct TrafficReport {
    pub arrivals: ArrivalProcess,
    pub queue_cap: usize,
    pub shed_policy: ShedPolicy,
    pub slo_ms: f64,
    /// Requests generated (the offered load).
    pub offered: usize,
    /// Requests that completed execution.
    pub completed: usize,
    /// Requests shed at admission (full queue under `reject`, or
    /// admission patience expired under `timeout`).
    pub shed: usize,
    /// Requests dropped at dequeue after exceeding the timeout budget.
    pub timed_out: usize,
    /// Requests that failed terminally (execution error after exhausting
    /// any retry budget). Non-zero only under faults/chaos.
    pub failed: usize,
    /// Retry attempts issued across all requests (re-executions and
    /// re-routes; informational — retries are attempts, not requests, so
    /// they sit outside the conservation sum).
    pub retried: usize,
    /// Completed requests whose arrival-to-completion latency met the SLO.
    pub within_slo: usize,
    /// Arrival-to-dequeue delay histogram (ns).
    pub queue_delay: Histogram,
    /// Realised offered rate (offered count over the schedule span).
    pub offered_rate_hz: f64,
}

impl TrafficReport {
    /// SLO attainment as a % of **offered** requests: sheds and timeouts
    /// count against the SLO (they are the requests users lost).
    pub fn slo_attainment_pct(&self) -> f64 {
        100.0 * self.within_slo as f64 / self.offered.max(1) as f64
    }

    /// Goodput: SLO-compliant completions per second of wall clock.
    pub fn goodput(&self, wall_s: f64) -> f64 {
        self.within_slo as f64 / wall_s.max(1e-9)
    }

    /// Conservation check: every offered request is accounted for exactly
    /// once — completed, shed at admission, timed out at dequeue, or
    /// failed after retries. Must hold even under chaos (ISSUE 7).
    pub fn accounted(&self) -> bool {
        self.completed + self.shed + self.timed_out + self.failed == self.offered
    }

    /// Fold another shard's slice into this one (fleet aggregation).
    pub fn merge(&mut self, other: &TrafficReport) {
        self.offered += other.offered;
        self.completed += other.completed;
        self.shed += other.shed;
        self.timed_out += other.timed_out;
        self.failed += other.failed;
        self.retried += other.retried;
        self.within_slo += other.within_slo;
        self.queue_delay.merge(&other.queue_delay);
    }

    /// Two-line human rendering (serving reports).
    pub fn render(&self, wall_s: f64) -> String {
        format!(
            "traffic {} (offered {:.1}/s, queue cap {}, shed policy {}): \
             offered={} completed={} shed={} timed-out={} failed={} retried={}\n\
             SLO {:.1} ms: attainment {:.1}% of offered, goodput {:.1}/s; \
             queue delay: {}",
            self.arrivals,
            self.offered_rate_hz,
            self.queue_cap,
            self.shed_policy,
            self.offered,
            self.completed,
            self.shed,
            self.timed_out,
            self.failed,
            self.retried,
            self.slo_ms,
            self.slo_attainment_pct(),
            self.goodput(wall_s),
            self.queue_delay.render_ms(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // ------------------------------------------------- arrival streams --

    #[test]
    fn parse_display_roundtrip() {
        for text in ["closed", "poisson:200", "bursty:300@50/20", "ramp:50-400"] {
            let p: ArrivalProcess = text.parse().unwrap();
            assert_eq!(p.to_string(), text);
            assert_eq!(p.to_string().parse::<ArrivalProcess>().unwrap(), p);
        }
        assert_eq!(
            "closed-loop".parse::<ArrivalProcess>().unwrap(),
            ArrivalProcess::ClosedLoop
        );
        // Bursty windows default when omitted.
        assert_eq!(
            "bursty:100".parse::<ArrivalProcess>().unwrap(),
            ArrivalProcess::Bursty { rate_hz: 100.0, on_ms: 100, off_ms: 100 }
        );
    }

    #[test]
    fn parse_rejects_nonsense() {
        assert!("poisson:".parse::<ArrivalProcess>().is_err());
        assert!("poisson:-5".parse::<ArrivalProcess>().is_err());
        assert!("poisson:0".parse::<ArrivalProcess>().is_err());
        assert!("ramp:50".parse::<ArrivalProcess>().is_err());
        assert!("uniform:10".parse::<ArrivalProcess>().is_err());
        assert!("bursty:10@0/10".parse::<ArrivalProcess>().is_err());
    }

    #[test]
    fn identical_seeds_identical_streams() {
        for p in [
            ArrivalProcess::Poisson { rate_hz: 500.0 },
            ArrivalProcess::Bursty { rate_hz: 500.0, on_ms: 10, off_ms: 10 },
            ArrivalProcess::Ramp { from_hz: 100.0, to_hz: 1000.0 },
        ] {
            assert_eq!(p.schedule_n(200, 42), p.schedule_n(200, 42), "{p}");
            assert_ne!(p.schedule_n(200, 42), p.schedule_n(200, 43), "{p}");
            assert_eq!(
                p.schedule_until(1_000_000_000, 42),
                p.schedule_until(1_000_000_000, 42),
                "{p}"
            );
        }
    }

    #[test]
    fn schedules_are_sorted_and_sized() {
        let p = ArrivalProcess::Poisson { rate_hz: 1000.0 };
        let s = p.schedule_n(500, 1);
        assert_eq!(s.len(), 500);
        assert!(s.windows(2).all(|w| w[0] <= w[1]), "offsets must be sorted");
        let su = p.schedule_until(1_000_000_000, 1);
        assert!(su.iter().all(|&t| t < 1_000_000_000));
        assert!(su.windows(2).all(|w| w[0] <= w[1]));
    }

    #[test]
    fn poisson_mean_rate_is_close() {
        let p = ArrivalProcess::Poisson { rate_hz: 1000.0 };
        let s = p.schedule_until(10_000_000_000, 3); // 10 s at 1000/s
        let n = s.len() as f64;
        assert!((n - 10_000.0).abs() < 500.0, "got {n} arrivals");
    }

    #[test]
    fn bursty_skips_off_windows() {
        let p = ArrivalProcess::Bursty { rate_hz: 2000.0, on_ms: 10, off_ms: 40 };
        let s = p.schedule_until(1_000_000_000, 5);
        assert!(!s.is_empty());
        for &t in &s {
            let pos = t % 50_000_000; // cycle = 50 ms
            assert!(pos < 10_000_000, "arrival at {t} lies in an off-window");
        }
    }

    #[test]
    fn ramp_accelerates() {
        let p = ArrivalProcess::Ramp { from_hz: 100.0, to_hz: 2000.0 };
        let s = p.schedule_n(1000, 9);
        // The first-half span must exceed the second-half span: gaps
        // shrink as the rate ramps up.
        let first = s[499] - s[0];
        let second = s[999] - s[500];
        assert!(first > second, "ramp not accelerating: {first} vs {second}");
    }

    #[test]
    fn closed_loop_has_no_schedule() {
        let p = ArrivalProcess::ClosedLoop;
        assert!(!p.is_open_loop());
        assert_eq!(p.schedule_n(3, 0), vec![0, 0, 0]);
        assert!(p.schedule_until(1_000_000_000, 0).is_empty());
    }

    // ------------------------------------------------------ shed policy --

    #[test]
    fn shed_policy_parse_roundtrip() {
        for text in ["block", "reject", "timeout:25"] {
            let p: ShedPolicy = text.parse().unwrap();
            assert_eq!(p.to_string(), text);
        }
        assert!("drop".parse::<ShedPolicy>().is_err());
        assert!("timeout:0".parse::<ShedPolicy>().is_err());
        assert!("timeout:x".parse::<ShedPolicy>().is_err());
    }

    #[test]
    fn traffic_spec_validation() {
        TrafficSpec::default().validate().unwrap(); // closed loop: anything goes
        let open = TrafficSpec {
            arrivals: ArrivalProcess::Poisson { rate_hz: 100.0 },
            ..TrafficSpec::default()
        };
        open.validate().unwrap();
        assert!(TrafficSpec { queue_cap: 0, ..open }.validate().is_err());
        assert!(TrafficSpec { slo_ms: 0.0, ..open }.validate().is_err());
    }

    // ------------------------------------------------- admission queue --

    #[test]
    fn queue_bounds_and_rejects() {
        let q = AdmissionQueue::new(2);
        assert!(q.try_push(1).is_ok());
        assert!(q.try_push(2).is_ok());
        assert_eq!(q.try_push(3), Err(3), "full queue must hand the item back");
        assert_eq!(q.len(), 2);
        assert_eq!(q.try_pop(), Some(1));
        assert!(q.try_push(3).is_ok());
        assert_eq!(q.pop(), Some(2));
        assert_eq!(q.pop(), Some(3));
        assert_eq!(q.try_pop(), None);
    }

    #[test]
    fn close_drains_then_ends() {
        let q = AdmissionQueue::new(4);
        q.try_push(7).unwrap();
        q.close();
        assert_eq!(q.try_push(8), Err(8), "closed queue admits nothing");
        assert!(!q.push_blocking(9));
        assert!(q.push_timeout(10, Duration::from_millis(1)).is_err());
        assert_eq!(q.pop(), Some(7), "backlog drains after close");
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn blocking_push_waits_for_space() {
        let q = std::sync::Arc::new(AdmissionQueue::new(1));
        q.try_push(1).unwrap();
        let q2 = std::sync::Arc::clone(&q);
        let h = std::thread::spawn(move || q2.push_blocking(2));
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.pop(), Some(1)); // frees the slot
        assert!(h.join().unwrap());
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn timeout_push_expires() {
        let q = AdmissionQueue::new(1);
        q.try_push(1).unwrap();
        let t0 = std::time::Instant::now();
        assert_eq!(q.push_timeout(2, Duration::from_millis(10)), Err(2));
        assert!(t0.elapsed() >= Duration::from_millis(10));
    }

    #[test]
    fn pop_blocks_until_push() {
        let q = std::sync::Arc::new(AdmissionQueue::new(1));
        let q2 = std::sync::Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop());
        std::thread::sleep(Duration::from_millis(20));
        q.try_push(42).unwrap();
        assert_eq!(h.join().unwrap(), Some(42));
    }

    #[test]
    fn pop_batch_drains_up_to_max_and_wakes_producers() {
        let q = std::sync::Arc::new(AdmissionQueue::new(4));
        for i in 0..4 {
            q.try_push(i).unwrap();
        }
        // Two producers blocked on the full queue: the batched drain's
        // per-item not_full wakes must release both.
        let handles: Vec<_> = (4..6)
            .map(|i| {
                let q2 = std::sync::Arc::clone(&q);
                std::thread::spawn(move || q2.push_blocking(i))
            })
            .collect();
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.pop_batch(3), vec![0, 1, 2], "FIFO prefix, capped at max");
        for h in handles {
            assert!(h.join().unwrap());
        }
        let mut rest = q.pop_batch(10);
        rest.sort_unstable(); // producer arrival order is racy
        assert_eq!(rest, vec![3, 4, 5], "batch takes whatever is queued");
    }

    #[test]
    fn pop_batch_blocks_until_item_then_ends_after_close() {
        let q = std::sync::Arc::new(AdmissionQueue::new(2));
        let q2 = std::sync::Arc::clone(&q);
        let h = std::thread::spawn(move || q2.pop_batch(8));
        std::thread::sleep(Duration::from_millis(20));
        q.try_push(1).unwrap();
        assert_eq!(h.join().unwrap(), vec![1], "wakes on first item");
        q.try_push(2).unwrap();
        q.close();
        assert_eq!(q.pop_batch(8), vec![2], "backlog drains after close");
        assert!(q.pop_batch(8).is_empty(), "empty vec = end of stream");
    }

    #[test]
    fn pop_batch_racing_close_conserves_items() {
        // Hammer a batched consumer against a producer that closes the
        // queue mid-stream: every admitted item must come out exactly
        // once — the accounted() conservation law the serving workers
        // rely on (DESIGN.md §8).
        for trial in 0..20u64 {
            let q = std::sync::Arc::new(AdmissionQueue::new(8));
            let qc = std::sync::Arc::clone(&q);
            let consumer = std::thread::spawn(move || {
                let mut got = Vec::new();
                loop {
                    let batch = qc.pop_batch(3);
                    if batch.is_empty() {
                        return got;
                    }
                    got.extend(batch);
                }
            });
            let mut admitted = Vec::new();
            for i in 0..50 {
                if q.try_push(trial * 1000 + i).is_ok() {
                    admitted.push(trial * 1000 + i);
                }
                if i % 7 == 0 {
                    std::thread::yield_now();
                }
            }
            q.close();
            let got = consumer.join().unwrap();
            assert_eq!(got, admitted, "trial {trial}: items lost or reordered");
        }
    }

    #[test]
    fn try_pop_batch_never_blocks_and_takes_a_prefix() {
        let q = AdmissionQueue::new(8);
        assert!(q.try_pop_batch(4).is_empty(), "empty queue: empty vec, no park");
        for i in 0..5 {
            q.try_push(i).unwrap();
        }
        assert_eq!(q.try_pop_batch(3), vec![0, 1, 2], "FIFO prefix, capped at max");
        assert_eq!(q.try_pop_batch(10), vec![3, 4]);
        q.close();
        assert!(q.try_pop_batch(4).is_empty(), "closed + drained: still empty");
    }

    #[test]
    fn try_pop_batch_wakes_blocked_producers() {
        let q = std::sync::Arc::new(AdmissionQueue::new(2));
        q.try_push(0).unwrap();
        q.try_push(1).unwrap();
        let q2 = std::sync::Arc::clone(&q);
        let h = std::thread::spawn(move || q2.push_blocking(2));
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(q.try_pop_batch(2), vec![0, 1], "steal drains the backlog");
        assert!(h.join().unwrap(), "the steal's not_full wakes must free the producer");
        assert_eq!(q.pop(), Some(2));
    }

    #[test]
    fn pop_batch_timeout_distinguishes_idle_from_end_of_stream() {
        let q = AdmissionQueue::new(4);
        let t0 = std::time::Instant::now();
        assert!(
            q.pop_batch_timeout(4, Duration::from_millis(10)).is_empty(),
            "idle: gives up after patience"
        );
        assert!(t0.elapsed() >= Duration::from_millis(10));
        assert!(!q.is_closed(), "timeout does not end the stream");
        q.try_push(7).unwrap();
        assert_eq!(q.pop_batch_timeout(4, Duration::from_millis(50)), vec![7]);
        q.try_push(8).unwrap();
        q.close();
        assert!(q.is_closed());
        assert_eq!(
            q.pop_batch_timeout(4, Duration::from_millis(50)),
            vec![8],
            "backlog still drains after close"
        );
        assert!(q.pop_batch_timeout(4, Duration::from_millis(1)).is_empty());
        assert!(q.is_closed() && q.is_empty(), "closed + drained = end of stream");
    }

    #[test]
    fn pop_batch_timeout_wakes_on_push() {
        let q = std::sync::Arc::new(AdmissionQueue::new(4));
        let q2 = std::sync::Arc::clone(&q);
        let h =
            std::thread::spawn(move || q2.pop_batch_timeout(4, Duration::from_millis(500)));
        std::thread::sleep(Duration::from_millis(20));
        q.try_push(42).unwrap();
        assert_eq!(h.join().unwrap(), vec![42], "wakes on the first item, not the deadline");
    }

    // ----------------------------------------------------------- report --

    #[test]
    fn report_accounting_and_render() {
        let mut r = TrafficReport {
            arrivals: ArrivalProcess::Poisson { rate_hz: 200.0 },
            queue_cap: 64,
            shed_policy: ShedPolicy::Reject,
            slo_ms: 50.0,
            offered: 100,
            completed: 89,
            shed: 8,
            timed_out: 2,
            failed: 1,
            retried: 3,
            within_slo: 81,
            queue_delay: Histogram::new(),
            offered_rate_hz: 198.5,
        };
        assert!(r.accounted());
        assert!((r.slo_attainment_pct() - 81.0).abs() < 1e-9);
        assert!((r.goodput(2.0) - 40.5).abs() < 1e-9);
        let text = r.render(2.0);
        assert!(text.contains("goodput"), "{text}");
        assert!(text.contains("attainment"), "{text}");
        assert!(text.contains("shed=8"), "{text}");
        assert!(text.contains("timed-out=2"), "{text}");
        assert!(text.contains("failed=1"), "{text}");
        assert!(text.contains("retried=3"), "{text}");

        let other = r.clone();
        r.merge(&other);
        assert_eq!(r.offered, 200);
        assert_eq!(r.within_slo, 162);
        assert_eq!(r.failed, 2);
        assert_eq!(r.retried, 6);
        assert!(r.accounted());
    }
}
