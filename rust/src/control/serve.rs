//! Live serving of real PJRT inferences through the access controller.
//!
//! The PJRT client handles are not `Send` (they wrap raw C API pointers),
//! so every executing thread owns its *own* engine — exactly like the
//! paper's setup where each application is a separate process with its own
//! CUDA context. Mutual exclusion across them is the global GPU lock.
//!
//! Strategies:
//! * `none`   — clients execute concurrently, unmitigated;
//! * `synced` — the client thread takes the GPU lock around each
//!   inference (Alg. 4: acquire, run, sync, release — PJRT execution is
//!   synchronous so insert+sync collapse into the call);
//! * `worker` — each client defers to a per-client worker thread that
//!   owns the engine and serialises under the lock (Alg. 5-6).

use crate::config::StrategyKind;
use crate::runtime::{PjrtEngine, PAYLOAD_DNA};
use anyhow::{anyhow, Result};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Result of a serving run.
#[derive(Debug)]
pub struct ServeReport {
    pub strategy: StrategyKind,
    pub clients: usize,
    pub requests_per_client: usize,
    pub wall_s: f64,
    /// Sorted per-request latencies, milliseconds.
    pub latencies_ms: Vec<f64>,
}

impl ServeReport {
    pub fn total(&self) -> usize {
        self.clients * self.requests_per_client
    }

    pub fn ips(&self) -> f64 {
        self.total() as f64 / self.wall_s
    }

    pub fn latency_p(&self, q: f64) -> f64 {
        let n = self.latencies_ms.len();
        self.latencies_ms[((n as f64 * q) as usize).min(n - 1)]
    }

    pub fn render(&self) -> String {
        format!(
            "{} clients x {} requests, strategy {}: {:.1} IPS; latency ms p50={:.2} p95={:.2} p99={:.2} max={:.2}",
            self.clients,
            self.requests_per_client,
            self.strategy,
            self.ips(),
            self.latency_p(0.50),
            self.latency_p(0.95),
            self.latency_p(0.99),
            self.latencies_ms.last().copied().unwrap_or(0.0),
        )
    }
}

/// Per-request input perturbation (randomised inputs, §VI-C).
fn perturb(inputs: &mut [Vec<f32>], client: usize, request: usize) {
    for (i, v) in inputs[0].iter_mut().enumerate() {
        *v += ((request * 31 + client * 17 + i) % 13) as f32 * 1e-3;
    }
}

/// Serve DNA-Net inferences from `clients` concurrent applications.
///
/// `artifacts_dir` points at the AOT output; every client (and worker)
/// thread loads its own engine from it.
pub fn serve_dna(
    strategy: StrategyKind,
    clients: usize,
    requests: usize,
    artifacts_dir: std::path::PathBuf,
) -> Result<ServeReport> {
    assert!(clients > 0 && requests > 0);
    let gpu_lock = Arc::new(Mutex::new(()));
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        let gpu_lock = Arc::clone(&gpu_lock);
        let dir = artifacts_dir.clone();
        handles.push(std::thread::spawn(move || -> Result<Vec<f64>> {
            match strategy {
                StrategyKind::None | StrategyKind::Synced => {
                    let engine = PjrtEngine::load(&dir)?;
                    let spec = &engine.manifest.artifacts[PAYLOAD_DNA];
                    let out_elems = spec.out_elems();
                    let base_inputs = spec.golden_inputs();
                    let mut lat = Vec::with_capacity(requests);
                    for r in 0..requests {
                        let mut inputs = base_inputs.clone();
                        perturb(&mut inputs, c, r);
                        let t = Instant::now();
                        let out = if strategy == StrategyKind::Synced {
                            let _gpu = gpu_lock.lock().unwrap();
                            engine.execute(PAYLOAD_DNA, &inputs)?
                        } else {
                            engine.execute(PAYLOAD_DNA, &inputs)?
                        };
                        lat.push(t.elapsed().as_secs_f64() * 1e3);
                        if out.len() != out_elems {
                            return Err(anyhow!("bad output size {}", out.len()));
                        }
                    }
                    Ok(lat)
                }
                StrategyKind::Worker => {
                    // The worker owns the engine; the client thread plays
                    // the host code: prepare inputs, defer, await.
                    type Req = (Vec<Vec<f32>>, mpsc::Sender<Result<Vec<f32>>>);
                    let (tx, rx) = mpsc::channel::<Req>();
                    let wl = Arc::clone(&gpu_lock);
                    let wdir = dir.clone();
                    let worker = std::thread::spawn(move || -> Result<()> {
                        let engine = PjrtEngine::load(&wdir)?;
                        while let Ok((inputs, reply)) = rx.recv() {
                            let out = {
                                let _gpu = wl.lock().unwrap();
                                engine.execute(PAYLOAD_DNA, &inputs)
                            };
                            let _ = reply.send(out);
                        }
                        Ok(())
                    });
                    // Host side still needs shapes: a light manifest load.
                    let manifest = crate::runtime::Manifest::load(&dir)?;
                    let spec = &manifest.artifacts[PAYLOAD_DNA];
                    let out_elems = spec.out_elems();
                    let base_inputs = spec.golden_inputs();
                    // Warm-up: the worker compiles its executables on
                    // first use; don't bill that to request latency.
                    {
                        let (rtx, rrx) = mpsc::channel();
                        tx.send((base_inputs.clone(), rtx))
                            .map_err(|_| anyhow!("worker gone"))?;
                        rrx.recv().map_err(|_| anyhow!("worker dropped"))??;
                    }
                    let mut lat = Vec::with_capacity(requests);
                    for r in 0..requests {
                        let mut inputs = base_inputs.clone();
                        perturb(&mut inputs, c, r);
                        let (rtx, rrx) = mpsc::channel();
                        let t = Instant::now();
                        tx.send((inputs, rtx)).map_err(|_| anyhow!("worker gone"))?;
                        let out = rrx.recv().map_err(|_| anyhow!("worker dropped"))??;
                        lat.push(t.elapsed().as_secs_f64() * 1e3);
                        if out.len() != out_elems {
                            return Err(anyhow!("bad output size {}", out.len()));
                        }
                    }
                    drop(tx); // drain + stop the worker
                    worker.join().map_err(|_| anyhow!("worker panicked"))??;
                    Ok(lat)
                }
                other => Err(anyhow!("live serving does not support strategy {other}")),
            }
        }));
    }
    let mut latencies_ms: Vec<f64> = Vec::new();
    for h in handles {
        latencies_ms.extend(h.join().map_err(|_| anyhow!("client panicked"))??);
    }
    let wall_s = t0.elapsed().as_secs_f64();
    latencies_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Ok(ServeReport {
        strategy,
        clients,
        requests_per_client: requests,
        wall_s,
        latencies_ms,
    })
}
