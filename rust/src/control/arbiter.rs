//! Pluggable grant arbitration for the live gate and the simulator lock.
//!
//! The paper's `GPU_LOCK` admits strictly in arrival order — every client
//! is equal. Production fleets are not: tenants carry weights, credit
//! budgets, deadlines, and SLOs. This module extracts the *grant-ordering
//! decision* out of [`crate::control::gate::GpuGate`] (and out of the
//! simulator's `LockWake` handler) behind one [`Arbiter`] trait, so both
//! layers answer "who runs next?" with the same policy and the same
//! tie-breaks — sim and live serving must agree on who starves under
//! overload (DESIGN.md §13).
//!
//! Four policies ship:
//! * [`Fifo`] — today's behaviour, bit-identical (pinned by
//!   `tests/arbitration.rs`): always pick the front of the queue.
//! * [`WeightedRoundRobin`] — pick the waiter whose class has received
//!   the smallest weight-normalised share of grants so far; long-run
//!   grant shares converge to the configured weights.
//! * [`CreditBased`] — FIFO *at the gate*; the policy acts at admission
//!   instead, where a [`CreditBank`] bounds each class's in-flight
//!   requests (the per-tenant generalisation of the PR 4 bounded queue).
//! * [`EarliestDeadlineFirst`] — pick the waiter with the earliest
//!   absolute deadline; deadline-less waiters rank last; ties break FIFO.
//!
//! Every policy is a pure function of the waiter list and its own grant
//! history — never of wall-clock time or thread identity — so arbitration
//! decisions are deterministic and the simulator mirror is exact.

use crate::util::lock_recover;
use std::fmt;
use std::str::FromStr;
use std::sync::{Condvar, Mutex, PoisonError};
use std::time::Duration;

// ---------------------------------------------------------------------
// tenant classes
// ---------------------------------------------------------------------

/// One tenant class: a named QoS tier with an arbitration weight and
/// optional credit budget, deadline, and SLO overrides.
///
/// Parsed from `name[:weight=W][:credits=C][:deadline=MS][:slo=MS]`,
/// comma-separated into a class list (the same clause grammar shape as
/// [`crate::control::fault::FaultSpec`]):
///
/// ```
/// use cook::control::arbiter::{parse_classes, render_classes};
///
/// let classes = parse_classes("gold:weight=4:credits=16:deadline=10:slo=5,free").unwrap();
/// assert_eq!(classes.len(), 2);
/// assert_eq!(classes[0].weight, 4);
/// assert_eq!(classes[1].weight, 1);
/// // Display/parse round-trips.
/// assert_eq!(parse_classes(&render_classes(&classes)).unwrap(), classes);
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct TenantClass {
    /// Class name (report labels; must be unique within a spec).
    pub name: String,
    /// Arbitration weight (WRR); >= 1. Default 1.
    pub weight: u32,
    /// Credit budget: max in-flight requests admitted for this class
    /// (credit arbiter). `None` = the spec-level default.
    pub credits: Option<u32>,
    /// Relative deadline in ms from enqueue (EDF). `None` = best-effort
    /// (ranks after every deadlined waiter).
    pub deadline_ms: Option<u64>,
    /// Per-class SLO override in ms for SLO-attainment reporting.
    /// `None` = the run-level `TrafficSpec::slo_ms`.
    pub slo_ms: Option<f64>,
}

impl TenantClass {
    pub fn new(name: &str) -> Self {
        Self { name: name.to_string(), weight: 1, credits: None, deadline_ms: None, slo_ms: None }
    }
}

impl fmt::Display for TenantClass {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.name)?;
        if self.weight != 1 {
            write!(f, ":weight={}", self.weight)?;
        }
        if let Some(c) = self.credits {
            write!(f, ":credits={c}")?;
        }
        if let Some(d) = self.deadline_ms {
            write!(f, ":deadline={d}")?;
        }
        if let Some(s) = self.slo_ms {
            write!(f, ":slo={s}")?;
        }
        Ok(())
    }
}

/// Parse a comma-separated tenant-class list (see [`TenantClass`]).
/// Empty input (or `"none"`) is the default single implicit class.
pub fn parse_classes(s: &str) -> Result<Vec<TenantClass>, String> {
    let s = s.trim();
    if s.is_empty() || s == "none" {
        return Ok(Vec::new());
    }
    let mut out: Vec<TenantClass> = Vec::new();
    for clause in s.split(',') {
        let clause = clause.trim();
        let mut parts = clause.split(':');
        let name = parts.next().unwrap_or("").trim();
        if name.is_empty() || name.contains('=') {
            return Err(format!(
                "bad class clause '{clause}': expected name[:weight=W][:credits=C][:deadline=MS][:slo=MS]"
            ));
        }
        if out.iter().any(|c| c.name == name) {
            return Err(format!("duplicate class name '{name}'"));
        }
        let mut c = TenantClass::new(name);
        for token in parts {
            let (key, value) = token
                .split_once('=')
                .ok_or_else(|| format!("bad class token '{token}' in '{clause}'"))?;
            let bad = |what: &str| format!("bad {key} '{value}' in '{clause}': {what}");
            match key {
                "weight" => {
                    let w: u32 = value.parse().map_err(|_| bad("expected an integer"))?;
                    if w == 0 {
                        return Err(bad("weight must be >= 1"));
                    }
                    c.weight = w;
                }
                "credits" => {
                    let n: u32 = value.parse().map_err(|_| bad("expected an integer"))?;
                    if n == 0 {
                        return Err(bad("credits must be >= 1"));
                    }
                    c.credits = Some(n);
                }
                "deadline" => {
                    let d: u64 = value.parse().map_err(|_| bad("expected milliseconds"))?;
                    if d == 0 {
                        return Err(bad("deadline must be >= 1 ms"));
                    }
                    c.deadline_ms = Some(d);
                }
                "slo" => {
                    let s: f64 = value.parse().map_err(|_| bad("expected milliseconds"))?;
                    if !(s > 0.0) {
                        return Err(bad("slo must be > 0"));
                    }
                    c.slo_ms = Some(s);
                }
                other => {
                    return Err(format!(
                        "unknown class token '{other}' in '{clause}' \
                         (expected weight|credits|deadline|slo)"
                    ))
                }
            }
        }
        out.push(c);
    }
    Ok(out)
}

/// Render a class list back to the [`parse_classes`] grammar.
pub fn render_classes(classes: &[TenantClass]) -> String {
    classes.iter().map(|c| c.to_string()).collect::<Vec<_>>().join(",")
}

/// The one class-assignment rule, shared by live serving (clients and
/// open-loop request sequence numbers) and the simulator (application
/// index): round-robin over the configured classes. Keeping this a
/// single function is what makes the sim-vs-serving starvation
/// agreement hold by construction.
#[inline]
pub fn class_of(index: usize, num_classes: usize) -> usize {
    if num_classes == 0 {
        0
    } else {
        index % num_classes
    }
}

// ---------------------------------------------------------------------
// the arbiter trait
// ---------------------------------------------------------------------

/// Which arbitration policy a gate (or the simulator's lock) runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ArbiterKind {
    /// Strict arrival order (the paper's `GPU_LOCK`; the default).
    #[default]
    Fifo,
    /// Weighted round-robin over tenant classes.
    Wrr,
    /// FIFO at the gate, per-class credit backpressure at admission.
    Credit,
    /// Earliest (absolute) deadline first, FIFO tie-break.
    Edf,
}

impl ArbiterKind {
    pub const ALL: [ArbiterKind; 4] =
        [ArbiterKind::Fifo, ArbiterKind::Wrr, ArbiterKind::Credit, ArbiterKind::Edf];

    pub fn name(&self) -> &'static str {
        match self {
            ArbiterKind::Fifo => "fifo",
            ArbiterKind::Wrr => "wrr",
            ArbiterKind::Credit => "credit",
            ArbiterKind::Edf => "edf",
        }
    }

    /// Does this policy ever pick anything but the queue front? FIFO and
    /// credit (which acts at admission, not at the gate) never do — the
    /// gate's release path skips the waiter-snapshot allocation for them.
    pub fn is_fifo_order(&self) -> bool {
        matches!(self, ArbiterKind::Fifo | ArbiterKind::Credit)
    }
}

impl fmt::Display for ArbiterKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for ArbiterKind {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s.trim() {
            "fifo" => Ok(ArbiterKind::Fifo),
            "wrr" | "weighted" => Ok(ArbiterKind::Wrr),
            "credit" | "credits" => Ok(ArbiterKind::Credit),
            "edf" | "deadline" => Ok(ArbiterKind::Edf),
            other => Err(format!("unknown arbiter '{other}' (expected fifo|wrr|credit|edf)")),
        }
    }
}

/// One parked waiter, as the arbiter sees it. The list handed to
/// [`Arbiter::pick`] is always in FIFO (ticket-ascending) order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Waiter {
    /// Monotonic arrival ticket (FIFO order and tie-breaks).
    pub ticket: u64,
    /// Tenant class index.
    pub class: usize,
    /// Absolute deadline in ns on the owning gate's clock (enqueue time
    /// plus the class's relative deadline); `None` = best-effort.
    pub deadline_ns: Option<u64>,
}

/// The grant-ordering decision, extracted from the gate (DESIGN.md §13).
///
/// Contract:
/// * `pick` is called with a non-empty, FIFO-ordered waiter list and
///   returns an index into it. It must be a *pure function* of the list
///   and of grant history accumulated via `on_grant` — no clocks, no
///   randomness — so the same contention script always produces the same
///   grant order (the determinism the simulator mirror relies on).
/// * `pick` takes `&self`: release paths may peek (e.g. to classify the
///   wake-up latency of the next grantee) without committing; state
///   moves only in `on_grant`, called exactly once per issued grant.
pub trait Arbiter: Send + fmt::Debug {
    fn kind(&self) -> ArbiterKind;

    /// Index of the waiter to grant next. `waiters` is non-empty.
    fn pick(&self, waiters: &[Waiter]) -> usize;

    /// A grant was issued to `class` (immediate admits included).
    fn on_grant(&mut self, class: usize) {
        let _ = class;
    }
}

/// Strict arrival order: always the queue front.
#[derive(Debug, Default, Clone)]
pub struct Fifo;

impl Arbiter for Fifo {
    fn kind(&self) -> ArbiterKind {
        ArbiterKind::Fifo
    }

    fn pick(&self, _waiters: &[Waiter]) -> usize {
        0
    }
}

/// Weighted round-robin: grant the waiter whose class has so far
/// received the smallest weight-normalised share of grants
/// (`issued[c] / weight[c]`, compared by cross-multiplication so no
/// floats enter the decision). Ties break FIFO — the earliest waiter of
/// the chosen share wins. Long-run grant shares converge to the weights
/// whenever every class keeps a waiter queued (pinned by the law suite).
#[derive(Debug, Clone)]
pub struct WeightedRoundRobin {
    weights: Vec<u64>,
    issued: Vec<u64>,
}

impl WeightedRoundRobin {
    pub fn new(classes: &[TenantClass]) -> Self {
        let weights: Vec<u64> = if classes.is_empty() {
            vec![1]
        } else {
            classes.iter().map(|c| u64::from(c.weight.max(1))).collect()
        };
        let issued = vec![0; weights.len()];
        Self { weights, issued }
    }

    /// Grants issued per class so far (share-convergence tests).
    pub fn issued(&self) -> &[u64] {
        &self.issued
    }

    #[inline]
    fn clamp(&self, class: usize) -> usize {
        class.min(self.weights.len() - 1)
    }
}

impl Arbiter for WeightedRoundRobin {
    fn kind(&self) -> ArbiterKind {
        ArbiterKind::Wrr
    }

    fn pick(&self, waiters: &[Waiter]) -> usize {
        let mut best = 0;
        let bc = self.clamp(waiters[0].class);
        let (mut bi, mut bw) = (self.issued[bc] as u128, self.weights[bc] as u128);
        for (i, w) in waiters.iter().enumerate().skip(1) {
            let c = self.clamp(w.class);
            let (ci, cw) = (self.issued[c] as u128, self.weights[c] as u128);
            // issued[c]/weight[c] < issued[best]/weight[best], cross-multiplied.
            if ci * bw < bi * cw {
                best = i;
                bi = ci;
                bw = cw;
            }
        }
        best
    }

    fn on_grant(&mut self, class: usize) {
        let c = self.clamp(class);
        self.issued[c] += 1;
    }
}

/// Credit-based flow control is FIFO *at the gate* by design: credits
/// bound how many requests per class are in flight at all (see
/// [`CreditBank`], consumed at admission and returned at terminal
/// accounting), so by the time a request reaches the gate its class has
/// already paid. Re-ordering grants here would double-charge.
#[derive(Debug, Default, Clone)]
pub struct CreditBased;

impl Arbiter for CreditBased {
    fn kind(&self) -> ArbiterKind {
        ArbiterKind::Credit
    }

    fn pick(&self, _waiters: &[Waiter]) -> usize {
        0
    }
}

/// Earliest (absolute) deadline first. Deadline-less waiters rank after
/// every deadlined one; within equal deadlines (and among the
/// deadline-less) the earliest ticket wins — the scan keeps the first
/// minimum, and the waiter list is FIFO-ordered.
#[derive(Debug, Default, Clone)]
pub struct EarliestDeadlineFirst;

impl Arbiter for EarliestDeadlineFirst {
    fn kind(&self) -> ArbiterKind {
        ArbiterKind::Edf
    }

    fn pick(&self, waiters: &[Waiter]) -> usize {
        let mut best = 0;
        let mut bd = waiters[0].deadline_ns.unwrap_or(u64::MAX);
        for (i, w) in waiters.iter().enumerate().skip(1) {
            let d = w.deadline_ns.unwrap_or(u64::MAX);
            if d < bd {
                best = i;
                bd = d;
            }
        }
        best
    }
}

/// Build the arbiter for `kind` over `classes`.
pub fn make_arbiter(kind: ArbiterKind, classes: &[TenantClass]) -> Box<dyn Arbiter> {
    match kind {
        ArbiterKind::Fifo => Box::new(Fifo),
        ArbiterKind::Wrr => Box::new(WeightedRoundRobin::new(classes)),
        ArbiterKind::Credit => Box::new(CreditBased),
        ArbiterKind::Edf => Box::new(EarliestDeadlineFirst),
    }
}

// ---------------------------------------------------------------------
// credit bank (admission-side flow control)
// ---------------------------------------------------------------------

/// Per-class credit pool: the admission-side backpressure of the credit
/// arbiter, generalising the PR 4 bounded queue to per-tenant budgets.
///
/// A request *takes* one credit of its class at admission (blocking,
/// failing, or timing out per the shed policy) and the credit is *put*
/// back exactly once, at the request's terminal accounting — completion,
/// terminal failure, in-queue timeout, or drain. A retry or a cross-shard
/// requeue keeps its credit outstanding (the request is still in
/// flight), and a lease revocation returns the credit only when the
/// request finally gives up or completes — so at every instant
/// `taken == returned + outstanding` and
/// `available + outstanding == total` (the conservation law pinned by
/// `tests/arbitration.rs`).
#[derive(Debug)]
pub struct CreditBank {
    state: Mutex<CreditState>,
    returned_cv: Condvar,
}

#[derive(Debug, Clone)]
struct CreditState {
    total: Vec<u32>,
    available: Vec<u32>,
    taken: Vec<u64>,
    returned: Vec<u64>,
}

/// A point-in-time copy of the bank's counters (law tests, reports).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CreditSnapshot {
    pub total: Vec<u32>,
    pub available: Vec<u32>,
    pub taken: Vec<u64>,
    pub returned: Vec<u64>,
}

impl CreditSnapshot {
    /// Credits currently held by in-flight requests of `class`.
    pub fn outstanding(&self, class: usize) -> u64 {
        self.taken[class] - self.returned[class]
    }

    /// The conservation law, checked across every class.
    pub fn conserved(&self) -> bool {
        (0..self.total.len()).all(|c| {
            self.taken[c] >= self.returned[c]
                && u64::from(self.available[c]) + self.outstanding(c) == u64::from(self.total[c])
        })
    }
}

impl CreditBank {
    /// One pool per class; a class without an explicit `credits=` budget
    /// gets `default_credits` (the serving layer passes its queue cap —
    /// exactly the old single-tenant bound).
    pub fn new(classes: &[TenantClass], default_credits: u32) -> Self {
        let default_credits = default_credits.max(1);
        let total: Vec<u32> = if classes.is_empty() {
            vec![default_credits]
        } else {
            classes.iter().map(|c| c.credits.unwrap_or(default_credits).max(1)).collect()
        };
        Self {
            state: Mutex::new(CreditState {
                available: total.clone(),
                taken: vec![0; total.len()],
                returned: vec![0; total.len()],
                total,
            }),
            returned_cv: Condvar::new(),
        }
    }

    #[inline]
    fn idx(&self, st: &CreditState, class: usize) -> usize {
        class.min(st.total.len() - 1)
    }

    /// Take one credit if the class has any; never blocks.
    pub fn try_take(&self, class: usize) -> bool {
        let mut st = lock_recover(&self.state);
        let c = self.idx(&st, class);
        if st.available[c] == 0 {
            return false;
        }
        st.available[c] -= 1;
        st.taken[c] += 1;
        true
    }

    /// Take one credit, blocking until one is returned.
    pub fn take_blocking(&self, class: usize) {
        let mut st = lock_recover(&self.state);
        let c = self.idx(&st, class);
        while st.available[c] == 0 {
            st = self.returned_cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
        st.available[c] -= 1;
        st.taken[c] += 1;
    }

    /// Take one credit, waiting at most `timeout`; false on expiry.
    pub fn take_timeout(&self, class: usize, timeout: Duration) -> bool {
        let deadline = std::time::Instant::now() + timeout;
        let mut st = lock_recover(&self.state);
        let c = self.idx(&st, class);
        while st.available[c] == 0 {
            let now = std::time::Instant::now();
            if now >= deadline {
                return false;
            }
            let (g, _) = self
                .returned_cv
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(PoisonError::into_inner);
            st = g;
        }
        st.available[c] -= 1;
        st.taken[c] += 1;
        true
    }

    /// Return one credit (terminal accounting; exactly once per take).
    pub fn put(&self, class: usize) {
        let mut st = lock_recover(&self.state);
        let c = self.idx(&st, class);
        debug_assert!(st.available[c] < st.total[c], "credit returned twice");
        st.available[c] = (st.available[c] + 1).min(st.total[c]);
        st.returned[c] += 1;
        drop(st);
        self.returned_cv.notify_one();
    }

    pub fn snapshot(&self) -> CreditSnapshot {
        let st = lock_recover(&self.state);
        CreditSnapshot {
            total: st.total.clone(),
            available: st.available.clone(),
            taken: st.taken.clone(),
            returned: st.returned.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn w(ticket: u64, class: usize) -> Waiter {
        Waiter { ticket, class, deadline_ns: None }
    }

    fn wd(ticket: u64, class: usize, deadline_ns: u64) -> Waiter {
        Waiter { ticket, class, deadline_ns: Some(deadline_ns) }
    }

    // ------------------------------------------------------- grammar --

    #[test]
    fn class_parse_display_roundtrip() {
        for text in [
            "gold",
            "gold:weight=4",
            "gold:weight=4:credits=16:deadline=10:slo=5",
            "gold:credits=2,silver:weight=2,free",
            "a:deadline=3,b:deadline=7,c",
            "batch:slo=12.5",
        ] {
            let classes = parse_classes(text).unwrap_or_else(|e| panic!("{text}: {e}"));
            let rendered = render_classes(&classes);
            let reparsed = parse_classes(&rendered).unwrap();
            assert_eq!(reparsed, classes, "{text} -> {rendered}");
        }
        assert!(parse_classes("").unwrap().is_empty());
        assert!(parse_classes("none").unwrap().is_empty());
    }

    #[test]
    fn class_parse_rejects_nonsense() {
        assert!(parse_classes(":weight=2").is_err(), "empty name");
        assert!(parse_classes("a,a").is_err(), "duplicate name");
        assert!(parse_classes("a:weight=0").is_err(), "zero weight");
        assert!(parse_classes("a:credits=0").is_err(), "zero credits");
        assert!(parse_classes("a:deadline=0").is_err(), "zero deadline");
        assert!(parse_classes("a:slo=-1").is_err(), "negative slo");
        assert!(parse_classes("a:frob=1").is_err(), "unknown key");
        assert!(parse_classes("a:weight").is_err(), "missing value");
        assert!(parse_classes("weight=2").is_err(), "key=value as a name");
    }

    #[test]
    fn arbiter_kind_roundtrip_and_aliases() {
        for kind in ArbiterKind::ALL {
            assert_eq!(kind.name().parse::<ArbiterKind>().unwrap(), kind);
        }
        assert_eq!("weighted".parse::<ArbiterKind>().unwrap(), ArbiterKind::Wrr);
        assert_eq!("deadline".parse::<ArbiterKind>().unwrap(), ArbiterKind::Edf);
        assert!("lifo".parse::<ArbiterKind>().is_err());
        assert_eq!(ArbiterKind::default(), ArbiterKind::Fifo);
        assert!(ArbiterKind::Fifo.is_fifo_order());
        assert!(ArbiterKind::Credit.is_fifo_order());
        assert!(!ArbiterKind::Wrr.is_fifo_order());
    }

    // ------------------------------------------------------- policies --

    #[test]
    fn fifo_and_credit_always_pick_the_front() {
        let waiters = [w(3, 1), w(4, 0), w(5, 2)];
        assert_eq!(Fifo.pick(&waiters), 0);
        assert_eq!(CreditBased.pick(&waiters), 0);
    }

    #[test]
    fn wrr_share_tracks_weights_under_saturation() {
        // Both classes always have a waiter queued; after N grants the
        // issued counts must match the 3:1 weights within one grant.
        let classes = parse_classes("gold:weight=3,free").unwrap();
        let mut arb = WeightedRoundRobin::new(&classes);
        for t in 0..4000u64 {
            let waiters = [w(t * 2, 0), w(t * 2 + 1, 1)];
            let i = arb.pick(&waiters);
            arb.on_grant(waiters[i].class);
        }
        let issued = arb.issued();
        assert_eq!(issued[0] + issued[1], 4000);
        assert_eq!(issued[0], 3000, "gold gets 3/4 of grants: {issued:?}");
    }

    #[test]
    fn wrr_ties_break_fifo() {
        // Equal weights, equal issued: the earliest ticket must win.
        let mut arb = WeightedRoundRobin::new(&parse_classes("a,b").unwrap());
        let waiters = [w(10, 1), w(11, 0)];
        assert_eq!(arb.pick(&waiters), 0);
        arb.on_grant(1);
        // Class 1 now ahead: the class-0 waiter wins regardless of order.
        assert_eq!(arb.pick(&[w(12, 1), w(13, 0)]), 1);
    }

    #[test]
    fn edf_orders_by_deadline_with_fifo_tiebreak() {
        let edf = EarliestDeadlineFirst;
        assert_eq!(edf.pick(&[wd(0, 0, 500), wd(1, 1, 100), wd(2, 2, 300)]), 1);
        // Best-effort (no deadline) ranks after any deadline.
        assert_eq!(edf.pick(&[w(0, 0), wd(1, 1, 900)]), 1);
        // Equal deadlines: first (earliest ticket) wins.
        assert_eq!(edf.pick(&[wd(5, 0, 200), wd(6, 1, 200)]), 0);
        // All best-effort: pure FIFO.
        assert_eq!(edf.pick(&[w(7, 0), w(8, 1)]), 0);
    }

    #[test]
    fn make_arbiter_dispatches_every_kind() {
        for kind in ArbiterKind::ALL {
            let arb = make_arbiter(kind, &parse_classes("a,b").unwrap());
            assert_eq!(arb.kind(), kind);
            assert_eq!(arb.pick(&[w(0, 0)]), 0, "singleton pick is always 0");
        }
    }

    // -------------------------------------------------------- credits --

    #[test]
    fn credit_bank_conserves_across_take_and_put() {
        let bank = CreditBank::new(&parse_classes("a:credits=2,b:credits=1").unwrap(), 8);
        assert!(bank.try_take(0));
        assert!(bank.try_take(0));
        assert!(!bank.try_take(0), "class a exhausted");
        assert!(bank.try_take(1));
        assert!(!bank.try_take(1));
        let s = bank.snapshot();
        assert!(s.conserved(), "{s:?}");
        assert_eq!(s.outstanding(0), 2);
        bank.put(0);
        assert!(bank.try_take(0));
        let s = bank.snapshot();
        assert!(s.conserved(), "{s:?}");
        assert_eq!(s.taken, vec![3, 1]);
        assert_eq!(s.returned, vec![1, 0]);
    }

    #[test]
    fn credit_bank_blocking_take_waits_for_put() {
        let bank = std::sync::Arc::new(CreditBank::new(&[], 1));
        assert!(bank.try_take(0));
        let taker = {
            let bank = std::sync::Arc::clone(&bank);
            std::thread::spawn(move || bank.take_blocking(0))
        };
        std::thread::sleep(Duration::from_millis(20));
        bank.put(0);
        taker.join().unwrap();
        let s = bank.snapshot();
        assert_eq!(s.outstanding(0), 1);
        assert!(s.conserved());
    }

    #[test]
    fn credit_bank_timeout_take_expires() {
        let bank = CreditBank::new(&[], 1);
        assert!(bank.take_timeout(0, Duration::from_millis(5)));
        assert!(!bank.take_timeout(0, Duration::from_millis(5)), "pool empty");
        bank.put(0);
        assert!(bank.take_timeout(0, Duration::from_millis(5)));
        assert!(bank.snapshot().conserved());
    }

    #[test]
    fn default_credit_budget_applies_to_unbudgeted_classes() {
        let bank = CreditBank::new(&parse_classes("a:credits=1,b").unwrap(), 3);
        let s = bank.snapshot();
        assert_eq!(s.total, vec![1, 3]);
    }

    #[test]
    fn class_of_deals_round_robin() {
        assert_eq!(class_of(0, 2), 0);
        assert_eq!(class_of(5, 2), 1);
        assert_eq!(class_of(7, 0), 0, "no classes = one implicit class");
        assert_eq!(class_of(7, 1), 0);
    }
}
