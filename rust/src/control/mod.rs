//! Access control: the global GPU lock and per-strategy runtime state.
//! Strategy *behaviour* lives in the engine's routine hooks
//! (gpu/engine.rs), driven by `config::StrategyKind`; this module holds
//! the shared mechanisms (lock, worker threads, live controller).

pub mod lock;
pub mod live;
pub mod serve;
pub mod worker;

pub use live::LiveController;
pub use lock::{GpuLock, LockClient};
pub use serve::{serve_dna, ServeReport};
pub use worker::{WorkerPhase, WorkerState};
