//! Access control: the policy layer, the global GPU lock/gate, the
//! per-strategy runtime state, and the sharded serving fleet.
//!
//! Strategy *dispatch* lives in exactly one place — [`policy`] — shared
//! by the simulator (`gpu::engine` interprets the policy's plans with
//! simulated events) and the live serving subsystem ([`serving`]
//! interprets the same plans with real threads and the FIFO [`gate`]).
//! This module also holds the shared mechanisms: the simulated semaphore
//! ([`lock`]), the live gate ([`gate`]), and worker-thread state
//! ([`worker`]) — and the horizontal scaling layer ([`fleet`]): a
//! [`ShardRouter`] placing clients over N shards, each shard owning its
//! own gate + policy instance so the paper's per-GPU isolation guarantee
//! survives fleet-scale serving. The [`traffic`] module opens the load
//! axis: seeded arrival processes, bounded admission queues with shed
//! policies, and SLO accounting measured from arrival (DESIGN.md §9).
//! The [`fault`] module closes the loop on failure: deterministic fault
//! injection, request retries, per-shard health breakers, and the gate's
//! lease watchdog accounting (DESIGN.md §12). The [`arbiter`] module
//! extracts the grant-ordering decision behind a pluggable [`Arbiter`]
//! trait — FIFO (golden-pinned), weighted round-robin, credit-based
//! admission backpressure, earliest-deadline-first — shared by the live
//! gate and the simulator's lock wake path (DESIGN.md §13). The
//! [`concurrency`] module extracts the serialization *assumption*
//! itself: a [`ConcurrencyMode`] (`cook|mps|mig|streams`) decides what
//! may run concurrently in both interpreters — the exclusive COOK gate,
//! MPS spatial sharing, MIG hard partitions, or priority streams
//! (DESIGN.md §14). The [`elastic`] module makes the fleet's *size*
//! dynamic: an SLO-driven controller hot-adds shards under pressure and
//! retires quiet ones drain-first, with idle workers stealing from the
//! deepest live queue, while the conservation law holds through every
//! scale event (DESIGN.md §15).

pub mod arbiter;
pub mod concurrency;
pub mod elastic;
pub mod fault;
pub mod fleet;
pub mod gate;
pub mod lock;
pub mod policy;
pub mod serving;
pub mod traffic;
pub mod worker;

pub use arbiter::{
    class_of, make_arbiter, parse_classes, render_classes, Arbiter, ArbiterKind, CreditBank,
    CreditSnapshot, TenantClass, Waiter,
};
pub use concurrency::{ConcurrencyMode, ModeGate};
pub use elastic::{
    plan_windows, serve_fleet_elastic, AutoscaleSpec, ElasticReport, ScaleEvent,
};
pub use fault::{
    panic_msg, Breaker, FaultPlan, FaultReport, FaultSpec, FaultyBackend, HealthSnapshot,
    HealthState, RequestTag, RetryPolicy, ShardHealth,
};
pub use fleet::{serve_fleet, FleetReport, FleetSpec, Placement, ShardReport, ShardRouter};
pub use gate::{GateGrant, GateStats, GpuGate};
pub use lock::{GpuLock, LockClient, QueuedWaiter};
pub use policy::{AccessPolicy, Admission, Arbitration, OrderedOpRule};
pub use serving::{
    serve, serve_dna, ClassReport, ManifestBackend, PayloadExecutor, ResolvedPayload,
    ServeBackend, ServeReport, ServeSpec, SyntheticBackend,
};
pub use traffic::{
    AdmissionQueue, ArrivalProcess, ShedPolicy, TrafficReport, TrafficSpec,
};
pub use worker::{WorkerPhase, WorkerState};
