//! The `AccessPolicy` layer: the ONE place that maps a [`StrategyKind`]
//! to behaviour.
//!
//! The paper specifies access control as a four-step state machine —
//! acquire → insert → sync → release (Algs. 1–7) — realised by
//! interchangeable strategies. Before this layer existed, each strategy
//! was implemented twice: once inside the discrete-event simulator
//! (`gpu::engine`) and once, divergently, in the live serving path. Both
//! consumers now ask the policy *what* a strategy does and keep only the
//! *mechanism* (event plumbing, threads, locks) local:
//!
//! * the simulator matches on [`Admission`] / [`OrderedOpRule`] /
//!   [`Arbitration`] plans instead of on `StrategyKind`;
//! * the live serving subsystem (`control::serving`) interprets the same
//!   plans with real threads and the FIFO [`GpuGate`](crate::control::gate).
//!
//! Adding a strategy means adding a variant here and teaching both
//! interpreters about any genuinely new plan — not copying a `match`.

use crate::config::StrategyKind;

/// How a kernel/copy submission is admitted to the device (the
/// acquire/insert/sync/release shape of Algs. 1–5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Insert directly into the submitting context's stream; no lock
    /// traffic (the `none` baseline and the spatial `ptb` baseline).
    Direct,
    /// Alg. 3 (callback strategy): bracket the op with deferred
    /// acquire/release closures that ride the stream as host funcs. The
    /// submitter does not block; the closures take/release the GPU lock
    /// when the stream reaches them.
    CallbackBracket,
    /// Alg. 4 (synced strategy): the submitter itself acquires the GPU
    /// lock, inserts the op, synchronises on its completion, releases.
    AcquireSyncRelease,
    /// Alg. 5 (worker strategy): deep-copy the arguments and defer the op
    /// to the application's worker, which serialises under the lock.
    DeferToWorker,
}

/// How an application host-func ("other ordered operation", Alg. 7) is
/// treated.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OrderedOpRule {
    /// Trampoline: pass through unchanged (only kernels/copies are
    /// hooked by this strategy).
    Passthrough,
    /// Alg. 7: wait for the worker to drain, then insert in the app
    /// stream (preserves cross-queue ordering).
    DrainWorkerFirst,
}

/// Who owns the SMs when several contexts have work.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Arbitration {
    /// Hardware temporal arbitration: one context active at a time,
    /// quantum-based preemptive switching (every temporal strategy).
    Temporal,
    /// Spatial partitioning (PTB baseline): all contexts co-active, each
    /// pinned to its SM share; no context switching.
    Spatial,
}

/// The per-strategy access-control policy: a pure, copyable description
/// of behaviour shared by the simulator and the live serving subsystem.
///
/// # Example
///
/// ```
/// use cook::config::StrategyKind;
/// use cook::control::policy::{AccessPolicy, Admission};
///
/// let synced = AccessPolicy::new(StrategyKind::Synced);
/// assert_eq!(synced.admission(), Admission::AcquireSyncRelease);
/// assert!(synced.gated()); // serialises behind the GPU lock
///
/// let ptb = AccessPolicy::new(StrategyKind::Ptb);
/// assert!(!ptb.gated()); // spatial partitioning, no lock traffic
/// assert_eq!(ptb.sm_share(4), 0.25); // each of 4 apps owns 1/4 of the SMs
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AccessPolicy {
    kind: StrategyKind,
}

impl AccessPolicy {
    pub fn new(kind: StrategyKind) -> Self {
        Self { kind }
    }

    pub fn kind(&self) -> StrategyKind {
        self.kind
    }

    /// Admission plan for a kernel or copy submission.
    pub fn admission(&self) -> Admission {
        match self.kind {
            StrategyKind::None | StrategyKind::Ptb => Admission::Direct,
            StrategyKind::Callback => Admission::CallbackBracket,
            StrategyKind::Synced => Admission::AcquireSyncRelease,
            StrategyKind::Worker => Admission::DeferToWorker,
        }
    }

    /// Treatment of application host-funcs (Alg. 7).
    pub fn ordered_op(&self) -> OrderedOpRule {
        match self.kind {
            StrategyKind::Worker => OrderedOpRule::DrainWorkerFirst,
            _ => OrderedOpRule::Passthrough,
        }
    }

    /// Does this policy run a per-application deferred worker (Alg. 6)?
    pub fn uses_worker(&self) -> bool {
        self.admission() == Admission::DeferToWorker
    }

    /// SM ownership model while several contexts have device work.
    pub fn arbitration(&self) -> Arbitration {
        match self.kind {
            StrategyKind::Ptb => Arbitration::Spatial,
            _ => Arbitration::Temporal,
        }
    }

    /// May application `app` (of `num_apps`) place blocks on `sm` (of
    /// `num_sms`)? Temporal policies allow every SM; the spatial PTB
    /// baseline splits the SMs evenly, giving the last application any
    /// remainder.
    pub fn sm_allowed(&self, app: usize, num_apps: usize, sm: usize, num_sms: usize) -> bool {
        if self.arbitration() != Arbitration::Spatial || num_apps <= 1 {
            return true;
        }
        let per = (num_sms / num_apps).max(1);
        sm / per == app || (sm / per >= num_apps && app == num_apps - 1)
    }

    /// The fraction of SMs available to one of `num_apps` applications
    /// under this policy — 1.0 for temporal policies (full device while
    /// active), `1/num_apps` under spatial partitioning. The live serving
    /// subsystem uses this to emulate PTB-style SM shares on platforms
    /// without real SM pinning.
    pub fn sm_share(&self, num_apps: usize) -> f64 {
        match self.arbitration() {
            Arbitration::Temporal => 1.0,
            Arbitration::Spatial => 1.0 / num_apps.max(1) as f64,
        }
    }

    /// Does admission serialise GPU operations behind the global lock?
    /// (Drives the serving subsystem's decision to construct a
    /// [`GpuGate`](crate::control::gate::GpuGate).)
    pub fn gated(&self) -> bool {
        !matches!(self.admission(), Admission::Direct)
    }
}

impl From<StrategyKind> for AccessPolicy {
    fn from(kind: StrategyKind) -> Self {
        Self::new(kind)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The dispatch table exactly as `gpu/engine.rs::routine_gpu_op`
    /// implemented it before the policy layer was extracted (the
    /// "legacy oracle"). The refactor is behaviour-preserving iff the
    /// policy maps every strategy to the same plan the engine's old
    /// `match` selected.
    fn legacy_admission(kind: StrategyKind) -> Admission {
        match kind {
            StrategyKind::None | StrategyKind::Ptb => Admission::Direct,
            StrategyKind::Callback => Admission::CallbackBracket,
            StrategyKind::Synced => Admission::AcquireSyncRelease,
            StrategyKind::Worker => Admission::DeferToWorker,
        }
    }

    fn legacy_ordered_op(kind: StrategyKind) -> OrderedOpRule {
        if kind == StrategyKind::Worker {
            OrderedOpRule::DrainWorkerFirst
        } else {
            OrderedOpRule::Passthrough
        }
    }

    /// The old `Sim::new` PTB SM-mask formula, verbatim.
    fn legacy_sm_mask(kind: StrategyKind, n: usize, num_sms: usize) -> Vec<Vec<bool>> {
        (0..n)
            .map(|i| {
                (0..num_sms)
                    .map(|sm| {
                        if kind == StrategyKind::Ptb && n > 1 {
                            let per = (num_sms / n).max(1);
                            sm / per == i || (sm / per >= n && i == n - 1)
                        } else {
                            true
                        }
                    })
                    .collect()
            })
            .collect()
    }

    #[test]
    fn admission_matches_legacy_engine_dispatch() {
        for kind in StrategyKind::ALL {
            let p = AccessPolicy::new(kind);
            assert_eq!(p.admission(), legacy_admission(kind), "{kind}");
            assert_eq!(p.ordered_op(), legacy_ordered_op(kind), "{kind}");
        }
    }

    #[test]
    fn worker_flag_only_for_worker_strategy() {
        for kind in StrategyKind::ALL {
            assert_eq!(
                AccessPolicy::new(kind).uses_worker(),
                kind == StrategyKind::Worker,
                "{kind}"
            );
        }
    }

    #[test]
    fn only_ptb_is_spatial() {
        for kind in StrategyKind::ALL {
            let arb = AccessPolicy::new(kind).arbitration();
            if kind == StrategyKind::Ptb {
                assert_eq!(arb, Arbitration::Spatial);
            } else {
                assert_eq!(arb, Arbitration::Temporal, "{kind}");
            }
        }
    }

    #[test]
    fn sm_mask_matches_legacy_formula() {
        for kind in StrategyKind::ALL {
            for n in [1usize, 2, 3, 5] {
                for num_sms in [1usize, 4, 8, 10] {
                    let legacy = legacy_sm_mask(kind, n, num_sms);
                    let p = AccessPolicy::new(kind);
                    for app in 0..n {
                        for sm in 0..num_sms {
                            assert_eq!(
                                p.sm_allowed(app, n, sm, num_sms),
                                legacy[app][sm],
                                "{kind} n={n} sms={num_sms} app={app} sm={sm}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn spatial_masks_partition_all_sms() {
        // Every SM belongs to exactly one app under PTB.
        let p = AccessPolicy::new(StrategyKind::Ptb);
        for n in [2usize, 3, 4] {
            for sm in 0..8 {
                let owners: Vec<usize> =
                    (0..n).filter(|&a| p.sm_allowed(a, n, sm, 8)).collect();
                assert_eq!(owners.len(), 1, "n={n} sm={sm} owners={owners:?}");
            }
        }
    }

    #[test]
    fn gated_matches_lock_usage() {
        assert!(!AccessPolicy::new(StrategyKind::None).gated());
        assert!(!AccessPolicy::new(StrategyKind::Ptb).gated());
        assert!(AccessPolicy::new(StrategyKind::Callback).gated());
        assert!(AccessPolicy::new(StrategyKind::Synced).gated());
        assert!(AccessPolicy::new(StrategyKind::Worker).gated());
    }

    #[test]
    fn sm_share_is_fractional_only_under_spatial() {
        assert_eq!(AccessPolicy::new(StrategyKind::Synced).sm_share(4), 1.0);
        assert_eq!(AccessPolicy::new(StrategyKind::Ptb).sm_share(4), 0.25);
        assert_eq!(AccessPolicy::new(StrategyKind::Ptb).sm_share(0), 1.0);
    }
}
