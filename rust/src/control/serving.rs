//! Live serving subsystem: real payload executions from concurrent
//! clients, admitted per the configured [`AccessPolicy`].
//!
//! This replaces the first-generation `serve_dna` path, which supported
//! three of the five strategies, hard-coded the DNA payload, and
//! serialised on a bare `Mutex<()>`. The rebuilt subsystem:
//!
//! * serves **any payload in the AOT manifest** (DNA-Net, mmult, vecadd —
//!   or a mix: client *i* serves `payloads[i % len]`), via a pluggable
//!   [`ServeBackend`] so tests and artifact-less environments can run the
//!   full admission machinery against a synthetic executor;
//! * implements **all five strategies** by interpreting the same
//!   [`Admission`] plans as the simulator — the callback strategy runs its
//!   acquire/release as deferred closures riding a per-client stream
//!   thread (Alg. 3), and the PTB baseline falls back to an SM-share
//!   *simulation* (each client is slowed to its `1/clients` share, since
//!   a CPU-side runtime has no real SM pinning);
//! * admits through the mode-defined [`ModeGate`] (the FIFO-fair
//!   [`GpuGate`](crate::control::gate::GpuGate) under the default `cook`
//!   mode; multi-holder or partitioned admission under
//!   `mps`/`mig`/`streams` — DESIGN.md §14), recording wait/hold
//!   histograms surfaced in the report;
//! * supports **request batching** (`batch > 1` amortises one gate
//!   admission over a burst of requests);
//! * reports **per-payload** latency/IPS breakdowns in [`ServeReport`].
//!
//! Engines may wrap non-`Send` handles (PJRT client pointers), so every
//! executing thread builds its *own* executor through the backend —
//! exactly like the paper's setup where each application is a separate
//! process with its own CUDA context.

use crate::config::StrategyKind;
use crate::control::arbiter::{class_of, ArbiterKind, CreditBank, CreditSnapshot, TenantClass};
use crate::control::fault::{panic_msg, FaultPlan, FaultReport, RequestTag, RetryPolicy};
use crate::control::concurrency::{ConcurrencyMode, ModeGate};
use crate::control::gate::GateStats;
use crate::control::policy::{AccessPolicy, Admission};
use crate::control::traffic::{
    AdmissionQueue, ShedPolicy, TrafficReport, TrafficSpec,
};
use crate::metrics::stats::{Histogram, LatencyStats};
use anyhow::{anyhow, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Barrier};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------
// backend abstraction
// ---------------------------------------------------------------------

/// A per-thread payload executor (may wrap non-`Send` engine handles).
pub trait PayloadExecutor {
    /// Execute artifact `payload` with flat f32 inputs.
    fn execute(&self, payload: usize, inputs: &[Vec<f32>]) -> Result<Vec<f32>>;

    /// Execute one *identified* request. Fault-injecting executors key
    /// their decisions off the tag; everything else ignores it. Warm-ups
    /// go through the untagged [`PayloadExecutor::execute`], which is
    /// what keeps them outside the fault domain.
    fn execute_tagged(
        &self,
        payload: usize,
        inputs: &[Vec<f32>],
        _tag: RequestTag,
    ) -> Result<Vec<f32>> {
        self.execute(payload, inputs)
    }
}

/// A payload resolved against the backend: everything a client needs to
/// generate requests and validate responses.
#[derive(Debug, Clone)]
pub struct ResolvedPayload {
    /// Executor-side payload index.
    pub index: usize,
    pub name: String,
    /// Template inputs (perturbed per request, §VI-C).
    pub base_inputs: Vec<Vec<f32>>,
    /// Expected output element count.
    pub out_elems: usize,
}

/// Source of executors and payload metadata for a serving run. `Sync`
/// because every client thread resolves/builds through a shared borrow.
pub trait ServeBackend: Sync {
    fn resolve(&self, payload: &str) -> Result<ResolvedPayload>;
    /// Build a fresh executor owned by the calling thread.
    fn executor(&self) -> Result<Box<dyn PayloadExecutor>>;
    /// The active fault plan, if this backend injects faults (see
    /// [`crate::control::fault::FaultyBackend`]). The serving layer uses
    /// this to attach injection counts to reports and to *tolerate*
    /// terminal request failures (count them instead of failing the run).
    fn fault_plan(&self) -> Option<&FaultPlan> {
        None
    }
}

/// Boxed backends serve like their contents (the CLI holds a
/// `Box<dyn ServeBackend>` and may wrap it in a `FaultyBackend`).
impl<B: ServeBackend + ?Sized> ServeBackend for Box<B> {
    fn resolve(&self, payload: &str) -> Result<ResolvedPayload> {
        (**self).resolve(payload)
    }

    fn executor(&self) -> Result<Box<dyn PayloadExecutor>> {
        (**self).executor()
    }

    fn fault_plan(&self) -> Option<&FaultPlan> {
        (**self).fault_plan()
    }
}

/// The real backend: AOT artifacts under a manifest directory, executed
/// by the runtime engine (PJRT when built with the `pjrt` feature, the
/// native interpreter otherwise).
pub struct ManifestBackend {
    dir: PathBuf,
    /// Manifest parsed once on first resolve (not in `new`, so merely
    /// constructing a backend cannot fail).
    manifest: std::sync::OnceLock<crate::runtime::Manifest>,
}

impl ManifestBackend {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self { dir: dir.into(), manifest: std::sync::OnceLock::new() }
    }

    fn manifest(&self) -> Result<&crate::runtime::Manifest> {
        if let Some(m) = self.manifest.get() {
            return Ok(m);
        }
        let m = crate::runtime::Manifest::load(&self.dir)?;
        // Another thread may have won the set race — either way a value
        // is present now; report (don't panic) if somehow not (ISSUE 7).
        let _ = self.manifest.set(m);
        self.manifest
            .get()
            .ok_or_else(|| anyhow!("manifest cell empty after set (load race)"))
    }
}

impl PayloadExecutor for crate::runtime::Engine {
    fn execute(&self, payload: usize, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        crate::runtime::Engine::execute(self, payload, inputs)
    }
}

impl ServeBackend for ManifestBackend {
    fn resolve(&self, payload: &str) -> Result<ResolvedPayload> {
        let manifest = self.manifest()?;
        let index = manifest
            .artifacts
            .iter()
            .position(|a| a.name == payload)
            .ok_or_else(|| {
                anyhow!(
                    "payload '{payload}' not in the AOT manifest (have: {})",
                    manifest
                        .artifacts
                        .iter()
                        .map(|a| a.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })?;
        let spec = &manifest.artifacts[index];
        Ok(ResolvedPayload {
            index,
            name: spec.name.clone(),
            base_inputs: spec.golden_inputs(),
            out_elems: spec.out_elems(),
        })
    }

    fn executor(&self) -> Result<Box<dyn PayloadExecutor>> {
        Ok(Box::new(crate::runtime::Engine::load(&self.dir)?))
    }
}

/// Synthetic backend: deterministic CPU work with a configurable
/// per-request cost. Lets the whole admission machinery (gate fairness,
/// batching, all five strategies) run — and be tested — without AOT
/// artifacts or a PJRT client.
pub struct SyntheticBackend {
    /// Busy-spin cost per request, microseconds.
    pub exec_us: u64,
    /// Input vector length per argument.
    pub elems: usize,
}

impl SyntheticBackend {
    pub fn new(exec_us: u64) -> Self {
        Self { exec_us, elems: 64 }
    }
}

struct SyntheticExecutor {
    exec_us: u64,
}

impl PayloadExecutor for SyntheticExecutor {
    fn execute(&self, payload: usize, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        let t0 = Instant::now();
        let budget = Duration::from_micros(self.exec_us);
        // Deterministic reduction over the inputs, re-run until the cost
        // budget elapses (busy spin models a device-bound kernel).
        let mut acc = payload as f32;
        loop {
            for v in inputs {
                for (i, x) in v.iter().enumerate() {
                    acc += x * ((i % 7) as f32 - 3.0);
                }
            }
            if t0.elapsed() >= budget {
                break;
            }
        }
        Ok(vec![acc; 8])
    }
}

impl ServeBackend for SyntheticBackend {
    fn resolve(&self, payload: &str) -> Result<ResolvedPayload> {
        // Any name resolves; index is its position in the standard payload
        // list when known (keeps reports aligned with the real manifest).
        let index = crate::runtime::PAYLOAD_NAMES
            .iter()
            .position(|n| *n == payload)
            .unwrap_or(0);
        Ok(ResolvedPayload {
            index,
            name: payload.to_string(),
            base_inputs: vec![vec![0.125; self.elems], vec![0.25; self.elems]],
            out_elems: 8,
        })
    }

    fn executor(&self) -> Result<Box<dyn PayloadExecutor>> {
        Ok(Box::new(SyntheticExecutor { exec_us: self.exec_us }))
    }
}

// ---------------------------------------------------------------------
// spec + report
// ---------------------------------------------------------------------

/// Configuration of one serving run.
///
/// # Example
///
/// ```
/// use cook::config::StrategyKind;
/// use cook::control::serving::{serve, ServeSpec, SyntheticBackend};
///
/// let spec = ServeSpec::new(StrategyKind::Worker, "dna")
///     .with_clients(2)
///     .with_requests(3)
///     .with_batch(1);
/// let report = serve(&spec, &SyntheticBackend::new(20)).unwrap();
/// assert_eq!(report.total(), 6);
/// assert!(report.gate.is_some()); // worker serialises behind the gate
/// ```
#[derive(Debug, Clone)]
pub struct ServeSpec {
    pub strategy: StrategyKind,
    /// Payload names; client `i` serves `payloads[i % payloads.len()]`
    /// (closed loop) / arrival `k` serves `payloads[k % len]` (open loop).
    pub payloads: Vec<String>,
    pub clients: usize,
    /// Requests per client. Under open-loop arrivals the run generates
    /// `clients * requests` arrivals total (same request budget, but
    /// paced by the arrival process instead of by completions).
    pub requests: usize,
    /// Requests admitted per gate grant (1 = per-op admission, the
    /// paper's shape; >1 amortises admission over a burst).
    pub batch: usize,
    /// Traffic shape: arrival process, admission-queue bound, shed
    /// policy, SLO target. Defaults to the historical closed loop.
    pub traffic: TrafficSpec,
    /// Keep the exact per-request latency vectors alongside the
    /// streaming sketch (`--exact-quantiles`): quantiles then come from
    /// the exact nearest-rank path at O(n log n) report cost. Off by
    /// default — the sketch's <= 2% relative error is ample for latency
    /// reporting, and recording stays O(1) per request.
    pub exact_quantiles: bool,
    /// Request-level retry policy (`--retries`). Disabled by default.
    pub retry: RetryPolicy,
    /// Gate lease in milliseconds (`--lease-ms`): holders exceeding it
    /// are revoked by the waiter-driven watchdog. None = no watchdog.
    pub lease_ms: Option<u64>,
    /// Which fleet shard this spec serves (0 for standalone runs; set by
    /// [`crate::control::fleet`] so fault selectors and per-shard
    /// injection counters address the right shard).
    pub shard: usize,
    /// Grant-ordering policy for the gate (`--arbiter`). FIFO — the
    /// paper's shape — unless asked otherwise.
    pub arbiter: ArbiterKind,
    /// Tenant classes (`--classes`). Empty = one implicit class. Clients
    /// (closed loop) and arrival sequence numbers (open loop) are dealt
    /// round-robin over the list by [`class_of`] — the same rule the
    /// simulator applies to application indices, which is what makes
    /// sim-vs-serving starvation rankings comparable.
    pub classes: Vec<TenantClass>,
    /// Concurrency mode (`--concurrency`, DESIGN.md §14): how many
    /// clients the admission gate lets hold the device at once. `Cook`
    /// (the default) is the paper's exclusive FIFO gate, bit-identical
    /// to the pre-refactor serving path.
    pub concurrency: ConcurrencyMode,
}

impl ServeSpec {
    pub fn new(strategy: StrategyKind, payload: impl Into<String>) -> Self {
        Self {
            strategy,
            payloads: vec![payload.into()],
            clients: 2,
            requests: 50,
            batch: 1,
            traffic: TrafficSpec::default(),
            exact_quantiles: false,
            retry: RetryPolicy::default(),
            lease_ms: None,
            shard: 0,
            arbiter: ArbiterKind::Fifo,
            classes: Vec::new(),
            concurrency: ConcurrencyMode::Cook,
        }
    }

    pub fn with_payloads(mut self, payloads: Vec<String>) -> Self {
        self.payloads = payloads;
        self
    }

    pub fn with_clients(mut self, clients: usize) -> Self {
        self.clients = clients;
        self
    }

    pub fn with_requests(mut self, requests: usize) -> Self {
        self.requests = requests;
        self
    }

    pub fn with_batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    pub fn with_traffic(mut self, traffic: TrafficSpec) -> Self {
        self.traffic = traffic;
        self
    }

    pub fn with_arrivals(mut self, arrivals: crate::control::traffic::ArrivalProcess) -> Self {
        self.traffic.arrivals = arrivals;
        self
    }

    pub fn with_exact_quantiles(mut self, exact: bool) -> Self {
        self.exact_quantiles = exact;
        self
    }

    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    pub fn with_lease_ms(mut self, lease_ms: u64) -> Self {
        self.lease_ms = Some(lease_ms);
        self
    }

    pub fn with_arbiter(mut self, arbiter: ArbiterKind) -> Self {
        self.arbiter = arbiter;
        self
    }

    pub fn with_classes(mut self, classes: Vec<TenantClass>) -> Self {
        self.classes = classes;
        self
    }

    pub fn with_concurrency(mut self, mode: ConcurrencyMode) -> Self {
        self.concurrency = mode;
        self
    }

    pub(crate) fn validate(&self) -> Result<()> {
        if self.clients == 0 || self.requests == 0 {
            return Err(anyhow!("serve requires clients > 0 and requests > 0"));
        }
        if self.batch == 0 {
            return Err(anyhow!("batch must be >= 1"));
        }
        if self.payloads.is_empty() {
            return Err(anyhow!("at least one payload required"));
        }
        self.traffic.validate().map_err(|e| anyhow!(e))?;
        Ok(())
    }
}

/// Latency breakdown for one payload.
#[derive(Debug)]
pub struct PayloadReport {
    pub payload: String,
    /// Per-request latency distribution, milliseconds (streaming sketch;
    /// exact vector retained on the `--exact-quantiles` path).
    pub latency: LatencyStats,
}

impl PayloadReport {
    pub fn ips(&self, wall_s: f64) -> f64 {
        self.latency.count() as f64 / wall_s.max(1e-9)
    }
}

/// Per-tenant-class breakdown: latency, goodput and SLO attainment for
/// one configured [`TenantClass`] (DESIGN.md §13). Starvation shows up
/// here — a starved class keeps its `offered` count but loses
/// `completed`/`within_slo`, cratering its attainment.
#[derive(Debug, Clone)]
pub struct ClassReport {
    pub name: String,
    /// Per-request latency distribution for this class, ms.
    pub latency: LatencyStats,
    /// Requests offered to this class (arrivals under open loop; the
    /// class's clients x requests under closed loop).
    pub offered: usize,
    /// Requests completed for this class.
    pub completed: usize,
    /// Completions within the class SLO.
    pub within_slo: usize,
    /// The SLO this class was judged against, ms (its own `slo=`
    /// override, else the run-level [`TrafficSpec::slo_ms`]).
    pub slo_ms: f64,
}

impl ClassReport {
    /// SLO-attaining completions per second of wall clock.
    pub fn goodput(&self, wall_s: f64) -> f64 {
        self.within_slo as f64 / wall_s.max(1e-9)
    }

    /// Share of *offered* requests completed within SLO. Judging against
    /// offered (not completed) traffic means shed and starved requests
    /// count against the class — which is the point.
    pub fn slo_attainment_pct(&self) -> f64 {
        if self.offered == 0 {
            return 100.0;
        }
        self.within_slo as f64 / self.offered as f64 * 100.0
    }

    /// Fold another shard's breakdown of the *same* class into this one
    /// (fleet assembly; entries are matched by position, since every
    /// shard runs the same class list).
    pub fn merge(&mut self, other: &ClassReport) {
        self.latency.merge(&other.latency);
        self.latency.seal();
        self.offered += other.offered;
        self.completed += other.completed;
        self.within_slo += other.within_slo;
    }
}

/// Fold per-class samples `(class, latency ms)` into [`ClassReport`]s
/// (shared by the closed-loop, open-loop, and fleet assembly paths —
/// one accounting, three callers, so per-class SLO math can't diverge).
pub(crate) fn build_class_reports(
    classes: &[TenantClass],
    samples: Vec<Sample>,
    offered: &[usize],
    default_slo_ms: f64,
    exact: bool,
) -> Vec<ClassReport> {
    if classes.is_empty() {
        return Vec::new();
    }
    let slo: Vec<f64> = classes.iter().map(|c| c.slo_ms.unwrap_or(default_slo_ms)).collect();
    let mut lat: Vec<LatencyStats> = vec![LatencyStats::new(exact); classes.len()];
    let mut completed = vec![0usize; classes.len()];
    let mut within = vec![0usize; classes.len()];
    for (class, ms) in samples {
        let c = class.min(classes.len() - 1);
        completed[c] += 1;
        if ms <= slo[c] {
            within[c] += 1;
        }
        lat[c].record(ms);
    }
    classes
        .iter()
        .zip(lat)
        .enumerate()
        .map(|(c, (tc, mut l))| {
            l.seal();
            ClassReport {
                name: tc.name.clone(),
                latency: l,
                offered: offered.get(c).copied().unwrap_or(completed[c]),
                completed: completed[c],
                within_slo: within[c],
                slo_ms: slo[c],
            }
        })
        .collect()
}

/// Result of a serving run: pooled + per-payload latency distributions,
/// throughput, and (for gated strategies) the gate's wait/hold
/// histograms. Aggregate across shards with
/// [`crate::control::fleet::FleetReport`]. Quantiles are nearest-rank
/// over a streaming sketch (exact on the `--exact-quantiles` path — see
/// [`ServeReport::latency_p`]); [`ServeReport::render`] produces the
/// human table printed by `cook serve`.
#[derive(Debug)]
pub struct ServeReport {
    pub strategy: StrategyKind,
    /// Concurrency mode the run was admitted under (DESIGN.md §14).
    pub concurrency: ConcurrencyMode,
    pub clients: usize,
    pub requests_per_client: usize,
    pub batch: usize,
    pub wall_s: f64,
    /// Per-request latency distribution across all payloads, ms.
    pub latency: LatencyStats,
    /// Per-payload breakdowns (one entry per distinct served payload).
    pub per_payload: Vec<PayloadReport>,
    /// Per-tenant-class breakdowns (empty unless classes are configured).
    pub classes: Vec<ClassReport>,
    /// Gate wait/hold statistics (None for ungated strategies).
    pub gate: Option<GateStats>,
    /// Credit-bank counters at run end (credit arbiter, open loop only);
    /// `conserved()` must hold and every class must end with zero
    /// outstanding credits — pinned by `tests/arbitration.rs`.
    pub credits: Option<CreditSnapshot>,
    /// Traffic/SLO accounting (Some for open-loop runs).
    pub traffic: Option<TrafficReport>,
    /// Fault/recovery accounting (Some when a fault plan was active or
    /// anything fault-shaped — failures, revocations — happened).
    pub fault: Option<FaultReport>,
}

impl ServeReport {
    /// Requests offered to the run (under open-loop arrivals some may
    /// have been shed; see [`ServeReport::traffic`]).
    pub fn total(&self) -> usize {
        self.clients * self.requests_per_client
    }

    /// Completed inferences per second of wall clock (completions, not
    /// offered requests, so shed traffic never inflates throughput).
    pub fn ips(&self) -> f64 {
        self.latency.count() as f64 / self.wall_s.max(1e-9)
    }

    /// Nearest-rank quantile (rank `ceil(q*n)`) of the pooled latencies;
    /// 0.0 when no latency was recorded. Exact when the spec kept the
    /// exact vectors, within the sketch's <= 2% relative error bound
    /// otherwise (min/max are always exact).
    pub fn latency_p(&self, q: f64) -> f64 {
        self.latency.quantile(q)
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "{} clients x {} requests (batch {}), strategy {}: {:.1} IPS; \
             latency ms p50={:.2} p95={:.2} p99={:.2} max={:.2}",
            self.clients,
            self.requests_per_client,
            self.batch,
            self.strategy,
            self.ips(),
            self.latency_p(0.50),
            self.latency_p(0.95),
            self.latency_p(0.99),
            self.latency.max(),
        );
        // Non-default concurrency is worth a line even for ungated
        // strategies (gated runs also carry it in the gate stats); cook
        // output stays byte-identical to the pre-refactor render.
        if !self.concurrency.is_cook() {
            out.push_str(&format!("\n  concurrency {}", self.concurrency));
        }
        if self.per_payload.len() > 1 {
            for p in &self.per_payload {
                out.push_str(&format!(
                    "\n  payload {:<8} n={:<5} {:.1} IPS; p50={:.2} p95={:.2} ms",
                    p.payload,
                    p.latency.count(),
                    p.ips(self.wall_s),
                    p.latency.quantile(0.50),
                    p.latency.quantile(0.95),
                ));
            }
        }
        for c in &self.classes {
            out.push_str(&format!(
                "\n  class {:<8} completed={}/{} goodput {:.1}/s; \
                 p50={:.2} p95={:.2} ms; SLO {:.0} ms attainment {:.1}%",
                c.name,
                c.completed,
                c.offered,
                c.goodput(self.wall_s),
                c.latency.quantile(0.50),
                c.latency.quantile(0.95),
                c.slo_ms,
                c.slo_attainment_pct(),
            ));
        }
        if let Some(g) = &self.gate {
            for line in g.render().lines() {
                out.push_str("\n  ");
                out.push_str(line);
            }
        }
        if let Some(t) = &self.traffic {
            for line in t.render(self.wall_s).lines() {
                out.push_str("\n  ");
                out.push_str(line);
            }
        }
        if let Some(f) = &self.fault {
            if !f.is_empty() {
                for line in f.render().lines() {
                    out.push_str("\n  ");
                    out.push_str(line);
                }
            }
        }
        out
    }
}

// ---------------------------------------------------------------------
// the serve loop
// ---------------------------------------------------------------------

/// Per-request input perturbation (randomised inputs, §VI-C).
fn perturb(inputs: &mut [Vec<f32>], client: usize, request: usize) {
    if let Some(first) = inputs.first_mut() {
        for (i, v) in first.iter_mut().enumerate() {
            *v += ((request * 31 + client * 17 + i) % 13) as f32 * 1e-3;
        }
    }
}

/// One recorded request: (slot into `spec.payloads`, latency ms).
type Sample = (usize, f64);

/// A deferred stream operation (callback/worker strategies). The
/// acquire/release closures of Alg. 3 ride the stream as first-class
/// jobs, so the grant is held across job boundaries.
enum StreamJob {
    Acquire,
    Exec {
        payload: usize,
        slot: usize,
        /// Global request seq (fault decisions + retry jitter).
        seq: u64,
        inputs: Vec<Vec<f32>>,
        out_elems: usize,
        enqueued: Instant,
        record: bool,
    },
    Release,
}

/// Fold recorded samples into the pooled + per-payload latency stats
/// (shared by the closed-loop, open-loop and fleet assembly paths). One
/// pass recording into streaming sketches — the old accumulate-then-sort
/// tables paid an O(n log n) sort per report; the exact vectors (and
/// their sort) survive only behind `exact` (`--exact-quantiles`).
pub(crate) fn build_latency_stats(
    samples: Vec<Sample>,
    payloads: &[String],
    exact: bool,
) -> (LatencyStats, Vec<PayloadReport>) {
    let mut pooled = LatencyStats::new(exact);
    let mut by_slot: Vec<LatencyStats> = vec![LatencyStats::new(exact); payloads.len()];
    for (slot, ms) in samples {
        by_slot[slot].record(ms);
        pooled.record(ms);
    }
    pooled.seal();
    let mut per_payload = Vec::new();
    for (slot, mut lat) in by_slot.into_iter().enumerate() {
        if lat.is_empty() {
            continue;
        }
        lat.seal();
        per_payload.push(PayloadReport { payload: payloads[slot].clone(), latency: lat });
    }
    (pooled, per_payload)
}

/// Serve `spec` against `backend`.
///
/// Closed loop (the default): one client thread per client (plus a
/// stream/worker thread per client for the deferred strategies), all
/// sharing one FIFO [`GpuGate`] when the policy is gated. Open-loop
/// arrival processes (`spec.traffic`) take the open-loop path instead:
/// a paced generator in front of a bounded admission queue drained by a
/// fixed worker pool, with latency measured from arrival (DESIGN.md §9).
pub fn serve(spec: &ServeSpec, backend: &dyn ServeBackend) -> Result<ServeReport> {
    spec.validate()?;
    // Injected boot crash (`crash:shard=N` with no other selector): this
    // serve dies at startup, the way a crashing shard process would. The
    // fleet's catch_unwind turns it into a failed ShardReport.
    if let Some(plan) = backend.fault_plan() {
        plan.check_boot(spec.shard);
    }
    if spec.traffic.arrivals.is_open_loop() {
        return serve_open_loop(spec, backend);
    }
    let policy = AccessPolicy::new(spec.strategy);
    let resolved: Vec<ResolvedPayload> = spec
        .payloads
        .iter()
        .map(|p| backend.resolve(p))
        .collect::<Result<_>>()?;
    let gate = make_gate(spec, policy);

    let t0 = Instant::now();
    let joined: Vec<Result<(Vec<Sample>, FaultReport)>> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..spec.clients {
            let slot = c % resolved.len();
            let class = class_of(c, spec.classes.len());
            let rp = &resolved[slot];
            let gate = gate.as_ref();
            handles
                .push(s.spawn(move || run_client(spec, backend, policy, c, slot, class, rp, gate)));
        }
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(r) => r,
                Err(p) => Err(anyhow!("client thread panicked: {}", panic_msg(p))),
            })
            .collect()
    });
    let wall_s = t0.elapsed().as_secs_f64();

    let k = spec.classes.len();
    let mut samples = Vec::new();
    let mut class_samples: Vec<Sample> = Vec::new();
    let mut fault = FaultReport::default();
    for (c, r) in joined.into_iter().enumerate() {
        let (s, f) = r?;
        if k > 0 {
            let class = class_of(c, k);
            class_samples.extend(s.iter().map(|&(_, ms)| (class, ms)));
        }
        samples.extend(s);
        fault.merge(&f);
    }
    if let Some(plan) = backend.fault_plan() {
        fault.injected.merge(&plan.counts_for(spec.shard));
    }
    let gate_stats = gate.map(|g| g.stats());
    if let Some(g) = &gate_stats {
        fault.revocations += g.revocations;
    }
    let fault = (backend.fault_plan().is_some() || !fault.is_empty()).then_some(fault);
    let (latency, per_payload) = build_latency_stats(samples, &spec.payloads, spec.exact_quantiles);
    let mut offered = vec![0usize; k];
    if k > 0 {
        for c in 0..spec.clients {
            offered[class_of(c, k)] += spec.requests;
        }
    }
    let classes = build_class_reports(
        &spec.classes,
        class_samples,
        &offered,
        spec.traffic.slo_ms,
        spec.exact_quantiles,
    );
    Ok(ServeReport {
        strategy: spec.strategy,
        concurrency: spec.concurrency,
        clients: spec.clients,
        requests_per_client: spec.requests,
        batch: spec.batch,
        wall_s,
        latency,
        per_payload,
        classes,
        gate: gate_stats,
        credits: None,
        traffic: None,
        fault,
    })
}

/// The shard's gate for a run: the spec's concurrency mode decides the
/// admission shape (capacity-1 FIFO for `cook`, multi-holder for
/// `mps`/`streams`, per-class partitions for `mig`); leased
/// (watchdog-armed) when the spec asks for it; None for ungated
/// strategies.
pub(crate) fn make_gate(spec: &ServeSpec, policy: AccessPolicy) -> Option<ModeGate> {
    if !policy.gated() {
        return None;
    }
    Some(ModeGate::new(
        spec.concurrency,
        spec.arbiter,
        &spec.classes,
        spec.lease_ms.map(Duration::from_millis),
    ))
}

/// One failed execution attempt: the error plus whether it was a panic
/// (panics skip local retry — the "process" died — and hit the health
/// breaker harder than an error does).
pub(crate) struct ExecFailure {
    pub error: anyhow::Error,
    pub panicked: bool,
}

/// One contained execution attempt: panics are caught and folded into
/// the failure (the executor state is a shared borrow of valid data —
/// unwind safety holds because nothing is observed mid-mutation).
pub(crate) fn execute_attempt(
    exec: &dyn PayloadExecutor,
    rp: &ResolvedPayload,
    inputs: &[Vec<f32>],
    tag: RequestTag,
) -> Result<(), ExecFailure> {
    match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        exec.execute_tagged(rp.index, inputs, tag)
    })) {
        Ok(result) => result
            .and_then(|r| check_out(rp, &r))
            .map_err(|error| ExecFailure { error, panicked: false }),
        Err(p) => Err(ExecFailure {
            error: anyhow!("payload execution panicked: {}", panic_msg(p)),
            panicked: true,
        }),
    }
}

/// Execute one request to completion: contained attempts with bounded
/// backoff between them, up to the retry budget. Every failure, retry,
/// recovery and give-up lands in `tally`. Closed-loop retries back off
/// in place (possibly while holding the gate grant — see DESIGN.md §12
/// for why the open-loop fleet retries after release instead).
pub(crate) fn execute_faulted(
    exec: &dyn PayloadExecutor,
    rp: &ResolvedPayload,
    inputs: &[Vec<f32>],
    mut tag: RequestTag,
    retry: RetryPolicy,
    tally: &mut FaultReport,
) -> Result<(), ExecFailure> {
    let mut first_failure: Option<Instant> = None;
    loop {
        let t = Instant::now();
        match execute_attempt(exec, rp, inputs, tag) {
            Ok(()) => {
                if let Some(f0) = first_failure {
                    tally.record_recovery(f0.elapsed().as_secs_f64() * 1e3);
                }
                return Ok(());
            }
            Err(fail) => {
                tally.record_failure(t.elapsed().as_secs_f64() * 1e3);
                first_failure.get_or_insert(t);
                if fail.panicked || tag.attempt >= retry.budget {
                    tally.gave_up += 1;
                    return Err(fail);
                }
                tally.retried += 1;
                std::thread::sleep(retry.backoff(tag.seq, tag.attempt));
                tag.attempt += 1;
            }
        }
    }
}

/// One client: interprets the policy's admission plan with real threads.
#[allow(clippy::too_many_arguments)]
fn run_client(
    spec: &ServeSpec,
    backend: &dyn ServeBackend,
    policy: AccessPolicy,
    client: usize,
    slot: usize,
    class: usize,
    rp: &ResolvedPayload,
    gate: Option<&ModeGate>,
) -> Result<(Vec<Sample>, FaultReport)> {
    // With a fault plan active, terminal request failures are expected
    // outcomes: count them (the report carries them) instead of failing
    // the run. Without one, behave exactly as before — propagate.
    let tolerate = backend.fault_plan().is_some();
    let seq_of = |r: usize| (client * spec.requests + r) as u64;
    let tag_of = |r: usize| RequestTag {
        shard: spec.shard,
        slot,
        seq: seq_of(r),
        attempt: 0,
    };
    let mut tally = FaultReport::default();
    match policy.admission() {
        Admission::Direct => {
            // Unmitigated (`none`) or spatially-shared (`ptb`) execution
            // on the client thread itself.
            let exec = backend.executor()?;
            let share = policy.sm_share(spec.clients);
            // Warm-up (first-use compile) outside the recorded window.
            check_out(rp, &exec.execute(rp.index, &rp.base_inputs)?)?;
            let mut out = Vec::with_capacity(spec.requests);
            for r in 0..spec.requests {
                let mut inputs = rp.base_inputs.clone();
                perturb(&mut inputs, client, r);
                let t = Instant::now();
                match execute_faulted(&*exec, rp, &inputs, tag_of(r), spec.retry, &mut tally) {
                    Ok(()) => {
                        if share < 1.0 {
                            // PTB SM-share simulation fallback: with 1/N
                            // of the SMs, a device-bound request takes ~N
                            // times longer.
                            std::thread::sleep(t.elapsed().mul_f64(1.0 / share - 1.0));
                        }
                        out.push((slot, t.elapsed().as_secs_f64() * 1e3));
                    }
                    Err(fail) if tolerate => {
                        let _ = fail; // tallied; the report carries it
                    }
                    Err(fail) => return Err(fail.error),
                }
            }
            Ok((out, tally))
        }
        Admission::AcquireSyncRelease => {
            // Alg. 4 on the client thread: acquire, run the batch
            // (PJRT-style execution is synchronous, so insert + sync
            // collapse into the call), release.
            let exec = backend.executor()?;
            if let Some(g) = gate {
                g.with_class(class, || check_out(rp, &exec.execute(rp.index, &rp.base_inputs)?))?;
            }
            let mut out = Vec::with_capacity(spec.requests);
            let mut r = 0;
            while r < spec.requests {
                let burst = spec.batch.min(spec.requests - r);
                let tb = Instant::now();
                let grant = gate.map(|g| g.acquire_class(class));
                // The grant MUST be released even on failure, or every
                // other client would deadlock in the FIFO gate.
                let mut burst_result = Ok(());
                for i in 0..burst {
                    let mut inputs = rp.base_inputs.clone();
                    perturb(&mut inputs, client, r + i);
                    match execute_faulted(
                        &*exec,
                        rp,
                        &inputs,
                        tag_of(r + i),
                        spec.retry,
                        &mut tally,
                    ) {
                        Ok(()) => out.push((slot, tb.elapsed().as_secs_f64() * 1e3)),
                        Err(fail) if tolerate => {
                            let _ = fail;
                        }
                        Err(fail) => {
                            burst_result = Err(fail.error);
                            break;
                        }
                    }
                }
                if let (Some(g), Some(grant)) = (gate, grant) {
                    g.release(grant);
                }
                burst_result?;
                r += burst;
            }
            Ok((out, tally))
        }
        Admission::CallbackBracket => {
            // Alg. 3: acquire/exec/release ride the client's stream as
            // deferred jobs; the host thread never blocks per request.
            stream_client(spec, backend, client, slot, class, rp, gate, false)
        }
        Admission::DeferToWorker => {
            // Alg. 5-6: the worker owns the engine and serialises under
            // the gate; the host blocks awaiting each batch (Alg. 7's
            // drain shape at batch granularity).
            stream_client(spec, backend, client, slot, class, rp, gate, true)
        }
    }
}

/// Shared machinery for the deferred strategies: a stream thread that
/// owns the executor and processes FIFO jobs, holding the gate grant
/// across the Acquire..Release bracket.
#[allow(clippy::too_many_arguments)]
fn stream_client(
    spec: &ServeSpec,
    backend: &dyn ServeBackend,
    client: usize,
    slot: usize,
    class: usize,
    rp: &ResolvedPayload,
    gate: Option<&ModeGate>,
    blocking: bool,
) -> Result<(Vec<Sample>, FaultReport)> {
    // Bounded pipeline: a real driver stream has finite depth, so the
    // callback strategy's non-blocking host must not run unboundedly
    // ahead of the device (that would hold every pending request's
    // deep-copied inputs in memory and make reported latencies pure
    // queue time). Two batches of run-ahead models the hw prefetch
    // window; `send` blocks when the stream is that far behind.
    let depth = 2 * (spec.batch + 2);
    let (tx, rx) = mpsc::sync_channel::<StreamJob>(depth);
    let (done_tx, done_rx) = mpsc::channel::<()>();
    std::thread::scope(|s| -> Result<(Vec<Sample>, FaultReport)> {
        let stream = s.spawn(move || run_stream(spec, backend, class, gate, rx, done_tx));
        // Feed the stream; a send/recv failure means the stream thread
        // died — its own Result (joined below) carries the real cause.
        let feed = || -> Result<()> {
            let gone = || anyhow!("stream thread gone");
            // Warm-up batch (not recorded).
            tx.send(StreamJob::Acquire).map_err(|_| gone())?;
            tx.send(StreamJob::Exec {
                payload: rp.index,
                slot,
                seq: 0,
                inputs: rp.base_inputs.clone(),
                out_elems: rp.out_elems,
                enqueued: Instant::now(),
                record: false,
            })
            .map_err(|_| gone())?;
            tx.send(StreamJob::Release).map_err(|_| gone())?;
            done_rx.recv().map_err(|_| gone())?;

            let mut r = 0;
            while r < spec.requests {
                let burst = spec.batch.min(spec.requests - r);
                tx.send(StreamJob::Acquire).map_err(|_| gone())?;
                for i in 0..burst {
                    let mut inputs = rp.base_inputs.clone();
                    perturb(&mut inputs, client, r + i);
                    tx.send(StreamJob::Exec {
                        payload: rp.index,
                        slot,
                        seq: (client * spec.requests + r + i) as u64,
                        inputs,
                        out_elems: rp.out_elems,
                        enqueued: Instant::now(),
                        record: true,
                    })
                    .map_err(|_| gone())?;
                }
                tx.send(StreamJob::Release).map_err(|_| gone())?;
                if blocking {
                    // Worker strategy: the host awaits the batch (deferred
                    // execute + drain) before preparing the next one.
                    done_rx.recv().map_err(|_| gone())?;
                }
                r += burst;
            }
            Ok(())
        };
        let fed = feed();
        drop(tx); // close the stream; the thread drains and exits
        let streamed = stream
            .join()
            .map_err(|p| anyhow!("stream thread panicked: {}", panic_msg(p)))?;
        match (fed, streamed) {
            (Ok(()), r) => r,
            (Err(_), Err(stream_err)) => Err(stream_err),
            (Err(feed_err), Ok(_)) => Err(feed_err),
        }
    })
}

/// The stream/worker thread body: FIFO job interpreter.
///
/// On a payload failure the thread keeps draining jobs (so the feeding
/// host never blocks on a full pipeline) and keeps balancing the gate
/// (so other clients never deadlock on a grant that would otherwise be
/// dropped unreleased); the first error is reported at the end.
fn run_stream(
    spec: &ServeSpec,
    backend: &dyn ServeBackend,
    class: usize,
    gate: Option<&ModeGate>,
    rx: mpsc::Receiver<StreamJob>,
    done_tx: mpsc::Sender<()>,
) -> Result<(Vec<Sample>, FaultReport)> {
    let tolerate = backend.fault_plan().is_some();
    let exec = backend.executor()?;
    let mut grant = None;
    let mut out = Vec::new();
    let mut tally = FaultReport::default();
    let mut failure: Option<anyhow::Error> = None;
    while let Ok(job) = rx.recv() {
        match job {
            StreamJob::Acquire => {
                if failure.is_none() {
                    if let Some(g) = gate {
                        grant = Some(g.acquire_class(class));
                    }
                }
            }
            StreamJob::Exec { payload, slot, seq, inputs, out_elems, enqueued, record } => {
                if failure.is_some() {
                    continue;
                }
                let rp = ResolvedPayload {
                    index: payload,
                    name: format!("slot {slot}"),
                    base_inputs: Vec::new(),
                    out_elems,
                };
                if record {
                    let tag = RequestTag { shard: spec.shard, slot, seq, attempt: 0 };
                    match execute_faulted(&*exec, &rp, &inputs, tag, spec.retry, &mut tally) {
                        Ok(()) => out.push((slot, enqueued.elapsed().as_secs_f64() * 1e3)),
                        // Terminal failure under an active fault plan:
                        // tallied; the stream keeps serving.
                        Err(_) if tolerate => {}
                        Err(fail) => failure = Some(fail.error),
                    }
                } else {
                    // Warm-up: untagged (outside the fault domain); a
                    // failure here is genuine and fails the client.
                    if let Err(e) = exec.execute(payload, &inputs).and_then(|r| check_out(&rp, &r))
                    {
                        failure = Some(e);
                    }
                }
            }
            StreamJob::Release => {
                if let (Some(g), Some(grant)) = (gate, grant.take()) {
                    g.release(grant);
                }
                // Batch boundary: signal hosts that block on drain. A
                // non-blocking host simply never reads past the warm-up.
                let _ = done_tx.send(());
            }
        }
    }
    if let (Some(g), Some(grant)) = (gate, grant.take()) {
        g.release(grant);
    }
    match failure {
        Some(e) => Err(e),
        None => Ok((out, tally)),
    }
}

fn check_out(rp: &ResolvedPayload, out: &[f32]) -> Result<()> {
    if out.len() != rp.out_elems {
        return Err(anyhow!(
            "payload {}: bad output size {} (expected {})",
            rp.name,
            out.len(),
            rp.out_elems
        ));
    }
    Ok(())
}

// ---------------------------------------------------------------------
// open-loop serving
// ---------------------------------------------------------------------

/// One generated request waiting in an admission queue. `arrival_at` is
/// the *scheduled* arrival instant — latency and queue delay are
/// measured from here even when the generator was delayed pushing it
/// (backpressure), which is exactly the coordinated-omission correction.
#[derive(Debug)]
pub(crate) struct Pending {
    /// Index into `ServeSpec::payloads`.
    pub slot: usize,
    /// Global arrival sequence number (input perturbation).
    pub seq: usize,
    pub arrival_at: Instant,
    /// Attempt number: 0 at generation, +1 per retry (a re-routed
    /// request arrives in the next shard's queue with its count intact).
    pub attempt: u32,
    /// Tenant class (index into `ServeSpec::classes`; 0 when unclassed).
    /// Assigned once at generation by [`class_of`] and carried across
    /// retries and re-routes — the class owns the request for life.
    pub class: usize,
}

/// What one open-loop worker brings home.
#[derive(Debug, Default)]
pub(crate) struct OpenWorkerOut {
    pub samples: Vec<Sample>,
    /// Per-class samples `(class, latency ms)` (empty when unclassed).
    pub class_samples: Vec<Sample>,
    /// Arrival-to-dequeue delay per dequeued request (ns).
    pub queue_delay: Histogram,
    /// Requests dropped at dequeue (timeout shed policy).
    pub timed_out: usize,
    /// Requests that failed terminally (after any retries).
    pub failed: usize,
    /// Failure/retry/recovery accounting.
    pub fault: FaultReport,
    pub error: Option<anyhow::Error>,
}

/// Aggregated outcome of a pool of open-loop workers (one shard's worth).
pub(crate) struct OpenOutcome {
    pub samples: Vec<Sample>,
    /// Per-class samples `(class, latency ms)` (empty when unclassed).
    pub class_samples: Vec<Sample>,
    pub queue_delay: Histogram,
    pub timed_out: usize,
    /// Terminal request failures (conservation: these are offered
    /// requests that neither completed, shed, nor timed out).
    pub failed: usize,
    /// Samples meeting the SLO (arrival-to-completion <= slo_ms).
    pub within_slo: usize,
    /// Merged fault accounting across the pool.
    pub fault: FaultReport,
    /// First worker error, if any. Under an active fault plan terminal
    /// request failures are tolerated (counted in `failed`, not here);
    /// infrastructure failures (executor build, warm-up) always land
    /// here.
    pub error: Option<anyhow::Error>,
}

/// Fold worker outputs into one outcome (shared by the single-shard and
/// per-shard fleet assembly paths, so shed/timeout/SLO accounting can
/// never diverge between them).
pub(crate) fn fold_open_outs(outs: Vec<OpenWorkerOut>, slo_ms: f64) -> OpenOutcome {
    let mut samples = Vec::new();
    let mut class_samples = Vec::new();
    let mut queue_delay = Histogram::new();
    let (mut timed_out, mut failed) = (0usize, 0usize);
    let mut fault = FaultReport::default();
    let mut error = None;
    for o in outs {
        samples.extend(o.samples);
        class_samples.extend(o.class_samples);
        queue_delay.merge(&o.queue_delay);
        timed_out += o.timed_out;
        failed += o.failed;
        fault.merge(&o.fault);
        if error.is_none() {
            error = o.error;
        }
    }
    let within_slo = samples.iter().filter(|(_, ms)| *ms <= slo_ms).count();
    OpenOutcome { samples, class_samples, queue_delay, timed_out, failed, within_slo, fault, error }
}

/// Everything an open-loop worker needs (the parameter list outgrew a
/// flat signature when faults arrived): the serving plumbing, the retry
/// policy, and the fleet's health/re-route hooks.
pub(crate) struct OpenWorkerCtx<'a> {
    pub backend: &'a dyn ServeBackend,
    pub resolved: &'a [ResolvedPayload],
    pub queue: &'a AdmissionQueue<Pending>,
    pub gate: Option<&'a ModeGate>,
    pub batch: usize,
    pub timeout: Option<Duration>,
    pub share: f64,
    pub client: usize,
    /// Shard this worker drains (fault selectors + injection counters).
    pub shard: usize,
    pub retry: RetryPolicy,
    /// Count terminal request failures instead of erroring the run
    /// (true when a fault plan is active).
    pub tolerate: bool,
    /// Runs once per finally-accounted request — the fleet uses it to
    /// release router depth. A successfully re-routed request does NOT
    /// fire it here (the receiving shard owns the request now).
    pub done: Option<&'a (dyn Fn() + Sync)>,
    /// This shard's circuit breaker, if the fleet is health-managed.
    pub health: Option<&'a crate::control::fault::ShardHealth>,
    /// Fleet re-route hook: offer a failed request to a different
    /// healthy shard. Returns false when no shard would take it (then
    /// the worker retries locally instead).
    pub requeue: Option<&'a (dyn Fn(Pending) -> bool + Sync)>,
    /// Per-class credit bank (credit arbiter only). Credits are taken at
    /// admission by the generator; [`OpenWorkerCtx::settle`] returns them
    /// exactly once at terminal accounting.
    pub credits: Option<&'a CreditBank>,
    /// Number of configured tenant classes (0 = unclassed; suppresses
    /// per-class sample recording).
    pub classes: usize,
}

impl OpenWorkerCtx<'_> {
    fn on_success(&self) {
        if let Some(h) = self.health {
            h.on_success();
        }
    }

    fn on_failure(&self, panicked: bool) {
        if let Some(h) = self.health {
            if panicked {
                h.on_panic();
            } else {
                h.on_failure();
            }
        }
    }

    fn done(&self) {
        if let Some(f) = self.done {
            f();
        }
    }

    /// Terminal accounting for one request: return its class credit (the
    /// one the generator took at admission) and fire the done hook. A
    /// request that is retried or re-routed is NOT settled — it is still
    /// in flight and its credit stays outstanding; a request whose grant
    /// the lease watchdog revoked settles when it finally completes or
    /// gives up, which is what keeps the credit conservation law intact
    /// across revocations.
    fn settle(&self, class: usize) {
        if let Some(b) = self.credits {
            b.put(class);
        }
        self.done();
    }
}

/// Warm-up (first-use compile) outside the recorded window, through the
/// gate so grant accounting matches the closed loop. Returns the error,
/// if any (an infrastructure failure, never a per-request one). Shared
/// by [`open_worker`] and the elastic worker
/// (`control::elastic`) so hot-added shards warm exactly like boot-time
/// ones.
pub(crate) fn warm_up(ctx: &OpenWorkerCtx<'_>, exec: &dyn PayloadExecutor) -> Option<anyhow::Error> {
    let rp = &ctx.resolved[ctx.client % ctx.resolved.len()];
    let warmed = match ctx.gate {
        Some(g) => g.with_class(class_of(ctx.client, ctx.classes), || {
            exec.execute(rp.index, &rp.base_inputs)
        }),
        None => exec.execute(rp.index, &rp.base_inputs),
    };
    warmed.and_then(|r| check_out(rp, &r)).err()
}

/// Unhealthy drain: count everything still queued as failed (settling
/// each request's credit) so blocking/timeout producers can never
/// deadlock on a dead worker.
pub(crate) fn drain_failed(ctx: &OpenWorkerCtx<'_>, out: &mut OpenWorkerOut) {
    loop {
        let dropped = ctx.queue.pop_batch(ctx.batch.max(1));
        if dropped.is_empty() {
            return;
        }
        out.failed += dropped.len();
        for p in dropped {
            ctx.settle(p.class);
        }
    }
}

/// Process one dequeued burst end to end: dequeue-side accounting
/// (queue-delay histogram, timeout shedding), one gate grant covering
/// the survivors, execution, then retries after the grant is released.
/// Shared by [`open_worker`] and the elastic worker — a stolen burst
/// runs through the *thief's* ctx, so its accounting is identical to a
/// locally-routed one (DESIGN.md §15).
pub(crate) fn process_burst(
    ctx: &OpenWorkerCtx<'_>,
    exec: &dyn PayloadExecutor,
    burst: Vec<Pending>,
    out: &mut OpenWorkerOut,
) {
    // Dequeue-side accounting happens HERE, before any gate wait:
    // the queue-delay histogram measures arrival-to-dequeue only
    // (the gate wait has its own histogram), and the timeout policy
    // judges a request's age at dequeue — never acquiring a grant
    // just to drop an already-expired burst.
    let mut ready = Vec::with_capacity(burst.len());
    for p in burst {
        let qd = p.arrival_at.elapsed();
        out.queue_delay.record(qd.as_nanos().min(u64::MAX as u128) as u64);
        if ctx.timeout.is_some_and(|t| qd > t) {
            out.timed_out += 1;
            ctx.settle(p.class);
        } else {
            ready.push(p);
        }
    }
    if ready.is_empty() {
        return;
    }
    // One grant covers the whole burst; it rides under the class of
    // the burst's head request (bursts can be class-mixed — the
    // per-request class still drives samples and credits).
    let grant = ctx.gate.map(|g| g.acquire_class(ready[0].class));
    // Failures collected here retry after the grant is gone.
    let mut retry_later: Vec<(Pending, ExecFailure)> = Vec::new();
    for p in ready {
        let rp = &ctx.resolved[p.slot];
        let mut inputs = rp.base_inputs.clone();
        perturb(&mut inputs, p.seq, p.seq);
        let tag = RequestTag {
            shard: ctx.shard,
            slot: p.slot,
            seq: p.seq as u64,
            attempt: p.attempt,
        };
        let t = Instant::now();
        match execute_attempt(exec, rp, &inputs, tag) {
            Ok(()) => {
                if ctx.share < 1.0 {
                    // PTB SM-share simulation (see run_client).
                    std::thread::sleep(t.elapsed().mul_f64(1.0 / ctx.share - 1.0));
                }
                let ms = p.arrival_at.elapsed().as_secs_f64() * 1e3;
                out.samples.push((p.slot, ms));
                if ctx.classes > 0 {
                    out.class_samples.push((p.class, ms));
                }
                if p.attempt > 0 {
                    // A re-routed request completing here closes its
                    // recovery (measured from arrival — the original
                    // failure instant stayed on the other shard).
                    out.fault.record_recovery(ms);
                }
                ctx.on_success();
                ctx.settle(p.class);
            }
            Err(fail) => {
                out.fault.record_failure(t.elapsed().as_secs_f64() * 1e3);
                ctx.on_failure(fail.panicked);
                retry_later.push((p, fail));
            }
        }
    }
    // A revoked grant means *we* overstayed the lease (a hung or
    // injected-slow request): the watchdog quarantined us, so the
    // breaker takes a hit too.
    if grant.as_ref().is_some_and(|g| g.is_revoked()) {
        ctx.on_failure(false);
    }
    drop(grant);
    for (p, fail) in retry_later {
        retry_pending(ctx, exec, p, fail, out);
    }
}

/// An open-loop serving worker: drains an [`AdmissionQueue`], admitting
/// bursts of up to `batch` requests per gate grant. An erroring worker
/// keeps draining (so blocking producers can never wedge) and reports
/// the first error at the end. Failed requests retry *after* the burst's
/// grant is released — first by re-routing to another healthy shard
/// (fleet), then locally with backoff under a fresh grant — so a backoff
/// sleep can never sit on the gate and trip the lease watchdog.
pub(crate) fn open_worker(ctx: &OpenWorkerCtx<'_>, warm: &Barrier) -> OpenWorkerOut {
    let mut out = OpenWorkerOut::default();
    let exec = match ctx.backend.executor() {
        Ok(e) => Some(e),
        Err(e) => {
            out.error = Some(e);
            None
        }
    };
    if let Some(exec) = &exec {
        if let Some(e) = warm_up(ctx, &**exec) {
            out.error = Some(e);
        }
    }
    // Every worker reaches the barrier exactly once, healthy or not —
    // the dispatcher starts the clock behind it.
    warm.wait();
    let Some(exec) = exec.filter(|_| out.error.is_none()) else {
        // Unhealthy: drain so blocking/timeout pushes cannot deadlock.
        drain_failed(ctx, &mut out);
        return out;
    };
    loop {
        // Burst collection: block for the first request, then take
        // whatever backlog is already waiting, up to `batch` — one lock
        // acquisition total, not one per request (DESIGN.md §8).
        let burst = ctx.queue.pop_batch(ctx.batch.max(1));
        if burst.is_empty() {
            break; // closed and drained
        }
        process_burst(ctx, &**exec, burst, &mut out);
    }
    out
}

/// Drive one failed request to its conclusion: re-route to another
/// healthy shard if the fleet will take it, otherwise retry locally
/// (backoff, fresh grant) until the budget runs out.
fn retry_pending(
    ctx: &OpenWorkerCtx<'_>,
    exec: &dyn PayloadExecutor,
    mut p: Pending,
    mut last: ExecFailure,
    out: &mut OpenWorkerOut,
) {
    loop {
        if p.attempt >= ctx.retry.budget {
            // Budget spent (or zero): terminal failure.
            out.failed += 1;
            out.fault.gave_up += 1;
            if !ctx.tolerate && out.error.is_none() {
                out.error = Some(last.error);
            }
            ctx.settle(p.class);
            return;
        }
        // Re-route first: a different healthy shard owns the request
        // from here on (it will fire ITS done hook; ours must not).
        if let Some(requeue) = ctx.requeue {
            let candidate = Pending {
                slot: p.slot,
                seq: p.seq,
                arrival_at: p.arrival_at,
                attempt: p.attempt + 1,
                class: p.class,
            };
            if requeue(candidate) {
                out.fault.retried += 1;
                return;
            }
        }
        // Local retry: back off (no grant held), then one more contained
        // attempt under a fresh grant.
        out.fault.retried += 1;
        std::thread::sleep(ctx.retry.backoff(p.seq as u64, p.attempt));
        p.attempt += 1;
        let rp = &ctx.resolved[p.slot];
        let mut inputs = rp.base_inputs.clone();
        perturb(&mut inputs, p.seq, p.seq);
        let tag = RequestTag {
            shard: ctx.shard,
            slot: p.slot,
            seq: p.seq as u64,
            attempt: p.attempt,
        };
        let grant = ctx.gate.map(|g| g.acquire_class(p.class));
        let t = Instant::now();
        let result = execute_attempt(exec, rp, &inputs, tag);
        drop(grant);
        match result {
            Ok(()) => {
                let ms = p.arrival_at.elapsed().as_secs_f64() * 1e3;
                out.fault.record_recovery(ms);
                out.samples.push((p.slot, ms));
                if ctx.classes > 0 {
                    out.class_samples.push((p.class, ms));
                }
                ctx.on_success();
                ctx.settle(p.class);
                return;
            }
            Err(fail) => {
                out.fault.record_failure(t.elapsed().as_secs_f64() * 1e3);
                ctx.on_failure(fail.panicked);
                last = fail;
            }
        }
    }
}

/// Push one request into `queue` per the shed policy; false = shed.
pub(crate) fn admit(queue: &AdmissionQueue<Pending>, p: Pending, shed: ShedPolicy) -> bool {
    match shed {
        ShedPolicy::Block => queue.push_blocking(p),
        ShedPolicy::Reject => queue.try_push(p).is_ok(),
        ShedPolicy::Timeout { ms } => queue.push_timeout(p, Duration::from_millis(ms)).is_ok(),
    }
}

/// Realised offered rate of a schedule (requests/s over its span).
pub(crate) fn offered_rate_hz(offsets: &[crate::util::Nanos]) -> f64 {
    match offsets.last() {
        Some(&last) if last > 0 => offsets.len() as f64 / (last as f64 / 1e9),
        _ => 0.0,
    }
}

/// Open-loop serving: a paced generator (this thread) feeds a bounded
/// [`AdmissionQueue`] drained by `spec.clients` workers. The deferred
/// per-client stream machinery is a closed-loop construct; under open
/// loop the workers *are* the streams, so every gated strategy brackets
/// execution with the FIFO gate directly (one grant per burst).
fn serve_open_loop(spec: &ServeSpec, backend: &dyn ServeBackend) -> Result<ServeReport> {
    let policy = AccessPolicy::new(spec.strategy);
    if let Some(plan) = backend.fault_plan() {
        plan.check_boot(spec.shard);
    }
    let resolved: Vec<ResolvedPayload> = spec
        .payloads
        .iter()
        .map(|p| backend.resolve(p))
        .collect::<Result<_>>()?;
    let gate = make_gate(spec, policy);
    let tolerate = backend.fault_plan().is_some();
    let total = spec.clients * spec.requests;
    let offsets = spec.traffic.arrivals.schedule_n(total, spec.traffic.seed);
    let queue: AdmissionQueue<Pending> = AdmissionQueue::new(spec.traffic.queue_cap);
    let k = spec.classes.len();
    // The credit arbiter's admission-side backpressure: one pool per
    // class; an unbudgeted class defaults to the queue cap (exactly the
    // old single-tenant bound, now charged per tenant).
    let credits = (spec.arbiter == ArbiterKind::Credit).then(|| {
        CreditBank::new(
            &spec.classes,
            u32::try_from(spec.traffic.queue_cap).unwrap_or(u32::MAX),
        )
    });
    let shed = AtomicUsize::new(0);
    let warm = Barrier::new(spec.clients + 1);
    let share = policy.sm_share(spec.clients);
    let timeout = match spec.traffic.shed {
        ShedPolicy::Timeout { ms } => Some(Duration::from_millis(ms)),
        _ => None,
    };

    let (outs, wall_s) = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..spec.clients {
            let (queue, gate, warm, resolved) = (&queue, gate.as_ref(), &warm, &resolved);
            let credits = credits.as_ref();
            handles.push(s.spawn(move || {
                let ctx = OpenWorkerCtx {
                    backend,
                    resolved,
                    queue,
                    gate,
                    batch: spec.batch,
                    timeout,
                    share,
                    client: c,
                    shard: spec.shard,
                    retry: spec.retry,
                    tolerate,
                    done: None,
                    health: None,
                    requeue: None,
                    credits,
                    classes: k,
                };
                open_worker(&ctx, warm)
            }));
        }
        warm.wait();
        let t0 = Instant::now();
        for (seq, &off) in offsets.iter().enumerate() {
            let arrival_at = t0 + Duration::from_nanos(off);
            let now = Instant::now();
            if arrival_at > now {
                std::thread::sleep(arrival_at - now);
            }
            let class = class_of(seq, k);
            // Credit admission (credit arbiter): a class out of credits
            // sheds — or waits, per the shed policy — HERE, before the
            // shared queue, so one tenant's flood can't crowd out the
            // others' admission. The credit returns at settle.
            let granted = match (credits.as_ref(), spec.traffic.shed) {
                (None, _) => true,
                (Some(b), ShedPolicy::Block) => {
                    b.take_blocking(class);
                    true
                }
                (Some(b), ShedPolicy::Reject) => b.try_take(class),
                (Some(b), ShedPolicy::Timeout { ms }) => {
                    b.take_timeout(class, Duration::from_millis(ms))
                }
            };
            if !granted {
                shed.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            let p = Pending { slot: seq % resolved.len(), seq, arrival_at, attempt: 0, class };
            if !admit(&queue, p, spec.traffic.shed) {
                if let Some(b) = credits.as_ref() {
                    b.put(class);
                }
                shed.fetch_add(1, Ordering::Relaxed);
            }
        }
        queue.close();
        let outs: Vec<OpenWorkerOut> = handles
            .into_iter()
            .map(|h| {
                h.join().unwrap_or_else(|_| OpenWorkerOut {
                    error: Some(anyhow!("open-loop worker thread panicked")),
                    ..OpenWorkerOut::default()
                })
            })
            .collect();
        // Wall clock spans generation AND backlog drain: the makespan.
        (outs, t0.elapsed().as_secs_f64())
    });

    let o = fold_open_outs(outs, spec.traffic.slo_ms);
    if let Some(e) = o.error {
        return Err(e);
    }
    let (queue_delay, timed_out, within_slo) = (o.queue_delay, o.timed_out, o.within_slo);
    let mut offered_by_class = vec![0usize; k];
    if k > 0 {
        for seq in 0..total {
            offered_by_class[class_of(seq, k)] += 1;
        }
    }
    let classes = build_class_reports(
        &spec.classes,
        o.class_samples,
        &offered_by_class,
        spec.traffic.slo_ms,
        spec.exact_quantiles,
    );
    let gate_stats = gate.map(|g| g.stats());
    let mut fault = o.fault;
    if let Some(plan) = backend.fault_plan() {
        fault.injected.merge(&plan.counts_for(spec.shard));
    }
    if let Some(g) = &gate_stats {
        fault.revocations += g.revocations;
    }
    let fault = (backend.fault_plan().is_some() || !fault.is_empty()).then_some(fault);
    let completed = o.samples.len();
    let (latency, per_payload) =
        build_latency_stats(o.samples, &spec.payloads, spec.exact_quantiles);
    Ok(ServeReport {
        strategy: spec.strategy,
        concurrency: spec.concurrency,
        clients: spec.clients,
        requests_per_client: spec.requests,
        batch: spec.batch,
        wall_s,
        latency,
        per_payload,
        classes,
        gate: gate_stats,
        credits: credits.map(|b| b.snapshot()),
        traffic: Some(TrafficReport {
            arrivals: spec.traffic.arrivals,
            queue_cap: spec.traffic.queue_cap,
            shed_policy: spec.traffic.shed,
            slo_ms: spec.traffic.slo_ms,
            offered: total,
            completed,
            shed: shed.into_inner(),
            timed_out,
            failed: o.failed,
            retried: fault.as_ref().map_or(0, |f| f.retried),
            within_slo,
            queue_delay,
            offered_rate_hz: offered_rate_hz(&offsets),
        }),
        fault,
    })
}

// ---------------------------------------------------------------------
// compatibility wrapper
// ---------------------------------------------------------------------

/// Serve DNA-Net inferences from `clients` concurrent applications
/// (the original serving entry point, kept for callers and tests).
pub fn serve_dna(
    strategy: StrategyKind,
    clients: usize,
    requests: usize,
    artifacts_dir: PathBuf,
) -> Result<ServeReport> {
    let spec = ServeSpec::new(strategy, "dna")
        .with_clients(clients)
        .with_requests(requests);
    serve(&spec, &ManifestBackend::new(artifacts_dir))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> SyntheticBackend {
        SyntheticBackend::new(50)
    }

    #[test]
    fn all_five_strategies_serve_synthetic() {
        for strategy in StrategyKind::ALL {
            let spec = ServeSpec::new(strategy, "dna")
                .with_clients(2)
                .with_requests(4);
            let r = serve(&spec, &backend()).unwrap_or_else(|e| panic!("{strategy}: {e}"));
            assert_eq!(r.total(), 8, "{strategy}");
            assert_eq!(r.latency.count(), 8, "{strategy}");
            assert!(!r.latency.is_exact(), "sketch-only by default");
            assert!(r.ips() > 0.0, "{strategy}");
            assert!(r.latency_p(0.5) > 0.0, "{strategy}");
            assert_eq!(r.gate.is_some(), AccessPolicy::new(strategy).gated(), "{strategy}");
        }
    }

    #[test]
    fn gated_strategies_record_wait_and_hold() {
        for strategy in [StrategyKind::Callback, StrategyKind::Synced, StrategyKind::Worker] {
            let spec = ServeSpec::new(strategy, "mmult")
                .with_clients(3)
                .with_requests(5);
            let r = serve(&spec, &backend()).unwrap();
            let g = r.gate.expect("gated strategy must report gate stats");
            // One warm-up grant per client + one grant per request batch.
            assert_eq!(g.grants(), 3 + 15, "{strategy}");
            assert!(g.hold.mean_ns() > 0.0, "{strategy}");
        }
    }

    #[test]
    fn batching_reduces_gate_grants() {
        let spec = ServeSpec::new(StrategyKind::Synced, "dna")
            .with_clients(2)
            .with_requests(6)
            .with_batch(3);
        let r = serve(&spec, &backend()).unwrap();
        // 2 warm-up grants + 2 clients x 2 batches.
        assert_eq!(r.gate.unwrap().grants(), 2 + 4);
        assert_eq!(r.total(), 12);
    }

    #[test]
    fn multi_payload_reports_per_payload() {
        let spec = ServeSpec::new(StrategyKind::Worker, "dna")
            .with_payloads(vec!["dna".into(), "mmult".into()])
            .with_clients(4)
            .with_requests(3);
        let r = serve(&spec, &backend()).unwrap();
        assert_eq!(r.per_payload.len(), 2);
        for p in &r.per_payload {
            assert_eq!(p.latency.count(), 6, "{}", p.payload);
            assert!(p.ips(r.wall_s) > 0.0);
        }
        assert!(r.render().contains("payload dna"));
        assert!(r.render().contains("payload mmult"));
    }

    #[test]
    fn nearest_rank_quantile_fixed() {
        // Regression for the original latency_p: it panicked on empty
        // vectors and was biased one rank high on exact multiples.
        let empty = ServeReport {
            strategy: StrategyKind::None,
            concurrency: ConcurrencyMode::Cook,
            clients: 1,
            requests_per_client: 1,
            batch: 1,
            wall_s: 1.0,
            latency: LatencyStats::new(true),
            per_payload: vec![],
            classes: vec![],
            gate: None,
            credits: None,
            traffic: None,
            fault: None,
        };
        assert_eq!(empty.latency_p(0.5), 0.0);
        assert_eq!(empty.latency_p(0.99), 0.0);

        let four = ServeReport {
            latency: LatencyStats::from_values(&[1.0, 2.0, 3.0, 4.0], true),
            ..empty
        };
        // Nearest rank (exact path): ceil(0.5*4) = 2 -> the 2nd smallest.
        assert_eq!(four.latency_p(0.50), 2.0);
        assert_eq!(four.latency_p(0.25), 1.0);
        assert_eq!(four.latency_p(0.75), 3.0);
        assert_eq!(four.latency_p(1.00), 4.0);
        assert_eq!(four.latency_p(0.0), 1.0);
    }

    #[test]
    fn exact_quantiles_flag_keeps_exact_vectors() {
        let spec = ServeSpec::new(StrategyKind::Synced, "dna")
            .with_clients(2)
            .with_requests(4)
            .with_exact_quantiles(true);
        let r = serve(&spec, &backend()).unwrap();
        assert!(r.latency.is_exact());
        let exact = r.latency.exact_values().unwrap();
        assert_eq!(exact.len(), 8);
        // Sketch and exact must agree within the documented error bound.
        for q in [0.25, 0.5, 0.95] {
            let (e, s) = (r.latency.quantile(q), r.latency.sketch.quantile(q));
            assert!(
                (s - e).abs() / e.max(1e-12)
                    <= crate::metrics::stats::QuantileSketch::GAMMA - 1.0 + 1e-9,
                "q={q}: sketch {s} vs exact {e}"
            );
        }
    }

    #[test]
    fn invalid_specs_rejected() {
        let b = backend();
        assert!(serve(&ServeSpec::new(StrategyKind::None, "x").with_clients(0), &b).is_err());
        assert!(serve(&ServeSpec::new(StrategyKind::None, "x").with_requests(0), &b).is_err());
        assert!(serve(&ServeSpec::new(StrategyKind::None, "x").with_batch(0), &b).is_err());
        assert!(
            serve(&ServeSpec::new(StrategyKind::None, "x").with_payloads(vec![]), &b).is_err()
        );
    }

    #[test]
    fn report_render_mentions_strategy_and_gate() {
        let spec = ServeSpec::new(StrategyKind::Synced, "dna")
            .with_clients(2)
            .with_requests(3);
        let r = serve(&spec, &backend()).unwrap();
        let text = r.render();
        assert!(text.contains("strategy synced"), "{text}");
        assert!(text.contains("gate wait"), "{text}");
        assert!(text.contains("IPS"), "{text}");
    }

    // ------------------------------------------------------ open loop --

    use crate::control::traffic::ArrivalProcess;

    fn open_traffic(rate_hz: f64) -> TrafficSpec {
        TrafficSpec {
            arrivals: ArrivalProcess::Poisson { rate_hz },
            queue_cap: 64,
            shed: ShedPolicy::Block,
            slo_ms: 1_000.0,
            seed: 7,
        }
    }

    #[test]
    fn open_loop_serves_every_strategy() {
        for strategy in StrategyKind::ALL {
            let spec = ServeSpec::new(strategy, "dna")
                .with_clients(2)
                .with_requests(5)
                .with_traffic(open_traffic(2_000.0));
            let r = serve(&spec, &backend()).unwrap_or_else(|e| panic!("{strategy}: {e}"));
            let t = r.traffic.as_ref().expect("open loop must report traffic");
            assert_eq!(t.offered, 10, "{strategy}");
            assert!(t.accounted(), "{strategy}: requests leaked");
            // Blocking shed policy + generous SLO: everything completes.
            assert_eq!(t.completed, 10, "{strategy}");
            assert_eq!(t.shed, 0, "{strategy}");
            assert_eq!(r.latency.count(), 10, "{strategy}");
            assert_eq!(t.queue_delay.count(), 10, "{strategy}");
            assert_eq!(r.gate.is_some(), AccessPolicy::new(strategy).gated(), "{strategy}");
        }
    }

    #[test]
    fn open_loop_overload_sheds_with_reject() {
        // Service capacity ~= clients/exec_us; offer far beyond it into a
        // tiny queue: the reject policy must shed most of the flood.
        let spec = ServeSpec::new(StrategyKind::Synced, "dna")
            .with_clients(2)
            .with_requests(20)
            .with_traffic(TrafficSpec {
                arrivals: ArrivalProcess::Poisson { rate_hz: 20_000.0 },
                queue_cap: 2,
                shed: ShedPolicy::Reject,
                slo_ms: 50.0,
                seed: 1,
            });
        let r = serve(&spec, &SyntheticBackend::new(2_000)).unwrap();
        let t = r.traffic.as_ref().unwrap();
        assert_eq!(t.offered, 40);
        assert!(t.shed > 0, "overload against cap 2 must shed");
        assert!(t.accounted());
        assert_eq!(t.completed, r.latency.count());
        assert!(t.completed < t.offered);
    }

    #[test]
    fn open_loop_slo_accounting_brackets() {
        let base = ServeSpec::new(StrategyKind::Worker, "dna")
            .with_clients(2)
            .with_requests(5);
        // Unreachably generous SLO: attainment equals completion rate.
        let generous = base
            .clone()
            .with_traffic(TrafficSpec { slo_ms: 1e9, ..open_traffic(2_000.0) });
        let r = serve(&generous, &backend()).unwrap();
        let t = r.traffic.as_ref().unwrap();
        assert_eq!(t.within_slo, t.completed);
        assert!((t.slo_attainment_pct() - 100.0).abs() < 1e-9);
        assert!(t.goodput(r.wall_s) > 0.0);
        // Unreachably tight SLO: nothing attains it.
        let tight = base.with_traffic(TrafficSpec { slo_ms: 1e-6, ..open_traffic(2_000.0) });
        let r = serve(&tight, &backend()).unwrap();
        assert_eq!(r.traffic.as_ref().unwrap().within_slo, 0);
    }

    #[test]
    fn open_loop_timeout_policy_drops_stale_requests() {
        // 1 ms of patience against multi-ms service: the backlog ages out
        // (at admission or at dequeue) instead of growing unboundedly.
        let spec = ServeSpec::new(StrategyKind::Synced, "dna")
            .with_clients(1)
            .with_requests(30)
            .with_traffic(TrafficSpec {
                arrivals: ArrivalProcess::Poisson { rate_hz: 20_000.0 },
                queue_cap: 4,
                shed: ShedPolicy::Timeout { ms: 1 },
                slo_ms: 50.0,
                seed: 3,
            });
        let r = serve(&spec, &SyntheticBackend::new(3_000)).unwrap();
        let t = r.traffic.as_ref().unwrap();
        assert!(t.shed + t.timed_out > 0, "saturation must age requests out");
        assert!(t.accounted());
    }

    #[test]
    fn open_loop_batching_and_payload_mix() {
        let spec = ServeSpec::new(StrategyKind::Worker, "dna")
            .with_payloads(vec!["dna".into(), "mmult".into()])
            .with_clients(2)
            .with_requests(6)
            .with_batch(3)
            .with_traffic(open_traffic(5_000.0));
        let r = serve(&spec, &backend()).unwrap();
        assert_eq!(r.traffic.as_ref().unwrap().completed, 12);
        // Arrivals alternate payload slots: both payloads must be served.
        assert_eq!(r.per_payload.len(), 2);
        let text = r.render();
        assert!(text.contains("goodput"), "{text}");
        assert!(text.contains("attainment"), "{text}");
    }

    #[test]
    fn open_loop_streams_are_seed_deterministic() {
        let p = ArrivalProcess::Poisson { rate_hz: 777.0 };
        assert_eq!(p.schedule_n(64, 11), p.schedule_n(64, 11));
        assert_ne!(p.schedule_n(64, 11), p.schedule_n(64, 12));
    }

    #[test]
    fn open_loop_rejects_invalid_traffic() {
        let b = backend();
        let bad_cap = ServeSpec::new(StrategyKind::None, "x").with_traffic(TrafficSpec {
            queue_cap: 0,
            ..open_traffic(100.0)
        });
        assert!(serve(&bad_cap, &b).is_err());
        let bad_slo = ServeSpec::new(StrategyKind::None, "x").with_traffic(TrafficSpec {
            slo_ms: 0.0,
            ..open_traffic(100.0)
        });
        assert!(serve(&bad_slo, &b).is_err());
    }

    // -- fault injection through the serving stack ---------------------

    fn faulty(spec: &str, seed: u64) -> crate::control::fault::FaultyBackend<SyntheticBackend> {
        let plan = FaultPlan::new(spec.parse().unwrap(), seed);
        crate::control::fault::FaultyBackend::new(backend(), std::sync::Arc::new(plan))
    }

    fn fast_retry(budget: u32) -> RetryPolicy {
        RetryPolicy { budget, base_ms: 0.1, cap_ms: 0.5, seed: 9 }
    }

    #[test]
    fn closed_loop_retry_recovers_injected_error() {
        // `req=2` fires exactly once (attempt 0 of global seq 2); one
        // retry heals it, so every request still completes.
        let fb = faulty("error:req=2", 7);
        let spec = ServeSpec::new(StrategyKind::None, "dna")
            .with_clients(1)
            .with_requests(5)
            .with_retry(fast_retry(2));
        let r = serve(&spec, &fb).unwrap();
        assert_eq!(r.latency.count(), 5, "the faulted request must recover");
        let f = r.fault.expect("active fault plan implies a report");
        assert_eq!(f.injected.errors, 1);
        assert_eq!(f.detected, 1);
        assert_eq!(f.retried, 1);
        assert_eq!(f.recovered, 1);
        assert_eq!(f.gave_up, 0);
        assert!(r.render().contains("faults:"), "{}", r.render());
    }

    #[test]
    fn closed_loop_tolerates_terminal_failures_under_a_plan() {
        // No retry budget: the injected failure is terminal, but with a
        // fault plan active it is tallied instead of erroring the run.
        let fb = faulty("error:req=1", 7);
        let spec = ServeSpec::new(StrategyKind::Synced, "dna")
            .with_clients(1)
            .with_requests(4);
        let r = serve(&spec, &fb).unwrap();
        assert_eq!(r.latency.count(), 3);
        let f = r.fault.unwrap();
        assert_eq!(f.gave_up, 1);
        assert_eq!(f.recovered, 0);
    }

    #[test]
    fn open_loop_conserves_requests_when_every_attempt_fails() {
        // p=1 with zero retries: nothing completes, everything is a
        // counted terminal failure — conservation must still balance.
        let fb = faulty("error:p=1", 7);
        let spec = ServeSpec::new(StrategyKind::Worker, "dna")
            .with_clients(2)
            .with_requests(5)
            .with_traffic(open_traffic(5_000.0));
        let r = serve(&spec, &fb).unwrap();
        let t = r.traffic.as_ref().unwrap();
        assert_eq!(t.completed, 0);
        assert_eq!(t.failed, t.offered);
        assert!(t.accounted(), "offered={} failed={}", t.offered, t.failed);
        let f = r.fault.unwrap();
        assert_eq!(f.gave_up, t.offered);
        assert_eq!(f.injected.errors, t.offered);
    }

    #[test]
    fn open_loop_retries_recover_a_point_fault() {
        let fb = faulty("error:req=3", 7);
        let spec = ServeSpec::new(StrategyKind::Synced, "dna")
            .with_clients(2)
            .with_requests(5)
            .with_retry(fast_retry(2))
            .with_traffic(open_traffic(5_000.0));
        let r = serve(&spec, &fb).unwrap();
        let t = r.traffic.as_ref().unwrap();
        assert_eq!(t.completed, t.offered, "retry must heal the point fault");
        assert_eq!(t.retried, 1);
        assert!(t.accounted());
        let f = r.fault.unwrap();
        assert_eq!(f.recovered, 1);
        assert_eq!(f.gave_up, 0);
    }

    #[test]
    fn boot_crash_clause_panics_at_serve_start() {
        // A bare `crash` clause models a process that dies on boot; the
        // panic escapes serve() (the fleet contains it per shard).
        let fb = faulty("crash", 7);
        let spec = ServeSpec::new(StrategyKind::None, "dna").with_requests(1);
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| serve(&spec, &fb)));
        assert!(caught.is_err(), "boot crash must panic, not error");
        assert_eq!(fb.plan().counts_total().crashes, 1);
    }
}
