//! Live serving subsystem: real payload executions from concurrent
//! clients, admitted per the configured [`AccessPolicy`].
//!
//! This replaces the first-generation `serve_dna` path, which supported
//! three of the five strategies, hard-coded the DNA payload, and
//! serialised on a bare `Mutex<()>`. The rebuilt subsystem:
//!
//! * serves **any payload in the AOT manifest** (DNA-Net, mmult, vecadd —
//!   or a mix: client *i* serves `payloads[i % len]`), via a pluggable
//!   [`ServeBackend`] so tests and artifact-less environments can run the
//!   full admission machinery against a synthetic executor;
//! * implements **all five strategies** by interpreting the same
//!   [`Admission`] plans as the simulator — the callback strategy runs its
//!   acquire/release as deferred closures riding a per-client stream
//!   thread (Alg. 3), and the PTB baseline falls back to an SM-share
//!   *simulation* (each client is slowed to its `1/clients` share, since
//!   a CPU-side runtime has no real SM pinning);
//! * admits through the FIFO-fair [`GpuGate`], which records wait/hold
//!   histograms surfaced in the report;
//! * supports **request batching** (`batch > 1` amortises one gate
//!   admission over a burst of requests);
//! * reports **per-payload** latency/IPS breakdowns in [`ServeReport`].
//!
//! Engines may wrap non-`Send` handles (PJRT client pointers), so every
//! executing thread builds its *own* executor through the backend —
//! exactly like the paper's setup where each application is a separate
//! process with its own CUDA context.

use crate::config::StrategyKind;
use crate::control::gate::{GateStats, GpuGate};
use crate::control::policy::{AccessPolicy, Admission};
use crate::control::traffic::{
    AdmissionQueue, ShedPolicy, TrafficReport, TrafficSpec,
};
use crate::metrics::stats::{Histogram, LatencyStats};
use anyhow::{anyhow, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Barrier};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------
// backend abstraction
// ---------------------------------------------------------------------

/// A per-thread payload executor (may wrap non-`Send` engine handles).
pub trait PayloadExecutor {
    /// Execute artifact `payload` with flat f32 inputs.
    fn execute(&self, payload: usize, inputs: &[Vec<f32>]) -> Result<Vec<f32>>;
}

/// A payload resolved against the backend: everything a client needs to
/// generate requests and validate responses.
#[derive(Debug, Clone)]
pub struct ResolvedPayload {
    /// Executor-side payload index.
    pub index: usize,
    pub name: String,
    /// Template inputs (perturbed per request, §VI-C).
    pub base_inputs: Vec<Vec<f32>>,
    /// Expected output element count.
    pub out_elems: usize,
}

/// Source of executors and payload metadata for a serving run. `Sync`
/// because every client thread resolves/builds through a shared borrow.
pub trait ServeBackend: Sync {
    fn resolve(&self, payload: &str) -> Result<ResolvedPayload>;
    /// Build a fresh executor owned by the calling thread.
    fn executor(&self) -> Result<Box<dyn PayloadExecutor>>;
}

/// The real backend: AOT artifacts under a manifest directory, executed
/// by the runtime engine (PJRT when built with the `pjrt` feature, the
/// native interpreter otherwise).
pub struct ManifestBackend {
    dir: PathBuf,
    /// Manifest parsed once on first resolve (not in `new`, so merely
    /// constructing a backend cannot fail).
    manifest: std::sync::OnceLock<crate::runtime::Manifest>,
}

impl ManifestBackend {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self { dir: dir.into(), manifest: std::sync::OnceLock::new() }
    }

    fn manifest(&self) -> Result<&crate::runtime::Manifest> {
        if self.manifest.get().is_none() {
            let m = crate::runtime::Manifest::load(&self.dir)?;
            let _ = self.manifest.set(m);
        }
        Ok(self.manifest.get().expect("manifest just set"))
    }
}

impl PayloadExecutor for crate::runtime::Engine {
    fn execute(&self, payload: usize, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        crate::runtime::Engine::execute(self, payload, inputs)
    }
}

impl ServeBackend for ManifestBackend {
    fn resolve(&self, payload: &str) -> Result<ResolvedPayload> {
        let manifest = self.manifest()?;
        let index = manifest
            .artifacts
            .iter()
            .position(|a| a.name == payload)
            .ok_or_else(|| {
                anyhow!(
                    "payload '{payload}' not in the AOT manifest (have: {})",
                    manifest
                        .artifacts
                        .iter()
                        .map(|a| a.name.as_str())
                        .collect::<Vec<_>>()
                        .join(", ")
                )
            })?;
        let spec = &manifest.artifacts[index];
        Ok(ResolvedPayload {
            index,
            name: spec.name.clone(),
            base_inputs: spec.golden_inputs(),
            out_elems: spec.out_elems(),
        })
    }

    fn executor(&self) -> Result<Box<dyn PayloadExecutor>> {
        Ok(Box::new(crate::runtime::Engine::load(&self.dir)?))
    }
}

/// Synthetic backend: deterministic CPU work with a configurable
/// per-request cost. Lets the whole admission machinery (gate fairness,
/// batching, all five strategies) run — and be tested — without AOT
/// artifacts or a PJRT client.
pub struct SyntheticBackend {
    /// Busy-spin cost per request, microseconds.
    pub exec_us: u64,
    /// Input vector length per argument.
    pub elems: usize,
}

impl SyntheticBackend {
    pub fn new(exec_us: u64) -> Self {
        Self { exec_us, elems: 64 }
    }
}

struct SyntheticExecutor {
    exec_us: u64,
}

impl PayloadExecutor for SyntheticExecutor {
    fn execute(&self, payload: usize, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        let t0 = Instant::now();
        let budget = Duration::from_micros(self.exec_us);
        // Deterministic reduction over the inputs, re-run until the cost
        // budget elapses (busy spin models a device-bound kernel).
        let mut acc = payload as f32;
        loop {
            for v in inputs {
                for (i, x) in v.iter().enumerate() {
                    acc += x * ((i % 7) as f32 - 3.0);
                }
            }
            if t0.elapsed() >= budget {
                break;
            }
        }
        Ok(vec![acc; 8])
    }
}

impl ServeBackend for SyntheticBackend {
    fn resolve(&self, payload: &str) -> Result<ResolvedPayload> {
        // Any name resolves; index is its position in the standard payload
        // list when known (keeps reports aligned with the real manifest).
        let index = crate::runtime::PAYLOAD_NAMES
            .iter()
            .position(|n| *n == payload)
            .unwrap_or(0);
        Ok(ResolvedPayload {
            index,
            name: payload.to_string(),
            base_inputs: vec![vec![0.125; self.elems], vec![0.25; self.elems]],
            out_elems: 8,
        })
    }

    fn executor(&self) -> Result<Box<dyn PayloadExecutor>> {
        Ok(Box::new(SyntheticExecutor { exec_us: self.exec_us }))
    }
}

// ---------------------------------------------------------------------
// spec + report
// ---------------------------------------------------------------------

/// Configuration of one serving run.
///
/// # Example
///
/// ```
/// use cook::config::StrategyKind;
/// use cook::control::serving::{serve, ServeSpec, SyntheticBackend};
///
/// let spec = ServeSpec::new(StrategyKind::Worker, "dna")
///     .with_clients(2)
///     .with_requests(3)
///     .with_batch(1);
/// let report = serve(&spec, &SyntheticBackend::new(20)).unwrap();
/// assert_eq!(report.total(), 6);
/// assert!(report.gate.is_some()); // worker serialises behind the gate
/// ```
#[derive(Debug, Clone)]
pub struct ServeSpec {
    pub strategy: StrategyKind,
    /// Payload names; client `i` serves `payloads[i % payloads.len()]`
    /// (closed loop) / arrival `k` serves `payloads[k % len]` (open loop).
    pub payloads: Vec<String>,
    pub clients: usize,
    /// Requests per client. Under open-loop arrivals the run generates
    /// `clients * requests` arrivals total (same request budget, but
    /// paced by the arrival process instead of by completions).
    pub requests: usize,
    /// Requests admitted per gate grant (1 = per-op admission, the
    /// paper's shape; >1 amortises admission over a burst).
    pub batch: usize,
    /// Traffic shape: arrival process, admission-queue bound, shed
    /// policy, SLO target. Defaults to the historical closed loop.
    pub traffic: TrafficSpec,
    /// Keep the exact per-request latency vectors alongside the
    /// streaming sketch (`--exact-quantiles`): quantiles then come from
    /// the exact nearest-rank path at O(n log n) report cost. Off by
    /// default — the sketch's <= 2% relative error is ample for latency
    /// reporting, and recording stays O(1) per request.
    pub exact_quantiles: bool,
}

impl ServeSpec {
    pub fn new(strategy: StrategyKind, payload: impl Into<String>) -> Self {
        Self {
            strategy,
            payloads: vec![payload.into()],
            clients: 2,
            requests: 50,
            batch: 1,
            traffic: TrafficSpec::default(),
            exact_quantiles: false,
        }
    }

    pub fn with_payloads(mut self, payloads: Vec<String>) -> Self {
        self.payloads = payloads;
        self
    }

    pub fn with_clients(mut self, clients: usize) -> Self {
        self.clients = clients;
        self
    }

    pub fn with_requests(mut self, requests: usize) -> Self {
        self.requests = requests;
        self
    }

    pub fn with_batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    pub fn with_traffic(mut self, traffic: TrafficSpec) -> Self {
        self.traffic = traffic;
        self
    }

    pub fn with_arrivals(mut self, arrivals: crate::control::traffic::ArrivalProcess) -> Self {
        self.traffic.arrivals = arrivals;
        self
    }

    pub fn with_exact_quantiles(mut self, exact: bool) -> Self {
        self.exact_quantiles = exact;
        self
    }

    pub(crate) fn validate(&self) -> Result<()> {
        if self.clients == 0 || self.requests == 0 {
            return Err(anyhow!("serve requires clients > 0 and requests > 0"));
        }
        if self.batch == 0 {
            return Err(anyhow!("batch must be >= 1"));
        }
        if self.payloads.is_empty() {
            return Err(anyhow!("at least one payload required"));
        }
        self.traffic.validate().map_err(|e| anyhow!(e))?;
        Ok(())
    }
}

/// Latency breakdown for one payload.
#[derive(Debug)]
pub struct PayloadReport {
    pub payload: String,
    /// Per-request latency distribution, milliseconds (streaming sketch;
    /// exact vector retained on the `--exact-quantiles` path).
    pub latency: LatencyStats,
}

impl PayloadReport {
    pub fn ips(&self, wall_s: f64) -> f64 {
        self.latency.count() as f64 / wall_s.max(1e-9)
    }
}

/// Result of a serving run: pooled + per-payload latency distributions,
/// throughput, and (for gated strategies) the gate's wait/hold
/// histograms. Aggregate across shards with
/// [`crate::control::fleet::FleetReport`]. Quantiles are nearest-rank
/// over a streaming sketch (exact on the `--exact-quantiles` path — see
/// [`ServeReport::latency_p`]); [`ServeReport::render`] produces the
/// human table printed by `cook serve`.
#[derive(Debug)]
pub struct ServeReport {
    pub strategy: StrategyKind,
    pub clients: usize,
    pub requests_per_client: usize,
    pub batch: usize,
    pub wall_s: f64,
    /// Per-request latency distribution across all payloads, ms.
    pub latency: LatencyStats,
    /// Per-payload breakdowns (one entry per distinct served payload).
    pub per_payload: Vec<PayloadReport>,
    /// Gate wait/hold statistics (None for ungated strategies).
    pub gate: Option<GateStats>,
    /// Traffic/SLO accounting (Some for open-loop runs).
    pub traffic: Option<TrafficReport>,
}

impl ServeReport {
    /// Requests offered to the run (under open-loop arrivals some may
    /// have been shed; see [`ServeReport::traffic`]).
    pub fn total(&self) -> usize {
        self.clients * self.requests_per_client
    }

    /// Completed inferences per second of wall clock (completions, not
    /// offered requests, so shed traffic never inflates throughput).
    pub fn ips(&self) -> f64 {
        self.latency.count() as f64 / self.wall_s.max(1e-9)
    }

    /// Nearest-rank quantile (rank `ceil(q*n)`) of the pooled latencies;
    /// 0.0 when no latency was recorded. Exact when the spec kept the
    /// exact vectors, within the sketch's <= 2% relative error bound
    /// otherwise (min/max are always exact).
    pub fn latency_p(&self, q: f64) -> f64 {
        self.latency.quantile(q)
    }

    pub fn render(&self) -> String {
        let mut out = format!(
            "{} clients x {} requests (batch {}), strategy {}: {:.1} IPS; \
             latency ms p50={:.2} p95={:.2} p99={:.2} max={:.2}",
            self.clients,
            self.requests_per_client,
            self.batch,
            self.strategy,
            self.ips(),
            self.latency_p(0.50),
            self.latency_p(0.95),
            self.latency_p(0.99),
            self.latency.max(),
        );
        if self.per_payload.len() > 1 {
            for p in &self.per_payload {
                out.push_str(&format!(
                    "\n  payload {:<8} n={:<5} {:.1} IPS; p50={:.2} p95={:.2} ms",
                    p.payload,
                    p.latency.count(),
                    p.ips(self.wall_s),
                    p.latency.quantile(0.50),
                    p.latency.quantile(0.95),
                ));
            }
        }
        if let Some(g) = &self.gate {
            for line in g.render().lines() {
                out.push_str("\n  ");
                out.push_str(line);
            }
        }
        if let Some(t) = &self.traffic {
            for line in t.render(self.wall_s).lines() {
                out.push_str("\n  ");
                out.push_str(line);
            }
        }
        out
    }
}

// ---------------------------------------------------------------------
// the serve loop
// ---------------------------------------------------------------------

/// Per-request input perturbation (randomised inputs, §VI-C).
fn perturb(inputs: &mut [Vec<f32>], client: usize, request: usize) {
    if let Some(first) = inputs.first_mut() {
        for (i, v) in first.iter_mut().enumerate() {
            *v += ((request * 31 + client * 17 + i) % 13) as f32 * 1e-3;
        }
    }
}

/// One recorded request: (slot into `spec.payloads`, latency ms).
type Sample = (usize, f64);

/// A deferred stream operation (callback/worker strategies). The
/// acquire/release closures of Alg. 3 ride the stream as first-class
/// jobs, so the grant is held across job boundaries.
enum StreamJob {
    Acquire,
    Exec {
        payload: usize,
        slot: usize,
        inputs: Vec<Vec<f32>>,
        out_elems: usize,
        enqueued: Instant,
        record: bool,
    },
    Release,
}

/// Fold recorded samples into the pooled + per-payload latency stats
/// (shared by the closed-loop, open-loop and fleet assembly paths). One
/// pass recording into streaming sketches — the old accumulate-then-sort
/// tables paid an O(n log n) sort per report; the exact vectors (and
/// their sort) survive only behind `exact` (`--exact-quantiles`).
pub(crate) fn build_latency_stats(
    samples: Vec<Sample>,
    payloads: &[String],
    exact: bool,
) -> (LatencyStats, Vec<PayloadReport>) {
    let mut pooled = LatencyStats::new(exact);
    let mut by_slot: Vec<LatencyStats> = vec![LatencyStats::new(exact); payloads.len()];
    for (slot, ms) in samples {
        by_slot[slot].record(ms);
        pooled.record(ms);
    }
    pooled.seal();
    let mut per_payload = Vec::new();
    for (slot, mut lat) in by_slot.into_iter().enumerate() {
        if lat.is_empty() {
            continue;
        }
        lat.seal();
        per_payload.push(PayloadReport { payload: payloads[slot].clone(), latency: lat });
    }
    (pooled, per_payload)
}

/// Serve `spec` against `backend`.
///
/// Closed loop (the default): one client thread per client (plus a
/// stream/worker thread per client for the deferred strategies), all
/// sharing one FIFO [`GpuGate`] when the policy is gated. Open-loop
/// arrival processes (`spec.traffic`) take the open-loop path instead:
/// a paced generator in front of a bounded admission queue drained by a
/// fixed worker pool, with latency measured from arrival (DESIGN.md §9).
pub fn serve(spec: &ServeSpec, backend: &dyn ServeBackend) -> Result<ServeReport> {
    spec.validate()?;
    if spec.traffic.arrivals.is_open_loop() {
        return serve_open_loop(spec, backend);
    }
    let policy = AccessPolicy::new(spec.strategy);
    let resolved: Vec<ResolvedPayload> = spec
        .payloads
        .iter()
        .map(|p| backend.resolve(p))
        .collect::<Result<_>>()?;
    let gate = if policy.gated() { Some(GpuGate::new()) } else { None };

    let t0 = Instant::now();
    let joined: Vec<Result<Vec<Sample>>> = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..spec.clients {
            let slot = c % resolved.len();
            let rp = &resolved[slot];
            let gate = gate.as_ref();
            handles.push(s.spawn(move || run_client(spec, backend, policy, c, slot, rp, gate)));
        }
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(r) => r,
                Err(_) => Err(anyhow!("client thread panicked")),
            })
            .collect()
    });
    let wall_s = t0.elapsed().as_secs_f64();

    let mut samples = Vec::new();
    for r in joined {
        samples.extend(r?);
    }
    let (latency, per_payload) = build_latency_stats(samples, &spec.payloads, spec.exact_quantiles);
    Ok(ServeReport {
        strategy: spec.strategy,
        clients: spec.clients,
        requests_per_client: spec.requests,
        batch: spec.batch,
        wall_s,
        latency,
        per_payload,
        gate: gate.map(|g| g.stats()),
        traffic: None,
    })
}

/// One client: interprets the policy's admission plan with real threads.
fn run_client(
    spec: &ServeSpec,
    backend: &dyn ServeBackend,
    policy: AccessPolicy,
    client: usize,
    slot: usize,
    rp: &ResolvedPayload,
    gate: Option<&GpuGate>,
) -> Result<Vec<Sample>> {
    match policy.admission() {
        Admission::Direct => {
            // Unmitigated (`none`) or spatially-shared (`ptb`) execution
            // on the client thread itself.
            let exec = backend.executor()?;
            let share = policy.sm_share(spec.clients);
            // Warm-up (first-use compile) outside the recorded window.
            check_out(rp, &exec.execute(rp.index, &rp.base_inputs)?)?;
            let mut out = Vec::with_capacity(spec.requests);
            for r in 0..spec.requests {
                let mut inputs = rp.base_inputs.clone();
                perturb(&mut inputs, client, r);
                let t = Instant::now();
                let result = exec.execute(rp.index, &inputs)?;
                let exec_dt = t.elapsed();
                if share < 1.0 {
                    // PTB SM-share simulation fallback: with 1/N of the
                    // SMs, a device-bound request takes ~N times longer.
                    std::thread::sleep(exec_dt.mul_f64(1.0 / share - 1.0));
                }
                check_out(rp, &result)?;
                out.push((slot, t.elapsed().as_secs_f64() * 1e3));
            }
            Ok(out)
        }
        Admission::AcquireSyncRelease => {
            // Alg. 4 on the client thread: acquire, run the batch
            // (PJRT-style execution is synchronous, so insert + sync
            // collapse into the call), release.
            let exec = backend.executor()?;
            if let Some(g) = gate {
                g.with(|| check_out(rp, &exec.execute(rp.index, &rp.base_inputs)?))?;
            }
            let mut out = Vec::with_capacity(spec.requests);
            let mut r = 0;
            while r < spec.requests {
                let burst = spec.batch.min(spec.requests - r);
                let tb = Instant::now();
                let grant = gate.map(|g| g.acquire());
                // The grant MUST be released even on failure, or every
                // other client would deadlock in the FIFO gate.
                let mut burst_result = Ok(());
                for i in 0..burst {
                    let mut inputs = rp.base_inputs.clone();
                    perturb(&mut inputs, client, r + i);
                    burst_result = exec
                        .execute(rp.index, &inputs)
                        .and_then(|result| check_out(rp, &result));
                    if burst_result.is_err() {
                        break;
                    }
                    out.push((slot, tb.elapsed().as_secs_f64() * 1e3));
                }
                if let (Some(g), Some(grant)) = (gate, grant) {
                    g.release(grant);
                }
                burst_result?;
                r += burst;
            }
            Ok(out)
        }
        Admission::CallbackBracket => {
            // Alg. 3: acquire/exec/release ride the client's stream as
            // deferred jobs; the host thread never blocks per request.
            stream_client(spec, backend, client, slot, rp, gate, false)
        }
        Admission::DeferToWorker => {
            // Alg. 5-6: the worker owns the engine and serialises under
            // the gate; the host blocks awaiting each batch (Alg. 7's
            // drain shape at batch granularity).
            stream_client(spec, backend, client, slot, rp, gate, true)
        }
    }
}

/// Shared machinery for the deferred strategies: a stream thread that
/// owns the executor and processes FIFO jobs, holding the gate grant
/// across the Acquire..Release bracket.
fn stream_client(
    spec: &ServeSpec,
    backend: &dyn ServeBackend,
    client: usize,
    slot: usize,
    rp: &ResolvedPayload,
    gate: Option<&GpuGate>,
    blocking: bool,
) -> Result<Vec<Sample>> {
    // Bounded pipeline: a real driver stream has finite depth, so the
    // callback strategy's non-blocking host must not run unboundedly
    // ahead of the device (that would hold every pending request's
    // deep-copied inputs in memory and make reported latencies pure
    // queue time). Two batches of run-ahead models the hw prefetch
    // window; `send` blocks when the stream is that far behind.
    let depth = 2 * (spec.batch + 2);
    let (tx, rx) = mpsc::sync_channel::<StreamJob>(depth);
    let (done_tx, done_rx) = mpsc::channel::<()>();
    std::thread::scope(|s| -> Result<Vec<Sample>> {
        let stream = s.spawn(move || run_stream(backend, gate, rx, done_tx));
        // Feed the stream; a send/recv failure means the stream thread
        // died — its own Result (joined below) carries the real cause.
        let feed = || -> Result<()> {
            let gone = || anyhow!("stream thread gone");
            // Warm-up batch (not recorded).
            tx.send(StreamJob::Acquire).map_err(|_| gone())?;
            tx.send(StreamJob::Exec {
                payload: rp.index,
                slot,
                inputs: rp.base_inputs.clone(),
                out_elems: rp.out_elems,
                enqueued: Instant::now(),
                record: false,
            })
            .map_err(|_| gone())?;
            tx.send(StreamJob::Release).map_err(|_| gone())?;
            done_rx.recv().map_err(|_| gone())?;

            let mut r = 0;
            while r < spec.requests {
                let burst = spec.batch.min(spec.requests - r);
                tx.send(StreamJob::Acquire).map_err(|_| gone())?;
                for i in 0..burst {
                    let mut inputs = rp.base_inputs.clone();
                    perturb(&mut inputs, client, r + i);
                    tx.send(StreamJob::Exec {
                        payload: rp.index,
                        slot,
                        inputs,
                        out_elems: rp.out_elems,
                        enqueued: Instant::now(),
                        record: true,
                    })
                    .map_err(|_| gone())?;
                }
                tx.send(StreamJob::Release).map_err(|_| gone())?;
                if blocking {
                    // Worker strategy: the host awaits the batch (deferred
                    // execute + drain) before preparing the next one.
                    done_rx.recv().map_err(|_| gone())?;
                }
                r += burst;
            }
            Ok(())
        };
        let fed = feed();
        drop(tx); // close the stream; the thread drains and exits
        let streamed = stream.join().map_err(|_| anyhow!("stream thread panicked"))?;
        match (fed, streamed) {
            (Ok(()), r) => r,
            (Err(_), Err(stream_err)) => Err(stream_err),
            (Err(feed_err), Ok(_)) => Err(feed_err),
        }
    })
}

/// The stream/worker thread body: FIFO job interpreter.
///
/// On a payload failure the thread keeps draining jobs (so the feeding
/// host never blocks on a full pipeline) and keeps balancing the gate
/// (so other clients never deadlock on a grant that would otherwise be
/// dropped unreleased); the first error is reported at the end.
fn run_stream(
    backend: &dyn ServeBackend,
    gate: Option<&GpuGate>,
    rx: mpsc::Receiver<StreamJob>,
    done_tx: mpsc::Sender<()>,
) -> Result<Vec<Sample>> {
    let exec = backend.executor()?;
    let mut grant = None;
    let mut out = Vec::new();
    let mut failure: Option<anyhow::Error> = None;
    while let Ok(job) = rx.recv() {
        match job {
            StreamJob::Acquire => {
                if failure.is_none() {
                    if let Some(g) = gate {
                        grant = Some(g.acquire());
                    }
                }
            }
            StreamJob::Exec { payload, slot, inputs, out_elems, enqueued, record } => {
                if failure.is_some() {
                    continue;
                }
                match exec.execute(payload, &inputs) {
                    Ok(result) if result.len() != out_elems => {
                        failure = Some(anyhow!(
                            "bad output size {} (expected {out_elems})",
                            result.len()
                        ));
                    }
                    Ok(_) => {
                        if record {
                            out.push((slot, enqueued.elapsed().as_secs_f64() * 1e3));
                        }
                    }
                    Err(e) => failure = Some(e),
                }
            }
            StreamJob::Release => {
                if let (Some(g), Some(grant)) = (gate, grant.take()) {
                    g.release(grant);
                }
                // Batch boundary: signal hosts that block on drain. A
                // non-blocking host simply never reads past the warm-up.
                let _ = done_tx.send(());
            }
        }
    }
    if let (Some(g), Some(grant)) = (gate, grant.take()) {
        g.release(grant);
    }
    match failure {
        Some(e) => Err(e),
        None => Ok(out),
    }
}

fn check_out(rp: &ResolvedPayload, out: &[f32]) -> Result<()> {
    if out.len() != rp.out_elems {
        return Err(anyhow!(
            "payload {}: bad output size {} (expected {})",
            rp.name,
            out.len(),
            rp.out_elems
        ));
    }
    Ok(())
}

// ---------------------------------------------------------------------
// open-loop serving
// ---------------------------------------------------------------------

/// One generated request waiting in an admission queue. `arrival_at` is
/// the *scheduled* arrival instant — latency and queue delay are
/// measured from here even when the generator was delayed pushing it
/// (backpressure), which is exactly the coordinated-omission correction.
#[derive(Debug)]
pub(crate) struct Pending {
    /// Index into `ServeSpec::payloads`.
    pub slot: usize,
    /// Global arrival sequence number (input perturbation).
    pub seq: usize,
    pub arrival_at: Instant,
}

/// What one open-loop worker brings home.
#[derive(Debug, Default)]
pub(crate) struct OpenWorkerOut {
    pub samples: Vec<Sample>,
    /// Arrival-to-dequeue delay per dequeued request (ns).
    pub queue_delay: Histogram,
    /// Requests dropped at dequeue (timeout shed policy).
    pub timed_out: usize,
    /// Requests whose execution failed (first error reported below).
    pub failed: usize,
    pub error: Option<anyhow::Error>,
}

/// Aggregated outcome of a pool of open-loop workers (one shard's worth).
pub(crate) struct OpenOutcome {
    pub samples: Vec<Sample>,
    pub queue_delay: Histogram,
    pub timed_out: usize,
    /// Samples meeting the SLO (arrival-to-completion <= slo_ms).
    pub within_slo: usize,
    /// First worker error, if any (failed-request counts always come
    /// with one).
    pub error: Option<anyhow::Error>,
}

/// Fold worker outputs into one outcome (shared by the single-shard and
/// per-shard fleet assembly paths, so shed/timeout/SLO accounting can
/// never diverge between them).
pub(crate) fn fold_open_outs(outs: Vec<OpenWorkerOut>, slo_ms: f64) -> OpenOutcome {
    let mut samples = Vec::new();
    let mut queue_delay = Histogram::new();
    let (mut timed_out, mut failed) = (0usize, 0usize);
    let mut error = None;
    for o in outs {
        samples.extend(o.samples);
        queue_delay.merge(&o.queue_delay);
        timed_out += o.timed_out;
        failed += o.failed;
        if error.is_none() {
            error = o.error;
        }
    }
    debug_assert!(error.is_some() || failed == 0, "failed requests must come with an error");
    let within_slo = samples.iter().filter(|(_, ms)| *ms <= slo_ms).count();
    OpenOutcome { samples, queue_delay, timed_out, within_slo, error }
}

/// An open-loop serving worker: drains an [`AdmissionQueue`], admitting
/// bursts of up to `batch` requests per gate grant. `done` (when given)
/// runs once per dequeued request — the fleet uses it to release router
/// depth. An erroring worker keeps draining (so blocking producers can
/// never wedge) and reports the first error at the end.
#[allow(clippy::too_many_arguments)]
pub(crate) fn open_worker(
    backend: &dyn ServeBackend,
    resolved: &[ResolvedPayload],
    queue: &AdmissionQueue<Pending>,
    gate: Option<&GpuGate>,
    batch: usize,
    timeout: Option<Duration>,
    share: f64,
    warm: &Barrier,
    client: usize,
    done: Option<&(dyn Fn() + Sync)>,
) -> OpenWorkerOut {
    let mut out = OpenWorkerOut::default();
    let exec = match backend.executor() {
        Ok(e) => Some(e),
        Err(e) => {
            out.error = Some(e);
            None
        }
    };
    if let Some(exec) = &exec {
        // Warm-up (first-use compile) outside the recorded window,
        // through the gate so grant accounting matches the closed loop.
        let rp = &resolved[client % resolved.len()];
        let warmed = match gate {
            Some(g) => g.with(|| exec.execute(rp.index, &rp.base_inputs)),
            None => exec.execute(rp.index, &rp.base_inputs),
        };
        if let Err(e) = warmed.and_then(|r| check_out(rp, &r)) {
            out.error = Some(e);
        }
    }
    // Every worker reaches the barrier exactly once, healthy or not —
    // the dispatcher starts the clock behind it.
    warm.wait();
    let Some(exec) = exec.filter(|_| out.error.is_none()) else {
        // Unhealthy: drain so blocking/timeout pushes cannot deadlock.
        loop {
            let dropped = queue.pop_batch(batch.max(1));
            if dropped.is_empty() {
                return out;
            }
            out.failed += dropped.len();
            if let Some(f) = done {
                for _ in 0..dropped.len() {
                    f();
                }
            }
        }
    };
    loop {
        // Burst collection: block for the first request, then take
        // whatever backlog is already waiting, up to `batch` — one lock
        // acquisition total, not one per request (DESIGN.md §8).
        let burst = queue.pop_batch(batch.max(1));
        if burst.is_empty() {
            break; // closed and drained
        }
        // Dequeue-side accounting happens HERE, before any gate wait:
        // the queue-delay histogram measures arrival-to-dequeue only
        // (the gate wait has its own histogram), and the timeout policy
        // judges a request's age at dequeue — never acquiring a grant
        // just to drop an already-expired burst.
        let mut ready = Vec::with_capacity(burst.len());
        for p in burst {
            let qd = p.arrival_at.elapsed();
            out.queue_delay.record(qd.as_nanos().min(u64::MAX as u128) as u64);
            if timeout.is_some_and(|t| qd > t) {
                out.timed_out += 1;
                if let Some(f) = done {
                    f();
                }
            } else {
                ready.push(p);
            }
        }
        if ready.is_empty() {
            continue;
        }
        let grant = gate.map(|g| g.acquire());
        for p in ready {
            let rp = &resolved[p.slot];
            let mut inputs = rp.base_inputs.clone();
            perturb(&mut inputs, p.seq, p.seq);
            let t = Instant::now();
            match exec.execute(rp.index, &inputs).and_then(|r| check_out(rp, &r)) {
                Ok(()) => {
                    if share < 1.0 {
                        // PTB SM-share simulation (see run_client).
                        std::thread::sleep(t.elapsed().mul_f64(1.0 / share - 1.0));
                    }
                    out.samples.push((p.slot, p.arrival_at.elapsed().as_secs_f64() * 1e3));
                }
                Err(e) => {
                    out.failed += 1;
                    if out.error.is_none() {
                        out.error = Some(e);
                    }
                }
            }
            if let Some(f) = done {
                f();
            }
        }
        if let (Some(g), Some(grant)) = (gate, grant) {
            g.release(grant);
        }
    }
    out
}

/// Push one request into `queue` per the shed policy; false = shed.
pub(crate) fn admit(queue: &AdmissionQueue<Pending>, p: Pending, shed: ShedPolicy) -> bool {
    match shed {
        ShedPolicy::Block => queue.push_blocking(p),
        ShedPolicy::Reject => queue.try_push(p).is_ok(),
        ShedPolicy::Timeout { ms } => queue.push_timeout(p, Duration::from_millis(ms)).is_ok(),
    }
}

/// Realised offered rate of a schedule (requests/s over its span).
pub(crate) fn offered_rate_hz(offsets: &[crate::util::Nanos]) -> f64 {
    match offsets.last() {
        Some(&last) if last > 0 => offsets.len() as f64 / (last as f64 / 1e9),
        _ => 0.0,
    }
}

/// Open-loop serving: a paced generator (this thread) feeds a bounded
/// [`AdmissionQueue`] drained by `spec.clients` workers. The deferred
/// per-client stream machinery is a closed-loop construct; under open
/// loop the workers *are* the streams, so every gated strategy brackets
/// execution with the FIFO gate directly (one grant per burst).
fn serve_open_loop(spec: &ServeSpec, backend: &dyn ServeBackend) -> Result<ServeReport> {
    let policy = AccessPolicy::new(spec.strategy);
    let resolved: Vec<ResolvedPayload> = spec
        .payloads
        .iter()
        .map(|p| backend.resolve(p))
        .collect::<Result<_>>()?;
    let gate = if policy.gated() { Some(GpuGate::new()) } else { None };
    let total = spec.clients * spec.requests;
    let offsets = spec.traffic.arrivals.schedule_n(total, spec.traffic.seed);
    let queue: AdmissionQueue<Pending> = AdmissionQueue::new(spec.traffic.queue_cap);
    let shed = AtomicUsize::new(0);
    let warm = Barrier::new(spec.clients + 1);
    let share = policy.sm_share(spec.clients);
    let timeout = match spec.traffic.shed {
        ShedPolicy::Timeout { ms } => Some(Duration::from_millis(ms)),
        _ => None,
    };

    let (outs, wall_s) = std::thread::scope(|s| {
        let mut handles = Vec::new();
        for c in 0..spec.clients {
            let (queue, gate, warm, resolved) = (&queue, gate.as_ref(), &warm, &resolved);
            handles.push(s.spawn(move || {
                open_worker(
                    backend, resolved, queue, gate, spec.batch, timeout, share, warm, c, None,
                )
            }));
        }
        warm.wait();
        let t0 = Instant::now();
        for (seq, &off) in offsets.iter().enumerate() {
            let arrival_at = t0 + Duration::from_nanos(off);
            let now = Instant::now();
            if arrival_at > now {
                std::thread::sleep(arrival_at - now);
            }
            let p = Pending { slot: seq % resolved.len(), seq, arrival_at };
            if !admit(&queue, p, spec.traffic.shed) {
                shed.fetch_add(1, Ordering::Relaxed);
            }
        }
        queue.close();
        let outs: Vec<OpenWorkerOut> = handles
            .into_iter()
            .map(|h| {
                h.join().unwrap_or_else(|_| OpenWorkerOut {
                    error: Some(anyhow!("open-loop worker thread panicked")),
                    ..OpenWorkerOut::default()
                })
            })
            .collect();
        // Wall clock spans generation AND backlog drain: the makespan.
        (outs, t0.elapsed().as_secs_f64())
    });

    let o = fold_open_outs(outs, spec.traffic.slo_ms);
    if let Some(e) = o.error {
        return Err(e);
    }
    let (queue_delay, timed_out, within_slo) = (o.queue_delay, o.timed_out, o.within_slo);
    let completed = o.samples.len();
    let (latency, per_payload) =
        build_latency_stats(o.samples, &spec.payloads, spec.exact_quantiles);
    Ok(ServeReport {
        strategy: spec.strategy,
        clients: spec.clients,
        requests_per_client: spec.requests,
        batch: spec.batch,
        wall_s,
        latency,
        per_payload,
        gate: gate.map(|g| g.stats()),
        traffic: Some(TrafficReport {
            arrivals: spec.traffic.arrivals,
            queue_cap: spec.traffic.queue_cap,
            shed_policy: spec.traffic.shed,
            slo_ms: spec.traffic.slo_ms,
            offered: total,
            completed,
            shed: shed.into_inner(),
            timed_out,
            within_slo,
            queue_delay,
            offered_rate_hz: offered_rate_hz(&offsets),
        }),
    })
}

// ---------------------------------------------------------------------
// compatibility wrapper
// ---------------------------------------------------------------------

/// Serve DNA-Net inferences from `clients` concurrent applications
/// (the original serving entry point, kept for callers and tests).
pub fn serve_dna(
    strategy: StrategyKind,
    clients: usize,
    requests: usize,
    artifacts_dir: PathBuf,
) -> Result<ServeReport> {
    let spec = ServeSpec::new(strategy, "dna")
        .with_clients(clients)
        .with_requests(requests);
    serve(&spec, &ManifestBackend::new(artifacts_dir))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn backend() -> SyntheticBackend {
        SyntheticBackend::new(50)
    }

    #[test]
    fn all_five_strategies_serve_synthetic() {
        for strategy in StrategyKind::ALL {
            let spec = ServeSpec::new(strategy, "dna")
                .with_clients(2)
                .with_requests(4);
            let r = serve(&spec, &backend()).unwrap_or_else(|e| panic!("{strategy}: {e}"));
            assert_eq!(r.total(), 8, "{strategy}");
            assert_eq!(r.latency.count(), 8, "{strategy}");
            assert!(!r.latency.is_exact(), "sketch-only by default");
            assert!(r.ips() > 0.0, "{strategy}");
            assert!(r.latency_p(0.5) > 0.0, "{strategy}");
            assert_eq!(r.gate.is_some(), AccessPolicy::new(strategy).gated(), "{strategy}");
        }
    }

    #[test]
    fn gated_strategies_record_wait_and_hold() {
        for strategy in [StrategyKind::Callback, StrategyKind::Synced, StrategyKind::Worker] {
            let spec = ServeSpec::new(strategy, "mmult")
                .with_clients(3)
                .with_requests(5);
            let r = serve(&spec, &backend()).unwrap();
            let g = r.gate.expect("gated strategy must report gate stats");
            // One warm-up grant per client + one grant per request batch.
            assert_eq!(g.grants(), 3 + 15, "{strategy}");
            assert!(g.hold.mean_ns() > 0.0, "{strategy}");
        }
    }

    #[test]
    fn batching_reduces_gate_grants() {
        let spec = ServeSpec::new(StrategyKind::Synced, "dna")
            .with_clients(2)
            .with_requests(6)
            .with_batch(3);
        let r = serve(&spec, &backend()).unwrap();
        // 2 warm-up grants + 2 clients x 2 batches.
        assert_eq!(r.gate.unwrap().grants(), 2 + 4);
        assert_eq!(r.total(), 12);
    }

    #[test]
    fn multi_payload_reports_per_payload() {
        let spec = ServeSpec::new(StrategyKind::Worker, "dna")
            .with_payloads(vec!["dna".into(), "mmult".into()])
            .with_clients(4)
            .with_requests(3);
        let r = serve(&spec, &backend()).unwrap();
        assert_eq!(r.per_payload.len(), 2);
        for p in &r.per_payload {
            assert_eq!(p.latency.count(), 6, "{}", p.payload);
            assert!(p.ips(r.wall_s) > 0.0);
        }
        assert!(r.render().contains("payload dna"));
        assert!(r.render().contains("payload mmult"));
    }

    #[test]
    fn nearest_rank_quantile_fixed() {
        // Regression for the original latency_p: it panicked on empty
        // vectors and was biased one rank high on exact multiples.
        let empty = ServeReport {
            strategy: StrategyKind::None,
            clients: 1,
            requests_per_client: 1,
            batch: 1,
            wall_s: 1.0,
            latency: LatencyStats::new(true),
            per_payload: vec![],
            gate: None,
            traffic: None,
        };
        assert_eq!(empty.latency_p(0.5), 0.0);
        assert_eq!(empty.latency_p(0.99), 0.0);

        let four = ServeReport {
            latency: LatencyStats::from_values(&[1.0, 2.0, 3.0, 4.0], true),
            ..empty
        };
        // Nearest rank (exact path): ceil(0.5*4) = 2 -> the 2nd smallest.
        assert_eq!(four.latency_p(0.50), 2.0);
        assert_eq!(four.latency_p(0.25), 1.0);
        assert_eq!(four.latency_p(0.75), 3.0);
        assert_eq!(four.latency_p(1.00), 4.0);
        assert_eq!(four.latency_p(0.0), 1.0);
    }

    #[test]
    fn exact_quantiles_flag_keeps_exact_vectors() {
        let spec = ServeSpec::new(StrategyKind::Synced, "dna")
            .with_clients(2)
            .with_requests(4)
            .with_exact_quantiles(true);
        let r = serve(&spec, &backend()).unwrap();
        assert!(r.latency.is_exact());
        let exact = r.latency.exact_values().unwrap();
        assert_eq!(exact.len(), 8);
        // Sketch and exact must agree within the documented error bound.
        for q in [0.25, 0.5, 0.95] {
            let (e, s) = (r.latency.quantile(q), r.latency.sketch.quantile(q));
            assert!(
                (s - e).abs() / e.max(1e-12)
                    <= crate::metrics::stats::QuantileSketch::GAMMA - 1.0 + 1e-9,
                "q={q}: sketch {s} vs exact {e}"
            );
        }
    }

    #[test]
    fn invalid_specs_rejected() {
        let b = backend();
        assert!(serve(&ServeSpec::new(StrategyKind::None, "x").with_clients(0), &b).is_err());
        assert!(serve(&ServeSpec::new(StrategyKind::None, "x").with_requests(0), &b).is_err());
        assert!(serve(&ServeSpec::new(StrategyKind::None, "x").with_batch(0), &b).is_err());
        assert!(
            serve(&ServeSpec::new(StrategyKind::None, "x").with_payloads(vec![]), &b).is_err()
        );
    }

    #[test]
    fn report_render_mentions_strategy_and_gate() {
        let spec = ServeSpec::new(StrategyKind::Synced, "dna")
            .with_clients(2)
            .with_requests(3);
        let r = serve(&spec, &backend()).unwrap();
        let text = r.render();
        assert!(text.contains("strategy synced"), "{text}");
        assert!(text.contains("gate wait"), "{text}");
        assert!(text.contains("IPS"), "{text}");
    }

    // ------------------------------------------------------ open loop --

    use crate::control::traffic::ArrivalProcess;

    fn open_traffic(rate_hz: f64) -> TrafficSpec {
        TrafficSpec {
            arrivals: ArrivalProcess::Poisson { rate_hz },
            queue_cap: 64,
            shed: ShedPolicy::Block,
            slo_ms: 1_000.0,
            seed: 7,
        }
    }

    #[test]
    fn open_loop_serves_every_strategy() {
        for strategy in StrategyKind::ALL {
            let spec = ServeSpec::new(strategy, "dna")
                .with_clients(2)
                .with_requests(5)
                .with_traffic(open_traffic(2_000.0));
            let r = serve(&spec, &backend()).unwrap_or_else(|e| panic!("{strategy}: {e}"));
            let t = r.traffic.as_ref().expect("open loop must report traffic");
            assert_eq!(t.offered, 10, "{strategy}");
            assert!(t.accounted(0), "{strategy}: requests leaked");
            // Blocking shed policy + generous SLO: everything completes.
            assert_eq!(t.completed, 10, "{strategy}");
            assert_eq!(t.shed, 0, "{strategy}");
            assert_eq!(r.latency.count(), 10, "{strategy}");
            assert_eq!(t.queue_delay.count(), 10, "{strategy}");
            assert_eq!(r.gate.is_some(), AccessPolicy::new(strategy).gated(), "{strategy}");
        }
    }

    #[test]
    fn open_loop_overload_sheds_with_reject() {
        // Service capacity ~= clients/exec_us; offer far beyond it into a
        // tiny queue: the reject policy must shed most of the flood.
        let spec = ServeSpec::new(StrategyKind::Synced, "dna")
            .with_clients(2)
            .with_requests(20)
            .with_traffic(TrafficSpec {
                arrivals: ArrivalProcess::Poisson { rate_hz: 20_000.0 },
                queue_cap: 2,
                shed: ShedPolicy::Reject,
                slo_ms: 50.0,
                seed: 1,
            });
        let r = serve(&spec, &SyntheticBackend::new(2_000)).unwrap();
        let t = r.traffic.as_ref().unwrap();
        assert_eq!(t.offered, 40);
        assert!(t.shed > 0, "overload against cap 2 must shed");
        assert!(t.accounted(0));
        assert_eq!(t.completed, r.latency.count());
        assert!(t.completed < t.offered);
    }

    #[test]
    fn open_loop_slo_accounting_brackets() {
        let base = ServeSpec::new(StrategyKind::Worker, "dna")
            .with_clients(2)
            .with_requests(5);
        // Unreachably generous SLO: attainment equals completion rate.
        let generous = base
            .clone()
            .with_traffic(TrafficSpec { slo_ms: 1e9, ..open_traffic(2_000.0) });
        let r = serve(&generous, &backend()).unwrap();
        let t = r.traffic.as_ref().unwrap();
        assert_eq!(t.within_slo, t.completed);
        assert!((t.slo_attainment_pct() - 100.0).abs() < 1e-9);
        assert!(t.goodput(r.wall_s) > 0.0);
        // Unreachably tight SLO: nothing attains it.
        let tight = base.with_traffic(TrafficSpec { slo_ms: 1e-6, ..open_traffic(2_000.0) });
        let r = serve(&tight, &backend()).unwrap();
        assert_eq!(r.traffic.as_ref().unwrap().within_slo, 0);
    }

    #[test]
    fn open_loop_timeout_policy_drops_stale_requests() {
        // 1 ms of patience against multi-ms service: the backlog ages out
        // (at admission or at dequeue) instead of growing unboundedly.
        let spec = ServeSpec::new(StrategyKind::Synced, "dna")
            .with_clients(1)
            .with_requests(30)
            .with_traffic(TrafficSpec {
                arrivals: ArrivalProcess::Poisson { rate_hz: 20_000.0 },
                queue_cap: 4,
                shed: ShedPolicy::Timeout { ms: 1 },
                slo_ms: 50.0,
                seed: 3,
            });
        let r = serve(&spec, &SyntheticBackend::new(3_000)).unwrap();
        let t = r.traffic.as_ref().unwrap();
        assert!(t.shed + t.timed_out > 0, "saturation must age requests out");
        assert!(t.accounted(0));
    }

    #[test]
    fn open_loop_batching_and_payload_mix() {
        let spec = ServeSpec::new(StrategyKind::Worker, "dna")
            .with_payloads(vec!["dna".into(), "mmult".into()])
            .with_clients(2)
            .with_requests(6)
            .with_batch(3)
            .with_traffic(open_traffic(5_000.0));
        let r = serve(&spec, &backend()).unwrap();
        assert_eq!(r.traffic.as_ref().unwrap().completed, 12);
        // Arrivals alternate payload slots: both payloads must be served.
        assert_eq!(r.per_payload.len(), 2);
        let text = r.render();
        assert!(text.contains("goodput"), "{text}");
        assert!(text.contains("attainment"), "{text}");
    }

    #[test]
    fn open_loop_streams_are_seed_deterministic() {
        let p = ArrivalProcess::Poisson { rate_hz: 777.0 };
        assert_eq!(p.schedule_n(64, 11), p.schedule_n(64, 11));
        assert_ne!(p.schedule_n(64, 11), p.schedule_n(64, 12));
    }

    #[test]
    fn open_loop_rejects_invalid_traffic() {
        let b = backend();
        let bad_cap = ServeSpec::new(StrategyKind::None, "x").with_traffic(TrafficSpec {
            queue_cap: 0,
            ..open_traffic(100.0)
        });
        assert!(serve(&bad_cap, &b).is_err());
        let bad_slo = ServeSpec::new(StrategyKind::None, "x").with_traffic(TrafficSpec {
            slo_ms: 0.0,
            ..open_traffic(100.0)
        });
        assert!(serve(&bad_slo, &b).is_err());
    }
}
