//! The global GPU lock (`GPU_LOCK`, §V-B).
//!
//! Implemented, like the paper, as a counting semaphore with FIFO wakeup:
//! `acquire` is `sem_wait`, `release` is `sem_post`. POSIX semantics matter
//! for fidelity: *anyone* may post, not just the current holder. The
//! callback strategy exploits exactly that (its release callbacks post from
//! driver threads), and the count drift that results under optimistic
//! callback retirement is what degrades its isolation (§VII-B).

use crate::util::{AppId, Nanos, OpUid};
use std::collections::VecDeque;

/// Who is waiting on / holding the semaphore.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LockClient {
    /// An application host thread (synced strategy).
    Host(AppId),
    /// A deferred-worker thread (worker strategy).
    Worker(AppId),
    /// An acquire callback running on a driver callback thread
    /// (callback strategy); the op is the host-func op executing it.
    Callback(OpUid),
}

/// A queued waiter: who, with an arrival ticket and enqueue time so a
/// pluggable [`Arbiter`](crate::control::arbiter::Arbiter) can order the
/// queue by age (FIFO), class weight, or deadline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueuedWaiter {
    pub client: LockClient,
    /// Monotone arrival ticket (FIFO tie-break for every policy).
    pub ticket: u64,
    /// Simulated time the waiter joined the queue.
    pub enqueued: Nanos,
}

/// Counting semaphore with FIFO waiters, instrumented for the traces.
#[derive(Debug)]
pub struct GpuLock {
    count: u32,
    waiters: VecDeque<QueuedWaiter>,
    next_ticket: u64,
    /// Grant log: (time, client) — drives lock-occupancy metrics.
    pub grants: Vec<(Nanos, LockClient)>,
    /// Release log: (time).
    pub releases: Vec<Nanos>,
    /// Peak number of simultaneous waiters (contention metric).
    pub max_waiters: usize,
}

impl GpuLock {
    /// A binary GPU lock (count = 1), as the paper's implementation.
    pub fn new() -> Self {
        Self::with_count(1)
    }

    pub fn with_count(count: u32) -> Self {
        Self {
            count,
            waiters: VecDeque::new(),
            next_ticket: 0,
            grants: Vec::new(),
            releases: Vec::new(),
            max_waiters: 0,
        }
    }

    /// `sem_wait`: returns true if the lock was acquired immediately;
    /// otherwise the client is queued and will be woken by a grant.
    ///
    /// NOTE — *barging* semantics, like the futex fast path behind POSIX
    /// semaphores: a fresh `sem_wait` that arrives while the count is
    /// positive wins even if older waiters are still being woken up. A
    /// tight release->acquire loop (cuda_mmult under the synced hook)
    /// therefore keeps the lock for long runs, while an application with
    /// host work between routines (onnx_dna) loses the race to the woken
    /// waiter. Both behaviours are visible in the paper's measurements.
    pub fn acquire(&mut self, client: LockClient, now: Nanos) -> bool {
        if self.count > 0 {
            self.count -= 1;
            self.grants.push((now, client));
            true
        } else {
            let ticket = self.next_ticket;
            self.next_ticket += 1;
            self.waiters.push_back(QueuedWaiter { client, ticket, enqueued: now });
            self.max_waiters = self.max_waiters.max(self.waiters.len());
            false
        }
    }

    /// `sem_post`: increments the count. Does NOT pick the next waiter —
    /// the engine calls [`GpuLock::grant_next`] from its pump so grants
    /// happen at well-defined points of the event loop.
    pub fn release(&mut self, now: Nanos) {
        self.count += 1;
        self.releases.push(now);
    }

    /// If the semaphore has capacity and someone is waiting, grant FIFO.
    /// Returns the granted client (the engine routes the wakeup).
    pub fn grant_next(&mut self, now: Nanos) -> Option<LockClient> {
        self.grant_nth(0, now)
    }

    /// Positional grant: if the semaphore has capacity, grant the waiter
    /// at queue position `pos` (as chosen by an arbiter over
    /// [`GpuLock::queued_waiters`]). `grant_nth(0, _)` is exactly the
    /// FIFO `grant_next`, so the golden traces are untouched when the
    /// FIFO arbiter drives this.
    pub fn grant_nth(&mut self, pos: usize, now: Nanos) -> Option<LockClient> {
        if self.count > 0 && pos < self.waiters.len() {
            if let Some(w) = self.waiters.remove(pos) {
                self.count -= 1;
                self.grants.push((now, w.client));
                return Some(w.client);
            }
        }
        None
    }

    pub fn available(&self) -> bool {
        self.count > 0
    }

    pub fn num_waiters(&self) -> usize {
        self.waiters.len()
    }

    /// The next waiter in line (wake-latency selection).
    pub fn head_waiter(&self) -> Option<LockClient> {
        self.waiters.front().map(|w| w.client)
    }

    /// The waiter at queue position `pos`, if any (peek, no state change).
    pub fn waiter_at(&self, pos: usize) -> Option<LockClient> {
        self.waiters.get(pos).map(|w| w.client)
    }

    /// Snapshot of the wait queue in arrival order, for arbiter input.
    pub fn queued_waiters(&self) -> impl Iterator<Item = &QueuedWaiter> {
        self.waiters.iter()
    }

    /// Remove a queued waiter (used only by teardown paths in tests).
    pub fn cancel_waiter(&mut self, client: LockClient) -> bool {
        if let Some(pos) = self.waiters.iter().position(|w| w.client == client) {
            self.waiters.remove(pos);
            true
        } else {
            false
        }
    }
}

impl Default for GpuLock {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn immediate_acquire_when_free() {
        let mut l = GpuLock::new();
        assert!(l.acquire(LockClient::Host(AppId(0)), 10));
        assert!(!l.available());
        assert_eq!(l.grants.len(), 1);
    }

    #[test]
    fn second_acquire_queues_fifo() {
        let mut l = GpuLock::new();
        assert!(l.acquire(LockClient::Host(AppId(0)), 0));
        assert!(!l.acquire(LockClient::Host(AppId(1)), 1));
        assert!(!l.acquire(LockClient::Worker(AppId(2)), 2));
        assert_eq!(l.num_waiters(), 2);
        // Nothing grantable until a release.
        assert_eq!(l.grant_next(3), None);
        l.release(4);
        assert_eq!(l.grant_next(4), Some(LockClient::Host(AppId(1))));
        l.release(5);
        assert_eq!(l.grant_next(5), Some(LockClient::Worker(AppId(2))));
    }

    #[test]
    fn new_arrivals_barge_past_sleeping_waiters() {
        // futex fast path: between release and the waiter's wakeup, a
        // fresh acquire steals the count (see acquire() docs).
        let mut l = GpuLock::new();
        assert!(l.acquire(LockClient::Host(AppId(0)), 0));
        assert!(!l.acquire(LockClient::Host(AppId(1)), 1));
        l.release(2);
        assert!(l.acquire(LockClient::Host(AppId(2)), 3), "barging allowed");
        // The sleeping waiter finds the count consumed at wakeup.
        assert_eq!(l.grant_next(4), None);
        l.release(5);
        assert_eq!(l.grant_next(5 + 1), Some(LockClient::Host(AppId(1))));
    }

    #[test]
    fn posix_post_semantics_allow_count_drift() {
        // The callback strategy's failure mode: posts without matching
        // waits inflate the count, letting two clients in at once.
        let mut l = GpuLock::new();
        assert!(l.acquire(LockClient::Callback(OpUid(1)), 0));
        l.release(1); // release from a driver thread
        l.release(2); // double post: count = 2
        assert!(l.acquire(LockClient::Callback(OpUid(2)), 3));
        assert!(l.acquire(LockClient::Callback(OpUid(3)), 4));
        assert!(!l.acquire(LockClient::Callback(OpUid(4)), 5));
    }

    #[test]
    fn contention_metric_tracks_peak() {
        let mut l = GpuLock::new();
        l.acquire(LockClient::Host(AppId(0)), 0);
        l.acquire(LockClient::Host(AppId(1)), 0);
        l.acquire(LockClient::Host(AppId(2)), 0);
        assert_eq!(l.max_waiters, 2);
    }

    #[test]
    fn positional_grant_and_queue_snapshot() {
        let mut l = GpuLock::new();
        assert!(l.acquire(LockClient::Host(AppId(0)), 0));
        assert!(!l.acquire(LockClient::Host(AppId(1)), 5));
        assert!(!l.acquire(LockClient::Host(AppId(2)), 9));
        let q: Vec<QueuedWaiter> = l.queued_waiters().copied().collect();
        assert_eq!(q.len(), 2);
        assert_eq!(q[0].ticket, 0);
        assert_eq!(q[0].enqueued, 5);
        assert_eq!(q[1].ticket, 1);
        assert_eq!(q[1].enqueued, 9);
        assert_eq!(l.waiter_at(1), Some(LockClient::Host(AppId(2))));
        // No capacity yet: positional grant refuses like grant_next.
        assert_eq!(l.grant_nth(1, 10), None);
        l.release(11);
        // An arbiter may grant out of FIFO order.
        assert_eq!(l.grant_nth(1, 12), Some(LockClient::Host(AppId(2))));
        assert_eq!(l.head_waiter(), Some(LockClient::Host(AppId(1))));
        // Out-of-range position never grants.
        l.release(13);
        assert_eq!(l.grant_nth(7, 14), None);
        assert_eq!(l.grant_nth(0, 15), Some(LockClient::Host(AppId(1))));
    }

    #[test]
    fn cancel_waiter() {
        let mut l = GpuLock::new();
        l.acquire(LockClient::Host(AppId(0)), 0);
        l.acquire(LockClient::Host(AppId(1)), 0);
        assert!(l.cancel_waiter(LockClient::Host(AppId(1))));
        assert!(!l.cancel_waiter(LockClient::Host(AppId(1))));
        assert_eq!(l.num_waiters(), 0);
    }
}
