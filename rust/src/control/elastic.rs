//! Elastic fleet: SLO-driven autoscaling with drain-then-retire scale
//! events and work stealing (ROADMAP item 2, DESIGN.md §15).
//!
//! A fixed fleet (`--shards N`) is sized once at startup, so the bursty
//! and diurnal arrival shapes either over-provision or shed. This module
//! adds a **fleet controller** thread that watches the per-shard signals
//! the fleet already emits — admission-queue occupancy, a windowed SLO
//! attainment derived from worker completions, and each shard's
//! [`ShardHealth`] state — and scales the live fleet between
//! `--autoscale min..max` at runtime:
//!
//! * **Hot-add** (`scale-up`): a dormant shard slot gets a fresh gate +
//!   policy + worker pool, spawned into the *same* `thread::scope` as
//!   the boot-time shards (nested scoped spawn), warmed exactly like
//!   them, and immediately eligible for routing.
//! * **Drain-then-retire** (`scale-down`): routing stops first (the slot
//!   leaves the ACTIVE state), then the shard's [`AdmissionQueue`] is
//!   closed and drained — leftovers are re-queued onto live shards with
//!   [`ShardRouter::transfer`] keeping depth accounting honest — and
//!   only after the last worker exits is the gate dropped. The
//!   conservation law `offered == completed + shed + timed_out + failed`
//!   therefore holds through every scale event, including a scale-down
//!   racing a boot-crash ejection (DESIGN.md §12).
//! * **Work stealing**: an idle worker whose own queue stays empty past
//!   a short patience window pulls a batch from the *deepest* other
//!   ACTIVE shard (skipping Ejected/Probing shards — they are drained,
//!   never stolen from) and runs it through its own accounting context,
//!   with per-request attribution moved via `transfer`.
//!
//! The same controller policy is mirrored deterministically in the
//! simulator ([`plan_windows`]): window counts are computed from the
//! arrival schedule before partitioning, so fleets stay bit-identical
//! across `COOK_SIM_THREADS`.
//!
//! Fixed fleets (`autoscale: None`) never enter this module — their
//! output stays byte-identical to the pre-elastic code.

use crate::control::arbiter::{class_of, ArbiterKind, CreditBank};
use crate::control::concurrency::ModeGate;
use crate::control::fault::{panic_msg, FaultReport, HealthState, ShardHealth};
use crate::control::fleet::{FleetReport, FleetSpec, ShardReport, ShardRouter};
use crate::control::gate::GateStats;
use crate::control::policy::AccessPolicy;
use crate::control::serving::{
    admit, build_class_reports, build_latency_stats, drain_failed, fold_open_outs, make_gate,
    offered_rate_hz, process_burst, warm_up, OpenWorkerCtx, OpenWorkerOut, Pending,
    ResolvedPayload, ServeBackend, ServeReport, ServeSpec,
};
use crate::control::traffic::{AdmissionQueue, ShedPolicy, TrafficReport};
use crate::metrics::stats::LatencyStats;
use crate::util::lock_recover;
use anyhow::{anyhow, Result};
use std::panic::AssertUnwindSafe;
use std::str::FromStr;
use std::sync::atomic::{AtomicBool, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Barrier, Mutex, PoisonError};
use std::time::{Duration, Instant};

// ---------------------------------------------------------------------
// spec
// ---------------------------------------------------------------------

/// Autoscaling bounds: the fleet holds between `min` and `max` live
/// shards. Parsed from `--autoscale MIN..MAX`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AutoscaleSpec {
    /// Live-shard floor (the boot-time fleet size; >= 1).
    pub min: usize,
    /// Live-shard ceiling (the pre-allocated slot pool).
    pub max: usize,
}

impl AutoscaleSpec {
    pub fn validate(&self) -> Result<(), String> {
        if self.min == 0 {
            return Err("autoscale min must be >= 1 (a fleet cannot scale to zero)".into());
        }
        if self.min > self.max {
            return Err(format!(
                "autoscale min ({}) must be <= max ({})",
                self.min, self.max
            ));
        }
        Ok(())
    }
}

impl FromStr for AutoscaleSpec {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, String> {
        let (lo, hi) = s
            .split_once("..")
            .ok_or_else(|| format!("autoscale wants MIN..MAX (e.g. 1..4), got {s:?}"))?;
        let min: usize =
            lo.trim().parse().map_err(|_| format!("autoscale min {:?} is not a count", lo))?;
        let max: usize =
            hi.trim().parse().map_err(|_| format!("autoscale max {:?} is not a count", hi))?;
        let spec = Self { min, max };
        spec.validate()?;
        Ok(spec)
    }
}

impl std::fmt::Display for AutoscaleSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}..{}", self.min, self.max)
    }
}

// ---------------------------------------------------------------------
// events & report
// ---------------------------------------------------------------------

/// One controller decision, timestamped from the run's start.
#[derive(Debug, Clone, PartialEq)]
pub enum ScaleEvent {
    /// Hot-add: `shard` spawned; the fleet now runs `active` shards.
    Up { at_ms: f64, shard: usize, active: usize },
    /// Drain-then-retire completed: `shard` drained (re-queueing
    /// `requeued` leftovers onto live shards) and its gate was dropped;
    /// the fleet now runs `active` shards.
    Retire { at_ms: f64, shard: usize, active: usize, requeued: usize },
    /// Pressure persisted with every slot already live: the fleet is
    /// saturated at `max` and degrades by shedding/queueing instead of
    /// growing (logged once per saturation episode).
    Saturated { at_ms: f64 },
}

/// What the elastic controller did over one run.
#[derive(Debug, Clone)]
pub struct ElasticReport {
    pub min: usize,
    pub max: usize,
    /// Shards live at t0 (= `min`).
    pub started: usize,
    /// Shards live when the run ended.
    pub final_active: usize,
    pub peak_active: usize,
    pub scale_ups: usize,
    pub retires: usize,
    /// Leftover requests re-queued onto live shards by retirements.
    pub requeued: usize,
    /// Stolen bursts / stolen requests (work stealing).
    pub steals: usize,
    pub stolen: usize,
    pub events: Vec<ScaleEvent>,
}

impl ElasticReport {
    /// Render the controller's story. The summary line always names both
    /// transition kinds ("scale-up", "drain-then-retire") so smoke greps
    /// stay stable even on runs with zero events.
    pub fn render(&self) -> String {
        let mut out = format!(
            "elastic: autoscale {}..{}, shards {} -> {} (peak {}); \
             scale-up x{}, drain-then-retire x{} ({} requeued); \
             steals {} bursts / {} requests",
            self.min,
            self.max,
            self.started,
            self.final_active,
            self.peak_active,
            self.scale_ups,
            self.retires,
            self.requeued,
            self.steals,
            self.stolen,
        );
        for e in &self.events {
            match e {
                ScaleEvent::Up { at_ms, shard, active } => {
                    out.push_str(&format!(
                        "\nscale-up: shard {shard} spawned at {at_ms:.1} ms (active {active})"
                    ));
                }
                ScaleEvent::Retire { at_ms, shard, active, requeued } => {
                    out.push_str(&format!(
                        "\ndrain-then-retire: shard {shard} drained at {at_ms:.1} ms \
                         (active {active}, requeued {requeued})"
                    ));
                }
                ScaleEvent::Saturated { at_ms } => {
                    out.push_str(&format!(
                        "\nsaturated at max ({}) at {at_ms:.1} ms: degrading via \
                         queueing/shedding, not growth",
                        self.max
                    ));
                }
            }
        }
        out
    }
}

// ---------------------------------------------------------------------
// controller policy constants
// ---------------------------------------------------------------------

/// Controller sampling period. Short against any realistic run length,
/// long against a queue-lock hold.
const TICK: Duration = Duration::from_millis(4);
/// How long an idle worker waits on its own queue before stealing.
const STEAL_PATIENCE: Duration = Duration::from_millis(1);
/// Mean queue occupancy that reads as pressure (scale up).
const HIGH_OCC: f64 = 0.5;
/// Mean queue occupancy low enough to consider retiring a shard.
const LOW_OCC: f64 = 0.10;
/// Consecutive low-occupancy ticks before a retirement (hysteresis).
const LOW_TICKS_TO_RETIRE: u32 = 3;
/// Windowed SLO attainment the controller defends, percent.
const SLO_TARGET_PCT: f64 = 90.0;

// Shard slot lifecycle. Transitions only move forward:
// DORMANT -> ACTIVE -> DRAINING -> RETIRED (an AdmissionQueue cannot
// reopen, so a retired slot is never reused — scale-up takes the next
// DORMANT slot instead).
const DORMANT: u8 = 0;
const ACTIVE: u8 = 1;
const DRAINING: u8 = 2;
const RETIRED: u8 = 3;

// ---------------------------------------------------------------------
// sim mirror
// ---------------------------------------------------------------------

/// Deterministic mirror of the controller policy for the simulator: map
/// per-window arrival counts onto an active-shard count per window,
/// clamped to `[min, max]`, with the same asymmetry as the live
/// controller — scale-up reacts immediately, scale-down waits for two
/// consecutive lower-demand windows (hysteresis). Pure integer
/// arithmetic on the pre-partition schedule, so every
/// `COOK_SIM_THREADS` setting sees the identical timeline.
pub fn plan_windows(counts: &[usize], min: usize, max: usize) -> Vec<usize> {
    let min = min.max(1);
    let max = max.max(min);
    if counts.is_empty() || max == min {
        return vec![min; counts.len()];
    }
    let lo = *counts.iter().min().expect("non-empty");
    let hi = *counts.iter().max().expect("non-empty");
    let span = (hi - lo).max(1);
    let mut active = min;
    let mut below = 0u32;
    counts
        .iter()
        .map(|&c| {
            // Linear demand map with round-half-up, pinned to integers.
            let desired = min + ((c - lo) * (max - min) + span / 2) / span;
            if desired > active {
                active = desired;
                below = 0;
            } else if desired < active {
                below += 1;
                if below >= 2 {
                    active = desired;
                    below = 0;
                }
            } else {
                below = 0;
            }
            active
        })
        .collect()
}

// ---------------------------------------------------------------------
// shard slots
// ---------------------------------------------------------------------

/// Runtime state of one pre-allocated shard slot.
struct ShardSlot {
    state: AtomicU8,
    /// The shard's gate while live. The controller `take()`s and drops
    /// it at retirement — after sealing its stats — so "drop the gate"
    /// is literal: the Arc's last reference dies with the slot.
    gate: Mutex<Option<Arc<ModeGate>>>,
    /// Gate statistics sealed at retirement (the live gate is gone).
    sealed_stats: Mutex<Option<GateStats>>,
    live_workers: AtomicUsize,
    /// Completion counters feeding the controller's windowed SLO signal.
    completed: AtomicUsize,
    within_slo: AtomicUsize,
    /// Boot-crash message (PR 7 fault clause), if the slot crashed when
    /// it was activated.
    boot_err: Mutex<Option<String>>,
}

impl ShardSlot {
    fn new() -> Self {
        Self {
            state: AtomicU8::new(DORMANT),
            gate: Mutex::new(None),
            sealed_stats: Mutex::new(None),
            live_workers: AtomicUsize::new(0),
            completed: AtomicUsize::new(0),
            within_slo: AtomicUsize::new(0),
            boot_err: Mutex::new(None),
        }
    }

    fn state(&self) -> u8 {
        self.state.load(Ordering::Acquire)
    }
}

/// Everything the generator, workers, and controller share. Declared
/// before the `thread::scope` so hot-added workers (spawned from the
/// controller thread, inside the scope) can borrow it for `'env`.
struct ElasticCtx<'a> {
    base: &'a ServeSpec,
    backend: &'a dyn ServeBackend,
    resolved: &'a [ResolvedPayload],
    policy: AccessPolicy,
    router: &'a ShardRouter,
    queues: &'a [AdmissionQueue<Pending>],
    slots: &'a [ShardSlot],
    healths: &'a [ShardHealth],
    routed: &'a [AtomicUsize],
    credits: Option<&'a CreditBank>,
    done: &'a [Box<dyn Fn() + Sync + 'a>],
    requeue: &'a [Box<dyn Fn(Pending) -> bool + Sync + 'a>],
    outs: &'a Mutex<Vec<(usize, OpenWorkerOut)>>,
    steals: &'a AtomicUsize,
    stolen: &'a AtomicUsize,
    shed: &'a AtomicUsize,
    /// Workers per shard (every slot gets the same pool size).
    wps: usize,
    /// Tenant-class count (0 = unclassed).
    k: usize,
    timeout: Option<Duration>,
    tolerate: bool,
    slo_ms: f64,
    batch: usize,
}

impl ElasticCtx<'_> {
    fn is_active(&self, shard: usize) -> bool {
        self.slots[shard].state() == ACTIVE
    }

    fn active_shards(&self) -> Vec<usize> {
        (0..self.slots.len()).filter(|&s| self.is_active(s)).collect()
    }
}

// ---------------------------------------------------------------------
// workers
// ---------------------------------------------------------------------

/// Spawn one shard's gate + worker pool into the scope. Called at boot
/// (with the warm barrier) and by the controller at hot-add (without —
/// a hot-added shard warms up before touching its queue, but nobody
/// waits for it; the fleet keeps serving).
fn activate_shard<'scope, 'env>(
    s: &'scope std::thread::Scope<'scope, 'env>,
    ec: &'env ElasticCtx<'env>,
    shard: usize,
    warm: Option<&'env Barrier>,
) {
    let slot = &ec.slots[shard];
    // A hot-added shard is a fresh process in the paper's terms: the
    // boot-crash fault clause applies to it exactly as at t0.
    if let Some(plan) = ec.backend.fault_plan() {
        if let Err(p) = std::panic::catch_unwind(AssertUnwindSafe(|| plan.check_boot(shard))) {
            ec.healths[shard].on_panic();
            *lock_recover(&slot.boot_err) = Some(panic_msg(p));
        }
    }
    let gate = make_gate(ec.base, ec.policy).map(Arc::new);
    *lock_recover(&slot.gate) = gate.clone();
    slot.live_workers.store(ec.wps, Ordering::Release);
    // ACTIVE last: the generator may route here the instant this flips.
    slot.state.store(ACTIVE, Ordering::Release);
    for w in 0..ec.wps {
        let client = shard * ec.wps + w;
        let gate = gate.clone();
        s.spawn(move || {
            let ctx = OpenWorkerCtx {
                backend: ec.backend,
                resolved: ec.resolved,
                queue: &ec.queues[shard],
                gate: gate.as_deref(),
                batch: ec.batch,
                timeout: ec.timeout,
                share: ec.policy.sm_share(ec.wps),
                client,
                shard,
                retry: ec.base.retry,
                tolerate: ec.tolerate,
                done: Some(&*ec.done[shard]),
                health: Some(&ec.healths[shard]),
                requeue: Some(&*ec.requeue[shard]),
                credits: ec.credits,
                classes: ec.k,
            };
            let out = elastic_worker(&ctx, ec, warm);
            lock_recover(ec.outs).push((shard, out));
            ec.slots[shard].live_workers.fetch_sub(1, Ordering::Release);
        });
    }
}

/// Record a burst's newly-completed samples into the worker's shard
/// slot (the controller's windowed SLO signal).
fn publish(ec: &ElasticCtx<'_>, shard: usize, out: &OpenWorkerOut, n0: usize) {
    let newly = &out.samples[n0..];
    if newly.is_empty() {
        return;
    }
    let ok = newly.iter().filter(|(_, ms)| *ms <= ec.slo_ms).count();
    ec.slots[shard].completed.fetch_add(newly.len(), Ordering::Relaxed);
    ec.slots[shard].within_slo.fetch_add(ok, Ordering::Relaxed);
}

/// Deepest ACTIVE shard (queue length > 0) other than the thief.
/// Ejected/Probing shards are skipped: they are being drained by their
/// own workers and health probes — stealing from them would starve the
/// probe path. `state()` is a pure read (unlike `accepting()`, which
/// consumes probe slots).
fn steal_victim(ec: &ElasticCtx<'_>, thief: usize) -> Option<usize> {
    (0..ec.slots.len())
        .filter(|&x| x != thief && ec.is_active(x))
        .filter(|&x| {
            !matches!(ec.healths[x].state(), HealthState::Ejected | HealthState::Probing)
        })
        .map(|x| (ec.queues[x].len(), x))
        .filter(|&(len, _)| len > 0)
        .max_by_key(|&(len, x)| (len, usize::MAX - x))
        .map(|(_, x)| x)
}

/// Move one request's accounting from shard `from` to shard `to`
/// (steal or re-queue): per-shard offered counts and router depth
/// follow the request, so `offered == completed + ...` holds per shard
/// as well as fleet-wide.
fn move_attribution(ec: &ElasticCtx<'_>, from: usize, to: usize) {
    let _ = ec.routed[from].fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| {
        d.checked_sub(1)
    });
    ec.routed[to].fetch_add(1, Ordering::Relaxed);
    ec.router.transfer(from, to);
}

/// The elastic open-loop worker: like
/// [`open_worker`](crate::control::serving) but with a bounded wait on
/// its own queue followed by a steal attempt against the deepest other
/// shard. Stolen bursts run through this worker's own ctx, so their
/// accounting (queue delay, timeout shed, samples, credits) is
/// identical to locally-routed work.
fn elastic_worker(
    ctx: &OpenWorkerCtx<'_>,
    ec: &ElasticCtx<'_>,
    warm: Option<&Barrier>,
) -> OpenWorkerOut {
    let mut out = OpenWorkerOut::default();
    let exec = match ctx.backend.executor() {
        Ok(e) => Some(e),
        Err(e) => {
            out.error = Some(e);
            None
        }
    };
    if let Some(exec) = &exec {
        if let Some(e) = warm_up(ctx, &**exec) {
            out.error = Some(e);
        }
    }
    if let Some(w) = warm {
        w.wait();
    }
    let Some(exec) = exec.filter(|_| out.error.is_none()) else {
        drain_failed(ctx, &mut out);
        return out;
    };
    loop {
        let burst = ctx.queue.pop_batch_timeout(ctx.batch.max(1), STEAL_PATIENCE);
        if !burst.is_empty() {
            let n0 = out.samples.len();
            process_burst(ctx, &**exec, burst, &mut out);
            publish(ec, ctx.shard, &out, n0);
            continue;
        }
        if ctx.queue.is_closed() && ctx.queue.is_empty() {
            break;
        }
        // Idle past patience: steal a burst from the deepest live shard.
        let Some(victim) = steal_victim(ec, ctx.shard) else { continue };
        let stolen = ec.queues[victim].try_pop_batch(ctx.batch.max(1));
        if stolen.is_empty() {
            continue; // lost the race to the victim's own workers
        }
        for _ in &stolen {
            move_attribution(ec, victim, ctx.shard);
        }
        ec.steals.fetch_add(1, Ordering::Relaxed);
        ec.stolen.fetch_add(stolen.len(), Ordering::Relaxed);
        let n0 = out.samples.len();
        process_burst(ctx, &**exec, stolen, &mut out);
        publish(ec, ctx.shard, &out, n0);
    }
    out
}

// ---------------------------------------------------------------------
// controller
// ---------------------------------------------------------------------

/// Drain-then-retire one shard. Ordering is the §15 contract:
/// 1. state -> DRAINING (the generator stops routing here);
/// 2. close the queue (producers mid-push wake and divert);
/// 3. drain leftovers, re-queueing each onto a live shard (or shedding
///    it with full credit/depth accounting when nobody will take it);
/// 4. wait for the worker pool to exit;
/// 5. seal the gate's stats, then drop the gate — the slot's Arc is the
///    last reference, so the gate dies here, never mid-request;
/// 6. state -> RETIRED.
///
/// Returns how many leftovers were re-queued.
fn retire_shard(ec: &ElasticCtx<'_>, victim: usize) -> usize {
    let slot = &ec.slots[victim];
    slot.state.store(DRAINING, Ordering::Release);
    ec.queues[victim].close();
    let mut requeued = 0usize;
    loop {
        let leftovers = ec.queues[victim].try_pop_batch(ec.batch.max(16));
        if leftovers.is_empty() {
            // The victim's own workers drain concurrently; empty here
            // plus closed means nothing more will ever appear.
            if ec.queues[victim].is_empty() {
                break;
            }
            continue;
        }
        for p in leftovers {
            if requeue_leftover(ec, victim, p) {
                requeued += 1;
            }
        }
    }
    while slot.live_workers.load(Ordering::Acquire) > 0 {
        std::thread::sleep(Duration::from_micros(200));
    }
    let gate = lock_recover(&slot.gate).take();
    if let Some(g) = &gate {
        *lock_recover(&slot.sealed_stats) = Some(g.stats());
    }
    drop(gate);
    slot.state.store(RETIRED, Ordering::Release);
    requeued
}

/// Re-home one drained leftover onto a live shard: first a non-blocking
/// sweep over ACTIVE accepting shards (shallowest first), then one
/// blocking push against the shallowest ACTIVE shard. Returns false —
/// after accounting the request as shed, with its credit returned and
/// the victim's depth released — when no live shard would take it
/// (e.g. the whole fleet is retiring at end of run). `push_blocking`
/// consumes the request even on failure, so the shed accounting here is
/// what keeps the conservation law intact.
fn requeue_leftover(ec: &ElasticCtx<'_>, from: usize, p: Pending) -> bool {
    let class = p.class;
    let mut order: Vec<usize> =
        (0..ec.slots.len()).filter(|&x| x != from && ec.is_active(x)).collect();
    order.sort_by_key(|&x| (ec.queues[x].len(), x));
    let mut pending = Some(p);
    for &to in &order {
        if !ec.healths[to].accepting() {
            continue;
        }
        match ec.queues[to].try_push(pending.take().unwrap()) {
            Ok(()) => {
                move_attribution(ec, from, to);
                return true;
            }
            Err(back) => pending = Some(back),
        }
    }
    if let Some(&to) = order.first() {
        if ec.queues[to].push_blocking(pending.take().unwrap()) {
            move_attribution(ec, from, to);
            return true;
        }
    }
    // Nobody took it (and a failed push_blocking already dropped it):
    // account it as shed so offered == completed + shed + ... holds.
    if let Some(b) = ec.credits {
        b.put(class);
    }
    ec.shed.fetch_add(1, Ordering::Relaxed);
    let _ = ec.routed[from].fetch_update(Ordering::Relaxed, Ordering::Relaxed, |d| {
        d.checked_sub(1)
    });
    ec.router.complete(from);
    false
}

/// The fleet controller loop: every [`TICK`] it reads queue occupancy
/// and the windowed SLO attainment, scales up under pressure (hot-add
/// into the shared scope), retires the highest-numbered quiet shard
/// after [`LOW_TICKS_TO_RETIRE`] calm ticks — but never the last
/// Healthy one — and logs a saturation event when pressure persists at
/// `max` (graceful degradation: the fleet queues/sheds instead of
/// growing).
fn run_controller<'scope, 'env>(
    s: &'scope std::thread::Scope<'scope, 'env>,
    ec: &'env ElasticCtx<'env>,
    auto: AutoscaleSpec,
    events: &'env Mutex<Vec<ScaleEvent>>,
    stopping: &'env AtomicBool,
    t0: Instant,
) {
    let cap = ec.base.traffic.queue_cap;
    let mut low_ticks = 0u32;
    let mut saturated_logged = false;
    let (mut prev_done, mut prev_ok) = (0usize, 0usize);
    while !stopping.load(Ordering::Acquire) {
        std::thread::sleep(TICK);
        let active = ec.active_shards();
        if active.is_empty() {
            continue;
        }
        let lens: Vec<usize> = active.iter().map(|&x| ec.queues[x].len()).collect();
        let any_full = lens.iter().any(|&l| l >= cap);
        let occ = lens.iter().sum::<usize>() as f64 / (active.len() * cap) as f64;
        // Windowed SLO attainment: completions since the last tick,
        // summed over every slot (stolen work publishes on the thief).
        let done_now: usize =
            ec.slots.iter().map(|sl| sl.completed.load(Ordering::Relaxed)).sum();
        let ok_now: usize =
            ec.slots.iter().map(|sl| sl.within_slo.load(Ordering::Relaxed)).sum();
        let (wd, wo) = (done_now - prev_done, ok_now - prev_ok);
        (prev_done, prev_ok) = (done_now, ok_now);
        let slo_ok = wd == 0 || (wo as f64) * 100.0 >= (wd as f64) * SLO_TARGET_PCT;
        let pressure = any_full || occ >= HIGH_OCC || (!slo_ok && occ > 0.0);
        if pressure {
            low_ticks = 0;
            let next = (0..ec.slots.len()).find(|&x| ec.slots[x].state() == DORMANT);
            match next {
                Some(shard) => {
                    activate_shard(s, ec, shard, None);
                    saturated_logged = false;
                    lock_recover(events).push(ScaleEvent::Up {
                        at_ms: t0.elapsed().as_secs_f64() * 1e3,
                        shard,
                        active: active.len() + 1,
                    });
                }
                None if !saturated_logged => {
                    saturated_logged = true;
                    lock_recover(events).push(ScaleEvent::Saturated {
                        at_ms: t0.elapsed().as_secs_f64() * 1e3,
                    });
                }
                None => {}
            }
        } else if occ <= LOW_OCC && slo_ok && active.len() > auto.min {
            low_ticks += 1;
            if low_ticks >= LOW_TICKS_TO_RETIRE {
                low_ticks = 0;
                let healthy = active
                    .iter()
                    .filter(|&&x| ec.healths[x].state() == HealthState::Healthy)
                    .count();
                // Highest-numbered candidate first; skip the last
                // Healthy shard — retiring it would leave the fleet with
                // only ejected/probing capacity.
                let victim = active.iter().rev().copied().find(|&x| {
                    !(ec.healths[x].state() == HealthState::Healthy && healthy <= 1)
                });
                if let Some(v) = victim {
                    let requeued = retire_shard(ec, v);
                    saturated_logged = false;
                    lock_recover(events).push(ScaleEvent::Retire {
                        at_ms: t0.elapsed().as_secs_f64() * 1e3,
                        shard: v,
                        active: active.len() - 1,
                        requeued,
                    });
                }
            }
        } else {
            low_ticks = 0;
        }
    }
}

// ---------------------------------------------------------------------
// the elastic serve loop
// ---------------------------------------------------------------------

/// Open-loop fleet serving with runtime scaling. Reached from
/// [`serve_fleet`](crate::control::fleet::serve_fleet) when
/// `FleetSpec::autoscale` is set (validation already pinned open-loop
/// arrivals and `shards == autoscale.max`). The fleet pre-allocates
/// `max` shard slots (queue, breaker, depth counter), boots `min` of
/// them, and lets the controller thread hot-add or drain-then-retire
/// the rest while the generator paces arrivals.
pub fn serve_fleet_elastic(spec: &FleetSpec, backend: &dyn ServeBackend) -> Result<FleetReport> {
    let base = &spec.base;
    let auto = spec.autoscale.expect("serve_fleet dispatches here only with autoscale set");
    let policy = AccessPolicy::new(base.strategy);
    let tolerate = backend.fault_plan().is_some();
    let resolved: Vec<ResolvedPayload> =
        base.payloads.iter().map(|p| backend.resolve(p)).collect::<Result<_>>()?;
    let max = spec.shards; // == auto.max (validated)
    // Every slot gets the same worker-pool size; the *fleet's* pool
    // grows and shrinks with the active shard count.
    let wps = base.clients.div_ceil(max).max(1);
    let router = ShardRouter::new(max, spec.placement);
    let queues: Vec<AdmissionQueue<Pending>> =
        (0..max).map(|_| AdmissionQueue::new(base.traffic.queue_cap)).collect();
    let slots: Vec<ShardSlot> = (0..max).map(|_| ShardSlot::new()).collect();
    let healths: Vec<ShardHealth> = (0..max).map(|_| ShardHealth::new(spec.breaker)).collect();
    let routed: Vec<AtomicUsize> = (0..max).map(|_| AtomicUsize::new(0)).collect();
    let timeout = match base.traffic.shed {
        ShedPolicy::Timeout { ms } => Some(Duration::from_millis(ms)),
        _ => None,
    };
    let total = base.clients * base.requests;
    let offsets = base.traffic.arrivals.schedule_n(total, base.traffic.seed);
    let k = base.classes.len();
    let credits = (base.arbiter == ArbiterKind::Credit).then(|| {
        CreditBank::new(
            &base.classes,
            u32::try_from(base.traffic.queue_cap).unwrap_or(u32::MAX),
        )
    });
    let shed = AtomicUsize::new(0);
    let steals = AtomicUsize::new(0);
    let stolen = AtomicUsize::new(0);
    let outs: Mutex<Vec<(usize, OpenWorkerOut)>> = Mutex::new(Vec::new());
    let events: Mutex<Vec<ScaleEvent>> = Mutex::new(Vec::new());
    let stopping = AtomicBool::new(false);
    // Boot-time warm barrier: the min shards' workers plus the
    // generator. Hot-added shards warm without a barrier.
    let warm = Barrier::new(auto.min * wps + 1);
    let router_ref = &router;
    let done: Vec<Box<dyn Fn() + Sync + '_>> = (0..max)
        .map(|s| Box::new(move || router_ref.complete(s)) as Box<dyn Fn() + Sync + '_>)
        .collect();
    // Worker re-route hooks (failure path): like the fixed fleet's, but
    // only ACTIVE slots are candidates — a draining shard must not
    // receive new work, and a dormant one has no workers.
    let (queues_ref, healths_ref, routed_ref, slots_ref) = (&queues, &healths, &routed, &slots);
    let requeue: Vec<Box<dyn Fn(Pending) -> bool + Sync + '_>> = (0..max)
        .map(|from| {
            Box::new(move |p: Pending| {
                let mut order: Vec<usize> = (0..max)
                    .filter(|&x| x != from && slots_ref[x].state() == ACTIVE)
                    .collect();
                order.sort_by_key(|&x| (queues_ref[x].len(), x));
                let mut pending = Some(p);
                for to in order {
                    if !healths_ref[to].accepting() {
                        continue;
                    }
                    match queues_ref[to].try_push(pending.take().unwrap()) {
                        Ok(()) => {
                            let _ = routed_ref[from].fetch_update(
                                Ordering::Relaxed,
                                Ordering::Relaxed,
                                |d| d.checked_sub(1),
                            );
                            routed_ref[to].fetch_add(1, Ordering::Relaxed);
                            router_ref.transfer(from, to);
                            return true;
                        }
                        Err(back) => pending = Some(back),
                    }
                }
                false
            }) as Box<dyn Fn(Pending) -> bool + Sync + '_>
        })
        .collect();
    let ec = ElasticCtx {
        base,
        backend,
        resolved: &resolved,
        policy,
        router: &router,
        queues: &queues,
        slots: &slots,
        healths: &healths,
        routed: &routed,
        credits: credits.as_ref(),
        done: &done,
        requeue: &requeue,
        outs: &outs,
        steals: &steals,
        stolen: &stolen,
        shed: &shed,
        wps,
        k,
        timeout,
        tolerate,
        slo_ms: base.traffic.slo_ms,
        batch: base.batch,
    };
    let ec = &ec;

    let t0 = std::thread::scope(|s| {
        for shard in 0..auto.min {
            activate_shard(s, ec, shard, Some(&warm));
        }
        warm.wait();
        let t0 = Instant::now();
        let (events_ref, stopping_ref) = (&events, &stopping);
        let ctrl = s.spawn(move || run_controller(s, ec, auto, events_ref, stopping_ref, t0));
        for (seq, &off) in offsets.iter().enumerate() {
            let arrival_at = t0 + Duration::from_nanos(off);
            let now = Instant::now();
            if arrival_at > now {
                std::thread::sleep(arrival_at - now);
            }
            let slot = seq % resolved.len();
            let class = class_of(seq, k);
            // Credit admission before routing, as in the fixed fleet.
            let granted = match (credits.as_ref(), base.traffic.shed) {
                (None, _) => true,
                (Some(b), ShedPolicy::Block) => {
                    b.take_blocking(class);
                    true
                }
                (Some(b), ShedPolicy::Reject) => b.try_take(class),
                (Some(b), ShedPolicy::Timeout { ms }) => {
                    b.take_timeout(class, Duration::from_millis(ms))
                }
            };
            if !granted {
                shed.fetch_add(1, Ordering::Relaxed);
                continue;
            }
            // The router places over the whole slot pool (its depths
            // track the live fleet via transfer/complete); a pick that
            // lands on a dormant or draining slot diverts immediately.
            let primary = router.route(slot);
            let mut pending = Some(Pending { slot, seq, arrival_at, attempt: 0, class });
            let mut placed: Option<usize> = None;
            if ec.is_active(primary) && healths[primary].accepting() {
                match queues[primary].try_push(pending.take().unwrap()) {
                    Ok(()) => placed = Some(primary),
                    Err(back) => pending = Some(back),
                }
            }
            if placed.is_none() {
                let mut order: Vec<usize> =
                    (0..max).filter(|&x| x != primary && ec.is_active(x)).collect();
                order.sort_by_key(|&x| (queues[x].len(), x));
                for cand in order {
                    if !healths[cand].accepting() {
                        continue;
                    }
                    match queues[cand].try_push(pending.take().unwrap()) {
                        Ok(()) => {
                            placed = Some(cand);
                            break;
                        }
                        Err(back) => pending = Some(back),
                    }
                }
            }
            match placed {
                Some(sh) => {
                    routed[sh].fetch_add(1, Ordering::Relaxed);
                    if sh != primary {
                        router.transfer(primary, sh);
                    }
                }
                None => {
                    // Every live shard full (or none accepting): the
                    // shed policy decides, against the shallowest live
                    // shard — the routed-to slot must have workers, and
                    // `primary` may be dormant here.
                    let fb = (0..max)
                        .filter(|&x| ec.is_active(x))
                        .min_by_key(|&x| (queues[x].len(), x));
                    let admitted = fb.is_some_and(|fb| {
                        admit(&queues[fb], pending.take().unwrap(), base.traffic.shed)
                            .then(|| {
                                routed[fb].fetch_add(1, Ordering::Relaxed);
                                if fb != primary {
                                    router.transfer(primary, fb);
                                }
                            })
                            .is_some()
                    });
                    if !admitted {
                        // Not placed anywhere (a closed queue during a
                        // racing retirement drops a blocking push):
                        // account the arrival as shed.
                        if let Some(b) = credits.as_ref() {
                            b.put(class);
                        }
                        shed.fetch_add(1, Ordering::Relaxed);
                        router.complete(primary);
                    }
                }
            }
        }
        stopping.store(true, Ordering::Release);
        let _ = ctrl.join();
        for q in &queues {
            q.close();
        }
        t0
        // Implicit scope join: every worker drains its closed queue
        // and exits before `scope` returns.
    });
    let wall_s = t0.elapsed().as_secs_f64();

    // ------------------------------------------------------ assembly --
    let outs = std::mem::take(&mut *lock_recover(&outs));
    let mut per_shard: Vec<Vec<OpenWorkerOut>> = (0..max).map(|_| Vec::new()).collect();
    for (shard, out) in outs {
        per_shard[shard].push(out);
    }
    let events = std::mem::take(&mut *lock_recover(&events));
    let (mut cur, mut peak) = (auto.min, auto.min);
    let (mut ups, mut retires, mut requeued_total) = (0usize, 0usize, 0usize);
    for e in &events {
        match e {
            ScaleEvent::Up { .. } => {
                cur += 1;
                peak = peak.max(cur);
                ups += 1;
            }
            ScaleEvent::Retire { requeued, .. } => {
                cur = cur.saturating_sub(1);
                retires += 1;
                requeued_total += requeued;
            }
            ScaleEvent::Saturated { .. } => {}
        }
    }
    let final_active = (0..max).filter(|&x| slots[x].state() == ACTIVE).count();
    let elastic = ElasticReport {
        min: auto.min,
        max: auto.max,
        started: auto.min,
        final_active,
        peak_active: peak,
        scale_ups: ups,
        retires,
        requeued: requeued_total,
        steals: steals.load(Ordering::Relaxed),
        stolen: stolen.load(Ordering::Relaxed),
        events,
    };

    let mut shards_out = Vec::with_capacity(max);
    let mut fleet_latency = LatencyStats::new(base.exact_quantiles);
    let mut fleet_gate: Option<GateStats> = None;
    let mut fleet_traffic: Option<TrafficReport> = None;
    let mut fleet_fault = FaultReport::default();
    let mut fleet_class_samples: Vec<(usize, f64)> = Vec::new();
    let span_s = offsets.last().map(|&l| l as f64 / 1e9).unwrap_or(0.0);
    for shard in 0..max {
        if slots[shard].state() == DORMANT {
            // Never activated: an idle slot, not a shard that served.
            shards_out.push(ShardReport {
                shard,
                clients: 0,
                report: None,
                error: None,
                health: None,
            });
            continue;
        }
        let o = fold_open_outs(std::mem::take(&mut per_shard[shard]), base.traffic.slo_ms);
        let mut shard_err = lock_recover(&slots[shard].boot_err).take();
        if let Some(e) = o.error {
            if !tolerate {
                return Err(anyhow!("shard {shard}: {e}"));
            }
            shard_err.get_or_insert(e.to_string());
        }
        let (queue_delay, timed_out, within_slo) = (o.queue_delay, o.timed_out, o.within_slo);
        let completed = o.samples.len();
        let (latency, per_payload) =
            build_latency_stats(o.samples, &base.payloads, base.exact_quantiles);
        fleet_latency.merge(&latency);
        let shard_classes = build_class_reports(
            &base.classes,
            o.class_samples.clone(),
            &[],
            base.traffic.slo_ms,
            base.exact_quantiles,
        );
        fleet_class_samples.extend(o.class_samples);
        // A retired shard's stats were sealed when its gate was dropped;
        // a shard still live at shutdown reports from the gate itself.
        let gate_stats = lock_recover(&slots[shard].sealed_stats)
            .take()
            .or_else(|| lock_recover(&slots[shard].gate).as_ref().map(|g| g.stats()));
        if let Some(g) = &gate_stats {
            match &mut fleet_gate {
                Some(merged) => merged.merge(g),
                None => fleet_gate = Some(g.clone()),
            }
        }
        let mut fault = o.fault;
        if let Some(plan) = backend.fault_plan() {
            fault.injected.merge(&plan.counts_for(shard));
        }
        if let Some(g) = &gate_stats {
            fault.revocations += g.revocations;
        }
        let health = healths[shard].snapshot();
        fault.ejections += health.ejections;
        fault.reinstatements += health.reinstatements;
        for ms in healths[shard].drain_recoveries_ms() {
            fault.recover_ms.record(ms);
        }
        fleet_fault.merge(&fault);
        let shard_offered = routed[shard].load(Ordering::Relaxed);
        let shard_traffic = TrafficReport {
            arrivals: base.traffic.arrivals,
            queue_cap: base.traffic.queue_cap,
            shed_policy: base.traffic.shed,
            slo_ms: base.traffic.slo_ms,
            offered: shard_offered,
            completed,
            shed: 0,
            timed_out,
            failed: o.failed,
            retried: fault.retried,
            within_slo,
            queue_delay,
            offered_rate_hz: if span_s > 0.0 { shard_offered as f64 / span_s } else { 0.0 },
        };
        match &mut fleet_traffic {
            Some(merged) => merged.merge(&shard_traffic),
            None => fleet_traffic = Some(shard_traffic.clone()),
        }
        shards_out.push(ShardReport {
            shard,
            clients: wps,
            report: Some(ServeReport {
                strategy: base.strategy,
                concurrency: base.concurrency,
                clients: wps,
                requests_per_client: base.requests,
                batch: base.batch,
                wall_s,
                latency,
                per_payload,
                classes: shard_classes,
                gate: gate_stats,
                credits: None,
                traffic: Some(shard_traffic),
                fault: (tolerate || !fault.is_empty()).then_some(fault),
            }),
            error: shard_err,
            health: Some(health),
        });
    }
    if let Some(t) = &mut fleet_traffic {
        t.offered = total;
        t.shed = shed.load(Ordering::Relaxed);
        t.offered_rate_hz = offered_rate_hz(&offsets);
    }
    fleet_latency.seal();
    let mut fleet_offered_by_class = vec![0usize; k];
    if k > 0 {
        for seq in 0..total {
            fleet_offered_by_class[class_of(seq, k)] += 1;
        }
    }
    let fleet_classes = build_class_reports(
        &base.classes,
        fleet_class_samples,
        &fleet_offered_by_class,
        base.traffic.slo_ms,
        base.exact_quantiles,
    );
    let fleet_fault = (tolerate || !fleet_fault.is_empty()).then_some(fleet_fault);
    Ok(FleetReport {
        strategy: base.strategy,
        concurrency: base.concurrency,
        placement: spec.placement,
        clients: base.clients,
        requests_per_client: base.requests,
        batch: base.batch,
        wall_s,
        latency: fleet_latency,
        shards: shards_out,
        classes: fleet_classes,
        gate: fleet_gate,
        credits: credits.map(|b| b.snapshot()),
        traffic: fleet_traffic,
        fault: fleet_fault,
        elastic: Some(elastic),
    })
}

// ---------------------------------------------------------------------
// tests
// ---------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StrategyKind;
    use crate::control::fleet::{serve_fleet, Placement};
    use crate::control::serving::SyntheticBackend;
    use crate::control::traffic::{ArrivalProcess, TrafficSpec};

    // ------------------------------------------------------- spec --

    #[test]
    fn autoscale_parse_roundtrip() {
        let a: AutoscaleSpec = "1..4".parse().unwrap();
        assert_eq!(a, AutoscaleSpec { min: 1, max: 4 });
        assert_eq!(a.to_string().parse::<AutoscaleSpec>().unwrap(), a);
        let b: AutoscaleSpec = " 2 .. 2 ".trim().parse().unwrap();
        assert_eq!(b, AutoscaleSpec { min: 2, max: 2 });
    }

    #[test]
    fn autoscale_rejects_malformed_and_inverted_bounds() {
        for bad in ["", "3", "x..y", "4..1", "0..2", "..", "1.."] {
            assert!(bad.parse::<AutoscaleSpec>().is_err(), "{bad:?} should not parse");
        }
    }

    // ----------------------------------------------- sim mirror --

    #[test]
    fn plan_windows_stays_within_bounds_and_tracks_demand() {
        let counts = [0, 0, 10, 50, 100, 100, 40, 5, 0, 0];
        let plan = plan_windows(&counts, 1, 4);
        assert_eq!(plan.len(), counts.len());
        assert!(plan.iter().all(|&a| (1..=4).contains(&a)), "{plan:?}");
        assert_eq!(plan[0], 1, "starts at min");
        assert_eq!(plan[4], 4, "peaks at max under peak demand");
    }

    #[test]
    fn plan_windows_scales_up_immediately_but_down_with_hysteresis() {
        let counts = [0, 100, 0, 0, 0];
        let plan = plan_windows(&counts, 1, 4);
        assert_eq!(plan[1], 4, "scale-up reacts in the same window");
        // One low window is not enough to shrink...
        assert_eq!(plan[2], 4, "hysteresis holds the first low window");
        // ...two consecutive low windows are.
        assert_eq!(plan[3], 1, "second low window retires");
    }

    #[test]
    fn plan_windows_degenerate_ranges() {
        assert_eq!(plan_windows(&[], 1, 4), Vec::<usize>::new());
        assert_eq!(plan_windows(&[7, 7, 7], 2, 2), vec![2, 2, 2]);
        // Flat demand maps to min (span clamps to 1, offsets are zero).
        assert_eq!(plan_windows(&[5, 5, 5], 1, 4), vec![1, 1, 1]);
    }

    // -------------------------------------------------- report --

    #[test]
    fn render_names_both_transitions_even_with_zero_events() {
        let r = ElasticReport {
            min: 1,
            max: 4,
            started: 1,
            final_active: 1,
            peak_active: 1,
            scale_ups: 0,
            retires: 0,
            requeued: 0,
            steals: 0,
            stolen: 0,
            events: Vec::new(),
        };
        let s = r.render();
        assert!(s.contains("scale-up"), "{s}");
        assert!(s.contains("drain-then-retire"), "{s}");
    }

    #[test]
    fn render_lists_events_in_order() {
        let r = ElasticReport {
            min: 1,
            max: 2,
            started: 1,
            final_active: 1,
            peak_active: 2,
            scale_ups: 1,
            retires: 1,
            requeued: 3,
            steals: 0,
            stolen: 0,
            events: vec![
                ScaleEvent::Up { at_ms: 1.0, shard: 1, active: 2 },
                ScaleEvent::Saturated { at_ms: 2.0 },
                ScaleEvent::Retire { at_ms: 9.0, shard: 1, active: 1, requeued: 3 },
            ],
        };
        let s = r.render();
        let up = s.find("shard 1 spawned").expect("up line");
        let sat = s.find("saturated at max").expect("saturated line");
        let down = s.find("shard 1 drained").expect("retire line");
        assert!(up < sat && sat < down, "{s}");
    }

    // ------------------------------------------------ end to end --

    fn open_spec(rate_hz: f64, seed: u64) -> ServeSpec {
        ServeSpec::new(StrategyKind::Worker, "dna")
            .with_clients(4)
            .with_requests(25)
            .with_traffic(TrafficSpec {
                arrivals: ArrivalProcess::Poisson { rate_hz },
                queue_cap: 8,
                shed: ShedPolicy::Block,
                slo_ms: 1e9,
                seed,
            })
    }

    #[test]
    fn elastic_fleet_conserves_and_reports() {
        let spec = FleetSpec::new(open_spec(4_000.0, 7), 4, Placement::RoundRobin)
            .with_autoscale("1..4".parse().unwrap());
        let r = serve_fleet(&spec, &SyntheticBackend::new(40)).unwrap();
        let t = r.traffic.as_ref().expect("open loop emits traffic");
        assert!(
            t.accounted(),
            "conservation violated: offered {} completed {} shed {} timed_out {} failed {}",
            t.offered,
            t.completed,
            t.shed,
            t.timed_out,
            t.failed
        );
        let e = r.elastic.as_ref().expect("elastic report present");
        assert_eq!((e.min, e.max, e.started), (1, 4, 1));
        assert!(e.final_active >= 1 && e.peak_active <= 4);
        let s = r.render();
        assert!(s.contains("scale-up") && s.contains("drain-then-retire"), "{s}");
    }

    #[test]
    fn pinned_fleet_min_equals_max_never_scales() {
        let spec = FleetSpec::new(open_spec(2_000.0, 3), 2, Placement::RoundRobin)
            .with_autoscale("2..2".parse().unwrap());
        let r = serve_fleet(&spec, &SyntheticBackend::new(40)).unwrap();
        let e = r.elastic.as_ref().expect("elastic report present");
        assert_eq!(e.scale_ups, 0, "no dormant slot to add");
        assert_eq!(e.retires, 0, "min == max cannot retire");
        assert_eq!(e.final_active, 2);
        assert!(r.traffic.as_ref().unwrap().accounted());
    }

    #[test]
    fn autoscale_requires_open_loop_and_matching_slot_pool() {
        let closed = ServeSpec::new(StrategyKind::Worker, "dna").with_clients(2).with_requests(2);
        let spec = FleetSpec::new(closed, 4, Placement::RoundRobin)
            .with_autoscale("1..4".parse().unwrap());
        let err = serve_fleet(&spec, &SyntheticBackend::new(20)).unwrap_err().to_string();
        assert!(err.contains("open-loop"), "{err}");

        let spec = FleetSpec::new(open_spec(1_000.0, 1), 3, Placement::RoundRobin)
            .with_autoscale("1..4".parse().unwrap());
        let err = serve_fleet(&spec, &SyntheticBackend::new(20)).unwrap_err().to_string();
        assert!(err.contains("slot pool"), "{err}");
    }
}
