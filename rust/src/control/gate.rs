//! The live GPU gate: a FIFO-fair, instrumented replacement for the bare
//! `Mutex<()>` the first serving path used as its "GPU lock".
//!
//! A plain mutex has two problems for serving:
//! * no fairness — an OS mutex may hand the lock back to the releasing
//!   thread repeatedly (convoy/barging), starving other clients, which is
//!   exactly the behaviour the paper's semaphore-based `GPU_LOCK` (§V-B)
//!   avoids for application threads;
//! * no observability — wait and hold times, the paper's lock-occupancy
//!   metrics, are invisible.
//!
//! `GpuGate` grants strictly in arrival (ticket) order and records every
//! grant's wait time and hold time into [`crate::metrics::stats::Histogram`]s,
//! so a serving run can report admission latency separately from payload
//! execution time.
//!
//! Unlike a `MutexGuard`, acquisition is *not* tied to a stack frame:
//! [`GpuGate::acquire`] returns a [`GateGrant`] token that may be carried
//! across closures and threads. The callback strategy needs exactly that
//! shape — its acquire and release run as separate deferred closures in
//! stream order (Alg. 3).

use crate::metrics::stats::Histogram;
// The gate's protected state is a pair of monotonic counters (or a
// histogram) — valid after any panic — so a client that panicked while
// holding a mutex must not leave the FIFO wedged behind a poisoned lock:
// every lock site recovers via `lock_recover`.
use crate::util::{lock_recover, Nanos};
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

#[derive(Debug)]
struct GateState {
    /// Next ticket to hand out.
    next_ticket: u64,
    /// Ticket currently allowed through.
    now_serving: u64,
    /// The admitted ticket and its grant time, while someone holds the
    /// gate. `None` between handoffs — and after a lease revocation,
    /// which is how a revoked grant's Drop knows not to advance
    /// `now_serving` a second time.
    holder: Option<(u64, Instant)>,
    /// Parked waiters in ticket order (front = next to admit), each with
    /// its own condvar. Release wakes exactly the front waiter — one
    /// futex wake per grant — instead of `notify_all` on one shared
    /// condvar stampeding all N waiters awake so N−1 immediately
    /// re-sleep (the thundering herd the single-condvar design paid on
    /// every handoff). A ticket holder is either being served or has an
    /// entry here: the ticket take and the park happen under one lock
    /// acquisition, so the front entry is always the lowest outstanding
    /// ticket and FIFO grant order is unchanged.
    waiters: VecDeque<(u64, Arc<Condvar>)>,
}

/// Wait/hold statistics of one gate, in nanoseconds.
#[derive(Debug, Clone, Default)]
pub struct GateStats {
    /// Time from arrival to grant, per grant.
    pub wait: Histogram,
    /// Time from grant to release, per grant.
    pub hold: Histogram,
    /// Grants the lease watchdog revoked from an overstaying holder.
    pub revocations: u64,
    /// How far past its lease each revoked holder was when cut off.
    pub revoke_lag: Histogram,
}

impl GateStats {
    pub fn grants(&self) -> u64 {
        self.hold.count()
    }

    /// Fold another gate's statistics into this one (fleet aggregation).
    pub fn merge(&mut self, other: &GateStats) {
        self.wait.merge(&other.wait);
        self.hold.merge(&other.hold);
        self.revocations += other.revocations;
        self.revoke_lag.merge(&other.revoke_lag);
    }

    /// Two-line human rendering (serving reports); a third line appears
    /// only when the watchdog actually revoked something.
    pub fn render(&self) -> String {
        let mut out = format!(
            "gate wait: {}\ngate hold: {}",
            self.wait.render_ms(),
            self.hold.render_ms()
        );
        if self.revocations > 0 {
            out.push_str(&format!(
                "\ngate revocations: {} (overstay {})",
                self.revocations,
                self.revoke_lag.render_ms()
            ));
        }
        out
    }
}

/// Proof of admission. Releasing happens on drop (recording the hold
/// time and waking the next ticket), so a panic while the grant is held
/// unwinds into a clean FIFO handoff instead of wedging every other
/// client; [`GpuGate::release`] is the explicit form. `#[must_use]`
/// because an unbound grant releases immediately.
#[must_use = "an unbound GateGrant releases immediately; hold it for the critical section"]
#[derive(Debug)]
pub struct GateGrant<'a> {
    gate: &'a GpuGate,
    granted_at: Instant,
    ticket: u64,
}

impl GateGrant<'_> {
    /// Did the lease watchdog revoke this grant out from under us? A
    /// revoked holder has already lost the gate — the FIFO moved on — so
    /// its results must be treated as suspect (the serving layer counts
    /// the request failed and lets the health breaker see it).
    pub fn is_revoked(&self) -> bool {
        let st = lock_recover(&self.gate.state);
        !matches!(st.holder, Some((t, _)) if t == self.ticket)
    }
}

impl Drop for GateGrant<'_> {
    fn drop(&mut self) {
        let held = self.granted_at.elapsed();
        // Regression (ISSUE 4): this used `if let Ok(..) = lock()`, which
        // silently skipped the `now_serving` bump whenever the state mutex
        // was poisoned — wedging every queued waiter forever. The state is
        // a pair of counters, always valid, so recover the guard instead.
        // (`lock_recover` never panics, which also keeps this Drop safe
        // during unwinding.)
        lock_recover(&self.gate.stats)
            .hold
            .record(held.as_nanos().min(u64::MAX as u128) as Nanos);
        let next = {
            let mut st = lock_recover(&self.gate.state);
            match st.holder {
                // Normal release: we still hold the gate. Clear the
                // holder, advance, and wake the next ticket.
                Some((t, _)) if t == self.ticket => {
                    st.holder = None;
                    st.now_serving += 1;
                    // Wake ONLY the next ticket holder (the queue front;
                    // lower tickets are impossible — see
                    // `GateState::waiters`). Waking outside the critical
                    // section avoids the hurry-up-and-wait pattern where
                    // the woken thread immediately blocks on the mutex the
                    // waker still holds. No lost wakeup either way:
                    // `now_serving` was published under the lock, and the
                    // waiter re-checks it under the same lock around every
                    // wait.
                    st.waiters.front().map(|(_, cv)| Arc::clone(cv))
                }
                // The watchdog revoked us while we overstayed: the FIFO
                // already advanced past our ticket (possibly several
                // grants ago). Touch nothing.
                _ => None,
            }
        };
        if let Some(cv) = next {
            cv.notify_one();
        }
    }
}

/// FIFO-fair gate serialising GPU access across serving threads.
///
/// One gate = one GPU's admission queue: the live counterpart of the
/// paper's `GPU_LOCK`. A serving fleet holds one per shard (see
/// [`crate::control::fleet`]) so isolation is enforced per device.
///
/// # Example
///
/// ```
/// use cook::control::gate::GpuGate;
///
/// let gate = GpuGate::new();
/// // Scoped critical section (the synced strategy's shape)...
/// let answer = gate.with(|| 42);
/// assert_eq!(answer, 42);
/// // ...or a grant carried across scopes (the callback strategy).
/// let grant = gate.acquire();
/// gate.release(grant);
/// assert_eq!(gate.stats().grants(), 2);
/// ```
#[derive(Debug)]
pub struct GpuGate {
    state: Mutex<GateState>,
    stats: Mutex<GateStats>,
    /// Maximum hold time before parked waiters may revoke the grant.
    /// `None` (the default) disables the watchdog entirely.
    lease: Option<Duration>,
}

impl GpuGate {
    pub fn new() -> Self {
        Self {
            state: Mutex::new(GateState {
                next_ticket: 0,
                now_serving: 0,
                holder: None,
                waiters: VecDeque::new(),
            }),
            stats: Mutex::new(GateStats::default()),
            lease: None,
        }
    }

    /// A gate whose grants carry a lease: a holder exceeding `lease` is
    /// revoked by the waiters it is blocking (see [`GpuGate::acquire`]).
    pub fn with_lease(lease: Duration) -> Self {
        Self { lease: Some(lease), ..Self::new() }
    }

    /// The configured lease, if any.
    pub fn lease(&self) -> Option<Duration> {
        self.lease
    }

    /// Block until admitted (strict arrival order), recording the wait.
    ///
    /// # The waiter-driven lease watchdog
    ///
    /// When the gate has a lease, parked waiters double as the watchdog:
    /// instead of sleeping unconditionally, each waiter wakes at the
    /// holder's lease deadline and — under the state lock — checks
    /// whether the holder overstayed. If so it revokes the grant: clears
    /// the holder, force-advances `now_serving`, records the revocation
    /// (and how far past the lease the holder was), and wakes the new
    /// front ticket. The revoked grant's own Drop sees the holder
    /// mismatch and touches nothing, so the FIFO never double-advances.
    /// No background thread exists to babysit an idle gate — which is
    /// exactly right: a hung holder with no waiters is blocking no one.
    pub fn acquire(&self) -> GateGrant<'_> {
        let arrived = Instant::now();
        let mut st = lock_recover(&self.state);
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        if st.now_serving != ticket {
            // Park on a private condvar, registered in the same critical
            // section that took the ticket (so a releasing grant always
            // finds the next ticket holder at the queue front).
            let cv = Arc::new(Condvar::new());
            st.waiters.push_back((ticket, Arc::clone(&cv)));
            while st.now_serving != ticket {
                let Some(lease) = self.lease else {
                    st = cv.wait(st).unwrap_or_else(PoisonError::into_inner);
                    continue;
                };
                match st.holder {
                    Some((held, since)) if since.elapsed() >= lease => {
                        // Revoke the overstaying holder.
                        debug_assert_eq!(held, st.now_serving, "holder is always now_serving");
                        st.holder = None;
                        st.now_serving += 1;
                        let lag = since.elapsed().saturating_sub(lease);
                        {
                            let mut stats = lock_recover(&self.stats);
                            stats.revocations += 1;
                            stats
                                .revoke_lag
                                .record(lag.as_nanos().min(u64::MAX as u128) as Nanos);
                        }
                        // The revoker need not be the front ticket: hand
                        // the gate to whoever is (unless it is us — the
                        // loop condition takes care of that case).
                        if st.now_serving != ticket {
                            if let Some((_, front)) = st.waiters.front() {
                                let front = Arc::clone(front);
                                front.notify_one();
                            }
                        }
                    }
                    Some((_, since)) => {
                        // Sleep until this holder's lease deadline (a
                        // release wakes the front sooner).
                        let remaining = lease
                            .saturating_sub(since.elapsed())
                            .max(Duration::from_micros(100));
                        let (g, _) = cv
                            .wait_timeout(st, remaining)
                            .unwrap_or_else(PoisonError::into_inner);
                        st = g;
                    }
                    None => {
                        // Between handoffs: the next admission sets the
                        // holder; re-check at lease granularity in case
                        // that wakeup is lost to a race.
                        let (g, _) = cv
                            .wait_timeout(st, lease)
                            .unwrap_or_else(PoisonError::into_inner);
                        st = g;
                    }
                }
            }
            // Admitted: retire our queue entry (at the front, by FIFO;
            // scan defensively anyway — it is 0 or 1 positions deep).
            if let Some(pos) = st.waiters.iter().position(|(t, _)| *t == ticket) {
                st.waiters.remove(pos);
            }
        }
        let granted_at = Instant::now();
        st.holder = Some((ticket, granted_at));
        drop(st);
        let waited = arrived.elapsed();
        lock_recover(&self.stats)
            .wait
            .record(waited.as_nanos().min(u64::MAX as u128) as Nanos);
        GateGrant { gate: self, granted_at, ticket }
    }

    /// Release an admission, recording the hold time and waking the next
    /// ticket in line (explicit form of dropping the grant).
    pub fn release(&self, grant: GateGrant<'_>) {
        debug_assert!(std::ptr::eq(self, grant.gate), "grant from another gate");
        drop(grant);
    }

    /// Run `f` under the gate (the synced strategy's shape).
    pub fn with<T>(&self, f: impl FnOnce() -> T) -> T {
        let grant = self.acquire();
        let out = f();
        self.release(grant);
        out
    }

    /// Snapshot of the wait/hold statistics so far.
    pub fn stats(&self) -> GateStats {
        lock_recover(&self.stats).clone()
    }
}

impl Default for GpuGate {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn serialises_critical_sections() {
        let gate = Arc::new(GpuGate::new());
        let inside = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let gate = Arc::clone(&gate);
            let inside = Arc::clone(&inside);
            let peak = Arc::clone(&peak);
            handles.push(std::thread::spawn(move || {
                for _ in 0..25 {
                    gate.with(|| {
                        let now = inside.fetch_add(1, Ordering::SeqCst) + 1;
                        peak.fetch_max(now, Ordering::SeqCst);
                        std::thread::sleep(std::time::Duration::from_micros(20));
                        inside.fetch_sub(1, Ordering::SeqCst);
                    });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(peak.load(Ordering::SeqCst), 1, "gate admitted two at once");
        let stats = gate.stats();
        assert_eq!(stats.grants(), 100);
        assert_eq!(stats.wait.count(), 100);
    }

    #[test]
    fn fifo_order_of_queued_waiters() {
        // Hold the gate, queue three waiters, then release and check they
        // are admitted in arrival order.
        let gate = Arc::new(GpuGate::new());
        let order = Arc::new(Mutex::new(Vec::new()));
        let first = gate.acquire();
        let mut handles = Vec::new();
        for i in 0..3 {
            let gate = Arc::clone(&gate);
            let order = Arc::clone(&order);
            handles.push(std::thread::spawn(move || {
                let g = gate.acquire();
                order.lock().unwrap().push(i);
                gate.release(g);
            }));
            // Let the waiter reach the queue before spawning the next so
            // arrival order is deterministic.
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        gate.release(first);
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2]);
    }

    #[test]
    fn grant_can_cross_threads() {
        // The callback strategy's deferred acquire/release: the grant is
        // taken on one thread and released on another.
        let gate = GpuGate::new();
        let grant = gate.acquire();
        std::thread::scope(|s| {
            s.spawn(|| gate.release(grant));
        });
        // Gate must be free again.
        let g = gate.acquire();
        gate.release(g);
        assert_eq!(gate.stats().grants(), 2);
    }

    #[test]
    fn panic_while_holding_grant_does_not_wedge_the_gate() {
        // Regression: the grant releases on drop during unwinding, so a
        // client panicking mid-critical-section hands the FIFO to the
        // next waiter instead of hanging it (the old bare Mutex<()> path
        // poisoned; a non-RAII grant would deadlock).
        let gate = GpuGate::new();
        let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _grant = gate.acquire();
            panic!("payload blew up");
        }));
        assert!(panicked.is_err());
        // Must be acquirable again without blocking.
        gate.with(|| ());
        assert_eq!(gate.stats().grants(), 2);
    }

    #[test]
    fn poisoned_state_mutex_does_not_wedge_waiters() {
        // Regression (ISSUE 4): GateGrant::Drop used to skip the
        // `now_serving` bump when the state mutex was poisoned, wedging
        // every queued waiter forever. Poison the mutex deliberately and
        // check the FIFO still hands off.
        let gate = Arc::new(GpuGate::new());
        {
            let gate = Arc::clone(&gate);
            let _ = std::thread::spawn(move || {
                let _guard = gate.state.lock().unwrap();
                panic!("poison the state mutex");
            })
            .join();
        }
        assert!(gate.state.is_poisoned(), "setup must actually poison");
        // Acquire/release must still progress the ticket counter...
        gate.with(|| ());
        // ...and a queued waiter must still be woken by a release.
        let first = gate.acquire();
        let waiter = {
            let gate = Arc::clone(&gate);
            std::thread::spawn(move || gate.with(|| 7))
        };
        std::thread::sleep(std::time::Duration::from_millis(30));
        gate.release(first);
        assert_eq!(waiter.join().unwrap(), 7);
        assert_eq!(gate.stats().grants(), 3);
    }

    #[test]
    fn single_wakeup_preserves_grant_order_and_histograms() {
        // ISSUE 6 satellite: release wakes only the next ticket holder
        // (per-waiter condvars) instead of notify_all. Under sustained
        // contention the observable contract must be exactly what the
        // herd version produced: strict FIFO grant order, and wait/hold
        // histograms recording one entry per grant.
        let gate = Arc::new(GpuGate::new());
        let order = Arc::new(Mutex::new(Vec::new()));
        let first = gate.acquire();
        let mut handles = Vec::new();
        for i in 0..8 {
            let gate = Arc::clone(&gate);
            let order = Arc::clone(&order);
            handles.push(std::thread::spawn(move || {
                let g = gate.acquire();
                order.lock().unwrap().push(i);
                // Hold briefly so later tickets genuinely queue behind us.
                std::thread::sleep(std::time::Duration::from_micros(50));
                gate.release(g);
            }));
            // Serialise arrival so ticket order == spawn order.
            std::thread::sleep(std::time::Duration::from_millis(15));
        }
        // All 8 queued behind the held grant: the deepest herd window.
        assert_eq!(lock_recover(&gate.state).waiters.len(), 8);
        gate.release(first);
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*order.lock().unwrap(), (0..8).collect::<Vec<_>>());
        let stats = gate.stats();
        assert_eq!(stats.grants(), 9, "one hold record per grant");
        assert_eq!(stats.wait.count(), 9, "one wait record per grant");
        // The queue fully drained: no waiter entry leaks past its grant.
        assert!(lock_recover(&gate.state).waiters.is_empty());
    }

    #[test]
    fn with_returns_value_and_records() {
        let gate = GpuGate::new();
        let v = gate.with(|| 41 + 1);
        assert_eq!(v, 42);
        let s = gate.stats();
        assert_eq!(s.grants(), 1);
        assert!(s.render().contains("gate wait"));
        assert!(
            !s.render().contains("revocations"),
            "no revocation line without revocations"
        );
    }

    #[test]
    fn hung_holder_is_revoked_by_a_waiter() {
        // ISSUE 7 tentpole: a holder exceeding its lease must cost one
        // lease period, not the fleet. The waiter doubles as watchdog.
        let gate = Arc::new(GpuGate::with_lease(std::time::Duration::from_millis(20)));
        let hung = gate.acquire();
        let waiter = {
            let gate = Arc::clone(&gate);
            std::thread::spawn(move || gate.with(|| 7))
        };
        // The waiter revokes the hung grant and proceeds on its own —
        // nobody ever releases `hung` for it.
        assert_eq!(waiter.join().unwrap(), 7);
        assert!(hung.is_revoked());
        let s = gate.stats();
        assert_eq!(s.revocations, 1);
        assert_eq!(s.revoke_lag.count(), 1);
        assert!(s.render().contains("gate revocations: 1"), "{}", s.render());
        // The revoked grant's Drop must NOT advance the FIFO again: the
        // gate still works, and grants line up (hung + waiter + this).
        drop(hung);
        gate.with(|| ());
        assert_eq!(gate.stats().grants(), 3);
        assert_eq!(gate.stats().revocations, 1);
    }

    #[test]
    fn revocation_hands_off_in_fifo_order_with_multiple_waiters() {
        let gate = Arc::new(GpuGate::with_lease(std::time::Duration::from_millis(20)));
        let order = Arc::new(Mutex::new(Vec::new()));
        let hung = gate.acquire();
        let mut handles = Vec::new();
        for i in 0..3 {
            let gate = Arc::clone(&gate);
            let order = Arc::clone(&order);
            handles.push(std::thread::spawn(move || {
                let g = gate.acquire();
                order.lock().unwrap().push(i);
                assert!(!g.is_revoked(), "a fresh grant is not revoked");
                gate.release(g);
            }));
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Every waiter got through (exactly one revocation was needed)
        // and strict ticket order survived the force-advance.
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2]);
        assert_eq!(gate.stats().revocations, 1);
        drop(hung);
        assert_eq!(gate.stats().grants(), 4, "revoked holder still records its hold");
    }

    #[test]
    fn well_behaved_holders_are_never_revoked() {
        let gate = Arc::new(GpuGate::with_lease(std::time::Duration::from_millis(250)));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let gate = Arc::clone(&gate);
            handles.push(std::thread::spawn(move || {
                for _ in 0..10 {
                    gate.with(|| std::thread::sleep(std::time::Duration::from_micros(200)));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = gate.stats();
        assert_eq!(s.revocations, 0);
        assert_eq!(s.grants(), 40);
    }

    #[test]
    fn stats_merge_sums_everything() {
        let a = GpuGate::new();
        a.with(|| ());
        let mut sa = a.stats();
        let b = GpuGate::new();
        b.with(|| ());
        b.with(|| ());
        let mut sb = b.stats();
        sb.revocations = 2;
        sa.merge(&sb);
        assert_eq!(sa.grants(), 3);
        assert_eq!(sa.wait.count(), 3);
        assert_eq!(sa.revocations, 2);
    }
}
