//! The live GPU gate: a fair, instrumented replacement for the bare
//! `Mutex<()>` the first serving path used as its "GPU lock".
//!
//! A plain mutex has two problems for serving:
//! * no fairness — an OS mutex may hand the lock back to the releasing
//!   thread repeatedly (convoy/barging), starving other clients, which is
//!   exactly the behaviour the paper's semaphore-based `GPU_LOCK` (§V-B)
//!   avoids for application threads;
//! * no observability — wait and hold times, the paper's lock-occupancy
//!   metrics, are invisible.
//!
//! The *grant order* is delegated to an [`Arbiter`]
//! (see [`crate::control::arbiter`]): FIFO by default — strictly in
//! arrival (ticket) order, bit-identical to the pre-arbiter gate — or
//! weighted round-robin / credit-based / earliest-deadline-first for
//! multi-tenant serving. Every grant's wait and hold time is recorded
//! into [`crate::metrics::stats::Histogram`]s, so a serving run can
//! report admission latency separately from payload execution time.
//!
//! Unlike a `MutexGuard`, acquisition is *not* tied to a stack frame:
//! [`GpuGate::acquire`] returns a [`GateGrant`] token that may be carried
//! across closures and threads. The callback strategy needs exactly that
//! shape — its acquire and release run as separate deferred closures in
//! stream order (Alg. 3).

use crate::control::arbiter::{make_arbiter, Arbiter, ArbiterKind, TenantClass, Waiter};
use crate::metrics::stats::Histogram;
// The gate's protected state is a pair of monotonic counters (or a
// histogram) — valid after any panic — so a client that panicked while
// holding a mutex must not leave the queue wedged behind a poisoned
// lock: every lock site recovers via `lock_recover`.
use crate::util::{lock_recover, Nanos};
use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::time::{Duration, Instant};

/// One parked waiter: its ticket, arbitration metadata, and the private
/// condvar a handoff wakes it through.
#[derive(Debug)]
struct WaitEntry {
    ticket: u64,
    class: usize,
    deadline_ns: Option<u64>,
    cv: Arc<Condvar>,
}

#[derive(Debug)]
struct GateState {
    /// Next ticket to hand out.
    next_ticket: u64,
    /// The ticket the arbiter picked to run next: set when a release (or
    /// revocation) hands the gate off, consumed when that waiter admits
    /// itself. `None` while the gate is full or idle. At most one baton
    /// is in flight even for a multi-holder gate: the admitting waiter
    /// chain-issues the next one while spare capacity remains.
    baton: Option<u64>,
    /// Admitted tickets and their grant times, in admission order. At
    /// most [`GateState::capacity`] entries. A revoked ticket is removed
    /// here at revocation, which is how a revoked grant's Drop knows not
    /// to hand off a second time.
    holders: Vec<(u64, Instant)>,
    /// Concurrent-holder bound. 1 = the pre-refactor exclusive gate
    /// (cook mode); the [`crate::control::concurrency::ConcurrencyMode`]
    /// picks larger values for mps/streams.
    capacity: usize,
    /// Parked waiters in ticket order, each with its own condvar. A
    /// release wakes exactly the waiter the arbiter picked — one futex
    /// wake per grant — instead of `notify_all` on one shared condvar
    /// stampeding all N waiters awake so N−1 immediately re-sleep (the
    /// thundering herd the single-condvar design paid on every handoff).
    /// A ticket holder is either being served, baton-in-hand, or has an
    /// entry here: the ticket take and the park happen under one lock
    /// acquisition, so the deque is always in arrival order — exactly
    /// the FIFO-ordered snapshot [`Arbiter::pick`] is specified over.
    waiters: VecDeque<WaitEntry>,
    /// The grant-ordering policy (FIFO unless configured otherwise).
    arbiter: Box<dyn Arbiter>,
}

/// Pick the next grantee among the parked waiters (arbiter order), hand
/// it the baton, and return its condvar for the wake-up. `None` when
/// nobody waits. The caller must have freed a holder slot first.
fn issue_baton(st: &mut GateState) -> Option<Arc<Condvar>> {
    debug_assert!(st.holders.len() < st.capacity, "baton issued while full");
    debug_assert!(st.baton.is_none(), "baton issued twice");
    if st.waiters.is_empty() {
        return None;
    }
    // FIFO-order policies (and a lone waiter) skip the snapshot: the
    // release hot path stays allocation-free in the default config.
    let idx = if st.arbiter.kind().is_fifo_order() || st.waiters.len() == 1 {
        0
    } else {
        let snap: Vec<Waiter> = st
            .waiters
            .iter()
            .map(|e| Waiter { ticket: e.ticket, class: e.class, deadline_ns: e.deadline_ns })
            .collect();
        st.arbiter.pick(&snap).min(snap.len() - 1)
    };
    let e = &st.waiters[idx];
    st.baton = Some(e.ticket);
    let cv = Arc::clone(&e.cv);
    let class = e.class;
    st.arbiter.on_grant(class);
    Some(cv)
}

/// Wait/hold statistics of one gate, in nanoseconds.
#[derive(Debug, Clone, Default)]
pub struct GateStats {
    /// Time from arrival to grant, per grant.
    pub wait: Histogram,
    /// Time from grant to release, per grant. A revoked grant's hold is
    /// recorded at revocation time (when it lost the gate), never again
    /// at its eventual Drop — exactly one entry per grant.
    pub hold: Histogram,
    /// Grants the lease watchdog revoked from an overstaying holder.
    pub revocations: u64,
    /// How far past its lease each revoked holder was when cut off.
    pub revoke_lag: Histogram,
    /// Grants issued per tenant class (index = class). Single-class
    /// gates keep this at length <= 1 and reports omit it.
    pub by_class: Vec<u64>,
    /// The concurrency-mode label this gate admits under ("cook",
    /// "mps:2", ...). Stamped by [`crate::control::concurrency::ModeGate`];
    /// empty on a bare gate, and the render omits the line then.
    pub mode: String,
    /// Concurrent holders at snapshot time (summed across partitions by
    /// merge) — the multi-holder debuggability counter (ISSUE 9).
    pub holders_now: u64,
}

impl GateStats {
    pub fn grants(&self) -> u64 {
        self.hold.count()
    }

    /// Fold another gate's statistics into this one (fleet aggregation).
    pub fn merge(&mut self, other: &GateStats) {
        self.wait.merge(&other.wait);
        self.hold.merge(&other.hold);
        self.revocations += other.revocations;
        self.revoke_lag.merge(&other.revoke_lag);
        if self.by_class.len() < other.by_class.len() {
            self.by_class.resize(other.by_class.len(), 0);
        }
        for (c, n) in other.by_class.iter().enumerate() {
            self.by_class[c] += n;
        }
        self.holders_now += other.holders_now;
        if self.mode.is_empty() {
            self.mode = other.mode.clone();
        }
    }

    /// Two-line human rendering (serving reports); extra lines appear
    /// only when the watchdog revoked something or classes are in play.
    pub fn render(&self) -> String {
        let mut out = format!(
            "gate wait: {}\ngate hold: {}",
            self.wait.render_ms(),
            self.hold.render_ms()
        );
        if !self.mode.is_empty() {
            out.push_str(&format!(
                "\ngate mode: {} (holders now {})",
                self.mode, self.holders_now
            ));
        }
        if self.revocations > 0 {
            out.push_str(&format!(
                "\ngate revocations: {} (overstay {})",
                self.revocations,
                self.revoke_lag.render_ms()
            ));
        }
        if self.by_class.len() > 1 {
            out.push_str(&format!("\ngate grants by class: {:?}", self.by_class));
        }
        out
    }
}

/// Proof of admission. Releasing happens on drop (recording the hold
/// time and waking the arbiter's next pick), so a panic while the grant
/// is held unwinds into a clean handoff instead of wedging every other
/// client; [`GpuGate::release`] is the explicit form. `#[must_use]`
/// because an unbound grant releases immediately.
#[must_use = "an unbound GateGrant releases immediately; hold it for the critical section"]
#[derive(Debug)]
pub struct GateGrant<'a> {
    gate: &'a GpuGate,
    granted_at: Instant,
    ticket: u64,
}

impl GateGrant<'_> {
    /// Did the lease watchdog revoke this grant out from under us? A
    /// revoked holder has already lost the gate — the queue moved on — so
    /// its results must be treated as suspect (the serving layer counts
    /// the request failed and lets the health breaker see it).
    pub fn is_revoked(&self) -> bool {
        let st = lock_recover(&self.gate.state);
        !st.holders.iter().any(|&(t, _)| t == self.ticket)
    }
}

impl Drop for GateGrant<'_> {
    fn drop(&mut self) {
        // Regression (ISSUE 4): this used `if let Ok(..) = lock()`, which
        // silently skipped the handoff whenever the state mutex was
        // poisoned — wedging every queued waiter forever. The state is a
        // handful of counters, always valid, so recover the guard instead.
        // (`lock_recover` never panics, which also keeps this Drop safe
        // during unwinding.)
        let next = {
            let mut st = lock_recover(&self.gate.state);
            match st.holders.iter().position(|&(t, _)| t == self.ticket) {
                // Normal release: our ticket still holds a slot. Record
                // the hold, free the slot, and hand off. (A revoked
                // grant's hold was already recorded at revocation time —
                // exactly one hold entry per grant either way, so
                // per-class stats can never double-count.)
                Some(pos) => {
                    lock_recover(&self.gate.stats)
                        .hold
                        .record(self.granted_at.elapsed().as_nanos().min(u64::MAX as u128)
                            as Nanos);
                    st.holders.remove(pos);
                    // Waking outside the critical section avoids the
                    // hurry-up-and-wait pattern where the woken thread
                    // immediately blocks on the mutex the waker still
                    // holds. No lost wakeup either way: the baton was
                    // published under the lock, and the waiter re-checks
                    // it under the same lock around every wait. On a
                    // multi-holder gate a concurrent release may already
                    // have a baton in flight; the admitting waiter
                    // chain-issues the next one, so one baton suffices.
                    if st.baton.is_none() {
                        issue_baton(&mut st)
                    } else {
                        None
                    }
                }
                // The watchdog revoked us while we overstayed: the queue
                // already moved past our ticket (possibly several grants
                // ago). Touch nothing.
                None => None,
            }
        };
        if let Some(cv) = next {
            cv.notify_one();
        }
    }
}

/// Arbitrated gate serialising GPU access across serving threads.
///
/// One gate = one GPU's admission queue: the live counterpart of the
/// paper's `GPU_LOCK`. A serving fleet holds one per shard (see
/// [`crate::control::fleet`]) so isolation is enforced per device.
///
/// # Example
///
/// ```
/// use cook::control::gate::GpuGate;
///
/// let gate = GpuGate::new();
/// // Scoped critical section (the synced strategy's shape)...
/// let answer = gate.with(|| 42);
/// assert_eq!(answer, 42);
/// // ...or a grant carried across scopes (the callback strategy).
/// let grant = gate.acquire();
/// gate.release(grant);
/// assert_eq!(gate.stats().grants(), 2);
/// ```
#[derive(Debug)]
pub struct GpuGate {
    state: Mutex<GateState>,
    stats: Mutex<GateStats>,
    /// Maximum hold time before parked waiters may revoke the grant.
    /// `None` (the default) disables the watchdog entirely.
    lease: Option<Duration>,
    /// The gate's clock origin: absolute waiter deadlines (EDF) are
    /// nanoseconds since this instant.
    epoch: Instant,
    /// Per-class relative deadline, from the tenant-class config.
    class_deadline: Vec<Option<Duration>>,
}

impl GpuGate {
    pub fn new() -> Self {
        Self::with_config(ArbiterKind::Fifo, &[], None)
    }

    /// A gate whose grants carry a lease: a holder exceeding `lease` is
    /// revoked by the waiters it is blocking (see [`GpuGate::acquire`]).
    pub fn with_lease(lease: Duration) -> Self {
        Self::with_config(ArbiterKind::Fifo, &[], Some(lease))
    }

    /// The fully-configured form: an arbitration policy over `classes`,
    /// with an optional lease watchdog. Capacity 1 — the pre-refactor
    /// exclusive gate.
    pub fn with_config(
        arbiter: ArbiterKind,
        classes: &[TenantClass],
        lease: Option<Duration>,
    ) -> Self {
        Self::with_capacity_config(1, arbiter, classes, lease)
    }

    /// A gate admitting up to `capacity` concurrent holders (semaphore
    /// shape) under an arbitration policy — the mps/streams admission of
    /// [`crate::control::concurrency::ModeGate`]. `capacity == 1` is
    /// bit-identical to [`GpuGate::with_config`].
    pub fn with_capacity_config(
        capacity: usize,
        arbiter: ArbiterKind,
        classes: &[TenantClass],
        lease: Option<Duration>,
    ) -> Self {
        Self {
            state: Mutex::new(GateState {
                next_ticket: 0,
                baton: None,
                holders: Vec::new(),
                capacity: capacity.max(1),
                waiters: VecDeque::new(),
                arbiter: make_arbiter(arbiter, classes),
            }),
            stats: Mutex::new(GateStats::default()),
            lease,
            epoch: Instant::now(),
            class_deadline: classes
                .iter()
                .map(|c| c.deadline_ms.map(Duration::from_millis))
                .collect(),
        }
    }

    /// The configured lease, if any.
    pub fn lease(&self) -> Option<Duration> {
        self.lease
    }

    /// The configured arbitration policy.
    pub fn arbiter_kind(&self) -> ArbiterKind {
        lock_recover(&self.state).arbiter.kind()
    }

    /// Block until admitted (class 0), recording the wait. See
    /// [`GpuGate::acquire_class`].
    pub fn acquire(&self) -> GateGrant<'_> {
        self.acquire_class(0)
    }

    /// Block until admitted as a member of tenant `class`, recording the
    /// wait. Under the default FIFO arbiter admission is in strict
    /// arrival order; other arbiters re-order parked waiters (weights,
    /// credits-at-admission, deadlines) — see [`crate::control::arbiter`].
    ///
    /// # The waiter-driven lease watchdog
    ///
    /// When the gate has a lease, parked waiters double as the watchdog:
    /// instead of sleeping unconditionally, each waiter wakes at the
    /// holder's lease deadline and — under the state lock — checks
    /// whether the holder overstayed. If so it revokes the grant: clears
    /// the holder, records the revoked hold (and how far past the lease
    /// the holder was), and hands the baton to the arbiter's next pick.
    /// The revoked grant's own Drop sees the holder mismatch and touches
    /// nothing, so the queue never double-advances and the hold
    /// histogram gets exactly one entry per grant. No background thread
    /// exists to babysit an idle gate — which is exactly right: a hung
    /// holder with no waiters is blocking no one.
    pub fn acquire_class(&self, class: usize) -> GateGrant<'_> {
        let arrived = Instant::now();
        let mut st = lock_recover(&self.state);
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        if st.holders.len() < st.capacity && st.baton.is_none() && st.waiters.is_empty() {
            // Spare capacity and nobody queued: admit immediately (no
            // arbitration possible with nobody else in sight, but the
            // grant still counts toward the class's share). On the
            // capacity-1 gate this is exactly the pre-refactor idle
            // fast path.
            let granted_at = Instant::now();
            st.holders.push((ticket, granted_at));
            st.arbiter.on_grant(class);
            drop(st);
            self.record_admit(class, arrived.elapsed());
            return GateGrant { gate: self, granted_at, ticket };
        }
        // Park on a private condvar, registered in the same critical
        // section that took the ticket (so a releasing grant always sees
        // every earlier arrival in its arbitration snapshot).
        let cv = Arc::new(Condvar::new());
        let deadline_ns = self
            .class_deadline
            .get(class)
            .copied()
            .flatten()
            .map(|d| (self.epoch.elapsed() + d).as_nanos().min(u64::MAX as u128) as u64);
        st.waiters.push_back(WaitEntry { ticket, class, deadline_ns, cv: Arc::clone(&cv) });
        while st.baton != Some(ticket) {
            let Some(lease) = self.lease else {
                st = cv.wait(st).unwrap_or_else(PoisonError::into_inner);
                continue;
            };
            // The oldest grant is the watchdog's suspect: on a
            // multi-holder gate only the longest-held ticket can have
            // overstayed the lease first.
            let oldest = st
                .holders
                .iter()
                .enumerate()
                .min_by_key(|&(_, &(_, at))| at)
                .map(|(pos, &(_, at))| (pos, at));
            match oldest {
                Some((pos, since)) if since.elapsed() >= lease => {
                    // Revoke the overstaying holder. Its hold ends here:
                    // the histogram entry is recorded at revocation —
                    // one entry per grant even if the revoked grant is
                    // never dropped, and no post-revocation inflation of
                    // the hold time (the latent double-accounting ISSUE 8
                    // closes). Exactly that ticket loses its slot;
                    // concurrent holders of a multi-holder gate are
                    // untouched.
                    let held = since.elapsed();
                    st.holders.remove(pos);
                    let lag = held.saturating_sub(lease);
                    {
                        let mut stats = lock_recover(&self.stats);
                        stats.hold.record(held.as_nanos().min(u64::MAX as u128) as Nanos);
                        stats.revocations += 1;
                        stats
                            .revoke_lag
                            .record(lag.as_nanos().min(u64::MAX as u128) as Nanos);
                    }
                    // The revoker need not be the arbiter's pick: hand
                    // the freed slot to whoever is (unless it is us — the
                    // loop condition takes care of that case). A baton
                    // already in flight keeps its claim; never issue two.
                    if st.baton.is_none() {
                        if let Some(next) = issue_baton(&mut st) {
                            if st.baton != Some(ticket) {
                                next.notify_one();
                            }
                        }
                    }
                }
                Some((_, since)) => {
                    // Sleep until this holder's lease deadline (a
                    // release wakes the arbiter's pick sooner).
                    let remaining = lease
                        .saturating_sub(since.elapsed())
                        .max(Duration::from_micros(100));
                    let (g, _) = cv
                        .wait_timeout(st, remaining)
                        .unwrap_or_else(PoisonError::into_inner);
                    st = g;
                }
                None => {
                    // Between handoffs: the baton holder admits itself
                    // next; re-check at lease granularity in case that
                    // wakeup is lost to a race.
                    let (g, _) = cv
                        .wait_timeout(st, lease)
                        .unwrap_or_else(PoisonError::into_inner);
                    st = g;
                }
            }
        }
        // Admitted: consume the baton and retire our queue entry.
        st.baton = None;
        if let Some(pos) = st.waiters.iter().position(|e| e.ticket == ticket) {
            st.waiters.remove(pos);
        }
        let granted_at = Instant::now();
        st.holders.push((ticket, granted_at));
        // Chain-wake: if slots remain (several releases landed while one
        // baton was in flight, or capacity opened under us), hand the
        // next baton on before entering the critical section. Never
        // fires on the capacity-1 gate — admission fills it.
        let chain = if st.holders.len() < st.capacity && !st.waiters.is_empty() {
            issue_baton(&mut st)
        } else {
            None
        };
        drop(st);
        if let Some(cv) = chain {
            cv.notify_one();
        }
        self.record_admit(class, arrived.elapsed());
        GateGrant { gate: self, granted_at, ticket }
    }

    fn record_admit(&self, class: usize, waited: Duration) {
        let mut stats = lock_recover(&self.stats);
        stats.wait.record(waited.as_nanos().min(u64::MAX as u128) as Nanos);
        if stats.by_class.len() <= class {
            stats.by_class.resize(class + 1, 0);
        }
        stats.by_class[class] += 1;
    }

    /// Release an admission, recording the hold time and waking the
    /// arbiter's next pick (explicit form of dropping the grant).
    pub fn release(&self, grant: GateGrant<'_>) {
        debug_assert!(std::ptr::eq(self, grant.gate), "grant from another gate");
        drop(grant);
    }

    /// Run `f` under the gate (the synced strategy's shape).
    pub fn with<T>(&self, f: impl FnOnce() -> T) -> T {
        let grant = self.acquire();
        let out = f();
        self.release(grant);
        out
    }

    /// [`GpuGate::with`] as tenant `class`.
    pub fn with_class<T>(&self, class: usize, f: impl FnOnce() -> T) -> T {
        let grant = self.acquire_class(class);
        let out = f();
        self.release(grant);
        out
    }

    /// The concurrent-holder bound (1 on the pre-refactor gate).
    pub fn capacity(&self) -> usize {
        lock_recover(&self.state).capacity
    }

    /// Snapshot of the wait/hold statistics so far, including the
    /// instantaneous holder count.
    pub fn stats(&self) -> GateStats {
        let mut s = lock_recover(&self.stats).clone();
        s.holders_now = lock_recover(&self.state).holders.len() as u64;
        s
    }
}

impl Default for GpuGate {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::arbiter::parse_classes;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn serialises_critical_sections() {
        let gate = Arc::new(GpuGate::new());
        let inside = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let gate = Arc::clone(&gate);
            let inside = Arc::clone(&inside);
            let peak = Arc::clone(&peak);
            handles.push(std::thread::spawn(move || {
                for _ in 0..25 {
                    gate.with(|| {
                        let now = inside.fetch_add(1, Ordering::SeqCst) + 1;
                        peak.fetch_max(now, Ordering::SeqCst);
                        std::thread::sleep(std::time::Duration::from_micros(20));
                        inside.fetch_sub(1, Ordering::SeqCst);
                    });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(peak.load(Ordering::SeqCst), 1, "gate admitted two at once");
        let stats = gate.stats();
        assert_eq!(stats.grants(), 100);
        assert_eq!(stats.wait.count(), 100);
    }

    #[test]
    fn fifo_order_of_queued_waiters() {
        // Hold the gate, queue three waiters, then release and check they
        // are admitted in arrival order.
        let gate = Arc::new(GpuGate::new());
        let order = Arc::new(Mutex::new(Vec::new()));
        let first = gate.acquire();
        let mut handles = Vec::new();
        for i in 0..3 {
            let gate = Arc::clone(&gate);
            let order = Arc::clone(&order);
            handles.push(std::thread::spawn(move || {
                let g = gate.acquire();
                order.lock().unwrap().push(i);
                gate.release(g);
            }));
            // Let the waiter reach the queue before spawning the next so
            // arrival order is deterministic.
            std::thread::sleep(std::time::Duration::from_millis(20));
        }
        gate.release(first);
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2]);
    }

    #[test]
    fn grant_can_cross_threads() {
        // The callback strategy's deferred acquire/release: the grant is
        // taken on one thread and released on another.
        let gate = GpuGate::new();
        let grant = gate.acquire();
        std::thread::scope(|s| {
            s.spawn(|| gate.release(grant));
        });
        // Gate must be free again.
        let g = gate.acquire();
        gate.release(g);
        assert_eq!(gate.stats().grants(), 2);
    }

    #[test]
    fn panic_while_holding_grant_does_not_wedge_the_gate() {
        // Regression: the grant releases on drop during unwinding, so a
        // client panicking mid-critical-section hands the gate to the
        // next waiter instead of hanging it (the old bare Mutex<()> path
        // poisoned; a non-RAII grant would deadlock).
        let gate = GpuGate::new();
        let panicked = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _grant = gate.acquire();
            panic!("payload blew up");
        }));
        assert!(panicked.is_err());
        // Must be acquirable again without blocking.
        gate.with(|| ());
        assert_eq!(gate.stats().grants(), 2);
    }

    #[test]
    fn poisoned_state_mutex_does_not_wedge_waiters() {
        // Regression (ISSUE 4): GateGrant::Drop used to skip the handoff
        // when the state mutex was poisoned, wedging every queued waiter
        // forever. Poison the mutex deliberately and check the gate
        // still hands off.
        let gate = Arc::new(GpuGate::new());
        {
            let gate = Arc::clone(&gate);
            let _ = std::thread::spawn(move || {
                let _guard = gate.state.lock().unwrap();
                panic!("poison the state mutex");
            })
            .join();
        }
        assert!(gate.state.is_poisoned(), "setup must actually poison");
        // Acquire/release must still progress the ticket counter...
        gate.with(|| ());
        // ...and a queued waiter must still be woken by a release.
        let first = gate.acquire();
        let waiter = {
            let gate = Arc::clone(&gate);
            std::thread::spawn(move || gate.with(|| 7))
        };
        std::thread::sleep(std::time::Duration::from_millis(30));
        gate.release(first);
        assert_eq!(waiter.join().unwrap(), 7);
        assert_eq!(gate.stats().grants(), 3);
    }

    #[test]
    fn single_wakeup_preserves_grant_order_and_histograms() {
        // ISSUE 6 satellite: release wakes only the next grantee
        // (per-waiter condvars) instead of notify_all. Under sustained
        // contention the observable contract must be exactly what the
        // herd version produced: strict FIFO grant order, and wait/hold
        // histograms recording one entry per grant.
        let gate = Arc::new(GpuGate::new());
        let order = Arc::new(Mutex::new(Vec::new()));
        let first = gate.acquire();
        let mut handles = Vec::new();
        for i in 0..8 {
            let gate = Arc::clone(&gate);
            let order = Arc::clone(&order);
            handles.push(std::thread::spawn(move || {
                let g = gate.acquire();
                order.lock().unwrap().push(i);
                // Hold briefly so later tickets genuinely queue behind us.
                std::thread::sleep(std::time::Duration::from_micros(50));
                gate.release(g);
            }));
            // Serialise arrival so ticket order == spawn order.
            std::thread::sleep(std::time::Duration::from_millis(15));
        }
        // All 8 queued behind the held grant: the deepest herd window.
        assert_eq!(lock_recover(&gate.state).waiters.len(), 8);
        gate.release(first);
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(*order.lock().unwrap(), (0..8).collect::<Vec<_>>());
        let stats = gate.stats();
        assert_eq!(stats.grants(), 9, "one hold record per grant");
        assert_eq!(stats.wait.count(), 9, "one wait record per grant");
        // The queue fully drained: no waiter entry leaks past its grant.
        assert!(lock_recover(&gate.state).waiters.is_empty());
    }

    #[test]
    fn with_returns_value_and_records() {
        let gate = GpuGate::new();
        let v = gate.with(|| 41 + 1);
        assert_eq!(v, 42);
        let s = gate.stats();
        assert_eq!(s.grants(), 1);
        assert!(s.render().contains("gate wait"));
        assert!(
            !s.render().contains("revocations"),
            "no revocation line without revocations"
        );
        assert!(
            !s.render().contains("by class"),
            "no class line for a single-class gate"
        );
    }

    #[test]
    fn hung_holder_is_revoked_by_a_waiter() {
        // ISSUE 7 tentpole: a holder exceeding its lease must cost one
        // lease period, not the fleet. The waiter doubles as watchdog.
        let gate = Arc::new(GpuGate::with_lease(std::time::Duration::from_millis(20)));
        let hung = gate.acquire();
        let waiter = {
            let gate = Arc::clone(&gate);
            std::thread::spawn(move || gate.with(|| 7))
        };
        // The waiter revokes the hung grant and proceeds on its own —
        // nobody ever releases `hung` for it.
        assert_eq!(waiter.join().unwrap(), 7);
        assert!(hung.is_revoked());
        let s = gate.stats();
        assert_eq!(s.revocations, 1);
        assert_eq!(s.revoke_lag.count(), 1);
        assert!(s.render().contains("gate revocations: 1"), "{}", s.render());
        // The revoked grant's Drop must NOT advance the queue again: the
        // gate still works, and grants line up (hung + waiter + this).
        drop(hung);
        gate.with(|| ());
        assert_eq!(gate.stats().grants(), 3);
        assert_eq!(gate.stats().revocations, 1);
    }

    #[test]
    fn revocation_hands_off_in_fifo_order_with_multiple_waiters() {
        let gate = Arc::new(GpuGate::with_lease(std::time::Duration::from_millis(20)));
        let order = Arc::new(Mutex::new(Vec::new()));
        let hung = gate.acquire();
        let mut handles = Vec::new();
        for i in 0..3 {
            let gate = Arc::clone(&gate);
            let order = Arc::clone(&order);
            handles.push(std::thread::spawn(move || {
                let g = gate.acquire();
                order.lock().unwrap().push(i);
                assert!(!g.is_revoked(), "a fresh grant is not revoked");
                gate.release(g);
            }));
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        for h in handles {
            h.join().unwrap();
        }
        // Every waiter got through (exactly one revocation was needed)
        // and strict ticket order survived the force-advance.
        assert_eq!(*order.lock().unwrap(), vec![0, 1, 2]);
        assert_eq!(gate.stats().revocations, 1);
        drop(hung);
        assert_eq!(gate.stats().grants(), 4, "revoked holder still records its hold");
    }

    #[test]
    fn revoked_grant_records_exactly_one_hold_entry() {
        // ISSUE 8 satellite: the pre-arbiter gate recorded the revoked
        // holder's hold at its (arbitrarily late) Drop — inflating the
        // hold time past the revocation, and never recording at all if
        // the hung thread never dropped. Now the entry lands at
        // revocation time: exactly one hold entry per grant, bounded by
        // the revocation instant, whether or not Drop ever runs.
        let gate = Arc::new(GpuGate::with_lease(std::time::Duration::from_millis(10)));
        let hung = gate.acquire();
        let waiter = {
            let gate = Arc::clone(&gate);
            std::thread::spawn(move || gate.with(|| ()))
        };
        waiter.join().unwrap();
        // Hold entry already present BEFORE the revoked grant drops.
        let s = gate.stats();
        assert_eq!(s.revocations, 1);
        assert_eq!(s.grants(), 2, "revoked hold recorded at revocation, not Drop");
        // Keep the revoked grant alive well past its revocation, then
        // drop it: the count must not move.
        std::thread::sleep(std::time::Duration::from_millis(30));
        drop(hung);
        assert_eq!(gate.stats().grants(), 2, "Drop of a revoked grant records nothing");
        assert_eq!(gate.stats().wait.count(), 2, "one wait entry per grant too");
    }

    #[test]
    fn well_behaved_holders_are_never_revoked() {
        let gate = Arc::new(GpuGate::with_lease(std::time::Duration::from_millis(250)));
        let mut handles = Vec::new();
        for _ in 0..4 {
            let gate = Arc::clone(&gate);
            handles.push(std::thread::spawn(move || {
                for _ in 0..10 {
                    gate.with(|| std::thread::sleep(std::time::Duration::from_micros(200)));
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = gate.stats();
        assert_eq!(s.revocations, 0);
        assert_eq!(s.grants(), 40);
    }

    #[test]
    fn stats_merge_sums_everything() {
        let a = GpuGate::new();
        a.with(|| ());
        let mut sa = a.stats();
        let b = GpuGate::new();
        b.with(|| ());
        b.with(|| ());
        let mut sb = b.stats();
        sb.revocations = 2;
        sb.by_class = vec![1, 1];
        sa.merge(&sb);
        assert_eq!(sa.grants(), 3);
        assert_eq!(sa.wait.count(), 3);
        assert_eq!(sa.revocations, 2);
        assert_eq!(sa.by_class, vec![2, 1], "class grants merge element-wise");
    }

    #[test]
    fn edf_class_jumps_the_queue() {
        // A deadline-bearing class admitted after a best-effort waiter
        // must be granted first once the holder releases.
        let classes = parse_classes("batch,rt:deadline=5").unwrap();
        let gate = Arc::new(GpuGate::with_config(ArbiterKind::Edf, &classes, None));
        let order = Arc::new(Mutex::new(Vec::new()));
        let first = gate.acquire_class(0);
        let mut handles = Vec::new();
        for (i, class) in [(0usize, 0usize), (1, 0), (2, 1)] {
            let gate = Arc::clone(&gate);
            let order = Arc::clone(&order);
            handles.push(std::thread::spawn(move || {
                let g = gate.acquire_class(class);
                order.lock().unwrap().push(i);
                gate.release(g);
            }));
            std::thread::sleep(std::time::Duration::from_millis(15));
        }
        gate.release(first);
        for h in handles {
            h.join().unwrap();
        }
        // Waiter 2 (class rt, deadline) beats the two earlier batch
        // waiters; those two then drain FIFO.
        assert_eq!(*order.lock().unwrap(), vec![2, 0, 1]);
        let s = gate.stats();
        assert_eq!(s.by_class, vec![3, 1], "per-class grant counts");
        assert!(s.render().contains("by class"), "{}", s.render());
    }

    #[test]
    fn capacity_two_admits_two_and_queues_the_third() {
        // ISSUE 9: the capacity-N gate is a fair semaphore. Two grants
        // fast-path in; the third parks until a slot frees, then admits
        // in ticket order.
        let gate = Arc::new(GpuGate::with_capacity_config(2, ArbiterKind::Fifo, &[], None));
        let a = gate.acquire();
        let b = gate.acquire();
        assert_eq!(gate.stats().holders_now, 2);
        let third = {
            let gate = Arc::clone(&gate);
            std::thread::spawn(move || gate.with(|| 9))
        };
        // The third waiter must genuinely queue behind the full gate.
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(lock_recover(&gate.state).waiters.len(), 1);
        gate.release(a);
        assert_eq!(third.join().unwrap(), 9);
        gate.release(b);
        let s = gate.stats();
        assert_eq!(s.grants(), 3);
        assert_eq!(s.wait.count(), 3);
        assert_eq!(s.holders_now, 0);
        assert!(lock_recover(&gate.state).waiters.is_empty());
    }

    #[test]
    fn capacity_gate_chain_wakes_through_multiple_free_slots() {
        // Two holders release while waiters are parked: the single
        // baton plus the admit-time chain-wake must drain both waiters
        // (a lost second wakeup would hang this test).
        let gate = Arc::new(GpuGate::with_capacity_config(2, ArbiterKind::Fifo, &[], None));
        let a = gate.acquire();
        let b = gate.acquire();
        let mut handles = Vec::new();
        for _ in 0..2 {
            let gate = Arc::clone(&gate);
            handles.push(std::thread::spawn(move || gate.with(|| ())));
        }
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert_eq!(lock_recover(&gate.state).waiters.len(), 2);
        // Free both slots back-to-back: only one baton is in flight; the
        // first admitted waiter must chain the second.
        gate.release(a);
        gate.release(b);
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(gate.stats().grants(), 4);
    }

    #[test]
    fn revocation_on_a_multi_holder_gate_revokes_exactly_one_ticket() {
        // ISSUE 9 tentpole law: revoking a multi-holder grant revokes
        // exactly that ticket — the concurrent holder keeps its slot.
        let gate = Arc::new(GpuGate::with_capacity_config(
            2,
            ArbiterKind::Fifo,
            &[],
            Some(std::time::Duration::from_millis(20)),
        ));
        let hung = gate.acquire(); // oldest: the watchdog's suspect
        std::thread::sleep(std::time::Duration::from_millis(5));
        let live = gate.acquire();
        let waiter = {
            let gate = Arc::clone(&gate);
            std::thread::spawn(move || gate.with(|| 7))
        };
        // The parked waiter revokes only the overstayed oldest grant.
        assert_eq!(waiter.join().unwrap(), 7);
        assert!(hung.is_revoked(), "the hung holder must lose its ticket");
        assert!(!live.is_revoked(), "the concurrent holder must keep its ticket");
        let s = gate.stats();
        assert_eq!(s.revocations, 1);
        drop(hung);
        gate.release(live);
        assert_eq!(gate.stats().grants(), 3, "one hold entry per grant, revoked included");
    }

    #[test]
    fn wrr_gate_balances_classes_by_weight() {
        // Two classes at weights 2:1, three queued waiters (a, a, b):
        // WRR grants a, then b (a's share is ahead), then a.
        let classes = parse_classes("a:weight=2,b").unwrap();
        let gate = Arc::new(GpuGate::with_config(ArbiterKind::Wrr, &classes, None));
        let order = Arc::new(Mutex::new(Vec::new()));
        let first = gate.acquire_class(0);
        let mut handles = Vec::new();
        for (i, class) in [(0usize, 0usize), (1, 0), (2, 1)] {
            let gate = Arc::clone(&gate);
            let order = Arc::clone(&order);
            handles.push(std::thread::spawn(move || {
                let g = gate.acquire_class(class);
                order.lock().unwrap().push(i);
                gate.release(g);
            }));
            std::thread::sleep(std::time::Duration::from_millis(15));
        }
        gate.release(first);
        for h in handles {
            h.join().unwrap();
        }
        // `first` (class a) already consumed one share: b is the most
        // underserved at the handoff, then a, a.
        assert_eq!(*order.lock().unwrap(), vec![2, 0, 1]);
        assert_eq!(gate.stats().by_class, vec![3, 1]);
    }
}
