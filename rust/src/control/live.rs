//! Live (wall-clock) access controller: the COOK strategies applied to
//! *real* executions on the PJRT runtime, for the serving path.
//!
//! The simulator reproduces the paper's Jetson measurements; this module
//! is the deployable counterpart: concurrent clients submit inference
//! requests, and the controller serialises the actual PJRT executions
//! behind a real global lock according to the configured strategy.
//!
//! Live mode supports `none`, `synced` and `worker` (the callback
//! strategy is CUDA-stream-specific: it needs `cudaLaunchHostFunc`
//! semantics that have no PJRT equivalent).

use crate::config::StrategyKind;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Per-application deferred worker (live analogue of Alg. 5-6).
struct LiveWorker {
    tx: mpsc::Sender<Job>,
    pending: Arc<(Mutex<usize>, Condvar)>,
    handle: Option<JoinHandle<()>>,
}

impl LiveWorker {
    fn new(gpu_lock: Arc<Mutex<()>>) -> Self {
        let (tx, rx) = mpsc::channel::<Job>();
        let pending: Arc<(Mutex<usize>, Condvar)> = Arc::new((Mutex::new(0), Condvar::new()));
        let pending2 = Arc::clone(&pending);
        let handle = std::thread::spawn(move || {
            // Alg. 6: pop; acquire GPU_LOCK; run (PJRT execute is
            // synchronous = insert + sync); release; mark done.
            while let Ok(job) = rx.recv() {
                {
                    let _gpu = gpu_lock.lock().unwrap();
                    job();
                }
                let (m, cv) = &*pending2;
                let mut n = m.lock().unwrap();
                *n -= 1;
                cv.notify_all();
            }
        });
        Self { tx, pending, handle: Some(handle) }
    }

    fn submit(&self, job: Job) {
        let (m, _) = &*self.pending;
        *m.lock().unwrap() += 1;
        self.tx.send(job).expect("worker thread gone");
    }

    /// Alg. 7 / barrier: wait until all queued work completed.
    fn drain(&self) {
        let (m, cv) = &*self.pending;
        let mut n = m.lock().unwrap();
        while *n > 0 {
            n = cv.wait(n).unwrap();
        }
    }
}

impl Drop for LiveWorker {
    fn drop(&mut self) {
        // Closing the channel stops the loop; join for clean shutdown.
        let (tx, _) = mpsc::channel::<Job>();
        let _old = std::mem::replace(&mut self.tx, tx);
        drop(_old);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// The live access controller.
pub struct LiveController {
    strategy: StrategyKind,
    gpu_lock: Arc<Mutex<()>>,
    workers: Vec<LiveWorker>,
}

impl LiveController {
    /// Build a controller for `apps` concurrent applications.
    pub fn new(strategy: StrategyKind, apps: usize) -> Self {
        assert!(
            matches!(strategy, StrategyKind::None | StrategyKind::Synced | StrategyKind::Worker),
            "live mode supports none|synced|worker, got {strategy}"
        );
        let gpu_lock = Arc::new(Mutex::new(()));
        let workers = if strategy == StrategyKind::Worker {
            (0..apps).map(|_| LiveWorker::new(Arc::clone(&gpu_lock))).collect()
        } else {
            Vec::new()
        };
        Self { strategy, gpu_lock, workers }
    }

    pub fn strategy(&self) -> StrategyKind {
        self.strategy
    }

    /// Execute one GPU operation for application `app`, returning its
    /// result. Under `worker` the call is deferred to the app's worker
    /// and awaited (callers wanting async can use `submit` + `drain`).
    pub fn execute<T: Send + 'static>(
        &self,
        app: usize,
        f: impl FnOnce() -> T + Send + 'static,
    ) -> T {
        match self.strategy {
            StrategyKind::None => f(),
            StrategyKind::Synced => {
                let _gpu = self.gpu_lock.lock().unwrap();
                f()
            }
            StrategyKind::Worker => {
                let (tx, rx) = mpsc::channel();
                self.workers[app].submit(Box::new(move || {
                    let _ = tx.send(f());
                }));
                rx.recv().expect("worker dropped result")
            }
            _ => unreachable!(),
        }
    }

    /// Fire-and-forget submission (worker strategy's true shape: the host
    /// continues while the worker serialises the GPU work).
    pub fn submit(&self, app: usize, f: impl FnOnce() + Send + 'static) {
        match self.strategy {
            StrategyKind::Worker => self.workers[app].submit(Box::new(f)),
            StrategyKind::Synced => {
                let _gpu = self.gpu_lock.lock().unwrap();
                f();
            }
            StrategyKind::None => f(),
            _ => unreachable!(),
        }
    }

    /// Synchronisation barrier for `app` (waits for its deferred work).
    pub fn barrier(&self, app: usize) {
        if self.strategy == StrategyKind::Worker {
            self.workers[app].drain();
        }
        // none/synced: every call already completed synchronously.
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn execute_returns_results_all_strategies() {
        for s in [StrategyKind::None, StrategyKind::Synced, StrategyKind::Worker] {
            let c = LiveController::new(s, 2);
            let out = c.execute(0, || 21 * 2);
            assert_eq!(out, 42, "{s}");
        }
    }

    #[test]
    fn worker_serialises_under_the_lock() {
        let c = Arc::new(LiveController::new(StrategyKind::Worker, 2));
        let in_crit = Arc::new(AtomicUsize::new(0));
        let max_seen = Arc::new(AtomicUsize::new(0));
        let mut handles = Vec::new();
        for app in 0..2 {
            let c = Arc::clone(&c);
            let in_crit = Arc::clone(&in_crit);
            let max_seen = Arc::clone(&max_seen);
            handles.push(std::thread::spawn(move || {
                for _ in 0..50 {
                    let ic = Arc::clone(&in_crit);
                    let ms = Arc::clone(&max_seen);
                    c.submit(app, move || {
                        let now = ic.fetch_add(1, Ordering::SeqCst) + 1;
                        ms.fetch_max(now, Ordering::SeqCst);
                        std::thread::sleep(std::time::Duration::from_micros(50));
                        ic.fetch_sub(1, Ordering::SeqCst);
                    });
                }
                c.barrier(app);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(
            max_seen.load(Ordering::SeqCst),
            1,
            "GPU lock must admit exactly one operation at a time"
        );
    }

    #[test]
    fn barrier_waits_for_submitted_work() {
        let c = LiveController::new(StrategyKind::Worker, 1);
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..20 {
            let d = Arc::clone(&done);
            c.submit(0, move || {
                std::thread::sleep(std::time::Duration::from_micros(100));
                d.fetch_add(1, Ordering::SeqCst);
            });
        }
        c.barrier(0);
        assert_eq!(done.load(Ordering::SeqCst), 20);
    }

    #[test]
    #[should_panic(expected = "live mode supports")]
    fn callback_rejected_in_live_mode() {
        let _ = LiveController::new(StrategyKind::Callback, 1);
    }
}
