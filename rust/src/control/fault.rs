//! Fault injection and self-healing: the robustness layer.
//!
//! COOK's whole thesis is serializing GPU access through a gate — which
//! makes the gate a single point of failure: one hung or crashed holder
//! wedges every waiter, and a panicking shard used to abort the entire
//! fleet run. This module supplies the machinery to *provoke* those
//! failures deterministically and to *survive* them:
//!
//! * [`FaultSpec`]/[`FaultPlan`] — a seeded, deterministic fault schedule
//!   parsed from a spec string (`cook serve --faults <spec>`). Every
//!   injection decision is a **pure hash** of `(seed, request seq,
//!   attempt)` — never a draw from shared sequential RNG state — so the
//!   set of injected faults is identical regardless of how many worker
//!   threads race over the request stream. That is the retry determinism
//!   contract (DESIGN.md §12).
//! * [`FaultyBackend`] — a [`ServeBackend`](crate::control::serving::ServeBackend)
//!   wrapper whose executors inject errors, hangs and panics at the
//!   points the plan selects.
//! * [`RetryPolicy`] — bounded exponential backoff with deterministic
//!   seeded jitter and a per-request attempt budget.
//! * [`ShardHealth`] — the per-shard circuit breaker driving the
//!   Healthy → Degraded → Ejected → Probing → Reinstated state machine
//!   the fleet router consults before placing an arrival.
//! * [`FaultReport`] — injected/detected/retried/recovered/gave-up
//!   accounting plus time-to-detect / time-to-recover
//!   [`QuantileSketch`]es, surfaced in `ServeReport`/`FleetReport`.
//!
//! The simulator mirrors the same spec: `hang` clauses carrying `at=MS`
//! or `period=MS` become seeded `Event::FaultDue` kernel-slowdown events
//! in [`crate::gpu::Sim`], replayable bit-identically at any
//! `COOK_SIM_THREADS` (the sharded runner deals per-app fault schedules
//! exactly like arrival schedules).
//!
//! # Spec grammar
//!
//! Comma-separated clauses, first match wins:
//!
//! ```text
//! error:p=0.01                 1% of attempts fail with an injected error
//! error:req=7                  request seq 7 fails (first attempt only)
//! hang:ms=50:p=0.02            2% of attempts stall 50 ms before executing
//! hang:shard=2@req=500:ms=50   request 500 on shard 2 stalls 50 ms
//! crash:payload=1@req=100      request 100 of payload slot 1 panics (once)
//! crash:shard=1                shard 1 panics at serve start (boot crash)
//! hang:at=20:ms=5              simulator: one 5 ms kernel stall at t=20 ms
//! hang:period=100:ms=3         simulator: ~every 100 ms, a 3 ms stall
//! ```
//!
//! Selector tokens (`shard=`, `payload=`, `req=`, and the combined
//! `shard=N@req=M` form) restrict where a clause fires; `p=` makes it
//! probabilistic per attempt; `req=`-selected faults fire on attempt 0
//! only, so a retry can recover. `at=`/`period=` address virtual time
//! and are consumed only by the simulator.

use crate::control::serving::{PayloadExecutor, ResolvedPayload, ServeBackend};
use crate::metrics::stats::QuantileSketch;
use crate::util::{lock_recover, DetRng};
use anyhow::{anyhow, Result};
use std::fmt;
use std::str::FromStr;
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// RNG stream tag for simulator fault schedules (independent of the
/// engine's `EXEC`/`STAL` and the traffic generator's `TRFF` streams).
const FAULT_RNG_TAG: u64 = 0x4641_4C54; // "FALT"

/// Runaway backstop on per-app simulator fault events.
const SIM_FAULT_CAP: usize = 4096;

// ---------------------------------------------------------------------
// spec
// ---------------------------------------------------------------------

/// What kind of misbehaviour a clause injects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// The attempt returns an injected `Err`.
    Error,
    /// The attempt stalls for `ms` before executing normally (a hung or
    /// slow kernel; long enough, it trips the gate-lease watchdog).
    Hang,
    /// The attempt panics (a crashing client/shard).
    Crash,
}

impl FaultKind {
    fn name(&self) -> &'static str {
        match self {
            Self::Error => "error",
            Self::Hang => "hang",
            Self::Crash => "crash",
        }
    }
}

/// One parsed fault clause: a kind plus its selectors and parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultClause {
    pub kind: FaultKind,
    /// Per-attempt firing probability (hashed, not drawn — see module
    /// docs). `None` with no `req=` selector means "always".
    pub p: Option<f64>,
    /// Stall duration for `hang` clauses, milliseconds.
    pub ms: u64,
    /// Fire exactly at this global request seq, attempt 0 only.
    pub req: Option<u64>,
    /// Restrict to one shard.
    pub shard: Option<usize>,
    /// Restrict to one payload slot (index into `ServeSpec::payloads`).
    pub payload: Option<usize>,
    /// Simulator: one injection at this virtual time, milliseconds.
    pub at_ms: Option<u64>,
    /// Simulator: recurring injections with this mean period (seeded
    /// exponential gaps), milliseconds.
    pub period_ms: Option<u64>,
}

impl FaultClause {
    fn new(kind: FaultKind) -> Self {
        Self {
            kind,
            p: None,
            ms: 10,
            req: None,
            shard: None,
            payload: None,
            at_ms: None,
            period_ms: None,
        }
    }

    /// Is this clause addressed at virtual time (simulator-only)?
    pub fn is_sim(&self) -> bool {
        self.at_ms.is_some() || self.period_ms.is_some()
    }

    /// A `crash` clause with no probability, request or virtual-time
    /// selector: the whole serve (or the selected shard) panics at
    /// startup — the "crashing shard process" scenario.
    pub fn is_boot_crash(&self) -> bool {
        self.kind == FaultKind::Crash
            && self.p.is_none()
            && self.req.is_none()
            && self.payload.is_none()
            && !self.is_sim()
    }
}

impl fmt::Display for FaultClause {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.kind.name())?;
        if let Some(s) = self.shard {
            write!(f, ":shard={s}")?;
        }
        if let Some(p) = self.payload {
            write!(f, ":payload={p}")?;
        }
        if let Some(r) = self.req {
            write!(f, ":req={r}")?;
        }
        if let Some(p) = self.p {
            write!(f, ":p={p}")?;
        }
        if let Some(at) = self.at_ms {
            write!(f, ":at={at}")?;
        }
        if let Some(per) = self.period_ms {
            write!(f, ":period={per}")?;
        }
        if self.kind == FaultKind::Hang {
            write!(f, ":ms={}", self.ms)?;
        }
        Ok(())
    }
}

/// A parsed fault specification: an ordered clause list (first matching
/// clause fires). Empty = no faults (the default).
///
/// # Example
///
/// ```
/// use cook::control::fault::FaultSpec;
///
/// let spec: FaultSpec = "error:p=0.01,hang:shard=2@req=500:ms=50".parse().unwrap();
/// assert_eq!(spec.clauses.len(), 2);
/// // Display/parse round-trips.
/// assert_eq!(spec.to_string().parse::<FaultSpec>().unwrap(), spec);
/// ```
#[derive(Debug, Clone, Default, PartialEq)]
pub struct FaultSpec {
    pub clauses: Vec<FaultClause>,
}

impl FaultSpec {
    pub fn is_empty(&self) -> bool {
        self.clauses.is_empty()
    }

    /// Any clause addressed at the simulator's virtual time?
    pub fn has_sim_clauses(&self) -> bool {
        self.clauses.iter().any(|c| c.is_sim())
    }

    /// The simulator's per-app fault schedule: sorted `(t_ns, extra_ns)`
    /// injections for app `app` on shard `shard`, strictly before
    /// `horizon_ns`. Pure function of `(spec, app, shard, horizon,
    /// seed)` — the sharded runner deals these per app exactly like
    /// arrival schedules, so the merged trace is thread-count-invariant.
    pub fn sim_schedule(
        &self,
        app: usize,
        shard: usize,
        horizon_ns: u64,
        seed: u64,
    ) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        for (idx, c) in self.clauses.iter().enumerate() {
            if c.kind != FaultKind::Hang || !c.is_sim() {
                continue;
            }
            if c.shard.is_some_and(|s| s != shard) || c.payload.is_some_and(|p| p != app) {
                continue;
            }
            let extra = c.ms.saturating_mul(1_000_000);
            if let Some(at) = c.at_ms {
                let t = at.saturating_mul(1_000_000);
                if t < horizon_ns {
                    out.push((t, extra));
                }
            }
            if let Some(period) = c.period_ms {
                let mut rng = DetRng::new(seed)
                    .child(FAULT_RNG_TAG)
                    .child(((app as u64) << 16) | idx as u64);
                let mean_ns = period as f64 * 1e6;
                let mut t = 0.0f64;
                while out.len() < SIM_FAULT_CAP {
                    // u in [0,1) => (1-u) in (0,1]: ln never sees 0.
                    t += -(1.0 - rng.f64()).ln() * mean_ns;
                    if t >= horizon_ns as f64 {
                        break;
                    }
                    out.push((t as u64, extra));
                }
            }
        }
        out.sort_unstable();
        out
    }
}

impl fmt::Display for FaultSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, c) in self.clauses.iter().enumerate() {
            if i > 0 {
                f.write_str(",")?;
            }
            write!(f, "{c}")?;
        }
        Ok(())
    }
}

impl FromStr for FaultSpec {
    type Err = String;

    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let s = s.trim();
        if s.is_empty() || s == "none" {
            return Ok(Self::default());
        }
        let mut clauses = Vec::new();
        for clause_text in s.split(',') {
            let mut parts = clause_text.trim().split(':');
            let kind = match parts.next().unwrap_or("") {
                "error" => FaultKind::Error,
                "hang" | "slow" => FaultKind::Hang,
                "crash" | "panic" => FaultKind::Crash,
                other => {
                    return Err(format!(
                        "bad fault clause '{clause_text}': unknown kind '{other}' \
                         (expected error|hang|crash)"
                    ))
                }
            };
            let mut c = FaultClause::new(kind);
            for token in parts {
                // The combined form `shard=N@req=M` (and `payload=N@req=M`)
                // is two key=value pairs joined by '@'.
                for kv in token.split('@') {
                    let (key, value) = kv
                        .split_once('=')
                        .ok_or_else(|| format!("bad fault token '{token}' in '{clause_text}'"))?;
                    let bad = |what: &str| format!("bad {key} '{value}' in '{clause_text}': {what}");
                    match key {
                        "p" => {
                            let p: f64 =
                                value.parse().map_err(|_| bad("expected a probability"))?;
                            if !(0.0..=1.0).contains(&p) {
                                return Err(bad("must be in [0, 1]"));
                            }
                            c.p = Some(p);
                        }
                        "ms" => c.ms = value.parse().map_err(|_| bad("expected milliseconds"))?,
                        "req" => c.req = Some(value.parse().map_err(|_| bad("expected a seq"))?),
                        "shard" => {
                            c.shard = Some(value.parse().map_err(|_| bad("expected a shard id"))?)
                        }
                        "payload" => {
                            c.payload =
                                Some(value.parse().map_err(|_| bad("expected a payload slot"))?)
                        }
                        "at" => c.at_ms = Some(value.parse().map_err(|_| bad("expected ms"))?),
                        "period" => {
                            let per: u64 = value.parse().map_err(|_| bad("expected ms"))?;
                            if per == 0 {
                                return Err(bad("period must be >= 1 ms"));
                            }
                            c.period_ms = Some(per);
                        }
                        other => {
                            return Err(format!(
                                "unknown fault token '{other}' in '{clause_text}' \
                                 (expected p|ms|req|shard|payload|at|period)"
                            ))
                        }
                    }
                }
            }
            clauses.push(c);
        }
        Ok(Self { clauses })
    }
}

// ---------------------------------------------------------------------
// deterministic decisions
// ---------------------------------------------------------------------

/// SplitMix64 finalizer: the avalanche step behind every injection and
/// jitter decision.
fn mix(mut x: u64) -> u64 {
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Uniform [0, 1) as a pure function of its inputs. NOT a sequential RNG
/// draw: two threads evaluating the same `(seed, stream, seq, attempt)`
/// get the same value, which is what makes chaos runs thread-count
/// -invariant.
fn hash_unit(seed: u64, stream: u64, seq: u64, attempt: u64) -> f64 {
    let h = mix(
        seed ^ mix(stream.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            ^ mix(seq.wrapping_add(0x517C_C1B7_2722_0A95))
            ^ mix(attempt.wrapping_add(0x6A09_E667_F3BC_C909)),
    );
    (h >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Identity of one execution attempt: which request, where, which try.
/// Everything an injection decision may depend on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RequestTag {
    /// Shard the attempt executes on.
    pub shard: usize,
    /// Payload slot (index into `ServeSpec::payloads`).
    pub slot: usize,
    /// Global arrival/request sequence number.
    pub seq: u64,
    /// 0 for the first try, +1 per retry.
    pub attempt: u32,
}

/// What the plan decided for one attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    Error,
    Hang { ms: u64 },
    Crash,
}

/// Injection counters of one plan, by kind.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounts {
    pub errors: usize,
    pub hangs: usize,
    pub crashes: usize,
}

impl FaultCounts {
    pub fn total(&self) -> usize {
        self.errors + self.hangs + self.crashes
    }

    pub fn merge(&mut self, other: &FaultCounts) {
        self.errors += other.errors;
        self.hangs += other.hangs;
        self.crashes += other.crashes;
    }
}

/// A live fault plan: the parsed spec, the decision seed, and per-shard
/// injection counters. Shared (via `Arc`) between the [`FaultyBackend`]
/// and the report assembly.
#[derive(Debug)]
pub struct FaultPlan {
    spec: FaultSpec,
    seed: u64,
    /// Injection counts indexed by shard (grown on demand; counting
    /// locks only when a fault actually fires).
    counts: Mutex<Vec<FaultCounts>>,
}

impl FaultPlan {
    pub fn new(spec: FaultSpec, seed: u64) -> Self {
        Self { spec, seed, counts: Mutex::new(Vec::new()) }
    }

    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    /// Decide (and count) what happens to one attempt. First matching
    /// clause wins; `req=`-selected clauses fire on attempt 0 only (so a
    /// retry recovers), probabilistic clauses re-roll per attempt via
    /// the pure hash.
    pub fn decide(&self, tag: RequestTag) -> Option<FaultAction> {
        for (idx, c) in self.spec.clauses.iter().enumerate() {
            if c.is_sim() || c.is_boot_crash() {
                continue; // virtual-time / startup clauses: not per-request
            }
            if c.shard.is_some_and(|s| s != tag.shard)
                || c.payload.is_some_and(|p| p != tag.slot)
            {
                continue;
            }
            let fires = match (c.req, c.p) {
                (Some(req), _) => tag.seq == req && tag.attempt == 0,
                (None, Some(p)) => {
                    hash_unit(self.seed, idx as u64, tag.seq, tag.attempt as u64) < p
                }
                (None, None) => true,
            };
            if !fires {
                continue;
            }
            self.count(tag.shard, c.kind);
            return Some(match c.kind {
                FaultKind::Error => FaultAction::Error,
                FaultKind::Hang => FaultAction::Hang { ms: c.ms },
                FaultKind::Crash => FaultAction::Crash,
            });
        }
        None
    }

    /// Panic if a boot-crash clause targets `shard` (the crashing-shard
    /// -process scenario the fleet's `catch_unwind` must contain).
    pub fn check_boot(&self, shard: usize) {
        for c in &self.spec.clauses {
            if c.is_boot_crash() && c.shard.is_none_or(|s| s == shard) {
                self.count(shard, FaultKind::Crash);
                panic!("injected boot crash on shard {shard}");
            }
        }
    }

    fn count(&self, shard: usize, kind: FaultKind) {
        let mut counts = lock_recover(&self.counts);
        if counts.len() <= shard {
            counts.resize(shard + 1, FaultCounts::default());
        }
        match kind {
            FaultKind::Error => counts[shard].errors += 1,
            FaultKind::Hang => counts[shard].hangs += 1,
            FaultKind::Crash => counts[shard].crashes += 1,
        }
    }

    /// Injections attributed to `shard` so far.
    pub fn counts_for(&self, shard: usize) -> FaultCounts {
        lock_recover(&self.counts).get(shard).copied().unwrap_or_default()
    }

    /// Injections across every shard.
    pub fn counts_total(&self) -> FaultCounts {
        let mut total = FaultCounts::default();
        for c in lock_recover(&self.counts).iter() {
            total.merge(c);
        }
        total
    }
}

// ---------------------------------------------------------------------
// faulty backend
// ---------------------------------------------------------------------

/// A [`ServeBackend`] wrapper injecting the plan's faults into every
/// tagged execution. Warm-ups (untagged `execute`) pass through clean:
/// faults target the recorded request stream, where the accounting can
/// see them.
pub struct FaultyBackend<B> {
    inner: B,
    plan: Arc<FaultPlan>,
}

impl<B> FaultyBackend<B> {
    pub fn new(inner: B, plan: Arc<FaultPlan>) -> Self {
        Self { inner, plan }
    }

    pub fn plan(&self) -> &Arc<FaultPlan> {
        &self.plan
    }
}

impl<B: ServeBackend> ServeBackend for FaultyBackend<B> {
    fn resolve(&self, payload: &str) -> Result<ResolvedPayload> {
        self.inner.resolve(payload)
    }

    fn executor(&self) -> Result<Box<dyn PayloadExecutor>> {
        Ok(Box::new(FaultyExecutor {
            inner: self.inner.executor()?,
            plan: Arc::clone(&self.plan),
        }))
    }

    fn fault_plan(&self) -> Option<&FaultPlan> {
        Some(&self.plan)
    }
}

struct FaultyExecutor {
    inner: Box<dyn PayloadExecutor>,
    plan: Arc<FaultPlan>,
}

impl PayloadExecutor for FaultyExecutor {
    fn execute(&self, payload: usize, inputs: &[Vec<f32>]) -> Result<Vec<f32>> {
        // Untagged path (warm-ups): no injection.
        self.inner.execute(payload, inputs)
    }

    fn execute_tagged(
        &self,
        payload: usize,
        inputs: &[Vec<f32>],
        tag: RequestTag,
    ) -> Result<Vec<f32>> {
        match self.plan.decide(tag) {
            Some(FaultAction::Error) => Err(anyhow!(
                "injected fault: error at shard {} seq {} attempt {}",
                tag.shard,
                tag.seq,
                tag.attempt
            )),
            Some(FaultAction::Crash) => panic!(
                "injected fault: crash at shard {} seq {} attempt {}",
                tag.shard, tag.seq, tag.attempt
            ),
            Some(FaultAction::Hang { ms }) => {
                // A hung/slow kernel: stall, then execute normally. Long
                // enough, this overstays a gate lease and the watchdog
                // revokes the grant out from under us — which is safe for
                // a CPU-bound backend (see DESIGN.md §12).
                std::thread::sleep(Duration::from_millis(ms));
                self.inner.execute_tagged(payload, inputs, tag)
            }
            None => self.inner.execute_tagged(payload, inputs, tag),
        }
    }
}

// ---------------------------------------------------------------------
// retries
// ---------------------------------------------------------------------

/// Request-level retry policy: a per-request attempt budget with bounded
/// exponential backoff and deterministic seeded jitter (a pure hash of
/// `(seed, seq, attempt)`, like every fault decision).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Retries per request beyond the first attempt (0 = no retries).
    pub budget: u32,
    /// Backoff before retry k: `base_ms * 2^k`, jittered, capped.
    pub base_ms: f64,
    /// Backoff ceiling, milliseconds.
    pub cap_ms: f64,
    /// Jitter seed.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self { budget: 0, base_ms: 1.0, cap_ms: 50.0, seed: 0 }
    }
}

impl RetryPolicy {
    pub fn with_budget(budget: u32) -> Self {
        Self { budget, ..Self::default() }
    }

    pub fn enabled(&self) -> bool {
        self.budget > 0
    }

    /// Backoff before retrying `seq`'s attempt number `attempt` (the one
    /// that just failed). Deterministic: the same `(policy, seq,
    /// attempt)` always sleeps the same duration.
    pub fn backoff(&self, seq: u64, attempt: u32) -> Duration {
        let exp = (self.base_ms * 2f64.powi(attempt.min(30) as i32)).min(self.cap_ms);
        // Jitter in [0.5, 1.5): decorrelates retry storms without
        // sacrificing replayability.
        let jitter = 0.5 + hash_unit(self.seed, u64::MAX, seq, attempt as u64);
        Duration::from_secs_f64(exp * jitter / 1e3)
    }
}

// ---------------------------------------------------------------------
// per-shard health
// ---------------------------------------------------------------------

/// The health state machine of one shard.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HealthState {
    /// Serving normally.
    Healthy,
    /// Consecutive failures crossed the degrade threshold; still
    /// accepting, one breaker step from ejection.
    Degraded,
    /// Out of rotation: the router places no new work here. Admitted
    /// work keeps draining (drain-then-eject, DESIGN.md §8).
    Ejected,
    /// Cooldown elapsed: exactly one probe request is in flight.
    Probing,
    /// The probe succeeded; back in rotation, one success from Healthy.
    Reinstated,
}

impl fmt::Display for HealthState {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Self::Healthy => "healthy",
            Self::Degraded => "degraded",
            Self::Ejected => "ejected",
            Self::Probing => "probing",
            Self::Reinstated => "reinstated",
        })
    }
}

/// Circuit-breaker thresholds of the health machine.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Breaker {
    /// Consecutive failures before Healthy -> Degraded.
    pub degrade_after: u32,
    /// Consecutive failures before -> Ejected (a panic ejects at once).
    pub eject_after: u32,
    /// Time out of rotation before the first probe is allowed.
    pub cooldown: Duration,
}

impl Default for Breaker {
    fn default() -> Self {
        Self { degrade_after: 2, eject_after: 5, cooldown: Duration::from_millis(50) }
    }
}

#[derive(Debug)]
struct HealthCore {
    state: HealthState,
    consecutive: u32,
    /// Set on the eject that *started* the current outage; cleared on
    /// reinstatement (time-to-recover spans the whole outage, including
    /// failed probes).
    outage_from: Option<std::time::Instant>,
    /// Reset on every (re-)ejection: the cooldown clock.
    cooled_from: Option<std::time::Instant>,
    probe_inflight: bool,
    ejections: usize,
    reinstatements: usize,
    /// Outage durations (ms), drained into the shard's FaultReport.
    recoveries_ms: Vec<f64>,
}

/// Per-shard breaker state. The fleet dispatcher calls
/// [`ShardHealth::accepting`] before routing an arrival (which is also
/// how cooldown probes get admitted); workers report
/// [`ShardHealth::on_success`]/[`ShardHealth::on_failure`]/
/// [`ShardHealth::on_panic`] per executed request.
#[derive(Debug)]
pub struct ShardHealth {
    breaker: Breaker,
    core: Mutex<HealthCore>,
}

impl ShardHealth {
    pub fn new(breaker: Breaker) -> Self {
        Self {
            breaker,
            core: Mutex::new(HealthCore {
                state: HealthState::Healthy,
                consecutive: 0,
                outage_from: None,
                cooled_from: None,
                probe_inflight: false,
                ejections: 0,
                reinstatements: 0,
                recoveries_ms: Vec::new(),
            }),
        }
    }

    pub fn state(&self) -> HealthState {
        lock_recover(&self.core).state
    }

    /// May new work be placed here right now? Ejected shards flip to
    /// Probing (admitting exactly one probe) once the cooldown elapsed.
    pub fn accepting(&self) -> bool {
        let mut core = lock_recover(&self.core);
        match core.state {
            HealthState::Healthy | HealthState::Degraded | HealthState::Reinstated => true,
            HealthState::Ejected => {
                let cooled = core
                    .cooled_from
                    .is_some_and(|t| t.elapsed() >= self.breaker.cooldown);
                if cooled {
                    core.state = HealthState::Probing;
                    core.probe_inflight = true;
                    true
                } else {
                    false
                }
            }
            HealthState::Probing => {
                // One probe at a time; if the previous probe vanished
                // (shed/timed out before executing), admit another.
                if core.probe_inflight {
                    false
                } else {
                    core.probe_inflight = true;
                    true
                }
            }
        }
    }

    pub fn on_success(&self) {
        let mut core = lock_recover(&self.core);
        core.consecutive = 0;
        core.state = match core.state {
            HealthState::Probing => {
                core.probe_inflight = false;
                core.reinstatements += 1;
                if let Some(from) = core.outage_from.take() {
                    core.recoveries_ms.push(from.elapsed().as_secs_f64() * 1e3);
                }
                core.cooled_from = None;
                HealthState::Reinstated
            }
            HealthState::Reinstated | HealthState::Healthy | HealthState::Degraded => {
                HealthState::Healthy
            }
            // A straggler success from before the eject: stay out.
            HealthState::Ejected => HealthState::Ejected,
        };
    }

    /// One failed request; returns the new state.
    pub fn on_failure(&self) -> HealthState {
        self.fail(false)
    }

    /// One panicked request: ejects immediately.
    pub fn on_panic(&self) -> HealthState {
        self.fail(true)
    }

    fn fail(&self, panicked: bool) -> HealthState {
        let mut core = lock_recover(&self.core);
        core.consecutive = core.consecutive.saturating_add(1);
        let eject = panicked
            || core.consecutive >= self.breaker.eject_after
            || core.state == HealthState::Probing;
        core.state = if eject {
            if core.state != HealthState::Ejected {
                core.ejections += 1;
            }
            core.probe_inflight = false;
            if core.outage_from.is_none() {
                core.outage_from = Some(std::time::Instant::now());
            }
            core.cooled_from = Some(std::time::Instant::now());
            HealthState::Ejected
        } else if core.consecutive >= self.breaker.degrade_after {
            HealthState::Degraded
        } else {
            core.state
        };
        core.state
    }

    /// Outage durations closed since the last drain (ms).
    pub fn drain_recoveries_ms(&self) -> Vec<f64> {
        std::mem::take(&mut lock_recover(&self.core).recoveries_ms)
    }

    pub fn snapshot(&self) -> HealthSnapshot {
        let core = lock_recover(&self.core);
        HealthSnapshot {
            state: core.state,
            ejections: core.ejections,
            reinstatements: core.reinstatements,
        }
    }
}

impl Default for ShardHealth {
    fn default() -> Self {
        Self::new(Breaker::default())
    }
}

/// Point-in-time health of one shard, surfaced in `ShardReport`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct HealthSnapshot {
    pub state: HealthState,
    pub ejections: usize,
    pub reinstatements: usize,
}

// ---------------------------------------------------------------------
// report
// ---------------------------------------------------------------------

/// Fault accounting of one serving run (or one shard's slice): what was
/// injected, what the serving layer saw, and how recovery went.
#[derive(Debug, Clone, Default)]
pub struct FaultReport {
    /// Faults the plan injected (by kind).
    pub injected: FaultCounts,
    /// Failures the serving layer observed (injected or organic;
    /// includes every failed attempt).
    pub detected: usize,
    /// Retry attempts issued (local re-executions and re-routes).
    pub retried: usize,
    /// Requests that failed at least once, then completed.
    pub recovered: usize,
    /// Requests that exhausted the retry budget.
    pub gave_up: usize,
    /// Gate-lease revocations (hung holders the watchdog cut off).
    pub revocations: u64,
    /// Shard ejections / reinstatements across the run.
    pub ejections: usize,
    pub reinstatements: usize,
    /// Time from attempt start to failure detection, ms.
    pub detect_ms: QuantileSketch,
    /// Time from first failure to recovery, ms (request recoveries and
    /// shard outage recoveries both land here).
    pub recover_ms: QuantileSketch,
}

impl FaultReport {
    /// Nothing injected, detected or revoked?
    pub fn is_empty(&self) -> bool {
        self.injected.total() == 0
            && self.detected == 0
            && self.retried == 0
            && self.gave_up == 0
            && self.revocations == 0
            && self.ejections == 0
    }

    /// Record one observed failure.
    pub fn record_failure(&mut self, detect_ms: f64) {
        self.detected += 1;
        self.detect_ms.record(detect_ms);
    }

    /// Record one request that recovered after failing.
    pub fn record_recovery(&mut self, recover_ms: f64) {
        self.recovered += 1;
        self.recover_ms.record(recover_ms);
    }

    pub fn merge(&mut self, other: &FaultReport) {
        self.injected.merge(&other.injected);
        self.detected += other.detected;
        self.retried += other.retried;
        self.recovered += other.recovered;
        self.gave_up += other.gave_up;
        self.revocations += other.revocations;
        self.ejections += other.ejections;
        self.reinstatements += other.reinstatements;
        self.detect_ms.merge(&other.detect_ms);
        self.recover_ms.merge(&other.recover_ms);
    }

    /// Two-line human rendering (serving reports).
    pub fn render(&self) -> String {
        let mut out = format!(
            "faults: injected={} (errors={} hangs={} crashes={}) detected={} \
             retried={} recovered={} gave-up={} revoked={} ejected={} reinstated={}",
            self.injected.total(),
            self.injected.errors,
            self.injected.hangs,
            self.injected.crashes,
            self.detected,
            self.retried,
            self.recovered,
            self.gave_up,
            self.revocations,
            self.ejections,
            self.reinstatements,
        );
        if self.detect_ms.count() > 0 || self.recover_ms.count() > 0 {
            out.push_str(&format!(
                "\ndetect ms p50={:.2} p99={:.2}; recover ms p50={:.2} p99={:.2}",
                self.detect_ms.quantile(0.50),
                self.detect_ms.quantile(0.99),
                self.recover_ms.quantile(0.50),
                self.recover_ms.quantile(0.99),
            ));
        }
        out
    }
}

// ---------------------------------------------------------------------
// panic payloads
// ---------------------------------------------------------------------

/// Recover the human-readable message from a caught panic payload
/// (thread joins used to discard it — ISSUE 7 satellite).
pub fn panic_msg(e: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::control::serving::SyntheticBackend;

    // ------------------------------------------------------------ spec --

    #[test]
    fn parse_display_roundtrip() {
        for text in [
            "error:p=0.01",
            "hang:shard=2@req=500:ms=50",
            "crash:payload=1@req=100",
            "error:p=0.01,hang:shard=2@req=500:ms=50,crash:payload=1@req=100",
            "crash:shard=1",
            "hang:at=20:ms=5",
            "hang:period=100:ms=3",
        ] {
            let spec: FaultSpec = text.parse().unwrap_or_else(|e| panic!("{text}: {e}"));
            let rendered = spec.to_string();
            let reparsed: FaultSpec = rendered.parse().unwrap();
            assert_eq!(reparsed, spec, "{text} -> {rendered}");
        }
        assert!("".parse::<FaultSpec>().unwrap().is_empty());
        assert!("none".parse::<FaultSpec>().unwrap().is_empty());
    }

    #[test]
    fn parse_rejects_nonsense() {
        assert!("explode:p=0.1".parse::<FaultSpec>().is_err());
        assert!("error:p=1.5".parse::<FaultSpec>().is_err());
        assert!("error:p=x".parse::<FaultSpec>().is_err());
        assert!("error:frob=1".parse::<FaultSpec>().is_err());
        assert!("hang:period=0".parse::<FaultSpec>().is_err());
        assert!("error:p".parse::<FaultSpec>().is_err());
    }

    #[test]
    fn clause_classification() {
        let spec: FaultSpec = "crash:shard=1,hang:at=5:ms=2,error:p=0.5".parse().unwrap();
        assert!(spec.clauses[0].is_boot_crash());
        assert!(spec.clauses[1].is_sim());
        assert!(spec.has_sim_clauses());
        assert!(!spec.clauses[2].is_sim());
        assert!(!spec.clauses[2].is_boot_crash());
    }

    // ------------------------------------------------------- decisions --

    fn tag(shard: usize, slot: usize, seq: u64, attempt: u32) -> RequestTag {
        RequestTag { shard, slot, seq, attempt }
    }

    #[test]
    fn decisions_are_pure_functions_of_the_tag() {
        let plan = FaultPlan::new("error:p=0.3".parse().unwrap(), 7);
        let a: Vec<_> = (0..200).map(|s| plan.decide(tag(0, 0, s, 0))).collect();
        let b: Vec<_> = (0..200).map(|s| plan.decide(tag(0, 0, s, 0))).collect();
        assert_eq!(a, b, "same tag, same decision — regardless of call order");
        let hits = a.iter().filter(|d| d.is_some()).count();
        assert!((30..90).contains(&hits), "p=0.3 over 200: got {hits}");
        // A different seed decides differently somewhere.
        let other = FaultPlan::new("error:p=0.3".parse().unwrap(), 8);
        let c: Vec<_> = (0..200).map(|s| other.decide(tag(0, 0, s, 0))).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn selectors_restrict_and_req_fires_once() {
        let plan =
            FaultPlan::new("hang:shard=2@req=500:ms=50,crash:payload=1@req=100".parse().unwrap(), 0);
        assert_eq!(
            plan.decide(tag(2, 0, 500, 0)),
            Some(FaultAction::Hang { ms: 50 })
        );
        assert_eq!(plan.decide(tag(1, 0, 500, 0)), None, "wrong shard");
        assert_eq!(plan.decide(tag(2, 0, 501, 0)), None, "wrong seq");
        assert_eq!(plan.decide(tag(2, 0, 500, 1)), None, "req fires on attempt 0 only");
        assert_eq!(plan.decide(tag(0, 1, 100, 0)), Some(FaultAction::Crash));
        assert_eq!(plan.decide(tag(0, 0, 100, 0)), None, "wrong payload slot");
        let c = plan.counts_total();
        assert_eq!((c.hangs, c.crashes, c.errors), (1, 1, 0));
        assert_eq!(plan.counts_for(2).hangs, 1);
        assert_eq!(plan.counts_for(0).crashes, 1);
    }

    #[test]
    fn p_zero_never_fires_p_one_always() {
        let never = FaultPlan::new("error:p=0".parse().unwrap(), 3);
        let always = FaultPlan::new("error:p=1".parse().unwrap(), 3);
        for s in 0..100 {
            assert_eq!(never.decide(tag(0, 0, s, 0)), None);
            assert_eq!(always.decide(tag(0, 0, s, 0)), Some(FaultAction::Error));
        }
    }

    #[test]
    fn boot_crash_clauses_skip_per_request_matching() {
        let plan = FaultPlan::new("crash:shard=1".parse().unwrap(), 0);
        assert_eq!(plan.decide(tag(1, 0, 0, 0)), None);
        let contained = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            plan.check_boot(1);
        }));
        assert!(contained.is_err(), "boot crash must panic for its shard");
        let ok = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            plan.check_boot(0);
        }));
        assert!(ok.is_ok(), "other shards boot fine");
        assert_eq!(plan.counts_for(1).crashes, 1);
    }

    // --------------------------------------------------- faulty backend --

    #[test]
    fn faulty_backend_injects_errors_and_passes_warmups() {
        let plan = Arc::new(FaultPlan::new("error:p=1".parse().unwrap(), 0));
        let fb = FaultyBackend::new(SyntheticBackend::new(5), Arc::clone(&plan));
        assert!(fb.fault_plan().is_some());
        let rp = fb.resolve("dna").unwrap();
        let exec = fb.executor().unwrap();
        // Warm-up (untagged): clean.
        assert!(exec.execute(rp.index, &rp.base_inputs).is_ok());
        // Tagged: injected.
        let err = exec
            .execute_tagged(rp.index, &rp.base_inputs, tag(0, 0, 1, 0))
            .unwrap_err();
        assert!(err.to_string().contains("injected fault"), "{err}");
        assert_eq!(plan.counts_total().errors, 1);
    }

    #[test]
    fn faulty_backend_crash_panics() {
        let plan = Arc::new(FaultPlan::new("crash:req=0".parse().unwrap(), 0));
        let fb = FaultyBackend::new(SyntheticBackend::new(5), plan);
        let rp = fb.resolve("dna").unwrap();
        let exec = fb.executor().unwrap();
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = exec.execute_tagged(rp.index, &rp.base_inputs, tag(0, 0, 0, 0));
        }));
        assert!(caught.is_err());
        assert_eq!(panic_msg(caught.unwrap_err()), "injected fault: crash at shard 0 seq 0 attempt 0");
    }

    // ----------------------------------------------------------- retry --

    #[test]
    fn backoff_is_bounded_exponential_and_deterministic() {
        let rp = RetryPolicy { budget: 5, base_ms: 2.0, cap_ms: 10.0, seed: 1 };
        assert!(rp.enabled());
        assert_eq!(rp.backoff(9, 2), rp.backoff(9, 2), "deterministic jitter");
        for attempt in 0..6 {
            let d = rp.backoff(9, attempt).as_secs_f64() * 1e3;
            let exp = (2.0 * 2f64.powi(attempt as i32)).min(10.0);
            assert!(d >= exp * 0.5 - 1e-9 && d < exp * 1.5 + 1e-9, "attempt {attempt}: {d} ms");
        }
        assert_ne!(rp.backoff(9, 1), rp.backoff(10, 1), "jitter varies by seq");
        assert!(!RetryPolicy::default().enabled());
    }

    // ---------------------------------------------------------- health --

    fn fast_breaker() -> Breaker {
        Breaker { degrade_after: 2, eject_after: 3, cooldown: Duration::from_millis(5) }
    }

    #[test]
    fn breaker_walks_the_full_state_machine() {
        let h = ShardHealth::new(fast_breaker());
        assert_eq!(h.state(), HealthState::Healthy);
        assert!(h.accepting());
        h.on_failure();
        assert_eq!(h.state(), HealthState::Healthy, "one failure is noise");
        h.on_failure();
        assert_eq!(h.state(), HealthState::Degraded);
        assert!(h.accepting(), "degraded still serves");
        h.on_failure();
        assert_eq!(h.state(), HealthState::Ejected);
        assert!(!h.accepting(), "no routing before cooldown");
        std::thread::sleep(Duration::from_millis(8));
        assert!(h.accepting(), "cooldown over: one probe admitted");
        assert_eq!(h.state(), HealthState::Probing);
        assert!(!h.accepting(), "only one probe in flight");
        h.on_success();
        assert_eq!(h.state(), HealthState::Reinstated);
        assert!(h.accepting());
        h.on_success();
        assert_eq!(h.state(), HealthState::Healthy);
        let snap = h.snapshot();
        assert_eq!((snap.ejections, snap.reinstatements), (1, 1));
        let rec = h.drain_recoveries_ms();
        assert_eq!(rec.len(), 1);
        assert!(rec[0] >= 5.0, "outage spanned at least the cooldown: {rec:?}");
        assert!(h.drain_recoveries_ms().is_empty(), "drain is once");
    }

    #[test]
    fn panic_ejects_immediately_and_failed_probe_re_ejects() {
        let h = ShardHealth::new(fast_breaker());
        assert_eq!(h.on_panic(), HealthState::Ejected);
        assert_eq!(h.snapshot().ejections, 1);
        std::thread::sleep(Duration::from_millis(8));
        assert!(h.accepting());
        assert_eq!(h.on_failure(), HealthState::Ejected, "failed probe goes back out");
        assert_eq!(h.snapshot().ejections, 2);
        assert!(!h.accepting(), "cooldown restarts");
        std::thread::sleep(Duration::from_millis(8));
        assert!(h.accepting());
        h.on_success();
        assert_eq!(h.state(), HealthState::Reinstated);
        // One outage, spanning both ejections.
        assert_eq!(h.drain_recoveries_ms().len(), 1);
    }

    // ------------------------------------------------------- sim mirror --

    #[test]
    fn sim_schedule_is_seeded_sorted_and_filtered() {
        let spec: FaultSpec = "hang:at=20:ms=5,hang:period=50:ms=3:shard=1".parse().unwrap();
        let horizon = 1_000_000_000; // 1 s
        let a = spec.sim_schedule(0, 0, horizon, 42);
        assert_eq!(a, vec![(20_000_000, 5_000_000)], "shard 0 sees only the at= clause");
        let b = spec.sim_schedule(0, 1, horizon, 42);
        assert!(b.len() > 2, "periodic clause fires repeatedly: {}", b.len());
        assert!(b.windows(2).all(|w| w[0].0 <= w[1].0), "sorted by time");
        assert!(b.iter().all(|&(t, _)| t < horizon));
        assert_eq!(b, spec.sim_schedule(0, 1, horizon, 42), "seed-deterministic");
        assert_ne!(b, spec.sim_schedule(0, 1, horizon, 43));
        // Per-request clauses contribute nothing to virtual time.
        let live: FaultSpec = "error:p=0.5,crash:req=3".parse().unwrap();
        assert!(live.sim_schedule(0, 0, horizon, 1).is_empty());
    }

    // ---------------------------------------------------------- report --

    #[test]
    fn report_merge_and_render() {
        let mut r = FaultReport::default();
        assert!(r.is_empty());
        r.injected.errors = 3;
        r.injected.hangs = 1;
        r.record_failure(4.0);
        r.record_failure(6.0);
        r.retried = 2;
        r.record_recovery(12.0);
        r.gave_up = 1;
        r.revocations = 1;
        r.ejections = 1;
        r.reinstatements = 1;
        assert!(!r.is_empty());
        let mut m = r.clone();
        m.merge(&r);
        assert_eq!(m.injected.total(), 8);
        assert_eq!(m.detected, 4);
        assert_eq!(m.recovered, 2);
        assert_eq!(m.revocations, 2);
        assert_eq!(m.detect_ms.count(), 4);
        let text = m.render();
        assert!(text.contains("injected=8"), "{text}");
        assert!(text.contains("gave-up=2"), "{text}");
        assert!(text.contains("revoked=2"), "{text}");
        assert!(text.contains("recover ms"), "{text}");
    }

    #[test]
    fn panic_msg_downcasts_common_payloads() {
        let s = std::panic::catch_unwind(|| panic!("static str")).unwrap_err();
        assert_eq!(panic_msg(s), "static str");
        let owned = std::panic::catch_unwind(|| panic!("{}", String::from("formatted"))).unwrap_err();
        assert_eq!(panic_msg(owned), "formatted");
        let odd = std::panic::catch_unwind(|| std::panic::panic_any(17u32)).unwrap_err();
        assert_eq!(panic_msg(odd), "non-string panic payload");
    }
}
