//! Pluggable GPU concurrency modes (ISSUE 9, DESIGN.md §14).
//!
//! The paper's thesis is *serialized* access: one `GPU_LOCK` holder at a
//! time. Real deployments instead pick MPS spatial sharing, MIG hard
//! partitions, or priority streams — the mechanisms the related
//! characterization papers enumerate. This module extracts the
//! serialization assumption, hard-coded in four layers at once
//! (`gate`, `lock`, `gpu::engine` dispatch, `serving` burst
//! bracketing), into one [`ConcurrencyMode`] value threaded through all
//! of them:
//!
//! * **`cook`** (default) — the paper: exactly one holder, FIFO gate.
//!   Bit-identical to the pre-refactor engine and gate; the golden
//!   traces pin this.
//! * **`mps:<quota>`** — spatial sharing: up to `quota` concurrent
//!   holders, each restricted to a contiguous SM bank (1/quota of the
//!   device); L2 and copy engines stay shared.
//! * **`mig:<slices>`** — hard partitions: `slices` independent
//!   capacity-1 gates, one per tenant-class slice; SM banks *and* L2
//!   are split so classes never share either.
//! * **`streams`** — priority streams: no admission bound, temporal
//!   scheduling by class priority with preemption only at kernel
//!   boundaries (no mid-batch freeze).
//!
//! The live counterpart is [`ModeGate`]: a thin router over one or more
//! [`GpuGate`]s that keeps the single-gate API so the serving loops are
//! mode-oblivious.

use crate::control::arbiter::{ArbiterKind, TenantClass};
use crate::control::gate::{GateGrant, GateStats, GpuGate};
use std::fmt;
use std::str::FromStr;
use std::time::Duration;

/// What may run on the device concurrently. See the module docs for the
/// semantics of each mode; [`ConcurrencyMode::Cook`] is the default and
/// is bit-identical to the pre-refactor engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ConcurrencyMode {
    /// Exclusive serialized access through the FIFO gate (the paper).
    #[default]
    Cook,
    /// MPS-style spatial sharing: up to `quota` concurrent clients,
    /// each on its own SM bank.
    Mps { quota: usize },
    /// MIG-style hard partitioning: `slices` isolated slices, one per
    /// tenant class (`class % slices`), with split SM banks and L2.
    Mig { slices: usize },
    /// Priority streams: unbounded admission, class-priority temporal
    /// scheduling, preemption only at kernel boundaries.
    Streams,
}

impl ConcurrencyMode {
    /// Does this mode co-schedule clients spatially (concurrent SM
    /// banks) rather than time-slicing one active context?
    pub fn spatial(&self) -> bool {
        matches!(self, ConcurrencyMode::Mps { .. } | ConcurrencyMode::Mig { .. })
    }

    pub fn is_cook(&self) -> bool {
        matches!(self, ConcurrencyMode::Cook)
    }

    /// Capacity of the simulator's per-shard `GpuLock` semaphore under
    /// this mode (how many gated clients may hold it at once).
    pub fn sim_lock_capacity(&self) -> u32 {
        match self {
            ConcurrencyMode::Cook | ConcurrencyMode::Streams => 1,
            ConcurrencyMode::Mps { quota } => (*quota).max(1) as u32,
            ConcurrencyMode::Mig { slices } => (*slices).max(1) as u32,
        }
    }

    /// Concurrent-holder capacity of each live admission gate. `mig`
    /// partitions are capacity-1 *each* (see
    /// [`ConcurrencyMode::partitions`]); `streams` admission is
    /// unbounded — priority acts at the device, not the door.
    pub fn live_capacity(&self) -> usize {
        match self {
            ConcurrencyMode::Cook | ConcurrencyMode::Mig { .. } => 1,
            ConcurrencyMode::Mps { quota } => (*quota).max(1),
            ConcurrencyMode::Streams => usize::MAX,
        }
    }

    /// How many independent admission gates (hard partitions) the mode
    /// needs: `mig` gets one per slice, everyone else shares one.
    pub fn partitions(&self) -> usize {
        match self {
            ConcurrencyMode::Mig { slices } => (*slices).max(1),
            _ => 1,
        }
    }

    /// How many ways the L2 is split. Only `mig` partitions the cache;
    /// `cook`/`streams` serialize and `mps` shares it whole.
    pub fn l2_slices(&self) -> usize {
        self.partitions()
    }
}

impl fmt::Display for ConcurrencyMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ConcurrencyMode::Cook => write!(f, "cook"),
            ConcurrencyMode::Mps { quota } => write!(f, "mps:{quota}"),
            ConcurrencyMode::Mig { slices } => write!(f, "mig:{slices}"),
            ConcurrencyMode::Streams => write!(f, "streams"),
        }
    }
}

impl FromStr for ConcurrencyMode {
    type Err = String;

    /// `cook`, `mps[:quota]`, `mig[:slices]`, `streams` (quota/slices
    /// default to 2 — the smallest non-degenerate split).
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        let (head, arg) = match s.split_once(':') {
            Some((h, a)) => (h, Some(a)),
            None => (s, None),
        };
        let parse_n = |what: &str| -> Result<usize, String> {
            match arg {
                None => Ok(2),
                Some(a) => match a.parse::<usize>() {
                    Ok(n) if n >= 1 => Ok(n),
                    _ => Err(format!("bad {what} '{a}' in concurrency mode '{s}'")),
                },
            }
        };
        match head {
            "cook" if arg.is_none() => Ok(ConcurrencyMode::Cook),
            "streams" if arg.is_none() => Ok(ConcurrencyMode::Streams),
            "mps" => Ok(ConcurrencyMode::Mps { quota: parse_n("quota")? }),
            "mig" => Ok(ConcurrencyMode::Mig { slices: parse_n("slice count")? }),
            _ => Err(format!(
                "unknown concurrency mode '{s}' (want cook|mps[:quota]|mig[:slices]|streams)"
            )),
        }
    }
}

/// Mode-defined admission over one or more [`GpuGate`]s, keeping the
/// single-gate API so the serving loops never branch on the mode:
///
/// * `cook` — one capacity-1 gate, bit-identical to the plain
///   [`GpuGate`] (same FIFO pick-0 short-circuit, same histograms);
/// * `mps:<q>` — one capacity-`q` gate (semaphore-like multi-holder);
/// * `streams` — one unbounded gate (admission never blocks);
/// * `mig:<s>` — `s` capacity-1 gates; a class-`c` client is routed to
///   partition `c % s`, so tenant classes never share an admission
///   queue (or, in the simulator, an SM bank or L2 slice).
///
/// Lease revocation composes per ticket: each grant belongs to exactly
/// one inner gate, and the watchdog revokes exactly that ticket —
/// concurrent holders of a multi-holder gate are untouched.
#[derive(Debug)]
pub struct ModeGate {
    mode: ConcurrencyMode,
    gates: Vec<GpuGate>,
}

impl ModeGate {
    pub fn new(
        mode: ConcurrencyMode,
        arbiter: ArbiterKind,
        classes: &[TenantClass],
        lease: Option<Duration>,
    ) -> Self {
        let gates = (0..mode.partitions())
            .map(|_| GpuGate::with_capacity_config(mode.live_capacity(), arbiter, classes, lease))
            .collect();
        Self { mode, gates }
    }

    pub fn mode(&self) -> ConcurrencyMode {
        self.mode
    }

    /// The configured lease, if any (same on every partition).
    pub fn lease(&self) -> Option<Duration> {
        self.gates[0].lease()
    }

    /// The partition gate serving tenant `class` — the single routing
    /// rule (`class % partitions`, degenerate for every mode but mig).
    fn gate_for(&self, class: usize) -> &GpuGate {
        &self.gates[class % self.gates.len()]
    }

    /// Block until admitted as tenant `class` (class 0 for
    /// [`ModeGate::acquire`]); the grant is tied to the class's
    /// partition gate and releases on drop like any [`GateGrant`].
    pub fn acquire_class(&self, class: usize) -> GateGrant<'_> {
        self.gate_for(class).acquire_class(class)
    }

    pub fn acquire(&self) -> GateGrant<'_> {
        self.acquire_class(0)
    }

    /// Run `f` under the class's partition gate.
    pub fn with_class<T>(&self, class: usize, f: impl FnOnce() -> T) -> T {
        self.gate_for(class).with_class(class, f)
    }

    /// Release an admission (explicit form of dropping the grant).
    pub fn release(&self, grant: GateGrant<'_>) {
        drop(grant);
    }

    /// Merged statistics across partitions, stamped with the mode label
    /// and the *current* concurrent-holder count so multi-holder grants
    /// are debuggable from serve output (ISSUE 9 satellite).
    pub fn stats(&self) -> GateStats {
        let mut out = GateStats::default();
        for g in &self.gates {
            out.merge(&g.stats());
        }
        out.mode = self.mode.to_string();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    #[test]
    fn mode_parse_and_display_round_trip() {
        for s in ["cook", "mps:2", "mps:4", "mig:2", "mig:3", "streams"] {
            let m: ConcurrencyMode = s.parse().unwrap();
            assert_eq!(m.to_string(), s, "round trip");
        }
        assert_eq!("mps".parse::<ConcurrencyMode>().unwrap(), ConcurrencyMode::Mps { quota: 2 });
        assert_eq!("mig".parse::<ConcurrencyMode>().unwrap(), ConcurrencyMode::Mig { slices: 2 });
        assert_eq!(ConcurrencyMode::default(), ConcurrencyMode::Cook);
        for bad in ["", "mps:0", "mig:x", "cook:1", "streams:2", "smp"] {
            assert!(bad.parse::<ConcurrencyMode>().is_err(), "'{bad}' must not parse");
        }
    }

    #[test]
    fn mode_capacity_table() {
        assert_eq!(ConcurrencyMode::Cook.live_capacity(), 1);
        assert_eq!(ConcurrencyMode::Mps { quota: 3 }.live_capacity(), 3);
        assert_eq!(ConcurrencyMode::Mig { slices: 4 }.live_capacity(), 1);
        assert_eq!(ConcurrencyMode::Mig { slices: 4 }.partitions(), 4);
        assert_eq!(ConcurrencyMode::Streams.live_capacity(), usize::MAX);
        assert!(ConcurrencyMode::Mps { quota: 2 }.spatial());
        assert!(ConcurrencyMode::Mig { slices: 2 }.spatial());
        assert!(!ConcurrencyMode::Cook.spatial());
        assert!(!ConcurrencyMode::Streams.spatial());
        assert_eq!(ConcurrencyMode::Mig { slices: 3 }.l2_slices(), 3);
        assert_eq!(ConcurrencyMode::Mps { quota: 3 }.l2_slices(), 1);
    }

    #[test]
    fn cook_mode_gate_serialises_like_the_plain_gate() {
        let gate = Arc::new(ModeGate::new(ConcurrencyMode::Cook, ArbiterKind::Fifo, &[], None));
        let inside = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let (gate, inside, peak) =
                    (Arc::clone(&gate), Arc::clone(&inside), Arc::clone(&peak));
                s.spawn(move || {
                    for _ in 0..10 {
                        gate.with_class(0, || {
                            let now = inside.fetch_add(1, Ordering::SeqCst) + 1;
                            peak.fetch_max(now, Ordering::SeqCst);
                            std::thread::sleep(std::time::Duration::from_micros(20));
                            inside.fetch_sub(1, Ordering::SeqCst);
                        });
                    }
                });
            }
        });
        assert_eq!(peak.load(Ordering::SeqCst), 1, "cook must admit one at a time");
        let s = gate.stats();
        assert_eq!(s.grants(), 40);
        assert_eq!(s.mode, "cook");
        assert!(s.render().contains("gate mode: cook"), "{}", s.render());
    }

    #[test]
    fn mps_mode_gate_admits_up_to_the_quota() {
        let gate =
            Arc::new(ModeGate::new(ConcurrencyMode::Mps { quota: 2 }, ArbiterKind::Fifo, &[], None));
        let inside = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let (gate, inside, peak) =
                    (Arc::clone(&gate), Arc::clone(&inside), Arc::clone(&peak));
                s.spawn(move || {
                    for _ in 0..10 {
                        gate.with_class(0, || {
                            let now = inside.fetch_add(1, Ordering::SeqCst) + 1;
                            peak.fetch_max(now, Ordering::SeqCst);
                            std::thread::sleep(std::time::Duration::from_micros(200));
                            inside.fetch_sub(1, Ordering::SeqCst);
                        });
                    }
                });
            }
        });
        let peak = peak.load(Ordering::SeqCst);
        assert!(peak <= 2, "mps:2 admitted {peak} concurrent holders");
        assert!(peak == 2, "contended mps:2 should reach its quota (got {peak})");
        assert_eq!(gate.stats().grants(), 40);
    }

    #[test]
    fn mig_routes_classes_to_disjoint_partitions() {
        // Same class serializes; different classes proceed concurrently
        // (each partition is its own capacity-1 gate).
        let gate =
            Arc::new(ModeGate::new(ConcurrencyMode::Mig { slices: 2 }, ArbiterKind::Fifo, &[], None));
        let a = gate.acquire_class(0);
        // Class 1 lives on the other partition: must admit immediately
        // even while class 0 holds.
        let b = gate.acquire_class(1);
        gate.release(b);
        gate.release(a);
        let s = gate.stats();
        assert_eq!(s.grants(), 2);
        assert_eq!(s.mode, "mig:2");
        // Same-class critical sections stay mutually exclusive.
        let inside = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..3 {
                let (gate, inside, peak) =
                    (Arc::clone(&gate), Arc::clone(&inside), Arc::clone(&peak));
                s.spawn(move || {
                    for _ in 0..10 {
                        gate.with_class(0, || {
                            let now = inside.fetch_add(1, Ordering::SeqCst) + 1;
                            peak.fetch_max(now, Ordering::SeqCst);
                            std::thread::sleep(std::time::Duration::from_micros(20));
                            inside.fetch_sub(1, Ordering::SeqCst);
                        });
                    }
                });
            }
        });
        assert_eq!(peak.load(Ordering::SeqCst), 1, "one partition must still serialize");
    }

    #[test]
    fn streams_admission_never_blocks() {
        let gate = ModeGate::new(ConcurrencyMode::Streams, ArbiterKind::Fifo, &[], None);
        let grants: Vec<_> = (0..8).map(|i| gate.acquire_class(i % 2)).collect();
        assert_eq!(grants.len(), 8, "unbounded admission");
        let s = gate.stats();
        assert_eq!(s.holders_now, 8, "all 8 concurrently held");
        assert!(s.render().contains("holders now 8"), "{}", s.render());
        drop(grants);
        assert_eq!(gate.stats().holders_now, 0);
        assert_eq!(gate.stats().grants(), 8);
    }
}
