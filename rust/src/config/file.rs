//! Config-file overrides: a flat `key = value` format (TOML subset) that
//! adjusts any timing/platform parameter of a run without recompiling —
//! the knobs the ablation benches sweep, exposed to the CLI
//! (`cook run <spec> --config my.toml`).
//!
//! Example:
//! ```text
//! # my.toml — what-if: slower context switches, deeper prefetch
//! timing.ctx_switch_ns = 60000
//! timing.lock_handoff_ns = 240000
//! platform.hw_prefetch_depth = 2
//! seed = 7
//! ```

use super::SimConfig;
use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ConfigError {
    pub line: usize,
    pub msg: String,
}

impl fmt::Display for ConfigError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "config line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for ConfigError {}

/// Apply `key = value` overrides from `text` onto `cfg`.
pub fn apply_overrides(cfg: &mut SimConfig, text: &str) -> Result<usize, ConfigError> {
    let mut applied = 0;
    for (i, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() || line.starts_with('[') {
            continue; // blank, comment, or section header (flat keys only)
        }
        let (key, value) = line.split_once('=').ok_or_else(|| ConfigError {
            line: i + 1,
            msg: format!("expected `key = value`, got '{line}'"),
        })?;
        let key = key.trim();
        let value = value.trim();
        set_key(cfg, key, value).map_err(|msg| ConfigError { line: i + 1, msg })?;
        applied += 1;
    }
    Ok(applied)
}

fn parse<T: std::str::FromStr>(key: &str, v: &str) -> Result<T, String> {
    v.parse::<T>().map_err(|_| format!("bad value '{v}' for {key}"))
}

/// Set one dotted key. Every tunable of the simulator is reachable here;
/// keep in sync with `TimingConfig`/`PlatformConfig` (the exhaustive test
/// below fails if a field is forgotten).
fn set_key(cfg: &mut SimConfig, key: &str, v: &str) -> Result<(), String> {
    let t = &mut cfg.timing;
    let p = &mut cfg.platform;
    match key {
        "seed" => cfg.seed = parse(key, v)?,
        "horizon_ns" => cfg.horizon_ns = parse(key, v)?,
        "strategy" => cfg.strategy = v.parse()?,
        "num_gpus" => {
            let g: usize = parse(key, v)?;
            if g == 0 {
                return Err("num_gpus must be >= 1".to_string());
            }
            cfg.num_gpus = g;
        }
        "arrivals" => cfg.arrivals = v.parse()?,
        "faults" => cfg.faults = v.parse()?,
        "arbiter" => cfg.arbiter = v.parse()?,
        "classes" => cfg.classes = crate::control::arbiter::parse_classes(v)?,
        "concurrency" => cfg.concurrency = v.parse()?,
        "autoscale" => {
            cfg.autoscale = if v == "none" { None } else { Some(v.parse()?) };
        }
        "arrival_queue_cap" => {
            let c: usize = parse(key, v)?;
            if c == 0 {
                return Err("arrival_queue_cap must be >= 1".to_string());
            }
            cfg.arrival_queue_cap = c;
        }
        // ----------------------------------------------------- timing --
        "timing.launch_overhead_ns" => t.launch_overhead_ns = parse(key, v)?,
        "timing.memcpy_call_extra_ns" => t.memcpy_call_extra_ns = parse(key, v)?,
        "timing.sync_wakeup_ns" => t.sync_wakeup_ns = parse(key, v)?,
        "timing.dispatch_ns" => t.dispatch_ns = parse(key, v)?,
        "timing.copy_bytes_per_us" => t.copy_bytes_per_us = parse(key, v)?,
        "timing.copy_setup_ns" => t.copy_setup_ns = parse(key, v)?,
        "timing.ctx_quantum_ns" => t.ctx_quantum_ns = parse(key, v)?,
        "timing.ctx_switch_ns" => t.ctx_switch_ns = parse(key, v)?,
        "timing.idle_switch_ns" => t.idle_switch_ns = parse(key, v)?,
        "timing.crpd_ns" => t.crpd_ns = parse(key, v)?,
        "timing.cb_dispatch_ns" => t.cb_dispatch_ns = parse(key, v)?,
        "timing.cb_exec_ns" => t.cb_exec_ns = parse(key, v)?,
        "timing.cb_steal_ns" => t.cb_steal_ns = parse(key, v)?,
        "timing.lock_handoff_ns" => t.lock_handoff_ns = parse(key, v)?,
        "timing.cb_wake_ns" => t.cb_wake_ns = parse(key, v)?,
        "timing.worker_enqueue_ns" => t.worker_enqueue_ns = parse(key, v)?,
        "timing.worker_dequeue_ns" => t.worker_dequeue_ns = parse(key, v)?,
        "timing.worker_contention_ns" => t.worker_contention_ns = parse(key, v)?,
        "timing.jitter_amp" => t.jitter_amp = parse(key, v)?,
        "timing.stall_prob" => t.stall_prob = parse(key, v)?,
        "timing.stall_alpha" => t.stall_alpha = parse(key, v)?,
        "timing.stall_cap" => t.stall_cap = parse(key, v)?,
        "timing.stall_window_ns" => t.stall_window_ns = parse(key, v)?,
        "timing.inherent_tail_prob" => t.inherent_tail_prob = parse(key, v)?,
        "timing.inherent_tail_cap" => t.inherent_tail_cap = parse(key, v)?,
        // --------------------------------------------------- platform --
        "platform.num_sms" => p.num_sms = parse(key, v)?,
        "platform.smps_per_sm" => p.smps_per_sm = parse(key, v)?,
        "platform.max_blocks_per_sm" => p.max_blocks_per_sm = parse(key, v)?,
        "platform.max_warps_per_sm" => p.max_warps_per_sm = parse(key, v)?,
        "platform.max_threads_per_block" => p.max_threads_per_block = parse(key, v)?,
        "platform.warp_size" => p.warp_size = parse(key, v)?,
        "platform.l2_bytes" => p.l2_bytes = parse(key, v)?,
        "platform.copy_engines" => p.copy_engines = parse(key, v)?,
        "platform.driver_queue_depth" => p.driver_queue_depth = parse(key, v)?,
        "platform.callback_threads" => p.callback_threads = parse(key, v)?,
        "platform.hw_prefetch_depth" => p.hw_prefetch_depth = parse(key, v)?,
        other => return Err(format!("unknown key '{other}'")),
    }
    Ok(())
}

/// All recognised keys (docs + exhaustiveness checks).
pub const KEYS: &[&str] = &[
    "seed",
    "horizon_ns",
    "strategy",
    "num_gpus",
    "arrivals",
    "arrival_queue_cap",
    "faults",
    "arbiter",
    "classes",
    "concurrency",
    "autoscale",
    "timing.launch_overhead_ns",
    "timing.memcpy_call_extra_ns",
    "timing.sync_wakeup_ns",
    "timing.dispatch_ns",
    "timing.copy_bytes_per_us",
    "timing.copy_setup_ns",
    "timing.ctx_quantum_ns",
    "timing.ctx_switch_ns",
    "timing.idle_switch_ns",
    "timing.crpd_ns",
    "timing.cb_dispatch_ns",
    "timing.cb_exec_ns",
    "timing.cb_steal_ns",
    "timing.lock_handoff_ns",
    "timing.cb_wake_ns",
    "timing.worker_enqueue_ns",
    "timing.worker_dequeue_ns",
    "timing.worker_contention_ns",
    "timing.jitter_amp",
    "timing.stall_prob",
    "timing.stall_alpha",
    "timing.stall_cap",
    "timing.stall_window_ns",
    "timing.inherent_tail_prob",
    "timing.inherent_tail_cap",
    "platform.num_sms",
    "platform.smps_per_sm",
    "platform.max_blocks_per_sm",
    "platform.max_warps_per_sm",
    "platform.max_threads_per_block",
    "platform.warp_size",
    "platform.l2_bytes",
    "platform.copy_engines",
    "platform.driver_queue_depth",
    "platform.callback_threads",
    "platform.hw_prefetch_depth",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::StrategyKind;

    #[test]
    fn applies_overrides() {
        let mut cfg = SimConfig::default();
        let n = apply_overrides(
            &mut cfg,
            "# what-if\n\ntiming.ctx_switch_ns = 99000\nplatform.num_sms = 4\nseed=3\nstrategy = worker\n",
        )
        .unwrap();
        assert_eq!(n, 4);
        assert_eq!(cfg.timing.ctx_switch_ns, 99_000);
        assert_eq!(cfg.platform.num_sms, 4);
        assert_eq!(cfg.seed, 3);
        assert_eq!(cfg.strategy, StrategyKind::Worker);
    }

    #[test]
    fn rejects_unknown_key_with_line_number() {
        let mut cfg = SimConfig::default();
        let err = apply_overrides(&mut cfg, "\ntiming.bogus = 1\n").unwrap_err();
        assert_eq!(err.line, 2);
        assert!(err.msg.contains("unknown key"));
    }

    #[test]
    fn rejects_bad_value() {
        let mut cfg = SimConfig::default();
        let err = apply_overrides(&mut cfg, "timing.crpd_ns = soon").unwrap_err();
        assert!(err.msg.contains("bad value"));
    }

    #[test]
    fn rejects_missing_equals() {
        let mut cfg = SimConfig::default();
        assert!(apply_overrides(&mut cfg, "just words").is_err());
    }

    #[test]
    fn every_listed_key_is_settable() {
        let mut cfg = SimConfig::default();
        for key in KEYS {
            let v = match *key {
                "strategy" => "synced",
                "arrivals" => "poisson:200",
                "faults" => "error:p=0.01",
                "arbiter" => "wrr",
                "classes" => "gold:weight=2,free",
                "concurrency" => "mps:2",
                "autoscale" => "1..4",
                _ => "1",
            };
            set_key(&mut cfg, key, v).unwrap_or_else(|e| panic!("{key}: {e}"));
        }
    }

    #[test]
    fn fault_key_parses_and_validates() {
        let mut cfg = SimConfig::default();
        apply_overrides(&mut cfg, "faults = hang:period=10:ms=2,error:p=0.05\n").unwrap();
        assert!(cfg.faults.has_sim_clauses());
        assert!(apply_overrides(&mut cfg, "faults = melt:p=1").is_err());
        apply_overrides(&mut cfg, "faults = none").unwrap();
        assert!(cfg.faults.is_empty());
    }

    #[test]
    fn arrival_keys_parse_and_validate() {
        let mut cfg = SimConfig::default();
        apply_overrides(&mut cfg, "arrivals = bursty:500@10/40\narrival_queue_cap = 8\n")
            .unwrap();
        assert!(cfg.arrivals.is_open_loop());
        assert_eq!(cfg.arrival_queue_cap, 8);
        assert!(apply_overrides(&mut cfg, "arrivals = warp:9").is_err());
        assert!(apply_overrides(&mut cfg, "arrival_queue_cap = 0").is_err());
    }

    #[test]
    fn arbiter_keys_parse_and_validate() {
        use crate::control::arbiter::ArbiterKind;
        let mut cfg = SimConfig::default();
        apply_overrides(
            &mut cfg,
            "arbiter = edf\nclasses = rt:deadline=5:weight=4,batch:slo=50\n",
        )
        .unwrap();
        assert_eq!(cfg.arbiter, ArbiterKind::Edf);
        assert_eq!(cfg.classes.len(), 2);
        assert_eq!(cfg.classes[0].name, "rt");
        assert_eq!(cfg.classes[0].deadline_ms, Some(5));
        assert_eq!(cfg.classes[0].weight, 4);
        assert!(apply_overrides(&mut cfg, "arbiter = lifo").is_err());
        assert!(apply_overrides(&mut cfg, "classes = gold:weight=zero").is_err());
        apply_overrides(&mut cfg, "classes = none").unwrap();
        assert!(cfg.classes.is_empty());
    }

    #[test]
    fn concurrency_key_parses_and_validates() {
        use crate::control::concurrency::ConcurrencyMode;
        let mut cfg = SimConfig::default();
        apply_overrides(&mut cfg, "concurrency = mig:3\n").unwrap();
        assert_eq!(cfg.concurrency, ConcurrencyMode::Mig { slices: 3 });
        assert!(apply_overrides(&mut cfg, "concurrency = smp").is_err());
        assert!(apply_overrides(&mut cfg, "concurrency = mps:0").is_err());
        apply_overrides(&mut cfg, "concurrency = cook").unwrap();
        assert!(cfg.concurrency.is_cook());
    }

    #[test]
    fn autoscale_key_parses_and_validates() {
        use crate::control::elastic::AutoscaleSpec;
        let mut cfg = SimConfig::default();
        apply_overrides(&mut cfg, "autoscale = 1..4\n").unwrap();
        assert_eq!(cfg.autoscale, Some(AutoscaleSpec { min: 1, max: 4 }));
        assert!(apply_overrides(&mut cfg, "autoscale = 4..1").is_err());
        assert!(apply_overrides(&mut cfg, "autoscale = 0..2").is_err());
        assert!(apply_overrides(&mut cfg, "autoscale = wide").is_err());
        apply_overrides(&mut cfg, "autoscale = none").unwrap();
        assert_eq!(cfg.autoscale, None);
    }

    #[test]
    fn zero_num_gpus_rejected_at_parse_time() {
        // Must surface as a config error, not a downstream Sim::new panic.
        let mut cfg = SimConfig::default();
        let err = apply_overrides(&mut cfg, "num_gpus = 0").unwrap_err();
        assert!(err.msg.contains(">= 1"), "{err}");
        apply_overrides(&mut cfg, "num_gpus = 3").unwrap();
        assert_eq!(cfg.num_gpus, 3);
    }

    #[test]
    fn float_keys_accept_fractions() {
        let mut cfg = SimConfig::default();
        apply_overrides(&mut cfg, "timing.stall_prob = 0.01\ntiming.jitter_amp = 0.1").unwrap();
        assert_eq!(cfg.timing.stall_prob, 0.01);
    }

    #[test]
    fn sections_and_comments_ignored() {
        let mut cfg = SimConfig::default();
        let n = apply_overrides(&mut cfg, "[timing]\n# note\ntiming.crpd_ns = 7 # inline\n").unwrap();
        assert_eq!(n, 1);
        assert_eq!(cfg.timing.crpd_ns, 7);
    }
}
