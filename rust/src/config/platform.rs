//! Volta GPU platform parameters (Jetson AGX Xavier, §II of the paper).


/// Hardware shape of the simulated GPU.
///
/// Defaults are the Xavier Volta iGPU: 8 SMs x 4 processing blocks, 64
/// CUDA cores per SM, residency limits of 32 blocks / 64 warps / 2048
/// threads per SM, warps of 32 threads, 512 KiB L2 (Xavier's integrated
/// Volta L2 is 512 KiB), and a single copy engine.
#[derive(Debug, Clone)]
pub struct PlatformConfig {
    /// Streaming multiprocessors on the device.
    pub num_sms: usize,
    /// Processing blocks (SMP) per SM — each with its own warp scheduler.
    pub smps_per_sm: usize,
    /// Maximum resident thread blocks per SM.
    pub max_blocks_per_sm: usize,
    /// Maximum resident warps per SM (register-file limit).
    pub max_warps_per_sm: usize,
    /// Maximum threads per block accepted by the block scheduler.
    pub max_threads_per_block: u32,
    /// Threads per warp (not user controllable on the platform).
    pub warp_size: u32,
    /// Unified L2 cache size in bytes (shared by all SMs).
    pub l2_bytes: u64,
    /// Copy engines moving data between host and device memory.
    pub copy_engines: usize,
    /// Depth of the shared driver queue funneling ops from all contexts.
    pub driver_queue_depth: usize,
    /// Host callback threads per context (drain `cudaLaunchHostFunc` work).
    pub callback_threads: usize,
    /// Kernels/copies the driver may push to the hardware queue past a
    /// still-pending host-func callback (the prefetch that defeats the
    /// callback strategy's isolation, §VII-B).
    pub hw_prefetch_depth: usize,
}

impl Default for PlatformConfig {
    fn default() -> Self {
        Self {
            num_sms: 8,
            smps_per_sm: 4,
            max_blocks_per_sm: 32,
            max_warps_per_sm: 64,
            max_threads_per_block: 1024,
            warp_size: 32,
            l2_bytes: 512 * 1024,
            copy_engines: 1,
            driver_queue_depth: 32,
            callback_threads: 2,
            hw_prefetch_depth: 1,
        }
    }
}

impl PlatformConfig {
    /// Total simultaneous thread capacity of one SM.
    pub fn threads_per_sm(&self) -> u32 {
        self.max_warps_per_sm as u32 * self.warp_size
    }

    /// How many blocks of `threads_per_block` threads fit on one SM at
    /// once, respecting both the block-count and warp-count limits.
    pub fn blocks_resident_per_sm(&self, threads_per_block: u32) -> usize {
        if threads_per_block == 0 {
            return self.max_blocks_per_sm;
        }
        let warps_per_block = threads_per_block.div_ceil(self.warp_size);
        let by_warps = (self.max_warps_per_sm as u32 / warps_per_block.max(1)) as usize;
        by_warps.min(self.max_blocks_per_sm)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn xavier_defaults() {
        let p = PlatformConfig::default();
        assert_eq!(p.num_sms, 8);
        assert_eq!(p.threads_per_sm(), 2048);
    }

    #[test]
    fn residency_limited_by_warps() {
        let p = PlatformConfig::default();
        // 1024-thread blocks = 32 warps each -> only 2 fit in 64 warps.
        assert_eq!(p.blocks_resident_per_sm(1024), 2);
        // 32-thread blocks = 1 warp -> block-count limit (32) dominates.
        assert_eq!(p.blocks_resident_per_sm(32), 32);
        // 256-thread blocks = 8 warps -> 8 blocks.
        assert_eq!(p.blocks_resident_per_sm(256), 8);
    }

    #[test]
    fn residency_degenerate_zero_threads() {
        let p = PlatformConfig::default();
        assert_eq!(p.blocks_resident_per_sm(0), p.max_blocks_per_sm);
    }
}
