//! Configuration system: platform model, timing model, strategies and
//! experiment specs. Experiments are reproducible from the config name +
//! seed alone (see `harness::spec`).

pub mod file;
pub mod platform;
pub mod strategy;
pub mod timing;

pub use file::{apply_overrides, ConfigError};
pub use platform::PlatformConfig;
pub use strategy::StrategyKind;
pub use timing::TimingConfig;

use crate::control::arbiter::{ArbiterKind, TenantClass};
use crate::control::concurrency::ConcurrencyMode;
use crate::control::fault::FaultSpec;
use crate::control::traffic::ArrivalProcess;

/// Full simulator configuration for one run.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub platform: PlatformConfig,
    pub timing: TimingConfig,
    pub strategy: StrategyKind,
    /// RNG seed; together with the config it fully determines the trace.
    pub seed: u64,
    /// Virtual-time horizon; the run stops at this time even if apps loop.
    pub horizon_ns: u64,
    /// Number of independent GPU shards in the simulated fleet. Each
    /// shard has its own SMs, L2, copy engine, context scheduler, and
    /// `GPU_LOCK`; applications are placed round-robin (ctx `i` on shard
    /// `i % num_gpus`). `1` (the default) is exactly the paper's
    /// single-Volta testbed.
    pub num_gpus: usize,
    /// How looping applications are driven. `ClosedLoop` (the default,
    /// the paper's protocol): each app re-runs its routine as fast as
    /// completions allow. Open-loop processes inject seeded arrival
    /// events instead; an iteration starts only when an admitted arrival
    /// is available, mirroring the live serving path's traffic generator
    /// (DESIGN.md §9).
    pub arrivals: ArrivalProcess,
    /// Bound on each app's admitted-arrival backlog under open-loop
    /// arrivals (the simulator mirror of the live admission queue);
    /// arrivals past the bound are shed and counted.
    pub arrival_queue_cap: usize,
    /// Seeded fault injections addressed at virtual time (DESIGN.md
    /// §12): `hang` clauses with `at=`/`period=` selectors stretch the
    /// victim app's next kernel batch, deterministically in (spec,
    /// seed) and invariant under the sharded runner's thread count.
    /// Empty (the default) injects nothing.
    pub faults: FaultSpec,
    /// Grant-ordering policy for every shard's `GPU_LOCK` wake path
    /// (DESIGN.md §13). `Fifo` (the default) reproduces the paper's
    /// semaphore exactly — golden traces are pinned against it.
    pub arbiter: ArbiterKind,
    /// QoS tenant classes; applications map to classes round-robin
    /// (`app i -> class i % classes.len()`), the same assignment the
    /// live serving path uses for clients/requests, so sim and serving
    /// agree on which class starves under overload. Empty (the
    /// default): every app is class 0 and arbitration is degenerate.
    pub classes: Vec<TenantClass>,
    /// What may run on each shard concurrently (DESIGN.md §14): `Cook`
    /// (the default) is the paper's exclusive serialized access,
    /// bit-identical to the pre-refactor engine; `mps:<quota>` shares
    /// SM banks spatially, `mig:<slices>` hard-partitions SM banks and
    /// L2 per tenant class, `streams` schedules by class priority with
    /// preemption only at kernel boundaries.
    pub concurrency: ConcurrencyMode,
    /// Mirrored elastic-controller bounds (DESIGN.md §15): under
    /// open-loop arrivals, the active-shard count follows a
    /// deterministic pre-partition timeline derived from the arrival
    /// schedule ([`crate::control::elastic::plan_windows`]), with
    /// `ScaleDue` events marking each transition. `None` (the default)
    /// keeps every trace bit-identical to the fixed-fleet engine.
    pub autoscale: Option<crate::control::elastic::AutoscaleSpec>,
}

impl Default for SimConfig {
    fn default() -> Self {
        Self {
            platform: PlatformConfig::default(),
            timing: TimingConfig::default(),
            strategy: StrategyKind::None,
            seed: 0,
            horizon_ns: 10_000_000_000, // 10 s of virtual time
            num_gpus: 1,
            arrivals: ArrivalProcess::ClosedLoop,
            arrival_queue_cap: 64,
            faults: FaultSpec::default(),
            arbiter: ArbiterKind::Fifo,
            classes: Vec::new(),
            concurrency: ConcurrencyMode::Cook,
            autoscale: None,
        }
    }
}

impl SimConfig {
    pub fn with_strategy(mut self, s: StrategyKind) -> Self {
        self.strategy = s;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_horizon_ns(mut self, h: u64) -> Self {
        self.horizon_ns = h;
        self
    }

    pub fn with_num_gpus(mut self, g: usize) -> Self {
        self.num_gpus = g;
        self
    }

    pub fn with_arrivals(mut self, arrivals: ArrivalProcess) -> Self {
        self.arrivals = arrivals;
        self
    }

    pub fn with_arrival_queue_cap(mut self, cap: usize) -> Self {
        self.arrival_queue_cap = cap;
        self
    }

    pub fn with_faults(mut self, faults: FaultSpec) -> Self {
        self.faults = faults;
        self
    }

    pub fn with_arbiter(mut self, arbiter: ArbiterKind) -> Self {
        self.arbiter = arbiter;
        self
    }

    pub fn with_classes(mut self, classes: Vec<TenantClass>) -> Self {
        self.classes = classes;
        self
    }

    pub fn with_concurrency(mut self, mode: ConcurrencyMode) -> Self {
        self.concurrency = mode;
        self
    }

    pub fn with_autoscale(mut self, auto: crate::control::elastic::AutoscaleSpec) -> Self {
        self.autoscale = Some(auto);
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_ten_seconds_none() {
        let cfg = SimConfig::default();
        assert_eq!(cfg.strategy, StrategyKind::None);
        assert_eq!(cfg.horizon_ns, 10_000_000_000);
        assert_eq!(cfg.platform.num_sms, 8);
        assert_eq!(cfg.num_gpus, 1, "default fleet is the paper's single GPU");
    }

    #[test]
    fn builder_helpers() {
        let cfg = SimConfig::default()
            .with_strategy(StrategyKind::Worker)
            .with_seed(9)
            .with_horizon_ns(123)
            .with_num_gpus(4)
            .with_arrivals(ArrivalProcess::Poisson { rate_hz: 200.0 })
            .with_arrival_queue_cap(16)
            .with_faults("hang:period=100:ms=5".parse().unwrap())
            .with_arbiter(ArbiterKind::Wrr)
            .with_classes(crate::control::arbiter::parse_classes("gold:weight=3,free").unwrap())
            .with_concurrency(ConcurrencyMode::Mps { quota: 2 })
            .with_autoscale("1..4".parse().unwrap());
        assert_eq!(cfg.strategy, StrategyKind::Worker);
        assert_eq!(cfg.seed, 9);
        assert_eq!(cfg.horizon_ns, 123);
        assert_eq!(cfg.num_gpus, 4);
        assert_eq!(cfg.arrivals, ArrivalProcess::Poisson { rate_hz: 200.0 });
        assert_eq!(cfg.arrival_queue_cap, 16);
        assert!(cfg.faults.has_sim_clauses());
        assert_eq!(cfg.arbiter, ArbiterKind::Wrr);
        assert_eq!(cfg.classes.len(), 2);
        assert_eq!(cfg.classes[0].weight, 3);
        assert_eq!(cfg.concurrency, ConcurrencyMode::Mps { quota: 2 });
        assert_eq!(
            cfg.autoscale,
            Some(crate::control::elastic::AutoscaleSpec { min: 1, max: 4 })
        );
    }

    #[test]
    fn default_autoscale_is_off() {
        // Golden traces are pinned against the fixed fleet: autoscale
        // must stay opt-in.
        assert_eq!(SimConfig::default().autoscale, None);
    }

    #[test]
    fn default_arbiter_is_fifo_with_no_classes() {
        let cfg = SimConfig::default();
        assert_eq!(cfg.arbiter, ArbiterKind::Fifo);
        assert!(cfg.classes.is_empty());
    }

    #[test]
    fn default_is_closed_loop() {
        let cfg = SimConfig::default();
        assert_eq!(cfg.arrivals, ArrivalProcess::ClosedLoop);
        assert!(!cfg.arrivals.is_open_loop());
    }

    #[test]
    fn default_concurrency_is_cook() {
        // The golden traces are pinned against this: the default mode
        // must stay the paper's exclusive gate.
        let cfg = SimConfig::default();
        assert_eq!(cfg.concurrency, ConcurrencyMode::Cook);
        assert!(cfg.concurrency.is_cook());
    }
}
