//! Timing model constants for the Volta/CUDA-stack simulator.
//!
//! Absolute values are calibrated so the unmitigated (`none`) runs land in
//! the paper's regime (cuda_mmult ~8 Mcycles in isolation, ~28 Mcycles in
//! parallel; onnx_dna ~113 inferences/s in isolation). What the evaluation
//! relies on is the *relative* shape, which these constants preserve; see
//! EXPERIMENTS.md for paper-vs-measured.


#[derive(Debug, Clone)]
pub struct TimingConfig {
    // -------------------------------------------------- host-side costs --
    /// CPU cost of an asynchronous GPU routine call (enqueue into stream).
    pub launch_overhead_ns: u64,
    /// Extra CPU cost of a `cudaMemcpy` routine over a kernel launch.
    pub memcpy_call_extra_ns: u64,
    /// Latency for a host thread to observe a device-side completion
    /// (synchronisation primitive wake-up).
    pub sync_wakeup_ns: u64,

    // ----------------------------------------------------- device costs --
    /// Fixed front-end cost from stream head to block scheduler.
    pub dispatch_ns: u64,
    /// Copy-engine throughput, bytes per microsecond.
    pub copy_bytes_per_us: u64,
    /// Fixed per-copy setup cost on the copy engine.
    pub copy_setup_ns: u64,

    // ---------------------------------------------- context switch model --
    /// Scheduling quantum: how long one context keeps the GPU while
    /// another has pending work.
    pub ctx_quantum_ns: u64,
    /// Cost of a GPU context switch that must save resident state
    /// (registers of frozen blocks) — a mid-kernel preemption.
    pub ctx_switch_ns: u64,
    /// Cost of switching between *drained* contexts (runlist update only,
    /// nothing to save).
    pub idle_switch_ns: u64,
    /// Cache-related preemption delay added to blocks resumed after the
    /// other context polluted L1/L2 (per resumed block).
    pub crpd_ns: u64,

    // ------------------------------------------------------- callbacks --
    /// Driver latency from a host-func op reaching the stream head to its
    /// callback starting on a callback thread.
    pub cb_dispatch_ns: u64,
    /// CPU execution time of the acquire/release callback bodies.
    pub cb_exec_ns: u64,
    /// CPU time *stolen from the application host thread* per callback:
    /// the driver's callback threads run inside the application process,
    /// preempting host code and polluting its CPU caches. This is why the
    /// callback strategy devastates host-heavy applications (onnx_dna IPS
    /// 113 -> 37) while barely affecting host-idle ones (cuda_mmult).
    pub cb_steal_ns: u64,

    // ------------------------------------------------------------ lock --
    /// Semaphore handoff latency (release to next-waiter wakeup) for
    /// application host/worker threads (cross-process futex + scheduler).
    pub lock_handoff_ns: u64,
    /// Wakeup latency when the head waiter is a driver callback thread
    /// (hot, busy-polling driver threads wake much faster).
    pub cb_wake_ns: u64,

    // ---------------------------------------------------------- worker --
    /// Host cost to deep-copy kernel arguments into the worker queue
    /// (the registered-kernel argument-layout walk of §V-B3).
    pub worker_enqueue_ns: u64,
    /// Worker loop cost to dequeue one operation.
    pub worker_dequeue_ns: u64,
    /// Extra per-operation delay when the worker thread contends with a
    /// busy application host thread for CPU resources (the ONNX runtime's
    /// own thread pool competes with the worker; an idle host — like
    /// cuda_mmult waiting at its barrier — costs nothing).
    pub worker_contention_ns: u64,

    // ------------------------------------------------------ variability --
    /// Multiplicative execution jitter amplitude on kernel blocks
    /// (inherent variability, present even in isolation).
    pub jitter_amp: f64,
    /// Probability that dispatching an op while the *other* context is
    /// active at the driver level hits a software-stack stall (shared
    /// queue collision — the paper's rare 1200x onnx_dna outliers).
    pub stall_prob: f64,
    /// Pareto shape of the stall duration multiplier.
    pub stall_alpha: f64,
    /// Stall duration cap, as a multiple of the stalled op's own cost.
    pub stall_cap: f64,
    /// Window after another context's device activity during which a
    /// dispatch is exposed to shared-queue stalls.
    pub stall_window_ns: u64,
    /// Probability of an *inherent* heavy-tail kernel instance (present
    /// even in isolation — onnx_dna exhibits these, Fig. 10).
    pub inherent_tail_prob: f64,
    /// Cap of the inherent tail multiplier.
    pub inherent_tail_cap: f64,
}

impl Default for TimingConfig {
    fn default() -> Self {
        Self {
            launch_overhead_ns: 5_000,
            memcpy_call_extra_ns: 2_000,
            sync_wakeup_ns: 12_000,
            dispatch_ns: 2_000,
            copy_bytes_per_us: 20_000, // ~20 GB/s effective
            copy_setup_ns: 4_000,
            ctx_quantum_ns: 60_000,
            ctx_switch_ns: 15_000,
            idle_switch_ns: 5_000,
            crpd_ns: 15_000,
            cb_dispatch_ns: 5_000,
            cb_exec_ns: 4_000,
            cb_steal_ns: 250_000,
            lock_handoff_ns: 120_000,
            cb_wake_ns: 5_000,
            worker_enqueue_ns: 3_000,
            worker_dequeue_ns: 6_000,
            worker_contention_ns: 55_000,
            jitter_amp: 0.03,
            stall_prob: 0.002,
            stall_alpha: 0.55,
            stall_cap: 1200.0,
            stall_window_ns: 200_000,
            inherent_tail_prob: 0.0008,
            inherent_tail_cap: 200.0,
        }
    }
}

impl TimingConfig {
    /// Duration of a host-to-device or device-to-host copy of `bytes`.
    pub fn copy_duration_ns(&self, bytes: u64) -> u64 {
        self.copy_setup_ns + bytes * 1_000 / self.copy_bytes_per_us.max(1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn copy_duration_scales_with_bytes() {
        let t = TimingConfig::default();
        let small = t.copy_duration_ns(1_000);
        let big = t.copy_duration_ns(1_000_000);
        assert!(big > small);
        // 1 MB at 20 GB/s ~ 50 us + setup.
        assert_eq!(big, t.copy_setup_ns + 50_000);
    }

    #[test]
    fn defaults_are_sane() {
        let t = TimingConfig::default();
        assert!(t.ctx_quantum_ns > t.ctx_switch_ns);
        assert!(t.stall_prob < 0.05, "stalls must stay rare (<0.5% of ops)");
        assert!(t.jitter_amp < 0.2);
    }

    #[test]
    fn zero_throughput_guard() {
        let t = TimingConfig { copy_bytes_per_us: 0, ..Default::default() };
        // Must not divide by zero.
        let _ = t.copy_duration_ns(100);
    }
}
