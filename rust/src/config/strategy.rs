//! Access-control strategy selection (§V-B of the paper).

use std::fmt;
use std::str::FromStr;

/// Which access-control strategy the generated hook library applies.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum StrategyKind {
    /// No hooks: the unmitigated platform behaviour.
    None,
    /// Host Callback strategy (Alg. 3): acquire/release ride the stream as
    /// `cudaLaunchHostFunc` operations around every kernel/copy.
    Callback,
    /// Synchronised Operation strategy (Alg. 4): the hook itself acquires
    /// the GPU lock, inserts the op, synchronises, releases. RGEM-like.
    Synced,
    /// Deferred Worker strategy (Alg. 5-7): ops transit through a per-app
    /// worker thread which serialises them under the GPU lock.
    Worker,
    /// Persistent-Thread-Block spatial baseline (§VII-B): each instance is
    /// pinned to a fixed subset of SMs; no temporal locking. Requires a
    /// cooperative application, violating Aspect 1 — included only as the
    /// paper's comparison point.
    Ptb,
}

impl StrategyKind {
    /// The four configurations of Figures 9/10 and Table I.
    pub const PAPER_SET: [StrategyKind; 4] =
        [Self::None, Self::Callback, Self::Synced, Self::Worker];

    /// All implemented strategies (paper set + PTB baseline).
    pub const ALL: [StrategyKind; 5] =
        [Self::None, Self::Callback, Self::Synced, Self::Worker, Self::Ptb];

    /// Does this strategy guarantee temporal isolation of GPU operations?
    /// (§VII-B: synced and worker do; callback fails; none/ptb don't try.)
    pub fn isolates(&self) -> bool {
        matches!(self, Self::Synced | Self::Worker)
    }

    /// Does the strategy require application cooperation (Aspect 1)?
    pub fn requires_cooperation(&self) -> bool {
        matches!(self, Self::Ptb)
    }

    pub fn name(&self) -> &'static str {
        match self {
            Self::None => "none",
            Self::Callback => "callback",
            Self::Synced => "synced",
            Self::Worker => "worker",
            Self::Ptb => "ptb",
        }
    }
}

impl fmt::Display for StrategyKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

impl FromStr for StrategyKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, Self::Err> {
        match s {
            "none" => Ok(Self::None),
            "callback" => Ok(Self::Callback),
            "synced" => Ok(Self::Synced),
            "worker" => Ok(Self::Worker),
            "ptb" => Ok(Self::Ptb),
            other => Err(format!("unknown strategy '{other}' (expected none|callback|synced|worker|ptb)")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for s in StrategyKind::ALL {
            assert_eq!(s.name().parse::<StrategyKind>().unwrap(), s);
        }
        assert!("mps".parse::<StrategyKind>().is_err());
    }

    #[test]
    fn isolation_claims_match_paper() {
        assert!(!StrategyKind::None.isolates());
        assert!(!StrategyKind::Callback.isolates()); // §VII-B: fails
        assert!(StrategyKind::Synced.isolates());
        assert!(StrategyKind::Worker.isolates());
        assert!(!StrategyKind::Ptb.isolates());
    }

    #[test]
    fn only_ptb_requires_cooperation() {
        for s in StrategyKind::ALL {
            assert_eq!(s.requires_cooperation(), s == StrategyKind::Ptb);
        }
    }
}
