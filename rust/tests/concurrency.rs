//! Concurrency-mode suite (ISSUE 9, DESIGN.md §14).
//!
//! The contract pinned here, layer by layer:
//!
//! * **cook is the paper** — the default mode is `Cook` and a run under
//!   it is bit-identical to a run that never mentions concurrency at
//!   all (the golden-trace suite pins the absolute values; this suite
//!   pins the equivalence).
//! * **mig partitions** — tenant classes never share an SM bank or an
//!   L2 slice, in the masks and in the executed block trace.
//! * **mps pays nothing for sharing** — on a contended 2-app workload
//!   spatial co-running completes at least as much work as cook's
//!   serialised access (it drops the lock handoffs and context
//!   switches).
//! * **streams preempt only at kernel boundaries** — a streams trace
//!   contains zero resumed (mid-kernel frozen) blocks, and the
//!   higher-priority class gets at least its peer's throughput.
//! * **every mode is thread-count invariant** — `COOK_SIM_THREADS` is a
//!   pure throughput knob for sharing modes exactly as it is for cook.
//! * **the live gate obeys the same mode** — multi-holder admission up
//!   to the quota, and the lease watchdog revokes exactly the hung
//!   ticket of a multi-holder grant.

use cook::config::{SimConfig, StrategyKind};
use cook::control::arbiter::{parse_classes, ArbiterKind};
use cook::control::concurrency::{ConcurrencyMode, ModeGate};
use cook::gpu::Sim;
use cook::util::AppId;
use std::collections::BTreeSet;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

// ---------------------------------------------------------------------
// stable hashing (FNV-1a 64, same scheme as the golden_trace suite)
// ---------------------------------------------------------------------

struct Fnv(u64);

impl Fnv {
    fn new() -> Self {
        Fnv(0xcbf29ce484222325)
    }
    fn bytes(&mut self, b: &[u8]) {
        for &x in b {
            self.0 ^= x as u64;
            self.0 = self.0.wrapping_mul(0x100000001b3);
        }
    }
    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }
    fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }
    fn bool(&mut self, v: bool) {
        self.bytes(&[v as u8]);
    }
}

/// Hash everything observable about a finished run (trace tables,
/// completions, arrival report, placement) — the same coverage the
/// fleet-parallel suite uses.
fn full_hash(sim: &Sim) -> u64 {
    let mut h = Fnv::new();
    let t = &sim.trace;
    h.usize(t.ops.len());
    for r in &t.ops {
        h.u64(r.op.0);
        h.usize(r.app.0);
        h.bytes(t.sym_name(r.sym).as_bytes());
        h.bool(r.is_kernel);
        h.bool(r.is_copy);
        h.u64(r.enqueued_at);
        h.u64(r.started_at);
        h.u64(r.completed_at);
        h.usize(r.burst);
    }
    h.usize(t.blocks.len());
    for b in &t.blocks {
        h.u64(b.op.0);
        h.usize(b.app.0);
        h.usize(b.sm.0);
        h.u64(b.blocks as u64);
        h.u64(b.start);
        h.u64(b.end);
        h.bool(b.resumed);
    }
    h.usize(t.switches.len());
    for s in &t.switches {
        h.u64(s.at);
        h.u64(s.from.map(|c| c.0 as u64 + 1).unwrap_or(0));
        h.usize(s.to.0);
        h.u64(s.cost_ns);
    }
    h.usize(t.stalls.len());
    for s in &t.stalls {
        h.u64(s.op.0);
        h.u64(s.at);
        h.u64(s.duration_ns);
    }
    for a in 0..sim.apps.len() {
        let app = AppId(a);
        let comps = sim.completions(app);
        h.usize(comps.len());
        for &c in comps {
            h.u64(c);
        }
        let lat = sim.arrival_latencies(app);
        h.usize(lat.len());
        for &l in lat {
            h.u64(l);
        }
        let (offered, shed) = sim.arrival_counts(app);
        h.usize(offered);
        h.usize(shed);
        h.usize(sim.shard_of(app));
    }
    h.bool(sim.horizon_reached());
    h.0
}

fn cfg(strategy: StrategyKind, mode: ConcurrencyMode, seed: u64) -> SimConfig {
    let mut cfg = SimConfig::default()
        .with_strategy(strategy)
        .with_seed(seed)
        .with_classes(parse_classes("a,b").unwrap())
        .with_concurrency(mode);
    cfg.horizon_ns = 150_000_000;
    cfg
}

fn run_apps(cfg: SimConfig, apps: usize, threads: usize) -> Sim {
    let programs = (0..apps).map(|_| cook::apps::dna::program()).collect();
    let mut sim = Sim::new(cfg, programs);
    sim.run_with_sim_threads(threads);
    assert!(!sim.trace.ops.is_empty(), "degenerate run");
    sim
}

// ---------------------------------------------------------------------
// cook: the refactor is invisible
// ---------------------------------------------------------------------

#[test]
fn cook_is_the_default_and_changes_nothing() {
    // A run that never mentions concurrency at all must be bit-identical
    // to one that explicitly asks for cook: the golden traces (which
    // predate the ConcurrencyMode refactor) pin the absolute values,
    // this pins the equivalence — including classes and a fleet.
    for (strategy, gpus) in
        [(StrategyKind::Synced, 1usize), (StrategyKind::Worker, 2), (StrategyKind::None, 1)]
    {
        let mut plain = SimConfig::default().with_strategy(strategy).with_seed(7);
        plain.horizon_ns = 150_000_000;
        plain.num_gpus = gpus;
        assert!(plain.concurrency.is_cook(), "default mode must be cook");
        let explicit = plain.clone().with_concurrency(ConcurrencyMode::Cook);
        assert_eq!(
            full_hash(&run_apps(plain, 4, 2)),
            full_hash(&run_apps(explicit, 4, 2)),
            "{strategy} x{gpus}: explicit cook diverged from the default engine"
        );
    }
}

// ---------------------------------------------------------------------
// mig: hard partitions
// ---------------------------------------------------------------------

#[test]
fn mig_classes_never_share_sm_banks_or_l2_slices() {
    // 4 apps, 2 classes (app i -> class i % 2), mig:2 on one GPU: the
    // two classes must own disjoint SM banks and distinct L2 slices —
    // in the configured masks AND in the executed block trace.
    let sim = run_apps(cfg(StrategyKind::None, ConcurrencyMode::Mig { slices: 2 }, 5), 4, 1);
    assert_eq!(sim.l2_slice_count(), 2, "mig:2 must split the L2 in two");
    let class_of = |a: usize| a % 2;
    // Mask-level: banks of different classes are disjoint, same class
    // shares one bank, and no bank is empty.
    let banks: Vec<BTreeSet<usize>> =
        (0..4).map(|a| sim.sm_bank_of_app(AppId(a)).into_iter().collect()).collect();
    for a in 0..4 {
        assert!(!banks[a].is_empty(), "app {a} has an empty SM bank");
        assert_eq!(
            sim.l2_slice_of_app(AppId(a)),
            class_of(a),
            "app {a} on the wrong L2 slice"
        );
        for b in (a + 1)..4 {
            if class_of(a) == class_of(b) {
                assert_eq!(banks[a], banks[b], "same class, different banks ({a},{b})");
            } else {
                assert!(
                    banks[a].is_disjoint(&banks[b]),
                    "classes share SMs: app {a} {:?} vs app {b} {:?}",
                    banks[a],
                    banks[b]
                );
            }
        }
    }
    // Trace-level: every executed block landed inside its class's bank.
    let mut used: Vec<BTreeSet<usize>> = vec![BTreeSet::new(), BTreeSet::new()];
    for b in &sim.trace.blocks {
        used[class_of(b.app.0)].insert(b.sm.0);
        assert!(
            banks[b.app.0].contains(&b.sm.0),
            "app {} executed outside its bank (sm {})",
            b.app.0,
            b.sm.0
        );
    }
    assert!(
        used[0].is_disjoint(&used[1]),
        "executed blocks of the two classes shared SMs: {used:?}"
    );
    assert!(!used[0].is_empty() && !used[1].is_empty(), "a class never ran");
}

// ---------------------------------------------------------------------
// mps: sharing beats serialising
// ---------------------------------------------------------------------

#[test]
fn mps_aggregate_completions_match_or_beat_cook_under_contention() {
    // 2 apps contending for one GPU. Cook serialises through the synced
    // strategy's lock (handoffs, wakeups, context switches); mps:2
    // co-runs the apps on half-device SM banks with none of those
    // overheads — its aggregate completed work must not be lower.
    let cook = run_apps(cfg(StrategyKind::Synced, ConcurrencyMode::Cook, 13), 2, 1);
    let mps = run_apps(cfg(StrategyKind::None, ConcurrencyMode::Mps { quota: 2 }, 13), 2, 1);
    let total = |s: &Sim| (0..2).map(|a| s.completions(AppId(a)).len()).sum::<usize>();
    let (c, m) = (total(&cook), total(&mps));
    assert!(c > 0 && m > 0, "degenerate contention run (cook={c}, mps={m})");
    assert!(m >= c, "mps completed less than cook under contention ({m} < {c})");
    // And the sharing really is spatial: the two apps own disjoint banks.
    let (a, b): (BTreeSet<usize>, BTreeSet<usize>) = (
        mps.sm_bank_of_app(AppId(0)).into_iter().collect(),
        mps.sm_bank_of_app(AppId(1)).into_iter().collect(),
    );
    assert!(a.is_disjoint(&b) && !a.is_empty() && !b.is_empty());
}

// ---------------------------------------------------------------------
// streams: kernel-boundary preemption
// ---------------------------------------------------------------------

#[test]
fn streams_never_freeze_a_batch_mid_kernel() {
    // Streams preempt only at kernel boundaries: no batch is ever
    // frozen mid-execution, so the trace must contain zero resumed
    // blocks — while the class-priority schedule still switches contexts
    // and the high-priority class (class 0 = `a`) keeps at least its
    // peer's throughput.
    let sim = run_apps(cfg(StrategyKind::None, ConcurrencyMode::Streams, 19), 2, 1);
    let resumed = sim.trace.blocks.iter().filter(|b| b.resumed).count();
    assert_eq!(resumed, 0, "streams froze {resumed} batches mid-kernel");
    assert!(!sim.trace.switches.is_empty(), "streams never scheduled a switch");
    let hi = sim.completions(AppId(0)).len();
    let lo = sim.completions(AppId(1)).len();
    assert!(hi > 0, "high-priority stream starved");
    assert!(
        hi >= lo,
        "priority inverted: class a completed {hi}, class b completed {lo}"
    );
    // The same workload under cook's quantum-sliced temporal scheduling
    // is the contrast: it may freeze batches at quantum expiry; streams
    // structurally cannot.
    let cook = run_apps(cfg(StrategyKind::None, ConcurrencyMode::Cook, 19), 2, 1);
    assert!(
        !cook.trace.blocks.is_empty() && !sim.trace.blocks.is_empty(),
        "degenerate streams-vs-cook comparison"
    );
}

// ---------------------------------------------------------------------
// every mode: the thread knob is pure throughput
// ---------------------------------------------------------------------

#[test]
fn all_modes_identical_across_thread_counts() {
    // mig is the regression target: its masks follow GLOBAL tenant
    // classes, which the sharded runner deals from the parent — a
    // sub-sim recomputing them from local indices diverges here.
    for mode in [
        ConcurrencyMode::Cook,
        ConcurrencyMode::Mps { quota: 2 },
        ConcurrencyMode::Mig { slices: 2 },
        ConcurrencyMode::Streams,
    ] {
        let mk = || {
            let mut c = cfg(StrategyKind::Synced, mode, 43);
            c.num_gpus = 2;
            c
        };
        let seq = full_hash(&run_apps(mk(), 4, 1));
        for threads in [2usize, 4, 8] {
            assert_eq!(
                seq,
                full_hash(&run_apps(mk(), 4, threads)),
                "{mode}: {threads} threads changed the run"
            );
        }
    }
}

// ---------------------------------------------------------------------
// the live gate: mode-defined admission
// ---------------------------------------------------------------------

#[test]
fn live_mps_gate_admits_the_quota_and_cook_admits_one() {
    for (mode, expect_peak) in
        [(ConcurrencyMode::Cook, 1usize), (ConcurrencyMode::Mps { quota: 3 }, 3)]
    {
        let gate = Arc::new(ModeGate::new(mode, ArbiterKind::Fifo, &[], None));
        let inside = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..6 {
                let (gate, inside, peak) =
                    (Arc::clone(&gate), Arc::clone(&inside), Arc::clone(&peak));
                s.spawn(move || {
                    for _ in 0..20 {
                        let grant = gate.acquire_class(0);
                        let now = inside.fetch_add(1, Ordering::SeqCst) + 1;
                        peak.fetch_max(now, Ordering::SeqCst);
                        std::thread::sleep(Duration::from_micros(200));
                        inside.fetch_sub(1, Ordering::SeqCst);
                        gate.release(grant);
                    }
                });
            }
        });
        let peak = peak.load(Ordering::SeqCst);
        assert!(
            peak <= expect_peak,
            "{mode}: {peak} concurrent holders exceeded the cap {expect_peak}"
        );
        assert_eq!(gate.stats().grants(), 120, "{mode}: grant accounting");
    }
}

#[test]
fn live_lease_revokes_exactly_the_hung_ticket_of_a_multi_holder_grant() {
    // Two concurrent holders under mps:2 with a short lease; one hangs,
    // one keeps working. The watchdog must revoke exactly the hung
    // ticket: the live holder's grant stays valid and the waiter gets
    // the freed slot.
    let gate =
        ModeGate::new(ConcurrencyMode::Mps { quota: 2 }, ArbiterKind::Fifo, &[], Some(
            Duration::from_millis(30),
        ));
    let hung = gate.acquire_class(0);
    std::thread::sleep(Duration::from_millis(5));
    let live = gate.acquire_class(0);
    // Full gate: this third acquire waits, arms the watchdog, and gets
    // the slot freed by revoking the OLDEST (hung) holder.
    let third = gate.acquire_class(0);
    assert!(hung.is_revoked(), "the hung ticket must be revoked");
    assert!(!live.is_revoked(), "the live co-holder must keep its grant");
    assert!(!third.is_revoked());
    let stats = gate.stats();
    assert_eq!(stats.revocations, 1, "exactly one ticket revoked");
    assert!(stats.mode.starts_with("mps"), "stats must carry the mode");
    drop(hung);
    drop(live);
    drop(third);
    // One hold entry per grant (the revoked one was recorded at
    // revocation time, the live ones at drop).
    assert_eq!(gate.stats().grants(), 3);
}
